"""Model family configs + artifact enumeration.

The flat-parameter layout defined here is mirrored bit-for-bit by
``rust/src/model/layout.rs``; any change must be made in both places.
All parameters are f32, row-major, concatenated in the order below:

  tok_embed (V, d)
  pos_embed (S, d)
  ln1_g (L, d)   ln1_b (L, d)
  wq (L, d, d)   wk (L, d, d)   wv (L, d, d)   wo (L, d, d)
  ln2_g (L, d)   ln2_b (L, d)
  w1 (L, F, d)   w2 (L, d, F)
  lnf_g (d)      lnf_b (d)

Linears are bias-free and stored (out, in); a layer computes ``x @ W.T``.
The LM head is tied to ``tok_embed`` (the paper excludes embeddings and the
head from pruning, as standard).

A *block slice* (the input to the ``block_fwd`` artifact) is block ``l``'s
parameters concatenated flat in the order:
  ln1_g, ln1_b, wq, wk, wv, wo, ln2_g, ln2_b, w1, w2
"""

from dataclasses import dataclass, field


VOCAB = 512
SEQ = 128


@dataclass(frozen=True)
class ModelConfig:
    name: str
    d: int
    layers: int
    heads: int
    train_batch: int
    eval_batch: int = 8
    vocab: int = VOCAB
    seq: int = SEQ

    @property
    def ffn(self) -> int:
        return 4 * self.d

    @property
    def head_dim(self) -> int:
        assert self.d % self.heads == 0
        return self.d // self.heads

    # ---- flat layout ----------------------------------------------------
    def param_entries(self):
        """(name, shape) in flat concatenation order."""
        d, L, F = self.d, self.layers, self.ffn
        return [
            ("tok_embed", (self.vocab, d)),
            ("pos_embed", (self.seq, d)),
            ("ln1_g", (L, d)),
            ("ln1_b", (L, d)),
            ("wq", (L, d, d)),
            ("wk", (L, d, d)),
            ("wv", (L, d, d)),
            ("wo", (L, d, d)),
            ("ln2_g", (L, d)),
            ("ln2_b", (L, d)),
            ("w1", (L, F, d)),
            ("w2", (L, d, F)),
            ("lnf_g", (d,)),
            ("lnf_b", (d,)),
        ]

    def param_offsets(self):
        """name -> (offset, shape) into the flat vector."""
        out, off = {}, 0
        for name, shape in self.param_entries():
            n = 1
            for s in shape:
                n *= s
            out[name] = (off, shape)
            off += n
        return out

    @property
    def n_params(self) -> int:
        off = 0
        for _, shape in self.param_entries():
            n = 1
            for s in shape:
                n *= s
            off += n
        return off

    # ---- per-block slice -------------------------------------------------
    def block_entries(self):
        d, F = self.d, self.ffn
        return [
            ("ln1_g", (d,)),
            ("ln1_b", (d,)),
            ("wq", (d, d)),
            ("wk", (d, d)),
            ("wv", (d, d)),
            ("wo", (d, d)),
            ("ln2_g", (d,)),
            ("ln2_b", (d,)),
            ("w1", (F, d)),
            ("w2", (d, F)),
        ]

    def block_offsets(self):
        out, off = {}, 0
        for name, shape in self.block_entries():
            n = 1
            for s in shape:
                n *= s
            out[name] = (off, shape)
            off += n
        return out

    @property
    def block_size(self) -> int:
        off = 0
        for _, shape in self.block_entries():
            n = 1
            for s in shape:
                n *= s
            off += n
        return off

    def prune_shapes(self):
        """Distinct (d_row, d_col) of prunable linears: q/k/v/o, fc1, fc2."""
        d, F = self.d, self.ffn
        return [(d, d), (F, d), (d, F)]

    def hessian_dims(self):
        return [self.d, self.ffn]


CONFIGS = {
    c.name: c
    for c in [
        # name,        d,   L,  h, train_batch — stand-ins for OPT sizes
        ModelConfig("nano", 64, 2, 2, 32),
        ModelConfig("micro", 128, 4, 4, 16),
        ModelConfig("small", 256, 6, 8, 8),
        ModelConfig("medium", 512, 8, 8, 4),
        ModelConfig("large", 768, 12, 12, 2),
    ]
}

# Calibration is fed in chunks of EVAL_BATCH segments; a chunk contributes
# EVAL_BATCH * SEQ activation rows to each Hessian.
CHUNK_TOKENS = 8 * SEQ  # 1024

# Lazy-update / mask-selection blocksize of the primary (Pallas) solver.
BLOCKSIZE = 128
# Mask-selection blocksizes for the Fig-10 ablation (jnp solver variants,
# lowered only for the `small` config).
ABLATION_BS = [1, 16, 64, 128, 512, 1024]
