"""Layer 2: training step (fwd + bwd + Adam) over the flat parameter vector.

Lowered once per config to ``train_step_<cfg>.hlo.txt``; the Rust launcher
owns the training loop, LR schedule, data order and checkpointing. The step
counter and learning rate enter as runtime scalars so a single artifact
serves any schedule.
"""

import jax
import jax.numpy as jnp

from .configs import ModelConfig
from .model import nll_fn

ADAM_B1 = 0.9
ADAM_B2 = 0.95
ADAM_EPS = 1e-8
GRAD_CLIP = 1.0


def loss_fn(cfg: ModelConfig, flat, tokens):
    return jnp.mean(nll_fn(cfg, flat, tokens))


def train_step_fn(cfg: ModelConfig, flat, m, v, step, lr, tokens):
    """(params, adam_m, adam_v, step, lr, tokens (B,T+1)) ->
    (params', m', v', loss).

    ``step`` is the 1-based step number as f32 (bias correction);
    global-norm gradient clipping at GRAD_CLIP.
    """
    loss, g = jax.value_and_grad(loss_fn, argnums=1)(cfg, flat, tokens)
    gnorm = jnp.sqrt(jnp.sum(jnp.square(g)) + 1e-12)
    g = g * jnp.minimum(1.0, GRAD_CLIP / gnorm)
    m = ADAM_B1 * m + (1.0 - ADAM_B1) * g
    v = ADAM_B2 * v + (1.0 - ADAM_B2) * jnp.square(g)
    mhat = m / (1.0 - ADAM_B1**step)
    vhat = v / (1.0 - ADAM_B2**step)
    flat = flat - lr * mhat / (jnp.sqrt(vhat) + ADAM_EPS)
    return flat, m, v, loss
