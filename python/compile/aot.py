"""AOT driver: enumerate every artifact, lower to HLO text, write manifest.

Run once at build time (``make artifacts``); the Rust runtime consumes
``artifacts/manifest.json`` plus the ``*.hlo.txt`` files and Python never
appears on the request path again.

Artifact inventory (shapes static per config; scalars are runtime inputs):
  train_step_<cfg>   (P, P, P, step, lr, tokens(tb,S+1))->(P, P, P, loss)
  nll_<cfg>          (P, tokens(eb,S+1)) -> nll(eb,S)
  embed_<cfg>        (P, tokens(eb,S)) -> hidden(eb,S,d)
  block_fwd_<cfg>    (block_slice, hidden) -> (hidden', x_qkv, x_wo, x_fc1, x_fc2)
  sparsegpt_<r>x<c>      (W, HinvChol, p, qlevels) -> (W_hat, mask)
  sparsegpt24_<r>x<c>    2:4 variant (same inputs; p ignored)
  sparsegpt48_<r>x<c>    4:8 variant
  sparsegpt_bs<Bs>_<r>x<c>  Fig-10 ablation (jnp solver), `small` shapes only
  adaprune_<r>x<c>       (W, mask, H, lr) -> W_hat
  hessian_<dim>          (X(chunk,dim)) -> X^T X

Incremental: existing .hlo.txt files are kept unless --force; the manifest is
always rewritten from the full enumeration (merged with a previous manifest
when --configs restricts the set).
"""

import argparse
import functools
import json
import os
import sys
import time

import jax
import jax.numpy as jnp

from .configs import ABLATION_BS, BLOCKSIZE, CHUNK_TOKENS, CONFIGS, SEQ, VOCAB
from . import model, train
from .sparsegpt import sparsegpt_layer_fn, sparsegpt_layer_jnp_fn
from .adaprune import adaprune_fn, ADAPRUNE_STEPS
from .kernels.hessian import hessian_chunk
from .linalg_jnp import hessian_prep_fn
from .hlo import lower_to_hlo_text

F32 = jnp.float32
I32 = jnp.int32


def _spec(shape, dtype=F32):
    return jax.ShapeDtypeStruct(shape, dtype)


def _sparsegpt_nm_fn(nm, w, hinv_chol, qlevels):
    return sparsegpt_layer_fn(w, hinv_chol, jnp.float32(0.0), qlevels, nm=nm)


def _shape_entry(s):
    return [str(s.dtype), list(s.shape)]


def enumerate_artifacts(config_names):
    """name -> (fn, example_args). Deduped across configs."""
    arts = {}

    for name in config_names:
        cfg = CONFIGS[name]
        P = _spec((cfg.n_params,))
        tb_tok = _spec((cfg.train_batch, SEQ + 1), I32)
        eb_tok1 = _spec((cfg.eval_batch, SEQ + 1), I32)
        eb_tok = _spec((cfg.eval_batch, SEQ), I32)
        hid = _spec((cfg.eval_batch, SEQ, cfg.d))
        blk = _spec((cfg.block_size,))
        s = _spec(())

        arts[f"train_step_{name}"] = (
            functools.partial(train.train_step_fn, cfg),
            (P, P, P, s, s, tb_tok),
        )
        arts[f"nll_{name}"] = (functools.partial(model.nll_fn, cfg), (P, eb_tok1))
        arts[f"next_logits_{name}"] = (
            functools.partial(model.next_logits_fn, cfg),
            (P, _spec((1, SEQ), I32)),
        )
        arts[f"embed_{name}"] = (functools.partial(model.embed_fn, cfg), (P, eb_tok))
        arts[f"block_fwd_{name}"] = (
            functools.partial(model.block_fwd_fn, cfg),
            (blk, hid),
        )
        arts[f"block_hess_{name}"] = (
            functools.partial(model.block_hess_fn, cfg),
            (blk, hid, s),
        )
        arts[f"block_prop_{name}"] = (
            functools.partial(model.block_prop_fn, cfg),
            (blk, hid),
        )

        for (r, c) in cfg.prune_shapes():
            w = _spec((r, c))
            hc = _spec((c, c))
            arts[f"sparsegpt_{r}x{c}"] = (sparsegpt_layer_fn, (w, hc, s, s))
            # n:m variants ignore the sparsity scalar, and XLA drops unused
            # parameters during lowering — so their signature omits it.
            arts[f"sparsegpt24_{r}x{c}"] = (
                functools.partial(_sparsegpt_nm_fn, (2, 4)),
                (w, hc, s),
            )
            arts[f"sparsegpt48_{r}x{c}"] = (
                functools.partial(_sparsegpt_nm_fn, (4, 8)),
                (w, hc, s),
            )
            arts[f"adaprune_{r}x{c}"] = (adaprune_fn, (w, w, hc, s))

        for dim in cfg.hessian_dims():
            arts[f"hessian_{dim}"] = (hessian_chunk, (_spec((CHUNK_TOKENS, dim)),))
            arts[f"hessian_prep_{dim}"] = (
                hessian_prep_fn,
                (_spec((dim, dim)), _spec(())),
            )

        if name == "small":
            for (r, c) in cfg.prune_shapes():
                for bs in ABLATION_BS:
                    if bs > c or c % bs != 0 or bs == BLOCKSIZE:
                        continue
                    arts[f"sparsegpt_bs{bs}_{r}x{c}"] = (
                        functools.partial(sparsegpt_layer_jnp_fn, bs),
                        (_spec((r, c)), _spec((c, c)), s, s),
                    )

    return arts


def config_manifest_entry(cfg):
    return {
        "d": cfg.d,
        "layers": cfg.layers,
        "heads": cfg.heads,
        "ffn": cfg.ffn,
        "vocab": cfg.vocab,
        "seq": cfg.seq,
        "n_params": cfg.n_params,
        "block_size": cfg.block_size,
        "train_batch": cfg.train_batch,
        "eval_batch": cfg.eval_batch,
        "param_layout": [
            [n, off, list(shape)] for n, (off, shape) in cfg.param_offsets().items()
        ],
        "block_layout": [
            [n, off, list(shape)] for n, (off, shape) in cfg.block_offsets().items()
        ],
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--configs", default="all")
    ap.add_argument("--only", default=None, help="substring filter on artifact names")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()

    names = list(CONFIGS) if args.configs == "all" else args.configs.split(",")
    for n in names:
        if n not in CONFIGS:
            sys.exit(f"unknown config {n!r}; have {list(CONFIGS)}")

    os.makedirs(args.out_dir, exist_ok=True)
    manifest_path = os.path.join(args.out_dir, "manifest.json")
    manifest = {
        "version": 1,
        "seq": SEQ,
        "vocab": VOCAB,
        "chunk_tokens": CHUNK_TOKENS,
        "blocksize": BLOCKSIZE,
        "adaprune_steps": ADAPRUNE_STEPS,
        "configs": {},
        "artifacts": {},
    }
    if os.path.exists(manifest_path):
        with open(manifest_path) as f:
            old = json.load(f)
        manifest["configs"].update(old.get("configs", {}))
        manifest["artifacts"].update(old.get("artifacts", {}))

    for name in names:
        manifest["configs"][name] = config_manifest_entry(CONFIGS[name])

    arts = enumerate_artifacts(names)
    total = len(arts)
    for idx, (aname, (fn, ex_args)) in enumerate(sorted(arts.items())):
        if args.only and args.only not in aname:
            continue
        out_shapes = jax.eval_shape(fn, *ex_args)
        if not isinstance(out_shapes, (tuple, list)):
            out_shapes = (out_shapes,)
        fname = f"{aname}.hlo.txt"
        manifest["artifacts"][aname] = {
            "file": fname,
            "inputs": [_shape_entry(a) for a in ex_args],
            "outputs": [_shape_entry(o) for o in out_shapes],
        }
        path = os.path.join(args.out_dir, fname)
        if os.path.exists(path) and not args.force:
            continue
        t0 = time.time()
        text = lower_to_hlo_text(fn, ex_args)
        tmp = path + ".tmp"
        with open(tmp, "w") as f:
            f.write(text)
        os.replace(tmp, path)
        print(
            f"[{idx + 1}/{total}] {aname}: {len(text) / 1e6:.2f} MB "
            f"in {time.time() - t0:.1f}s",
            flush=True,
        )

    with open(manifest_path + ".tmp", "w") as f:
        json.dump(manifest, f, indent=1, sort_keys=True)
    os.replace(manifest_path + ".tmp", manifest_path)
    print(f"manifest: {len(manifest['artifacts'])} artifacts -> {manifest_path}")


if __name__ == "__main__":
    main()
