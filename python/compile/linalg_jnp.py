"""Blocked dense linear algebra in pure jnp (no LAPACK custom-calls).

The pinned xla_extension 0.5.1 behind the Rust runtime cannot execute the
custom-calls that ``jnp.linalg.cholesky``/``inv`` lower to on CPU, so the
Hessian preparation chain of SparseGPT —

    H_damped = H + damp * mean(diag H) * I          (App. A dampening)
    L        = chol(H_damped)
    H^{-1}   = L^{-T} L^{-1}
    U        = chol(H^{-1})^T   (upper factor consumed by Algorithm 1)

— is implemented here with explicit right-looking blocked algorithms whose
panel work is masked ``fori_loop`` arithmetic and whose trailing updates are
plain matmuls (the XLA CPU backend executes those near-roofline). Lowered
once per layer width as the ``hessian_prep_<dim>`` artifact.
"""

import jax
import jax.numpy as jnp

PANEL = 128


def _chol_unblocked(a):
    """Cholesky (lower) of a small SPD block via masked right-looking steps.
    a: (b, b). Runs b fori steps of O(b^2) masked arithmetic."""
    b = a.shape[0]
    row = jax.lax.broadcasted_iota(jnp.int32, (b, b), 0)
    col = jax.lax.broadcasted_iota(jnp.int32, (b, b), 1)

    def body(j, a):
        piv = jnp.sqrt(jax.lax.dynamic_slice(a, (j, j), (1, 1)))  # (1,1)
        colj = jax.lax.dynamic_slice(a, (0, j), (b, 1)) / piv     # (b,1)
        colj = jnp.where(row[:, :1] > j, colj, jnp.where(row[:, :1] == j, piv, 0.0))
        # trailing update: a[j+1:, j+1:] -= colj[j+1:] colj[j+1:]^T
        outer = colj * colj.reshape(1, b)[:, :]  # broadcast (b,1)*(1,b) -> (b,b)
        outer = colj @ colj.T
        upd = jnp.where((row > j) & (col > j), outer, 0.0)
        a = a - upd
        # write the finalized column j (and zero above-diagonal of column j)
        a = jnp.where(col == j, colj, a)
        return a

    a = jax.lax.fori_loop(0, b, body, a)
    return jnp.tril(a)


def _tril_inverse_unblocked(l):
    """Inverse of a small lower-triangular block via forward substitution:
    columnwise solve L x = e_j, all columns in parallel (masked updates)."""
    b = l.shape[0]
    eye = jnp.eye(b, dtype=l.dtype)
    row = jax.lax.broadcasted_iota(jnp.int32, (b, b), 0)

    def body(i, x):
        # x[i, :] = (eye[i, :] - L[i, :i] @ x[:i, :]) / L[i, i]
        li = jax.lax.dynamic_slice(l, (i, 0), (1, b))          # (1,b)
        mask = (row < i).astype(l.dtype)                        # zero rows >= i
        acc = (li * mask[:, 0:1].T) @ x                         # (1,b) of partial sums
        ei = jax.lax.dynamic_slice(eye, (i, 0), (1, b))
        lii = jax.lax.dynamic_slice(l, (i, i), (1, 1))
        xi = (ei - acc) / lii
        return jax.lax.dynamic_update_slice(x, xi, (i, 0))

    return jax.lax.fori_loop(0, b, body, jnp.zeros_like(l))


def blocked_cholesky(a, panel=PANEL):
    """Lower Cholesky factor of SPD ``a`` (n divisible by panel or n<=panel)."""
    n = a.shape[0]
    if n <= panel:
        return _chol_unblocked(a)
    assert n % panel == 0
    nb = n // panel
    blocks = [[a[i * panel:(i + 1) * panel, j * panel:(j + 1) * panel]
               for j in range(nb)] for i in range(nb)]
    lower = [[jnp.zeros((panel, panel), a.dtype) for _ in range(nb)] for _ in range(nb)]
    for k in range(nb):
        lkk = _chol_unblocked(blocks[k][k])
        lower[k][k] = lkk
        lkk_inv_t = _tril_inverse_unblocked(lkk).T
        for i in range(k + 1, nb):
            lower[i][k] = blocks[i][k] @ lkk_inv_t
        for i in range(k + 1, nb):
            for j in range(k + 1, i + 1):
                blocks[i][j] = blocks[i][j] - lower[i][k] @ lower[j][k].T
    return jnp.block(lower)


def blocked_tril_inverse(l, panel=PANEL):
    """Inverse of lower-triangular ``l`` by blocked forward substitution."""
    n = l.shape[0]
    if n <= panel:
        return _tril_inverse_unblocked(l)
    assert n % panel == 0
    nb = n // panel
    lb = [[l[i * panel:(i + 1) * panel, j * panel:(j + 1) * panel]
           for j in range(nb)] for i in range(nb)]
    x = [[jnp.zeros((panel, panel), l.dtype) for _ in range(nb)] for _ in range(nb)]
    for i in range(nb):
        x[i][i] = _tril_inverse_unblocked(lb[i][i])
    for i in range(1, nb):
        for j in range(i - 1, -1, -1):
            acc = jnp.zeros((panel, panel), l.dtype)
            for k in range(j, i):
                acc = acc + lb[i][k] @ x[k][j]
            x[i][j] = -(x[i][i] @ acc)
    return jnp.block(x)


def hessian_prep_fn(h, damp):
    """Artifact: (H, damp) -> upper Cholesky factor U of (H + damp*mean(diag)*I)^{-1}
    with H^{-1} = U^T U — the factor Algorithm 1 consumes."""
    n = h.shape[0]
    mean_diag = jnp.mean(jnp.diagonal(h))
    # guard fully-zero Hessians (dead layers): fall back to identity scale
    mean_diag = jnp.where(mean_diag <= 0.0, 1.0, mean_diag)
    hd = h + damp * mean_diag * jnp.eye(n, dtype=h.dtype)
    l = blocked_cholesky(hd)
    linv = blocked_tril_inverse(l)
    hinv = linv.T @ linv
    c = blocked_cholesky(hinv)
    return c.T
