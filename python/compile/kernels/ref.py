"""Pure-NumPy oracle for the SparseGPT algorithm (Algorithm 1) and the
Hessian accumulation. Written as a direct, naive transcription of the paper's
pseudocode — deliberately sharing no code with the Pallas/JAX implementations
it validates.

Conventions (matching the production path):
  * ``hinv_chol`` is the upper-triangular Cholesky factor of
    (X X^T + λ I)^{-1} transposed, i.e. ``Cholesky(H^{-1})^T``; Algorithm 1's
    ``[H^{-1}]_jj`` / row reads refer to this factor.
  * keep-mask: 1.0 = kept, 0.0 = pruned.
  * Unstructured selection: per ``Bs``-column block, prune the
    ``round(p * numel)`` entries of smallest saliency w^2 / [H^{-1}]_cc^2
    over the whole (d_row x Bs) block (stable-rank tie-break by index).
  * n:m selection: per row, per group of m consecutive columns, prune the n
    smallest-saliency entries, selected when the sweep reaches the group
    (i.e. from already-updated weights).
  * Joint quantization (Eq. 7): per-row asymmetric RTN grid computed from the
    ORIGINAL weights; frozen kept weights are quantized, errors propagated.
"""

import numpy as np


def ref_hessian(x):
    x = np.asarray(x, dtype=np.float64)
    return (x.T @ x).astype(np.float32)


def quant_grid(w, levels):
    """Per-row asymmetric min/max grid over the original weights.
    Returns (scale, zero) with shapes (d_row, 1). lo/hi are the row's true
    min/max (no zero fold): an all-positive row keeps its tight range, and
    zero stays representable whenever the row spans it (pruned weights are
    masked to exact zero before quantization, so they never need the grid)."""
    lo = w.min(axis=1, keepdims=True)
    hi = w.max(axis=1, keepdims=True)
    scale = (hi - lo) / max(float(levels), 1.0)
    scale = np.where(scale <= 0.0, 1.0, scale)
    zero = np.round(-lo / scale)
    return scale, zero


def _quantize(w, scale, zero, levels):
    q = np.clip(np.round(w / scale + zero), 0.0, float(levels))
    return scale * (q - zero)


def _stable_ranks(flat):
    order = np.argsort(flat, kind="stable")
    ranks = np.empty_like(order)
    ranks[order] = np.arange(flat.size)
    return ranks


def ref_sparsegpt(
    w,
    hinv_chol,
    sparsity=None,
    nm=None,
    blocksize=128,
    mask_blocksize=128,
    quant_levels=0,
    dtype=np.float64,
):
    """Run Algorithm 1 on one layer. Returns (w_hat, keep_mask) as float32.

    w: (d_row, d_col); hinv_chol: (d_col, d_col) upper factor;
    exactly one of ``sparsity`` (float in [0,1]) or ``nm`` ((n, m)) set —
    ``sparsity=0.0`` with ``quant_levels>0`` is GPTQ-style pure quantization.
    """
    w = np.array(w, dtype=dtype)
    hc = np.asarray(hinv_chol, dtype=dtype)
    d_row, d_col = w.shape
    B = min(blocksize, d_col)
    Bs = min(mask_blocksize, d_col)
    keep = np.ones((d_row, d_col), dtype=dtype)
    diag = np.diag(hc).copy()

    if quant_levels > 0:
        scale, zero = quant_grid(w, quant_levels)

    def frozen_value(col_vals, keep_col):
        if quant_levels > 0:
            return keep_col * _quantize(col_vals, scale[:, 0], zero[:, 0], quant_levels)
        return keep_col * col_vals

    for i in range(0, d_col, B):
        ib = min(i + B, d_col)
        err_block = np.zeros((d_row, ib - i), dtype=dtype)
        for j in range(i, ib):
            if nm is None and j % Bs == 0:
                je = min(j + Bs, d_col)
                s = np.square(w[:, j:je]) / np.square(diag[j:je])[None, :]
                k = int(round(sparsity * s.size))
                ranks = _stable_ranks(s.reshape(-1)).reshape(s.shape)
                keep[:, j:je] = (ranks >= k).astype(dtype)
            if nm is not None and j % nm[1] == 0:
                n_, m_ = nm
                je = j + m_
                s = np.square(w[:, j:je]) / np.square(diag[j:je])[None, :]
                for r in range(d_row):
                    ranks = _stable_ranks(s[r])
                    keep[r, j:je] = (ranks >= n_).astype(dtype)
            fz = frozen_value(w[:, j], keep[:, j])
            err = (w[:, j] - fz) / diag[j]
            w[:, j + 1 : ib] -= np.outer(err, hc[j, j + 1 : ib])
            w[:, j] = fz
            err_block[:, j - i] = err
        w[:, ib:] -= err_block @ hc[i:ib, ib:]

    return w.astype(np.float32), keep.astype(np.float32)


def ref_adaprune(w, mask, h, lr, steps):
    """Gradient-descent reconstruction of the masked layer on the AdaPrune
    objective tr((W_hat - W) H (W_hat - W)^T); oracle for the HLO artifact."""
    w = np.asarray(w, dtype=np.float64)
    h = np.asarray(h, dtype=np.float64)
    mask = np.asarray(mask, dtype=np.float64)
    wh = w * mask
    for _ in range(steps):
        g = (wh - w) @ h
        wh = wh - lr * g * mask
    return wh.astype(np.float32)


def layer_sq_error(w_orig, w_hat, h):
    """||(W - W_hat) X||_F^2 = tr(dW H dW^T) with the *undamped* H."""
    dw = np.asarray(w_orig, np.float64) - np.asarray(w_hat, np.float64)
    return float(np.sum((dw @ np.asarray(h, np.float64)) * dw))
