"""Layer 1: Pallas kernel for layer-wise Hessian accumulation H = X^T X.

X is one calibration chunk of activation rows (N, dim); the coordinator sums
chunk results on the Rust side (zero rows contribute nothing, so short chunks
are zero-padded there). The grid tiles the (dim, dim) output into MXU-shaped
(T, T) blocks; each program contracts the full N dimension with one
``jnp.dot`` so the HBM->VMEM schedule is one column-strip pair per program
(2 * N*T*4 bytes = 1 MiB at N=1024, T=128 — comfortably VMEM resident).
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _hessian_kernel(xi_ref, xj_ref, o_ref):
    o_ref[...] = jnp.dot(
        xi_ref[...].T, xj_ref[...], preferred_element_type=jnp.float32
    )


def hessian_chunk(x, *, interpret=True):
    """(N, dim) f32 -> (dim, dim) f32 = X^T X."""
    n, dim = x.shape
    t = 128 if dim % 128 == 0 else dim
    grid = (dim // t, dim // t)
    return pl.pallas_call(
        _hessian_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((n, t), lambda i, j: (0, i)),
            pl.BlockSpec((n, t), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((t, t), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((dim, dim), jnp.float32),
        interpret=interpret,
    )(x, x)
