"""Layer 1: Pallas kernels for the SparseGPT column sweep (Algorithm 1 core).

The kernel processes one lazy-update window of ``B`` consecutive columns of
the weight matrix. The grid tiles the rows (each program owns an
``R_TILE x B`` VMEM-resident block of ``W``); the sequential dependence of
Algorithm 1 lives in an in-kernel ``fori_loop`` over the window's columns:

  for j in window:
      err_j   = (w_j - keep_j * q(w_j)) / Hinv[j, j]          (Eq. 3 / Eq. 7)
      W[:, j+1:B] -= err_j * Hinv[j, j+1:B]                    (OBS update)
      W[:, j]  = keep_j * q(w_j)                               (freeze)

``Hinv`` here is the window-diagonal slice of the upper-triangular Cholesky
factor of (XX^T + λI)^{-1}, computed once per layer on the Rust side (f64)
and shared by every row — the paper's Hessian-synchronization trick. The
trailing update beyond the window (lazy batching, the GPTQ enhancement) is a
single MXU-shaped matmul done at Layer 2 with the error block ``E`` this
kernel emits.

Two variants:
  * unstructured — the keep-mask for the window is selected at Layer 2
    (adaptive per-``Bs``-block global top-k, Sec. 3.2) and passed in;
  * n:m semi-structured — selection happens *inside* the kernel per group of
    ``m`` columns using the updated weights (Sec. 3.3), exactly ``n`` zeros
    per group per row, via a comparison-count ranking (no sort needed for
    m ∈ {4, 8}).

Joint sparsification + quantization (Sec. 3.5) is supported in both via the
per-row asymmetric grid (scale/zero) computed at Layer 2 from the original
weights; ``qmeta = [qflag, qlevels]`` disables it at runtime when 0.

Hardware adaptation (paper: A100/CUDA, PyTorch): rows->grid programs replace
the GPU's row-parallel batched rank-1 updates; the window is one VMEM
residency (R_TILE*B + B*B + R_TILE*B floats ~ 320 KiB at 128x128 tiles,
far under ~16 MiB VMEM, leaving room for double buffering); the rank-1
update is VPU work and the trailing block update maps to the MXU. Kernels
are lowered with ``interpret=True`` (CPU PJRT cannot execute Mosaic
custom-calls); see DESIGN.md §7 for the real-TPU roofline estimate.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _quantize(wj, scale, zero, qflag, qlevels):
    """RTN on the per-row asymmetric grid; identity when qflag == 0."""
    q = jnp.clip(jnp.round(wj / scale + zero), 0.0, qlevels)
    deq = scale * (q - zero)
    return jnp.where(qflag > 0.0, deq, wj)


def _prune_window_kernel(w_ref, m_ref, hinv_ref, scale_ref, zero_ref, qmeta_ref,
                         wout_ref, e_ref):
    """Unstructured variant: keep-mask precomputed at Layer 2."""
    w = w_ref[...]            # (R, B)
    keep = m_ref[...]         # (R, B) 1.0 = keep
    hinv = hinv_ref[...]      # (B, B) upper-triangular factor slice
    scale = scale_ref[...]    # (R, 1)
    zero = zero_ref[...]      # (R, 1)
    qflag = qmeta_ref[0, 0]
    qlevels = qmeta_ref[0, 1]
    R, B = w.shape
    col = jax.lax.broadcasted_iota(jnp.int32, (R, B), 1)

    def body(j, carry):
        w, e = carry
        wj = jax.lax.dynamic_slice_in_dim(w, j, 1, axis=1)       # (R,1)
        kj = jax.lax.dynamic_slice_in_dim(keep, j, 1, axis=1)    # (R,1)
        frozen = kj * _quantize(wj, scale, zero, qflag, qlevels)
        dj = jax.lax.dynamic_slice(hinv, (j, j), (1, 1))         # (1,1)
        err = (wj - frozen) / dj                                 # (R,1)
        hrow = jax.lax.dynamic_slice(hinv, (j, 0), (1, B))       # (1,B)
        w = jnp.where(col > j, w - err * hrow, w)
        w = jnp.where(col == j, frozen, w)
        e = jnp.where(col == j, err, e)
        return w, e

    w, e = jax.lax.fori_loop(0, B, body, (w, jnp.zeros_like(w)))
    wout_ref[...] = w
    e_ref[...] = e


def _group_ranks(s):
    """Stable ranks within the last axis: rank_i = #{j : s_j < s_i or
    (s_j == s_i and j < i)}. Exact n-of-m selection even with ties."""
    m = s.shape[-1]
    si = s[..., :, None]
    sj = s[..., None, :]
    idx_i = jax.lax.broadcasted_iota(jnp.int32, (m, m), 0)
    idx_j = jax.lax.broadcasted_iota(jnp.int32, (m, m), 1)
    less = (sj < si) | ((sj == si) & (idx_j < idx_i))
    return jnp.sum(less.astype(jnp.int32), axis=-1)  # (..., m)


def _prune_window_nm_kernel(n, m, w_ref, hinv_ref, scale_ref, zero_ref,
                            qmeta_ref, wout_ref, e_ref, mout_ref):
    """n:m variant: per-group mask selected in-kernel from *updated* weights
    (paper: blocksize Bs = m), exactly n zeros per m consecutive columns."""
    w = w_ref[...]            # (R, B)
    hinv = hinv_ref[...]      # (B, B)
    scale = scale_ref[...]
    zero = zero_ref[...]
    qflag = qmeta_ref[0, 0]
    qlevels = qmeta_ref[0, 1]
    R, B = w.shape
    col = jax.lax.broadcasted_iota(jnp.int32, (R, B), 1)
    diag = jnp.diagonal(hinv).reshape(1, B)

    def group_body(g, carry):
        w, e, keep_acc = carry
        j0 = g * m
        wg = jax.lax.dynamic_slice(w, (0, j0), (R, m))          # (R, m)
        dg = jax.lax.dynamic_slice(diag, (0, j0), (1, m))       # (1, m)
        s = jnp.square(wg) / jnp.square(dg)                     # OBS saliency
        ranks = _group_ranks(s)                                 # (R, m)
        keep_g = (ranks >= n).astype(w.dtype)                   # prune n smallest

        def col_body(jj, carry2):
            w, e = carry2
            j = j0 + jj
            wj = jax.lax.dynamic_slice_in_dim(w, j, 1, axis=1)
            kj = jax.lax.dynamic_slice_in_dim(keep_g, jj, 1, axis=1)
            frozen = kj * _quantize(wj, scale, zero, qflag, qlevels)
            dj = jax.lax.dynamic_slice(hinv, (j, j), (1, 1))
            err = (wj - frozen) / dj
            hrow = jax.lax.dynamic_slice(hinv, (j, 0), (1, B))
            w = jnp.where(col > j, w - err * hrow, w)
            w = jnp.where(col == j, frozen, w)
            e = jnp.where(col == j, err, e)
            return w, e

        w, e = jax.lax.fori_loop(0, m, col_body, (w, e))
        in_group = (col >= j0) & (col < j0 + m)
        gmask = jax.lax.dynamic_update_slice(jnp.zeros_like(w), keep_g, (0, j0))
        keep_acc = jnp.where(in_group, gmask, keep_acc)
        return w, e, keep_acc

    z = jnp.zeros_like(w)
    w, e, keep = jax.lax.fori_loop(0, B // m, group_body, (w, z, z))
    wout_ref[...] = w
    e_ref[...] = e
    mout_ref[...] = keep


def _row_tile(d_row: int) -> int:
    return 128 if d_row % 128 == 0 else d_row


def prune_window(w, keep, hinv_win, scale, zero, qmeta, *, interpret=True):
    """Apply the unstructured column sweep to one window.

    w: (d_row, B); keep: (d_row, B); hinv_win: (B, B); scale/zero: (d_row, 1);
    qmeta: (1, 2) = [[qflag, qlevels]].  Returns (w_out, e) both (d_row, B).
    """
    d_row, B = w.shape
    R = _row_tile(d_row)
    grid = (d_row // R,)
    row_spec = pl.BlockSpec((R, B), lambda i: (i, 0))
    shared = pl.BlockSpec((B, B), lambda i: (0, 0))
    vec_spec = pl.BlockSpec((R, 1), lambda i: (i, 0))
    meta_spec = pl.BlockSpec((1, 2), lambda i: (0, 0))
    return pl.pallas_call(
        _prune_window_kernel,
        grid=grid,
        in_specs=[row_spec, row_spec, shared, vec_spec, vec_spec, meta_spec],
        out_specs=[row_spec, row_spec],
        out_shape=[
            jax.ShapeDtypeStruct((d_row, B), w.dtype),
            jax.ShapeDtypeStruct((d_row, B), w.dtype),
        ],
        interpret=interpret,
    )(w, keep, hinv_win, scale, zero, qmeta)


def prune_window_nm(n, m, w, hinv_win, scale, zero, qmeta, *, interpret=True):
    """n:m column sweep for one window. Returns (w_out, e, keep_mask)."""
    d_row, B = w.shape
    assert B % m == 0
    R = _row_tile(d_row)
    grid = (d_row // R,)
    row_spec = pl.BlockSpec((R, B), lambda i: (i, 0))
    shared = pl.BlockSpec((B, B), lambda i: (0, 0))
    vec_spec = pl.BlockSpec((R, 1), lambda i: (i, 0))
    meta_spec = pl.BlockSpec((1, 2), lambda i: (0, 0))
    return pl.pallas_call(
        functools.partial(_prune_window_nm_kernel, n, m),
        grid=grid,
        in_specs=[row_spec, shared, vec_spec, vec_spec, meta_spec],
        out_specs=[row_spec, row_spec, row_spec],
        out_shape=[
            jax.ShapeDtypeStruct((d_row, B), w.dtype),
            jax.ShapeDtypeStruct((d_row, B), w.dtype),
            jax.ShapeDtypeStruct((d_row, B), w.dtype),
        ],
        interpret=interpret,
    )(w, hinv_win, scale, zero, qmeta)
