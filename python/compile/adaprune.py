"""Layer 2: AdaPrune baseline (Hubara et al., 2021) — magnitude mask (chosen
on the Rust side) followed by gradient-descent reconstruction of the kept
weights on the layer-wise objective

    f(W_hat) = 1/2 tr((W_hat - W) H (W_hat - W)^T),   H = X X^T,

whose gradient is (W_hat - W) H, projected onto the mask each step. The
original uses SGD over calibration batches; with H precomputed the two are
the same objective (this is also the memory-optimized reformulation of
Frantar & Alistarh 2022 cited by the paper as the tuned baseline).

The learning rate enters as a runtime scalar: the Rust driver sets
lr = 1 / lambda_max(H) (power-iteration estimate), the classic stable step
size for quadratic objectives.
"""

import jax
import jax.numpy as jnp

ADAPRUNE_STEPS = 256


def adaprune_fn(w, mask, h, lr):
    """(W, keep_mask, H, lr) -> reconstructed W_hat (pruned entries exactly 0)."""
    wh = w * mask

    def body(_, wh):
        g = (wh - w) @ h
        return wh - lr * g * mask

    return jax.lax.fori_loop(0, ADAPRUNE_STEPS, body, wh)
