"""Layer 2: the full SparseGPT layer solver (Algorithm 1), assembling the
Pallas window kernel, the adaptive mask selection (Sec. 3.2), the lazy
trailing updates and the joint-quantization grid (Sec. 3.5) into one graph
per (d_row, d_col, pattern), AOT-lowered to an HLO artifact.

Inputs at runtime (all from the Rust coordinator):
  w          (d_row, d_col) the layer weights
  hinv_chol  (d_col, d_col) upper Cholesky factor of (XX^T + λI)^{-1},
             computed in f64 on the Rust side (keeps LAPACK custom-calls out
             of the HLO; the pinned xla_extension cannot execute them)
  p          () target sparsity in [0, 1) — runtime scalar, so one artifact
             serves every sweep point (0.0 = pure quantization = GPTQ)
  qlevels    () quantization levels (2^bits - 1), 0 disables quantization

Outputs: (w_hat, keep_mask) both (d_row, d_col) f32.

With ``sparsity = 0`` and ``qlevels > 0`` this graph *is* GPTQ — the paper's
observation that both algorithms share the column-greedy framework — and is
used as the quantization baseline of Figure 6.
"""

import jax
import jax.numpy as jnp

from .kernels.prune_block import prune_window, prune_window_nm
from .configs import BLOCKSIZE


def _quant_params(w, qlevels):
    """Per-row asymmetric RTN grid from the ORIGINAL weights. lo/hi are the
    row's true min/max (no zero fold — matches ``quant_grid`` in
    kernels/ref.py and ``QuantGrid`` on the Rust side): pruned weights are
    frozen at exact zero by the keep-mask, never through the grid."""
    lo = jnp.min(w, axis=1, keepdims=True)
    hi = jnp.max(w, axis=1, keepdims=True)
    scale = (hi - lo) / jnp.maximum(qlevels, 1.0)
    scale = jnp.where(scale <= 0.0, 1.0, scale)
    zero = jnp.round(-lo / scale)
    qflag = (qlevels > 0.0).astype(w.dtype)
    qmeta = jnp.stack([qflag, qlevels]).reshape(1, 2)
    return scale, zero, qmeta


def _stable_ranks_flat(flat):
    order = jnp.argsort(flat, stable=True)
    return jnp.argsort(order, stable=True)


def _select_window_mask(w_win, diag_win, p):
    """Adaptive selection over one (d_row x Bs) block: prune the
    round(p * numel) entries of smallest saliency w^2 / diag^2 globally in
    the block (non-uniform per column — the outlier-feature motivation)."""
    s = jnp.square(w_win) / jnp.square(diag_win)[None, :]
    flat = s.reshape(-1)
    ranks = _stable_ranks_flat(flat)
    k = jnp.round(p * flat.size).astype(jnp.int32)
    return (ranks >= k).astype(w_win.dtype).reshape(w_win.shape)


def sparsegpt_layer_fn(w, hinv_chol, p, qlevels, *, nm=None, interpret=True):
    """Full Algorithm 1 over all columns; windows of BLOCKSIZE are processed
    by the Pallas kernel, trailing lazy updates are MXU matmuls here."""
    d_row, d_col = w.shape
    B = min(BLOCKSIZE, d_col)
    assert d_col % B == 0
    diag = jnp.diagonal(hinv_chol)
    scale, zero, qmeta = _quant_params(w, qlevels)
    mask = jnp.ones_like(w)

    for i in range(0, d_col, B):
        ib = i + B
        w_win = w[:, i:ib]
        hinv_win = hinv_chol[i:ib, i:ib]
        if nm is None:
            keep = _select_window_mask(w_win, diag[i:ib], p)
            w_new, e = prune_window(
                w_win, keep, hinv_win, scale, zero, qmeta, interpret=interpret
            )
        else:
            n_, m_ = nm
            w_new, e, keep = prune_window_nm(
                n_, m_, w_win, hinv_win, scale, zero, qmeta, interpret=interpret
            )
        w = w.at[:, i:ib].set(w_new)
        mask = mask.at[:, i:ib].set(keep)
        if ib < d_col:
            w = w.at[:, ib:].add(-(e @ hinv_chol[i:ib, ib:]))

    return w, mask


def sparsegpt_layer_jnp_fn(mask_blocksize, w, hinv_chol, p, qlevels):
    """Pure-jnp variant with arbitrary mask-selection blocksize ``Bs``
    (Fig. 10 ablation). fori-loop over columns, full-width masked updates
    (algebraically identical to lazy batching); selection every Bs columns.
    Requires Bs to divide d_col."""
    d_row, d_col = w.shape
    Bs = mask_blocksize
    assert d_col % Bs == 0
    diag = jnp.diagonal(hinv_chol)
    scale, zero, qmeta = _quant_params(w, qlevels)
    qflag, qlv = qmeta[0, 0], qmeta[0, 1]
    col = jax.lax.broadcasted_iota(jnp.int32, (1, d_col), 1)
    diag_row = diag.reshape(1, d_col)

    def body(j, carry):
        w, mask = carry

        def select(mask):
            w_blk = jax.lax.dynamic_slice(w, (0, j), (d_row, Bs))
            d_blk = jax.lax.dynamic_slice(diag_row, (0, j), (1, Bs))
            s = jnp.square(w_blk) / jnp.square(d_blk)
            ranks = _stable_ranks_flat(s.reshape(-1))
            k = jnp.round(p * (d_row * Bs)).astype(jnp.int32)
            keep = (ranks >= k).astype(w.dtype).reshape(d_row, Bs)
            return jax.lax.dynamic_update_slice(mask, keep, (0, j))

        mask = jax.lax.cond(j % Bs == 0, select, lambda m: m, mask)
        wj = jax.lax.dynamic_slice(w, (0, j), (d_row, 1))
        kj = jax.lax.dynamic_slice(mask, (0, j), (d_row, 1))
        q = jnp.clip(jnp.round(wj / scale + zero), 0.0, qlv)
        frozen = kj * jnp.where(qflag > 0.0, scale * (q - zero), wj)
        dj = jax.lax.dynamic_slice(diag_row, (0, j), (1, 1))
        err = (wj - frozen) / dj
        hrow = jax.lax.dynamic_slice(hinv_chol, (j, 0), (1, d_col))
        w = jnp.where(col > j, w - err * hrow, w)
        w = jnp.where(col == j, frozen, w)
        return w, mask

    w, mask = jax.lax.fori_loop(0, d_col, body, (w, jnp.ones_like(w)))
    return w, mask
