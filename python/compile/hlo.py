"""Lowering utilities: jitted JAX function -> HLO text.

HLO *text* (not serialized HloModuleProto) is the interchange format: jax
>= 0.5 emits protos with 64-bit instruction ids which the pinned
xla_extension 0.5.1 (behind the published ``xla`` 0.1.6 crate) rejects
(``proto.id() <= INT_MAX``); the text parser reassigns ids and round-trips
cleanly. Lowered with ``return_tuple=True`` — the Rust side always unwraps a
tuple, even for single outputs.
"""

import jax
from jax._src.lib import xla_client as xc


def lower_to_hlo_text(fn, example_args) -> str:
    lowered = jax.jit(fn).lower(*example_args)
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()
