"""Layer 2: OPT-style decoder-only transformer over a flat parameter vector.

Everything here is pure JAX (no torch, no python on the request path): these
functions are traced once by ``aot.py`` and lowered to HLO text artifacts
executed from the Rust runtime.

Design notes
------------
* All parameters live in ONE flat f32 vector (layout in ``configs.py``),
  so the Rust<->HLO boundary is a single literal per state tensor.
* Blocks are executed with ``lax.scan`` over stacked (L, ...) block params:
  keeps the HLO small and the trace/lowering time flat in depth.
* GELU uses the explicit tanh approximation — ``jax.nn.gelu``'s erf path can
  lower to custom calls that the pinned xla_extension 0.5.1 cannot execute.
* No linear algebra (cholesky/inv) is done here; the solver artifacts take a
  precomputed Cholesky factor from the Rust side for the same reason.
"""

import jax
import jax.numpy as jnp

from .configs import ModelConfig


# --------------------------------------------------------------------------
# flat-vector (un)packing
# --------------------------------------------------------------------------

def unflatten(cfg: ModelConfig, flat):
    """Flat f32 vector -> dict of named parameter arrays."""
    out = {}
    for name, (off, shape) in cfg.param_offsets().items():
        n = 1
        for s in shape:
            n *= s
        out[name] = jax.lax.dynamic_slice_in_dim(flat, off, n).reshape(shape)
    return out


def unflatten_block(cfg: ModelConfig, flat_block):
    """Flat per-block slice -> dict of block parameter arrays."""
    out = {}
    for name, (off, shape) in cfg.block_offsets().items():
        n = 1
        for s in shape:
            n *= s
        out[name] = jax.lax.dynamic_slice_in_dim(flat_block, off, n).reshape(shape)
    return out


def stacked_block_params(params):
    """Dict of (L, ...) arrays that ``lax.scan`` iterates over."""
    keys = ["ln1_g", "ln1_b", "wq", "wk", "wv", "wo", "ln2_g", "ln2_b", "w1", "w2"]
    return {k: params[k] for k in keys}


# --------------------------------------------------------------------------
# primitives
# --------------------------------------------------------------------------

def layer_norm(x, g, b, eps=1e-5):
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x - mu), axis=-1, keepdims=True)
    return (x - mu) * jax.lax.rsqrt(var + eps) * g + b


def gelu_tanh(x):
    # 0.5x(1 + tanh(sqrt(2/pi)(x + 0.044715 x^3))) — explicit, custom-call free
    c = 0.7978845608028654
    return 0.5 * x * (1.0 + jnp.tanh(c * (x + 0.044715 * x * x * x)))


def causal_attention(cfg: ModelConfig, q, k, v):
    """q,k,v: (B, T, d) -> (B, T, d) concatenated head outputs (input to wo)."""
    B, T, d = q.shape
    h, hd = cfg.heads, cfg.head_dim

    def split(x):
        return x.reshape(B, T, h, hd).transpose(0, 2, 1, 3)  # (B,h,T,hd)

    qh, kh, vh = split(q), split(k), split(v)
    scores = jnp.einsum("bhqd,bhkd->bhqk", qh, kh) / jnp.sqrt(float(hd))
    mask = jnp.tril(jnp.ones((T, T), dtype=jnp.bool_))
    scores = jnp.where(mask[None, None], scores, -1e9)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhqk,bhkd->bhqd", probs, vh)
    return out.transpose(0, 2, 1, 3).reshape(B, T, d)


def block_forward(cfg: ModelConfig, bp, x):
    """One transformer block. Returns (x_out, captures).

    Captures are the inputs of each prunable linear, flattened to
    (B*T, d_in) — exactly what the layer-wise Hessians H = X^T X need:
      x_qkv : input of wq/wk/wv (post-ln1; they share one Hessian)
      x_wo  : input of wo (concatenated head outputs)
      x_fc1 : input of w1 (post-ln2)
      x_fc2 : input of w2 (post-GELU)
    """
    B, T, d = x.shape
    a = layer_norm(x, bp["ln1_g"], bp["ln1_b"])
    q = a @ bp["wq"].T
    k = a @ bp["wk"].T
    v = a @ bp["wv"].T
    attn = causal_attention(cfg, q, k, v)
    x = x + attn @ bp["wo"].T
    u = layer_norm(x, bp["ln2_g"], bp["ln2_b"])
    g = gelu_tanh(u @ bp["w1"].T)
    x = x + g @ bp["w2"].T
    captures = {
        "x_qkv": a.reshape(B * T, d),
        "x_wo": attn.reshape(B * T, d),
        "x_fc1": u.reshape(B * T, d),
        "x_fc2": g.reshape(B * T, cfg.ffn),
    }
    return x, captures


# --------------------------------------------------------------------------
# full model
# --------------------------------------------------------------------------

def embed(cfg: ModelConfig, params, tokens):
    """tokens (B, T) int32 -> hidden (B, T, d)."""
    T = tokens.shape[1]
    return params["tok_embed"][tokens] + params["pos_embed"][:T][None]


def forward_hidden(cfg: ModelConfig, params, tokens):
    x = embed(cfg, params, tokens)
    bps = stacked_block_params(params)

    def step(h, bp):
        h, _ = block_forward(cfg, bp, h)
        return h, None

    x, _ = jax.lax.scan(step, x, bps)
    return layer_norm(x, params["lnf_g"], params["lnf_b"])


def logits_fn(cfg: ModelConfig, params, tokens):
    h = forward_hidden(cfg, params, tokens)
    return h @ params["tok_embed"].T  # tied head


def nll_fn(cfg: ModelConfig, flat, tokens):
    """tokens (B, T+1) int32 -> per-position negative log-likelihood (B, T).

    Serves both perplexity evaluation (summed in Rust, HuggingFace full-stride
    procedure) and the zero-shot harness (candidate log-likelihood ranking
    with Rust-side masks).
    """
    params = unflatten(cfg, flat)
    inp, tgt = tokens[:, :-1], tokens[:, 1:]
    logits = logits_fn(cfg, params, inp)
    logp = jax.nn.log_softmax(logits, axis=-1)
    return -jnp.take_along_axis(logp, tgt[..., None], axis=-1)[..., 0]


def embed_fn(cfg: ModelConfig, flat, tokens):
    """Artifact: (flat_params, tokens (B,T)) -> hidden (B,T,d)."""
    return embed(cfg, unflatten(cfg, flat), tokens)


def block_fwd_fn(cfg: ModelConfig, flat_block, hidden):
    """Artifact: (block_slice, hidden) -> (hidden_out, x_qkv, x_wo, x_fc1, x_fc2).

    Driven per-block from the Rust coordinator during sequential pruning:
    one pass with dense block weights collects the Hessian inputs, a second
    pass with the pruned slice produces the next block's inputs.
    """
    bp = unflatten_block(cfg, flat_block)
    out, cap = block_forward(cfg, bp, hidden)
    return out, cap["x_qkv"], cap["x_wo"], cap["x_fc1"], cap["x_fc2"]


def next_logits_fn(cfg: ModelConfig, flat, tokens):
    """Artifact: (flat_params, tokens (1, T)) -> next-token logits (vocab,).

    Drives the Rust-side sampler (`eval::generate`) — a demo/debug feature
    showing compressed models still generate coherent text."""
    params = unflatten(cfg, flat)
    logits = logits_fn(cfg, params, tokens)
    return logits[0, -1, :]


def block_prop_fn(cfg: ModelConfig, flat_block, hidden):
    """Lean propagation artifact: (block_slice, hidden) -> hidden_out only.
    Used after a block is pruned — the captures of `block_fwd_fn` would be
    dead outputs whose device->host copies dominate marshalling cost."""
    bp = unflatten_block(cfg, flat_block)
    out, _ = block_forward(cfg, bp, hidden)
    return out


def block_hess_fn(cfg: ModelConfig, flat_block, hidden, valid_rows):
    """Fused capture + Hessian artifact (the L2 perf-pass optimization):
    (block_slice, hidden (B,T,d), valid_rows scalar) ->
    (hidden_out, H_qkv (d,d), H_wo (d,d), H_fc1 (d,d), H_fc2 (F,F)).

    Computes this chunk's contribution X^T X of every capture inside one
    HLO module (calling the Pallas hessian kernel), so the coordinator does
    one dispatch per (chunk, block) instead of five, and the big activation
    buffers never cross the runtime boundary. Rows >= valid_rows (zero
    padding of short calibration chunks) are masked out before the products.
    """
    from .kernels.hessian import hessian_chunk

    bp = unflatten_block(cfg, flat_block)
    out, cap = block_forward(cfg, bp, hidden)
    n_rows = hidden.shape[0] * hidden.shape[1]
    row_ok = (
        jax.lax.broadcasted_iota(jnp.int32, (n_rows, 1), 0)
        < valid_rows.astype(jnp.int32)
    ).astype(hidden.dtype)
    hs = [
        hessian_chunk(cap[k] * row_ok) for k in ["x_qkv", "x_wo", "x_fc1", "x_fc2"]
    ]
    return (out, *hs)
