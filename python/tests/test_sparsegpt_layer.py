"""L2 correctness: the full layer solver (multi-window, lazy trailing
updates, adaptive selection) vs the oracle, plus the solver's mathematical
guarantees: error never worse than no-reconstruction magnitude pruning, OBS
single-prune optimality, and the Fig-10 blocksize variant equivalences."""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.sparsegpt import sparsegpt_layer_fn, sparsegpt_layer_jnp_fn
from compile.adaprune import adaprune_fn, ADAPRUNE_STEPS
from compile.kernels.ref import ref_sparsegpt, ref_adaprune, layer_sq_error

SETTINGS = dict(max_examples=10, deadline=None)


def make_problem(rng, d_row, d_col, n_mult=2, damp=0.01):
    w = rng.normal(size=(d_row, d_col)).astype(np.float32)
    x = rng.normal(size=(n_mult * d_col, d_col)).astype(np.float32)
    h = x.T @ x
    hd = h + damp * np.trace(h) / d_col * np.eye(d_col)
    hinv = np.linalg.inv(hd)
    hc = np.linalg.cholesky(hinv).T.astype(np.float32)
    return w, h, hc


@given(
    shape=st.sampled_from([(64, 256), (256, 64), (96, 384), (128, 128)]),
    p=st.floats(0.1, 0.8),
    seed=st.integers(0, 2**31 - 1),
)
@settings(**SETTINGS)
def test_multi_window_matches_oracle(shape, p, seed):
    rng = np.random.default_rng(seed)
    w, _, hc = make_problem(rng, *shape)
    w1, m1 = sparsegpt_layer_fn(
        jnp.array(w), jnp.array(hc), jnp.float32(p), jnp.float32(0.0)
    )
    w2, m2 = ref_sparsegpt(w, hc, sparsity=p)
    np.testing.assert_array_equal(np.array(m1), m2)
    np.testing.assert_allclose(np.array(w1), w2, atol=1e-4, rtol=1e-3)


@given(
    nm=st.sampled_from([(2, 4), (4, 8)]),
    seed=st.integers(0, 2**31 - 1),
)
@settings(**SETTINGS)
def test_multi_window_nm_matches_oracle(nm, seed):
    rng = np.random.default_rng(seed)
    w, _, hc = make_problem(rng, 64, 256)
    w1, m1 = sparsegpt_layer_fn(
        jnp.array(w), jnp.array(hc), jnp.float32(0.0), jnp.float32(0.0), nm=nm
    )
    w2, m2 = ref_sparsegpt(w, hc, nm=nm)
    np.testing.assert_array_equal(np.array(m1), m2)
    np.testing.assert_allclose(np.array(w1), w2, atol=1e-4, rtol=1e-3)


def test_reconstruction_beats_pure_magnitude():
    """SparseGPT's layer error must beat mask-and-zero magnitude pruning
    (the whole point of weight reconstruction)."""
    rng = np.random.default_rng(3)
    w, h, hc = make_problem(rng, 128, 256)
    w1, m1 = sparsegpt_layer_fn(
        jnp.array(w), jnp.array(hc), jnp.float32(0.5), jnp.float32(0.0)
    )
    err_sgpt = layer_sq_error(w, np.array(w1), h)
    thresh = np.quantile(np.abs(w), 0.5)
    w_mag = np.where(np.abs(w) > thresh, w, 0.0)
    err_mag = layer_sq_error(w, w_mag, h)
    assert err_sgpt < err_mag


def test_obs_single_column_optimality():
    """Pruning a single weight at column 0 (where SparseGPT's rightward
    partial update covers ALL remaining weights, so it coincides with the
    full OBS step) must match both the closed-form optimal reconstruction
    and the predicted error w_m^2 / [H^-1]_mm (Eq. 3)."""
    rng = np.random.default_rng(4)
    d = 32
    w = rng.normal(size=(1, d)).astype(np.float64)
    w[0, 0] = 1e-4  # force min saliency -> pruned weight is column 0
    x = rng.normal(size=(3 * d, d)).astype(np.float64)
    h = x.T @ x + 0.01 * np.eye(d)
    hinv = np.linalg.inv(h)
    hc = np.linalg.cholesky(hinv).T
    w_ref, keep = ref_sparsegpt(w, hc, sparsity=1.0 / d, mask_blocksize=d, blocksize=d)
    m = int(np.where(keep[0] == 0.0)[0][0])
    assert m == 0
    # closed-form optimal reconstruction for that mask
    idx = [i for i in range(d) if i != m]
    hmm = h[np.ix_(idx, idx)]
    target = (w[0] @ h[:, idx]).T
    w_opt = np.zeros(d)
    w_opt[idx] = np.linalg.solve(hmm, target)
    err_opt = float((w[0] - w_opt) @ h @ (w[0] - w_opt))
    err_sgpt = layer_sq_error(w, w_ref, h)
    obs_pred = float(w[0, m] ** 2 / hinv[m, m])
    assert err_sgpt == pytest.approx(err_opt, rel=1e-4)
    assert err_sgpt == pytest.approx(obs_pred, rel=1e-4)


@given(bs=st.sampled_from([16, 64]), seed=st.integers(0, 2**31 - 1))
@settings(**SETTINGS)
def test_jnp_blocksize_variant_matches_oracle(bs, seed):
    rng = np.random.default_rng(seed)
    w, _, hc = make_problem(rng, 48, 128)
    w1, m1 = sparsegpt_layer_jnp_fn(
        bs, jnp.array(w), jnp.array(hc), jnp.float32(0.5), jnp.float32(0.0)
    )
    w2, m2 = ref_sparsegpt(w, hc, sparsity=0.5, mask_blocksize=bs, blocksize=128)
    np.testing.assert_array_equal(np.array(m1), m2)
    np.testing.assert_allclose(np.array(w1), w2, atol=1e-4, rtol=1e-3)


def test_jnp_bs128_equals_pallas_path():
    """Same Bs -> the fori-loop solver and the Pallas window solver are the
    same algorithm with different update batching; results must agree."""
    rng = np.random.default_rng(6)
    w, _, hc = make_problem(rng, 64, 256)
    w1, m1 = sparsegpt_layer_fn(
        jnp.array(w), jnp.array(hc), jnp.float32(0.6), jnp.float32(0.0)
    )
    w2, m2 = sparsegpt_layer_jnp_fn(
        128, jnp.array(w), jnp.array(hc), jnp.float32(0.6), jnp.float32(0.0)
    )
    np.testing.assert_array_equal(np.array(m1), np.array(m2))
    np.testing.assert_allclose(np.array(w1), np.array(w2), atol=1e-4, rtol=1e-3)


def test_gptq_mode_pure_quantization():
    """p=0 + 3-bit grid: nothing pruned, all weights on grid, and the GPTQ
    update beats plain RTN in layer error."""
    rng = np.random.default_rng(11)
    w, h, hc = make_problem(rng, 64, 128)
    levels = 7.0
    w1, m1 = sparsegpt_layer_fn(
        jnp.array(w), jnp.array(hc), jnp.float32(0.0), jnp.float32(levels)
    )
    assert np.array(m1).all()
    from compile.kernels.ref import quant_grid, _quantize

    scale, zero = quant_grid(w, levels)
    w_rtn = _quantize(w, scale, zero, levels)
    assert layer_sq_error(w, np.array(w1), h) < layer_sq_error(w, w_rtn, h)


def test_adaprune_matches_oracle_and_reduces_error():
    rng = np.random.default_rng(12)
    w, h, hc = make_problem(rng, 64, 128)
    thresh = np.quantile(np.abs(w), 0.5)
    mask = (np.abs(w) > thresh).astype(np.float32)
    lam = np.linalg.eigvalsh(h).max()
    lr = np.float32(1.0 / lam)
    w1 = adaprune_fn(jnp.array(w), jnp.array(mask), jnp.array(h, np.float32), lr)
    w2 = ref_adaprune(w, mask, h, float(lr), ADAPRUNE_STEPS)
    np.testing.assert_allclose(np.array(w1), w2, atol=1e-3, rtol=1e-2)
    err_recon = layer_sq_error(w, np.array(w1), h)
    err_mag = layer_sq_error(w, w * mask, h)
    assert err_recon < err_mag
    # pruned entries stay exactly zero
    assert (np.array(w1)[mask == 0.0] == 0.0).all()
