"""L1 correctness: the Pallas Hessian kernel vs the f64 oracle."""

import numpy as np
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from compile.kernels.hessian import hessian_chunk
from compile.kernels.ref import ref_hessian


@given(
    n=st.sampled_from([64, 256, 1024]),
    dim=st.sampled_from([32, 64, 128, 256]),
    seed=st.integers(0, 2**31 - 1),
)
@settings(max_examples=15, deadline=None)
def test_hessian_matches_oracle(n, dim, seed):
    rng = np.random.default_rng(seed)
    x = rng.normal(size=(n, dim)).astype(np.float32)
    hk = np.array(hessian_chunk(jnp.array(x)))
    href = ref_hessian(x)
    np.testing.assert_allclose(hk, href, atol=1e-3, rtol=1e-4)
    # symmetry and PSD diagonal
    np.testing.assert_allclose(hk, hk.T, atol=1e-3)
    assert (np.diag(hk) >= -1e-4).all()


def test_zero_rows_contribute_nothing():
    """The coordinator zero-pads short calibration chunks; padding must not
    perturb the accumulated Hessian."""
    rng = np.random.default_rng(0)
    x = rng.normal(size=(256, 64)).astype(np.float32)
    xp = np.concatenate([x, np.zeros((256, 64), np.float32)])
    np.testing.assert_allclose(
        np.array(hessian_chunk(jnp.array(xp))),
        np.array(hessian_chunk(jnp.array(x))),
        atol=1e-4,
    )
