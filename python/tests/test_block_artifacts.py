"""The fused capture+Hessian artifact and the lean propagation artifact must
agree exactly with the reference block_fwd + oracle Hessian path."""

import numpy as np
import jax.numpy as jnp

from compile.configs import ModelConfig
from compile import model
from compile.kernels.ref import ref_hessian

CFG = ModelConfig("t", d=32, layers=2, heads=2, train_batch=2, eval_batch=2, seq=16)


def _setup(seed=0):
    rng = np.random.default_rng(seed)
    blk = jnp.array((rng.normal(size=(CFG.block_size,)) * 0.05).astype(np.float32))
    hid = jnp.array(rng.normal(size=(CFG.eval_batch, CFG.seq, CFG.d)).astype(np.float32))
    return blk, hid


def test_block_hess_matches_unfused():
    blk, hid = _setup()
    out_ref = model.block_fwd_fn(CFG, blk, hid)
    n_rows = CFG.eval_batch * CFG.seq
    fused = model.block_hess_fn(CFG, blk, hid, jnp.float32(n_rows))
    np.testing.assert_allclose(np.array(fused[0]), np.array(out_ref[0]), atol=1e-5)
    for i, cap in enumerate(out_ref[1:], start=1):
        h_ref = ref_hessian(np.array(cap))
        np.testing.assert_allclose(np.array(fused[i]), h_ref, atol=2e-2, rtol=1e-4)


def test_block_hess_masks_padded_rows():
    blk, hid = _setup(1)
    n_rows = CFG.eval_batch * CFG.seq
    valid = n_rows - CFG.seq  # one padded segment
    fused = model.block_hess_fn(CFG, blk, hid, jnp.float32(valid))
    # reference: zero the padded capture rows before X^T X
    outs = model.block_fwd_fn(CFG, blk, hid)
    for i, cap in enumerate(outs[1:], start=1):
        cap = np.array(cap)
        cap[valid:] = 0.0
        np.testing.assert_allclose(np.array(fused[i]), ref_hessian(cap), atol=2e-2, rtol=1e-4)


def test_block_prop_matches_block_fwd_hidden():
    blk, hid = _setup(2)
    h1 = model.block_prop_fn(CFG, blk, hid)
    h2 = model.block_fwd_fn(CFG, blk, hid)[0]
    np.testing.assert_allclose(np.array(h1), np.array(h2), atol=1e-6)
