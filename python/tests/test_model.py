"""L2 model correctness: layout integrity, causality, loss behaviour and the
per-block capture path that feeds the layer-wise Hessians."""

import functools

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from compile.configs import CONFIGS, ModelConfig
from compile import model, train

CFG = ModelConfig("test", d=32, layers=2, heads=2, train_batch=2, eval_batch=2, seq=16)


def init_flat(cfg, seed=0, scale=0.05):
    rng = np.random.default_rng(seed)
    return (rng.normal(size=(cfg.n_params,)) * scale).astype(np.float32)


def test_layout_offsets_are_contiguous_and_cover():
    for cfg in list(CONFIGS.values()) + [CFG]:
        off = 0
        for name, (o, shape) in cfg.param_offsets().items():
            assert o == off, name
            off += int(np.prod(shape))
        assert off == cfg.n_params
        boff = 0
        for name, (o, shape) in cfg.block_offsets().items():
            assert o == boff, name
            boff += int(np.prod(shape))
        assert boff == cfg.block_size


def test_unflatten_roundtrip():
    flat = init_flat(CFG)
    params = model.unflatten(CFG, jnp.array(flat))
    # reconstruct the flat vector from the parts in layout order
    rebuilt = np.concatenate(
        [np.array(params[n]).reshape(-1) for n, _ in CFG.param_entries()]
    )
    np.testing.assert_array_equal(rebuilt, flat)


def test_causality():
    """Changing a future token must not affect past NLL positions."""
    flat = jnp.array(init_flat(CFG))
    rng = np.random.default_rng(1)
    toks = rng.integers(0, CFG.vocab, size=(2, CFG.seq + 1)).astype(np.int32)
    nll1 = np.array(model.nll_fn(CFG, flat, jnp.array(toks)))
    toks2 = toks.copy()
    toks2[:, -1] = (toks2[:, -1] + 7) % CFG.vocab
    nll2 = np.array(model.nll_fn(CFG, flat, jnp.array(toks2)))
    np.testing.assert_allclose(nll1[:, :-1], nll2[:, :-1], atol=1e-5)
    assert not np.allclose(nll1[:, -1], nll2[:, -1])


def test_block_fwd_matches_scan_forward():
    """Driving blocks one-by-one (the coordinator's path) must reproduce the
    scan-based full forward exactly."""
    flat = jnp.array(init_flat(CFG))
    rng = np.random.default_rng(2)
    toks = jnp.array(rng.integers(0, CFG.vocab, size=(2, CFG.seq)).astype(np.int32))
    h = model.embed_fn(CFG, flat, toks)
    params = model.unflatten(CFG, flat)
    for l in range(CFG.layers):
        bslice = []
        for name, (off, shape) in CFG.block_offsets().items():
            bslice.append(np.array(params[name][l]).reshape(-1))
        bflat = jnp.array(np.concatenate(bslice))
        h, xq, xo, x1, x2 = model.block_fwd_fn(CFG, bflat, h)
        assert xq.shape == (2 * CFG.seq, CFG.d)
        assert x2.shape == (2 * CFG.seq, CFG.ffn)
    hs = model.forward_hidden(CFG, params, toks)
    # forward_hidden applies the final LN; apply it to h too
    h_final = model.layer_norm(h, params["lnf_g"], params["lnf_b"])
    np.testing.assert_allclose(np.array(h_final), np.array(hs), atol=1e-4, rtol=1e-3)


def test_train_step_decreases_loss():
    flat = jnp.array(init_flat(CFG, scale=0.1))
    m = jnp.zeros_like(flat)
    v = jnp.zeros_like(flat)
    rng = np.random.default_rng(3)
    toks = jnp.array(rng.integers(0, CFG.vocab, size=(2, CFG.seq + 1)).astype(np.int32))
    step_fn = jax.jit(functools.partial(train.train_step_fn, CFG))
    losses = []
    for step in range(1, 121):
        flat, m, v, loss = step_fn(
            flat, m, v, jnp.float32(step), jnp.float32(1e-2), toks
        )
        losses.append(float(loss))
    assert losses[0] == pytest.approx(np.log(CFG.vocab), rel=0.3)
    assert losses[-1] < 0.3 * losses[0]  # overfits one batch


def test_nll_is_finite_and_positive():
    flat = jnp.array(init_flat(CFG))
    rng = np.random.default_rng(4)
    toks = jnp.array(rng.integers(0, CFG.vocab, size=(2, CFG.seq + 1)).astype(np.int32))
    nll = np.array(model.nll_fn(CFG, flat, toks))
    assert np.isfinite(nll).all() and (nll > 0).all()
