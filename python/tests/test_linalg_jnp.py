"""Blocked jnp linalg (the hessian_prep artifact body) vs NumPy/f64."""

import numpy as np
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from compile.linalg_jnp import (
    blocked_cholesky,
    blocked_tril_inverse,
    hessian_prep_fn,
)


def spd(rng, n, mult=2):
    x = rng.normal(size=(mult * n, n)).astype(np.float32)
    return (x.T @ x).astype(np.float32)


@given(n=st.sampled_from([16, 64, 128, 256]), seed=st.integers(0, 2**31 - 1))
@settings(max_examples=10, deadline=None)
def test_blocked_cholesky(n, seed):
    rng = np.random.default_rng(seed)
    h = spd(rng, n) + np.eye(n, dtype=np.float32)
    l = np.array(blocked_cholesky(jnp.array(h)))
    ref = np.linalg.cholesky(h.astype(np.float64))
    assert np.abs(l - ref).max() / np.abs(ref).max() < 1e-4
    assert np.allclose(np.triu(l, 1), 0.0)


@given(n=st.sampled_from([16, 128, 256]), seed=st.integers(0, 2**31 - 1))
@settings(max_examples=10, deadline=None)
def test_blocked_tril_inverse(n, seed):
    rng = np.random.default_rng(seed)
    h = spd(rng, n) + np.eye(n, dtype=np.float32)
    l = np.linalg.cholesky(h.astype(np.float64)).astype(np.float32)
    li = np.array(blocked_tril_inverse(jnp.array(l)))
    assert np.abs(li @ l - np.eye(n)).max() < 1e-3
    assert np.allclose(np.triu(li, 1), 0.0)


def test_hessian_prep_matches_f64_chain():
    rng = np.random.default_rng(0)
    for n in [64, 256, 512]:
        h = spd(rng, n)
        u = np.array(hessian_prep_fn(jnp.array(h), jnp.float32(0.01)))
        hd = h.astype(np.float64) + 0.01 * np.mean(np.diag(h)) * np.eye(n)
        ref = np.linalg.cholesky(np.linalg.inv(hd)).T
        assert np.abs(u - ref).max() / np.abs(ref).max() < 1e-4
        # factor property: H^{-1} = U^T U
        assert np.allclose(u.T @ u, np.linalg.inv(hd), rtol=1e-3, atol=1e-5)


def test_hessian_prep_zero_hessian_guard():
    """A dead layer (all-zero activations) must still produce a finite factor."""
    u = np.array(hessian_prep_fn(jnp.zeros((64, 64), jnp.float32), jnp.float32(0.01)))
    assert np.isfinite(u).all()
