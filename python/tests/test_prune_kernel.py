"""L1 correctness: Pallas window kernels vs the NumPy oracle.

Hypothesis sweeps shapes, sparsities, patterns and quantization grids; every
case asserts elementwise agreement of both the reconstructed weights and the
selected mask (the oracle and the kernels share tie-break semantics by
construction, so masks must match exactly).
"""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.prune_block import prune_window, prune_window_nm
from compile.kernels.ref import ref_sparsegpt, quant_grid
from compile.sparsegpt import _select_window_mask

SETTINGS = dict(max_examples=20, deadline=None)


def make_problem(rng, d_row, d_col, damp=0.01):
    w = rng.normal(size=(d_row, d_col)).astype(np.float32)
    x = rng.normal(size=(2 * d_col, d_col)).astype(np.float32)
    h = x.T @ x
    h += damp * np.trace(h) / d_col * np.eye(d_col)
    hinv = np.linalg.inv(h)
    hc = np.linalg.cholesky(hinv).T.astype(np.float32)  # upper factor
    return w, hc


def run_window(w, hc, p, qlevels):
    """Single-window (d_col == B) path through the production kernel."""
    d_row, d_col = w.shape
    diag = np.diag(hc)
    keep = _select_window_mask(jnp.array(w), jnp.array(diag), jnp.float32(p))
    if qlevels > 0:
        scale, zero = quant_grid(w, qlevels)
    else:
        scale, zero = np.ones((d_row, 1)), np.zeros((d_row, 1))
    qmeta = np.array([[1.0 if qlevels > 0 else 0.0, float(qlevels)]], np.float32)
    w_out, e = prune_window(
        jnp.array(w), keep, jnp.array(hc),
        jnp.array(scale, np.float32), jnp.array(zero, np.float32), jnp.array(qmeta),
    )
    return np.array(w_out), np.array(keep), np.array(e)


@given(
    d_row=st.sampled_from([16, 64, 128]),
    d_col=st.sampled_from([32, 64, 128]),
    p=st.floats(0.0, 0.9),
    seed=st.integers(0, 2**31 - 1),
)
@settings(**SETTINGS)
def test_unstructured_window_matches_oracle(d_row, d_col, p, seed):
    rng = np.random.default_rng(seed)
    w, hc = make_problem(rng, d_row, d_col)
    w_out, keep, _ = run_window(w, hc, p, 0)
    w_ref, keep_ref = ref_sparsegpt(
        w, hc, sparsity=p, blocksize=d_col, mask_blocksize=d_col
    )
    np.testing.assert_array_equal(keep, keep_ref)
    np.testing.assert_allclose(w_out, w_ref, atol=5e-5, rtol=1e-4)


@given(
    d_row=st.sampled_from([16, 64]),
    nm=st.sampled_from([(2, 4), (4, 8), (1, 4), (3, 4)]),
    seed=st.integers(0, 2**31 - 1),
)
@settings(**SETTINGS)
def test_nm_window_matches_oracle(d_row, nm, seed):
    rng = np.random.default_rng(seed)
    d_col = 64
    w, hc = make_problem(rng, d_row, d_col)
    qmeta = np.array([[0.0, 0.0]], np.float32)
    w_out, e, keep = prune_window_nm(
        nm[0], nm[1], jnp.array(w), jnp.array(hc),
        jnp.ones((d_row, 1), np.float32), jnp.zeros((d_row, 1), np.float32),
        jnp.array(qmeta),
    )
    w_ref, keep_ref = ref_sparsegpt(w, hc, nm=nm, blocksize=d_col)
    np.testing.assert_array_equal(np.array(keep), keep_ref)
    np.testing.assert_allclose(np.array(w_out), w_ref, atol=5e-5, rtol=1e-4)
    # exactly n zeros per m consecutive weights, per row
    groups = np.array(keep).reshape(d_row, d_col // nm[1], nm[1])
    assert (groups.sum(-1) == nm[1] - nm[0]).all()


@given(
    p=st.floats(0.0, 0.8),
    bits=st.sampled_from([2, 3, 4, 8]),
    seed=st.integers(0, 2**31 - 1),
)
@settings(**SETTINGS)
def test_joint_quantization_matches_oracle(p, bits, seed):
    rng = np.random.default_rng(seed)
    w, hc = make_problem(rng, 32, 64)
    levels = 2**bits - 1
    w_out, keep, _ = run_window(w, hc, p, levels)
    w_ref, keep_ref = ref_sparsegpt(
        w, hc, sparsity=p, blocksize=64, mask_blocksize=64, quant_levels=levels
    )
    np.testing.assert_array_equal(keep, keep_ref)
    np.testing.assert_allclose(w_out, w_ref, atol=5e-5, rtol=1e-4)
    # every surviving weight sits exactly on the per-row grid
    scale, zero = quant_grid(w, levels)
    wq = np.array(w_out)
    onto = np.round(wq / scale + zero)
    np.testing.assert_allclose(wq, scale * (onto - zero), atol=1e-5)


def test_pruned_entries_are_exactly_zero():
    rng = np.random.default_rng(7)
    w, hc = make_problem(rng, 64, 128)
    w_out, keep, _ = run_window(w, hc, 0.6, 0)
    assert (w_out[keep == 0.0] == 0.0).all()


def test_mask_density_exact():
    rng = np.random.default_rng(8)
    w, hc = make_problem(rng, 64, 128)
    for p in [0.0, 0.25, 0.5, 0.75]:
        _, keep, _ = run_window(w, hc, p, 0)
        assert keep.sum() == round((1 - p) * keep.size)


def test_zero_sparsity_no_quant_is_identity():
    rng = np.random.default_rng(9)
    w, hc = make_problem(rng, 32, 64)
    w_out, keep, e = run_window(w, hc, 0.0, 0)
    np.testing.assert_allclose(w_out, w, atol=1e-6)
    assert keep.all() and np.abs(e).max() == 0.0


def test_error_block_matches_definition():
    """E[:, j] must equal (w_j_at_processing_time - frozen_j) / hinv_jj."""
    rng = np.random.default_rng(10)
    w, hc = make_problem(rng, 16, 32)
    w_out, keep, e = run_window(w, hc, 0.5, 0)
    # kept columns generate zero error when not quantizing
    assert (e[keep == 1.0] == 0.0).all()
    # pruned entries generated nonzero error wherever the running weight was nonzero
    assert (np.abs(e[keep == 0.0]) > 0).mean() > 0.9
