"""AOT path integrity: lowering produces parseable, custom-call-free HLO
text and a manifest consistent with the enumeration."""

import functools
import re

import jax
import jax.numpy as jnp
import numpy as np

from compile import model, train
from compile.aot import enumerate_artifacts
from compile.configs import CONFIGS, ModelConfig
from compile.hlo import lower_to_hlo_text
from compile.sparsegpt import sparsegpt_layer_fn

F32, I32 = jnp.float32, jnp.int32
S = jax.ShapeDtypeStruct


def test_enumeration_names_unique_and_complete():
    arts = enumerate_artifacts(list(CONFIGS))
    names = set(arts)
    for cfg in CONFIGS.values():
        assert f"train_step_{cfg.name}" in names
        assert f"nll_{cfg.name}" in names
        assert f"embed_{cfg.name}" in names
        assert f"block_fwd_{cfg.name}" in names
        for (r, c) in cfg.prune_shapes():
            for pat in ["sparsegpt", "sparsegpt24", "sparsegpt48", "adaprune"]:
                assert f"{pat}_{r}x{c}" in names
        for dim in cfg.hessian_dims():
            assert f"hessian_{dim}" in names
    # Fig-10 ablation variants exist for the `small` config only
    assert any(n.startswith("sparsegpt_bs") for n in names)
    for n in names:
        if n.startswith("sparsegpt_bs"):
            r, c = n.split("_")[-1].split("x")
            assert (int(r), int(c)) in CONFIGS["small"].prune_shapes()


def _no_custom_calls(text):
    return set(re.findall(r'custom_call_target="([^"]+)"', text)) == set()


def test_solver_artifact_lowering_clean():
    t = lower_to_hlo_text(
        sparsegpt_layer_fn, (S((64, 128), F32), S((128, 128), F32), S((), F32), S((), F32))
    )
    assert t.startswith("HloModule")
    assert _no_custom_calls(t)


def test_model_artifact_lowering_clean():
    cfg = ModelConfig("t", d=32, layers=2, heads=2, train_batch=2, eval_batch=2, seq=16)
    t = lower_to_hlo_text(
        functools.partial(train.train_step_fn, cfg),
        (S((cfg.n_params,), F32),) * 3
        + (S((), F32), S((), F32), S((cfg.train_batch, cfg.seq + 1), I32)),
    )
    assert _no_custom_calls(t)
    t = lower_to_hlo_text(
        functools.partial(model.nll_fn, cfg),
        (S((cfg.n_params,), F32), S((cfg.eval_batch, cfg.seq + 1), I32)),
    )
    assert _no_custom_calls(t)


def test_eval_shape_matches_execution():
    """Manifest output shapes come from eval_shape; spot-check they match a
    real execution for one artifact."""
    cfg = ModelConfig("t", d=32, layers=2, heads=2, train_batch=2, eval_batch=2, seq=16)
    fn = functools.partial(model.block_fwd_fn, cfg)
    args = (S((cfg.block_size,), F32), S((cfg.eval_batch, cfg.seq, cfg.d), F32))
    shapes = jax.eval_shape(fn, *args)
    rng = np.random.default_rng(0)
    outs = fn(
        jnp.array(rng.normal(size=(cfg.block_size,)).astype(np.float32) * 0.05),
        jnp.array(rng.normal(size=(cfg.eval_batch, cfg.seq, cfg.d)).astype(np.float32)),
    )
    for s, o in zip(shapes, outs):
        assert s.shape == o.shape and s.dtype == o.dtype
