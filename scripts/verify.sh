#!/usr/bin/env bash
# Tier-1 verification: what CI runs and what every PR must keep green.
#   scripts/verify.sh          build + tests + formatting
#   scripts/verify.sh --fast   skip the release build (tests only)
set -euo pipefail
cd "$(dirname "$0")/.."

FAST=0
[ "${1:-}" = "--fast" ] && FAST=1

if [ "$FAST" -eq 0 ]; then
    echo "==> cargo build --release"
    cargo build --release
fi

echo "==> cargo test -q"
cargo test -q

echo "==> cargo fmt --check"
if ! cargo fmt --version >/dev/null 2>&1; then
    echo "    (rustfmt unavailable; skipping format check)"
else
    cargo fmt --check
fi

echo "verify: OK"
