#!/usr/bin/env python3
"""Generate rust/tests/golden/v1.spkt — a version-1 packed sparse
checkpoint, byte-for-byte what `SparseStore::save` wrote before the v2 TOC
(40-byte entries, no quant metadata, dense f32 sections).

The parameter vector is the deterministic fill
    val(i) = float32(((i * 31 + 7) % 256) - 128)
over the flat layout of ModelCfg::from_dims("v1-golden", 8, 2, 2, 1, 1, 13, 6),
so the pinned Rust test (tests/spkt_v1_golden.rs) can rebuild the expected
params without sharing any code with this script.
"""
import struct
from pathlib import Path

D, LAYERS, FFN, VOCAB, SEQ = 8, 2, 32, 13, 6
NAME, SRC = b"v1-golden", b"v1-golden-fixture"

# ModelCfg::from_dims param_layout, entry-for-entry
LAYOUT = [
    ("tok_embed", VOCAB * D),
    ("pos_embed", SEQ * D),
    ("ln1_g", LAYERS * D),
    ("ln1_b", LAYERS * D),
    ("wq", LAYERS * D * D),
    ("wk", LAYERS * D * D),
    ("wv", LAYERS * D * D),
    ("wo", LAYERS * D * D),
    ("ln2_g", LAYERS * D),
    ("ln2_b", LAYERS * D),
    ("w1", LAYERS * FFN * D),
    ("w2", LAYERS * D * FFN),
    ("lnf_g", D),
    ("lnf_b", D),
]
# PRUNABLE_KINDS order with (rows, cols); kind tag = position
KINDS = [("wq", D, D), ("wk", D, D), ("wv", D, D), ("wo", D, D), ("w1", FFN, D), ("w2", D, FFN)]
PRUNABLE = {k for k, _, _ in KINDS}

offsets, off = {}, 0
for name, numel in LAYOUT:
    offsets[name] = off
    off += numel
N_PARAMS = off


def val(i):
    return float(((i * 31 + 7) % 256) - 128)


def align8(n):
    return (n + 7) & ~7


def linear_slice(kind, layer, rows, cols):
    start = offsets[kind] + layer * rows * cols
    return [val(start + j) for j in range(rows * cols)]


def dense_section(rows, cols, values):
    out = struct.pack("<B3xII", 0, rows, cols)
    out += b"".join(struct.pack("<f", v) for v in values)
    return out


rest = []
for name, numel in LAYOUT:
    if name not in PRUNABLE:
        start = offsets[name]
        rest.extend(val(start + j) for j in range(numel))

entries = []  # (layer, ktag, rows, cols, nnz, section_bytes)
for layer in range(LAYERS):
    for ktag, (kind, rows, cols) in enumerate(KINDS):
        values = linear_slice(kind, layer, rows, cols)
        nnz = sum(1 for v in values if v != 0.0)
        entries.append((layer, ktag, rows, cols, nnz, dense_section(rows, cols, values)))

header_len = 8 + 4 + 4 + (4 + len(NAME)) + (4 + len(SRC)) + 8 + 4 + 4 + 8 + 8
toc_off = align8(header_len)
TOC_ENTRY = 40  # v1: layer u32, kind u8, fmt u8, pad u16, off u64, len u64, rows u32, cols u32, nnz u64
rest_off = align8(toc_off + len(entries) * TOC_ENTRY)
cursor = align8(rest_off + len(rest) * 4)
placed = []
for e in entries:
    placed.append((cursor, len(e[5])))
    cursor = align8(cursor + len(e[5]))

buf = bytearray()
buf += b"SGPTSPKT"
buf += struct.pack("<II", 1, 0)  # version 1, flags 0
buf += struct.pack("<I", len(NAME)) + NAME
buf += struct.pack("<I", len(SRC)) + SRC
buf += struct.pack("<QII", N_PARAMS, LAYERS, len(entries))
buf += struct.pack("<QQ", rest_off, len(rest))
assert len(buf) == header_len
buf += b"\0" * (toc_off - len(buf))
for (layer, ktag, rows, cols, nnz, _), (soff, slen) in zip(entries, placed):
    buf += struct.pack("<IBBHQQIIQ", layer, ktag, 0, 0, soff, slen, rows, cols, nnz)
buf += b"\0" * (rest_off - len(buf))
buf += b"".join(struct.pack("<f", v) for v in rest)
for (_, _, _, _, _, section), (soff, _) in zip(entries, placed):
    buf += b"\0" * (soff - len(buf))
    buf += section

out = Path(__file__).resolve().parent.parent / "rust" / "tests" / "golden" / "v1.spkt"
out.write_bytes(bytes(buf))
print(f"wrote {out} ({len(buf)} bytes, {N_PARAMS} params, {len(entries)} entries)")
