//! End-to-end driver: proves every layer of the stack composes on a real
//! workload — now four `api::Session` jobs instead of hand-wired plumbing:
//!   1. a `GenData` job if the corpora are missing,
//!   2. an `E2e` job (train from scratch unless a checkpoint exists, then
//!      one-shot prune with magnitude / SparseGPT-50% / SparseGPT-2:4 over
//!      shared calibration, then perplexity + zero-shot on each variant),
//!   3. the whole record written to reports/e2e_<config>.{txt,csv}.
//!
//! Defaults to the `medium` (~25M) config; pass a config name to override —
//! `large` (~85M, the OPT-175B stand-in) is the full-scale run recorded in
//! EXPERIMENTS.md.
//!
//! Run: cargo run --release --example e2e_pipeline [-- <config> [steps]]

use anyhow::Result;
use sparsegpt::api::{E2eSpec, GenDataSpec, HumanSink, JobSpec, Session};
use sparsegpt::eval::report::{fmt_ppl, Table};

fn main() -> Result<()> {
    let config = std::env::args().nth(1).unwrap_or_else(|| "medium".to_string());
    let steps: usize = std::env::args().nth(2).and_then(|s| s.parse().ok()).unwrap_or(300);

    let mut session = Session::new();
    let mut sink = HumanSink::new();

    // 1. data (idempotent: only when the tokenizer is missing)
    let data_dir = session.workspace()?.data_dir.clone();
    if !data_dir.join("tokenizer.txt").exists() {
        let gen = GenDataSpec { out: data_dir, ..Default::default() };
        session.run(&JobSpec::GenData(gen), &mut sink)?;
    }

    // 2. train (or reuse) -> prune 3 variants -> eval + zero-shot
    let mut spec = E2eSpec::new(&config);
    spec.steps = steps;
    let report = session
        .run(&JobSpec::E2e(spec), &mut sink)?
        .into_e2e()
        .expect("e2e job returns an e2e report");

    // 3. record
    if let Some(train) = &report.train {
        println!("\nloss curve (step, loss):");
        for (s, l) in &train.losses {
            println!("  {s:>6}  {l:.4}");
        }
    }
    let mut table = Table::new(
        &format!("e2e {config}: dense vs one-shot compressed"),
        &["variant", "sparsity", "wiki", "ptb", "c4", "zeroshot-avg"],
    );
    for v in report.sweep.all_rows() {
        table.row(vec![
            v.label.clone(),
            format!("{:.3}", v.sparsity),
            fmt_ppl(v.ppl["synth-wiki"]),
            fmt_ppl(v.ppl["synth-ptb"]),
            fmt_ppl(v.ppl["synth-c4-val"]),
            v.zeroshot
                .as_ref()
                .map(|z| format!("{:.1}%", z.avg * 100.0))
                .unwrap_or_else(|| "-".into()),
        ]);
    }
    print!("{}", table.render());
    table.save(&session.workspace()?.report_dir, &format!("e2e_{config}"))?;
    println!("(saved reports/e2e_{config}.txt)");
    Ok(())
}
