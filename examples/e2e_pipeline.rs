//! End-to-end driver: proves every layer of the stack composes on a real
//! workload. In one process it
//!   1. generates data if missing (synthetic corpora + BPE tokenizer),
//!   2. trains the target transformer from scratch for a few hundred steps
//!      through the `train_step` HLO artifact, logging the loss curve,
//!   3. one-shot prunes it with SparseGPT (50%, 2:4) and magnitude,
//!   4. evaluates perplexity on all three held-out corpora and the
//!      five zero-shot tasks,
//!   5. writes the whole record to reports/e2e_<config>.{txt,csv}.
//!
//! Defaults to the `medium` (~25M) config; pass a config name to override —
//! `large` (~85M, the OPT-175B stand-in) is the full-scale run recorded in
//! EXPERIMENTS.md.
//!
//! Run: cargo run --release --example e2e_pipeline [-- <config> [steps]]

use anyhow::Result;
use sparsegpt::bench::{eval_all, prune_variant};
use sparsegpt::coordinator::{PruneMethod, TrainOptions, Trainer};
use sparsegpt::data::corpus::Lexicon;
use sparsegpt::eval::report::{fmt_ppl, Table};
use sparsegpt::eval::zeroshot::{gen_items, zero_shot_accuracy, ZeroShotTask};
use sparsegpt::harness::{generate_data, Workspace, CALIB_SET};
use sparsegpt::model::checkpoint::Checkpoint;
use sparsegpt::model::init::init_params;
use sparsegpt::solver::sparsegpt_ref::Pattern;

fn main() -> Result<()> {
    let config = std::env::args().nth(1).unwrap_or_else(|| "medium".to_string());
    let steps: usize = std::env::args().nth(2).and_then(|s| s.parse().ok()).unwrap_or(300);

    let ws = Workspace::open()?;
    let cfg = ws.config(&config)?;
    println!("=== e2e: {config} ({} params) ===", cfg.n_params);

    // 1. data
    if !ws.data_dir.join("tokenizer.txt").exists() {
        println!("[e2e] generating data...");
        generate_data(&ws.data_dir, 0, 4)?;
    }
    let data = ws.dataset(CALIB_SET)?;

    // 2. train (resume from an existing checkpoint when present)
    let ckpt_path = Checkpoint::path_for(&ws.ckpt_dir, &config, "");
    let (params, losses) = if ckpt_path.exists() {
        println!("[e2e] using existing checkpoint {ckpt_path:?}");
        (ws.load_model(&config)?, Vec::new())
    } else {
        let mut opts = TrainOptions::for_config(&config, steps);
        opts.out = Some(ws.ckpt_dir.clone());
        opts.log_every = 10;
        let out = Trainer::new(&ws.rt).train(init_params(&cfg, 0), None, 0, &data, &opts)?;
        println!("[e2e] trained {} steps in {:.0}s", steps, out.secs);
        (out.params, out.losses)
    };

    // 3+4. prune variants and evaluate
    let mut table = Table::new(
        &format!("e2e {config}: dense vs one-shot compressed"),
        &["variant", "sparsity", "wiki", "ptb", "c4", "zeroshot-avg"],
    );
    let tok = ws.tokenizer()?;
    let lex = Lexicon::new(0);
    let zs = |p: &sparsegpt::model::FlatParams| -> Result<f64> {
        let mut sum = 0.0;
        for task in ZeroShotTask::ALL {
            let items = gen_items(task, &lex, 7, 50);
            sum += zero_shot_accuracy(&ws.rt, p, &tok, &items)?;
        }
        Ok(sum / ZeroShotTask::ALL.len() as f64)
    };

    let dense_ppl = eval_all(&ws, &params)?;
    let dense_zs = zs(&params)?;
    table.row(vec![
        "dense".into(),
        "0.000".into(),
        fmt_ppl(dense_ppl["synth-wiki"]),
        fmt_ppl(dense_ppl["synth-ptb"]),
        fmt_ppl(dense_ppl["synth-c4-val"]),
        format!("{:.1}%", dense_zs * 100.0),
    ]);

    for method in [
        PruneMethod::Magnitude { pattern: Pattern::Unstructured(0.5) },
        PruneMethod::SparseGpt { pattern: Pattern::Unstructured(0.5), quant_bits: None },
        PruneMethod::SparseGpt { pattern: Pattern::NM(2, 4), quant_bits: None },
    ] {
        let label = method.label();
        println!("[e2e] pruning: {label}");
        let outcome = prune_variant(&ws, &params, method)?;
        println!(
            "[e2e] {label}: sparsity {:.3} in {:.0}s",
            outcome.overall_sparsity(),
            outcome.total_secs
        );
        let ppl = eval_all(&ws, &outcome.params)?;
        let z = zs(&outcome.params)?;
        table.row(vec![
            label,
            format!("{:.3}", outcome.overall_sparsity()),
            fmt_ppl(ppl["synth-wiki"]),
            fmt_ppl(ppl["synth-ptb"]),
            fmt_ppl(ppl["synth-c4-val"]),
            format!("{:.1}%", z * 100.0),
        ]);
    }

    // 5. record
    if !losses.is_empty() {
        println!("\nloss curve (step, loss):");
        for (s, l) in &losses {
            println!("  {s:>6}  {l:.4}");
        }
    }
    print!("{}", table.render());
    table.save(&ws.report_dir, &format!("e2e_{config}"))?;
    println!("(saved reports/e2e_{config}.txt)");
    Ok(())
}
