//! Sparsity sweep (the Figure 1 / Figure 5 experiment): SparseGPT vs
//! magnitude pruning at uniform per-layer sparsities 10%..80% on one model,
//! printing the perplexity series the paper plots. One `Sweep` job: the
//! calibration chunks are drawn once and shared by all 16 prune variants.
//!
//! Run: cargo run --release --example sparsity_sweep [-- <config> [dataset]]

use anyhow::Result;
use sparsegpt::api::{HumanSink, JobSpec, PruneSpec, Session, SweepSpec};
use sparsegpt::eval::report::{fmt_ppl, Table};

const POINTS: [f64; 8] = [0.1, 0.2, 0.3, 0.4, 0.5, 0.6, 0.7, 0.8];

fn main() -> Result<()> {
    let config = std::env::args().nth(1).unwrap_or_else(|| "small".to_string());
    let dataset = std::env::args().nth(2).unwrap_or_else(|| "synth-wiki".to_string());

    let mut spec = SweepSpec::new(&config).dense(true).dataset(&dataset);
    for &p in &POINTS {
        spec = spec.variant(PruneSpec::sparsegpt(p)).variant(PruneSpec::magnitude(p));
    }

    let mut session = Session::new();
    let report = session
        .run(&JobSpec::Sweep(spec), &mut HumanSink::new())?
        .into_sweep()
        .expect("sweep job returns a sweep report");

    let dense_ppl = report
        .dense
        .as_ref()
        .and_then(|d| d.ppl.get(dataset.as_str()).copied())
        .unwrap_or(f64::NAN);
    let mut table = Table::new(
        &format!("sparsity sweep: {config} on {dataset} (dense {})", fmt_ppl(dense_ppl)),
        &["sparsity", "sparsegpt", "magnitude"],
    );
    for (i, &p) in POINTS.iter().enumerate() {
        let s = &report.variants[2 * i];
        let m = &report.variants[2 * i + 1];
        table.row(vec![
            format!("{:.0}%", p * 100.0),
            fmt_ppl(s.ppl[dataset.as_str()]),
            fmt_ppl(m.ppl[dataset.as_str()]),
        ]);
    }
    print!("{}", table.render());
    table.save(&session.workspace()?.report_dir, &format!("sweep_{config}"))?;
    Ok(())
}
