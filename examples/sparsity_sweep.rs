//! Sparsity sweep (the Figure 1 / Figure 5 experiment): SparseGPT vs
//! magnitude pruning at uniform per-layer sparsities 10%..80% on one model,
//! printing the perplexity series the paper plots.
//!
//! Run: cargo run --release --example sparsity_sweep [-- <config> [dataset]]

use anyhow::Result;
use sparsegpt::bench::{eval_one, prune_variant};
use sparsegpt::coordinator::PruneMethod;
use sparsegpt::eval::report::{fmt_ppl, Table};
use sparsegpt::harness::Workspace;
use sparsegpt::solver::sparsegpt_ref::Pattern;

fn main() -> Result<()> {
    let config = std::env::args().nth(1).unwrap_or_else(|| "small".to_string());
    let dataset = std::env::args().nth(2).unwrap_or_else(|| "synth-wiki".to_string());
    let ws = Workspace::open()?;
    let dense = ws.load_model(&config)?;
    let dense_ppl = eval_one(&ws, &dense, &dataset)?;
    println!("dense {config} on {dataset}: ppl {}", fmt_ppl(dense_ppl));

    let mut table = Table::new(
        &format!("sparsity sweep: {config} on {dataset} (dense {})", fmt_ppl(dense_ppl)),
        &["sparsity", "sparsegpt", "magnitude"],
    );
    for p in [0.1, 0.2, 0.3, 0.4, 0.5, 0.6, 0.7, 0.8] {
        let s = prune_variant(
            &ws,
            &dense,
            PruneMethod::SparseGpt { pattern: Pattern::Unstructured(p), quant_bits: None },
        )?;
        let m = prune_variant(
            &ws,
            &dense,
            PruneMethod::Magnitude { pattern: Pattern::Unstructured(p) },
        )?;
        let ps = eval_one(&ws, &s.params, &dataset)?;
        let pm = eval_one(&ws, &m.params, &dataset)?;
        println!("p={p:.1}: sparsegpt {} magnitude {}", fmt_ppl(ps), fmt_ppl(pm));
        table.row(vec![format!("{:.0}%", p * 100.0), fmt_ppl(ps), fmt_ppl(pm)]);
    }
    print!("{}", table.render());
    table.save(&ws.report_dir, &format!("sweep_{config}"))?;
    Ok(())
}
