//! Quickstart: one-shot prune a trained model to 50% unstructured sparsity
//! with SparseGPT and compare perplexity against the dense baseline and
//! magnitude pruning — the paper's core claim in ~40 lines of API use.
//!
//! Prereqs: `make artifacts && sparsegpt gen-data && sparsegpt train --config nano`
//! Run:     cargo run --release --example quickstart [-- <config>]

use anyhow::Result;
use sparsegpt::api::{HumanSink, JobSpec, PruneSpec, Session, SweepSpec};
use sparsegpt::eval::report::{fmt_ppl, Table};

fn main() -> Result<()> {
    let config = std::env::args().nth(1).unwrap_or_else(|| "nano".to_string());
    let spec = SweepSpec::new(&config)
        .dense(true)
        .variant(PruneSpec::magnitude(0.5))
        .variant(PruneSpec::sparsegpt(0.5));

    let mut session = Session::new();
    let report = session
        .run(&JobSpec::Sweep(spec), &mut HumanSink::new())?
        .into_sweep()
        .expect("sweep job returns a sweep report");

    let mut table = Table::new(
        &format!("quickstart: {config} @ 50% sparsity"),
        &["variant", "sparsity", "synth-wiki", "synth-ptb", "synth-c4-val"],
    );
    for v in report.all_rows() {
        table.row(vec![
            v.label.clone(),
            format!("{:.3}", v.sparsity),
            fmt_ppl(v.ppl["synth-wiki"]),
            fmt_ppl(v.ppl["synth-ptb"]),
            fmt_ppl(v.ppl["synth-c4-val"]),
        ]);
    }
    print!("{}", table.render());
    Ok(())
}
