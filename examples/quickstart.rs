//! Quickstart: one-shot prune a trained model to 50% unstructured sparsity
//! with SparseGPT and compare perplexity against the dense baseline and
//! magnitude pruning — the paper's core claim in ~60 lines of API use.
//!
//! Prereqs: `make artifacts && sparsegpt gen-data && sparsegpt train --config nano`
//! Run:     cargo run --release --example quickstart [-- <config>]

use anyhow::Result;
use sparsegpt::bench::{eval_all, prune_variant};
use sparsegpt::coordinator::PruneMethod;
use sparsegpt::eval::report::{fmt_ppl, Table};
use sparsegpt::harness::Workspace;
use sparsegpt::solver::sparsegpt_ref::Pattern;

fn main() -> Result<()> {
    let config = std::env::args().nth(1).unwrap_or_else(|| "nano".to_string());
    let ws = Workspace::open()?;
    let dense = ws.load_model(&config)?;
    println!(
        "loaded {config}: {} params ({} prunable)",
        dense.cfg.n_params,
        dense.cfg.prunable_params()
    );

    let mut table = Table::new(
        &format!("quickstart: {config} @ 50% sparsity"),
        &["variant", "sparsity", "synth-wiki", "synth-ptb", "synth-c4-val"],
    );

    let dense_ppl = eval_all(&ws, &dense)?;
    table.row(vec![
        "dense".into(),
        "0.000".into(),
        fmt_ppl(dense_ppl["synth-wiki"]),
        fmt_ppl(dense_ppl["synth-ptb"]),
        fmt_ppl(dense_ppl["synth-c4-val"]),
    ]);

    for method in [
        PruneMethod::Magnitude { pattern: Pattern::Unstructured(0.5) },
        PruneMethod::SparseGpt { pattern: Pattern::Unstructured(0.5), quant_bits: None },
    ] {
        let label = method.label();
        let outcome = prune_variant(&ws, &dense, method)?;
        println!(
            "{label}: pruned in {:.1}s (solver {:.1}s)",
            outcome.total_secs, outcome.solver_secs
        );
        let ppl = eval_all(&ws, &outcome.params)?;
        table.row(vec![
            label,
            format!("{:.3}", outcome.overall_sparsity()),
            fmt_ppl(ppl["synth-wiki"]),
            fmt_ppl(ppl["synth-ptb"]),
            fmt_ppl(ppl["synth-c4-val"]),
        ]);
    }

    print!("{}", table.render());
    Ok(())
}
