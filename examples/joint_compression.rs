//! Joint sparsification + quantization (the Figure 6 experiment): compare
//! 50% sparse + 4-bit (3 effective bits/weight with the bitmask) against
//! size-equivalent 3-bit GPTQ — which in this codebase is literally the same
//! artifact with sparsity = 0, the paper's own observation that SparseGPT
//! generalizes GPTQ. All variants run as one `Sweep` job over shared
//! calibration.
//!
//! Run: cargo run --release --example joint_compression [-- <config>]

use anyhow::Result;
use sparsegpt::api::{HumanSink, JobSpec, PruneSpec, Session, SweepSpec};
use sparsegpt::eval::report::{fmt_ppl, Table};
use sparsegpt::solver::quant::effective_bits;

fn main() -> Result<()> {
    let config = std::env::args().nth(1).unwrap_or_else(|| "small".to_string());
    let variants: Vec<(&str, PruneSpec, f64)> = vec![
        ("50% + 4-bit", PruneSpec::sparsegpt(0.5).with_quant_bits(4), effective_bits(0.5, 4.0)),
        ("GPTQ 3-bit", PruneSpec::sparsegpt(0.0).with_quant_bits(3), 3.0),
        ("50% + 3-bit", PruneSpec::sparsegpt(0.5).with_quant_bits(3), effective_bits(0.5, 3.0)),
        ("2:4 + 4-bit", PruneSpec::sparsegpt_nm(2, 4).with_quant_bits(4), effective_bits(0.5, 4.0)),
    ];

    let spec = SweepSpec::new(&config)
        .dense(true)
        .dataset("synth-wiki")
        .variants(variants.iter().map(|(_, v, _)| v.clone()).collect());

    let mut session = Session::new();
    let report = session
        .run(&JobSpec::Sweep(spec), &mut HumanSink::new())?
        .into_sweep()
        .expect("sweep job returns a sweep report");

    let dense_ppl = report
        .dense
        .as_ref()
        .and_then(|d| d.ppl.get("synth-wiki").copied())
        .unwrap_or(f64::NAN);
    let mut table = Table::new(
        &format!("joint compression: {config} on synth-wiki (dense {})", fmt_ppl(dense_ppl)),
        &["variant", "bits/weight", "ppl"],
    );
    for ((label, _, bits), v) in variants.iter().zip(&report.variants) {
        table.row(vec![label.to_string(), format!("{bits:.1}"), fmt_ppl(v.ppl["synth-wiki"])]);
    }
    print!("{}", table.render());
    table.save(&session.workspace()?.report_dir, &format!("joint_{config}"))?;
    Ok(())
}
