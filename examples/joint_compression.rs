//! Joint sparsification + quantization (the Figure 6 experiment): compare
//! 50% sparse + 4-bit (3 effective bits/weight with the bitmask) against
//! size-equivalent 3-bit GPTQ — which in this codebase is literally the same
//! artifact with sparsity = 0, the paper's own observation that SparseGPT
//! generalizes GPTQ.
//!
//! Run: cargo run --release --example joint_compression [-- <config>]

use anyhow::Result;
use sparsegpt::bench::{eval_one, prune_variant};
use sparsegpt::coordinator::PruneMethod;
use sparsegpt::eval::report::{fmt_ppl, Table};
use sparsegpt::harness::Workspace;
use sparsegpt::solver::quant::effective_bits;
use sparsegpt::solver::sparsegpt_ref::Pattern;

fn main() -> Result<()> {
    let config = std::env::args().nth(1).unwrap_or_else(|| "small".to_string());
    let ws = Workspace::open()?;
    let dense = ws.load_model(&config)?;
    let dense_ppl = eval_one(&ws, &dense, "synth-wiki")?;

    let variants: Vec<(String, PruneMethod, f64)> = vec![
        (
            "50% + 4-bit".into(),
            PruneMethod::SparseGpt { pattern: Pattern::Unstructured(0.5), quant_bits: Some(4) },
            effective_bits(0.5, 4.0),
        ),
        (
            "GPTQ 3-bit".into(),
            PruneMethod::SparseGpt { pattern: Pattern::Unstructured(0.0), quant_bits: Some(3) },
            3.0,
        ),
        (
            "50% + 3-bit".into(),
            PruneMethod::SparseGpt { pattern: Pattern::Unstructured(0.5), quant_bits: Some(3) },
            effective_bits(0.5, 3.0),
        ),
        (
            "2:4 + 4-bit".into(),
            PruneMethod::SparseGpt { pattern: Pattern::NM(2, 4), quant_bits: Some(4) },
            effective_bits(0.5, 4.0),
        ),
    ];

    let mut table = Table::new(
        &format!("joint compression: {config} on synth-wiki (dense {})", fmt_ppl(dense_ppl)),
        &["variant", "bits/weight", "ppl"],
    );
    for (label, method, bits) in variants {
        let out = prune_variant(&ws, &dense, method)?;
        let ppl = eval_one(&ws, &out.params, "synth-wiki")?;
        println!("{label}: ppl {}", fmt_ppl(ppl));
        table.row(vec![label, format!("{bits:.1}"), fmt_ppl(ppl)]);
    }
    print!("{}", table.render());
    table.save(&ws.report_dir, &format!("joint_{config}"))?;
    Ok(())
}
