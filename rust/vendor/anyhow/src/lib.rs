//! Minimal offline stand-in for the `anyhow` crate.
//!
//! Implements the subset this repository uses: `Result<T>`, `Error` with a
//! context chain, the `anyhow!` / `bail!` / `ensure!` macros, and the
//! `Context` extension trait for `Result` and `Option`. Formatting follows
//! the real crate's conventions: `{}` prints the outermost message, `{:#}`
//! prints the whole chain separated by `: `, and `{:?}` prints the message
//! followed by a `Caused by:` list.

use std::error::Error as StdError;
use std::fmt;

pub type Result<T, E = Error> = std::result::Result<T, E>;

/// An error with an ordered context chain (outermost context first).
pub struct Error {
    chain: Vec<String>,
}

impl Error {
    /// Create an error from a displayable message.
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error { chain: vec![message.to_string()] }
    }

    /// Wrap this error with an additional layer of context.
    pub fn wrap<C: fmt::Display>(mut self, context: C) -> Error {
        self.chain.insert(0, context.to_string());
        self
    }

    /// The context chain, outermost first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(|s| s.as_str())
    }

    /// The innermost (root) message of the chain.
    pub fn root_cause(&self) -> &str {
        self.chain.last().map(|s| s.as_str()).unwrap_or("")
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            write!(f, "{}", self.chain.join(": "))
        } else {
            write!(f, "{}", self.chain.first().map(|s| s.as_str()).unwrap_or(""))
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.chain.first().map(|s| s.as_str()).unwrap_or(""))?;
        if self.chain.len() > 1 {
            write!(f, "\n\nCaused by:")?;
            for c in &self.chain[1..] {
                write!(f, "\n    {c}")?;
            }
        }
        Ok(())
    }
}

impl<E: StdError + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        let mut chain = vec![e.to_string()];
        let mut src = e.source();
        while let Some(s) = src {
            chain.push(s.to_string());
            src = s.source();
        }
        Error { chain }
    }
}

/// Extension trait adding `.context(..)` / `.with_context(..)`.
pub trait Context<T> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T>;
    fn with_context<C, F>(self, f: F) -> Result<T>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C;
}

impl<T, E: Into<Error>> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T> {
        self.map_err(|e| e.into().wrap(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.map_err(|e| e.into().wrap(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an `Error` from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(format!($($arg)*))
    };
}

/// Return early with an `Error` built from a format string.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return ::std::result::Result::Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an error if a condition is false.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            return ::std::result::Result::Err($crate::anyhow!($($arg)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "file missing")
    }

    #[test]
    fn display_and_alternate_chain() {
        let e: Error = io_err().into();
        let e = e.wrap("reading manifest");
        assert_eq!(format!("{e}"), "reading manifest");
        assert_eq!(format!("{e:#}"), "reading manifest: file missing");
    }

    #[test]
    fn context_on_result_and_option() {
        fn inner() -> Result<()> {
            let r: std::result::Result<(), std::io::Error> = Err(io_err());
            r?;
            Ok(())
        }
        let e = inner().context("outer").unwrap_err();
        assert!(format!("{e:#}").contains("outer"));
        let v: Option<u32> = None;
        assert!(v.with_context(|| "missing value").is_err());
    }

    #[test]
    fn macros_compile_and_format() {
        fn f(x: u32) -> Result<u32> {
            ensure!(x < 10, "too big: {x}");
            if x == 3 {
                bail!("unlucky {}", x);
            }
            Ok(x)
        }
        assert_eq!(f(5).unwrap(), 5);
        assert_eq!(format!("{}", f(3).unwrap_err()), "unlucky 3");
        assert!(f(11).is_err());
        let e = anyhow!("plain");
        assert_eq!(e.root_cause(), "plain");
    }
}
