//! Offline stand-in for the `xla` PJRT bindings (xla-rs).
//!
//! This crate exposes the exact API subset `sparsegpt::runtime` compiles
//! against — `PjRtClient`, `PjRtBuffer`, `PjRtLoadedExecutable`,
//! `HloModuleProto`, `XlaComputation`, `Literal` — but cannot execute
//! anything: the container this repository builds in has no XLA/PJRT
//! shared libraries, so `PjRtClient::cpu()` fails with a descriptive
//! error before any other entry point can be reached.
//!
//! To run the real pipeline, replace this vendored crate with the actual
//! PJRT bindings (same API surface) in `rust/Cargo.toml`:
//!
//! ```toml
//! [dependencies]
//! xla = { path = "/path/to/real/xla-rs" }
//! ```
//!
//! Everything that does not dispatch to PJRT — the pure-Rust reference
//! solvers, the sparse inference engines, data/tokenizer/checkpoint IO,
//! the `api` job layer, and all tier-1 tests — works with this stub.

use std::error::Error as StdError;
use std::fmt;

const UNAVAILABLE: &str = "PJRT backend unavailable: this build links the offline `xla` stub \
     (rust/vendor/xla); swap in the real PJRT bindings to execute artifacts";

/// Error type mirroring xla-rs's: `Debug`-printable and a std error.
#[derive(Clone)]
pub struct Error {
    msg: String,
}

impl Error {
    fn unavailable() -> Error {
        Error { msg: UNAVAILABLE.to_string() }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "XlaError({})", self.msg)
    }
}

impl StdError for Error {}

pub type Result<T> = std::result::Result<T, Error>;

/// Element types marshallable to device buffers.
pub trait ElementType: Copy {}
impl ElementType for f32 {}
impl ElementType for i32 {}
impl ElementType for u8 {}

/// A parsed HLO module (stub: never constructed successfully).
pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        Err(Error::unavailable())
    }
}

/// An XLA computation wrapping an HLO module.
pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

/// A PJRT device handle.
pub struct PjRtDevice;

/// A device-resident buffer.
pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(Error::unavailable())
    }
}

/// A host-side literal value (possibly a tuple).
pub struct Literal;

impl Literal {
    pub fn to_tuple(&self) -> Result<Vec<Literal>> {
        Err(Error::unavailable())
    }

    pub fn copy_raw_to<T: ElementType>(&self, _dst: &mut [T]) -> Result<()> {
        Err(Error::unavailable())
    }
}

/// A compiled executable.
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute_b<T: std::borrow::Borrow<PjRtBuffer>>(
        &self,
        _args: &[T],
    ) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(Error::unavailable())
    }
}

/// The PJRT client. In this stub, construction always fails.
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Err(Error::unavailable())
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(Error::unavailable())
    }

    pub fn buffer_from_host_buffer<T: ElementType>(
        &self,
        _data: &[T],
        _dims: &[usize],
        _device: Option<&PjRtDevice>,
    ) -> Result<PjRtBuffer> {
        Err(Error::unavailable())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn client_construction_fails_with_clear_message() {
        let err = PjRtClient::cpu().err().unwrap();
        assert!(format!("{err}").contains("PJRT backend unavailable"));
        assert!(format!("{err:?}").contains("XlaError"));
    }

    #[test]
    fn hlo_parsing_fails_cleanly() {
        assert!(HloModuleProto::from_text_file("x.hlo.txt").is_err());
    }
}
