//! Checkpoint IO: a small self-describing binary format (serde is not
//! available offline).
//!
//! Layout (little-endian):
//!   magic  b"SGPTCKPT"            8 bytes
//!   version u32                    (currently 1)
//!   name_len u32 + utf8 name
//!   n_params u64
//!   step u64                       (training step the checkpoint was taken at)
//!   flags u32                      bit0: has Adam state
//!   params  f32 * n_params
//!   [m f32 * n_params, v f32 * n_params]  if bit0

use std::io::{Read, Write};
use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::model::config::ModelCfg;
use crate::model::layout::FlatParams;

const MAGIC: &[u8; 8] = b"SGPTCKPT";
const VERSION: u32 = 1;

#[derive(Clone, Debug)]
pub struct Checkpoint {
    pub config_name: String,
    pub step: u64,
    pub params: Vec<f32>,
    pub adam: Option<(Vec<f32>, Vec<f32>)>,
}

impl Checkpoint {
    pub fn save(&self, path: impl AsRef<Path>) -> Result<()> {
        let path = path.as_ref();
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        let tmp = path.with_extension("tmp");
        {
            let mut f = std::io::BufWriter::new(std::fs::File::create(&tmp)?);
            f.write_all(MAGIC)?;
            f.write_all(&VERSION.to_le_bytes())?;
            let name = self.config_name.as_bytes();
            f.write_all(&(name.len() as u32).to_le_bytes())?;
            f.write_all(name)?;
            f.write_all(&(self.params.len() as u64).to_le_bytes())?;
            f.write_all(&self.step.to_le_bytes())?;
            let flags: u32 = if self.adam.is_some() { 1 } else { 0 };
            f.write_all(&flags.to_le_bytes())?;
            write_f32s(&mut f, &self.params)?;
            if let Some((m, v)) = &self.adam {
                if m.len() != self.params.len() || v.len() != self.params.len() {
                    bail!("adam state length mismatch");
                }
                write_f32s(&mut f, m)?;
                write_f32s(&mut f, v)?;
            }
        }
        std::fs::rename(&tmp, path)?;
        Ok(())
    }

    pub fn load(path: impl AsRef<Path>) -> Result<Checkpoint> {
        let path = path.as_ref();
        let mut f = std::io::BufReader::new(
            std::fs::File::open(path).with_context(|| format!("opening checkpoint {path:?}"))?,
        );
        let mut magic = [0u8; 8];
        f.read_exact(&mut magic)?;
        if &magic != MAGIC {
            bail!("{path:?} is not a SparseGPT checkpoint (bad magic)");
        }
        let version = read_u32(&mut f)?;
        if version != VERSION {
            bail!("unsupported checkpoint version {version}");
        }
        let name_len = read_u32(&mut f)? as usize;
        if name_len > 1024 {
            bail!("implausible name length {name_len}");
        }
        let mut name = vec![0u8; name_len];
        f.read_exact(&mut name)?;
        let n_params = read_u64(&mut f)? as usize;
        let step = read_u64(&mut f)?;
        let flags = read_u32(&mut f)?;
        let params = read_f32s(&mut f, n_params)?;
        let adam = if flags & 1 != 0 {
            Some((read_f32s(&mut f, n_params)?, read_f32s(&mut f, n_params)?))
        } else {
            None
        };
        Ok(Checkpoint {
            config_name: String::from_utf8(name)?,
            step,
            params,
            adam,
        })
    }

    pub fn into_flat_params(self, cfg: &ModelCfg) -> Result<FlatParams> {
        if self.config_name != cfg.name {
            bail!("checkpoint is for config {:?}, expected {:?}", self.config_name, cfg.name);
        }
        FlatParams::new(cfg, self.params)
    }

    /// Conventional checkpoint path: `<dir>/<config><suffix>.ckpt`.
    pub fn path_for(dir: impl AsRef<Path>, config: &str, suffix: &str) -> std::path::PathBuf {
        dir.as_ref().join(format!("{config}{suffix}.ckpt"))
    }
}

fn write_f32s<W: Write>(w: &mut W, xs: &[f32]) -> Result<()> {
    // bulk byte-cast (LE host assumed; asserted at runtime below)
    assert!(cfg!(target_endian = "little"), "big-endian hosts unsupported");
    let bytes =
        unsafe { std::slice::from_raw_parts(xs.as_ptr() as *const u8, xs.len() * 4) };
    w.write_all(bytes)?;
    Ok(())
}

fn read_f32s<R: Read>(r: &mut R, n: usize) -> Result<Vec<f32>> {
    let mut xs = vec![0f32; n];
    let bytes =
        unsafe { std::slice::from_raw_parts_mut(xs.as_mut_ptr() as *mut u8, n * 4) };
    r.read_exact(bytes)?;
    Ok(xs)
}

fn read_u32<R: Read>(r: &mut R) -> Result<u32> {
    let mut b = [0u8; 4];
    r.read_exact(&mut b)?;
    Ok(u32::from_le_bytes(b))
}

fn read_u64<R: Read>(r: &mut R) -> Result<u64> {
    let mut b = [0u8; 8];
    r.read_exact(&mut b)?;
    Ok(u64::from_le_bytes(b))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_with_adam() {
        let dir = std::env::temp_dir().join(format!("sgpt_ckpt_test_{}", std::process::id()));
        let path = dir.join("t.ckpt");
        let ck = Checkpoint {
            config_name: "nano".into(),
            step: 42,
            params: vec![1.0, -2.5, 3.25],
            adam: Some((vec![0.1, 0.2, 0.3], vec![0.4, 0.5, 0.6])),
        };
        ck.save(&path).unwrap();
        let back = Checkpoint::load(&path).unwrap();
        assert_eq!(back.config_name, "nano");
        assert_eq!(back.step, 42);
        assert_eq!(back.params, ck.params);
        assert_eq!(back.adam, ck.adam);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn roundtrip_without_adam() {
        let dir = std::env::temp_dir().join(format!("sgpt_ckpt_test2_{}", std::process::id()));
        let path = dir.join("t.ckpt");
        let ck = Checkpoint { config_name: "x".into(), step: 0, params: vec![7.0; 10], adam: None };
        ck.save(&path).unwrap();
        let back = Checkpoint::load(&path).unwrap();
        assert!(back.adam.is_none());
        assert_eq!(back.params.len(), 10);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn rejects_garbage_file() {
        let dir = std::env::temp_dir().join(format!("sgpt_ckpt_test3_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("bad.ckpt");
        std::fs::write(&path, b"not a checkpoint at all").unwrap();
        assert!(Checkpoint::load(&path).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }
}
