//! Sparsity / compression statistics over a model's prunable layers,
//! reported per layer and per linear kind (what the paper's tables quote).

use crate::model::layout::{FlatParams, LinearKind, PRUNABLE_KINDS};

#[derive(Clone, Debug)]
pub struct LayerStats {
    pub layer: usize,
    pub kind: LinearKind,
    pub total: usize,
    pub zeros: usize,
    /// n:m constraint violations (groups without exactly n zeros); only
    /// meaningful after n:m pruning.
    pub nm_violations: Option<usize>,
}

impl LayerStats {
    pub fn sparsity(&self) -> f64 {
        self.zeros as f64 / self.total.max(1) as f64
    }
}

#[derive(Clone, Debug)]
pub struct ModelStats {
    pub per_layer: Vec<LayerStats>,
}

impl ModelStats {
    pub fn collect(fp: &FlatParams) -> ModelStats {
        Self::collect_nm(fp, None)
    }

    /// Collect stats; if `nm` is given, also count violated n:m groups.
    pub fn collect_nm(fp: &FlatParams, nm: Option<(usize, usize)>) -> ModelStats {
        let mut per_layer = Vec::new();
        for l in 0..fp.cfg.layers {
            for kind in PRUNABLE_KINDS {
                let w = fp.get_linear(kind, l).unwrap();
                let zeros = w.data().iter().filter(|&&x| x == 0.0).count();
                let nm_violations = nm.map(|(n, m)| {
                    let (rows, cols) = (w.rows(), w.cols());
                    let mut bad = 0;
                    let full = cols / m * m; // complete groups only
                    for r in 0..rows {
                        let row = w.row(r);
                        for g in (0..full).step_by(m) {
                            let z = row[g..g + m].iter().filter(|&&x| x == 0.0).count();
                            if z != n {
                                bad += 1;
                            }
                        }
                    }
                    bad
                });
                per_layer.push(LayerStats { layer: l, kind, total: w.len(), zeros, nm_violations });
            }
        }
        ModelStats { per_layer }
    }

    pub fn overall_sparsity(&self) -> f64 {
        let zeros: usize = self.per_layer.iter().map(|s| s.zeros).sum();
        let total: usize = self.per_layer.iter().map(|s| s.total).sum();
        zeros as f64 / total.max(1) as f64
    }

    pub fn total_nm_violations(&self) -> usize {
        self.per_layer.iter().filter_map(|s| s.nm_violations).sum()
    }

    pub fn pruned_weight_count(&self) -> usize {
        self.per_layer.iter().map(|s| s.zeros).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::layout::tests::tiny_cfg;
    use crate::tensor::Tensor;

    #[test]
    fn counts_sparsity_and_nm() {
        let cfg = tiny_cfg();
        let mut fp = FlatParams::zeros(&cfg);
        // make everything dense first
        for l in 0..cfg.layers {
            for kind in PRUNABLE_KINDS {
                let (r, c) = kind.shape(&cfg);
                fp.set_linear(kind, l, &Tensor::ones(vec![r, c])).unwrap();
            }
        }
        // 2:4 pattern on fc2 of layer 0 (d x ffn = 2 x 4)
        let w = Tensor::new(vec![2, 4], vec![0., 1., 0., 2., 3., 0., 4., 0.]);
        fp.set_linear(LinearKind::Fc2, 0, &w).unwrap();
        let stats = ModelStats::collect_nm(&fp, Some((2, 4)));
        let fc2 = stats
            .per_layer
            .iter()
            .find(|s| s.layer == 0 && s.kind == LinearKind::Fc2)
            .unwrap();
        assert_eq!(fc2.zeros, 4);
        assert_eq!(fc2.nm_violations, Some(0));
        // every other layer violates 2:4 (fully dense)
        assert!(stats.total_nm_violations() > 0);
    }
}
