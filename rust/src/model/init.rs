//! Parameter initialization (GPT-2 style): N(0, 0.02) for embeddings and
//! linears, residual-output projections (wo, w2) scaled by 1/sqrt(2L),
//! LayerNorm gains 1 / shifts 0.

use crate::model::config::ModelCfg;
use crate::model::layout::FlatParams;
use crate::util::prng::Rng;

pub const INIT_STD: f64 = 0.02;

pub fn init_params(cfg: &ModelCfg, seed: u64) -> FlatParams {
    let mut rng = Rng::new(seed);
    let mut fp = FlatParams::zeros(cfg);
    let resid_scale = 1.0 / (2.0 * cfg.layers as f64).sqrt();
    for e in cfg.param_layout.clone() {
        let std = match e.name.as_str() {
            "ln1_g" | "ln2_g" | "lnf_g" => {
                fill(&mut fp, &e.name, 1.0);
                continue;
            }
            "ln1_b" | "ln2_b" | "lnf_b" => {
                fill(&mut fp, &e.name, 0.0);
                continue;
            }
            "wo" | "w2" => INIT_STD * resid_scale,
            _ => INIT_STD,
        };
        let entry = fp.cfg.param_entry(&e.name).unwrap().clone();
        for x in &mut fp.data[entry.offset..entry.offset + entry.numel()] {
            *x = (rng.normal() * std) as f32;
        }
    }
    fp
}

fn fill(fp: &mut FlatParams, name: &str, v: f32) {
    let e = fp.cfg.param_entry(name).unwrap().clone();
    for x in &mut fp.data[e.offset..e.offset + e.numel()] {
        *x = v;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::layout::tests::tiny_cfg;

    #[test]
    fn init_statistics() {
        let cfg = tiny_cfg();
        let fp = init_params(&cfg, 0);
        // LN gains are 1, shifts 0
        assert!(fp.region("ln1_g").unwrap().iter().all(|&x| x == 1.0));
        assert!(fp.region("lnf_b").unwrap().iter().all(|&x| x == 0.0));
        // weights are small and not all equal
        let wq = fp.region("wq").unwrap();
        assert!(wq.iter().any(|&x| x != 0.0));
        assert!(wq.iter().all(|&x| x.abs() < 0.2));
    }

    #[test]
    fn deterministic_by_seed() {
        let cfg = tiny_cfg();
        assert_eq!(init_params(&cfg, 7).data, init_params(&cfg, 7).data);
        assert_ne!(init_params(&cfg, 7).data, init_params(&cfg, 8).data);
    }
}
