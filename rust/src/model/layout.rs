//! Flat-parameter vector access: named slices, per-block extraction and
//! write-back, and typed access to the prunable linear layers.
//!
//! Mirrors `python/compile/configs.py` exactly: parameters are stacked per
//! kind over layers (e.g. `wq` is one (L, d, d) region), and the
//! `block_fwd_<cfg>` artifact consumes a per-block flat slice in the order
//! ln1_g, ln1_b, wq, wk, wv, wo, ln2_g, ln2_b, w1, w2.

use anyhow::{anyhow, Result};

use crate::model::config::ModelCfg;
use crate::tensor::Tensor;

/// The six prunable linears of a transformer block and which Hessian
/// (capture) feeds each: q/k/v share `x_qkv`, `wo` uses `x_wo`, etc.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum LinearKind {
    Wq,
    Wk,
    Wv,
    Wo,
    Fc1,
    Fc2,
}

pub const PRUNABLE_KINDS: [LinearKind; 6] = [
    LinearKind::Wq,
    LinearKind::Wk,
    LinearKind::Wv,
    LinearKind::Wo,
    LinearKind::Fc1,
    LinearKind::Fc2,
];

impl LinearKind {
    pub fn param_name(&self) -> &'static str {
        match self {
            LinearKind::Wq => "wq",
            LinearKind::Wk => "wk",
            LinearKind::Wv => "wv",
            LinearKind::Wo => "wo",
            LinearKind::Fc1 => "w1",
            LinearKind::Fc2 => "w2",
        }
    }

    /// (d_row, d_col) of this linear.
    pub fn shape(&self, cfg: &ModelCfg) -> (usize, usize) {
        match self {
            LinearKind::Fc1 => (cfg.ffn, cfg.d),
            LinearKind::Fc2 => (cfg.d, cfg.ffn),
            _ => (cfg.d, cfg.d),
        }
    }

    /// Which block capture provides this linear's Hessian inputs.
    pub fn capture(&self) -> Capture {
        match self {
            LinearKind::Wq | LinearKind::Wk | LinearKind::Wv => Capture::Qkv,
            LinearKind::Wo => Capture::Wo,
            LinearKind::Fc1 => Capture::Fc1,
            LinearKind::Fc2 => Capture::Fc2,
        }
    }

    pub fn label(&self) -> &'static str {
        match self {
            LinearKind::Wq => "q",
            LinearKind::Wk => "k",
            LinearKind::Wv => "v",
            LinearKind::Wo => "out",
            LinearKind::Fc1 => "fc1",
            LinearKind::Fc2 => "fc2",
        }
    }

    /// Layer-type group used by the Fig-7 sensitivity experiment
    /// ("attention", "fully-connected-1", "fully-connected-2").
    pub fn layer_type(&self) -> &'static str {
        match self {
            LinearKind::Fc1 => "fc1",
            LinearKind::Fc2 => "fc2",
            _ => "attn",
        }
    }
}

/// Activation-capture slots emitted by `block_fwd` (input X of each linear).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Capture {
    Qkv,
    Wo,
    Fc1,
    Fc2,
}

impl Capture {
    pub const ALL: [Capture; 4] = [Capture::Qkv, Capture::Wo, Capture::Fc1, Capture::Fc2];

    /// Index of this capture in block_fwd's output tuple (after hidden_out).
    pub fn output_index(&self) -> usize {
        match self {
            Capture::Qkv => 1,
            Capture::Wo => 2,
            Capture::Fc1 => 3,
            Capture::Fc2 => 4,
        }
    }

    pub fn dim(&self, cfg: &ModelCfg) -> usize {
        match self {
            Capture::Fc2 => cfg.ffn,
            _ => cfg.d,
        }
    }
}

/// A model's flat parameter vector plus its layout.
#[derive(Clone, Debug)]
pub struct FlatParams {
    pub cfg: ModelCfg,
    pub data: Vec<f32>,
}

impl FlatParams {
    pub fn zeros(cfg: &ModelCfg) -> FlatParams {
        FlatParams { cfg: cfg.clone(), data: vec![0.0; cfg.n_params] }
    }

    pub fn new(cfg: &ModelCfg, data: Vec<f32>) -> Result<FlatParams> {
        if data.len() != cfg.n_params {
            return Err(anyhow!(
                "param vector has {} elements, config {} needs {}",
                data.len(),
                cfg.name,
                cfg.n_params
            ));
        }
        Ok(FlatParams { cfg: cfg.clone(), data })
    }

    /// Named region of the flat vector (all layers stacked).
    pub fn region(&self, name: &str) -> Result<&[f32]> {
        let e = self.cfg.param_entry(name).ok_or_else(|| anyhow!("no param {name:?}"))?;
        Ok(&self.data[e.offset..e.offset + e.numel()])
    }

    fn linear_range(&self, kind: LinearKind, layer: usize) -> Result<std::ops::Range<usize>> {
        let e = self
            .cfg
            .param_entry(kind.param_name())
            .ok_or_else(|| anyhow!("no param {:?}", kind.param_name()))?;
        let (r, c) = kind.shape(&self.cfg);
        let per_layer = r * c;
        if layer >= self.cfg.layers {
            return Err(anyhow!("layer {layer} out of range"));
        }
        let start = e.offset + layer * per_layer;
        Ok(start..start + per_layer)
    }

    /// Extract one prunable weight matrix as a (d_row, d_col) tensor.
    pub fn get_linear(&self, kind: LinearKind, layer: usize) -> Result<Tensor> {
        let range = self.linear_range(kind, layer)?;
        let (r, c) = kind.shape(&self.cfg);
        Ok(Tensor::new(vec![r, c], self.data[range].to_vec()))
    }

    /// Write a weight matrix back into the flat vector.
    pub fn set_linear(&mut self, kind: LinearKind, layer: usize, w: &Tensor) -> Result<()> {
        let range = self.linear_range(kind, layer)?;
        let (r, c) = kind.shape(&self.cfg);
        if w.shape() != [r, c] {
            return Err(anyhow!("shape mismatch: {:?} vs ({r},{c})", w.shape()));
        }
        self.data[range].copy_from_slice(w.data());
        Ok(())
    }

    /// Build block `layer`'s flat slice in the block_fwd artifact order.
    pub fn block_slice(&self, layer: usize) -> Result<Vec<f32>> {
        let mut out = Vec::with_capacity(self.cfg.block_size);
        for be in &self.cfg.block_layout {
            let pe = self
                .cfg
                .param_entry(&be.name)
                .ok_or_else(|| anyhow!("block param {:?} missing", be.name))?;
            let per_layer = be.numel();
            let start = pe.offset + layer * per_layer;
            out.extend_from_slice(&self.data[start..start + per_layer]);
        }
        debug_assert_eq!(out.len(), self.cfg.block_size);
        Ok(out)
    }

    /// Sparsity over the prunable linears only (the paper's reported number
    /// excludes embeddings and the head).
    pub fn prunable_sparsity(&self) -> f64 {
        let mut zeros = 0usize;
        let mut total = 0usize;
        for l in 0..self.cfg.layers {
            for kind in PRUNABLE_KINDS {
                let range = self.linear_range(kind, l).unwrap();
                let slice = &self.data[range];
                zeros += slice.iter().filter(|&&x| x == 0.0).count();
                total += slice.len();
            }
        }
        zeros as f64 / total.max(1) as f64
    }
}

#[cfg(test)]
pub(crate) mod tests {
    use super::*;
    use crate::model::config::LayoutEntry;

    pub fn tiny_cfg() -> ModelCfg {
        // d=2, L=2, ffn=4, vocab=3, seq=2 — hand-computed layout
        let d = 2usize;
        let l = 2usize;
        let f = 4usize;
        let v = 3usize;
        let s = 2usize;
        let entries: Vec<(&str, Vec<usize>)> = vec![
            ("tok_embed", vec![v, d]),
            ("pos_embed", vec![s, d]),
            ("ln1_g", vec![l, d]),
            ("ln1_b", vec![l, d]),
            ("wq", vec![l, d, d]),
            ("wk", vec![l, d, d]),
            ("wv", vec![l, d, d]),
            ("wo", vec![l, d, d]),
            ("ln2_g", vec![l, d]),
            ("ln2_b", vec![l, d]),
            ("w1", vec![l, f, d]),
            ("w2", vec![l, d, f]),
            ("lnf_g", vec![d]),
            ("lnf_b", vec![d]),
        ];
        let mut off = 0;
        let param_layout: Vec<LayoutEntry> = entries
            .iter()
            .map(|(n, sh)| {
                let e = LayoutEntry { name: n.to_string(), offset: off, shape: sh.clone() };
                off += e.numel();
                e
            })
            .collect();
        let n_params = off;
        let block_entries: Vec<(&str, Vec<usize>)> = vec![
            ("ln1_g", vec![d]),
            ("ln1_b", vec![d]),
            ("wq", vec![d, d]),
            ("wk", vec![d, d]),
            ("wv", vec![d, d]),
            ("wo", vec![d, d]),
            ("ln2_g", vec![d]),
            ("ln2_b", vec![d]),
            ("w1", vec![f, d]),
            ("w2", vec![d, f]),
        ];
        let mut boff = 0;
        let block_layout: Vec<LayoutEntry> = block_entries
            .iter()
            .map(|(n, sh)| {
                let e = LayoutEntry { name: n.to_string(), offset: boff, shape: sh.clone() };
                boff += e.numel();
                e
            })
            .collect();
        ModelCfg {
            name: "tiny".into(),
            d,
            layers: l,
            heads: 1,
            ffn: f,
            vocab: v,
            seq: s,
            n_params,
            block_size: boff,
            train_batch: 1,
            eval_batch: 1,
            param_layout,
            block_layout,
        }
    }

    #[test]
    fn linear_roundtrip() {
        let cfg = tiny_cfg();
        let mut fp = FlatParams::zeros(&cfg);
        let w = Tensor::new(vec![4, 2], (0..8).map(|x| x as f32).collect());
        fp.set_linear(LinearKind::Fc1, 1, &w).unwrap();
        assert_eq!(fp.get_linear(LinearKind::Fc1, 1).unwrap(), w);
        // layer 0 untouched
        assert!(fp.get_linear(LinearKind::Fc1, 0).unwrap().data().iter().all(|&x| x == 0.0));
    }

    #[test]
    fn block_slice_order_and_content() {
        let cfg = tiny_cfg();
        let mut fp = FlatParams::zeros(&cfg);
        // mark each region of layer 1 with a distinct value
        for (i, kind) in PRUNABLE_KINDS.iter().enumerate() {
            let (r, c) = kind.shape(&cfg);
            let w = Tensor::new(vec![r, c], vec![(i + 1) as f32; r * c]);
            fp.set_linear(*kind, 1, &w).unwrap();
        }
        let slice = fp.block_slice(1).unwrap();
        assert_eq!(slice.len(), cfg.block_size);
        // block layout: ln1_g(2) ln1_b(2) wq(4) wk(4) wv(4) wo(4) ln2_g(2) ln2_b(2) w1(8) w2(8)
        assert_eq!(&slice[4..8], &[1.0; 4]); // wq
        assert_eq!(&slice[16..20], &[4.0; 4]); // wo
        assert_eq!(&slice[24..32], &[5.0; 8]); // w1
        assert_eq!(&slice[32..40], &[6.0; 8]); // w2
    }

    #[test]
    fn prunable_sparsity_excludes_embeddings() {
        let cfg = tiny_cfg();
        let mut fp = FlatParams::zeros(&cfg);
        // all prunables zero -> sparsity 1.0 regardless of embeddings
        for x in fp.data.iter_mut().take(10) {
            *x = 1.0; // embeddings nonzero
        }
        assert_eq!(fp.prunable_sparsity(), 1.0);
    }

    #[test]
    fn wrong_size_rejected() {
        let cfg = tiny_cfg();
        assert!(FlatParams::new(&cfg, vec![0.0; 3]).is_err());
        let fp = FlatParams::zeros(&cfg);
        assert!(fp.get_linear(LinearKind::Wq, 5).is_err());
    }
}
