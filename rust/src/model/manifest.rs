//! The AOT manifest: the single source of truth connecting the Python
//! compile path to the Rust runtime (artifact files, IO shapes, model
//! layouts, solver constants).

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, Context, Result};

use crate::model::config::ModelCfg;
use crate::util::json::Json;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DType {
    F32,
    I32,
}

impl DType {
    fn parse(s: &str) -> Result<DType> {
        match s {
            "float32" => Ok(DType::F32),
            "int32" => Ok(DType::I32),
            _ => bail!("unsupported dtype {s:?}"),
        }
    }
}

#[derive(Clone, Debug)]
pub struct IoSpec {
    pub dtype: DType,
    pub shape: Vec<usize>,
}

impl IoSpec {
    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }
}

#[derive(Clone, Debug)]
pub struct ArtifactSpec {
    pub name: String,
    pub file: PathBuf,
    pub inputs: Vec<IoSpec>,
    pub outputs: Vec<IoSpec>,
}

#[derive(Clone, Debug)]
pub struct Manifest {
    pub dir: PathBuf,
    pub seq: usize,
    pub vocab: usize,
    pub chunk_tokens: usize,
    pub blocksize: usize,
    pub configs: BTreeMap<String, ModelCfg>,
    pub artifacts: BTreeMap<String, ArtifactSpec>,
}

fn parse_iospec(v: &Json) -> Result<IoSpec> {
    let e = v.as_arr()?;
    Ok(IoSpec {
        dtype: DType::parse(e[0].as_str()?)?,
        shape: e[1].as_arr()?.iter().map(|s| s.as_usize()).collect::<Result<_>>()?,
    })
}

impl Manifest {
    pub fn load(dir: impl AsRef<Path>) -> Result<Manifest> {
        let dir = dir.as_ref().to_path_buf();
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {path:?} — run `make artifacts` first"))?;
        let v = Json::parse(&text).with_context(|| format!("parsing {path:?}"))?;

        let mut configs = BTreeMap::new();
        for (name, cv) in v.get("configs")?.as_obj()? {
            configs.insert(name.clone(), ModelCfg::from_json(name, cv)?);
        }
        let mut artifacts = BTreeMap::new();
        for (name, av) in v.get("artifacts")?.as_obj()? {
            artifacts.insert(
                name.clone(),
                ArtifactSpec {
                    name: name.clone(),
                    file: dir.join(av.get("file")?.as_str()?),
                    inputs: av.get("inputs")?.as_arr()?.iter().map(parse_iospec).collect::<Result<_>>()?,
                    outputs: av.get("outputs")?.as_arr()?.iter().map(parse_iospec).collect::<Result<_>>()?,
                },
            );
        }
        Ok(Manifest {
            dir,
            seq: v.get("seq")?.as_usize()?,
            vocab: v.get("vocab")?.as_usize()?,
            chunk_tokens: v.get("chunk_tokens")?.as_usize()?,
            blocksize: v.get("blocksize")?.as_usize()?,
            configs,
            artifacts,
        })
    }

    pub fn config(&self, name: &str) -> Result<&ModelCfg> {
        self.configs
            .get(name)
            .ok_or_else(|| anyhow!("config {name:?} not in manifest (have {:?})", self.configs.keys().collect::<Vec<_>>()))
    }

    pub fn artifact(&self, name: &str) -> Result<&ArtifactSpec> {
        self.artifacts
            .get(name)
            .ok_or_else(|| anyhow!("artifact {name:?} not in manifest — re-run `make artifacts`"))
    }

    /// Default artifacts directory: `$SPARSEGPT_ARTIFACTS` or `./artifacts`.
    pub fn default_dir() -> PathBuf {
        std::env::var_os("SPARSEGPT_ARTIFACTS")
            .map(PathBuf::from)
            .unwrap_or_else(|| PathBuf::from("artifacts"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn loads_real_manifest_if_present() {
        let dir = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts");
        if !std::path::Path::new(dir).join("manifest.json").exists() {
            return; // artifacts not built in this environment
        }
        let m = Manifest::load(dir).unwrap();
        assert_eq!(m.seq, 128);
        assert_eq!(m.vocab, 512);
        let nano = m.config("nano").unwrap();
        assert_eq!(nano.d, 64);
        // flat layout must be contiguous and cover n_params
        let mut off = 0;
        for e in &nano.param_layout {
            assert_eq!(e.offset, off, "{}", e.name);
            off += e.numel();
        }
        assert_eq!(off, nano.n_params);
        let a = m.artifact("sparsegpt_64x64").unwrap();
        assert_eq!(a.inputs.len(), 4);
        assert_eq!(a.outputs.len(), 2);
        assert!(a.file.exists());
    }

    #[test]
    fn missing_artifact_errors() {
        let dir = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts");
        if !std::path::Path::new(dir).join("manifest.json").exists() {
            return;
        }
        let m = Manifest::load(dir).unwrap();
        assert!(m.artifact("nope").is_err());
        assert!(m.config("nope").is_err());
    }
}
