//! Model configuration, parsed from the AOT manifest so the Rust side can
//! never drift from the Python layout definition.

use anyhow::Result;

use crate::util::json::Json;

#[derive(Clone, Debug)]
pub struct LayoutEntry {
    pub name: String,
    pub offset: usize,
    pub shape: Vec<usize>,
}

impl LayoutEntry {
    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }
}

#[derive(Clone, Debug)]
pub struct ModelCfg {
    pub name: String,
    pub d: usize,
    pub layers: usize,
    pub heads: usize,
    pub ffn: usize,
    pub vocab: usize,
    pub seq: usize,
    pub n_params: usize,
    pub block_size: usize,
    pub train_batch: usize,
    pub eval_batch: usize,
    pub param_layout: Vec<LayoutEntry>,
    pub block_layout: Vec<LayoutEntry>,
}

fn parse_layout(v: &Json) -> Result<Vec<LayoutEntry>> {
    let mut out = Vec::new();
    for e in v.as_arr()? {
        let e = e.as_arr()?;
        out.push(LayoutEntry {
            name: e[0].as_str()?.to_string(),
            offset: e[1].as_usize()?,
            shape: e[2].as_arr()?.iter().map(|s| s.as_usize()).collect::<Result<_>>()?,
        });
    }
    Ok(out)
}

impl ModelCfg {
    pub fn from_json(name: &str, v: &Json) -> Result<ModelCfg> {
        Ok(ModelCfg {
            name: name.to_string(),
            d: v.get("d")?.as_usize()?,
            layers: v.get("layers")?.as_usize()?,
            heads: v.get("heads")?.as_usize()?,
            ffn: v.get("ffn")?.as_usize()?,
            vocab: v.get("vocab")?.as_usize()?,
            seq: v.get("seq")?.as_usize()?,
            n_params: v.get("n_params")?.as_usize()?,
            block_size: v.get("block_size")?.as_usize()?,
            train_batch: v.get("train_batch")?.as_usize()?,
            eval_batch: v.get("eval_batch")?.as_usize()?,
            param_layout: parse_layout(v.get("param_layout")?)?,
            block_layout: parse_layout(v.get("block_layout")?)?,
        })
    }

    pub fn param_entry(&self, name: &str) -> Option<&LayoutEntry> {
        self.param_layout.iter().find(|e| e.name == name)
    }

    pub fn block_entry(&self, name: &str) -> Option<&LayoutEntry> {
        self.block_layout.iter().find(|e| e.name == name)
    }

    /// Distinct prunable (d_row, d_col) shapes: q/k/v/o, fc1, fc2.
    pub fn prune_shapes(&self) -> Vec<(usize, usize)> {
        vec![(self.d, self.d), (self.ffn, self.d), (self.d, self.ffn)]
    }

    /// Total prunable weights (all linear layers, excluding embeddings/head).
    pub fn prunable_params(&self) -> usize {
        self.layers * (4 * self.d * self.d + 2 * self.d * self.ffn)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    pub(crate) fn test_cfg_json() -> Json {
        // a hand-written manifest entry for d=4, L=1, heads=2, ffn=16, V=8, S=4
        Json::parse(
            r#"{
          "d": 4, "layers": 1, "heads": 2, "ffn": 16, "vocab": 8, "seq": 4,
          "n_params": 256, "block_size": 200, "train_batch": 2, "eval_batch": 2,
          "param_layout": [["tok_embed", 0, [8, 4]], ["pos_embed", 32, [4, 4]]],
          "block_layout": [["ln1_g", 0, [4]], ["wq", 8, [4, 4]]]
        }"#,
        )
        .unwrap()
    }

    #[test]
    fn parses_config() {
        let cfg = ModelCfg::from_json("t", &test_cfg_json()).unwrap();
        assert_eq!(cfg.d, 4);
        assert_eq!(cfg.param_entry("pos_embed").unwrap().offset, 32);
        assert_eq!(cfg.block_entry("wq").unwrap().shape, vec![4, 4]);
        assert_eq!(cfg.prune_shapes(), vec![(4, 4), (16, 4), (4, 16)]);
        assert_eq!(cfg.prunable_params(), 4 * 16 + 2 * 64);
    }
}
