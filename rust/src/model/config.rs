//! Model configuration: parsed from the AOT manifest (so the Rust side can
//! never drift from the Python layout definition), or constructed directly
//! from dimensions ([`ModelCfg::from_dims`] / [`ModelCfg::builtin`]) for
//! backends that derive shapes without a compiled manifest — both mirror
//! `python/compile/configs.py` entry-for-entry.

use anyhow::Result;

use crate::util::json::Json;

/// Family-wide constants, identical to `python/compile/configs.py`.
pub const BUILTIN_VOCAB: usize = 512;
pub const BUILTIN_SEQ: usize = 128;
/// Lazy-update / mask-selection blocksize of the production solver.
pub const BUILTIN_BLOCKSIZE: usize = 128;

#[derive(Clone, Debug)]
pub struct LayoutEntry {
    pub name: String,
    pub offset: usize,
    pub shape: Vec<usize>,
}

impl LayoutEntry {
    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }
}

#[derive(Clone, Debug)]
pub struct ModelCfg {
    pub name: String,
    pub d: usize,
    pub layers: usize,
    pub heads: usize,
    pub ffn: usize,
    pub vocab: usize,
    pub seq: usize,
    pub n_params: usize,
    pub block_size: usize,
    pub train_batch: usize,
    pub eval_batch: usize,
    pub param_layout: Vec<LayoutEntry>,
    pub block_layout: Vec<LayoutEntry>,
}

fn parse_layout(v: &Json) -> Result<Vec<LayoutEntry>> {
    let mut out = Vec::new();
    for e in v.as_arr()? {
        let e = e.as_arr()?;
        out.push(LayoutEntry {
            name: e[0].as_str()?.to_string(),
            offset: e[1].as_usize()?,
            shape: e[2].as_arr()?.iter().map(|s| s.as_usize()).collect::<Result<_>>()?,
        });
    }
    Ok(out)
}

impl ModelCfg {
    pub fn from_json(name: &str, v: &Json) -> Result<ModelCfg> {
        Ok(ModelCfg {
            name: name.to_string(),
            d: v.get("d")?.as_usize()?,
            layers: v.get("layers")?.as_usize()?,
            heads: v.get("heads")?.as_usize()?,
            ffn: v.get("ffn")?.as_usize()?,
            vocab: v.get("vocab")?.as_usize()?,
            seq: v.get("seq")?.as_usize()?,
            n_params: v.get("n_params")?.as_usize()?,
            block_size: v.get("block_size")?.as_usize()?,
            train_batch: v.get("train_batch")?.as_usize()?,
            eval_batch: v.get("eval_batch")?.as_usize()?,
            param_layout: parse_layout(v.get("param_layout")?)?,
            block_layout: parse_layout(v.get("block_layout")?)?,
        })
    }

    /// Build a config purely from dimensions, mirroring the flat layout of
    /// `python/compile/configs.py` entry-for-entry (same names, same order,
    /// same shapes) — the manifest-free path used by the reference backend
    /// and by tests that need custom-sized models.
    pub fn from_dims(
        name: &str,
        d: usize,
        layers: usize,
        heads: usize,
        train_batch: usize,
        eval_batch: usize,
        vocab: usize,
        seq: usize,
    ) -> ModelCfg {
        assert!(heads > 0 && d % heads == 0, "heads must divide d");
        let ffn = 4 * d;
        let entries: Vec<(&str, Vec<usize>)> = vec![
            ("tok_embed", vec![vocab, d]),
            ("pos_embed", vec![seq, d]),
            ("ln1_g", vec![layers, d]),
            ("ln1_b", vec![layers, d]),
            ("wq", vec![layers, d, d]),
            ("wk", vec![layers, d, d]),
            ("wv", vec![layers, d, d]),
            ("wo", vec![layers, d, d]),
            ("ln2_g", vec![layers, d]),
            ("ln2_b", vec![layers, d]),
            ("w1", vec![layers, ffn, d]),
            ("w2", vec![layers, d, ffn]),
            ("lnf_g", vec![d]),
            ("lnf_b", vec![d]),
        ];
        let mut off = 0;
        let param_layout: Vec<LayoutEntry> = entries
            .iter()
            .map(|(n, sh)| {
                let e = LayoutEntry { name: n.to_string(), offset: off, shape: sh.clone() };
                off += e.numel();
                e
            })
            .collect();
        let n_params = off;
        let block_entries: Vec<(&str, Vec<usize>)> = vec![
            ("ln1_g", vec![d]),
            ("ln1_b", vec![d]),
            ("wq", vec![d, d]),
            ("wk", vec![d, d]),
            ("wv", vec![d, d]),
            ("wo", vec![d, d]),
            ("ln2_g", vec![d]),
            ("ln2_b", vec![d]),
            ("w1", vec![ffn, d]),
            ("w2", vec![d, ffn]),
        ];
        let mut boff = 0;
        let block_layout: Vec<LayoutEntry> = block_entries
            .iter()
            .map(|(n, sh)| {
                let e = LayoutEntry { name: n.to_string(), offset: boff, shape: sh.clone() };
                boff += e.numel();
                e
            })
            .collect();
        ModelCfg {
            name: name.to_string(),
            d,
            layers,
            heads,
            ffn,
            vocab,
            seq,
            n_params,
            block_size: boff,
            train_batch,
            eval_batch,
            param_layout,
            block_layout,
        }
    }

    /// The built-in model family (the `CONFIGS` table of
    /// `python/compile/configs.py`): nano/micro/small/medium/large.
    pub fn builtin(name: &str) -> Option<ModelCfg> {
        let (d, layers, heads, train_batch) = match name {
            "nano" => (64, 2, 2, 32),
            "micro" => (128, 4, 4, 16),
            "small" => (256, 6, 8, 8),
            "medium" => (512, 8, 8, 4),
            "large" => (768, 12, 12, 2),
            _ => return None,
        };
        Some(ModelCfg::from_dims(
            name,
            d,
            layers,
            heads,
            train_batch,
            8,
            BUILTIN_VOCAB,
            BUILTIN_SEQ,
        ))
    }

    pub fn builtin_names() -> [&'static str; 5] {
        ["nano", "micro", "small", "medium", "large"]
    }

    pub fn param_entry(&self, name: &str) -> Option<&LayoutEntry> {
        self.param_layout.iter().find(|e| e.name == name)
    }

    pub fn block_entry(&self, name: &str) -> Option<&LayoutEntry> {
        self.block_layout.iter().find(|e| e.name == name)
    }

    /// Distinct prunable (d_row, d_col) shapes: q/k/v/o, fc1, fc2.
    pub fn prune_shapes(&self) -> Vec<(usize, usize)> {
        vec![(self.d, self.d), (self.ffn, self.d), (self.d, self.ffn)]
    }

    /// Total prunable weights (all linear layers, excluding embeddings/head).
    pub fn prunable_params(&self) -> usize {
        self.layers * (4 * self.d * self.d + 2 * self.d * self.ffn)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    pub(crate) fn test_cfg_json() -> Json {
        // a hand-written manifest entry for d=4, L=1, heads=2, ffn=16, V=8, S=4
        Json::parse(
            r#"{
          "d": 4, "layers": 1, "heads": 2, "ffn": 16, "vocab": 8, "seq": 4,
          "n_params": 256, "block_size": 200, "train_batch": 2, "eval_batch": 2,
          "param_layout": [["tok_embed", 0, [8, 4]], ["pos_embed", 32, [4, 4]]],
          "block_layout": [["ln1_g", 0, [4]], ["wq", 8, [4, 4]]]
        }"#,
        )
        .unwrap()
    }

    #[test]
    fn builtin_layouts_are_contiguous_and_complete() {
        for name in ModelCfg::builtin_names() {
            let cfg = ModelCfg::builtin(name).unwrap();
            assert_eq!(cfg.name, name);
            assert_eq!(cfg.ffn, 4 * cfg.d);
            assert_eq!(cfg.d % cfg.heads, 0);
            let mut off = 0;
            for e in &cfg.param_layout {
                assert_eq!(e.offset, off, "{name}/{}", e.name);
                off += e.numel();
            }
            assert_eq!(off, cfg.n_params, "{name}");
            let mut boff = 0;
            for e in &cfg.block_layout {
                assert_eq!(e.offset, boff, "{name}/{}", e.name);
                boff += e.numel();
            }
            assert_eq!(boff, cfg.block_size, "{name}");
            assert_eq!(cfg.vocab, BUILTIN_VOCAB);
            assert_eq!(cfg.seq, BUILTIN_SEQ);
        }
        assert!(ModelCfg::builtin("giant").is_none());
    }

    #[test]
    fn builtin_nano_matches_hand_computed_sizes() {
        // independently summed from the configs.py layout: any drift here
        // breaks checkpoint compatibility between the two backends
        let nano = ModelCfg::builtin("nano").unwrap();
        assert_eq!(nano.d, 64);
        assert_eq!(nano.layers, 2);
        assert_eq!(nano.heads, 2);
        assert_eq!(nano.n_params, 139_904);
        assert_eq!(nano.block_size, 49_408);
        assert_eq!(nano.prunable_params(), 98_304);
        assert_eq!(nano.param_entry("pos_embed").unwrap().offset, 512 * 64);
        assert_eq!(nano.block_entry("w1").unwrap().shape, vec![256, 64]);
    }

    #[test]
    fn builtin_matches_manifest_when_artifacts_present() {
        let dir = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts");
        if !std::path::Path::new(dir).join("manifest.json").exists() {
            return;
        }
        let m = crate::model::manifest::Manifest::load(dir).unwrap();
        for (name, mc) in &m.configs {
            let bc = ModelCfg::builtin(name).expect("manifest config not in builtin family");
            assert_eq!(bc.n_params, mc.n_params, "{name}");
            assert_eq!(bc.block_size, mc.block_size, "{name}");
            // heads/batches don't shape the flat layout but do shape the
            // reference backend's attention and batching — pin them too
            assert_eq!(bc.heads, mc.heads, "{name}");
            assert_eq!(bc.train_batch, mc.train_batch, "{name}");
            assert_eq!(bc.eval_batch, mc.eval_batch, "{name}");
            for (a, b) in bc.param_layout.iter().zip(&mc.param_layout) {
                assert_eq!(a.name, b.name, "{name}");
                assert_eq!(a.offset, b.offset, "{name}/{}", a.name);
                assert_eq!(a.shape, b.shape, "{name}/{}", a.name);
            }
        }
    }

    #[test]
    fn parses_config() {
        let cfg = ModelCfg::from_json("t", &test_cfg_json()).unwrap();
        assert_eq!(cfg.d, 4);
        assert_eq!(cfg.param_entry("pos_embed").unwrap().offset, 32);
        assert_eq!(cfg.block_entry("wq").unwrap().shape, vec![4, 4]);
        assert_eq!(cfg.prune_shapes(), vec![(4, 4), (16, 4), (4, 16)]);
        assert_eq!(cfg.prunable_params(), 4 * 16 + 2 * 64);
    }
}
