//! Model substrate: configs (mirroring `python/compile/configs.py` via the
//! AOT manifest), the flat-parameter layout, initialization, checkpoint IO
//! and sparsity statistics.

pub mod checkpoint;
pub mod config;
pub mod init;
pub mod layout;
pub mod manifest;
pub mod sparse_store;
pub mod stats;

pub use config::ModelCfg;
pub use layout::{FlatParams, LinearKind, PRUNABLE_KINDS};
pub use manifest::Manifest;
pub use sparse_store::{SparseStore, StoreEntry};
