//! Packed sparse checkpoint IO (`.spkt`): a pruned model serialized in the
//! formats the serving engine executes — each prunable linear as CSR,
//! bitmask-packed n:m, dense, or a quantized variant (`qcsr` / `qnm` /
//! `qdense`, u8-coded values behind the same streams — see
//! [`crate::sparse::pack`]), plus the non-prunable remainder (embeddings,
//! layer norms) stored raw.
//!
//! Layout (little-endian, mmap-friendly: fixed header, then a table of
//! contents with absolute byte offsets into 8-byte-aligned sections, so a
//! reader can map the file and slice sections without a parse pass):
//!
//! ```text
//! magic    b"SGPTSPKT"                    8 bytes
//! version  u32                            (2; v1 files still load)
//! flags    u32                            (reserved, 0)
//! name_len u32 + utf8 config name
//! src_len  u32 + utf8 source label        (the prune spec that produced it)
//! n_params u64, layers u32, entries u32   (entries = layers * 6)
//! rest_off u64, rest_len u64              (f32 count of the dense remainder)
//! toc      entries * { layer u32, kind u8, format u8, pad u16,
//!                      offset u64, byte_len u64,
//!                      rows u32, cols u32, nnz u64,
//!                      bits u8, pad u8, group u16,     -- v2 only
//!                      effective_bits f32 }            -- v2 only
//! rest     f32 * rest_len                 (non-prunable regions, layout order)
//! sections one PackedMatrix byte-encoding per entry, 8-byte aligned
//! ```
//!
//! The v2 TOC appends 8 bytes of quantization metadata per entry (entries
//! are 48 bytes, still 8-aligned): the code width (`bits`, 0 for f32
//! formats), the grid group size (`group`, 0 = per-row), and the matrix's
//! effective storage bits/weight under the paper's Fig.-6 accounting —
//! readable without touching the sections. v1 files (40-byte entries, f32
//! formats only) load unchanged; the writer always emits v2.

use std::collections::BTreeMap;
use std::io::Write;
use std::path::Path;
use std::sync::Arc;

use anyhow::{anyhow, bail, Context, Result};

use crate::model::config::ModelCfg;
use crate::model::layout::{FlatParams, LinearKind, PRUNABLE_KINDS};
use crate::sparse::{PackPolicy, PackedMatrix};
use crate::util::mmap::{ByteSource, MmapRegion};

const MAGIC: &[u8; 8] = b"SGPTSPKT";
const VERSION: u32 = 2;
const VERSION_V1: u32 = 1;
/// TOC entry bytes: v1, and v2's appended quant metadata.
const TOC_ENTRY_V1: usize = 4 + 1 + 1 + 2 + 8 + 8 + 4 + 4 + 8;
const TOC_ENTRY_V2: usize = TOC_ENTRY_V1 + 1 + 1 + 2 + 4;
/// serialized [`LinearKind`] order (stable across versions)
const KIND_TAGS: [LinearKind; 6] = PRUNABLE_KINDS;

/// The TOC format byte (mirrors the section tag of the same matrix).
fn format_tag(m: &PackedMatrix) -> u8 {
    match m {
        PackedMatrix::Dense(_) => 0,
        PackedMatrix::Csr(c) if c.perm.is_some() => 6, // row-permuted layout
        PackedMatrix::Csr(_) => 1,
        PackedMatrix::Nm(_) => 2,
        PackedMatrix::QDense(_) => 3,
        PackedMatrix::QCsr(_) => 4,
        PackedMatrix::QNm(_) => 5,
    }
}

fn kind_tag(kind: LinearKind) -> u8 {
    KIND_TAGS.iter().position(|k| *k == kind).unwrap() as u8
}

fn kind_from_tag(tag: u8) -> Result<LinearKind> {
    KIND_TAGS
        .get(tag as usize)
        .copied()
        .ok_or_else(|| anyhow!("unknown linear-kind tag {tag}"))
}

/// Is this named region one of the packed prunable linears?
fn is_prunable_region(name: &str) -> bool {
    PRUNABLE_KINDS.iter().any(|k| k.param_name() == name)
}

/// One packed prunable linear.
#[derive(Clone, Debug)]
pub struct StoreEntry {
    pub layer: usize,
    pub kind: LinearKind,
    pub matrix: PackedMatrix,
}

/// A packed sparse checkpoint: what `.spkt` files hold in memory.
#[derive(Clone, Debug)]
pub struct SparseStore {
    pub config_name: String,
    /// prune-spec label of the job that produced the params
    pub source_label: String,
    pub n_params: usize,
    pub layers: usize,
    /// non-prunable regions (embeddings, norms) concatenated in
    /// `param_layout` order
    pub rest: Vec<f32>,
    /// layer-major, [`PRUNABLE_KINDS`]-ordered packed linears
    pub entries: Vec<StoreEntry>,
}

impl SparseStore {
    /// Conventional path: `<dir>/<config><suffix>.spkt`.
    pub fn path_for(dir: impl AsRef<Path>, config: &str, suffix: &str) -> std::path::PathBuf {
        dir.as_ref().join(format!("{config}{suffix}.spkt"))
    }

    /// Pack pruned parameters: every prunable linear through `policy`, the
    /// remainder raw.
    pub fn pack(
        params: &FlatParams,
        policy: &PackPolicy,
        source_label: &str,
    ) -> Result<SparseStore> {
        let cfg = &params.cfg;
        let mut rest = Vec::new();
        for e in &cfg.param_layout {
            if !is_prunable_region(&e.name) {
                rest.extend_from_slice(params.region(&e.name)?);
            }
        }
        let mut entries = Vec::with_capacity(cfg.layers * PRUNABLE_KINDS.len());
        for layer in 0..cfg.layers {
            for kind in PRUNABLE_KINDS {
                let w = params.get_linear(kind, layer)?;
                let matrix = PackedMatrix::pack(&w, policy).with_context(|| {
                    format!("packing layer {layer} {}", kind.label())
                })?;
                entries.push(StoreEntry { layer, kind, matrix });
            }
        }
        Ok(SparseStore {
            config_name: cfg.name.clone(),
            source_label: source_label.to_string(),
            n_params: cfg.n_params,
            layers: cfg.layers,
            rest,
            entries,
        })
    }

    /// Rebuild the flat parameter vector (bit-exact inverse of [`pack`]
    /// over the kernels' value grid: f32 formats reproduce the pruned
    /// weights exactly; quantized formats reproduce the dequantized
    /// weights the kernels execute).
    ///
    /// [`pack`]: SparseStore::pack
    pub fn unpack(&self, cfg: &ModelCfg) -> Result<FlatParams> {
        if cfg.name != self.config_name {
            bail!(
                "packed checkpoint is for config {:?}, expected {:?}",
                self.config_name,
                cfg.name
            );
        }
        if cfg.n_params != self.n_params || cfg.layers != self.layers {
            bail!(
                "packed checkpoint shape mismatch: {} params / {} layers vs config {} / {}",
                self.n_params,
                self.layers,
                cfg.n_params,
                cfg.layers
            );
        }
        let mut fp = FlatParams::zeros(cfg);
        let mut off = 0usize;
        for e in &cfg.param_layout {
            if is_prunable_region(&e.name) {
                continue;
            }
            let n = e.numel();
            if off + n > self.rest.len() {
                bail!("packed checkpoint remainder too short for region {:?}", e.name);
            }
            fp.data[e.offset..e.offset + n].copy_from_slice(&self.rest[off..off + n]);
            off += n;
        }
        if off != self.rest.len() {
            bail!("packed checkpoint remainder has {} trailing f32s", self.rest.len() - off);
        }
        for entry in &self.entries {
            fp.set_linear(entry.kind, entry.layer, &entry.matrix.to_dense())?;
        }
        Ok(fp)
    }

    /// Density over the packed (prunable) weights.
    pub fn density(&self) -> f64 {
        let mut nnz = 0usize;
        let mut total = 0usize;
        for e in &self.entries {
            nnz += e.matrix.nnz();
            total += e.matrix.rows() * e.matrix.cols();
        }
        nnz as f64 / total.max(1) as f64
    }

    /// format label -> matrix count, e.g. {"csr": 10, "dense": 2}.
    pub fn format_counts(&self) -> BTreeMap<&'static str, usize> {
        let mut out = BTreeMap::new();
        for e in &self.entries {
            *out.entry(e.matrix.format_label()).or_insert(0) += 1;
        }
        out
    }

    /// Compact "csr:10 dense:2" summary for logs/events.
    pub fn format_summary(&self) -> String {
        self.format_counts()
            .iter()
            .map(|(k, v)| format!("{k}:{v}"))
            .collect::<Vec<_>>()
            .join(" ")
    }

    /// Size-weighted average storage bits per packed weight (the paper's
    /// Fig.-6 accounting — see [`PackedMatrix::effective_bits`]).
    pub fn effective_bits(&self) -> f64 {
        let mut bits = 0.0f64;
        let mut total = 0.0f64;
        for e in &self.entries {
            let numel = (e.matrix.rows() * e.matrix.cols()) as f64;
            bits += e.matrix.effective_bits() * numel;
            total += numel;
        }
        if total > 0.0 {
            bits / total
        } else {
            32.0
        }
    }

    /// Weight-section bytes currently served from mapped pages (0 for
    /// packed-in-memory or owned-loaded stores).
    pub fn mapped_bytes(&self) -> u64 {
        self.entries.iter().map(|e| e.matrix.mapped_bytes()).sum()
    }

    /// Total packed weight-stream bytes, however backed.
    pub fn payload_bytes(&self) -> u64 {
        self.entries.iter().map(|e| e.matrix.payload_bytes()).sum()
    }

    /// Serialize to `path`; returns the byte size written.
    pub fn save(&self, path: impl AsRef<Path>) -> Result<u64> {
        let path = path.as_ref();
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        // encode sections first so the TOC can carry absolute offsets
        let name = self.config_name.as_bytes();
        let src = self.source_label.as_bytes();
        let header_len = 8 + 4 + 4 + (4 + name.len()) + (4 + src.len()) + 8 + 4 + 4 + 8 + 8;
        let toc_off = align8(header_len);
        let rest_off = align8(toc_off + self.entries.len() * TOC_ENTRY_V2);
        let mut sections: Vec<Vec<u8>> = Vec::with_capacity(self.entries.len());
        let mut offsets: Vec<(u64, u64)> = Vec::with_capacity(self.entries.len());
        let mut cursor = align8(rest_off + self.rest.len() * 4);
        for e in &self.entries {
            let mut buf = Vec::new();
            e.matrix.write_bytes(&mut buf);
            offsets.push((cursor as u64, buf.len() as u64));
            cursor = align8(cursor + buf.len());
            sections.push(buf);
        }
        let _total_bytes = cursor; // final cursor = aligned end of file

        fn put(f: &mut impl Write, w: &mut usize, b: &[u8]) -> Result<()> {
            f.write_all(b)?;
            *w += b.len();
            Ok(())
        }
        fn pad_to(f: &mut impl Write, w: &mut usize, target: usize) -> Result<()> {
            while *w < target {
                f.write_all(&[0u8])?;
                *w += 1;
            }
            Ok(())
        }
        let tmp = path.with_extension("tmp");
        {
            let mut f = std::io::BufWriter::new(std::fs::File::create(&tmp)?);
            let mut written = 0usize;
            put(&mut f, &mut written, MAGIC)?;
            put(&mut f, &mut written, &VERSION.to_le_bytes())?;
            put(&mut f, &mut written, &0u32.to_le_bytes())?;
            put(&mut f, &mut written, &u32_len(name.len(), "config name")?.to_le_bytes())?;
            put(&mut f, &mut written, name)?;
            put(&mut f, &mut written, &u32_len(src.len(), "source label")?.to_le_bytes())?;
            put(&mut f, &mut written, src)?;
            put(&mut f, &mut written, &(self.n_params as u64).to_le_bytes())?;
            put(&mut f, &mut written, &u32_len(self.layers, "layer count")?.to_le_bytes())?;
            put(
                &mut f,
                &mut written,
                &u32_len(self.entries.len(), "entry count")?.to_le_bytes(),
            )?;
            put(&mut f, &mut written, &(rest_off as u64).to_le_bytes())?;
            put(&mut f, &mut written, &(self.rest.len() as u64).to_le_bytes())?;
            debug_assert_eq!(written, header_len);
            pad_to(&mut f, &mut written, toc_off)?;
            for (e, (off, len)) in self.entries.iter().zip(&offsets) {
                put(&mut f, &mut written, &(e.layer as u32).to_le_bytes())?;
                put(&mut f, &mut written, &[kind_tag(e.kind)])?;
                put(&mut f, &mut written, &[format_tag(&e.matrix)])?;
                put(&mut f, &mut written, &0u16.to_le_bytes())?;
                put(&mut f, &mut written, &off.to_le_bytes())?;
                put(&mut f, &mut written, &len.to_le_bytes())?;
                put(&mut f, &mut written, &(e.matrix.rows() as u32).to_le_bytes())?;
                put(&mut f, &mut written, &(e.matrix.cols() as u32).to_le_bytes())?;
                put(&mut f, &mut written, &(e.matrix.nnz() as u64).to_le_bytes())?;
                // v2: quant metadata + effective bits, readable from the
                // TOC alone (section-aligned like every other field)
                let (bits, group) = e.matrix.quant_meta().unwrap_or((0, 0));
                put(&mut f, &mut written, &[bits, 0u8])?;
                put(&mut f, &mut written, &group.to_le_bytes())?;
                put(&mut f, &mut written, &(e.matrix.effective_bits() as f32).to_le_bytes())?;
            }
            pad_to(&mut f, &mut written, rest_off)?;
            for v in &self.rest {
                put(&mut f, &mut written, &v.to_le_bytes())?;
            }
            for (buf, (off, _)) in sections.iter().zip(&offsets) {
                pad_to(&mut f, &mut written, *off as usize)?;
                put(&mut f, &mut written, buf)?;
            }
            f.flush()?;
        }
        let bytes = std::fs::metadata(&tmp)?.len();
        std::fs::rename(&tmp, path)?;
        Ok(bytes)
    }

    /// Zero-copy load: map the file ([`MmapRegion`]; owned aligned copy
    /// where mapping is unavailable) and hand the kernels validated views
    /// into the weight sections instead of copying them.
    pub fn load(path: impl AsRef<Path>) -> Result<SparseStore> {
        let path = path.as_ref();
        let region = Arc::new(
            MmapRegion::load(path)
                .with_context(|| format!("opening packed checkpoint {path:?}"))?,
        );
        Self::load_region(&region, path, true)
    }

    /// Copying load: every stream decoded into owned buffers. The
    /// differential reference for the zero-copy path (`tests/mmap_parity`)
    /// — and the escape hatch if a mapped file must not be held open.
    pub fn load_owned(path: impl AsRef<Path>) -> Result<SparseStore> {
        let path = path.as_ref();
        let buf = std::fs::read(path)
            .with_context(|| format!("opening packed checkpoint {path:?}"))?;
        let region = Arc::new(MmapRegion::from_bytes(&buf));
        Self::load_region(&region, path, false)
    }

    fn load_region(region: &Arc<MmapRegion>, path: &Path, zero_copy: bool) -> Result<SparseStore> {
        fn take<'a>(buf: &'a [u8], i: &mut usize, n: usize) -> Result<&'a [u8]> {
            // checked: `n` comes from unvalidated header fields, so `i + n`
            // must not wrap around usize
            let end = i.checked_add(n).filter(|&e| e <= buf.len());
            let Some(end) = end else {
                bail!("packed checkpoint truncated at byte {i}");
            };
            let out = &buf[*i..end];
            *i = end;
            Ok(out)
        }
        fn u32_at(buf: &[u8], i: &mut usize) -> Result<u32> {
            Ok(u32::from_le_bytes(take(buf, i, 4)?.try_into().unwrap()))
        }
        fn u64_at(buf: &[u8], i: &mut usize) -> Result<u64> {
            Ok(u64::from_le_bytes(take(buf, i, 8)?.try_into().unwrap()))
        }
        let buf = region.bytes();
        let mut i = 0usize;
        if take(buf, &mut i, 8)? != MAGIC {
            bail!("{path:?} is not a packed sparse checkpoint (bad magic)");
        }
        let version = u32_at(buf, &mut i)?;
        if version != VERSION && version != VERSION_V1 {
            bail!("unsupported packed checkpoint version {version}");
        }
        let _flags = u32_at(buf, &mut i)?;
        let name_len = u32_at(buf, &mut i)? as usize;
        if name_len > 1024 {
            bail!("implausible config-name length {name_len}");
        }
        let config_name = String::from_utf8(take(buf, &mut i, name_len)?.to_vec())?;
        let src_len = u32_at(buf, &mut i)? as usize;
        if src_len > 1024 {
            bail!("implausible source-label length {src_len}");
        }
        let source_label = String::from_utf8(take(buf, &mut i, src_len)?.to_vec())?;
        let n_params = u64_at(buf, &mut i)? as usize;
        let layers = u32_at(buf, &mut i)? as usize;
        let n_entries = u32_at(buf, &mut i)? as usize;
        let rest_off = u64_at(buf, &mut i)? as usize;
        let rest_len = u64_at(buf, &mut i)? as usize;
        if n_entries > 6 * layers.max(1) || n_entries % PRUNABLE_KINDS.len() != 0 {
            bail!("implausible entry count {n_entries} for {layers} layers");
        }
        let toc_off = align8(i);

        // validate the whole TOC extent up front: `n_entries` is hostile
        // input until now, and it sizes the allocation below
        let toc_entry = if version >= VERSION { TOC_ENTRY_V2 } else { TOC_ENTRY_V1 };
        let toc_end = n_entries
            .checked_mul(toc_entry)
            .and_then(|b| toc_off.checked_add(b))
            .filter(|&e| e <= buf.len());
        if toc_end.is_none() {
            bail!("{path:?}: TOC for {n_entries} entries out of bounds");
        }

        // remainder section (checked: rest_off/rest_len are u64 fields)
        let rest_end = rest_len
            .checked_mul(4)
            .and_then(|b| rest_off.checked_add(b))
            .filter(|&e| e <= buf.len());
        let Some(rest_end) = rest_end else {
            bail!("{path:?}: remainder section out of bounds");
        };
        if rest_off < i {
            bail!("{path:?}: remainder section out of bounds");
        }
        let rest: Vec<f32> = buf[rest_off..rest_end]
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
            .collect();

        // TOC + sections
        let mut entries = Vec::with_capacity(n_entries);
        let mut t = toc_off;
        for _ in 0..n_entries {
            let layer = u32_at(buf, &mut t)? as usize;
            let ktag = take(buf, &mut t, 1)?[0];
            let fmt = take(buf, &mut t, 1)?[0];
            let _pad = take(buf, &mut t, 2)?;
            let off = u64_at(buf, &mut t)? as usize;
            let len = u64_at(buf, &mut t)? as usize;
            let rows = u32_at(buf, &mut t)? as usize;
            let cols = u32_at(buf, &mut t)? as usize;
            let nnz = u64_at(buf, &mut t)? as usize;
            // v2 quant metadata (v1 entries stop at nnz)
            let quant = if version >= VERSION {
                let bits = take(buf, &mut t, 1)?[0];
                let _pad = take(buf, &mut t, 1)?;
                let group = u16::from_le_bytes(take(buf, &mut t, 2)?.try_into().unwrap());
                let ebits = f32::from_le_bytes(take(buf, &mut t, 4)?.try_into().unwrap());
                Some((bits, group, ebits))
            } else {
                None
            };
            let kind = kind_from_tag(ktag)?;
            if layer >= layers {
                bail!("TOC entry layer {layer} out of range");
            }
            if off.checked_add(len).filter(|&e| e <= buf.len()).is_none() {
                bail!("TOC entry section out of bounds");
            }
            let (matrix, used) = if zero_copy {
                PackedMatrix::read_bytes_mapped(region, off, len)
            } else {
                PackedMatrix::read_bytes(&buf[off..off + len])
            }
            .with_context(|| format!("decoding layer {layer} {}", kind.label()))?;
            if used != len {
                bail!("section for layer {layer} {} has trailing bytes", kind.label());
            }
            if matrix.rows() != rows || matrix.cols() != cols || matrix.nnz() != nnz {
                bail!("TOC/section mismatch for layer {layer} {}", kind.label());
            }
            if let Some((bits, group, ebits)) = quant {
                // the v2 TOC metadata must agree with the decoded section
                let meta = matrix.quant_meta().unwrap_or((0, 0));
                if fmt != format_tag(&matrix) || (bits, group) != meta {
                    bail!("TOC quant metadata mismatch for layer {layer} {}", kind.label());
                }
                if (ebits as f64 - matrix.effective_bits()).abs() > 1e-3 {
                    bail!("TOC effective_bits drifted for layer {layer} {}", kind.label());
                }
            }
            entries.push(StoreEntry { layer, kind, matrix });
        }
        Ok(SparseStore { config_name, source_label, n_params, layers, rest, entries })
    }
}

fn align8(n: usize) -> usize {
    (n + 7) & !7
}

/// Checked narrowing for the `.spkt` header's u32 length fields: an
/// oversized value must fail the save, not silently truncate and produce a
/// file whose header lies about its own layout.
fn u32_len(n: usize, what: &str) -> Result<u32> {
    u32::try_from(n).map_err(|_| anyhow!("{what} length {n} exceeds the .spkt u32 field"))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::init::init_params;
    use crate::solver::magnitude::{magnitude_prune, magnitude_prune_nm};
    use crate::sparse::PackFormat;

    fn test_cfg() -> ModelCfg {
        ModelCfg::from_dims("spkt-test", 8, 2, 2, 1, 1, 13, 6)
    }

    fn pruned_params(cfg: &ModelCfg, p: f64) -> FlatParams {
        let mut fp = init_params(cfg, 3);
        for layer in 0..cfg.layers {
            for kind in PRUNABLE_KINDS {
                let mut w = magnitude_prune(&fp.get_linear(kind, layer).unwrap(), p).0;
                // keep one dense 8-wide run so Auto can never pick n:m
                for j in 0..8.min(w.cols()) {
                    w.set2(0, j, 1.0 + j as f32);
                }
                fp.set_linear(kind, layer, &w).unwrap();
            }
        }
        fp
    }

    #[test]
    fn pack_save_load_unpack_roundtrip() {
        let cfg = test_cfg();
        // 80% sparse: deep enough that the packed file beats raw f32
        // (CSR costs 8 bytes per surviving weight, so break-even is ~50%)
        let fp = pruned_params(&cfg, 0.8);
        let store = SparseStore::pack(&fp, &PackPolicy::default(), "magnitude-80%").unwrap();
        assert!((store.density() - 0.25).abs() < 0.1, "{}", store.density());
        assert_eq!(store.format_counts().get("csr"), Some(&12));

        let dir = std::env::temp_dir().join(format!("sgpt_spkt_{}", std::process::id()));
        let path = dir.join("t.spkt");
        let bytes = store.save(&path).unwrap();
        assert_eq!(bytes, std::fs::metadata(&path).unwrap().len());
        let back = SparseStore::load(&path).unwrap();
        std::fs::remove_dir_all(&dir).ok();

        assert_eq!(back.config_name, "spkt-test");
        assert_eq!(back.source_label, "magnitude-80%");
        assert_eq!(back.unpack(&cfg).unwrap().data, fp.data);
        // the packed file skips pruned weights: smaller than raw f32 params
        assert!((bytes as usize) < cfg.n_params * 4, "{bytes} vs {}", cfg.n_params * 4);
    }

    #[test]
    fn nm_packed_store_roundtrips() {
        let cfg = test_cfg();
        let mut fp = init_params(&cfg, 5);
        for layer in 0..cfg.layers {
            for kind in PRUNABLE_KINDS {
                let w = fp.get_linear(kind, layer).unwrap();
                fp.set_linear(kind, layer, &magnitude_prune_nm(&w, 2, 4).0).unwrap();
            }
        }
        let store = SparseStore::pack(&fp, &PackPolicy::default(), "magnitude-2:4").unwrap();
        assert_eq!(store.format_counts().get("nm"), Some(&12));
        assert_eq!(store.unpack(&cfg).unwrap().data, fp.data);
    }

    #[test]
    fn forced_dense_format_keeps_everything() {
        let cfg = test_cfg();
        let fp = init_params(&cfg, 1);
        let store =
            SparseStore::pack(&fp, &PackPolicy::with_format(PackFormat::Dense), "dense").unwrap();
        assert_eq!(store.format_counts().get("dense"), Some(&12));
        assert_eq!(store.unpack(&cfg).unwrap().data, fp.data);
    }

    #[test]
    fn quantized_store_roundtrips_with_metadata() {
        let cfg = test_cfg();
        let fp = pruned_params(&cfg, 0.5);
        let fmt = PackFormat::QCsr { bits: 4, group: 4 };
        let store = SparseStore::pack(&fp, &PackPolicy::with_format(fmt), "sparsegpt-50%+q4")
            .unwrap();
        assert_eq!(store.format_counts().get("qcsr"), Some(&12));
        assert!(store.effective_bits() < 32.0);

        let dir = std::env::temp_dir().join(format!("sgpt_spkt_q_{}", std::process::id()));
        let path = dir.join("q.spkt");
        store.save(&path).unwrap();
        let back = SparseStore::load(&path).unwrap();
        std::fs::remove_dir_all(&dir).ok();

        // the dequantized weights round-trip bit-exactly, and the v2 TOC
        // metadata survives
        assert_eq!(back.unpack(&cfg).unwrap().data, store.unpack(&cfg).unwrap().data);
        assert_eq!(back.effective_bits(), store.effective_bits());
        for (a, b) in store.entries.iter().zip(&back.entries) {
            assert_eq!(a.matrix.quant_meta(), b.matrix.quant_meta());
            assert_eq!(a.matrix.quant_meta(), Some((4, 4)));
        }
        // quantization is lossy against the original params, but zeros
        // (pruned weights) survive exactly
        let unpacked = back.unpack(&cfg).unwrap();
        for (orig, got) in fp.data.iter().zip(&unpacked.data) {
            if *orig == 0.0 {
                assert_eq!(*got, 0.0);
            }
        }
    }

    #[test]
    fn unpack_rejects_wrong_config() {
        let cfg = test_cfg();
        let fp = pruned_params(&cfg, 0.5);
        let store = SparseStore::pack(&fp, &PackPolicy::default(), "x").unwrap();
        let other = ModelCfg::from_dims("other", 8, 2, 2, 1, 1, 13, 6);
        assert!(store.unpack(&other).is_err());
    }

    #[test]
    fn load_rejects_garbage() {
        let dir = std::env::temp_dir().join(format!("sgpt_spkt_bad_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("bad.spkt");
        std::fs::write(&path, b"definitely not a packed checkpoint").unwrap();
        assert!(SparseStore::load(&path).is_err());

        // corrupt a real file: every hostile header field must produce a
        // clean error, never a giant allocation or an out-of-bounds slice
        let cfg = test_cfg();
        let store =
            SparseStore::pack(&pruned_params(&cfg, 0.8), &PackPolicy::default(), "g").unwrap();
        store.save(&path).unwrap();
        let good = std::fs::read(&path).unwrap();
        assert!(SparseStore::load(&path).is_ok());

        let check = |bytes: &[u8], why: &str| {
            let p = dir.join("evil.spkt");
            std::fs::write(&p, bytes).unwrap();
            assert!(SparseStore::load(&p).is_err(), "{why}");
            assert!(SparseStore::load_owned(&p).is_err(), "{why} (owned)");
        };

        // truncation at every structural boundary
        for k in [0, 7, 12, 40, good.len() / 2, good.len() - 1] {
            check(&good[..k], &format!("truncated to {k} bytes"));
        }

        // header field byte offsets (see the save layout)
        let name = store.config_name.len();
        let src = store.source_label.len();
        let hdr = 8 + 4 + 4 + 4 + name + 4 + src + 8;
        let patch = |off: usize, with: &[u8]| {
            let mut b = good.clone();
            b[off..off + with.len()].copy_from_slice(with);
            b
        };
        // layers huge + entry count huge but "plausible" for those layers:
        // the TOC extent check must fire before the entry allocation
        let evil = patch(hdr, &0x2000_0000u32.to_le_bytes());
        let evil2 = {
            let mut b = evil;
            b[hdr + 4..hdr + 8].copy_from_slice(&0x3000_0000u32.to_le_bytes());
            b
        };
        check(&evil2, "oversized TOC");
        // remainder length off the end of the file
        check(&patch(hdr + 16, &u64::MAX.to_le_bytes()), "oversized remainder");
        // remainder length that overflows rest_off + rest_len * 4
        check(&patch(hdr + 16, &(u64::MAX / 4).to_le_bytes()), "overflowing remainder");
        // first TOC entry's section offset far out of bounds
        let toc_off = align8(hdr + 4 + 4 + 8 + 8);
        check(&patch(toc_off + 8, &u64::MAX.to_le_bytes()), "section offset out of bounds");

        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn u32_len_rejects_past_the_field_width() {
        assert_eq!(u32_len(0, "x").unwrap(), 0);
        assert_eq!(u32_len(u32::MAX as usize, "x").unwrap(), u32::MAX);
        let err = u32_len(u32::MAX as usize + 1, "entry count").unwrap_err();
        assert!(err.to_string().contains("entry count"), "{err}");
    }

    #[test]
    fn mapped_load_matches_owned_load() {
        let cfg = test_cfg();
        let fp = pruned_params(&cfg, 0.8);
        let store = SparseStore::pack(&fp, &PackPolicy::default(), "mm").unwrap();
        let dir = std::env::temp_dir().join(format!("sgpt_spkt_mm_{}", std::process::id()));
        let path = dir.join("m.spkt");
        store.save(&path).unwrap();

        let mapped = SparseStore::load(&path).unwrap();
        let owned = SparseStore::load_owned(&path).unwrap();
        std::fs::remove_dir_all(&dir).ok();

        assert_eq!(mapped.unpack(&cfg).unwrap().data, owned.unpack(&cfg).unwrap().data);
        assert_eq!(mapped.payload_bytes(), owned.payload_bytes());
        assert_eq!(owned.mapped_bytes(), 0);
        #[cfg(all(target_os = "linux", any(target_arch = "x86_64", target_arch = "aarch64")))]
        assert!(mapped.mapped_bytes() > 0, "zero-copy load should serve mapped sections");
    }
}
