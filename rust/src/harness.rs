//! Workspace conventions shared by the CLI, examples and benches: where
//! data, tokenizer, checkpoints and reports live, and how to load them.
//!
//! Layout:
//!   data/tokenizer.txt            BPE merges
//!   data/<corpus>-<split>.tokens  tokenized corpora (i32 LE)
//!   checkpoints/<config>.ckpt     trained models
//!   reports/                      bench outputs (txt + csv + jsonl)

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

use crate::coordinator::CalibChunks;
use crate::data::corpus::{gen_corpus, CorpusStyle, Lexicon};
use crate::data::{Dataset, Tokenizer};
use crate::model::checkpoint::Checkpoint;
use crate::model::layout::FlatParams;
use crate::model::ModelCfg;
use crate::runtime::{Backend, BackendKind};
use crate::util::prng::Rng;

pub const CALIB_SET: &str = "synth-c4-train";
pub const EVAL_SETS: [&str; 3] = ["synth-wiki", "synth-ptb", "synth-c4-val"];
/// paper default: 128 calibration segments
pub const DEFAULT_CALIB_SEGMENTS: usize = 128;

pub struct Workspace {
    pub data_dir: PathBuf,
    pub ckpt_dir: PathBuf,
    pub report_dir: PathBuf,
    /// The execution backend (PJRT runtime or the pure-Rust reference
    /// interpreter); everything downstream takes `&dyn Backend`.
    pub rt: Box<dyn Backend>,
}

impl Workspace {
    /// Open with defaults (`data/`, `checkpoints/`, `reports/`, `artifacts/`),
    /// overridable via SPARSEGPT_{DATA,CKPT,REPORTS,ARTIFACTS}; the backend
    /// comes from `SPARSEGPT_BACKEND` (default: pjrt).
    pub fn open() -> Result<Workspace> {
        Self::open_with(BackendKind::resolve(None)?)
    }

    /// Open with an explicit execution backend (the CLI `--backend` path —
    /// explicit choice wins over the `SPARSEGPT_BACKEND` env override).
    pub fn open_with(kind: BackendKind) -> Result<Workspace> {
        let env = |k: &str, d: &str| {
            std::env::var_os(k).map(PathBuf::from).unwrap_or_else(|| PathBuf::from(d))
        };
        Ok(Workspace {
            data_dir: env("SPARSEGPT_DATA", "data"),
            ckpt_dir: env("SPARSEGPT_CKPT", "checkpoints"),
            report_dir: env("SPARSEGPT_REPORTS", "reports"),
            rt: kind.open()?,
        })
    }

    pub fn tokenizer(&self) -> Result<Tokenizer> {
        Tokenizer::load(self.data_dir.join("tokenizer.txt"))
            .context("loading tokenizer — run `sparsegpt gen-data` first")
    }

    pub fn dataset(&self, name: &str) -> Result<Dataset> {
        Dataset::load_tokens(name, self.dataset_path(name))
            .with_context(|| format!("loading dataset {name} — run `sparsegpt gen-data` first"))
    }

    pub fn eval_datasets(&self) -> Result<BTreeMap<String, Dataset>> {
        EVAL_SETS
            .iter()
            .map(|n| Ok((n.to_string(), self.dataset(n)?)))
            .collect()
    }

    pub fn config(&self, name: &str) -> Result<ModelCfg> {
        self.rt.config(name)
    }

    pub fn dataset_path(&self, name: &str) -> PathBuf {
        self.data_dir.join(format!("{name}.tokens"))
    }

    pub fn has_dataset(&self, name: &str) -> bool {
        self.dataset_path(name).exists()
    }

    pub fn load_model(&self, config: &str) -> Result<FlatParams> {
        let cfg = self.config(config)?;
        let path = Checkpoint::path_for(&self.ckpt_dir, config, "");
        Checkpoint::load(&path)
            .with_context(|| format!("run `sparsegpt train --config {config}` first"))?
            .into_flat_params(&cfg)
    }

    /// Calibration chunks per the paper's recipe: `n` random segments from
    /// the (training-distribution) calibration corpus. Errors when
    /// `gen-data` has not run — a model trained on real data must never be
    /// silently calibrated on something else (see
    /// [`Workspace::calib_chunks_or_synthetic`] for the explicit zero-setup
    /// path).
    pub fn calib_chunks(&self, cfg: &ModelCfg, n: usize, seed: u64) -> Result<CalibChunks> {
        self.chunks_from(self.dataset(CALIB_SET)?, cfg, n, seed)
    }

    /// Like [`Workspace::calib_chunks`], but when the calibration corpus is
    /// missing, substitutes a deterministic in-memory synthetic corpus so a
    /// fresh checkout can prune with zero setup. Returns whether the
    /// substitution happened so the caller can announce it.
    pub fn calib_chunks_or_synthetic(
        &self,
        cfg: &ModelCfg,
        n: usize,
        seed: u64,
    ) -> Result<(CalibChunks, bool)> {
        if self.has_dataset(CALIB_SET) {
            Ok((self.calib_chunks(cfg, n, seed)?, false))
        } else {
            let ds = synthetic_calibration_corpus();
            Ok((self.chunks_from(ds, cfg, n, seed)?, true))
        }
    }

    fn chunks_from(&self, ds: Dataset, cfg: &ModelCfg, n: usize, seed: u64) -> Result<CalibChunks> {
        let mut rng = Rng::new(seed ^ 0xca11b);
        let segs = ds.calibration_segments(&mut rng, n, cfg.seq)?;
        CalibChunks::new(cfg, &segs)
    }
}

/// Deterministic in-memory stand-in for the calibration corpus (same
/// generator family as `gen-data`, fixed seed): used when the data
/// directory has not been populated yet.
pub fn synthetic_calibration_corpus() -> Dataset {
    let lex = Lexicon::new(0);
    let text = gen_corpus(&lex, CorpusStyle::C4, 5, 400_000);
    let tok = Tokenizer::train(&text[..100_000.min(text.len())]);
    Dataset::from_text("synthetic-calib", &tok, &text)
}

/// Generate corpora + tokenizer + tokenized datasets into `out`, logging
/// progress to stdout.
pub fn generate_data(out: impl AsRef<Path>, seed: u64, train_mb: usize) -> Result<()> {
    generate_data_with(out, seed, train_mb, &mut |t| println!("{t}"))
}

/// Like [`generate_data`] but routing progress lines through `log` (the
/// `api` layer turns them into structured events).
pub fn generate_data_with(
    out: impl AsRef<Path>,
    seed: u64,
    train_mb: usize,
    log: &mut dyn FnMut(&str),
) -> Result<()> {
    let out = out.as_ref();
    std::fs::create_dir_all(out)?;
    let lex = Lexicon::new(seed);

    let specs: Vec<(&str, CorpusStyle, u64, usize)> = vec![
        ("synth-c4-train", CorpusStyle::C4, seed ^ 1, train_mb * 1_000_000),
        ("synth-c4-val", CorpusStyle::C4, seed ^ 2, 300_000),
        ("synth-wiki", CorpusStyle::Wiki, seed ^ 3, 300_000),
        ("synth-ptb", CorpusStyle::Ptb, seed ^ 4, 300_000),
    ];
    let mut texts = Vec::new();
    for (name, style, s, bytes) in &specs {
        let t = gen_corpus(&lex, *style, *s, (*bytes).max(100_000));
        log(&format!("[gen-data] {name}: {} chars", t.len()));
        texts.push((name.to_string(), t));
    }

    // train the tokenizer on a slice of the calibration corpus only
    let train_text = &texts[0].1;
    let tok = Tokenizer::train(&train_text[..train_text.len().min(400_000)]);
    tok.save(out.join("tokenizer.txt"))?;
    log(&format!("[gen-data] tokenizer: {} merges", tok.merges.len()));

    for (name, text) in &texts {
        let ds = Dataset::from_text(name, &tok, text);
        log(&format!("[gen-data] {name}: {} tokens", ds.len()));
        ds.save_tokens(out.join(format!("{name}.tokens")))?;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn generate_data_roundtrip() {
        let dir = std::env::temp_dir().join(format!("sgpt_ws_{}", std::process::id()));
        generate_data(&dir, 1, 0).unwrap(); // 0 MB -> minimum-size corpora
        assert!(dir.join("tokenizer.txt").exists());
        for n in ["synth-c4-train", "synth-c4-val", "synth-wiki", "synth-ptb"] {
            let ds = Dataset::load_tokens(n, dir.join(format!("{n}.tokens"))).unwrap();
            assert!(!ds.is_empty(), "{n}");
        }
        std::fs::remove_dir_all(&dir).ok();
    }
}
