//! Typed job results: what [`crate::api::Session::run`] returns.
//!
//! Reports carry everything a programmatic caller needs (including, for
//! prune jobs, the compressed parameters themselves) — the event stream is
//! for progress, the report is for results.

use std::collections::BTreeMap;
use std::path::PathBuf;

use crate::coordinator::MatrixReport;
use crate::model::layout::FlatParams;
use crate::util::json::Json;

#[derive(Clone, Debug)]
pub struct GenDataReport {
    pub out: PathBuf,
}

#[derive(Clone, Debug)]
pub struct TrainReport {
    pub config: String,
    pub steps: usize,
    pub final_loss: f64,
    pub secs: f64,
    /// (step, loss) at the logging cadence
    pub losses: Vec<(usize, f64)>,
    pub ckpt: Option<PathBuf>,
}

#[derive(Clone, Debug)]
pub struct PruneReport {
    pub config: String,
    pub label: String,
    pub sparsity: f64,
    pub total_secs: f64,
    pub hessian_secs: f64,
    pub solver_secs: f64,
    pub propagate_secs: f64,
    pub matrices: Vec<MatrixReport>,
    pub saved_to: Option<PathBuf>,
    /// where the packed sparse checkpoint (`.spkt`) went, with `--pack`
    pub packed_to: Option<PathBuf>,
    /// the compressed model
    pub params: FlatParams,
}

#[derive(Clone, Debug)]
pub struct EvalRow {
    pub dataset: String,
    pub ppl: f64,
    pub tokens: usize,
}

#[derive(Clone, Debug)]
pub struct EvalReport {
    pub config: String,
    pub rows: Vec<EvalRow>,
}

#[derive(Clone, Debug)]
pub struct ZeroShotReport {
    pub config: String,
    /// (task name, accuracy) for the five tasks
    pub rows: Vec<(String, f64)>,
    pub avg: f64,
}

#[derive(Clone, Debug)]
pub struct StatsReport {
    pub config: String,
    pub sparsity: f64,
    pub pruned_weights: usize,
    pub nm_violations: Option<usize>,
}

#[derive(Clone, Debug)]
pub struct GenerateReport {
    pub config: String,
    pub text: String,
}

/// One variant's results within a sweep (or the dense baseline).
#[derive(Clone, Debug)]
pub struct VariantResult {
    pub label: String,
    pub sparsity: f64,
    /// prune wall time (0 for the dense baseline)
    pub secs: f64,
    /// dataset -> perplexity (empty when the sweep disabled the ppl pass)
    pub ppl: BTreeMap<String, f64>,
    pub zeroshot: Option<ZeroShotReport>,
}

#[derive(Clone, Debug)]
pub struct SweepReport {
    pub config: String,
    pub dense: Option<VariantResult>,
    pub variants: Vec<VariantResult>,
}

impl SweepReport {
    /// Dense baseline + variants, in execution order.
    pub fn all_rows(&self) -> impl Iterator<Item = &VariantResult> {
        self.dense.iter().chain(self.variants.iter())
    }
}

#[derive(Clone, Debug)]
pub struct E2eReport {
    /// `None` when an existing checkpoint was reused
    pub train: Option<TrainReport>,
    pub sweep: SweepReport,
}

/// One retired request of a serve run.
#[derive(Clone, Debug)]
pub struct ServeRequestRow {
    pub id: u64,
    pub prompt_tokens: usize,
    /// generated token ids
    pub tokens: Vec<i32>,
    pub joined_step: usize,
    pub finished_step: usize,
    /// enqueue -> first streamed token
    pub ttft_secs: f64,
    /// median inter-token gap
    pub gap_p50_secs: f64,
    /// p95 inter-token gap
    pub gap_p95_secs: f64,
}

#[derive(Clone, Debug)]
pub struct ServeReport {
    pub config: String,
    /// compression the served weights came from (prune-spec label)
    pub label: String,
    /// "csr:10 dense:2"-style pack summary
    pub formats: String,
    /// density over the packed prunable weights
    pub density: f64,
    /// storage bits per packed weight (Fig.-6 accounting; 32.0 = f32)
    pub effective_bits: f64,
    /// decoded through the incremental KV-cached path (vs full re-forward)
    pub kv_cache: bool,
    pub steps: usize,
    pub tokens: usize,
    /// wall time inside batched decode steps (prefill excluded)
    pub decode_secs: f64,
    pub tokens_per_sec: f64,
    /// wall time inside chunked prefill passes (KV-cached mode)
    pub prefill_secs: f64,
    /// prompt tokens streamed through prefill (KV-cached mode)
    pub prefill_tokens: usize,
    /// KV ring-buffer evictions across all requests
    pub cache_evictions: usize,
    /// high-water mark of reserved cache memory
    pub peak_cache_bytes: u64,
    /// requests retired as cancelled (client disconnect or scripted)
    pub cancelled: usize,
    /// over-capacity submissions answered with `rejected` frames
    pub rejected: usize,
    /// median time-to-first-token across finished requests
    pub ttft_p50_secs: f64,
    /// p95 time-to-first-token across finished requests
    pub ttft_p95_secs: f64,
    /// the bound listen address, when serving over TCP
    pub listen: Option<String>,
    pub requests: Vec<ServeRequestRow>,
    /// where the packed checkpoint was written, when requested
    pub packed_to: Option<PathBuf>,
    /// the post-run [`Obs`](crate::obs::Obs) snapshot, as the same JSON
    /// object the `stats` frame and `metrics-snapshot` event carry
    pub metrics: Json,
}

/// The result of one executed [`crate::api::JobSpec`].
#[derive(Clone, Debug)]
pub enum JobReport {
    GenData(GenDataReport),
    Train(TrainReport),
    Prune(PruneReport),
    Eval(EvalReport),
    ZeroShot(ZeroShotReport),
    Stats(StatsReport),
    Generate(GenerateReport),
    E2e(E2eReport),
    Sweep(SweepReport),
    Serve(ServeReport),
}

impl JobReport {
    pub fn into_train(self) -> Option<TrainReport> {
        match self {
            JobReport::Train(r) => Some(r),
            _ => None,
        }
    }

    pub fn into_prune(self) -> Option<PruneReport> {
        match self {
            JobReport::Prune(r) => Some(r),
            _ => None,
        }
    }

    pub fn into_eval(self) -> Option<EvalReport> {
        match self {
            JobReport::Eval(r) => Some(r),
            _ => None,
        }
    }

    pub fn into_zeroshot(self) -> Option<ZeroShotReport> {
        match self {
            JobReport::ZeroShot(r) => Some(r),
            _ => None,
        }
    }

    pub fn into_sweep(self) -> Option<SweepReport> {
        match self {
            JobReport::Sweep(r) => Some(r),
            _ => None,
        }
    }

    pub fn into_e2e(self) -> Option<E2eReport> {
        match self {
            JobReport::E2e(r) => Some(r),
            _ => None,
        }
    }

    pub fn into_generate(self) -> Option<GenerateReport> {
        match self {
            JobReport::Generate(r) => Some(r),
            _ => None,
        }
    }

    pub fn into_serve(self) -> Option<ServeReport> {
        match self {
            JobReport::Serve(r) => Some(r),
            _ => None,
        }
    }
}
