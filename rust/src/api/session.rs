//! The job executor: owns the [`Workspace`] (and through it the execution
//! [`crate::runtime::Backend`] — PJRT or the pure-Rust reference
//! interpreter), resolves checkpoints, and runs [`JobSpec`]s to typed
//! [`JobReport`]s while narrating progress through an [`EventSink`].

use std::path::PathBuf;
use std::time::Instant;

use anyhow::{Context, Result};

use crate::api::events::{Event, EventSink};
use crate::api::report::{
    E2eReport, EvalReport, EvalRow, GenDataReport, GenerateReport, JobReport, PruneReport,
    ServeReport, ServeRequestRow, StatsReport, SweepReport, TrainReport, VariantResult,
    ZeroShotReport,
};
use crate::api::spec::{
    E2eSpec, EvalSpec, GenDataSpec, GenerateSpec, JobSpec, PruneJobSpec, PruneSpec, ServeSpec,
    StatsSpec, SweepSpec, TrainSpec, ZeroShotSpec,
};
use crate::coordinator::{
    CalibChunks, PipelineEvent, PruneOptions, Pruner, SkipSpec, TrainEvent, TrainOptions, Trainer,
};
use crate::data::corpus::Lexicon;
use crate::data::Dataset;
use crate::eval::generate::{sample, SampleOptions};
use crate::eval::perplexity;
use crate::eval::zeroshot::{gen_items, zero_shot_accuracy, ZeroShotTask};
use crate::harness::{generate_data_with, Workspace, CALIB_SET, EVAL_SETS};
use crate::model::checkpoint::Checkpoint;
use crate::model::init::init_params;
use crate::model::layout::FlatParams;
use crate::model::sparse_store::SparseStore;
use crate::model::stats::ModelStats;
use crate::obs::{Clock, Obs, Phase};
use crate::runtime::BackendKind;
use crate::serve::net::{NetServer, NetServerOptions};
use crate::serve::{
    percentile_sorted, EngineOptions, ModelFleet, Router, SchedulerPolicy, ServeEngine,
    ServeEvent, ServeRequest, SparseModel, SyntheticSource,
};
use crate::sparse::PackPolicy;
use crate::util::prng::Rng;

/// A handle for executing jobs. The workspace (and the execution backend
/// inside it) opens lazily, so jobs that need neither — `gen-data` — run on
/// a machine without built artifacts.
pub struct Session {
    ws: Option<Workspace>,
    backend: Option<BackendKind>,
}

impl Session {
    /// A session whose workspace opens on first use, with the backend
    /// resolved from `SPARSEGPT_BACKEND` (default: pjrt).
    pub fn new() -> Session {
        Session { ws: None, backend: None }
    }

    /// A session pinned to an explicit execution backend (the CLI
    /// `--backend` path; wins over the env override).
    pub fn with_backend(kind: BackendKind) -> Session {
        Session { ws: None, backend: Some(kind) }
    }

    /// A session with the workspace opened eagerly.
    pub fn open() -> Result<Session> {
        Ok(Session { ws: Some(Workspace::open()?), backend: None })
    }

    /// Wrap an already-configured workspace.
    pub fn with_workspace(ws: Workspace) -> Session {
        Session { ws: Some(ws), backend: None }
    }

    /// The workspace, opening it if this is the first job that needs one.
    pub fn workspace(&mut self) -> Result<&Workspace> {
        if self.ws.is_none() {
            self.ws = Some(Workspace::open_with(BackendKind::resolve(self.backend)?)?);
        }
        Ok(self.ws.as_ref().unwrap())
    }

    /// The workspace only if some job has already opened it (e.g. for
    /// post-run runtime stats without forcing a runtime to exist).
    pub fn opened_workspace(&self) -> Option<&Workspace> {
        self.ws.as_ref()
    }

    /// Execute one job, emitting `job-started` / progress / `job-finished`
    /// events into `sink` and returning the typed report.
    pub fn run(&mut self, spec: &JobSpec, sink: &mut dyn EventSink) -> Result<JobReport> {
        let t0 = Instant::now();
        sink.emit(&Event::JobStarted {
            job: spec.kind().to_string(),
            label: spec.label(),
            config: spec.config().map(|c| c.to_string()),
        });
        let report = self.dispatch(spec, sink);
        sink.emit(&Event::JobFinished {
            job: spec.kind().to_string(),
            ok: report.is_ok(),
            secs: t0.elapsed().as_secs_f64(),
        });
        report
    }

    fn dispatch(&mut self, spec: &JobSpec, sink: &mut dyn EventSink) -> Result<JobReport> {
        if let JobSpec::GenData(g) = spec {
            return run_gen_data(g, sink).map(JobReport::GenData);
        }
        let ws = self.workspace()?;
        match spec {
            JobSpec::GenData(_) => unreachable!("handled above"),
            JobSpec::Train(s) => run_train(ws, s, sink).map(JobReport::Train),
            JobSpec::Prune(s) => run_prune(ws, s, sink).map(JobReport::Prune),
            JobSpec::Eval(s) => run_eval(ws, s, sink).map(JobReport::Eval),
            JobSpec::ZeroShot(s) => run_zeroshot(ws, s, sink).map(JobReport::ZeroShot),
            JobSpec::Stats(s) => run_stats(ws, s, sink).map(JobReport::Stats),
            JobSpec::Generate(s) => run_generate(ws, s, sink).map(JobReport::Generate),
            JobSpec::E2e(s) => run_e2e(ws, s, sink).map(JobReport::E2e),
            JobSpec::Sweep(s) => run_sweep(ws, s, sink).map(JobReport::Sweep),
            JobSpec::Serve(s) => run_serve(ws, s, sink).map(JobReport::Serve),
        }
    }
}

impl Default for Session {
    fn default() -> Session {
        Session::new()
    }
}

/// Resolve the parameters a job operates on: an explicit checkpoint path
/// or the config's conventionally-named trained checkpoint. Missing
/// checkpoints are a hard error — measurement jobs (eval, zeroshot, stats,
/// generate) must never silently score random weights.
fn load_params(ws: &Workspace, config: &str, ckpt: &Option<PathBuf>) -> Result<FlatParams> {
    let cfg = ws.config(config)?;
    match ckpt {
        Some(p) => Checkpoint::load(p)?.into_flat_params(&cfg),
        None => ws.load_model(config),
    }
}

/// Like [`load_params`], but for the compression jobs (prune, sweep): when
/// nothing has been trained yet, fall back to a seed-0 random
/// initialization, announced on the event stream, so zero-setup runs
/// (fresh checkout, `--backend reference`) still complete end-to-end. The
/// second element reports whether the fallback was taken (a *trained*
/// model must never be silently calibrated on substitute data — see
/// [`calib_for`]).
fn load_params_or_init(
    ws: &Workspace,
    config: &str,
    ckpt: &Option<PathBuf>,
    sink: &mut dyn EventSink,
) -> Result<(FlatParams, bool)> {
    let cfg = ws.config(config)?;
    if ckpt.is_none() && !Checkpoint::path_for(&ws.ckpt_dir, config, "").exists() {
        sink.emit(&Event::Message {
            text: format!(
                "[{config}] no trained checkpoint found; using fresh seed-0 parameters \
                 (run `sparsegpt train --config {config}` for meaningful numbers)"
            ),
        });
        return Ok((init_params(&cfg, 0), true));
    }
    Ok((load_params(ws, config, ckpt)?, false))
}

/// Draw calibration chunks. Only a zero-setup run (`params_initialized`:
/// nothing trained, nothing generated) may substitute the in-memory
/// synthetic corpus — and announces it; with a real checkpoint a missing
/// corpus stays a hard "run gen-data first" error, because calibrating a
/// trained model on differently-tokenized text silently corrupts the prune.
fn calib_for(
    ws: &Workspace,
    cfg: &crate::model::ModelCfg,
    calib: usize,
    calib_seed: u64,
    params_initialized: bool,
    sink: &mut dyn EventSink,
) -> Result<CalibChunks> {
    if !params_initialized {
        return ws.calib_chunks(cfg, calib, calib_seed);
    }
    let (chunks, substituted) = ws.calib_chunks_or_synthetic(cfg, calib, calib_seed)?;
    if substituted {
        sink.emit(&Event::Message {
            text: format!(
                "[calib] dataset {CALIB_SET:?} not found under {:?}; synthesizing an \
                 in-memory calibration corpus (run `sparsegpt gen-data` to persist corpora)",
                ws.data_dir
            ),
        });
    }
    Ok(chunks)
}

fn run_gen_data(spec: &GenDataSpec, sink: &mut dyn EventSink) -> Result<GenDataReport> {
    generate_data_with(&spec.out, spec.seed, spec.train_mb, &mut |text| {
        sink.emit(&Event::Message { text: text.to_string() })
    })?;
    Ok(GenDataReport { out: spec.out.clone() })
}

fn run_train(ws: &Workspace, spec: &TrainSpec, sink: &mut dyn EventSink) -> Result<TrainReport> {
    let cfg = ws.config(&spec.config)?;
    let mut opts = TrainOptions::for_config(&spec.config, spec.steps);
    opts.seed = spec.seed;
    opts.log_every = spec.log_every;
    if let Some(lr) = spec.lr {
        opts.base_lr = lr;
    }
    opts.checkpoint_every = spec.checkpoint_every;
    let out_dir = spec.out.clone().unwrap_or_else(|| ws.ckpt_dir.clone());
    opts.out = Some(out_dir.clone());
    let data = ws.dataset(CALIB_SET)?;

    let (params, adam, start) = if spec.resume {
        // resume always reads the conventional checkpoint (out_dir is only
        // where new checkpoints go — matches the original CLI behavior)
        let ck = Checkpoint::load(Checkpoint::path_for(&ws.ckpt_dir, &spec.config, ""))?;
        let step = ck.step;
        let adam = ck.adam.clone();
        (ck.into_flat_params(&cfg)?, adam, step)
    } else {
        (init_params(&cfg, spec.seed), None, 0)
    };
    sink.emit(&Event::Message {
        text: format!(
            "[train {}] {} params, {} steps, batch {}, lr {:.1e}",
            spec.config, cfg.n_params, spec.steps, cfg.train_batch, opts.base_lr
        ),
    });
    let mut ckpt_path = None;
    let out = Trainer::new(&ws.rt).train_with(params, adam, start, &data, &opts, &mut |ev| {
        match ev {
            TrainEvent::Step { step, loss, lr, secs_per_step } => sink.emit(&Event::TrainStep {
                step: *step,
                loss: *loss,
                lr: *lr,
                secs_per_step: *secs_per_step,
            }),
            TrainEvent::Checkpoint { path, .. } => {
                ckpt_path = Some(path.clone());
                sink.emit(&Event::CheckpointSaved { path: path.display().to_string() });
            }
        }
    })?;
    let final_loss = out.losses.last().map(|l| l.1).unwrap_or(f64::NAN);
    sink.emit(&Event::Message {
        text: format!(
            "[train {}] done in {:.1}s, final loss {final_loss:.4}",
            spec.config, out.secs
        ),
    });
    Ok(TrainReport {
        config: spec.config.clone(),
        steps: spec.steps,
        final_loss,
        secs: out.secs,
        losses: out.losses,
        ckpt: ckpt_path,
    })
}

/// Compress `params` with shared, pre-drawn calibration chunks. This is the
/// single prune entry every job kind (and the bench helpers) goes through.
pub(crate) fn prune_params(
    ws: &Workspace,
    config: &str,
    params: FlatParams,
    chunks: &CalibChunks,
    opts: &PruneOptions,
    sink: &mut dyn EventSink,
) -> Result<PruneReport> {
    let label = opts.method.label();
    sink.emit(&Event::Message {
        text: format!(
            "[prune {config}] method {label} | {} calib segments | damp {}",
            chunks.n_chunks(),
            opts.damp
        ),
    });
    let outcome = Pruner::new(&ws.rt).prune_with(params, chunks, opts, &mut |ev| match ev {
        PipelineEvent::BlockStart { .. } => {}
        PipelineEvent::Matrix(r) => sink.emit(&Event::matrix(r)),
        PipelineEvent::BlockDone { layer, layers, sparsity, secs } => {
            sink.emit(&Event::BlockCompressed {
                layer: *layer,
                layers: *layers,
                sparsity: *sparsity,
                secs: *secs,
            })
        }
    })?;
    let sparsity = outcome.overall_sparsity();
    sink.emit(&Event::Message {
        text: format!(
            "[prune {config}] sparsity {sparsity:.3} in {:.1}s (hessian {:.1}s solver {:.1}s prop {:.1}s)",
            outcome.total_secs, outcome.hessian_secs, outcome.solver_secs, outcome.propagate_secs
        ),
    });
    Ok(PruneReport {
        config: config.to_string(),
        label,
        sparsity,
        total_secs: outcome.total_secs,
        hessian_secs: outcome.hessian_secs,
        solver_secs: outcome.solver_secs,
        propagate_secs: outcome.propagate_secs,
        matrices: outcome.reports,
        saved_to: None,
        packed_to: None,
        params: outcome.params,
    })
}

fn run_prune(
    ws: &Workspace,
    spec: &PruneJobSpec,
    sink: &mut dyn EventSink,
) -> Result<PruneReport> {
    let cfg = ws.config(&spec.config)?;
    let (params, initialized) = load_params_or_init(ws, &spec.config, &spec.ckpt, sink)?;
    let opts = PruneOptions {
        method: spec.prune.method.clone(),
        damp: spec.damp,
        skip: spec.skip.clone(),
        record_errors: spec.record_errors,
        exact_rows: None,
    };
    let chunks = calib_for(ws, &cfg, spec.calib, spec.calib_seed, initialized, sink)?;
    let mut report = prune_params(ws, &spec.config, params, &chunks, &opts, sink)?;
    if spec.save {
        let suffix = spec.suffix.clone().unwrap_or_else(|| format!("-{}", report.label));
        let path = match &spec.out {
            Some(p) => p.clone(),
            None => Checkpoint::path_for(&ws.ckpt_dir, &spec.config, &suffix),
        };
        Checkpoint {
            config_name: spec.config.clone(),
            step: 0,
            params: report.params.data.clone(),
            adam: None,
        }
        .save(&path)?;
        sink.emit(&Event::CheckpointSaved { path: path.display().to_string() });
        report.saved_to = Some(path);
    }
    if spec.pack {
        let path = match &spec.pack_out {
            Some(p) => p.clone(),
            None => {
                SparseStore::path_for(&ws.ckpt_dir, &spec.config, &format!("-{}", report.label))
            }
        };
        let policy = PackPolicy::with_format(spec.pack_format);
        pack_to(&report.params, &report.label, &policy, &path, sink)?;
        report.packed_to = Some(path);
    }
    Ok(report)
}

/// Pack + persist a `.spkt` checkpoint, announcing it on the event stream.
fn pack_to(
    params: &FlatParams,
    label: &str,
    policy: &PackPolicy,
    path: &std::path::Path,
    sink: &mut dyn EventSink,
) -> Result<SparseStore> {
    let store = SparseStore::pack(params, policy, label)?;
    let bytes = store.save(path)?;
    sink.emit(&Event::CheckpointPacked {
        path: path.display().to_string(),
        bytes,
        density: store.density(),
        formats: store.format_summary(),
        effective_bits: store.effective_bits(),
    });
    Ok(store)
}

fn run_eval(ws: &Workspace, spec: &EvalSpec, sink: &mut dyn EventSink) -> Result<EvalReport> {
    let params = load_params(ws, &spec.config, &spec.ckpt)?;
    let mut rows = Vec::new();
    for (dsname, ds) in ws.eval_datasets()? {
        let p = perplexity(&ws.rt, &params, &ds, spec.max_segments)?;
        sink.emit(&Event::EvalResult { dataset: dsname.clone(), ppl: p.ppl, tokens: p.tokens });
        rows.push(EvalRow { dataset: dsname, ppl: p.ppl, tokens: p.tokens });
    }
    Ok(EvalReport { config: spec.config.clone(), rows })
}

/// The zero-shot suite over already-loaded params (shared by the zeroshot
/// job and the sweep's optional zero-shot pass).
fn zeroshot_for(
    ws: &Workspace,
    config: &str,
    params: &FlatParams,
    items: usize,
    seed: u64,
    data_seed: u64,
    sink: &mut dyn EventSink,
) -> Result<ZeroShotReport> {
    let tok = ws.tokenizer()?;
    let lex = Lexicon::new(data_seed);
    let mut rows = Vec::new();
    let mut sum = 0.0;
    for task in ZeroShotTask::ALL {
        let batch = gen_items(task, &lex, seed, items);
        let acc = zero_shot_accuracy(&ws.rt, params, &tok, &batch)?;
        sum += acc;
        sink.emit(&Event::ZeroShotResult { task: task.name().to_string(), accuracy: acc });
        rows.push((task.name().to_string(), acc));
    }
    Ok(ZeroShotReport {
        config: config.to_string(),
        rows,
        avg: sum / ZeroShotTask::ALL.len() as f64,
    })
}

fn run_zeroshot(
    ws: &Workspace,
    spec: &ZeroShotSpec,
    sink: &mut dyn EventSink,
) -> Result<ZeroShotReport> {
    let params = load_params(ws, &spec.config, &spec.ckpt)?;
    zeroshot_for(ws, &spec.config, &params, spec.items, spec.seed, spec.data_seed, sink)
}

fn run_stats(ws: &Workspace, spec: &StatsSpec, sink: &mut dyn EventSink) -> Result<StatsReport> {
    let params = load_params(ws, &spec.config, &spec.ckpt)?;
    let stats = ModelStats::collect_nm(&params, spec.nm);
    let report = StatsReport {
        config: spec.config.clone(),
        sparsity: stats.overall_sparsity(),
        pruned_weights: stats.pruned_weight_count(),
        nm_violations: spec.nm.map(|_| stats.total_nm_violations()),
    };
    sink.emit(&Event::Message {
        text: format!(
            "overall prunable sparsity: {:.4} ({} weights zeroed)",
            report.sparsity, report.pruned_weights
        ),
    });
    if let Some(v) = report.nm_violations {
        sink.emit(&Event::Message { text: format!("n:m violations: {v}") });
    }
    Ok(report)
}

fn run_generate(
    ws: &Workspace,
    spec: &GenerateSpec,
    sink: &mut dyn EventSink,
) -> Result<GenerateReport> {
    let params = load_params(ws, &spec.config, &spec.ckpt)?;
    let tok = ws.tokenizer()?;
    let prompt = tok.encode(&spec.prompt);
    let opts = SampleOptions {
        max_tokens: spec.tokens,
        temperature: spec.temperature,
        top_k: spec.top_k,
        seed: spec.seed,
    };
    let out = sample(&ws.rt, &params, &prompt, &opts)?;
    let text = format!("{}{}", spec.prompt, tok.decode(&out));
    sink.emit(&Event::Message { text: text.clone() });
    Ok(GenerateReport { config: spec.config.clone(), text })
}

fn run_sweep(ws: &Workspace, spec: &SweepSpec, sink: &mut dyn EventSink) -> Result<SweepReport> {
    let cfg = ws.config(&spec.config)?;
    let (dense, initialized) = load_params_or_init(ws, &spec.config, &spec.ckpt, sink)?;
    let datasets: Vec<(String, Dataset)> = if spec.max_segments == 0 {
        Vec::new()
    } else if spec.datasets.is_empty() {
        // zero-setup runs (nothing trained, nothing generated) degrade the
        // *default* perplexity pass gracefully instead of dying after the
        // fallbacks already engaged; an explicit --dataset stays strict
        if initialized && !EVAL_SETS.iter().any(|n| ws.has_dataset(n)) {
            sink.emit(&Event::Message {
                text: "[sweep] eval corpora not generated yet; skipping the perplexity \
                       pass (run `sparsegpt gen-data` to enable it)"
                    .to_string(),
            });
            Vec::new()
        } else {
            ws.eval_datasets()?.into_iter().collect()
        }
    } else {
        spec.datasets
            .iter()
            .map(|n| Ok((n.clone(), ws.dataset(n)?)))
            .collect::<Result<_>>()?
    };
    // shared calibration: drawn once, reused by every variant
    let chunks = calib_for(ws, &cfg, spec.calib, spec.calib_seed, initialized, sink)?;

    let eval_ppl = |params: &FlatParams,
                    sink: &mut dyn EventSink|
     -> Result<std::collections::BTreeMap<String, f64>> {
        let mut out = std::collections::BTreeMap::new();
        for (name, ds) in &datasets {
            let p = perplexity(&ws.rt, params, ds, spec.max_segments)?;
            sink.emit(&Event::EvalResult { dataset: name.clone(), ppl: p.ppl, tokens: p.tokens });
            out.insert(name.clone(), p.ppl);
        }
        Ok(out)
    };
    let zs = |params: &FlatParams, sink: &mut dyn EventSink| -> Result<Option<ZeroShotReport>> {
        if spec.zeroshot_items == 0 {
            return Ok(None);
        }
        zeroshot_for(
            ws,
            &spec.config,
            params,
            spec.zeroshot_items,
            spec.zeroshot_seed,
            spec.data_seed,
            sink,
        )
        .map(Some)
    };

    let total = spec.variants.len() + usize::from(spec.include_dense);
    let mut index = 0;
    let dense_result = if spec.include_dense {
        sink.emit(&Event::SweepVariant { index, total, label: "dense".to_string() });
        index += 1;
        let ppl = eval_ppl(&dense, sink)?;
        let zeroshot = zs(&dense, sink)?;
        Some(VariantResult { label: "dense".to_string(), sparsity: 0.0, secs: 0.0, ppl, zeroshot })
    } else {
        None
    };

    let mut variants = Vec::new();
    for v in &spec.variants {
        sink.emit(&Event::SweepVariant { index, total, label: v.label() });
        index += 1;
        let opts = PruneOptions {
            method: v.method.clone(),
            damp: spec.damp,
            skip: SkipSpec::None,
            record_errors: false,
            exact_rows: None,
        };
        let pr = prune_params(ws, &spec.config, dense.clone(), &chunks, &opts, sink)?;
        if spec.save {
            let path = Checkpoint::path_for(&ws.ckpt_dir, &spec.config, &format!("-{}", pr.label));
            Checkpoint {
                config_name: spec.config.clone(),
                step: 0,
                params: pr.params.data.clone(),
                adam: None,
            }
            .save(&path)?;
            sink.emit(&Event::CheckpointSaved { path: path.display().to_string() });
        }
        let ppl = eval_ppl(&pr.params, sink)?;
        let zeroshot = zs(&pr.params, sink)?;
        variants.push(VariantResult {
            label: pr.label,
            sparsity: pr.sparsity,
            secs: pr.total_secs,
            ppl,
            zeroshot,
        });
    }
    Ok(SweepReport { config: spec.config.clone(), dense: dense_result, variants })
}

fn run_e2e(ws: &Workspace, spec: &E2eSpec, sink: &mut dyn EventSink) -> Result<E2eReport> {
    // train only when no checkpoint exists yet (repeat runs reuse it)
    let ckpt_path = Checkpoint::path_for(&ws.ckpt_dir, &spec.config, "");
    let train = if ckpt_path.exists() {
        sink.emit(&Event::Message {
            text: format!("[e2e {}] using existing checkpoint {ckpt_path:?}", spec.config),
        });
        None
    } else {
        let mut tspec = TrainSpec::new(&spec.config);
        tspec.steps = spec.steps;
        Some(run_train(ws, &tspec, sink)?)
    };
    let sweep = SweepSpec::new(&spec.config)
        .dense(true)
        .variant(PruneSpec::magnitude(0.5))
        .variant(PruneSpec::sparsegpt(0.5))
        .variant(PruneSpec::sparsegpt_nm(2, 4))
        .zeroshot(50)
        .save(true); // e2e has always left compressed checkpoints behind
    let sweep = run_sweep(ws, &sweep, sink)?;
    Ok(E2eReport { train, sweep })
}

/// `serve`: obtain a packed sparse model (pre-packed `.spkt`, or
/// prune → pack — with the zero-setup fallbacks of the prune job), then
/// drain a synthetic continuous-batching decode workload through the
/// sparse kernels, narrating the request lifecycle on the event stream.
fn run_serve(ws: &Workspace, spec: &ServeSpec, sink: &mut dyn EventSink) -> Result<ServeReport> {
    let cfg = ws.config(&spec.config)?;
    // one registry for the whole run: prune/pack spans, engine counters,
    // net traffic — every sink (stats frame, snapshot events, Prometheus
    // dump, report) reads the same atomics. The mock clock (1ms per read)
    // makes every timing deterministic for the golden tests.
    let obs = if spec.mock_clock { Obs::new(Clock::mock(1_000_000)) } else { Obs::default() };
    let policy = PackPolicy::with_format(spec.format);
    let (store, label, packed_to) = match &spec.store {
        Some(path) => {
            let store = SparseStore::load(path)?;
            sink.emit(&Event::Message {
                text: format!(
                    "[serve {}] packed checkpoint {path:?}: {} (density {:.3}, from {})",
                    spec.config,
                    store.format_summary(),
                    store.density(),
                    store.source_label
                ),
            });
            let label = store.source_label.clone();
            (store, label, None)
        }
        None => {
            let (params, initialized) = load_params_or_init(ws, &spec.config, &spec.ckpt, sink)?;
            let opts = PruneOptions {
                method: spec.prune.method.clone(),
                damp: spec.damp,
                skip: SkipSpec::None,
                record_errors: false,
                exact_rows: None,
            };
            let chunks = calib_for(ws, &cfg, spec.calib, spec.calib_seed, initialized, sink)?;
            let pr = {
                let _span = obs.span(Phase::Solve);
                prune_params(ws, &spec.config, params, &chunks, &opts, sink)?
            };
            match &spec.save_store {
                Some(path) => {
                    let store = {
                        let _span = obs.span(Phase::Pack);
                        pack_to(&pr.params, &pr.label, &policy, path, sink)?
                    };
                    (store, pr.label, Some(path.clone()))
                }
                None => {
                    let store = {
                        let _span = obs.span(Phase::Pack);
                        SparseStore::pack(&pr.params, &policy, &pr.label)?
                    };
                    sink.emit(&Event::Message {
                        text: format!(
                            "[serve {}] packed in-memory: {} (density {:.3}, {:.2} bits/weight)",
                            spec.config,
                            store.format_summary(),
                            store.density(),
                            store.effective_bits()
                        ),
                    });
                    (store, pr.label, None)
                }
            }
        }
    };
    let model = SparseModel::from_store(&store, &cfg)?;

    let opts = EngineOptions {
        policy: SchedulerPolicy {
            max_batch: spec.max_batch.max(1),
            max_wait: spec.max_wait,
            queue_cap: spec.queue_cap.max(1),
            max_prefill_tokens: spec.max_prefill_tokens,
        },
        temperature: spec.temperature,
        top_k: spec.top_k,
        kv_cache: spec.kv_cache,
        prefill_chunk: spec.prefill_chunk,
        cache_budget_bytes: spec.cache_budget_mb as u64 * 1024 * 1024,
        workers: spec.workers,
        snap_every: spec.snap_every,
        replica: 0,
    };
    // every engine event also refreshes the dropped-event counter from the
    // sink, so a dying JSONL pipe shows up in the very stream that survives
    let metrics = obs.metrics();
    // named fleet variants: validated up front (duplicate/empty names),
    // loaded lazily at first routed request
    let fleet = if spec.models.is_empty() {
        None
    } else {
        Some(ModelFleet::new(
            &cfg,
            &spec.models,
            spec.model_cache_mb as u64 * 1024 * 1024,
        )?)
    };
    let mut listen_addr = None;
    let outcome = match &spec.listen {
        Some(addr) => {
            // network front door: requests come in over TCP; the run drains
            // when a client sends a `shutdown` frame
            let mut net_opts = NetServerOptions::new(spec.config.clone(), cfg.vocab);
            net_opts.obs = Some(obs.clone());
            let srv = NetServer::bind(addr, net_opts)?;
            let bound = srv.local_addr().to_string();
            sink.emit(&Event::ServeListening { addr: bound.clone() });
            if let Some(path) = &spec.addr_file {
                std::fs::write(path, format!("{bound}\n"))
                    .with_context(|| format!("writing listen address to {path:?}"))?;
            }
            listen_addr = Some(bound);
            srv.serve_router(&model, opts, spec.replicas, fleet, &mut |ev| {
                sink.emit(&serve_event_to_event(ev));
                metrics.events_dropped_total.set_at_least(sink.dropped_count());
            })?
        }
        None => {
            // synthetic workload: seeded prompts, staggered arrivals, plus
            // the spec's scripted cancels ((id, step) -> source's (step, id))
            let mut rng = Rng::new(spec.seed ^ 0x5e21e5);
            // with a fleet, synthetic requests round-robin across the
            // default model and every named variant — no fleet means every
            // request keeps `model: None` and the stream is unchanged
            let routes: Vec<Option<String>> = std::iter::once(None)
                .chain(spec.models.iter().map(|(name, _)| Some(name.clone())))
                .collect();
            let mut incoming = Vec::with_capacity(spec.requests);
            for i in 0..spec.requests {
                let prompt: Vec<i32> =
                    (0..spec.prompt_len.max(1)).map(|_| rng.below(cfg.vocab) as i32).collect();
                incoming.push((
                    i * spec.arrival_every,
                    ServeRequest {
                        id: i as u64,
                        prompt,
                        max_new_tokens: spec.max_new_tokens.max(1),
                        seed: spec.seed ^ (i as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15),
                        model: routes[i % routes.len()].clone(),
                    },
                ));
            }
            let cancels = spec.cancel.iter().map(|&(id, step)| (step, id)).collect();
            let mut source = SyntheticSource::new(incoming, cancels);
            let mut on_event = |ev: &ServeEvent| {
                sink.emit(&serve_event_to_event(ev));
                metrics.events_dropped_total.set_at_least(sink.dropped_count());
            };
            if spec.replicas > 1 {
                // admission router: the synthetic intake fans out across N
                // replica engines; the report reads the aggregated outcome
                let mut router =
                    Router::new(&model, opts, spec.replicas).with_obs(obs.clone());
                if let Some(f) = fleet {
                    router = router.with_fleet(f);
                }
                router.run_source(&mut source, &mut on_event)?.total
            } else {
                let mut engine = ServeEngine::new(&model, opts).with_obs(obs.clone());
                if let Some(f) = fleet {
                    engine = engine.with_fleet(f);
                }
                engine.run_source(&mut source, &mut on_event)?
            }
        }
    };

    let mut requests: Vec<ServeRequestRow> = outcome
        .finished
        .iter()
        .map(|f| ServeRequestRow {
            id: f.id,
            prompt_tokens: f.prompt_tokens,
            tokens: f.tokens.clone(),
            joined_step: f.joined_step,
            finished_step: f.finished_step,
            ttft_secs: f.ttft_secs,
            gap_p50_secs: f.gap_p50_secs,
            gap_p95_secs: f.gap_p95_secs,
        })
        .collect();
    requests.sort_by_key(|r| r.id);
    let mut ttfts: Vec<f64> = requests.iter().map(|r| r.ttft_secs).collect();
    ttfts.sort_by(|a, b| a.total_cmp(b));
    // one post-run snapshot feeds both the Prometheus dump and the report
    let snap = obs.snapshot();
    if let Some(path) = &spec.metrics_file {
        std::fs::write(path, snap.to_prometheus())
            .with_context(|| format!("writing Prometheus metrics to {path:?}"))?;
    }
    Ok(ServeReport {
        config: spec.config.clone(),
        label,
        formats: model.format_summary().to_string(),
        density: model.density(),
        effective_bits: model.effective_bits(),
        kv_cache: spec.kv_cache,
        steps: outcome.steps,
        tokens: outcome.tokens,
        decode_secs: outcome.decode_secs,
        tokens_per_sec: outcome.tokens_per_sec(),
        prefill_secs: outcome.prefill_secs,
        prefill_tokens: outcome.prefill_tokens,
        cache_evictions: outcome.cache_evictions,
        peak_cache_bytes: outcome.peak_cache_bytes,
        cancelled: outcome.cancelled,
        rejected: outcome.rejected,
        ttft_p50_secs: percentile_sorted(&ttfts, 0.5),
        ttft_p95_secs: percentile_sorted(&ttfts, 0.95),
        listen: listen_addr,
        requests,
        packed_to,
        metrics: snap.to_json(),
    })
}

/// Map the engine's serve-side events onto the session event stream.
fn serve_event_to_event(ev: &ServeEvent) -> Event {
    match ev {
        ServeEvent::Enqueued { id, step, prompt_tokens, max_new_tokens, replica } => {
            Event::RequestEnqueued {
                id: *id,
                step: *step,
                prompt_tokens: *prompt_tokens,
                max_new_tokens: *max_new_tokens,
                replica: *replica,
            }
        }
        ServeEvent::BatchFormed { step, joined, batch, replica } => {
            Event::BatchFormed { step: *step, joined: *joined, batch: *batch, replica: *replica }
        }
        ServeEvent::PrefillStarted { id, step, prompt_tokens, chunks, replica } => {
            Event::PrefillStarted {
                id: *id,
                step: *step,
                prompt_tokens: *prompt_tokens,
                chunks: *chunks,
                replica: *replica,
            }
        }
        ServeEvent::CacheEvicted { id, step, evicted, replica } => {
            Event::CacheEvicted { id: *id, step: *step, evicted: *evicted, replica: *replica }
        }
        ServeEvent::Finished { id, step, tokens, replica } => {
            Event::RequestFinished { id: *id, step: *step, tokens: *tokens, replica: *replica }
        }
        ServeEvent::Cancelled { id, step, tokens, replica } => {
            Event::RequestCancelled { id: *id, step: *step, tokens: *tokens, replica: *replica }
        }
        ServeEvent::Rejected { id, step, queue, cap } => {
            Event::RequestRejected { id: *id, step: *step, queue: *queue, cap: *cap }
        }
        ServeEvent::ModelLoaded { name, step, bytes, mapped } => Event::ModelLoaded {
            name: name.clone(),
            step: *step,
            bytes: *bytes,
            mapped: *mapped,
        },
        ServeEvent::ModelEvicted { name, step, bytes } => {
            Event::ModelEvicted { name: name.clone(), step: *step, bytes: *bytes }
        }
        ServeEvent::Drained {
            steps,
            requests,
            tokens,
            decode_secs,
            cancelled,
            cache_bytes_in_use,
            replica,
        } => Event::EngineDrained {
            steps: *steps,
            requests: *requests,
            tokens: *tokens,
            tokens_per_sec: if *decode_secs > 0.0 { *tokens as f64 / *decode_secs } else { 0.0 },
            cancelled: *cancelled,
            cache_bytes_in_use: *cache_bytes_in_use,
            replica: *replica,
        },
        ServeEvent::MetricsSnapshot { snapshot } => {
            Event::MetricsSnapshot { snapshot: snapshot.clone() }
        }
    }
}
