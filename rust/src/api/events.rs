//! The structured event stream: every job narrates its progress as typed
//! events, and an [`EventSink`] decides how they surface — classic human
//! log lines ([`HumanSink`]) or machine-readable JSON lines
//! ([`JsonlSink`], one object per line with a `reason` discriminator, in
//! the spirit of cargo's `--message-format=json`).

use std::collections::BTreeMap;
use std::io::Write;

use crate::eval::report::fmt_ppl;
use crate::util::json::Json;

/// One progress or result notification from a running job.
#[derive(Clone, Debug, PartialEq)]
pub enum Event {
    /// a job began executing
    JobStarted {
        job: String,
        label: String,
        config: Option<String>,
    },
    /// free-form narrative (what used to be a `println!`)
    Message { text: String },
    /// a logged training step
    TrainStep {
        step: u64,
        loss: f64,
        lr: f64,
        secs_per_step: f64,
    },
    /// a checkpoint was written
    CheckpointSaved { path: String },
    /// one transformer block finished compressing + propagating
    BlockCompressed {
        layer: usize,
        layers: usize,
        sparsity: f64,
        secs: f64,
    },
    /// one weight matrix was compressed (or skipped by policy)
    MatrixReport {
        layer: usize,
        kind: String,
        sparsity: f64,
        skipped: bool,
        solver_secs: f64,
        sq_error: Option<f64>,
    },
    /// perplexity on one eval corpus
    EvalResult {
        dataset: String,
        ppl: f64,
        tokens: usize,
    },
    /// accuracy on one zero-shot task
    ZeroShotResult { task: String, accuracy: f64 },
    /// a sweep moved on to its next variant
    SweepVariant {
        index: usize,
        total: usize,
        label: String,
    },
    /// a packed sparse checkpoint (`.spkt`) was written
    CheckpointPacked {
        path: String,
        bytes: u64,
        density: f64,
        /// "csr:10 dense:2"-style per-format matrix counts
        formats: String,
        /// storage bits per packed weight (Fig.-6 accounting; 32.0 = f32)
        effective_bits: f64,
    },
    /// a serve request entered the bounded queue
    RequestEnqueued {
        id: u64,
        step: usize,
        prompt_tokens: usize,
        max_new_tokens: usize,
        /// router replica that owns the request (0 for a bare engine)
        replica: usize,
    },
    /// queued requests joined the decode batch
    BatchFormed {
        step: usize,
        joined: usize,
        batch: usize,
        replica: usize,
    },
    /// a joiner's chunked prefill began populating its KV cache
    PrefillStarted {
        id: u64,
        step: usize,
        prompt_tokens: usize,
        chunks: usize,
        replica: usize,
    },
    /// a request's KV ring buffer evicted positions this step
    CacheEvicted {
        id: u64,
        step: usize,
        evicted: usize,
        replica: usize,
    },
    /// a serve request finished (token budget reached) and retired
    RequestFinished {
        id: u64,
        step: usize,
        tokens: usize,
        replica: usize,
    },
    /// a serve request's client went away (disconnect or cancel frame);
    /// the request retired early with `tokens` already generated
    RequestCancelled {
        id: u64,
        step: usize,
        tokens: usize,
        replica: usize,
    },
    /// a serve submission was shed because the bounded queue was full
    /// (429 semantics — never blocks the decode loop)
    RequestRejected {
        id: u64,
        step: usize,
        queue: usize,
        cap: usize,
    },
    /// a fleet model variant became resident (lazy mmap-backed load at
    /// admission); `mapped` of its `bytes` are served from mapped pages
    ModelLoaded {
        name: String,
        step: usize,
        bytes: u64,
        mapped: u64,
    },
    /// a fleet model variant was dropped — by the LRU weight-residency
    /// budget or by the drain
    ModelEvicted {
        name: String,
        step: usize,
        bytes: u64,
    },
    /// the serve TCP front door is accepting connections on `addr`
    ServeListening { addr: String },
    /// the serve engine drained its workload (one event per replica in a
    /// multi-replica run)
    EngineDrained {
        steps: usize,
        requests: usize,
        tokens: usize,
        tokens_per_sec: f64,
        cancelled: usize,
        /// cache bytes still reserved after the drain — pinned at 0 so a
        /// leaked reservation (e.g. a disconnect that skipped its
        /// release) is visible in the event stream and greppable in CI
        cache_bytes_in_use: u64,
        replica: usize,
    },
    /// a point-in-time metrics snapshot from the serve-path [`Obs`]
    /// registry (periodic `snap_every` ticks plus one at drain); the
    /// payload is the snapshot object itself, flattened into the event
    ///
    /// [`Obs`]: crate::obs::Obs
    MetricsSnapshot { snapshot: Json },
    /// the job finished (ok or failed)
    JobFinished { job: String, ok: bool, secs: f64 },
}

fn obj(fields: Vec<(&str, Json)>) -> Json {
    let mut m = BTreeMap::new();
    for (k, v) in fields {
        m.insert(k.to_string(), v);
    }
    Json::Obj(m)
}

fn s(v: &str) -> Json {
    Json::Str(v.to_string())
}

fn n(v: f64) -> Json {
    Json::Num(v)
}

impl Event {
    /// Build a `matrix-report` event from the coordinator's report.
    pub fn matrix(r: &crate::coordinator::MatrixReport) -> Event {
        Event::MatrixReport {
            layer: r.layer,
            kind: r.kind.label().to_string(),
            sparsity: r.sparsity,
            skipped: r.skipped,
            solver_secs: r.solver_secs,
            sq_error: r.sq_error,
        }
    }

    /// The machine-readable discriminator (the `reason` field).
    pub fn reason(&self) -> &'static str {
        match self {
            Event::JobStarted { .. } => "job-started",
            Event::Message { .. } => "message",
            Event::TrainStep { .. } => "train-step",
            Event::CheckpointSaved { .. } => "checkpoint-saved",
            Event::BlockCompressed { .. } => "block-compressed",
            Event::MatrixReport { .. } => "matrix-report",
            Event::EvalResult { .. } => "eval-result",
            Event::ZeroShotResult { .. } => "zeroshot-result",
            Event::SweepVariant { .. } => "sweep-variant",
            Event::CheckpointPacked { .. } => "checkpoint-packed",
            Event::RequestEnqueued { .. } => "request-enqueued",
            Event::BatchFormed { .. } => "batch-formed",
            Event::PrefillStarted { .. } => "prefill-started",
            Event::CacheEvicted { .. } => "cache-evicted",
            Event::RequestFinished { .. } => "request-finished",
            Event::RequestCancelled { .. } => "request-cancelled",
            Event::RequestRejected { .. } => "request-rejected",
            Event::ModelLoaded { .. } => "model-loaded",
            Event::ModelEvicted { .. } => "model-evicted",
            Event::ServeListening { .. } => "serve-listening",
            Event::EngineDrained { .. } => "engine-drained",
            Event::MetricsSnapshot { .. } => "metrics-snapshot",
            Event::JobFinished { .. } => "job-finished",
        }
    }

    /// Serialize as a JSON object; every event carries `reason`.
    pub fn to_json(&self) -> Json {
        let reason = ("reason", s(self.reason()));
        match self {
            Event::JobStarted { job, label, config } => obj(vec![
                reason,
                ("job", s(job)),
                ("label", s(label)),
                (
                    "config",
                    config.as_ref().map(|c| s(c)).unwrap_or(Json::Null),
                ),
            ]),
            Event::Message { text } => obj(vec![reason, ("text", s(text))]),
            Event::TrainStep { step, loss, lr, secs_per_step } => obj(vec![
                reason,
                ("step", n(*step as f64)),
                ("loss", n(*loss)),
                ("lr", n(*lr)),
                ("secs_per_step", n(*secs_per_step)),
            ]),
            Event::CheckpointSaved { path } => obj(vec![reason, ("path", s(path))]),
            Event::BlockCompressed { layer, layers, sparsity, secs } => obj(vec![
                reason,
                ("layer", n(*layer as f64)),
                ("layers", n(*layers as f64)),
                ("sparsity", n(*sparsity)),
                ("secs", n(*secs)),
            ]),
            Event::MatrixReport { layer, kind, sparsity, skipped, solver_secs, sq_error } => {
                obj(vec![
                    reason,
                    ("layer", n(*layer as f64)),
                    ("kind", s(kind)),
                    ("sparsity", n(*sparsity)),
                    ("skipped", Json::Bool(*skipped)),
                    ("solver_secs", n(*solver_secs)),
                    ("sq_error", sq_error.map(Json::Num).unwrap_or(Json::Null)),
                ])
            }
            Event::EvalResult { dataset, ppl, tokens } => obj(vec![
                reason,
                ("dataset", s(dataset)),
                ("ppl", n(*ppl)),
                ("tokens", n(*tokens as f64)),
            ]),
            Event::ZeroShotResult { task, accuracy } => {
                obj(vec![reason, ("task", s(task)), ("accuracy", n(*accuracy))])
            }
            Event::SweepVariant { index, total, label } => obj(vec![
                reason,
                ("index", n(*index as f64)),
                ("total", n(*total as f64)),
                ("label", s(label)),
            ]),
            Event::CheckpointPacked { path, bytes, density, formats, effective_bits } => {
                obj(vec![
                    reason,
                    ("path", s(path)),
                    ("bytes", n(*bytes as f64)),
                    ("density", n(*density)),
                    ("formats", s(formats)),
                    ("effective_bits", n(*effective_bits)),
                ])
            }
            Event::RequestEnqueued { id, step, prompt_tokens, max_new_tokens, replica } => {
                obj(vec![
                    reason,
                    ("id", n(*id as f64)),
                    ("step", n(*step as f64)),
                    ("prompt_tokens", n(*prompt_tokens as f64)),
                    ("max_new_tokens", n(*max_new_tokens as f64)),
                    ("replica", n(*replica as f64)),
                ])
            }
            Event::BatchFormed { step, joined, batch, replica } => obj(vec![
                reason,
                ("step", n(*step as f64)),
                ("joined", n(*joined as f64)),
                ("batch", n(*batch as f64)),
                ("replica", n(*replica as f64)),
            ]),
            Event::PrefillStarted { id, step, prompt_tokens, chunks, replica } => obj(vec![
                reason,
                ("id", n(*id as f64)),
                ("step", n(*step as f64)),
                ("prompt_tokens", n(*prompt_tokens as f64)),
                ("chunks", n(*chunks as f64)),
                ("replica", n(*replica as f64)),
            ]),
            Event::CacheEvicted { id, step, evicted, replica } => obj(vec![
                reason,
                ("id", n(*id as f64)),
                ("step", n(*step as f64)),
                ("evicted", n(*evicted as f64)),
                ("replica", n(*replica as f64)),
            ]),
            Event::RequestFinished { id, step, tokens, replica } => obj(vec![
                reason,
                ("id", n(*id as f64)),
                ("step", n(*step as f64)),
                ("tokens", n(*tokens as f64)),
                ("replica", n(*replica as f64)),
            ]),
            Event::RequestCancelled { id, step, tokens, replica } => obj(vec![
                reason,
                ("id", n(*id as f64)),
                ("step", n(*step as f64)),
                ("tokens", n(*tokens as f64)),
                ("replica", n(*replica as f64)),
            ]),
            Event::RequestRejected { id, step, queue, cap } => obj(vec![
                reason,
                ("id", n(*id as f64)),
                ("step", n(*step as f64)),
                ("queue", n(*queue as f64)),
                ("cap", n(*cap as f64)),
            ]),
            Event::ModelLoaded { name, step, bytes, mapped } => obj(vec![
                reason,
                ("name", s(name)),
                ("step", n(*step as f64)),
                ("bytes", n(*bytes as f64)),
                ("mapped", n(*mapped as f64)),
            ]),
            Event::ModelEvicted { name, step, bytes } => obj(vec![
                reason,
                ("name", s(name)),
                ("step", n(*step as f64)),
                ("bytes", n(*bytes as f64)),
            ]),
            Event::ServeListening { addr } => obj(vec![reason, ("addr", s(addr))]),
            Event::EngineDrained {
                steps,
                requests,
                tokens,
                tokens_per_sec,
                cancelled,
                cache_bytes_in_use,
                replica,
            } => obj(vec![
                reason,
                ("steps", n(*steps as f64)),
                ("requests", n(*requests as f64)),
                ("tokens", n(*tokens as f64)),
                ("tokens_per_sec", n(*tokens_per_sec)),
                ("cancelled", n(*cancelled as f64)),
                ("cache_bytes_in_use", n(*cache_bytes_in_use as f64)),
                ("replica", n(*replica as f64)),
            ]),
            Event::MetricsSnapshot { snapshot } => {
                // flatten: the snapshot object IS the event, plus `reason`
                let mut m = match snapshot {
                    Json::Obj(m) => m.clone(),
                    other => BTreeMap::from([("snapshot".to_string(), other.clone())]),
                };
                m.insert("reason".to_string(), s(self.reason()));
                Json::Obj(m)
            }
            Event::JobFinished { job, ok, secs } => obj(vec![
                reason,
                ("job", s(job)),
                ("ok", Json::Bool(*ok)),
                ("secs", n(*secs)),
            ]),
        }
    }
}

/// Where a job's events go.
pub trait EventSink {
    fn emit(&mut self, ev: &Event);

    /// How many events this sink failed to deliver. Advisory streams
    /// swallow write errors rather than abort the job; this makes the
    /// loss countable (surfaced as the `events_dropped_total` metric).
    fn dropped_count(&self) -> u64 {
        0
    }
}

/// Classic terminal log lines (what the CLI printed before the event
/// stream existed). Progress lines are tagged by the *phase* the event
/// belongs to ("train"/"prune"/"eval"/...), not the outer job kind, so
/// nested jobs (e2e's train, a sweep's prunes) label like they always
/// did. Per-matrix reports are intentionally quiet.
#[derive(Default)]
pub struct HumanSink {
    config: String,
}

impl HumanSink {
    pub fn new() -> HumanSink {
        HumanSink::default()
    }

    /// "phase config" or just "phase" when the job has no config.
    fn tag(&self, phase: &str) -> String {
        if self.config.is_empty() {
            phase.to_string()
        } else {
            format!("{phase} {}", self.config)
        }
    }
}

impl EventSink for HumanSink {
    fn emit(&mut self, ev: &Event) {
        match ev {
            Event::JobStarted { config, .. } => {
                self.config = config.clone().unwrap_or_default();
            }
            Event::Message { text } => println!("{text}"),
            Event::TrainStep { step, loss, lr, secs_per_step } => println!(
                "[{}] step {step} loss {loss:.4} lr {lr:.2e} ({secs_per_step:.2} s/step)",
                self.tag("train")
            ),
            Event::CheckpointSaved { path } => {
                println!("[{}] checkpoint -> {path}", self.tag("ckpt"))
            }
            Event::BlockCompressed { layer, layers, sparsity, secs } => println!(
                "[{}] block {}/{layers} sparsity {sparsity:.3} ({secs:.1}s)",
                self.tag("prune"),
                *layer + 1
            ),
            Event::MatrixReport { .. } => {}
            Event::EvalResult { dataset, ppl, tokens } => println!(
                "[{}] {dataset}: ppl {} ({tokens} tokens)",
                self.tag("eval"),
                fmt_ppl(*ppl)
            ),
            Event::ZeroShotResult { task, accuracy } => {
                println!("[{}] {task}: {:.1}%", self.tag("zeroshot"), *accuracy * 100.0)
            }
            Event::SweepVariant { index, total, label } => {
                println!("[{}] variant {}/{total}: {label}", self.tag("sweep"), *index + 1)
            }
            Event::CheckpointPacked { path, bytes, density, formats, effective_bits } => {
                println!(
                    "[{}] packed -> {path} ({bytes} bytes, density {density:.3}, {formats}, \
                     {effective_bits:.2} bits/weight)",
                    self.tag("pack")
                )
            }
            Event::RequestEnqueued { id, step, prompt_tokens, max_new_tokens, .. } => println!(
                "[{}] step {step}: request {id} enqueued ({prompt_tokens} prompt, \
                 {max_new_tokens} max tokens)",
                self.tag("serve")
            ),
            Event::BatchFormed { step, joined, batch, .. } => println!(
                "[{}] step {step}: +{joined} joined, batch {batch}",
                self.tag("serve")
            ),
            Event::PrefillStarted { id, step, prompt_tokens, chunks, .. } => println!(
                "[{}] step {step}: request {id} prefilling {prompt_tokens} tokens \
                 in {chunks} chunks",
                self.tag("serve")
            ),
            Event::CacheEvicted { id, step, evicted, .. } => println!(
                "[{}] step {step}: request {id} evicted {evicted} cached positions",
                self.tag("serve")
            ),
            Event::RequestFinished { id, step, tokens, .. } => println!(
                "[{}] step {step}: request {id} finished ({tokens} tokens)",
                self.tag("serve")
            ),
            Event::RequestCancelled { id, step, tokens, .. } => println!(
                "[{}] step {step}: request {id} cancelled by its client \
                 ({tokens} tokens streamed)",
                self.tag("serve")
            ),
            Event::RequestRejected { id, step, queue, cap } => println!(
                "[{}] step {step}: request {id} rejected (queue full, {queue} of {cap})",
                self.tag("serve")
            ),
            Event::ModelLoaded { name, step, bytes, mapped } => println!(
                "[{}] step {step}: model {name:?} loaded ({bytes} weight bytes, \
                 {mapped} mapped)",
                self.tag("serve")
            ),
            Event::ModelEvicted { name, step, bytes } => println!(
                "[{}] step {step}: model {name:?} evicted ({bytes} weight bytes freed)",
                self.tag("serve")
            ),
            Event::ServeListening { addr } => {
                println!("[{}] listening on {addr}", self.tag("serve"))
            }
            Event::EngineDrained {
                steps,
                requests,
                tokens,
                tokens_per_sec,
                cancelled,
                cache_bytes_in_use,
                ..
            } => println!(
                "[{}] drained: {requests} requests (+{cancelled} cancelled), {tokens} tokens \
                 in {steps} steps ({tokens_per_sec:.1} tok/s, {cache_bytes_in_use} cache bytes \
                 still reserved)",
                self.tag("serve")
            ),
            // machine-shaped payload — JSONL consumers want it, humans don't
            Event::MetricsSnapshot { .. } => {}
            Event::JobFinished { .. } => {}
        }
    }
}

/// Machine-readable JSON lines: one compact object per event, each with a
/// `reason` field. Write errors are deliberately swallowed — the event
/// stream is advisory and must never abort the job it narrates — but each
/// swallowed event is counted in [`EventSink::dropped_count`].
pub struct JsonlSink<W: Write> {
    out: W,
    dropped: u64,
}

impl JsonlSink<std::io::Stdout> {
    pub fn stdout() -> JsonlSink<std::io::Stdout> {
        JsonlSink { out: std::io::stdout(), dropped: 0 }
    }
}

impl<W: Write> JsonlSink<W> {
    pub fn new(out: W) -> JsonlSink<W> {
        JsonlSink { out, dropped: 0 }
    }

    pub fn into_inner(self) -> W {
        self.out
    }
}

impl<W: Write> EventSink for JsonlSink<W> {
    fn emit(&mut self, ev: &Event) {
        let wrote = writeln!(self.out, "{}", ev.to_json().to_string_compact())
            .and_then(|()| self.out.flush());
        if wrote.is_err() {
            self.dropped += 1;
        }
    }

    fn dropped_count(&self) -> u64 {
        self.dropped
    }
}

/// Collects events in memory (tests, programmatic consumers).
#[derive(Default)]
pub struct MemorySink {
    pub events: Vec<Event>,
}

impl MemorySink {
    pub fn new() -> MemorySink {
        MemorySink::default()
    }
}

impl EventSink for MemorySink {
    fn emit(&mut self, ev: &Event) {
        self.events.push(ev.clone());
    }
}

/// Discards everything.
pub struct NullSink;

impl EventSink for NullSink {
    fn emit(&mut self, _ev: &Event) {}
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_events() -> Vec<Event> {
        vec![
            Event::JobStarted { job: "prune".into(), label: "prune/nano/sparsegpt-50%".into(), config: Some("nano".into()) },
            Event::Message { text: "hello".into() },
            Event::TrainStep { step: 1, loss: 5.0, lr: 0.001, secs_per_step: 0.5 },
            Event::CheckpointSaved { path: "c.ckpt".into() },
            Event::BlockCompressed { layer: 0, layers: 2, sparsity: 0.5, secs: 1.0 },
            Event::MatrixReport { layer: 0, kind: "q".into(), sparsity: 0.5, skipped: false, solver_secs: 0.1, sq_error: None },
            Event::EvalResult { dataset: "synth-wiki".into(), ppl: 12.5, tokens: 64 },
            Event::ZeroShotResult { task: "cloze".into(), accuracy: 0.5 },
            Event::SweepVariant { index: 0, total: 1, label: "sparsegpt-50%".into() },
            Event::CheckpointPacked {
                path: "c.spkt".into(),
                bytes: 1024,
                density: 0.5,
                formats: "qcsr:12".into(),
                effective_bits: 3.0,
            },
            Event::RequestEnqueued { id: 0, step: 0, prompt_tokens: 8, max_new_tokens: 16, replica: 0 },
            Event::BatchFormed { step: 1, joined: 2, batch: 2, replica: 0 },
            Event::PrefillStarted { id: 0, step: 1, prompt_tokens: 8, chunks: 1, replica: 1 },
            Event::CacheEvicted { id: 0, step: 5, evicted: 1, replica: 0 },
            Event::RequestFinished { id: 0, step: 17, tokens: 16, replica: 1 },
            Event::RequestCancelled { id: 1, step: 9, tokens: 4, replica: 0 },
            Event::RequestRejected { id: 2, step: 9, queue: 64, cap: 64 },
            Event::ModelLoaded { name: "q4".into(), step: 3, bytes: 4096, mapped: 4096 },
            Event::ModelEvicted { name: "q4".into(), step: 18, bytes: 4096 },
            Event::ServeListening { addr: "127.0.0.1:7070".into() },
            Event::EngineDrained {
                steps: 20,
                requests: 2,
                tokens: 32,
                tokens_per_sec: 64.0,
                cancelled: 1,
                cache_bytes_in_use: 0,
                replica: 0,
            },
            Event::MetricsSnapshot {
                snapshot: Json::parse(r#"{"generation":1,"tokens_decoded_total":8}"#).unwrap(),
            },
            Event::JobFinished { job: "prune".into(), ok: true, secs: 2.0 },
        ]
    }

    #[test]
    fn every_event_serializes_with_reason() {
        for ev in sample_events() {
            let v = ev.to_json();
            assert_eq!(v.get("reason").unwrap().as_str().unwrap(), ev.reason());
            let line = v.to_string_compact();
            assert!(!line.contains('\n'));
            assert_eq!(Json::parse(&line).unwrap(), v);
        }
    }

    #[test]
    fn jsonl_sink_writes_one_line_per_event() {
        let mut sink = JsonlSink::new(Vec::new());
        for ev in sample_events() {
            sink.emit(&ev);
        }
        let text = String::from_utf8(sink.into_inner()).unwrap();
        assert_eq!(text.lines().count(), sample_events().len());
        for line in text.lines() {
            let v = Json::parse(line).unwrap();
            assert!(v.get("reason").unwrap().as_str().is_ok());
        }
    }

    #[test]
    fn memory_sink_collects() {
        let mut sink = MemorySink::new();
        sink.emit(&Event::Message { text: "x".into() });
        assert_eq!(sink.events.len(), 1);
    }

    /// Every write fails — the disk-full / broken-pipe stand-in.
    struct FailWriter;

    impl Write for FailWriter {
        fn write(&mut self, _buf: &[u8]) -> std::io::Result<usize> {
            Err(std::io::Error::other("disk full"))
        }

        fn flush(&mut self) -> std::io::Result<()> {
            Ok(())
        }
    }

    #[test]
    fn jsonl_sink_counts_dropped_events_instead_of_aborting() {
        let mut sink = JsonlSink::new(FailWriter);
        assert_eq!(sink.dropped_count(), 0);
        sink.emit(&Event::Message { text: "x".into() });
        sink.emit(&Event::Message { text: "y".into() });
        assert_eq!(sink.dropped_count(), 2, "each failed write counts once");
        // a healthy sink never counts drops
        let mut ok = JsonlSink::new(Vec::new());
        ok.emit(&Event::Message { text: "z".into() });
        assert_eq!(ok.dropped_count(), 0);
    }
}
