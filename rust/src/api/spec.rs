//! Typed job specifications: everything a run needs, as data.
//!
//! `JobSpec` is the single input type of [`crate::api::Session::run`]. Every
//! spec has builder constructors with the CLI's defaults and a canonical
//! string form (`label()`), and the canonical forms parse back
//! (`parse(label()) == spec`, `parse(s).label() == s` for canonical `s`):
//!
//! ```text
//! prune spec grammar     sparsegpt-50% | sparsegpt-2:4+4bit | sparsegpt-50%-bs64
//!                        magnitude-50% | magnitude-2:4 | adaprune-50%
//! job spec grammar       <kind>[/<config>[/<prune-spec>[,<prune-spec>...]]]
//!                        e.g. prune/nano/sparsegpt-2:4+4bit
//!                             sweep/small/sparsegpt-50%,magnitude-50%
//! ```

use std::path::PathBuf;

use anyhow::{anyhow, Result};

use crate::coordinator::{PruneMethod, SkipSpec};
use crate::harness::DEFAULT_CALIB_SEGMENTS;
use crate::solver::sparsegpt_ref::Pattern;
use crate::sparse::PackFormat;

/// A compression method selection, round-trippable through its label.
#[derive(Clone, Debug, PartialEq)]
pub struct PruneSpec {
    pub method: PruneMethod,
}

fn parse_percent(s: &str) -> Option<f64> {
    let p: f64 = s.strip_suffix('%')?.parse().ok()?;
    if (0.0..=100.0).contains(&p) {
        Some(p / 100.0)
    } else {
        None
    }
}

fn parse_pattern(s: &str) -> Option<Pattern> {
    if let Some(p) = parse_percent(s) {
        return Some(Pattern::Unstructured(p));
    }
    let (n, m) = s.split_once(':')?;
    let (n, m): (usize, usize) = (n.parse().ok()?, m.parse().ok()?);
    if n > 0 && m > n {
        Some(Pattern::NM(n, m))
    } else {
        None
    }
}

impl PruneSpec {
    /// SparseGPT at unstructured sparsity `p` (0.0..1.0).
    pub fn sparsegpt(sparsity: f64) -> PruneSpec {
        PruneSpec {
            method: PruneMethod::SparseGpt {
                pattern: Pattern::Unstructured(sparsity),
                quant_bits: None,
            },
        }
    }

    /// SparseGPT with an n:m semi-structured pattern (2:4, 4:8).
    pub fn sparsegpt_nm(n: usize, m: usize) -> PruneSpec {
        PruneSpec {
            method: PruneMethod::SparseGpt { pattern: Pattern::NM(n, m), quant_bits: None },
        }
    }

    /// Magnitude-pruning baseline at unstructured sparsity `p`.
    pub fn magnitude(sparsity: f64) -> PruneSpec {
        PruneSpec { method: PruneMethod::Magnitude { pattern: Pattern::Unstructured(sparsity) } }
    }

    /// Magnitude-pruning baseline with an n:m pattern.
    pub fn magnitude_nm(n: usize, m: usize) -> PruneSpec {
        PruneSpec { method: PruneMethod::Magnitude { pattern: Pattern::NM(n, m) } }
    }

    /// AdaPrune baseline (magnitude mask + GD reconstruction).
    pub fn adaprune(sparsity: f64) -> PruneSpec {
        PruneSpec { method: PruneMethod::AdaPrune { sparsity } }
    }

    /// Enable joint quantization (Eq. 7). Only meaningful for the SparseGPT
    /// method; a no-op on the baselines, which have no quantized variant.
    pub fn with_quant_bits(mut self, bits: u32) -> PruneSpec {
        if let PruneMethod::SparseGpt { quant_bits, .. } = &mut self.method {
            *quant_bits = Some(bits);
        }
        self
    }

    /// The canonical label, identical to [`PruneMethod::label`].
    pub fn label(&self) -> String {
        self.method.label()
    }

    /// Parse a canonical label back into a spec (inverse of [`label`]).
    ///
    /// [`label`]: PruneSpec::label
    pub fn parse(s: &str) -> Result<PruneSpec> {
        let err = || {
            anyhow!(
                "unrecognized prune spec {s:?} (expected e.g. sparsegpt-50%, \
                 sparsegpt-2:4+4bit, magnitude-80%, adaprune-50%)"
            )
        };
        let (method, rest) = s.split_once('-').ok_or_else(err)?;
        match method {
            "sparsegpt" => {
                let (pat_str, quant_bits) = match rest.rsplit_once('+') {
                    Some((p, q)) => {
                        let b = q.strip_suffix("bit").ok_or_else(err)?;
                        (p, Some(b.parse::<u32>().map_err(|_| err())?))
                    }
                    None => (rest, None),
                };
                if let Some((p, bs)) = pat_str.split_once("-bs") {
                    // Fig-10 mask-blocksize ablation variant
                    if quant_bits.is_some() {
                        return Err(err());
                    }
                    let sparsity = parse_percent(p).ok_or_else(err)?;
                    let mask_blocksize = bs.parse::<usize>().map_err(|_| err())?;
                    return Ok(PruneSpec {
                        method: PruneMethod::SparseGptBs { sparsity, mask_blocksize },
                    });
                }
                let pattern = parse_pattern(pat_str).ok_or_else(err)?;
                Ok(PruneSpec { method: PruneMethod::SparseGpt { pattern, quant_bits } })
            }
            "magnitude" => {
                let pattern = parse_pattern(rest).ok_or_else(err)?;
                Ok(PruneSpec { method: PruneMethod::Magnitude { pattern } })
            }
            "adaprune" => {
                let sparsity = parse_percent(rest).ok_or_else(err)?;
                Ok(PruneSpec { method: PruneMethod::AdaPrune { sparsity } })
            }
            _ => Err(err()),
        }
    }
}

/// `gen-data`: synthesize corpora + train the BPE tokenizer.
#[derive(Clone, Debug, PartialEq)]
pub struct GenDataSpec {
    pub out: PathBuf,
    pub seed: u64,
    pub train_mb: usize,
}

impl Default for GenDataSpec {
    fn default() -> Self {
        GenDataSpec { out: "data".into(), seed: 0, train_mb: 4 }
    }
}

/// `train`: pretrain a model config through the `train_step` artifact.
#[derive(Clone, Debug, PartialEq)]
pub struct TrainSpec {
    pub config: String,
    pub steps: usize,
    pub seed: u64,
    pub log_every: usize,
    /// override the per-config default learning rate
    pub lr: Option<f64>,
    /// checkpoint directory; `None` = the workspace checkpoint dir
    pub out: Option<PathBuf>,
    pub checkpoint_every: usize,
    pub resume: bool,
}

impl TrainSpec {
    pub fn new(config: &str) -> TrainSpec {
        TrainSpec {
            config: config.to_string(),
            steps: 400,
            seed: 0,
            log_every: 20,
            lr: None,
            out: None,
            checkpoint_every: 0,
            resume: false,
        }
    }
}

/// `prune`: one-shot compress a trained model.
#[derive(Clone, Debug, PartialEq)]
pub struct PruneJobSpec {
    pub config: String,
    pub prune: PruneSpec,
    pub damp: f64,
    pub skip: SkipSpec,
    pub calib: usize,
    pub calib_seed: u64,
    /// input checkpoint; `None` = the config's trained checkpoint
    pub ckpt: Option<PathBuf>,
    pub record_errors: bool,
    /// write the compressed checkpoint (CLI sets this; library callers
    /// usually keep the params in memory instead)
    pub save: bool,
    /// output path when saving; `None` = `<ckpt-dir>/<config><suffix>.ckpt`
    pub out: Option<PathBuf>,
    /// checkpoint suffix; `None` = `-<label>`
    pub suffix: Option<String>,
    /// also write a packed sparse checkpoint (`.spkt`) for serving
    pub pack: bool,
    /// packed-checkpoint path; `None` = `<ckpt-dir>/<config>-<label>.spkt`
    pub pack_out: Option<PathBuf>,
    /// packed-checkpoint format policy (auto | dense | csr | n:m |
    /// q{dense,csr,nm}:<bits>[,g=<cols>])
    pub pack_format: PackFormat,
}

impl PruneJobSpec {
    pub fn new(config: &str, prune: PruneSpec) -> PruneJobSpec {
        PruneJobSpec {
            config: config.to_string(),
            prune,
            damp: 0.01,
            skip: SkipSpec::None,
            calib: DEFAULT_CALIB_SEGMENTS,
            calib_seed: 0,
            ckpt: None,
            record_errors: false,
            save: false,
            out: None,
            suffix: None,
            pack: false,
            pack_out: None,
            pack_format: PackFormat::Auto,
        }
    }
}

/// `eval`: perplexity on the three held-out corpora.
#[derive(Clone, Debug, PartialEq)]
pub struct EvalSpec {
    pub config: String,
    pub ckpt: Option<PathBuf>,
    pub max_segments: usize,
}

impl EvalSpec {
    pub fn new(config: &str) -> EvalSpec {
        EvalSpec { config: config.to_string(), ckpt: None, max_segments: 512 }
    }
}

/// `zeroshot`: the five multiple-choice tasks.
#[derive(Clone, Debug, PartialEq)]
pub struct ZeroShotSpec {
    pub config: String,
    pub ckpt: Option<PathBuf>,
    pub items: usize,
    pub seed: u64,
    pub data_seed: u64,
}

impl ZeroShotSpec {
    pub fn new(config: &str) -> ZeroShotSpec {
        ZeroShotSpec { config: config.to_string(), ckpt: None, items: 100, seed: 7, data_seed: 0 }
    }
}

/// `stats`: sparsity statistics of a checkpoint.
#[derive(Clone, Debug, PartialEq)]
pub struct StatsSpec {
    pub config: String,
    pub ckpt: Option<PathBuf>,
    pub nm: Option<(usize, usize)>,
}

impl StatsSpec {
    pub fn new(config: &str) -> StatsSpec {
        StatsSpec { config: config.to_string(), ckpt: None, nm: None }
    }
}

/// `generate`: autoregressive sampling (qualitative check).
#[derive(Clone, Debug, PartialEq)]
pub struct GenerateSpec {
    pub config: String,
    pub ckpt: Option<PathBuf>,
    pub prompt: String,
    pub tokens: usize,
    pub temperature: f64,
    pub top_k: usize,
    pub seed: u64,
}

impl GenerateSpec {
    pub fn new(config: &str) -> GenerateSpec {
        GenerateSpec {
            config: config.to_string(),
            ckpt: None,
            prompt: "the ".to_string(),
            tokens: 64,
            temperature: 0.8,
            top_k: 40,
            seed: 0,
        }
    }
}

/// `e2e`: train -> prune (3 variants) -> eval in one run.
#[derive(Clone, Debug, PartialEq)]
pub struct E2eSpec {
    pub config: String,
    pub steps: usize,
}

impl E2eSpec {
    pub fn new(config: &str) -> E2eSpec {
        E2eSpec { config: config.to_string(), steps: 300 }
    }
}

/// `sweep`: run a list of prune variants against one checkpoint with
/// *shared calibration* (the chunks are drawn once), evaluating each.
#[derive(Clone, Debug, PartialEq)]
pub struct SweepSpec {
    pub config: String,
    pub ckpt: Option<PathBuf>,
    pub variants: Vec<PruneSpec>,
    pub damp: f64,
    pub calib: usize,
    pub calib_seed: u64,
    /// eval corpora; empty = all three held-out sets
    pub datasets: Vec<String>,
    /// eval segments per corpus; 0 disables the perplexity pass
    pub max_segments: usize,
    /// also evaluate the unpruned model as a baseline row
    pub include_dense: bool,
    /// zero-shot items per task; 0 disables the zero-shot pass
    pub zeroshot_items: usize,
    pub zeroshot_seed: u64,
    pub data_seed: u64,
    /// write each variant's compressed checkpoint (`<config>-<label>.ckpt`)
    pub save: bool,
}

impl SweepSpec {
    pub fn new(config: &str) -> SweepSpec {
        SweepSpec {
            config: config.to_string(),
            ckpt: None,
            variants: Vec::new(),
            damp: 0.01,
            calib: DEFAULT_CALIB_SEGMENTS,
            calib_seed: 0,
            datasets: Vec::new(),
            max_segments: 128,
            include_dense: false,
            zeroshot_items: 0,
            zeroshot_seed: 7,
            data_seed: 0,
            save: false,
        }
    }

    pub fn variant(mut self, v: PruneSpec) -> SweepSpec {
        self.variants.push(v);
        self
    }

    pub fn variants(mut self, vs: Vec<PruneSpec>) -> SweepSpec {
        self.variants = vs;
        self
    }

    pub fn dense(mut self, include: bool) -> SweepSpec {
        self.include_dense = include;
        self
    }

    pub fn dataset(mut self, name: &str) -> SweepSpec {
        self.datasets.push(name.to_string());
        self
    }

    pub fn calib(mut self, segments: usize) -> SweepSpec {
        self.calib = segments;
        self
    }

    pub fn max_segments(mut self, segments: usize) -> SweepSpec {
        self.max_segments = segments;
        self
    }

    pub fn zeroshot(mut self, items: usize) -> SweepSpec {
        self.zeroshot_items = items;
        self
    }

    pub fn save(mut self, save: bool) -> SweepSpec {
        self.save = save;
        self
    }

    pub fn ckpt(mut self, path: PathBuf) -> SweepSpec {
        self.ckpt = Some(path);
        self
    }
}

pub use crate::serve::engine::DEFAULT_PREFILL_CHUNK;

/// `serve`: prune (or load a packed checkpoint) and run a synthetic
/// continuous-batching decode workload through the sparse kernels.
///
/// The cache and pack knobs round-trip through the job label as a comma
/// list after the prune spec (only non-default values appear):
/// `serve/<config>/<prune-spec>[,kv=off][,chunk=<n>][,cache-mb=<n>]`
/// `[,prefill=<n>][,workers=<n>][,replicas=<n>][,fmt=<pack-format>]`
/// `[,g=<cols>][,net=<addr>][,cancel=<id>@<step>[+...]][,snap=<n>][,clock=mock]`
/// `[,models=<name>@<path>[+...]][,model-cache-mb=<n>]` — `fmt` carries
/// the base pack-format label (e.g. `qcsr:4`) and `g` the quantization
/// group, kept separate so the comma-separated knob list stays flat; `net`
/// switches from the synthetic workload to the TCP front door, `cancel`
/// scripts synthetic-workload cancellations, `snap` emits periodic
/// `metrics-snapshot` events, `clock=mock` makes telemetry timing
/// deterministic, `models` registers named `.spkt` fleet variants for
/// per-request routing, and `model-cache-mb` bounds their resident weight
/// bytes (LRU eviction; 0 = unlimited).
#[derive(Clone, Debug, PartialEq)]
pub struct ServeSpec {
    pub config: String,
    /// compression applied before packing (ignored with [`ServeSpec::store`])
    pub prune: PruneSpec,
    /// packed-checkpoint format policy (auto | dense | csr | n:m)
    pub format: PackFormat,
    /// incremental KV-cached decode (the serving path); `false` selects the
    /// full re-forward reference path
    pub kv_cache: bool,
    /// prefill chunk rows (0 = the whole prompt in one chunk)
    pub prefill_chunk: usize,
    /// cache-memory budget in MiB (0 = unlimited); admission defers joins
    /// that would exceed it until retirements free caches
    pub cache_budget_mb: usize,
    /// prompt tokens admission may hand to prefill per step (0 = unlimited)
    pub max_prefill_tokens: usize,
    /// kernel worker-pool size for this engine (`workers=<n>` knob; 0 =
    /// share the process pool sized from `SPARSEGPT_THREADS` at startup)
    pub workers: usize,
    /// engine replicas behind the admission router (`replicas=<n>` knob;
    /// 1 = the bare engine). Each replica gets its own worker pool and an
    /// even split of `cache_budget_mb`, sharing read-only mapped weights
    pub replicas: usize,
    /// synthetic request count
    pub requests: usize,
    /// tokens generated per request
    pub max_new_tokens: usize,
    /// synthetic prompt length (token ids)
    pub prompt_len: usize,
    /// steps between successive synthetic arrivals (0 = all at once)
    pub arrival_every: usize,
    /// decode-batch capacity
    pub max_batch: usize,
    /// idle steps to wait for a full batch before a partial launch
    pub max_wait: usize,
    /// bounded admission-queue capacity
    pub queue_cap: usize,
    pub temperature: f64,
    pub top_k: usize,
    pub seed: u64,
    pub damp: f64,
    pub calib: usize,
    pub calib_seed: u64,
    /// dense checkpoint to prune; `None` = the config's trained checkpoint
    /// (falling back to seed-0 init on a zero-setup run)
    pub ckpt: Option<PathBuf>,
    /// serve an existing packed checkpoint instead of pruning
    pub store: Option<PathBuf>,
    /// write the packed checkpoint here after pruning
    pub save_store: Option<PathBuf>,
    /// listen for network clients on this address instead of running the
    /// synthetic workload (`net=<addr>` knob; `127.0.0.1:0` picks a port)
    pub listen: Option<String>,
    /// write the bound listen address to this file once the socket is up
    /// (CLI/script plumbing for `net=...:0`; not part of the label)
    pub addr_file: Option<PathBuf>,
    /// scripted synthetic-workload cancellations as (request id, step)
    /// pairs (`cancel=<id>@<step>[+<id>@<step>...]` knob); ignored with
    /// [`ServeSpec::listen`], where cancellation comes from disconnects
    pub cancel: Vec<(u64, usize)>,
    /// emit a `metrics-snapshot` event every n engine steps plus once at
    /// drain (`snap=<n>` knob; 0 = off)
    pub snap_every: usize,
    /// drive all telemetry timing from the deterministic mock clock
    /// (`clock=mock` knob) — each read advances exactly 1ms; golden tests
    /// pin snapshots under it
    pub mock_clock: bool,
    /// write a Prometheus text dump of the final snapshot here after the
    /// drain (CLI `--metrics-file`; not part of the label)
    pub metrics_file: Option<PathBuf>,
    /// named packed-checkpoint fleet variants served from the same process
    /// (`models=<name>@<path>[+...]` knob); requests route with `model=`,
    /// omitted = the default checkpoint
    pub models: Vec<(String, PathBuf)>,
    /// weight-residency budget for fleet variants in MiB
    /// (`model-cache-mb=<n>` knob; 0 = unlimited) — LRU eviction, the
    /// default checkpoint never counts against it
    pub model_cache_mb: usize,
}

impl ServeSpec {
    pub fn new(config: &str) -> ServeSpec {
        ServeSpec {
            config: config.to_string(),
            prune: PruneSpec::sparsegpt(0.5),
            format: PackFormat::Auto,
            kv_cache: true,
            prefill_chunk: DEFAULT_PREFILL_CHUNK,
            cache_budget_mb: 0,
            max_prefill_tokens: 0,
            workers: 0,
            replicas: 1,
            requests: 8,
            max_new_tokens: 16,
            prompt_len: 8,
            arrival_every: 1,
            max_batch: 8,
            max_wait: 2,
            queue_cap: 64,
            temperature: 0.8,
            top_k: 40,
            seed: 0,
            damp: 0.01,
            calib: 32,
            calib_seed: 0,
            ckpt: None,
            store: None,
            save_store: None,
            listen: None,
            addr_file: None,
            cancel: Vec::new(),
            snap_every: 0,
            mock_clock: false,
            metrics_file: None,
            models: Vec::new(),
            model_cache_mb: 0,
        }
    }

    pub fn prune(mut self, p: PruneSpec) -> ServeSpec {
        self.prune = p;
        self
    }

    pub fn requests(mut self, n: usize) -> ServeSpec {
        self.requests = n;
        self
    }

    pub fn tokens(mut self, n: usize) -> ServeSpec {
        self.max_new_tokens = n;
        self
    }

    pub fn kv_cache(mut self, on: bool) -> ServeSpec {
        self.kv_cache = on;
        self
    }

    pub fn cache_budget_mb(mut self, mb: usize) -> ServeSpec {
        self.cache_budget_mb = mb;
        self
    }

    /// The canonical label tail: prune spec + non-default cache/pack knobs.
    fn extra_label(&self) -> String {
        let mut parts = vec![self.prune.label()];
        if !self.kv_cache {
            parts.push("kv=off".to_string());
        }
        if self.prefill_chunk != DEFAULT_PREFILL_CHUNK {
            parts.push(format!("chunk={}", self.prefill_chunk));
        }
        if self.cache_budget_mb != 0 {
            parts.push(format!("cache-mb={}", self.cache_budget_mb));
        }
        if self.max_prefill_tokens != 0 {
            parts.push(format!("prefill={}", self.max_prefill_tokens));
        }
        if self.workers != 0 {
            parts.push(format!("workers={}", self.workers));
        }
        if self.replicas != 1 {
            parts.push(format!("replicas={}", self.replicas));
        }
        if self.format != PackFormat::Auto {
            // the group rides as its own knob so fmt's value has no comma
            match self.format.label().split_once(',') {
                Some((base, group)) => {
                    parts.push(format!("fmt={base}"));
                    parts.push(group.to_string());
                }
                None => parts.push(format!("fmt={}", self.format.label())),
            }
        }
        if let Some(addr) = &self.listen {
            parts.push(format!("net={addr}"));
        }
        if !self.cancel.is_empty() {
            let cs: Vec<String> =
                self.cancel.iter().map(|(id, step)| format!("{id}@{step}")).collect();
            parts.push(format!("cancel={}", cs.join("+")));
        }
        if self.snap_every != 0 {
            parts.push(format!("snap={}", self.snap_every));
        }
        if self.mock_clock {
            parts.push("clock=mock".to_string());
        }
        if !self.models.is_empty() {
            let ms: Vec<String> = self
                .models
                .iter()
                .map(|(name, path)| format!("{name}@{}", path.display()))
                .collect();
            parts.push(format!("models={}", ms.join("+")));
        }
        if self.model_cache_mb != 0 {
            parts.push(format!("model-cache-mb={}", self.model_cache_mb));
        }
        parts.join(",")
    }

    /// Parse the label tail produced by [`extra_label`].
    ///
    /// [`extra_label`]: ServeSpec::extra_label
    fn apply_extra(&mut self, extra: &str) -> Result<()> {
        let mut parts = extra.split(',');
        self.prune = PruneSpec::parse(parts.next().unwrap_or(""))?;
        for part in parts {
            let err = || {
                anyhow!(
                    "unrecognized serve knob {part:?} (expected kv=on|off, chunk=<n>, \
                     cache-mb=<n>, prefill=<n>, workers=<n>, replicas=<n>, \
                     fmt=<pack-format>, g=<cols>, net=<addr>, \
                     cancel=<id>@<step>[+...], snap=<n>, clock=mock|real, \
                     models=<name>@<path>[+...] or model-cache-mb=<n>)"
                )
            };
            let (key, value) = part.split_once('=').ok_or_else(err)?;
            match key {
                "kv" => {
                    self.kv_cache = match value {
                        "on" => true,
                        "off" => false,
                        _ => return Err(err()),
                    }
                }
                "chunk" => self.prefill_chunk = value.parse().map_err(|_| err())?,
                "cache-mb" => self.cache_budget_mb = value.parse().map_err(|_| err())?,
                "prefill" => self.max_prefill_tokens = value.parse().map_err(|_| err())?,
                "workers" => self.workers = value.parse().map_err(|_| err())?,
                "replicas" => {
                    self.replicas = value.parse().map_err(|_| err())?;
                    if self.replicas == 0 {
                        return Err(err());
                    }
                }
                "fmt" => self.format = PackFormat::parse(value)?,
                "g" => {
                    let g: usize = value.parse().map_err(|_| err())?;
                    self.format = self.format.with_group(g)?;
                }
                "net" => {
                    if value.is_empty() {
                        return Err(err());
                    }
                    self.listen = Some(value.to_string());
                }
                "cancel" => {
                    let mut cs = Vec::new();
                    for c in value.split('+') {
                        let (id, step) = c.split_once('@').ok_or_else(err)?;
                        cs.push((
                            id.parse::<u64>().map_err(|_| err())?,
                            step.parse::<usize>().map_err(|_| err())?,
                        ));
                    }
                    self.cancel = cs;
                }
                "snap" => self.snap_every = value.parse().map_err(|_| err())?,
                "clock" => {
                    self.mock_clock = match value {
                        "mock" => true,
                        "real" => false,
                        _ => return Err(err()),
                    }
                }
                "models" => {
                    let mut ms = Vec::new();
                    for m in value.split('+') {
                        let (name, path) = m.split_once('@').ok_or_else(err)?;
                        if name.is_empty() || path.is_empty() {
                            return Err(err());
                        }
                        ms.push((name.to_string(), PathBuf::from(path)));
                    }
                    self.models = ms;
                }
                "model-cache-mb" => self.model_cache_mb = value.parse().map_err(|_| err())?,
                _ => return Err(err()),
            }
        }
        Ok(())
    }
}

/// One job the [`crate::api::Session`] can execute.
#[derive(Clone, Debug, PartialEq)]
pub enum JobSpec {
    GenData(GenDataSpec),
    Train(TrainSpec),
    Prune(PruneJobSpec),
    Eval(EvalSpec),
    ZeroShot(ZeroShotSpec),
    Stats(StatsSpec),
    Generate(GenerateSpec),
    E2e(E2eSpec),
    Sweep(SweepSpec),
    Serve(ServeSpec),
}

impl JobSpec {
    /// The job kind (matches the CLI subcommand).
    pub fn kind(&self) -> &'static str {
        match self {
            JobSpec::GenData(_) => "gen-data",
            JobSpec::Train(_) => "train",
            JobSpec::Prune(_) => "prune",
            JobSpec::Eval(_) => "eval",
            JobSpec::ZeroShot(_) => "zeroshot",
            JobSpec::Stats(_) => "stats",
            JobSpec::Generate(_) => "generate",
            JobSpec::E2e(_) => "e2e",
            JobSpec::Sweep(_) => "sweep",
            JobSpec::Serve(_) => "serve",
        }
    }

    /// The model config this job targets, if any.
    pub fn config(&self) -> Option<&str> {
        match self {
            JobSpec::GenData(_) => None,
            JobSpec::Train(s) => Some(s.config.as_str()),
            JobSpec::Prune(s) => Some(s.config.as_str()),
            JobSpec::Eval(s) => Some(s.config.as_str()),
            JobSpec::ZeroShot(s) => Some(s.config.as_str()),
            JobSpec::Stats(s) => Some(s.config.as_str()),
            JobSpec::Generate(s) => Some(s.config.as_str()),
            JobSpec::E2e(s) => Some(s.config.as_str()),
            JobSpec::Sweep(s) => Some(s.config.as_str()),
            JobSpec::Serve(s) => Some(s.config.as_str()),
        }
    }

    /// Canonical string form: `<kind>[/<config>[/<prune-spec>,...]]`.
    pub fn label(&self) -> String {
        match self {
            JobSpec::GenData(_) => "gen-data".to_string(),
            JobSpec::Prune(s) => format!("prune/{}/{}", s.config, s.prune.label()),
            JobSpec::Serve(s) => format!("serve/{}/{}", s.config, s.extra_label()),
            JobSpec::Sweep(s) => {
                if s.variants.is_empty() {
                    // dense-only sweep: no trailing slash, so it parses back
                    format!("sweep/{}", s.config)
                } else {
                    let vs: Vec<String> = s.variants.iter().map(|v| v.label()).collect();
                    format!("sweep/{}/{}", s.config, vs.join(","))
                }
            }
            other => format!("{}/{}", other.kind(), other.config().unwrap_or("")),
        }
    }

    /// Parse a canonical label (inverse of [`JobSpec::label`] on canonical
    /// strings); unspecified fields take the builder defaults.
    pub fn parse(s: &str) -> Result<JobSpec> {
        let mut parts = s.splitn(3, '/');
        let kind = parts.next().unwrap_or("");
        let config = parts.next();
        let extra = parts.next();
        let need_config = || {
            config
                .filter(|c| !c.is_empty())
                .ok_or_else(|| anyhow!("job spec {s:?} needs a config: {kind}/<config>"))
        };
        let no_extra = |spec: JobSpec| {
            if extra.is_some() {
                Err(anyhow!("job spec {s:?} has trailing parts"))
            } else {
                Ok(spec)
            }
        };
        match kind {
            "gen-data" => {
                if config.is_some() {
                    return Err(anyhow!("gen-data takes no config in {s:?}"));
                }
                Ok(JobSpec::GenData(GenDataSpec::default()))
            }
            "train" => no_extra(JobSpec::Train(TrainSpec::new(need_config()?))),
            "prune" => {
                let cfg = need_config()?;
                let pr = PruneSpec::parse(
                    extra.ok_or_else(|| anyhow!("prune spec {s:?} needs prune/<config>/<method>"))?,
                )?;
                Ok(JobSpec::Prune(PruneJobSpec::new(cfg, pr)))
            }
            "eval" => no_extra(JobSpec::Eval(EvalSpec::new(need_config()?))),
            "zeroshot" => no_extra(JobSpec::ZeroShot(ZeroShotSpec::new(need_config()?))),
            "stats" => no_extra(JobSpec::Stats(StatsSpec::new(need_config()?))),
            "generate" => no_extra(JobSpec::Generate(GenerateSpec::new(need_config()?))),
            "e2e" => no_extra(JobSpec::E2e(E2eSpec::new(need_config()?))),
            "serve" => {
                let cfg = need_config()?;
                let mut s = ServeSpec::new(cfg);
                if let Some(p) = extra {
                    // "serve/<config>" keeps the default compression; the
                    // tail is "<prune-spec>[,kv=off][,chunk=N][,cache-mb=N][,prefill=N]"
                    s.apply_extra(p)?;
                }
                Ok(JobSpec::Serve(s))
            }
            "sweep" => {
                let cfg = need_config()?;
                let variants = match extra {
                    // bare "sweep/<config>" = dense-only sweep
                    None => Vec::new(),
                    Some(list) => list
                        .split(',')
                        .map(|v| PruneSpec::parse(v.trim()))
                        .collect::<Result<Vec<_>>>()?,
                };
                Ok(JobSpec::Sweep(SweepSpec::new(cfg).variants(variants)))
            }
            other => Err(anyhow!("unknown job kind {other:?} in {s:?}")),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builders_produce_canonical_labels() {
        assert_eq!(PruneSpec::sparsegpt(0.5).label(), "sparsegpt-50%");
        assert_eq!(PruneSpec::sparsegpt_nm(2, 4).label(), "sparsegpt-2:4");
        assert_eq!(PruneSpec::sparsegpt_nm(2, 4).with_quant_bits(4).label(), "sparsegpt-2:4+4bit");
        assert_eq!(PruneSpec::magnitude(0.8).label(), "magnitude-80%");
        assert_eq!(PruneSpec::magnitude_nm(4, 8).label(), "magnitude-4:8");
        assert_eq!(PruneSpec::adaprune(0.5).label(), "adaprune-50%");
    }

    #[test]
    fn quant_bits_ignored_on_baselines() {
        assert_eq!(PruneSpec::magnitude(0.5).with_quant_bits(4), PruneSpec::magnitude(0.5));
    }

    #[test]
    fn job_kind_and_config() {
        let j = JobSpec::Prune(PruneJobSpec::new("nano", PruneSpec::sparsegpt(0.5)));
        assert_eq!(j.kind(), "prune");
        assert_eq!(j.config(), Some("nano"));
        assert_eq!(JobSpec::GenData(GenDataSpec::default()).config(), None);
        let s = JobSpec::Serve(ServeSpec::new("nano"));
        assert_eq!(s.kind(), "serve");
        assert_eq!(s.config(), Some("nano"));
        assert_eq!(s.label(), "serve/nano/sparsegpt-50%");
    }

    #[test]
    fn serve_spec_round_trips_and_defaults() {
        let spec = ServeSpec::new("small").prune(PruneSpec::sparsegpt_nm(2, 4));
        let j = JobSpec::Serve(spec.clone());
        assert_eq!(JobSpec::parse(&j.label()).unwrap(), j);
        // bare "serve/<cfg>" takes the default compression + cache knobs
        let JobSpec::Serve(parsed) = JobSpec::parse("serve/small").unwrap() else {
            panic!("wrong kind");
        };
        assert_eq!(parsed.prune, PruneSpec::sparsegpt(0.5));
        assert_eq!(parsed.requests, 8);
        assert_eq!(parsed.max_batch, 8);
        assert!(parsed.kv_cache);
        assert_eq!(parsed.prefill_chunk, DEFAULT_PREFILL_CHUNK);
        assert_eq!(parsed.cache_budget_mb, 0);
        assert!(JobSpec::parse("serve/").is_err());
        assert!(JobSpec::parse("serve/nano/bogus-50%").is_err());
    }

    #[test]
    fn serve_pack_format_knobs_round_trip_through_labels() {
        let mut spec = ServeSpec::new("nano");
        spec.format = PackFormat::QCsr { bits: 4, group: 128 };
        let j = JobSpec::Serve(spec);
        assert_eq!(j.label(), "serve/nano/sparsegpt-50%,fmt=qcsr:4,g=128");
        assert_eq!(JobSpec::parse(&j.label()).unwrap(), j);
        let mut spec = ServeSpec::new("nano").kv_cache(false);
        spec.format = PackFormat::Csr;
        let j = JobSpec::Serve(spec);
        assert_eq!(j.label(), "serve/nano/sparsegpt-50%,kv=off,fmt=csr");
        assert_eq!(JobSpec::parse(&j.label()).unwrap(), j);
        // Auto (the default) stays out of the label
        assert_eq!(JobSpec::Serve(ServeSpec::new("nano")).label(), "serve/nano/sparsegpt-50%");
        for bad in [
            "serve/nano/sparsegpt-50%,fmt=bogus",
            "serve/nano/sparsegpt-50%,fmt=qcsr:9",
            "serve/nano/sparsegpt-50%,g=4",      // group without a quantized fmt
            "serve/nano/sparsegpt-50%,fmt=csr,g=4",
        ] {
            assert!(JobSpec::parse(bad).is_err(), "should reject {bad:?}");
        }
    }

    #[test]
    fn serve_net_and_cancel_knobs_round_trip_through_labels() {
        let mut spec = ServeSpec::new("nano");
        spec.listen = Some("127.0.0.1:7070".to_string());
        let j = JobSpec::Serve(spec);
        assert_eq!(j.label(), "serve/nano/sparsegpt-50%,net=127.0.0.1:7070");
        assert_eq!(JobSpec::parse(&j.label()).unwrap(), j);
        let mut spec = ServeSpec::new("nano");
        spec.cancel = vec![(0, 2), (3, 7)];
        let j = JobSpec::Serve(spec);
        assert_eq!(j.label(), "serve/nano/sparsegpt-50%,cancel=0@2+3@7");
        assert_eq!(JobSpec::parse(&j.label()).unwrap(), j);
        // addr_file is CLI plumbing, deliberately not in the label
        let mut spec = ServeSpec::new("nano");
        spec.addr_file = Some("addr.txt".into());
        assert_eq!(JobSpec::Serve(spec).label(), "serve/nano/sparsegpt-50%");
        for bad in [
            "serve/nano/sparsegpt-50%,net=",
            "serve/nano/sparsegpt-50%,cancel=0",
            "serve/nano/sparsegpt-50%,cancel=x@2",
            "serve/nano/sparsegpt-50%,cancel=0@y",
            "serve/nano/sparsegpt-50%,cancel=0@1+",
        ] {
            assert!(JobSpec::parse(bad).is_err(), "should reject {bad:?}");
        }
    }

    #[test]
    fn serve_cache_knobs_round_trip_through_labels() {
        let mut spec = ServeSpec::new("nano").kv_cache(false).cache_budget_mb(16);
        spec.prefill_chunk = 8;
        spec.max_prefill_tokens = 64;
        let j = JobSpec::Serve(spec);
        assert_eq!(
            j.label(),
            "serve/nano/sparsegpt-50%,kv=off,chunk=8,cache-mb=16,prefill=64"
        );
        assert_eq!(JobSpec::parse(&j.label()).unwrap(), j);
        // defaults stay out of the label entirely
        assert_eq!(JobSpec::Serve(ServeSpec::new("nano")).label(), "serve/nano/sparsegpt-50%");
        for bad in [
            "serve/nano/sparsegpt-50%,kv=maybe",
            "serve/nano/sparsegpt-50%,chunk=x",
            "serve/nano/sparsegpt-50%,wat=1",
            "serve/nano/sparsegpt-50%,kv",
        ] {
            assert!(JobSpec::parse(bad).is_err(), "should reject {bad:?}");
        }
    }

    #[test]
    fn serve_telemetry_knobs_round_trip_through_labels() {
        let mut spec = ServeSpec::new("nano");
        spec.snap_every = 4;
        spec.mock_clock = true;
        let j = JobSpec::Serve(spec);
        assert_eq!(j.label(), "serve/nano/sparsegpt-50%,snap=4,clock=mock");
        assert_eq!(JobSpec::parse(&j.label()).unwrap(), j);
        // clock=real is accepted but, being the default, never emitted
        let JobSpec::Serve(parsed) =
            JobSpec::parse("serve/nano/sparsegpt-50%,clock=real").unwrap()
        else {
            panic!("not a serve spec")
        };
        assert!(!parsed.mock_clock);
        // metrics_file is CLI plumbing, deliberately not in the label
        let mut spec = ServeSpec::new("nano");
        spec.metrics_file = Some("metrics.prom".into());
        assert_eq!(JobSpec::Serve(spec).label(), "serve/nano/sparsegpt-50%");
        for bad in [
            "serve/nano/sparsegpt-50%,snap=x",
            "serve/nano/sparsegpt-50%,clock=maybe",
            "serve/nano/sparsegpt-50%,clock=",
        ] {
            assert!(JobSpec::parse(bad).is_err(), "should reject {bad:?}");
        }
    }

    #[test]
    fn serve_replicas_knob_round_trips_through_labels() {
        let mut spec = ServeSpec::new("nano");
        spec.replicas = 4;
        spec.workers = 2;
        let j = JobSpec::Serve(spec);
        assert_eq!(j.label(), "serve/nano/sparsegpt-50%,workers=2,replicas=4");
        assert_eq!(JobSpec::parse(&j.label()).unwrap(), j);
        // the single-replica default stays out of the label entirely
        assert_eq!(JobSpec::Serve(ServeSpec::new("nano")).label(), "serve/nano/sparsegpt-50%");
        let JobSpec::Serve(parsed) =
            JobSpec::parse("serve/nano/sparsegpt-50%,replicas=1").unwrap()
        else {
            panic!("not a serve spec")
        };
        assert_eq!(parsed.replicas, 1);
        for bad in [
            "serve/nano/sparsegpt-50%,replicas=x",
            "serve/nano/sparsegpt-50%,replicas=0",
            "serve/nano/sparsegpt-50%,replicas=",
        ] {
            assert!(JobSpec::parse(bad).is_err(), "should reject {bad:?}");
        }
    }

    #[test]
    fn serve_fleet_knobs_round_trip_through_labels() {
        let mut spec = ServeSpec::new("nano");
        spec.models = vec![
            ("dense".to_string(), PathBuf::from("out/dense.spkt")),
            ("q4".to_string(), PathBuf::from("out/q4.spkt")),
        ];
        spec.model_cache_mb = 2;
        let j = JobSpec::Serve(spec);
        assert_eq!(
            j.label(),
            "serve/nano/sparsegpt-50%,models=dense@out/dense.spkt+q4@out/q4.spkt,model-cache-mb=2"
        );
        assert_eq!(JobSpec::parse(&j.label()).unwrap(), j);
        // an empty fleet and an unlimited budget stay out of the label
        assert_eq!(JobSpec::Serve(ServeSpec::new("nano")).label(), "serve/nano/sparsegpt-50%");
        for bad in [
            "serve/nano/sparsegpt-50%,models=dense",      // no @path
            "serve/nano/sparsegpt-50%,models=@x.spkt",    // empty name
            "serve/nano/sparsegpt-50%,models=a@",         // empty path
            "serve/nano/sparsegpt-50%,model-cache-mb=x",
        ] {
            assert!(JobSpec::parse(bad).is_err(), "should reject {bad:?}");
        }
    }
}
