//! The unified job API (Layer 4): one typed front door for everything the
//! system can run.
//!
//! * [`JobSpec`] — what to run, as data: `GenData`, `Train`, `Prune`,
//!   `Eval`, `ZeroShot`, `Stats`, `Generate`, `E2e`, `Sweep`, `Serve`,
//!   with builder constructors and string round-tripping
//!   (`PruneSpec::parse("sparsegpt-2:4+4bit")` ↔ `label()`).
//! * [`Session`] — owns the [`crate::harness::Workspace`] (and through it
//!   the PJRT runtime), resolves checkpoints, and executes specs.
//! * [`EventSink`] — where progress goes: [`HumanSink`] prints the classic
//!   log lines, [`JsonlSink`] emits machine-readable JSON lines (one
//!   object per line, each with a `reason` field — cargo's
//!   `--message-format=json` pattern).
//! * [`JobReport`] — typed results, including compressed parameters.
//!
//! The CLI, every example and the benches all route through this module;
//! new compression methods or workloads plug in as new specs rather than
//! as new ad-hoc drivers.
//!
//! ```text
//! use sparsegpt::api::{HumanSink, JobSpec, PruneSpec, Session, SweepSpec};
//!
//! let spec = SweepSpec::new("small")
//!     .dense(true)
//!     .variant(PruneSpec::sparsegpt(0.5))
//!     .variant(PruneSpec::sparsegpt_nm(2, 4).with_quant_bits(4));
//! let report = Session::new().run(&JobSpec::Sweep(spec), &mut HumanSink::new())?;
//! ```

mod events;
mod report;
mod session;
mod spec;

pub use events::{Event, EventSink, HumanSink, JsonlSink, MemorySink, NullSink};
pub use report::{
    E2eReport, EvalReport, EvalRow, GenDataReport, GenerateReport, JobReport, PruneReport,
    ServeReport, ServeRequestRow, StatsReport, SweepReport, TrainReport, VariantResult,
    ZeroShotReport,
};
pub use session::Session;
pub use spec::{
    E2eSpec, EvalSpec, GenDataSpec, GenerateSpec, JobSpec, PruneJobSpec, PruneSpec, ServeSpec,
    StatsSpec, SweepSpec, TrainSpec, ZeroShotSpec, DEFAULT_PREFILL_CHUNK,
};

pub(crate) use session::prune_params;
