//! Zero-shot evaluation suite: five multiple-choice tasks generated from
//! the same lexicon machinery as the corpora (held-out seeds), standing in
//! for Lambada, PIQA, ARC-Easy, ARC-Challenge and StoryCloze. Scoring
//! follows the eval-harness convention: rank candidate completions by
//! length-normalized log-likelihood under the model.

use anyhow::{bail, Result};

use crate::data::corpus::{gen_sentence, CorpusStyle, Lexicon, N_TOPICS};
use crate::data::Tokenizer;
use crate::model::layout::FlatParams;
use crate::runtime::{ArgValue, Backend};
use crate::util::prng::Rng;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ZeroShotTask {
    /// final-word cloze with cross-topic distractors (Lambada-like)
    Cloze,
    /// 2-way template-consistency choice (PIQA-like)
    Pair,
    /// 4-way, distractors from other topics (ARC-Easy-like)
    EasyMc,
    /// 4-way, distractors from the SAME topic (ARC-Challenge-like:
    /// topic signal alone cannot solve it, local syntax must)
    HardMc,
    /// story-ending coherence, 2-way (StoryCloze-like)
    Story,
}

impl ZeroShotTask {
    pub const ALL: [ZeroShotTask; 5] = [
        ZeroShotTask::Cloze,
        ZeroShotTask::Pair,
        ZeroShotTask::EasyMc,
        ZeroShotTask::HardMc,
        ZeroShotTask::Story,
    ];

    pub fn name(&self) -> &'static str {
        match self {
            ZeroShotTask::Cloze => "cloze",
            ZeroShotTask::Pair => "pair",
            ZeroShotTask::EasyMc => "arc-e",
            ZeroShotTask::HardMc => "arc-c",
            ZeroShotTask::Story => "story",
        }
    }
}

#[derive(Clone, Debug)]
pub struct McItem {
    pub context: String,
    /// candidate completions; index 0 is correct (shuffled at scoring time)
    pub candidates: Vec<String>,
}

fn other_topic(rng: &mut Rng, t: usize) -> usize {
    let mut o = rng.below(N_TOPICS - 1);
    if o >= t {
        o += 1;
    }
    o
}

/// Generate `n` items for a task (deterministic in `seed`).
pub fn gen_items(task: ZeroShotTask, lex: &Lexicon, seed: u64, n: usize) -> Vec<McItem> {
    let mut rng = Rng::new(seed ^ 0x2e_705_407 ^ task.name().len() as u64);
    let mut items = Vec::with_capacity(n);
    while items.len() < n {
        let t = rng.below(N_TOPICS);
        let item = match task {
            ZeroShotTask::Cloze => {
                let ctx: Vec<String> = (0..3)
                    .map(|_| gen_sentence(lex, &mut rng, t, CorpusStyle::C4).text)
                    .collect();
                let s = gen_sentence(lex, &mut rng, t, CorpusStyle::C4);
                let stem = s.text.trim_end_matches(&s.final_word).trim_end().to_string();
                let mut cands = vec![format!(" {}", s.final_word)];
                for _ in 0..3 {
                    let o = other_topic(&mut rng, t);
                    cands.push(format!(" {}", lex.noun(&mut rng, o, 1.0)));
                }
                McItem { context: format!("{} . {} . {} . {}", ctx[0], ctx[1], ctx[2], stem), candidates: cands }
            }
            ZeroShotTask::Pair => {
                let s = gen_sentence(lex, &mut rng, t, CorpusStyle::C4);
                let stem = s.text.trim_end_matches(&s.final_word).trim_end().to_string();
                // correct: the generated final word; wrong: a verb where a
                // noun belongs (or vice versa) — template violation
                let wrong = lex.verb(&mut rng, t, 1.0).to_string();
                McItem {
                    context: stem,
                    candidates: vec![format!(" {}", s.final_word), format!(" the {wrong} of")],
                }
            }
            ZeroShotTask::EasyMc => {
                let ctx: Vec<String> = (0..2)
                    .map(|_| gen_sentence(lex, &mut rng, t, CorpusStyle::C4).text)
                    .collect();
                let s = gen_sentence(lex, &mut rng, t, CorpusStyle::C4);
                let stem = s.text.trim_end_matches(&s.final_word).trim_end().to_string();
                let mut cands = vec![format!(" {}", s.final_word)];
                for _ in 0..3 {
                    let o = other_topic(&mut rng, t);
                    cands.push(format!(" {}", lex.noun(&mut rng, o, 1.0)));
                }
                McItem { context: format!("{} . {} . {}", ctx[0], ctx[1], stem), candidates: cands }
            }
            ZeroShotTask::HardMc => {
                let ctx = gen_sentence(lex, &mut rng, t, CorpusStyle::C4).text;
                let s = gen_sentence(lex, &mut rng, t, CorpusStyle::C4);
                let stem = s.text.trim_end_matches(&s.final_word).trim_end().to_string();
                // distractors from the SAME topic but wrong word class for
                // the template slot (an adjective/verb where the template
                // expects the sentence-final noun/verb)
                let mut cands = vec![format!(" {}", s.final_word)];
                cands.push(format!(" {}", lex.adj(&mut rng, t, 1.0)));
                cands.push(format!(" {}", lex.verb(&mut rng, t, 1.0)));
                cands.push(format!(" {}", lex.adj(&mut rng, t, 1.0)));
                McItem { context: format!("{ctx} . {stem}"), candidates: cands }
            }
            ZeroShotTask::Story => {
                let ctx: Vec<String> = (0..2)
                    .map(|_| gen_sentence(lex, &mut rng, t, CorpusStyle::C4).text)
                    .collect();
                let good = gen_sentence(lex, &mut rng, t, CorpusStyle::C4).text;
                let o = other_topic(&mut rng, t);
                let bad = gen_sentence(lex, &mut rng, o, CorpusStyle::C4).text;
                McItem {
                    context: format!("{} . {} .", ctx[0], ctx[1]),
                    candidates: vec![format!(" {good}"), format!(" {bad}")],
                }
            }
        };
        items.push(item);
    }
    items
}

/// Score one task: accuracy of picking the correct candidate by
/// length-normalized log-likelihood.
pub fn zero_shot_accuracy(
    rt: &dyn Backend,
    params: &FlatParams,
    tok: &Tokenizer,
    items: &[McItem],
) -> Result<f64> {
    let cfg = &params.cfg;
    let artifact = format!("nll_{}", cfg.name);
    let row_len = cfg.seq + 1;
    let mut correct = 0usize;

    // flatten all (item, candidate) rows, batch them through nll_<cfg>
    struct RowRef {
        item: usize,
        cand: usize,
        score_from: usize,
        score_to: usize,
    }
    let mut rows: Vec<Vec<i32>> = Vec::new();
    let mut refs: Vec<RowRef> = Vec::new();
    for (ii, item) in items.iter().enumerate() {
        let ctx = tok.encode(&item.context);
        for (ci, cand) in item.candidates.iter().enumerate() {
            let cand_toks = tok.encode(cand);
            if cand_toks.is_empty() {
                bail!("empty candidate encoding");
            }
            let mut r = ctx.clone();
            // keep the tail if too long: truncate context from the left
            let need = cand_toks.len() + 1;
            if r.len() + cand_toks.len() > row_len {
                let keep = row_len.saturating_sub(cand_toks.len());
                if keep == 0 || need > row_len {
                    bail!("candidate longer than context window");
                }
                r = r[r.len() - keep..].to_vec();
            }
            let ctx_len = r.len();
            r.extend_from_slice(&cand_toks);
            let score_from = ctx_len - 1; // nll position predicting first cand token
            let score_to = score_from + cand_toks.len();
            r.resize(row_len, 0);
            rows.push(r);
            refs.push(RowRef { item: ii, cand: ci, score_from, score_to });
        }
    }

    let mut scores: Vec<Vec<f64>> = items.iter().map(|i| vec![0.0; i.candidates.len()]).collect();
    let plit = rt.cache_f32(&params.data, &[cfg.n_params])?;
    for (batch_rows, batch_refs) in rows.chunks(cfg.eval_batch).zip(refs.chunks(cfg.eval_batch)) {
        let mut toks = Vec::with_capacity(cfg.eval_batch * row_len);
        for r in batch_rows {
            toks.extend_from_slice(r);
        }
        toks.resize(cfg.eval_batch * row_len, 0);
        let out = rt.run(&artifact, &[ArgValue::Cached(&plit), ArgValue::I32(&toks)])?;
        let nll = &out[0];
        for (r, rr) in batch_refs.iter().enumerate() {
            let row = nll.row(r);
            let s: f64 = row[rr.score_from..rr.score_to].iter().map(|&x| x as f64).sum();
            scores[rr.item][rr.cand] = s / (rr.score_to - rr.score_from) as f64;
        }
    }

    for (ii, item) in items.iter().enumerate() {
        let best = scores[ii]
            .iter()
            .enumerate()
            .min_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .map(|(i, _)| i)
            .unwrap();
        if best == 0 {
            correct += 1;
        }
        let _ = item;
    }
    Ok(correct as f64 / items.len().max(1) as f64)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn items_deterministic_and_well_formed() {
        let lex = Lexicon::new(0);
        for task in ZeroShotTask::ALL {
            let a = gen_items(task, &lex, 1, 20);
            let b = gen_items(task, &lex, 1, 20);
            assert_eq!(a.len(), 20);
            for (x, y) in a.iter().zip(&b) {
                assert_eq!(x.context, y.context);
                assert_eq!(x.candidates, y.candidates);
                assert!(x.candidates.len() >= 2);
                assert!(!x.context.is_empty());
                // correct candidate differs from distractors
                for d in &x.candidates[1..] {
                    assert_ne!(&x.candidates[0], d);
                }
            }
        }
    }

    #[test]
    fn tasks_have_distinct_distributions() {
        let lex = Lexicon::new(0);
        let easy = gen_items(ZeroShotTask::EasyMc, &lex, 2, 5);
        let hard = gen_items(ZeroShotTask::HardMc, &lex, 2, 5);
        assert_ne!(easy[0].context, hard[0].context);
    }
}
