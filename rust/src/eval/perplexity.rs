//! Perplexity, computed exactly as the paper describes (App. B /
//! HuggingFace): concatenate the test set, split into non-overlapping
//! context-length segments, sum token NLLs, exponentiate the mean.

use anyhow::{Context, Result};

use crate::data::Dataset;
use crate::model::layout::FlatParams;
use crate::runtime::{ArgValue, Backend};

#[derive(Clone, Copy, Debug)]
pub struct Ppl {
    pub ppl: f64,
    pub nll_sum: f64,
    pub tokens: usize,
}

/// Evaluate perplexity of `params` on `ds` over at most `max_segments`
/// non-overlapping segments (usize::MAX = the whole set).
pub fn perplexity(
    rt: &dyn Backend,
    params: &FlatParams,
    ds: &Dataset,
    max_segments: usize,
) -> Result<Ppl> {
    let cfg = &params.cfg;
    let segs = ds.eval_segments(cfg.seq, max_segments);
    let artifact = format!("nll_{}", cfg.name);
    // marshal the parameter vector once for the whole evaluation
    let plit = rt.cache_f32(&params.data, &[cfg.n_params])?;
    let mut nll_sum = 0.0f64;
    let mut tokens = 0usize;
    let row = cfg.seq + 1;
    for group in segs.chunks(cfg.eval_batch) {
        let mut toks = Vec::with_capacity(cfg.eval_batch * row);
        for s in group {
            toks.extend_from_slice(s);
        }
        toks.resize(cfg.eval_batch * row, 0); // pad rows are discarded below
        let out = rt
            .run(&artifact, &[ArgValue::Cached(&plit), ArgValue::I32(&toks)])
            .with_context(|| format!("nll eval on {}", ds.name))?;
        let nll = &out[0];
        for (r, _s) in group.iter().enumerate() {
            nll_sum += nll.row(r).iter().map(|&x| x as f64).sum::<f64>();
            tokens += cfg.seq;
        }
    }
    Ok(Ppl { ppl: (nll_sum / tokens.max(1) as f64).exp(), nll_sum, tokens })
}
