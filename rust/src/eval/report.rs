//! Experiment report writers: aligned-text tables for the terminal (what
//! the benches print), plus CSV and JSON for post-processing.

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::Result;

use crate::util::json::Json;

/// A simple column-aligned table that prints like the paper's tables.
#[derive(Clone, Debug, Default)]
pub struct Table {
    pub title: String,
    pub header: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, header: &[&str]) -> Table {
        Table {
            title: title.to_string(),
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.header.len(), "row arity");
        self.rows.push(cells);
    }

    pub fn render(&self) -> String {
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for r in &self.rows {
            for (i, c) in r.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        if !self.title.is_empty() {
            out.push_str(&format!("## {}\n", self.title));
        }
        let fmt_row = |cells: &[String], widths: &[usize]| -> String {
            cells
                .iter()
                .enumerate()
                .map(|(i, c)| format!("{:>w$}", c, w = widths[i]))
                .collect::<Vec<_>>()
                .join("  ")
        };
        out.push_str(&fmt_row(&self.header, &widths));
        out.push('\n');
        out.push_str(&"-".repeat(widths.iter().sum::<usize>() + 2 * (widths.len() - 1)));
        out.push('\n');
        for r in &self.rows {
            out.push_str(&fmt_row(r, &widths));
            out.push('\n');
        }
        out
    }

    pub fn to_csv(&self) -> String {
        let esc = |s: &str| {
            if s.contains(',') || s.contains('"') {
                format!("\"{}\"", s.replace('"', "\"\""))
            } else {
                s.to_string()
            }
        };
        let mut out = self.header.iter().map(|h| esc(h)).collect::<Vec<_>>().join(",");
        out.push('\n');
        for r in &self.rows {
            out.push_str(&r.iter().map(|c| esc(c)).collect::<Vec<_>>().join(","));
            out.push('\n');
        }
        out
    }

    /// Write `<dir>/<stem>.txt` and `<dir>/<stem>.csv`.
    pub fn save(&self, dir: impl AsRef<Path>, stem: &str) -> Result<()> {
        let dir = dir.as_ref();
        std::fs::create_dir_all(dir)?;
        std::fs::write(dir.join(format!("{stem}.txt")), self.render())?;
        std::fs::write(dir.join(format!("{stem}.csv")), self.to_csv())?;
        Ok(())
    }
}

/// Append a JSON record to a results log (one object per line).
pub fn append_jsonl(path: impl AsRef<Path>, fields: &[(&str, Json)]) -> Result<()> {
    use std::io::Write as _;
    let path = path.as_ref();
    if let Some(dir) = path.parent() {
        std::fs::create_dir_all(dir)?;
    }
    let mut obj = BTreeMap::new();
    for (k, v) in fields {
        obj.insert(k.to_string(), v.clone());
    }
    let mut line = Json::Obj(obj).to_string_compact();
    line.push('\n');
    let mut f = std::fs::OpenOptions::new().create(true).append(true).open(path)?;
    f.write_all(line.as_bytes())?;
    Ok(())
}

pub fn fmt_ppl(p: f64) -> String {
    if !p.is_finite() {
        "inf".into()
    } else if p >= 10_000.0 {
        format!("{:.1e}", p)
    } else if p >= 100.0 {
        format!("{:.0}.", p)
    } else {
        format!("{:.2}", p)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new("t", &["a", "bbbb"]);
        t.row(vec!["1".into(), "2".into()]);
        t.row(vec!["10".into(), "20000".into()]);
        let s = t.render();
        assert!(s.contains("## t"));
        let lines: Vec<&str> = s.lines().collect();
        assert_eq!(lines[1].len(), lines[3].len());
    }

    #[test]
    fn csv_escapes() {
        let mut t = Table::new("", &["x"]);
        t.row(vec!["a,b".into()]);
        assert!(t.to_csv().contains("\"a,b\""));
    }

    #[test]
    fn ppl_formatting_matches_paper_style() {
        assert_eq!(fmt_ppl(27.66), "27.66");
        assert_eq!(fmt_ppl(265.0), "265.");
        assert_eq!(fmt_ppl(43_000.0), "4.3e4");
        assert_eq!(fmt_ppl(f64::INFINITY), "inf");
    }
}
