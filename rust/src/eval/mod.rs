//! Evaluation harness: strided perplexity (the HuggingFace procedure the
//! paper follows) and the zero-shot multiple-choice suite (the offline
//! analogs of Lambada / PIQA / ARC-e / ARC-c / StoryCloze).

pub mod generate;
pub mod perplexity;
pub mod report;
pub mod zeroshot;

pub use perplexity::{perplexity, Ppl};
pub use zeroshot::{zero_shot_accuracy, ZeroShotTask};
