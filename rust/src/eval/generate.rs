//! Autoregressive sampling through the `next_logits_<cfg>` artifact —
//! a qualitative check that compressed models still generate coherent text
//! (the paper's "output correlates extremely closely with the dense model"
//! observation, made tangible).

use anyhow::Result;

use crate::model::layout::FlatParams;
use crate::runtime::{ArgValue, Backend};
use crate::util::prng::Rng;

#[derive(Clone, Debug)]
pub struct SampleOptions {
    pub max_tokens: usize,
    pub temperature: f64,
    /// keep only the k most likely tokens (0 = disabled)
    pub top_k: usize,
    pub seed: u64,
}

impl Default for SampleOptions {
    fn default() -> Self {
        SampleOptions { max_tokens: 64, temperature: 0.8, top_k: 40, seed: 0 }
    }
}

/// Greedy/temperature sampling continuing `prompt` (token ids). The model
/// window slides over the last `seq` tokens. Returns only the newly
/// generated ids.
pub fn sample(
    rt: &dyn Backend,
    params: &FlatParams,
    prompt: &[i32],
    opts: &SampleOptions,
) -> Result<Vec<i32>> {
    let cfg = &params.cfg;
    let artifact = format!("next_logits_{}", cfg.name);
    let plit = rt.cache_f32(&params.data, &[cfg.n_params])?;
    let mut rng = Rng::new(opts.seed ^ 0x9e4e);
    let mut ctx: Vec<i32> = prompt.to_vec();
    // left-fill a short prompt by repeating it (the model has no pad token)
    while ctx.len() < cfg.seq {
        let take = (cfg.seq - ctx.len()).min(prompt.len().max(1));
        ctx.splice(0..0, prompt.iter().cloned().take(take));
        if prompt.is_empty() {
            ctx.splice(0..0, [0]);
        }
    }
    let mut out = Vec::with_capacity(opts.max_tokens);
    for _ in 0..opts.max_tokens {
        let window = &ctx[ctx.len() - cfg.seq..];
        let logits = rt
            .run(&artifact, &[ArgValue::Cached(&plit), ArgValue::I32(window)])?
            .remove(0);
        let next = pick(logits.data(), opts, &mut rng);
        out.push(next);
        ctx.push(next);
    }
    Ok(out)
}

fn pick(logits: &[f32], opts: &SampleOptions, rng: &mut Rng) -> i32 {
    pick_token(logits, opts.temperature, opts.top_k, rng)
}

/// Sample one token id from `logits`: greedy argmax at temperature <= 0,
/// otherwise top-k filtered softmax sampling (k = 0 disables the filter).
/// Shared by [`sample`] and the serving engine's per-request samplers.
pub fn pick_token(logits: &[f32], temperature: f64, top_k: usize, rng: &mut Rng) -> i32 {
    if temperature <= 0.0 {
        return argmax(logits) as i32;
    }
    // top-k filter then softmax at temperature
    let mut idx: Vec<usize> = (0..logits.len()).collect();
    idx.sort_by(|&a, &b| logits[b].partial_cmp(&logits[a]).unwrap());
    let k = if top_k == 0 { logits.len() } else { top_k.min(logits.len()) };
    let kept = &idx[..k];
    let maxv = logits[kept[0]] as f64;
    let weights: Vec<f64> = kept
        .iter()
        .map(|&i| ((logits[i] as f64 - maxv) / temperature).exp())
        .collect();
    kept[rng.weighted(&weights)] as i32
}

fn argmax(xs: &[f32]) -> usize {
    xs.iter()
        .enumerate()
        .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
        .map(|(i, _)| i)
        .unwrap()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn greedy_picks_argmax() {
        let mut rng = Rng::new(0);
        let logits = vec![0.1f32, 3.0, -1.0, 2.9];
        let o = SampleOptions { temperature: 0.0, ..Default::default() };
        assert_eq!(pick(&logits, &o, &mut rng), 1);
    }

    #[test]
    fn top_k_restricts_support() {
        let mut rng = Rng::new(1);
        let logits = vec![10.0f32, 9.5, -50.0, -60.0];
        let o = SampleOptions { temperature: 1.0, top_k: 2, ..Default::default() };
        for _ in 0..100 {
            let t = pick(&logits, &o, &mut rng);
            assert!(t == 0 || t == 1);
        }
    }

    #[test]
    fn temperature_sampling_is_seeded() {
        let logits: Vec<f32> = (0..16).map(|i| (i as f32).sin()).collect();
        let o = SampleOptions { temperature: 0.9, top_k: 8, ..Default::default() };
        let run = |seed| {
            let mut rng = Rng::new(seed);
            (0..20).map(|_| pick(&logits, &o, &mut rng)).collect::<Vec<_>>()
        };
        assert_eq!(run(7), run(7));
        assert_ne!(run(7), run(8));
    }
}
