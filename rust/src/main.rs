//! `sparsegpt` — launcher for the SparseGPT reproduction pipeline.
//!
//! Subcommands:
//!   gen-data   generate synthetic corpora + train the BPE tokenizer
//!   train      pretrain a model config (train_step artifact loop)
//!   prune      one-shot compress a trained model (SparseGPT / baselines)
//!   eval       perplexity on the three eval corpora
//!   zeroshot   the five zero-shot tasks
//!   stats      sparsity statistics of a checkpoint
//!   e2e        train -> prune -> eval in one run (see examples/ too)

use anyhow::{bail, Context, Result};

use sparsegpt::cli::{parse_nm, Args};
use sparsegpt::coordinator::{
    PruneMethod, PruneOptions, Pruner, SkipSpec, TrainOptions, Trainer,
};
use sparsegpt::data::corpus::Lexicon;
use sparsegpt::eval::perplexity;
use sparsegpt::eval::report::{fmt_ppl, Table};
use sparsegpt::eval::zeroshot::{gen_items, zero_shot_accuracy, ZeroShotTask};
use sparsegpt::harness::{generate_data, Workspace, DEFAULT_CALIB_SEGMENTS};
use sparsegpt::model::checkpoint::Checkpoint;
use sparsegpt::model::init::init_params;
use sparsegpt::model::stats::ModelStats;
use sparsegpt::solver::sparsegpt_ref::Pattern;

const USAGE: &str = "\
sparsegpt <command> [flags]

commands:
  gen-data  --out data [--seed 0] [--train-mb 4]
  train     --config <cfg> [--steps 400] [--out checkpoints]
            [--seed 0] [--resume] [--lr <f>] [--log-every 20]
  prune     --config <cfg> [--method sparsegpt|magnitude|adaprune]
            [--sparsity 0.5 | --nm 2:4] [--quant-bits 4] [--damp 0.01]
            [--calib 128] [--calib-seed 0] [--skip attn|fc1|fc2|front|middle|back]
            [--prefix-frac 0.66] [--out <ckpt>] [--suffix -50]
  eval      --config <cfg> [--ckpt <path>] [--max-segments 512]
  zeroshot  --config <cfg> [--ckpt <path>] [--items 100] [--seed 7]
  stats     --config <cfg> [--ckpt <path>] [--nm 2:4]
  generate  --config <cfg> [--ckpt <path>] [--prompt <text>] [--tokens 64]
            [--temperature 0.8] [--top-k 40] [--seed 0]
  e2e       [--config small] [--steps 300]
";

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    if argv.is_empty() {
        eprint!("{USAGE}");
        std::process::exit(2);
    }
    if let Err(e) = run(&argv) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn run(argv: &[String]) -> Result<()> {
    let args = Args::parse(argv, &["resume", "record-errors", "rt-stats"])?;
    let cmd = args.positional.first().map(|s| s.as_str()).unwrap_or("");
    match cmd {
        "gen-data" => cmd_gen_data(&args),
        "train" => cmd_train(&args),
        "prune" => cmd_prune(&args),
        "eval" => cmd_eval(&args),
        "zeroshot" => cmd_zeroshot(&args),
        "stats" => cmd_stats(&args),
        "generate" => cmd_generate(&args),
        "e2e" => cmd_e2e(&args),
        other => bail!("unknown command {other:?}\n{USAGE}"),
    }
}

fn cmd_gen_data(args: &Args) -> Result<()> {
    let out = args.get_or("out", "data");
    let seed = args.u64_or("seed", 0)?;
    let mb = args.usize_or("train-mb", 4)?;
    generate_data(out, seed, mb)
}

fn cmd_train(args: &Args) -> Result<()> {
    let ws = Workspace::open()?;
    let name = args.required("config")?;
    let cfg = ws.config(name)?;
    let steps = args.usize_or("steps", 400)?;
    let mut opts = TrainOptions::for_config(name, steps);
    opts.seed = args.u64_or("seed", 0)?;
    opts.log_every = args.usize_or("log-every", 20)?;
    if let Some(lr) = args.get("lr") {
        opts.base_lr = lr.parse()?;
    }
    opts.out = Some(args.get_or("out", ws.ckpt_dir.to_str().unwrap()).into());
    opts.checkpoint_every = args.usize_or("checkpoint-every", 0)?;
    let data = ws.dataset(sparsegpt::harness::CALIB_SET)?;

    let (params, adam, start) = if args.has("resume") {
        let ck = Checkpoint::load(Checkpoint::path_for(&ws.ckpt_dir, name, ""))?;
        let step = ck.step;
        let adam = ck.adam.clone();
        (ck.into_flat_params(&cfg)?, adam, step)
    } else {
        (init_params(&cfg, opts.seed), None, 0)
    };
    println!(
        "[train {name}] {} params, {} steps, batch {}, lr {:.1e}",
        cfg.n_params, steps, cfg.train_batch, opts.base_lr
    );
    let out = Trainer::new(&ws.rt).train(params, adam, start, &data, &opts)?;
    println!(
        "[train {name}] done in {:.1}s, final loss {:.4}",
        out.secs,
        out.losses.last().map(|l| l.1).unwrap_or(f64::NAN)
    );
    Ok(())
}

pub fn method_from_args(args: &Args) -> Result<PruneMethod> {
    let quant_bits = args.get("quant-bits").map(|b| b.parse()).transpose()?;
    let pattern = match args.get("nm") {
        Some(nm) => {
            let (n, m) = parse_nm(nm)?;
            Pattern::NM(n, m)
        }
        None => Pattern::Unstructured(args.f64_or("sparsity", 0.5)?),
    };
    Ok(match args.get_or("method", "sparsegpt") {
        "sparsegpt" => PruneMethod::SparseGpt { pattern, quant_bits },
        "magnitude" => PruneMethod::Magnitude { pattern },
        "adaprune" => match pattern {
            Pattern::Unstructured(p) => PruneMethod::AdaPrune { sparsity: p },
            _ => bail!("adaprune supports unstructured sparsity only"),
        },
        m => bail!("unknown method {m:?}"),
    })
}

fn skip_from_args(args: &Args) -> Result<SkipSpec> {
    if let Some(f) = args.get("prefix-frac") {
        return Ok(SkipSpec::PrefixFraction(f.parse()?));
    }
    Ok(match args.get("skip") {
        None => SkipSpec::None,
        Some("attn") | Some("fc1") | Some("fc2") => {
            SkipSpec::LayerType(args.get("skip").unwrap().to_string())
        }
        Some("front") => SkipSpec::Third(0),
        Some("middle") => SkipSpec::Third(1),
        Some("back") => SkipSpec::Third(2),
        Some(s) => bail!("unknown --skip {s:?}"),
    })
}

fn cmd_prune(args: &Args) -> Result<()> {
    let ws = Workspace::open()?;
    let name = args.required("config")?;
    let cfg = ws.config(name)?;
    let params = match args.get("ckpt") {
        Some(p) => Checkpoint::load(p)?.into_flat_params(&cfg)?,
        None => ws.load_model(name)?,
    };
    let opts = PruneOptions {
        method: method_from_args(args)?,
        damp: args.f64_or("damp", 0.01)?,
        skip: skip_from_args(args)?,
        record_errors: args.has("record-errors"),
        exact_rows: None,
    };
    let n_calib = args.usize_or("calib", DEFAULT_CALIB_SEGMENTS)?;
    let chunks = ws.calib_chunks(&cfg, n_calib, args.u64_or("calib-seed", 0)?)?;
    println!(
        "[prune {name}] method {} | {} calib segments | damp {}",
        opts.method.label(),
        n_calib,
        opts.damp
    );
    let outcome = Pruner::new(&ws.rt).prune(params, &chunks, &opts)?;
    println!(
        "[prune {name}] sparsity {:.3} in {:.1}s (hessian {:.1}s solver {:.1}s prop {:.1}s)",
        outcome.overall_sparsity(),
        outcome.total_secs,
        outcome.hessian_secs,
        outcome.solver_secs,
        outcome.propagate_secs
    );
    if args.has("rt-stats") {
        println!("per-artifact runtime totals (compile / run / marshal seconds):");
        for (name, s) in ws.rt.stats() {
            println!(
                "  {name:<28} x{:<4} compile {:.2} run {:.2} marshal {:.2}",
                s.runs, s.compile_secs, s.run_secs, s.marshal_secs
            );
        }
    }
    let default_suffix = format!("-{}", opts.method.label());
    let suffix = args.get_or("suffix", &default_suffix);
    let path = match args.get("out") {
        Some(p) => p.into(),
        None => Checkpoint::path_for(&ws.ckpt_dir, name, suffix),
    };
    Checkpoint {
        config_name: name.to_string(),
        step: 0,
        params: outcome.params.data.clone(),
        adam: None,
    }
    .save(&path)?;
    println!("[prune {name}] saved -> {path:?}");
    Ok(())
}

fn cmd_eval(args: &Args) -> Result<()> {
    let ws = Workspace::open()?;
    let name = args.required("config")?;
    let cfg = ws.config(name)?;
    let params = match args.get("ckpt") {
        Some(p) => Checkpoint::load(p)?.into_flat_params(&cfg)?,
        None => ws.load_model(name)?,
    };
    let max_seg = args.usize_or("max-segments", 512)?;
    let mut table = Table::new(&format!("perplexity: {name}"), &["dataset", "ppl", "tokens"]);
    for (dsname, ds) in ws.eval_datasets()? {
        let p = perplexity(&ws.rt, &params, &ds, max_seg)?;
        table.row(vec![dsname, fmt_ppl(p.ppl), p.tokens.to_string()]);
    }
    print!("{}", table.render());
    Ok(())
}

fn cmd_zeroshot(args: &Args) -> Result<()> {
    let ws = Workspace::open()?;
    let name = args.required("config")?;
    let cfg = ws.config(name)?;
    let params = match args.get("ckpt") {
        Some(p) => Checkpoint::load(p)?.into_flat_params(&cfg)?,
        None => ws.load_model(name)?,
    };
    let tok = ws.tokenizer()?;
    let lex = Lexicon::new(args.u64_or("data-seed", 0)?);
    let n = args.usize_or("items", 100)?;
    let seed = args.u64_or("seed", 7)?;
    let mut table = Table::new(&format!("zero-shot: {name}"), &["task", "accuracy"]);
    let mut sum = 0.0;
    for task in ZeroShotTask::ALL {
        let items = gen_items(task, &lex, seed, n);
        let acc = zero_shot_accuracy(&ws.rt, &params, &tok, &items)?;
        sum += acc;
        table.row(vec![task.name().into(), format!("{:.1}%", acc * 100.0)]);
    }
    table.row(vec!["avg".into(), format!("{:.1}%", sum / 5.0 * 100.0)]);
    print!("{}", table.render());
    Ok(())
}

fn cmd_stats(args: &Args) -> Result<()> {
    let ws = Workspace::open()?;
    let name = args.required("config")?;
    let cfg = ws.config(name)?;
    let params = match args.get("ckpt") {
        Some(p) => Checkpoint::load(p)?.into_flat_params(&cfg)?,
        None => ws.load_model(name)?,
    };
    let nm = args.get("nm").map(parse_nm).transpose()?;
    let stats = ModelStats::collect_nm(&params, nm);
    println!(
        "overall prunable sparsity: {:.4} ({} weights zeroed)",
        stats.overall_sparsity(),
        stats.pruned_weight_count()
    );
    if nm.is_some() {
        println!("n:m violations: {}", stats.total_nm_violations());
    }
    Ok(())
}

fn cmd_generate(args: &Args) -> Result<()> {
    use sparsegpt::eval::generate::{sample, SampleOptions};
    let ws = Workspace::open()?;
    let name = args.required("config")?;
    let cfg = ws.config(name)?;
    let params = match args.get("ckpt") {
        Some(p) => Checkpoint::load(p)?.into_flat_params(&cfg)?,
        None => ws.load_model(name)?,
    };
    let tok = ws.tokenizer()?;
    let prompt_text = args.get_or("prompt", "the ");
    let prompt = tok.encode(prompt_text);
    let opts = SampleOptions {
        max_tokens: args.usize_or("tokens", 64)?,
        temperature: args.f64_or("temperature", 0.8)?,
        top_k: args.usize_or("top-k", 40)?,
        seed: args.u64_or("seed", 0)?,
    };
    let out = sample(&ws.rt, &params, &prompt, &opts)?;
    println!("{}{}", prompt_text, tok.decode(&out));
    Ok(())
}

fn cmd_e2e(args: &Args) -> Result<()> {
    // a thin wrapper — the fully instrumented driver is examples/e2e_pipeline.rs
    let config = args.get_or("config", "small").to_string();
    let steps = args.usize_or("steps", 300)?;
    println!("running end-to-end for {config} ({steps} steps); see examples/e2e_pipeline.rs");
    let s = steps.to_string();
    let train_args: Vec<String> =
        ["train", "--config", &config, "--steps", &s].iter().map(|x| x.to_string()).collect();
    cmd_train(&Args::parse(&train_args, &[])?)?;
    let prune_args: Vec<String> =
        ["prune", "--config", &config].iter().map(|x| x.to_string()).collect();
    cmd_prune(&Args::parse(&prune_args, &["record-errors"])?)?;
    let eval_args: Vec<String> =
        ["eval", "--config", &config].iter().map(|x| x.to_string()).collect();
    cmd_eval(&Args::parse(&eval_args, &[])?).context("eval after prune")
}
