//! `sparsegpt` — launcher for the SparseGPT reproduction pipeline.
//!
//! Every subcommand parses into a typed `api::JobSpec` and executes
//! through `api::Session`; progress is narrated as structured events.
//! With the global `--json` flag the event stream is machine-readable
//! JSON lines (one object per line, each with a `reason` field); without
//! it the classic human log lines plus result tables are printed.

use anyhow::{anyhow, bail, Result};
use std::path::PathBuf;
use std::time::Duration;

use sparsegpt::api::{
    E2eSpec, EvalSpec, GenDataSpec, GenerateSpec, HumanSink, JobReport, JobSpec, JsonlSink,
    PruneJobSpec, PruneSpec, ServeSpec, Session, StatsSpec, SweepSpec, TrainSpec, ZeroShotSpec,
};
use sparsegpt::cli::{parse_nm, Args, GLOBAL_BOOL_FLAGS};
use sparsegpt::serve::net::{fetch_stats, run_client, send_shutdown, ClientOptions, ClientRequest};
use sparsegpt::coordinator::{PruneMethod, SkipSpec};
use sparsegpt::runtime::BackendKind;
use sparsegpt::sparse::PackFormat;
use sparsegpt::eval::report::{fmt_ppl, Table};
use sparsegpt::eval::zeroshot::ZeroShotTask;
use sparsegpt::solver::sparsegpt_ref::Pattern;

const USAGE: &str = "\
sparsegpt <command> [flags] [--json]

commands:
  gen-data  --out data [--seed 0] [--train-mb 4]
  train     --config <cfg> [--steps 400] [--out checkpoints]
            [--seed 0] [--resume] [--lr <f>] [--log-every 20]
  prune     --config <cfg> [--spec sparsegpt-2:4+4bit]
            [--method sparsegpt|magnitude|adaprune]
            [--sparsity 0.5 | --nm 2:4] [--quant-bits 4] [--damp 0.01]
            [--calib 128] [--calib-seed 0] [--skip attn|fc1|fc2|front|middle|back]
            [--prefix-frac 0.66] [--out <ckpt>] [--suffix -50]
            [--pack] [--pack-out <path.spkt>]
            [--pack-format auto|dense|csr|n:m|q{dense,csr,nm}:<bits>[,g=<cols>]]
            (quantized formats store 3/4/8-bit codes behind the sparse
            index/bitmask streams, e.g. qcsr:4,g=128 for GPTQ-style
            128-column groups; 50% sparse + qcsr:4 ~= 3 bits/weight)
  eval      --config <cfg> [--ckpt <path>] [--max-segments 512]
  zeroshot  --config <cfg> [--ckpt <path>] [--items 100] [--seed 7]
  stats     --config <cfg> [--ckpt <path>] [--nm 2:4]
  generate  --config <cfg> [--ckpt <path>] [--prompt <text>] [--tokens 64]
            [--temperature 0.8] [--top-k 40] [--seed 0]
  sweep     --config <cfg> [--specs sparsegpt-50%,magnitude-50%,sparsegpt-2:4]
            [--dataset <name>[,<name>...]] [--calib 128] [--max-segments 128]
            [--zeroshot-items 0] [--no-dense] [--save] [--ckpt <path>]
  e2e       [--config small] [--steps 300]
  serve     [--config nano] [--spec sparsegpt-50%]
            [--format auto|dense|csr|2:4|qdense:4|qcsr:4[,g=128]|qnm:4]
            [--kv-cache on|off] [--prefill-chunk 32] [--cache-mb 0]
            [--max-prefill-tokens 0] [--workers 0] [--replicas 1]
            [--requests 8] [--tokens 16] [--prompt-len 8] [--arrival-every 1]
            [--max-batch 8] [--max-wait 2] [--queue-cap 64]
            [--temperature 0.8] [--top-k 40] [--seed 0]
            [--damp 0.01] [--calib 32] [--calib-seed 0] [--ckpt <path>]
            [--store <path.spkt>] [--save-store <path.spkt>]
            [--models <name>=<path.spkt>[,<name>=<path.spkt>...]]
            [--model-cache-mb <n>]
            [--listen <host:port>] [--addr-file <path>]
            [--cancel <id>@<step>[+<id>@<step>...]]
            [--snap-every <n>] [--metrics-file <path>]
            (kv-cache on = incremental decode through per-request KV ring
            buffers with chunked prefill; off = the full re-forward
            reference path — token-for-token identical, O(ctx) slower)
            (--listen serves network clients over framed JSON-lines TCP
            instead of the synthetic workload; port 0 picks a free port
            and --addr-file writes the bound address for scripts;
            --cancel scripts synthetic-workload disconnects)
            (--workers 0 shares the process-wide kernel pool sized from
            SPARSEGPT_THREADS at startup; n > 0 gives this serve run a
            private pool of n workers)
            (--replicas n > 1 runs n engine replicas behind an admission
            router: least-outstanding-tokens routing with sticky
            request ownership, per-replica worker pools, the cache
            budget split evenly, weights shared read-only; requests
            are rejected only when every replica's queue is full)
            (--snap-every n emits a metrics-snapshot event every n engine
            steps plus once at drain; --metrics-file writes the final
            snapshot as Prometheus text after the drain)
            (--models registers named .spkt fleet variants of the same
            config, served from one process: network requests route with
            model=<name>, the synthetic workload round-robins across the
            default model and every variant; --model-cache-mb bounds
            their resident weight bytes with LRU eviction, 0 = unlimited)
  client    --addr <host:port> | --addr-file <path>
            [--prompt 1,2,3] [--requests 1] [--tokens 16] [--seed 0]
            [--model <name>[,<name>...]] [--tag cli]
            [--disconnect-after <n>] [--timeout-secs 60]
            [--shutdown] [--shutdown-only] [--stats] [--stats-only]
            (loopback client for a `serve --listen` server: submits
            requests and prints the streamed tokens; with --json every
            raw server frame passes through to stdout. --model routes
            requests to named fleet variants, round-robin when a comma
            list is given — a bare `,`-leading entry means the default
            model. --shutdown drains
            the server once resolved; --shutdown-only only sends the
            drain frame; --disconnect-after drops the socket cold after
            n token frames, exercising disconnect-as-cancellation;
            --stats-only just asks the server for a metrics snapshot and
            prints it — a table, or the raw JSON object with --json —
            and --stats prints the same snapshot after the requests)

global flags:
  --json    emit machine-readable JSON-lines events on stdout
            (one object per line; every object has a \"reason\" field)
  --backend pjrt|reference
            execution backend: compiled PJRT artifacts (default) or the
            pure-Rust reference interpreter, which needs no artifacts and
            runs the full pipeline on a fresh checkout. Also settable via
            SPARSEGPT_BACKEND; the flag wins over the env var.
";

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    if argv.is_empty() {
        eprint!("{USAGE}");
        std::process::exit(2);
    }
    if let Err(e) = run(&argv) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn run(argv: &[String]) -> Result<()> {
    // fail fast on a typo'd SPARSEGPT_THREADS: a bad value must error here,
    // not panic mid-decode (and never silently run single-threaded). The
    // validated count sizes the process-wide worker pool once, up front;
    // kernels never consult the environment again after this point.
    let workers = sparsegpt::sparse::threads::worker_count().map_err(|e| anyhow!(e))?;
    sparsegpt::sparse::WorkerPool::init_global(workers);
    let args = Args::parse(argv, GLOBAL_BOOL_FLAGS)?;
    let cmd = args.positional.first().map(|s| s.as_str()).unwrap_or("");
    if cmd == "client" {
        // pure network client: no workspace, no backend, no job spec
        return run_net_client(&args);
    }
    let spec = spec_from_args(cmd, &args)?;
    let json = args.has("json");

    let mut session = match args.get("backend").map(BackendKind::parse).transpose()? {
        Some(kind) => Session::with_backend(kind),
        None => Session::new(),
    };
    let report = if json {
        session.run(&spec, &mut JsonlSink::stdout())?
    } else {
        session.run(&spec, &mut HumanSink::new())?
    };
    if !json {
        print_tables(&report);
    }
    if args.has("rt-stats") {
        // stderr in --json mode: stdout stays one-JSON-object-per-line;
        // only report when the job actually opened a runtime (gen-data
        // does not, and must not fail here after succeeding)
        let mut emit = |line: String| {
            if json {
                eprintln!("{line}");
            } else {
                println!("{line}");
            }
        };
        match session.opened_workspace() {
            Some(ws) => {
                emit("per-artifact runtime totals (compile / run / marshal seconds):".to_string());
                for (name, s) in ws.rt.stats() {
                    emit(format!(
                        "  {name:<28} x{:<4} compile {:.2} run {:.2} marshal {:.2}",
                        s.runs, s.compile_secs, s.run_secs, s.marshal_secs
                    ));
                }
            }
            None => emit("no runtime stats: this job did not use the runtime".to_string()),
        }
    }
    Ok(())
}

/// Map a subcommand + flags onto its typed job spec. Defaults live in one
/// place — the spec builders — and are read back as the CLI fallbacks.
fn spec_from_args(cmd: &str, args: &Args) -> Result<JobSpec> {
    Ok(match cmd {
        "gen-data" => {
            let mut s = GenDataSpec::default();
            if let Some(out) = args.get("out") {
                s.out = out.into();
            }
            s.seed = args.u64_or("seed", s.seed)?;
            s.train_mb = args.usize_or("train-mb", s.train_mb)?;
            JobSpec::GenData(s)
        }
        "train" => {
            let mut s = TrainSpec::new(args.required("config")?);
            s.steps = args.usize_or("steps", s.steps)?;
            s.seed = args.u64_or("seed", s.seed)?;
            s.log_every = args.usize_or("log-every", s.log_every)?;
            s.lr = args.get("lr").map(|v| v.parse()).transpose()?;
            s.out = args.get("out").map(PathBuf::from);
            s.checkpoint_every = args.usize_or("checkpoint-every", s.checkpoint_every)?;
            s.resume = args.has("resume");
            JobSpec::Train(s)
        }
        "prune" => {
            let mut s = PruneJobSpec::new(args.required("config")?, prune_spec_from_args(args)?);
            s.damp = args.f64_or("damp", s.damp)?;
            s.skip = skip_from_args(args)?;
            s.calib = args.usize_or("calib", s.calib)?;
            s.calib_seed = args.u64_or("calib-seed", s.calib_seed)?;
            s.ckpt = args.get("ckpt").map(PathBuf::from);
            s.record_errors = args.has("record-errors");
            s.save = true;
            s.out = args.get("out").map(PathBuf::from);
            s.suffix = args.get("suffix").map(String::from);
            s.pack = args.has("pack");
            s.pack_out = args.get("pack-out").map(PathBuf::from);
            s.pack_format = PackFormat::parse(args.get_or("pack-format", "auto"))?;
            JobSpec::Prune(s)
        }
        "eval" => {
            let mut s = EvalSpec::new(args.required("config")?);
            s.ckpt = args.get("ckpt").map(PathBuf::from);
            s.max_segments = args.usize_or("max-segments", s.max_segments)?;
            JobSpec::Eval(s)
        }
        "zeroshot" => {
            let mut s = ZeroShotSpec::new(args.required("config")?);
            s.ckpt = args.get("ckpt").map(PathBuf::from);
            s.items = args.usize_or("items", s.items)?;
            s.seed = args.u64_or("seed", s.seed)?;
            s.data_seed = args.u64_or("data-seed", s.data_seed)?;
            JobSpec::ZeroShot(s)
        }
        "stats" => {
            let mut s = StatsSpec::new(args.required("config")?);
            s.ckpt = args.get("ckpt").map(PathBuf::from);
            s.nm = args.get("nm").map(parse_nm).transpose()?;
            JobSpec::Stats(s)
        }
        "generate" => {
            let mut s = GenerateSpec::new(args.required("config")?);
            s.ckpt = args.get("ckpt").map(PathBuf::from);
            if let Some(p) = args.get("prompt") {
                s.prompt = p.to_string();
            }
            s.tokens = args.usize_or("tokens", s.tokens)?;
            s.temperature = args.f64_or("temperature", s.temperature)?;
            s.top_k = args.usize_or("top-k", s.top_k)?;
            s.seed = args.u64_or("seed", s.seed)?;
            JobSpec::Generate(s)
        }
        "sweep" => {
            let mut s = SweepSpec::new(args.required("config")?);
            let list = args.get_or("specs", "sparsegpt-50%,magnitude-50%,sparsegpt-2:4");
            s.variants = list
                .split(',')
                .map(|v| PruneSpec::parse(v.trim()))
                .collect::<Result<Vec<_>>>()?;
            if let Some(ds) = args.get("dataset") {
                // comma list, e.g. --dataset synth-wiki,synth-ptb
                s.datasets = ds.split(',').map(|d| d.trim().to_string()).collect();
            }
            s.include_dense = !args.has("no-dense");
            s.save = args.has("save");
            s.damp = args.f64_or("damp", s.damp)?;
            s.calib = args.usize_or("calib", s.calib)?;
            s.calib_seed = args.u64_or("calib-seed", s.calib_seed)?;
            s.max_segments = args.usize_or("max-segments", s.max_segments)?;
            s.zeroshot_items = args.usize_or("zeroshot-items", s.zeroshot_items)?;
            s.ckpt = args.get("ckpt").map(PathBuf::from);
            JobSpec::Sweep(s)
        }
        "e2e" => {
            let mut s = E2eSpec::new(args.get_or("config", "small"));
            s.steps = args.usize_or("steps", s.steps)?;
            JobSpec::E2e(s)
        }
        "serve" => {
            let mut s = ServeSpec::new(args.get_or("config", "nano"));
            if let Some(label) = args.get("spec") {
                s.prune = PruneSpec::parse(label)?;
            }
            s.format = PackFormat::parse(args.get_or("format", "auto"))?;
            s.kv_cache = match args.get_or("kv-cache", "on") {
                "on" => true,
                "off" => false,
                other => bail!("--kv-cache takes on|off (got {other:?})"),
            };
            s.prefill_chunk = args.usize_or("prefill-chunk", s.prefill_chunk)?;
            s.cache_budget_mb = args.usize_or("cache-mb", s.cache_budget_mb)?;
            s.max_prefill_tokens = args.usize_or("max-prefill-tokens", s.max_prefill_tokens)?;
            s.workers = args.usize_or("workers", s.workers)?;
            s.replicas = args.usize_or("replicas", s.replicas)?;
            if s.replicas == 0 {
                bail!("--replicas takes a positive replica count");
            }
            s.requests = args.usize_or("requests", s.requests)?;
            s.max_new_tokens = args.usize_or("tokens", s.max_new_tokens)?;
            s.prompt_len = args.usize_or("prompt-len", s.prompt_len)?;
            s.arrival_every = args.usize_or("arrival-every", s.arrival_every)?;
            s.max_batch = args.usize_or("max-batch", s.max_batch)?;
            s.max_wait = args.usize_or("max-wait", s.max_wait)?;
            s.queue_cap = args.usize_or("queue-cap", s.queue_cap)?;
            s.temperature = args.f64_or("temperature", s.temperature)?;
            s.top_k = args.usize_or("top-k", s.top_k)?;
            s.seed = args.u64_or("seed", s.seed)?;
            s.damp = args.f64_or("damp", s.damp)?;
            s.calib = args.usize_or("calib", s.calib)?;
            s.calib_seed = args.u64_or("calib-seed", s.calib_seed)?;
            s.ckpt = args.get("ckpt").map(PathBuf::from);
            s.store = args.get("store").map(PathBuf::from);
            s.save_store = args.get("save-store").map(PathBuf::from);
            if let Some(list) = args.get("models") {
                s.models = parse_models(list)?;
            }
            s.model_cache_mb = args.usize_or("model-cache-mb", s.model_cache_mb)?;
            s.listen = args.get("listen").map(String::from);
            s.addr_file = args.get("addr-file").map(PathBuf::from);
            if let Some(list) = args.get("cancel") {
                s.cancel = parse_cancels(list)?;
            }
            s.snap_every = args.usize_or("snap-every", s.snap_every)?;
            s.metrics_file = args.get("metrics-file").map(PathBuf::from);
            JobSpec::Serve(s)
        }
        other => bail!("unknown command {other:?}\n{USAGE}"),
    })
}

/// Parse `--models <name>=<path.spkt>[,<name>=<path.spkt>...]`.
fn parse_models(list: &str) -> Result<Vec<(String, PathBuf)>> {
    let mut out = Vec::new();
    for part in list.split(',') {
        let (name, path) = part
            .split_once('=')
            .ok_or_else(|| anyhow!("--models takes <name>=<path>[,...] (got {part:?})"))?;
        if name.is_empty() || path.is_empty() {
            bail!("--models entry {part:?} needs a non-empty name and path");
        }
        out.push((name.to_string(), PathBuf::from(path)));
    }
    Ok(out)
}

/// Parse `--cancel <id>@<step>[+<id>@<step>...]`.
fn parse_cancels(list: &str) -> Result<Vec<(u64, usize)>> {
    let mut out = Vec::new();
    for part in list.split('+') {
        let (id, step) = part
            .split_once('@')
            .ok_or_else(|| anyhow!("--cancel takes <id>@<step>[+...] (got {part:?})"))?;
        out.push((
            id.parse().map_err(|e| anyhow!("--cancel id in {part:?}: {e}"))?,
            step.parse().map_err(|e| anyhow!("--cancel step in {part:?}: {e}"))?,
        ));
    }
    Ok(out)
}

/// The `client` subcommand: drive a `serve --listen` server over TCP.
/// Deliberately spec-less — no workspace or backend opens, so it runs on
/// a bare checkout against any reachable server.
fn run_net_client(args: &Args) -> Result<()> {
    let json = args.has("json");
    let addr = match args.get("addr") {
        Some(a) => a.to_string(),
        None => {
            let path = args
                .get("addr-file")
                .ok_or_else(|| anyhow!("client needs --addr <host:port> or --addr-file <path>"))?;
            std::fs::read_to_string(path)
                .map_err(|e| anyhow!("reading --addr-file {path:?}: {e}"))?
                .trim()
                .to_string()
        }
    };
    let timeout = Duration::from_secs(args.u64_or("timeout-secs", 60)?);
    if args.has("shutdown-only") {
        send_shutdown(&addr, timeout)?;
        if !json {
            println!("sent shutdown to {addr}");
        }
        return Ok(());
    }
    if args.has("stats-only") {
        let snapshot = fetch_stats(&addr, timeout)?;
        if json {
            println!("{}", snapshot.to_string_compact());
        } else {
            print_stats(&snapshot);
        }
        return Ok(());
    }
    let prompt: Vec<i32> = match args.get("prompt") {
        Some(p) => p
            .split(',')
            .map(|t| t.trim().parse::<i32>().map_err(|e| anyhow!("--prompt: {e}")))
            .collect::<Result<_>>()?,
        None => vec![1, 2, 3, 4],
    };
    let n = args.usize_or("requests", 1)?.max(1);
    let seed = args.u64_or("seed", 0)?;
    let tokens = args.usize_or("tokens", 16)?.max(1);
    let tag = args.get_or("tag", "cli");
    // --model a,b round-robins requests across fleet variants; an empty
    // segment routes to the server's default model
    let routes: Vec<Option<String>> = match args.get("model") {
        Some(list) => list
            .split(',')
            .map(|m| {
                let m = m.trim();
                if m.is_empty() { None } else { Some(m.to_string()) }
            })
            .collect(),
        None => vec![None],
    };
    let requests: Vec<ClientRequest> = (0..n)
        .map(|i| ClientRequest {
            tag: Some(format!("{tag}-{i}")),
            prompt: prompt.clone(),
            max_new_tokens: tokens,
            seed: seed.wrapping_add(i as u64),
            model: routes[i % routes.len()].clone(),
        })
        .collect();
    let disconnect_after = args
        .get("disconnect-after")
        .map(|v| v.parse::<usize>().map_err(|e| anyhow!("--disconnect-after: {e}")))
        .transpose()?;
    let opts = ClientOptions { disconnect_after, shutdown: args.has("shutdown"), timeout };
    let out = run_client(&addr, &requests, &opts, &mut |line| {
        if json {
            println!("{line}");
        }
    })?;
    if !json {
        println!("connected to {addr} (config {}, vocab {})", out.config, out.vocab);
        for (id, stream) in &out.streams {
            let toks: Vec<String> = stream.iter().map(|t| t.to_string()).collect();
            println!("request {id}: [{}]", toks.join(" "));
        }
        println!(
            "finished {} | cancelled {} | rejected {}{}",
            out.finished.len(),
            out.cancelled.len(),
            out.rejected,
            if out.disconnected { " | disconnected mid-stream" } else { "" }
        );
    }
    if args.has("stats") && !out.disconnected {
        let snapshot = fetch_stats(&addr, timeout)?;
        if json {
            println!("{}", snapshot.to_string_compact());
        } else {
            print_stats(&snapshot);
        }
    }
    Ok(())
}

/// Render a metrics snapshot as aligned `name value` lines: scalars
/// verbatim, histograms as their count/sum, workers one line each.
fn print_stats(snapshot: &sparsegpt::util::json::Json) {
    use sparsegpt::util::json::Json;
    let Json::Obj(fields) = snapshot else {
        println!("{}", snapshot.to_string_compact());
        return;
    };
    let fmt_num =
        |v: f64| if v.fract() == 0.0 { format!("{}", v as i64) } else { format!("{v}") };
    for (name, value) in fields {
        match value {
            Json::Num(v) => println!("{name:<32} {}", fmt_num(*v)),
            // histograms carry {buckets, count, sum}
            Json::Obj(h) => {
                let get = |k: &str| h.get(k).and_then(|v| v.as_f64().ok()).unwrap_or(0.0);
                println!(
                    "{name:<32} count {} sum {}",
                    fmt_num(get("count")),
                    fmt_num(get("sum"))
                );
            }
            Json::Arr(workers) => {
                for w in workers {
                    let get = |k: &str| w.opt(k).and_then(|v| v.as_f64().ok()).unwrap_or(0.0);
                    println!(
                        "{name}[{}] busy_ns {} tiles {}",
                        fmt_num(get("worker")),
                        fmt_num(get("busy_ns")),
                        fmt_num(get("tiles"))
                    );
                }
            }
            other => println!("{name:<32} {}", other.to_string_compact()),
        }
    }
}

/// Build the prune method from `--spec <label>` or the granular flags.
fn prune_spec_from_args(args: &Args) -> Result<PruneSpec> {
    if let Some(label) = args.get("spec") {
        for granular in ["method", "sparsity", "nm", "quant-bits"] {
            if args.get(granular).is_some() {
                bail!("--spec conflicts with --{granular}; give one or the other");
            }
        }
        return PruneSpec::parse(label);
    }
    let quant_bits = args.get("quant-bits").map(|b| b.parse()).transpose()?;
    let pattern = match args.get("nm") {
        Some(nm) => {
            let (n, m) = parse_nm(nm)?;
            Pattern::NM(n, m)
        }
        None => Pattern::Unstructured(args.f64_or("sparsity", 0.5)?),
    };
    Ok(match args.get_or("method", "sparsegpt") {
        "sparsegpt" => PruneSpec { method: PruneMethod::SparseGpt { pattern, quant_bits } },
        "magnitude" => PruneSpec { method: PruneMethod::Magnitude { pattern } },
        "adaprune" => match pattern {
            Pattern::Unstructured(p) => PruneSpec { method: PruneMethod::AdaPrune { sparsity: p } },
            _ => bail!("adaprune supports unstructured sparsity only"),
        },
        m => bail!("unknown method {m:?}"),
    })
}

fn skip_from_args(args: &Args) -> Result<SkipSpec> {
    if let Some(f) = args.get("prefix-frac") {
        return Ok(SkipSpec::PrefixFraction(f.parse()?));
    }
    Ok(match args.get("skip") {
        None => SkipSpec::None,
        Some("attn") | Some("fc1") | Some("fc2") => {
            SkipSpec::LayerType(args.get("skip").unwrap().to_string())
        }
        Some("front") => SkipSpec::Third(0),
        Some("middle") => SkipSpec::Third(1),
        Some("back") => SkipSpec::Third(2),
        Some(s) => bail!("unknown --skip {s:?}"),
    })
}

/// Human-mode result tables (the event stream carries the same data as
/// `eval-result` / `matrix-report` / `zeroshot-result` events in --json).
fn print_tables(report: &JobReport) {
    match report {
        JobReport::Eval(r) => {
            let mut table =
                Table::new(&format!("perplexity: {}", r.config), &["dataset", "ppl", "tokens"]);
            for row in &r.rows {
                table.row(vec![row.dataset.clone(), fmt_ppl(row.ppl), row.tokens.to_string()]);
            }
            print!("{}", table.render());
        }
        JobReport::ZeroShot(r) => {
            print!("{}", zeroshot_table(r).render());
        }
        JobReport::Sweep(r) => {
            print!("{}", sweep_table(r).render());
        }
        JobReport::Serve(r) => {
            let mut table = Table::new(
                &format!(
                    "serve: {} [{}] density {:.3} ({}) {:.2} bits/w kv-cache {}",
                    r.config,
                    r.label,
                    r.density,
                    r.formats,
                    r.effective_bits,
                    if r.kv_cache { "on" } else { "off" }
                ),
                &[
                    "request", "prompt", "tokens", "joined", "finished", "ttft-ms", "gap-p50-ms",
                    "gap-p95-ms",
                ],
            );
            for req in &r.requests {
                table.row(vec![
                    req.id.to_string(),
                    req.prompt_tokens.to_string(),
                    req.tokens.len().to_string(),
                    req.joined_step.to_string(),
                    req.finished_step.to_string(),
                    format!("{:.1}", req.ttft_secs * 1e3),
                    format!("{:.2}", req.gap_p50_secs * 1e3),
                    format!("{:.2}", req.gap_p95_secs * 1e3),
                ]);
            }
            print!("{}", table.render());
            if let Some(addr) = &r.listen {
                println!("served over TCP on {addr}");
            }
            println!(
                "{} tokens in {} steps, {:.2}s decode -> {:.1} tok/s",
                r.tokens, r.steps, r.decode_secs, r.tokens_per_sec
            );
            println!(
                "ttft p50 {:.1} ms / p95 {:.1} ms | {} cancelled, {} rejected",
                r.ttft_p50_secs * 1e3,
                r.ttft_p95_secs * 1e3,
                r.cancelled,
                r.rejected
            );
            if r.kv_cache {
                println!(
                    "prefill: {} tokens in {:.2}s | {} cache evictions | peak cache {} KiB",
                    r.prefill_tokens,
                    r.prefill_secs,
                    r.cache_evictions,
                    r.peak_cache_bytes / 1024
                );
            }
        }
        JobReport::E2e(r) => {
            if let Some(t) = &r.train {
                if !t.losses.is_empty() {
                    println!("\nloss curve (step, loss):");
                    for (s, l) in &t.losses {
                        println!("  {s:>6}  {l:.4}");
                    }
                }
            }
            print!("{}", sweep_table(&r.sweep).render());
        }
        _ => {}
    }
}

fn zeroshot_table(r: &sparsegpt::api::ZeroShotReport) -> Table {
    let mut table = Table::new(&format!("zero-shot: {}", r.config), &["task", "accuracy"]);
    for (task, acc) in &r.rows {
        table.row(vec![task.clone(), format!("{:.1}%", acc * 100.0)]);
    }
    table.row(vec!["avg".into(), format!("{:.1}%", r.avg * 100.0)]);
    table
}

fn sweep_table(r: &sparsegpt::api::SweepReport) -> Table {
    let mut header: Vec<String> = vec!["variant".into(), "sparsity".into()];
    let datasets: Vec<String> = r
        .all_rows()
        .next()
        .map(|v| v.ppl.keys().cloned().collect())
        .unwrap_or_default();
    header.extend(datasets.iter().cloned());
    let has_zs = r.all_rows().any(|v| v.zeroshot.is_some());
    if has_zs {
        for task in ZeroShotTask::ALL {
            header.push(task.name().to_string());
        }
        header.push("zs-avg".into());
    }
    let hdr: Vec<&str> = header.iter().map(|s| s.as_str()).collect();
    let mut table = Table::new(&format!("sweep: {}", r.config), &hdr);
    for v in r.all_rows() {
        let mut cells = vec![v.label.clone(), format!("{:.3}", v.sparsity)];
        for ds in &datasets {
            cells.push(v.ppl.get(ds).map(|p| fmt_ppl(*p)).unwrap_or_else(|| "-".into()));
        }
        if has_zs {
            match &v.zeroshot {
                Some(zs) => {
                    for (_, acc) in &zs.rows {
                        cells.push(format!("{:.1}%", acc * 100.0));
                    }
                    cells.push(format!("{:.1}%", zs.avg * 100.0));
                }
                None => {
                    for _ in 0..=ZeroShotTask::ALL.len() {
                        cells.push("-".into());
                    }
                }
            }
        }
        table.row(cells);
    }
    table
}
