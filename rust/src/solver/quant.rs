//! RTN (round-to-nearest) quantization on per-row asymmetric min/max grids.
//!
//! Matches `quant_grid` in `python/compile/kernels/ref.py` (and the grid the
//! solver artifacts compute internally): the grid always contains zero so
//! pruned weights stay exactly representable. Used stand-alone as the RTN
//! baseline and inside the reference solver for the joint mode (Eq. 7).

use crate::tensor::Tensor;

#[derive(Clone, Debug)]
pub struct QuantGrid {
    pub levels: u32,
    /// per-row (scale, zero-point)
    pub rows: Vec<(f32, f32)>,
}

impl QuantGrid {
    /// Build the per-row grid from the ORIGINAL weights (as the paper /
    /// GPTQ do — the grid is fixed before error propagation shifts values).
    pub fn from_weights(w: &Tensor, levels: u32) -> QuantGrid {
        assert!(levels > 0);
        let rows = (0..w.rows())
            .map(|r| {
                let row = w.row(r);
                let lo = row.iter().fold(0.0f32, |a, &b| a.min(b));
                let hi = row.iter().fold(0.0f32, |a, &b| a.max(b));
                let mut scale = (hi - lo) / levels as f32;
                if scale <= 0.0 {
                    scale = 1.0;
                }
                let zero = (-lo / scale).round();
                (scale, zero)
            })
            .collect();
        QuantGrid { levels, rows }
    }

    pub fn quantize_one(&self, row: usize, v: f32) -> f32 {
        let (scale, zero) = self.rows[row];
        let q = (v / scale + zero).round().clamp(0.0, self.levels as f32);
        scale * (q - zero)
    }

    /// Quantize a whole matrix (the plain RTN baseline).
    pub fn quantize(&self, w: &Tensor) -> Tensor {
        let mut out = w.clone();
        for r in 0..w.rows() {
            for v in out.row_mut(r) {
                *v = self.quantize_one(r, *v);
            }
        }
        out
    }
}

/// Effective storage bits per weight for "p-sparse + b-bit + bitmask"
/// compression (the paper's size-equivalence argument in Fig. 6:
/// 50% sparse + 4-bit + 1-bit mask == 3 bits/weight).
pub fn effective_bits(sparsity: f64, bits: f64) -> f64 {
    (1.0 - sparsity) * bits + 1.0
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Rng;

    #[test]
    fn zero_always_representable() {
        let mut rng = Rng::new(0);
        let w = Tensor::new(vec![8, 16], (0..128).map(|_| rng.normal_f32() + 0.5).collect());
        let g = QuantGrid::from_weights(&w, 15);
        for r in 0..8 {
            assert_eq!(g.quantize_one(r, 0.0), 0.0);
        }
    }

    #[test]
    fn quantization_error_bounded_by_half_step() {
        let mut rng = Rng::new(1);
        let w = Tensor::new(vec![4, 64], (0..256).map(|_| rng.normal_f32()).collect());
        let g = QuantGrid::from_weights(&w, 255);
        let q = g.quantize(&w);
        for r in 0..4 {
            let (scale, _) = g.rows[r];
            for (a, b) in w.row(r).iter().zip(q.row(r)) {
                assert!((a - b).abs() <= 0.5 * scale + 1e-6);
            }
        }
    }

    #[test]
    fn more_bits_less_error() {
        let mut rng = Rng::new(2);
        let w = Tensor::new(vec![4, 64], (0..256).map(|_| rng.normal_f32()).collect());
        let e4 = {
            let q = QuantGrid::from_weights(&w, 15).quantize(&w);
            w.data().iter().zip(q.data()).map(|(a, b)| ((a - b) as f64).powi(2)).sum::<f64>()
        };
        let e2 = {
            let q = QuantGrid::from_weights(&w, 3).quantize(&w);
            w.data().iter().zip(q.data()).map(|(a, b)| ((a - b) as f64).powi(2)).sum::<f64>()
        };
        assert!(e4 < e2);
    }

    #[test]
    fn effective_bits_equivalence() {
        assert!((effective_bits(0.5, 4.0) - 3.0).abs() < 1e-12);
        assert!((effective_bits(0.5, 3.0) - 2.5).abs() < 1e-12);
    }

    #[test]
    fn constant_row_handled() {
        let w = Tensor::new(vec![1, 4], vec![0.0; 4]);
        let g = QuantGrid::from_weights(&w, 15);
        assert_eq!(g.quantize_one(0, 0.0), 0.0);
    }
}
