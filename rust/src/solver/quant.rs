//! RTN (round-to-nearest) quantization on asymmetric min/max grids —
//! per-row (the solver's joint mode, Eq. 7) or GPTQ-style grouped: one
//! (scale, zero) pair per `group_cols` consecutive columns of each row.
//!
//! Matches `quant_grid` in `python/compile/kernels/ref.py`. `lo`/`hi` fold
//! from the group's actual minimum/maximum (NOT from 0.0): an all-positive
//! group gets its true minimum as `lo` instead of wasting grid range on
//! `[0, min)`, and symmetrically for all-negative groups. Zero stays
//! exactly representable whenever the group spans zero — which every group
//! containing a pruned weight does, so packed sparse matrices never lose
//! their zeros (the packed formats additionally store zeros structurally,
//! outside the grid).
//!
//! Used stand-alone as the RTN baseline, inside the reference solver for
//! the joint mode, and by the quantized packed formats
//! (`crate::sparse::quant`), whose u8 code streams round-trip through
//! [`QuantGrid::encode`] / [`QuantGrid::decode`].

use crate::tensor::Tensor;

#[derive(Clone, Debug, PartialEq)]
pub struct QuantGrid {
    pub levels: u32,
    /// columns covered by one (scale, zero) pair; `cols` for per-row grids
    pub group_cols: usize,
    pub cols: usize,
    /// (scale, zero) per (row, column-group), row-major
    pub rows: Vec<(f32, f32)>,
}

impl QuantGrid {
    /// Build the per-row grid from the ORIGINAL weights (as the paper /
    /// GPTQ do — the grid is fixed before error propagation shifts values).
    pub fn from_weights(w: &Tensor, levels: u32) -> QuantGrid {
        QuantGrid::from_weights_grouped(w, levels, 0)
    }

    /// Grouped grids: one (scale, zero) pair per `group_cols` consecutive
    /// columns of each row; `0` (or >= cols) collapses to one pair per row.
    pub fn from_weights_grouped(w: &Tensor, levels: u32, group_cols: usize) -> QuantGrid {
        assert!(levels > 0);
        let cols = w.cols();
        let group_cols = if group_cols == 0 || group_cols > cols { cols } else { group_cols };
        let groups = cols.div_ceil(group_cols);
        let mut rows = Vec::with_capacity(w.rows() * groups);
        for r in 0..w.rows() {
            let row = w.row(r);
            for g in 0..groups {
                let seg = &row[g * group_cols..cols.min((g + 1) * group_cols)];
                // fold from the first element, not from 0.0: all-positive
                // (or all-negative) groups get their true lo/hi
                let lo = seg.iter().copied().fold(seg[0], f32::min);
                let hi = seg.iter().copied().fold(seg[0], f32::max);
                let mut scale = (hi - lo) / levels as f32;
                if scale <= 0.0 {
                    scale = 1.0;
                }
                let zero = (-lo / scale).round();
                rows.push((scale, zero));
            }
        }
        QuantGrid { levels, group_cols, cols, rows }
    }

    fn groups_per_row(&self) -> usize {
        self.cols.div_ceil(self.group_cols)
    }

    /// The (scale, zero) pair governing column `col` of row `row`.
    #[inline]
    pub fn scale_zero(&self, row: usize, col: usize) -> (f32, f32) {
        self.rows[row * self.groups_per_row() + col / self.group_cols]
    }

    /// The integer code of `v` on its (row, col) grid. u8-storable —
    /// requires `levels <= 255` (the packed formats' 2..=8-bit regime).
    #[inline]
    pub fn encode(&self, row: usize, col: usize, v: f32) -> u8 {
        debug_assert!(self.levels <= u8::MAX as u32);
        let (scale, zero) = self.scale_zero(row, col);
        (v / scale + zero).round().clamp(0.0, self.levels as f32) as u8
    }

    /// Dequantize a stored code: `scale * (code - zero)` — the exact f32
    /// operation the dequant-fused kernels perform, bit-identical to
    /// [`quantize_at`] of the value the code came from (the testability
    /// invariant `tests/quant_parity.rs` pins).
    ///
    /// [`quantize_at`]: QuantGrid::quantize_at
    #[inline]
    pub fn decode(&self, row: usize, col: usize, code: u8) -> f32 {
        let (scale, zero) = self.scale_zero(row, col);
        scale * (code as f32 - zero)
    }

    /// Round `v` to its nearest (row, col) grid point.
    #[inline]
    pub fn quantize_at(&self, row: usize, col: usize, v: f32) -> f32 {
        let (scale, zero) = self.scale_zero(row, col);
        let q = (v / scale + zero).round().clamp(0.0, self.levels as f32);
        scale * (q - zero)
    }

    /// Per-row grids (the solver's joint mode): round on row `row`'s grid.
    pub fn quantize_one(&self, row: usize, v: f32) -> f32 {
        self.quantize_at(row, 0, v)
    }

    /// Quantize a whole matrix (the plain RTN baseline).
    pub fn quantize(&self, w: &Tensor) -> Tensor {
        let mut out = w.clone();
        for r in 0..w.rows() {
            for (c, v) in out.row_mut(r).iter_mut().enumerate() {
                *v = self.quantize_at(r, c, *v);
            }
        }
        out
    }

    /// Quantize only surviving (nonzero) weights, preserving pruned zeros
    /// exactly — the reference semantics of the quantized packed formats,
    /// which store zeros structurally (mask/index streams) rather than as
    /// grid codes.
    pub fn quantize_surviving(&self, w: &Tensor) -> Tensor {
        let mut out = w.clone();
        for r in 0..w.rows() {
            for (c, v) in out.row_mut(r).iter_mut().enumerate() {
                if *v != 0.0 {
                    *v = self.quantize_at(r, c, *v);
                }
            }
        }
        out
    }
}

/// Effective storage bits per weight for "p-sparse + b-bit + bitmask"
/// compression (the paper's size-equivalence argument in Fig. 6:
/// 50% sparse + 4-bit + 1-bit mask == 3 bits/weight).
pub fn effective_bits(sparsity: f64, bits: f64) -> f64 {
    (1.0 - sparsity) * bits + 1.0
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Rng;

    #[test]
    fn zero_representable_when_rows_span_zero() {
        // every group holding a pruned weight spans zero, so 0.0 is on its
        // grid — pin that with rows forced to carry both signs
        let mut rng = Rng::new(0);
        let mut w = Tensor::new(vec![8, 16], (0..128).map(|_| rng.normal_f32() + 0.5).collect());
        for r in 0..8 {
            w.set2(r, 0, -1.0);
            w.set2(r, 1, 1.0);
        }
        let g = QuantGrid::from_weights(&w, 15);
        for r in 0..8 {
            assert_eq!(g.quantize_one(r, 0.0), 0.0);
        }
    }

    #[test]
    fn all_positive_and_all_negative_rows_use_tight_grids() {
        // regression: lo/hi used to fold from 0.0, so an all-positive row
        // got lo = 0.0 and wasted half its range on [0, min) (and an
        // all-negative row the mirror image). The fixed grid puts all 16
        // of these evenly-spaced values exactly on grid points.
        let pos: Vec<f32> = (0..16).map(|j| 1.0 + 0.1 * j as f32).collect();
        let neg: Vec<f32> = pos.iter().map(|v| -v).collect();
        let w = Tensor::new(vec![2, 16], pos.iter().chain(&neg).copied().collect());
        let g = QuantGrid::from_weights(&w, 15);
        let (s0, _) = g.rows[0];
        let (s1, _) = g.rows[1];
        assert!((s0 - 0.1).abs() < 1e-6, "all-positive row scale {s0} != (hi-lo)/levels");
        assert!((s1 - 0.1).abs() < 1e-6, "all-negative row scale {s1}");
        for (r, row) in [&pos, &neg].into_iter().enumerate() {
            for &v in row {
                assert!((g.quantize_one(r, v) - v).abs() < 1e-6, "row {r}: {v} off-grid");
            }
        }
    }

    #[test]
    fn grouped_grid_indexes_pairs_per_column_group() {
        // two rows, groups of 4: one tight grid per group, 4 pairs total
        // per row; values land exactly on their own group's grid
        let row0: Vec<f32> = vec![1.0, 1.5, 2.0, 2.5, -30.0, -20.0, -10.0, 0.0];
        let row1: Vec<f32> = row0.iter().map(|v| v * 2.0).collect();
        let w = Tensor::new(vec![2, 8], row0.iter().chain(&row1).copied().collect());
        let g = QuantGrid::from_weights_grouped(&w, 15, 4);
        assert_eq!(g.rows.len(), 4);
        assert_eq!(g.group_cols, 4);
        assert_eq!(g.scale_zero(0, 5), g.rows[1]);
        assert_eq!(g.scale_zero(1, 0), g.rows[2]);
        let (s, _) = g.scale_zero(0, 0);
        assert!((s - 1.5 / 15.0).abs() < 1e-6, "group 0 scale {s}");
        for r in 0..2 {
            for c in 0..8 {
                let v = w.at2(r, c);
                assert!((g.quantize_at(r, c, v) - v).abs() < 1e-4, "({r},{c}) {v}");
            }
        }
    }

    #[test]
    fn encode_decode_round_trip_is_bitwise_quantize_at() {
        // the dequant-fused kernels replay decode(encode(v)); that must be
        // bit-identical to the f32 quantize_at reference path
        let mut rng = Rng::new(3);
        let w = Tensor::new(vec![4, 32], (0..128).map(|_| rng.normal_f32()).collect());
        for levels in [3u32, 7, 15, 255] {
            for group in [0usize, 8] {
                let g = QuantGrid::from_weights_grouped(&w, levels, group);
                for r in 0..4 {
                    for c in 0..32 {
                        let v = w.at2(r, c);
                        let direct = g.quantize_at(r, c, v);
                        let coded = g.decode(r, c, g.encode(r, c, v));
                        assert_eq!(direct.to_bits(), coded.to_bits(), "({r},{c}) {v}");
                    }
                }
            }
        }
    }

    #[test]
    fn quantize_surviving_preserves_exact_zeros() {
        // pruned (zero) weights never touch the grid: quantize_surviving
        // rounds survivors only, whatever the grid looks like
        let w = Tensor::new(vec![1, 4], vec![1.0, 0.0, 2.0, 0.0]);
        let g = QuantGrid::from_weights(&w, 15);
        let q = g.quantize_surviving(&w);
        assert_eq!(q.at2(0, 1), 0.0);
        assert_eq!(q.at2(0, 3), 0.0);
        assert!((q.at2(0, 0) - 1.0).abs() < 1e-6);
        assert!((q.at2(0, 2) - 2.0).abs() < 1e-6);
    }

    #[test]
    fn quantization_error_bounded_by_half_step() {
        let mut rng = Rng::new(1);
        let w = Tensor::new(vec![4, 64], (0..256).map(|_| rng.normal_f32()).collect());
        let g = QuantGrid::from_weights(&w, 255);
        let q = g.quantize(&w);
        for r in 0..4 {
            let (scale, _) = g.rows[r];
            for (a, b) in w.row(r).iter().zip(q.row(r)) {
                assert!((a - b).abs() <= 0.5 * scale + 1e-6);
            }
        }
    }

    #[test]
    fn more_bits_less_error() {
        let mut rng = Rng::new(2);
        let w = Tensor::new(vec![4, 64], (0..256).map(|_| rng.normal_f32()).collect());
        let e4 = {
            let q = QuantGrid::from_weights(&w, 15).quantize(&w);
            w.data().iter().zip(q.data()).map(|(a, b)| ((a - b) as f64).powi(2)).sum::<f64>()
        };
        let e2 = {
            let q = QuantGrid::from_weights(&w, 3).quantize(&w);
            w.data().iter().zip(q.data()).map(|(a, b)| ((a - b) as f64).powi(2)).sum::<f64>()
        };
        assert!(e4 < e2);
    }

    #[test]
    fn grouped_error_bounded_by_the_groups_own_half_step() {
        // each group's scale fits its local range, so the error bound
        // tightens from half the row step to half the group step
        let mut rng = Rng::new(4);
        let w = Tensor::new(vec![4, 64], (0..256).map(|_| 3.0 * rng.normal_f32()).collect());
        let g = QuantGrid::from_weights_grouped(&w, 15, 16);
        let q = g.quantize(&w);
        for r in 0..4 {
            for c in 0..64 {
                let (scale, _) = g.scale_zero(r, c);
                let err = (w.at2(r, c) - q.at2(r, c)).abs();
                assert!(err <= 0.5 * scale + 1e-6, "({r},{c}): {err} vs scale {scale}");
            }
        }
    }

    #[test]
    fn effective_bits_equivalence() {
        assert!((effective_bits(0.5, 4.0) - 3.0).abs() < 1e-12);
        assert!((effective_bits(0.5, 3.0) - 2.5).abs() < 1e-12);
    }

    #[test]
    fn constant_row_handled() {
        let w = Tensor::new(vec![1, 4], vec![0.0; 4]);
        let g = QuantGrid::from_weights(&w, 15);
        assert_eq!(g.quantize_one(0, 0.0), 0.0);
    }
}
