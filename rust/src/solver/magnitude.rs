//! Magnitude pruning (Zhu & Gupta 2017), the paper's main scalable baseline
//! — applied layer-wise: zero the smallest-|w| entries, no reconstruction.

use crate::tensor::Tensor;

/// Unstructured layer-wise magnitude pruning to sparsity `p`.
/// Returns (pruned weights, keep mask); exactly round(p * numel) zeros
/// (stable tie-break by index, matching the solver's rank semantics).
pub fn magnitude_prune(w: &Tensor, p: f64) -> (Tensor, Tensor) {
    let n = w.len();
    let k = (p * n as f64).round() as usize;
    let mut order: Vec<usize> = (0..n).collect();
    let d = w.data();
    order.sort_by(|&a, &b| {
        d[a].abs().partial_cmp(&d[b].abs()).unwrap().then(a.cmp(&b))
    });
    let mut keep = vec![1.0f32; n];
    for &i in order.iter().take(k) {
        keep[i] = 0.0;
    }
    let pruned: Vec<f32> = d.iter().zip(&keep).map(|(x, m)| x * m).collect();
    (
        Tensor::new(w.shape().to_vec(), pruned),
        Tensor::new(w.shape().to_vec(), keep),
    )
}

/// n:m magnitude pruning: per row, per group of m consecutive columns, zero
/// the n smallest-|w| entries.
pub fn magnitude_prune_nm(w: &Tensor, n: usize, m: usize) -> (Tensor, Tensor) {
    let (rows, cols) = (w.rows(), w.cols());
    let mut keep = vec![1.0f32; rows * cols];
    let full = cols / m * m;
    for r in 0..rows {
        let row = w.row(r);
        for g in (0..full).step_by(m) {
            let mut idx: Vec<usize> = (g..g + m).collect();
            idx.sort_by(|&a, &b| {
                row[a].abs().partial_cmp(&row[b].abs()).unwrap().then(a.cmp(&b))
            });
            for &j in idx.iter().take(n) {
                keep[r * cols + j] = 0.0;
            }
        }
    }
    let pruned: Vec<f32> = w.data().iter().zip(&keep).map(|(x, m)| x * m).collect();
    (
        Tensor::new(w.shape().to_vec(), pruned),
        Tensor::new(w.shape().to_vec(), keep),
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Rng;

    #[test]
    fn exact_count_and_smallest_removed() {
        let w = Tensor::new(vec![2, 4], vec![0.1, -3.0, 0.2, 4.0, -0.05, 2.0, 1.0, -0.3]);
        let (pruned, mask) = magnitude_prune(&w, 0.5);
        assert_eq!(mask.data().iter().filter(|&&m| m == 0.0).count(), 4);
        // the four smallest |w|: 0.05, 0.1, 0.2, 0.3
        assert_eq!(pruned.data(), &[0.0, -3.0, 0.0, 4.0, 0.0, 2.0, 1.0, 0.0]);
    }

    #[test]
    fn nm_groups_exact() {
        let mut rng = Rng::new(0);
        let w = Tensor::new(vec![8, 16], (0..128).map(|_| rng.normal_f32()).collect());
        let (pruned, mask) = magnitude_prune_nm(&w, 2, 4);
        for r in 0..8 {
            for g in (0..16).step_by(4) {
                let kept: f32 = (g..g + 4).map(|j| mask.at2(r, j)).sum();
                assert_eq!(kept, 2.0);
                // kept entries are the 2 largest |w| in the group
                let mut vals: Vec<f32> = (g..g + 4).map(|j| w.at2(r, j).abs()).collect();
                vals.sort_by(|a, b| b.partial_cmp(a).unwrap());
                for j in g..g + 4 {
                    if mask.at2(r, j) == 1.0 {
                        assert!(w.at2(r, j).abs() >= vals[1] - 1e-6);
                    }
                }
            }
        }
        assert!((pruned.sparsity() - 0.5).abs() < 1e-9);
    }

    #[test]
    fn p_zero_and_one_edges() {
        let mut rng = Rng::new(1);
        let w = Tensor::new(vec![4, 4], (0..16).map(|_| rng.normal_f32()).collect());
        let (p0, m0) = magnitude_prune(&w, 0.0);
        assert_eq!(p0, w);
        assert!(m0.data().iter().all(|&m| m == 1.0));
        let (p1, _) = magnitude_prune(&w, 1.0);
        assert!(p1.data().iter().all(|&x| x == 0.0));
    }
}
