//! Exact layer reconstruction for a fixed mask (the "much more expensive"
//! comparator of the Fig-11 approximation-quality experiment).
//!
//! For each row i with keep-set M_i, the optimal reconstruction solves the
//! masked normal equations (Eq. 2):
//!     w_hat[M_i] = (H_{M_i})^{-1} (H w)[M_i-restricted rhs]
//! i.e. minimize ||(w - w_hat) X||^2 over w_hat supported on M_i, giving
//!     H_{M_i} w_hat_{M_i} = (H w)_{M_i}.
//! Cost is O(d_row * d_col^3) — the very scaling SparseGPT exists to avoid —
//! so callers subsample rows on larger layers.

use anyhow::{anyhow, Result};

use crate::tensor::linalg::{spd_solve, Mat};
use crate::tensor::Tensor;

/// Exact per-row optimal reconstruction for `rows` (all rows if None),
/// given the *dampened* Hessian `h` (d_col x d_col) and keep mask.
/// Rows not in `rows` are left at mask-and-zero.
pub fn exact_reconstruction(
    w: &Tensor,
    mask: &Tensor,
    h: &Tensor,
    rows: Option<&[usize]>,
) -> Result<Tensor> {
    let (d_row, d_col) = (w.rows(), w.cols());
    if mask.shape() != w.shape() || h.shape() != [d_col, d_col] {
        return Err(anyhow!("shape mismatch"));
    }
    let hf = Mat::from_f32(d_col, h.data());
    let all_rows: Vec<usize>;
    let rows = match rows {
        Some(r) => r,
        None => {
            all_rows = (0..d_row).collect();
            &all_rows
        }
    };
    // start from mask-and-zero
    let mut out: Vec<f32> = w.data().iter().zip(mask.data()).map(|(x, m)| x * m).collect();

    for &r in rows {
        let keep: Vec<usize> =
            (0..d_col).filter(|&j| mask.at2(r, j) != 0.0).collect();
        let kn = keep.len();
        if kn == 0 {
            continue;
        }
        // H_M (kn x kn) and rhs = (H w)_M
        let mut hm = Mat::zeros(kn);
        for (a, &ja) in keep.iter().enumerate() {
            for (b, &jb) in keep.iter().enumerate() {
                hm.set(a, b, hf.at(ja, jb));
            }
        }
        let mut rhs = vec![0.0f64; kn];
        for (a, &ja) in keep.iter().enumerate() {
            let mut s = 0.0f64;
            for j in 0..d_col {
                s += hf.at(ja, j) * w.at2(r, j) as f64;
            }
            rhs[a] = s;
        }
        let sol = spd_solve(&hm, &rhs)
            .ok_or_else(|| anyhow!("masked Hessian not SPD for row {r} (add dampening)"))?;
        for (a, &ja) in keep.iter().enumerate() {
            out[r * d_col + ja] = sol[a] as f32;
        }
    }
    Ok(Tensor::new(vec![d_row, d_col], out))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::solver::hessian::{dampened_hinv_chol_f64, layer_sq_error};
    use crate::solver::magnitude::magnitude_prune;
    use crate::solver::sparsegpt_ref::{ref_sparsegpt, Pattern};
    use crate::tensor::linalg::dampen;
    use crate::util::prng::Rng;

    fn problem(seed: u64, r: usize, c: usize) -> (Tensor, Tensor) {
        let mut rng = Rng::new(seed);
        let w = Tensor::new(vec![r, c], (0..r * c).map(|_| rng.normal_f32()).collect());
        let n = 2 * c;
        let x = Tensor::new(vec![n, c], (0..n * c).map(|_| rng.normal_f32()).collect());
        let h = x.transpose2().matmul(&x);
        (w, h)
    }

    fn dampened(h: &Tensor) -> Tensor {
        let m = dampen(&Mat::from_f32(h.rows(), h.data()), 0.01);
        Tensor::new(vec![h.rows(), h.cols()], m.to_f32())
    }

    #[test]
    fn exact_beats_or_matches_sparsegpt() {
        let (w, h) = problem(0, 24, 48);
        let hd = dampened(&h);
        let hc = dampened_hinv_chol_f64(&h, 0.01).unwrap();
        let (ws, mask) = ref_sparsegpt(&w, &hc, Pattern::Unstructured(0.5), 0, 128);
        let we = exact_reconstruction(&w, &mask, &hd, None).unwrap();
        let e_exact = layer_sq_error(&w, &we, &hd);
        let e_sgpt = layer_sq_error(&w, &ws, &hd);
        assert!(
            e_exact <= e_sgpt * (1.0 + 1e-6),
            "exact {e_exact} must not exceed sparsegpt {e_sgpt}"
        );
        // and both beat mask-and-zero
        let wz: Vec<f32> = w.data().iter().zip(mask.data()).map(|(x, m)| x * m).collect();
        let wz = Tensor::new(vec![24, 48], wz);
        assert!(e_exact < layer_sq_error(&w, &wz, &hd));
    }

    #[test]
    fn exact_satisfies_normal_equations() {
        let (w, h) = problem(1, 6, 16);
        let hd = dampened(&h);
        let (_, mask) = magnitude_prune(&w, 0.5);
        let we = exact_reconstruction(&w, &mask, &hd, None).unwrap();
        // residual (w - we) H must vanish on the kept coordinates
        for r in 0..6 {
            for j in 0..16 {
                if mask.at2(r, j) == 1.0 {
                    let mut g = 0.0f64;
                    for k in 0..16 {
                        g += (w.at2(r, k) - we.at2(r, k)) as f64 * hd.at2(k, j) as f64;
                    }
                    assert!(g.abs() < 1e-2, "row {r} col {j}: grad {g}");
                }
            }
        }
    }

    #[test]
    fn row_subsampling_leaves_other_rows_masked() {
        let (w, h) = problem(2, 8, 12);
        let hd = dampened(&h);
        let (_, mask) = magnitude_prune(&w, 0.5);
        let we = exact_reconstruction(&w, &mask, &hd, Some(&[0, 3])).unwrap();
        for r in [1usize, 2, 4, 5, 6, 7] {
            for j in 0..12 {
                assert_eq!(we.at2(r, j), w.at2(r, j) * mask.at2(r, j));
            }
        }
    }
}
