//! Pure-Rust f64 reference implementation of the SparseGPT layer solver
//! (Algorithm 1) — a third, independent transcription (besides the Pallas
//! kernel path and the NumPy oracle) used to cross-validate the HLO
//! artifacts end-to-end from the Rust side, and as the solver for shapes
//! that have no artifact (e.g. property tests on odd sizes).
//!
//! Semantics are identical to `python/compile/kernels/ref.py`:
//! upper Cholesky factor `hc` of the dampened H^{-1}; per-Bs-block adaptive
//! mask selection with stable-rank tie-breaks; rightward OBS updates with
//! lazy trailing application; optional per-row RTN grid for joint
//! sparsification + quantization (Eq. 7).

use crate::solver::quant::QuantGrid;
use crate::tensor::Tensor;

#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Pattern {
    /// target sparsity in [0, 1)
    Unstructured(f64),
    /// n zeros per m consecutive weights, per row
    NM(usize, usize),
}

/// Stable ranks: rank[i] = position of element i in a stable ascending sort.
fn stable_ranks(xs: &[f64]) -> Vec<usize> {
    let mut order: Vec<usize> = (0..xs.len()).collect();
    order.sort_by(|&a, &b| xs[a].partial_cmp(&xs[b]).unwrap().then(a.cmp(&b)));
    let mut ranks = vec![0usize; xs.len()];
    for (r, &i) in order.iter().enumerate() {
        ranks[i] = r;
    }
    ranks
}

/// Run Algorithm 1 on one layer. Returns (w_hat, keep_mask) as f32 tensors.
/// `quant_levels = 0` disables quantization; `blocksize` is both the lazy
/// update window B and the mask-selection blocksize Bs (the production
/// configuration; the Fig-10 ablation uses the jnp artifacts instead).
pub fn ref_sparsegpt(
    w: &Tensor,
    hc: &Tensor,
    pattern: Pattern,
    quant_levels: u32,
    blocksize: usize,
) -> (Tensor, Tensor) {
    let (d_row, d_col) = (w.rows(), w.cols());
    assert_eq!(hc.shape(), &[d_col, d_col]);
    let b = blocksize.min(d_col);
    let mut wf: Vec<f64> = w.data().iter().map(|&x| x as f64).collect();
    let hcf: Vec<f64> = hc.data().iter().map(|&x| x as f64).collect();
    let diag: Vec<f64> = (0..d_col).map(|j| hcf[j * d_col + j]).collect();
    let mut keep = vec![1.0f64; d_row * d_col];

    let grid = (quant_levels > 0).then(|| QuantGrid::from_weights(w, quant_levels));
    let frozen = |v: f64, k: f64, row: usize| -> f64 {
        match &grid {
            Some(g) => k * g.quantize_one(row, v as f32) as f64,
            None => k * v,
        }
    };

    let mut i = 0;
    while i < d_col {
        let ib = (i + b).min(d_col);
        let mut err = vec![0.0f64; d_row * (ib - i)];
        for j in i..ib {
            // ---- mask selection ----
            match pattern {
                Pattern::Unstructured(p) => {
                    if (j - i) == 0 {
                        // select for the whole window [i, ib)
                        let bs = ib - i;
                        let mut scores = Vec::with_capacity(d_row * bs);
                        for r in 0..d_row {
                            for jj in i..ib {
                                let v = wf[r * d_col + jj];
                                scores.push((v * v) / (diag[jj] * diag[jj]));
                            }
                        }
                        let k = (p * scores.len() as f64).round() as usize;
                        let ranks = stable_ranks(&scores);
                        for r in 0..d_row {
                            for (idx, jj) in (i..ib).enumerate() {
                                keep[r * d_col + jj] =
                                    if ranks[r * bs + idx] >= k { 1.0 } else { 0.0 };
                            }
                        }
                    }
                }
                Pattern::NM(n, m) => {
                    if (j - i) % m == 0 && j + m <= d_col {
                        for r in 0..d_row {
                            let scores: Vec<f64> = (j..j + m)
                                .map(|jj| {
                                    let v = wf[r * d_col + jj];
                                    (v * v) / (diag[jj] * diag[jj])
                                })
                                .collect();
                            let ranks = stable_ranks(&scores);
                            for (idx, jj) in (j..j + m).enumerate() {
                                keep[r * d_col + jj] = if ranks[idx] >= n { 1.0 } else { 0.0 };
                            }
                        }
                    }
                }
            }
            // ---- prune/freeze column j, propagate error rightward ----
            let dj = diag[j];
            for r in 0..d_row {
                let v = wf[r * d_col + j];
                let k = keep[r * d_col + j];
                let fz = frozen(v, k, r);
                let e = (v - fz) / dj;
                let hrow = &hcf[j * d_col..(j + 1) * d_col];
                let wrow = &mut wf[r * d_col..(r + 1) * d_col];
                for jj in j + 1..ib {
                    wrow[jj] -= e * hrow[jj];
                }
                wrow[j] = fz;
                err[r * (ib - i) + (j - i)] = e;
            }
        }
        // ---- lazy trailing update: W[:, ib:] -= E @ Hc[i:ib, ib:] ----
        if ib < d_col {
            for r in 0..d_row {
                for (jidx, j) in (i..ib).enumerate() {
                    let e = err[r * (ib - i) + jidx];
                    if e == 0.0 {
                        continue;
                    }
                    let hrow = &hcf[j * d_col..(j + 1) * d_col];
                    let wrow = &mut wf[r * d_col..(r + 1) * d_col];
                    for jj in ib..d_col {
                        wrow[jj] -= e * hrow[jj];
                    }
                }
            }
        }
        i = ib;
    }

    (
        Tensor::new(vec![d_row, d_col], wf.iter().map(|&x| x as f32).collect()),
        Tensor::new(vec![d_row, d_col], keep.iter().map(|&x| x as f32).collect()),
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::solver::hessian::{dampened_hinv_chol_f64, layer_sq_error};
    use crate::solver::magnitude::magnitude_prune;
    use crate::util::prng::Rng;

    pub(crate) fn problem(seed: u64, r: usize, c: usize) -> (Tensor, Tensor, Tensor) {
        let mut rng = Rng::new(seed);
        let w = Tensor::new(vec![r, c], (0..r * c).map(|_| rng.normal_f32()).collect());
        let n = 2 * c;
        let x = Tensor::new(vec![n, c], (0..n * c).map(|_| rng.normal_f32()).collect());
        let h = x.transpose2().matmul(&x);
        let hc = dampened_hinv_chol_f64(&h, 0.01).unwrap();
        (w, h, hc)
    }

    #[test]
    fn exact_density_and_zeros() {
        let (w, _h, hc) = problem(0, 32, 64);
        for p in [0.25, 0.5, 0.75] {
            let (wh, mask) = ref_sparsegpt(&w, &hc, Pattern::Unstructured(p), 0, 128);
            let kept: f32 = mask.data().iter().sum();
            assert_eq!(kept as usize, ((1.0 - p) * (32.0 * 64.0)).round() as usize);
            for (x, m) in wh.data().iter().zip(mask.data()) {
                if *m == 0.0 {
                    assert_eq!(*x, 0.0);
                }
            }
        }
    }

    #[test]
    fn nm_constraint_satisfied() {
        let (w, _h, hc) = problem(1, 16, 32);
        let (_wh, mask) = ref_sparsegpt(&w, &hc, Pattern::NM(2, 4), 0, 128);
        for r in 0..16 {
            for g in (0..32).step_by(4) {
                let kept: f32 = (g..g + 4).map(|j| mask.at2(r, j)).sum();
                assert_eq!(kept, 2.0);
            }
        }
    }

    #[test]
    fn beats_magnitude_in_layer_error() {
        let (w, h, hc) = problem(2, 48, 96);
        let (wh, _) = ref_sparsegpt(&w, &hc, Pattern::Unstructured(0.5), 0, 128);
        let (wm, _) = magnitude_prune(&w, 0.5);
        let e_s = layer_sq_error(&w, &wh, &h);
        let e_m = layer_sq_error(&w, &wm, &h);
        assert!(e_s < e_m, "sparsegpt {e_s} vs magnitude {e_m}");
    }

    #[test]
    fn blocksize_invariance_without_selection_drift() {
        // With the same Bs the algorithm is exact in the window split; using
        // b = d_col vs b = 32 changes the selection granularity, so compare
        // a fixed mask path: p = 0 with quantization (no selection at all).
        let (w, _h, hc) = problem(3, 16, 64);
        let (a, _) = ref_sparsegpt(&w, &hc, Pattern::Unstructured(0.0), 7, 64);
        let (b, _) = ref_sparsegpt(&w, &hc, Pattern::Unstructured(0.0), 7, 16);
        for (x, y) in a.data().iter().zip(b.data()) {
            assert!((x - y).abs() < 1e-4, "{x} vs {y}");
        }
    }

    #[test]
    fn joint_quant_outputs_on_grid() {
        let (w, _h, hc) = problem(4, 16, 32);
        let levels = 15;
        let (wh, mask) = ref_sparsegpt(&w, &hc, Pattern::Unstructured(0.5), levels, 128);
        let grid = QuantGrid::from_weights(&w, levels);
        for r in 0..16 {
            for c in 0..32 {
                if mask.at2(r, c) == 1.0 {
                    let v = wh.at2(r, c);
                    let q = grid.quantize_one(r, v);
                    assert!((v - q).abs() < 1e-5, "off-grid value {v}");
                }
            }
        }
    }
}
