//! Pruning solvers and Hessian utilities.
//!
//! The production path runs the AOT HLO artifacts (Pallas kernel inside);
//! this module provides (a) the pure-Rust f64 reference implementation of
//! Algorithm 1 used to cross-check that path end-to-end, (b) the baselines
//! the paper compares against (magnitude pruning; AdaPrune's mask selection
//! — its reconstruction runs as an artifact), (c) the *exact* per-row OBS
//! reconstruction for the Fig-11 approximation-quality experiment, and
//! (d) RTN quantization used by the Fig-6 joint-compression comparison.

pub mod exact;
pub mod hessian;
pub mod magnitude;
pub mod quant;
pub mod sparsegpt_ref;
