//! Layer Hessian bookkeeping: accumulation across calibration chunks,
//! dampening, and the inverse-Cholesky chain (f64 reference; the production
//! pipeline uses the `hessian_prep_<dim>` artifact for large dims).

use anyhow::{anyhow, Result};

use crate::tensor::linalg::{self, Mat};
use crate::tensor::Tensor;

/// Running sum of X^T X over calibration chunks for one linear layer.
#[derive(Clone, Debug)]
pub struct HessianAccumulator {
    pub dim: usize,
    pub h: Tensor,
    pub rows_seen: usize,
}

impl HessianAccumulator {
    pub fn new(dim: usize) -> HessianAccumulator {
        HessianAccumulator { dim, h: Tensor::zeros(vec![dim, dim]), rows_seen: 0 }
    }

    /// Add a chunk's X^T X (as produced by the `hessian_<dim>` artifact).
    pub fn add(&mut self, chunk_h: &Tensor, rows: usize) -> Result<()> {
        if chunk_h.shape() != [self.dim, self.dim] {
            return Err(anyhow!("chunk Hessian shape {:?}", chunk_h.shape()));
        }
        for (a, b) in self.h.data_mut().iter_mut().zip(chunk_h.data()) {
            *a += b;
        }
        self.rows_seen += rows;
        Ok(())
    }
}

/// f64 reference for the artifact chain: upper factor U with
/// (H + damp*mean(diag)*I)^{-1} = U^T U. Returns None if H is too
/// degenerate even after dampening.
pub fn dampened_hinv_chol_f64(h: &Tensor, damp: f64) -> Option<Tensor> {
    let n = h.rows();
    let m = Mat::from_f32(n, h.data());
    let u = linalg::hessian_prep(&m, damp)?;
    Some(Tensor::new(vec![n, n], u.to_f32()))
}

/// ||(W - W_hat) X||_F^2 = tr(dW H dW^T) with the raw (undamped) H.
pub fn layer_sq_error(w_orig: &Tensor, w_hat: &Tensor, h: &Tensor) -> f64 {
    let (r, c) = (w_orig.rows(), w_orig.cols());
    assert_eq!(w_hat.shape(), w_orig.shape());
    assert_eq!(h.shape(), &[c, c]);
    let mut total = 0.0f64;
    let mut dw = vec![0.0f64; c];
    for i in 0..r {
        for j in 0..c {
            dw[j] = (w_orig.at2(i, j) - w_hat.at2(i, j)) as f64;
        }
        // total += dw^T H dw
        for j in 0..c {
            if dw[j] == 0.0 {
                continue;
            }
            let hrow = h.row(j);
            let mut s = 0.0f64;
            for k in 0..c {
                s += hrow[k] as f64 * dw[k];
            }
            total += dw[j] * s;
        }
    }
    total
}

/// Power-iteration estimate of lambda_max(H) (AdaPrune's stable step size).
pub fn lambda_max(h: &Tensor, seed: u64) -> f64 {
    let m = Mat::from_f32(h.rows(), h.data());
    linalg::lambda_max(&m, 50, seed)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Rng;

    fn random_x(rng: &mut Rng, n: usize, d: usize) -> Tensor {
        Tensor::new(vec![n, d], (0..n * d).map(|_| rng.normal_f32()).collect())
    }

    #[test]
    fn accumulator_equals_whole_product() {
        let mut rng = Rng::new(0);
        let d = 16;
        let x1 = random_x(&mut rng, 32, d);
        let x2 = random_x(&mut rng, 32, d);
        let mut acc = HessianAccumulator::new(d);
        acc.add(&x1.transpose2().matmul(&x1), 32).unwrap();
        acc.add(&x2.transpose2().matmul(&x2), 32).unwrap();
        // concatenated product
        let mut all = x1.data().to_vec();
        all.extend_from_slice(x2.data());
        let xall = Tensor::new(vec![64, d], all);
        let href = xall.transpose2().matmul(&xall);
        for (a, b) in acc.h.data().iter().zip(href.data()) {
            assert!((a - b).abs() < 1e-3 * (1.0 + b.abs()));
        }
        assert_eq!(acc.rows_seen, 64);
    }

    #[test]
    fn hinv_chol_factor_property() {
        let mut rng = Rng::new(1);
        let d = 24;
        let x = random_x(&mut rng, 48, d);
        let h = x.transpose2().matmul(&x);
        let u = dampened_hinv_chol_f64(&h, 0.01).unwrap();
        // U^T U * (H + damp mean diag I) ~ I
        let ut = u.transpose2();
        let hinv = ut.matmul(&u);
        let mean_diag: f32 = (0..d).map(|i| h.at2(i, i)).sum::<f32>() / d as f32;
        let mut hd = h.clone();
        for i in 0..d {
            let v = hd.at2(i, i) + 0.01 * mean_diag;
            hd.set2(i, i, v);
        }
        let prod = hinv.matmul(&hd);
        for i in 0..d {
            for j in 0..d {
                let want = if i == j { 1.0 } else { 0.0 };
                assert!((prod.at2(i, j) - want).abs() < 1e-3, "{i},{j}: {}", prod.at2(i, j));
            }
        }
    }

    #[test]
    fn layer_error_zero_for_identical() {
        let mut rng = Rng::new(2);
        let w = random_x(&mut rng, 8, 12);
        let x = random_x(&mut rng, 24, 12);
        let h = x.transpose2().matmul(&x);
        assert_eq!(layer_sq_error(&w, &w, &h), 0.0);
        // and positive for a perturbation
        let mut w2 = w.clone();
        w2.set2(0, 0, w.at2(0, 0) + 1.0);
        assert!(layer_sq_error(&w, &w2, &h) > 0.0);
    }

    #[test]
    fn lambda_max_positive() {
        let mut rng = Rng::new(3);
        let x = random_x(&mut rng, 32, 10);
        let h = x.transpose2().matmul(&x);
        assert!(lambda_max(&h, 0) > 0.0);
    }
}
