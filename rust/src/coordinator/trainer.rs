//! Training driver: the Rust loop around the `train_step_<cfg>` artifact
//! (fwd + bwd + Adam inside XLA). Owns the LR schedule (linear warmup +
//! cosine decay), data order, loss logging and checkpointing — the e2e
//! example uses this to pretrain the model family from scratch.

use std::path::PathBuf;
use std::time::Instant;

use anyhow::{Context, Result};

use crate::data::Dataset;
use crate::model::checkpoint::Checkpoint;
use crate::model::layout::FlatParams;
use crate::runtime::{ArgValue, Backend};
use crate::util::prng::Rng;

#[derive(Clone, Debug)]
pub struct TrainOptions {
    pub steps: usize,
    pub base_lr: f64,
    pub warmup: usize,
    /// decay to this fraction of base_lr at the final step
    pub min_lr_frac: f64,
    pub seed: u64,
    pub log_every: usize,
    pub checkpoint_every: usize,
    pub out: Option<PathBuf>,
}

impl TrainOptions {
    /// Sensible defaults per config scale.
    pub fn for_config(name: &str, steps: usize) -> TrainOptions {
        let base_lr = match name {
            "nano" | "micro" => 3e-3,
            "small" => 1.5e-3,
            "medium" => 8e-4,
            _ => 5e-4,
        };
        TrainOptions {
            steps,
            base_lr,
            warmup: (steps / 10).clamp(10, 100),
            min_lr_frac: 0.1,
            seed: 0,
            log_every: 20,
            checkpoint_every: 0,
            out: None,
        }
    }

    pub fn lr_at(&self, step: usize) -> f64 {
        let warm = self.warmup.max(1);
        if step <= warm {
            return self.base_lr * step as f64 / warm as f64;
        }
        let t = (step - warm) as f64 / (self.steps - warm).max(1) as f64;
        let cos = 0.5 * (1.0 + (std::f64::consts::PI * t.min(1.0)).cos());
        self.base_lr * (self.min_lr_frac + (1.0 - self.min_lr_frac) * cos)
    }
}

pub struct Trainer<'rt> {
    pub rt: &'rt dyn Backend,
}

/// Progress notifications emitted by the training loop; `api::Session` maps
/// these onto its structured event stream, the plain [`Trainer::train`]
/// entry point prints the classic log lines.
#[derive(Debug)]
pub enum TrainEvent {
    /// a logged step (cadence: `opts.log_every`, plus the first and last)
    Step {
        step: u64,
        loss: f64,
        lr: f64,
        secs_per_step: f64,
    },
    /// a checkpoint was written
    Checkpoint { path: PathBuf, step: u64 },
}

#[derive(Debug)]
pub struct TrainOutcome {
    pub params: FlatParams,
    pub adam: (Vec<f32>, Vec<f32>),
    pub losses: Vec<(usize, f64)>,
    pub final_step: u64,
    pub secs: f64,
}

impl<'rt> Trainer<'rt> {
    pub fn new(rt: &'rt dyn Backend) -> Trainer<'rt> {
        Trainer { rt }
    }

    /// Train (or continue training) `params` on `data`, printing the
    /// classic progress lines to stdout.
    pub fn train(
        &self,
        params: FlatParams,
        adam: Option<(Vec<f32>, Vec<f32>)>,
        start_step: u64,
        data: &Dataset,
        opts: &TrainOptions,
    ) -> Result<TrainOutcome> {
        let name = params.cfg.name.clone();
        self.train_with(params, adam, start_step, data, opts, &mut |ev| match ev {
            TrainEvent::Step { step, loss, lr, secs_per_step } => println!(
                "[train {name}] step {step} loss {loss:.4} lr {lr:.2e} ({secs_per_step:.2} s/step)"
            ),
            TrainEvent::Checkpoint { path, step } => {
                println!("[train {name}] checkpoint -> {path:?} (step {step})")
            }
        })
    }

    /// Like [`Trainer::train`] but silent, invoking `progress` instead of
    /// printing (the event-emission hook the `api` layer plugs into).
    pub fn train_with(
        &self,
        params: FlatParams,
        adam: Option<(Vec<f32>, Vec<f32>)>,
        start_step: u64,
        data: &Dataset,
        opts: &TrainOptions,
        progress: &mut dyn FnMut(&TrainEvent),
    ) -> Result<TrainOutcome> {
        let cfg = params.cfg.clone();
        let artifact = format!("train_step_{}", cfg.name);
        let mut rng = Rng::new(opts.seed ^ 0x7ea1_9a9e);
        let n = cfg.n_params;
        let (mut m, mut v) = adam.unwrap_or((vec![0.0; n], vec![0.0; n]));
        let mut p = params.data;
        let mut losses = Vec::new();
        let t0 = Instant::now();

        for s in 1..=opts.steps {
            let step = start_step + s as u64;
            let toks = data.train_batch(&mut rng, cfg.train_batch, cfg.seq)?;
            let lr = opts.lr_at(s) as f32;
            let out = self
                .rt
                .run(
                    &artifact,
                    &[
                        ArgValue::F32(&p),
                        ArgValue::F32(&m),
                        ArgValue::F32(&v),
                        ArgValue::Scalar(step as f32),
                        ArgValue::Scalar(lr),
                        ArgValue::I32(&toks),
                    ],
                )
                .with_context(|| format!("train step {step}"))?;
            let mut it = out.into_iter();
            p = it.next().unwrap().into_data();
            m = it.next().unwrap().into_data();
            v = it.next().unwrap().into_data();
            let loss = it.next().unwrap().data()[0] as f64;
            if s % opts.log_every.max(1) == 0 || s == 1 || s == opts.steps {
                let dt = t0.elapsed().as_secs_f64();
                progress(&TrainEvent::Step {
                    step,
                    loss,
                    lr: lr as f64,
                    secs_per_step: dt / s as f64,
                });
                losses.push((step as usize, loss));
            }
            if opts.checkpoint_every > 0 && s % opts.checkpoint_every == 0 {
                if let Some(dir) = &opts.out {
                    let path = self.save(dir, &cfg.name, step, &p, &m, &v)?;
                    progress(&TrainEvent::Checkpoint { path, step });
                }
            }
        }
        let final_step = start_step + opts.steps as u64;
        if let Some(dir) = &opts.out {
            let path = self.save(dir, &cfg.name, final_step, &p, &m, &v)?;
            progress(&TrainEvent::Checkpoint { path, step: final_step });
        }
        Ok(TrainOutcome {
            params: FlatParams::new(&cfg, p)?,
            adam: (m, v),
            losses,
            final_step,
            secs: t0.elapsed().as_secs_f64(),
        })
    }

    fn save(
        &self,
        dir: &PathBuf,
        name: &str,
        step: u64,
        p: &[f32],
        m: &[f32],
        v: &[f32],
    ) -> Result<PathBuf> {
        let ck = Checkpoint {
            config_name: name.to_string(),
            step,
            params: p.to_vec(),
            adam: Some((m.to_vec(), v.to_vec())),
        };
        let path = Checkpoint::path_for(dir, name, "");
        ck.save(&path)?;
        Ok(path)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lr_schedule_shape() {
        let o = TrainOptions { warmup: 10, steps: 100, base_lr: 1e-3, min_lr_frac: 0.1, seed: 0, log_every: 1, checkpoint_every: 0, out: None };
        assert!(o.lr_at(1) < o.lr_at(10));
        assert!((o.lr_at(10) - 1e-3).abs() < 1e-12);
        assert!(o.lr_at(50) < 1e-3);
        assert!(o.lr_at(100) >= 1e-4 - 1e-12);
        assert!(o.lr_at(100) < o.lr_at(50));
    }

    #[test]
    fn defaults_scale_with_config() {
        assert!(TrainOptions::for_config("nano", 100).base_lr > TrainOptions::for_config("medium", 100).base_lr);
    }
}
