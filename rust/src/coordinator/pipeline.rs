//! The one-shot compression pipeline (the system the paper contributes).
//!
//! For each transformer block, in order:
//!   1. run `block_fwd` over the calibration chunks with the block's current
//!      (dense) weights, collecting the inputs X of each of its six linears;
//!   2. accumulate the four layer Hessians H = sum X^T X (`hessian_<dim>`,
//!      q/k/v share one) and prepare the inverse-Cholesky factor
//!      (`hessian_prep_<dim>`, App-A dampening);
//!   3. compress each linear with the configured method — SparseGPT
//!      (unstructured / 2:4 / 4:8, optionally joint with quantization),
//!      magnitude, or AdaPrune — honoring the partial-pruning skip policy;
//!   4. re-run `block_fwd` with the *pruned* weights so the next block
//!      calibrates against the compressed model's activations (the paper's
//!      sequential memory-saving schedule).
//!
//! The whole pass is one-shot: no gradients, no finetuning.

use std::collections::HashMap;
use std::time::Instant;

use anyhow::{bail, Context, Result};

use crate::coordinator::calibration::CalibChunks;
use crate::coordinator::partial::SkipSpec;
use crate::model::layout::{Capture, FlatParams, LinearKind, PRUNABLE_KINDS};
use crate::runtime::{ArgValue, Backend};
use crate::solver::hessian::{lambda_max, layer_sq_error, HessianAccumulator};
use crate::solver::magnitude::{magnitude_prune, magnitude_prune_nm};
use crate::solver::sparsegpt_ref::Pattern;
use crate::tensor::Tensor;

#[derive(Clone, Debug, PartialEq)]
pub enum PruneMethod {
    /// the paper's solver; `quant_bits` enables joint compression (Eq. 7)
    SparseGpt { pattern: Pattern, quant_bits: Option<u32> },
    /// Fig-10 ablation: jnp solver variant with mask blocksize Bs
    SparseGptBs { sparsity: f64, mask_blocksize: usize },
    /// layer-wise magnitude baseline (optionally quantize survivors RTN)
    Magnitude { pattern: Pattern },
    /// magnitude mask + GD reconstruction baseline
    AdaPrune { sparsity: f64 },
}

/// Render a sparsity fraction as a percent label: integral percents print
/// bare ("50%"), anything finer keeps full precision ("62.5%") so that
/// `api::PruneSpec::parse(label())` recovers the same sparsity whenever
/// `p * 100` is exactly representable (all practically-specified points;
/// adversarial fractions may differ in the last bit after the /100).
fn pct(p: f64) -> String {
    let v = p * 100.0;
    if (v - v.round()).abs() < 1e-9 {
        format!("{:.0}%", v.round())
    } else {
        format!("{v}%")
    }
}

impl PruneMethod {
    pub fn label(&self) -> String {
        match self {
            PruneMethod::SparseGpt { pattern, quant_bits } => {
                let p = match pattern {
                    Pattern::Unstructured(p) => pct(*p),
                    Pattern::NM(n, m) => format!("{n}:{m}"),
                };
                match quant_bits {
                    Some(b) => format!("sparsegpt-{p}+{b}bit"),
                    None => format!("sparsegpt-{p}"),
                }
            }
            PruneMethod::SparseGptBs { sparsity, mask_blocksize } => {
                format!("sparsegpt-{}-bs{}", pct(*sparsity), mask_blocksize)
            }
            PruneMethod::Magnitude { pattern } => match pattern {
                Pattern::Unstructured(p) => format!("magnitude-{}", pct(*p)),
                Pattern::NM(n, m) => format!("magnitude-{n}:{m}"),
            },
            PruneMethod::AdaPrune { sparsity } => format!("adaprune-{}", pct(*sparsity)),
        }
    }
}

#[derive(Clone, Debug)]
pub struct PruneOptions {
    pub method: PruneMethod,
    /// Hessian dampening multiplier (paper default 1e-2, Fig-9 ablation)
    pub damp: f64,
    pub skip: SkipSpec,
    /// record per-matrix layer errors tr(dW H dW^T) — O(d^3), small models
    pub record_errors: bool,
    /// additionally solve the EXACT per-row masked reconstruction (Eq. 2)
    /// on this many subsampled rows and record its error — O(rows * d^3),
    /// the Fig-11 comparator; use only on micro/small models
    pub exact_rows: Option<usize>,
}

impl Default for PruneOptions {
    fn default() -> Self {
        PruneOptions {
            method: PruneMethod::SparseGpt {
                pattern: Pattern::Unstructured(0.5),
                quant_bits: None,
            },
            damp: 0.01,
            skip: SkipSpec::None,
            record_errors: false,
            exact_rows: None,
        }
    }
}

#[derive(Clone, Debug)]
pub struct MatrixReport {
    pub layer: usize,
    pub kind: LinearKind,
    pub sparsity: f64,
    pub skipped: bool,
    pub solver_secs: f64,
    /// layer error tr(dW H dW^T) when record_errors is set
    pub sq_error: Option<f64>,
    /// same-mask exact-reconstruction error on the subsampled rows, paired
    /// with the solver's error on those SAME rows (Fig-11 ratio)
    pub exact_vs_solver: Option<(f64, f64)>,
}

/// Progress notifications emitted by the pipeline as it walks the model.
/// `api::Session` maps these onto its structured event stream; callers that
/// do not care pass a no-op hook (see [`Pruner::prune`]).
#[derive(Debug)]
pub enum PipelineEvent<'a> {
    /// calibration capture for block `layer` is starting
    BlockStart { layer: usize, layers: usize },
    /// one weight matrix was compressed (or skipped by policy)
    Matrix(&'a MatrixReport),
    /// block `layer` finished compressing + propagating; `sparsity` is the
    /// numel-weighted sparsity over the block's six linears
    BlockDone {
        layer: usize,
        layers: usize,
        sparsity: f64,
        secs: f64,
    },
}

#[derive(Debug)]
pub struct PruneOutcome {
    pub params: FlatParams,
    pub reports: Vec<MatrixReport>,
    pub total_secs: f64,
    pub hessian_secs: f64,
    pub solver_secs: f64,
    pub propagate_secs: f64,
}

impl PruneOutcome {
    pub fn overall_sparsity(&self) -> f64 {
        self.params.prunable_sparsity()
    }
}

/// Fig-11 comparator: on `nrows` evenly-spaced rows, solve the exact
/// masked reconstruction (Eq. 2, f64, with the same dampened H and the
/// solver's own mask) and return (exact_error, solver_error) on those rows.
fn exact_vs_solver_error(
    w: &Tensor,
    w_solver: &Tensor,
    mask: &Tensor,
    h: &Tensor,
    damp: f64,
    nrows: usize,
) -> Result<(f64, f64)> {
    use crate::solver::exact::exact_reconstruction;
    use crate::tensor::linalg::{dampen, Mat};
    let d_row = w.rows();
    let stride = (d_row / nrows.min(d_row)).max(1);
    let rows: Vec<usize> = (0..d_row).step_by(stride).take(nrows).collect();
    let hd_mat = dampen(&Mat::from_f32(h.rows(), h.data()), damp);
    let hd = Tensor::new(vec![h.rows(), h.cols()], hd_mat.to_f32());
    let w_exact = exact_reconstruction(w, mask, &hd, Some(&rows))?;
    let row_error = |what: &Tensor| -> f64 {
        let mut total = 0.0;
        for &r in &rows {
            let c = w.cols();
            let mut dw = vec![0.0f64; c];
            for j in 0..c {
                dw[j] = (w.at2(r, j) - what.at2(r, j)) as f64;
            }
            for j in 0..c {
                if dw[j] == 0.0 {
                    continue;
                }
                let hrow = hd.row(j);
                let mut s = 0.0f64;
                for k in 0..c {
                    s += hrow[k] as f64 * dw[k];
                }
                total += dw[j] * s;
            }
        }
        total
    };
    Ok((row_error(&w_exact), row_error(w_solver)))
}

pub struct Pruner<'rt> {
    pub rt: &'rt dyn Backend,
}

impl<'rt> Pruner<'rt> {
    pub fn new(rt: &'rt dyn Backend) -> Pruner<'rt> {
        Pruner { rt }
    }

    /// Run the one-shot pipeline. `params` is consumed and returned pruned.
    pub fn prune(
        &self,
        params: FlatParams,
        chunks: &CalibChunks,
        opts: &PruneOptions,
    ) -> Result<PruneOutcome> {
        self.prune_with(params, chunks, opts, &mut |_| {})
    }

    /// Like [`Pruner::prune`], invoking `progress` as blocks and matrices
    /// complete (the event-emission hook the `api` layer plugs into).
    pub fn prune_with(
        &self,
        mut params: FlatParams,
        chunks: &CalibChunks,
        opts: &PruneOptions,
        progress: &mut dyn FnMut(&PipelineEvent),
    ) -> Result<PruneOutcome> {
        let cfg = params.cfg.clone();
        let t_total = Instant::now();
        let mut reports = Vec::new();
        let (mut hessian_secs, mut solver_secs, mut propagate_secs) = (0.0, 0.0, 0.0);

        // 1. embed all calibration chunks (params marshalled once)
        let t0 = Instant::now();
        let plit = self.rt.cache_f32(&params.data, &[cfg.n_params])?;
        let mut hidden: Vec<Tensor> = Vec::with_capacity(chunks.n_chunks());
        for toks in &chunks.tokens {
            let out = self
                .rt
                .run(&format!("embed_{}", cfg.name), &[ArgValue::Cached(&plit), ArgValue::I32(toks)])
                .context("embed")?;
            hidden.push(out.into_iter().next().unwrap());
        }
        drop(plit);
        propagate_secs += t0.elapsed().as_secs_f64();

        // the fused capture+Hessian artifact is the fast path (one dispatch
        // per chunk instead of five, activations never cross the boundary);
        // SPARSEGPT_UNFUSED_HESSIANS=1 selects the original path (perf A/B)
        let fused_name = format!("block_hess_{}", cfg.name);
        let use_fused = std::env::var_os("SPARSEGPT_UNFUSED_HESSIANS").is_none()
            && self.rt.has_artifact(&fused_name);

        for layer in 0..cfg.layers {
            let t_layer = Instant::now();
            let layer_report_start = reports.len();
            progress(&PipelineEvent::BlockStart { layer, layers: cfg.layers });
            // 2. capture pass with dense block weights -> Hessians
            let t0 = Instant::now();
            let block = params.block_slice(layer)?;
            let blit = self.rt.cache_f32(&block, &[cfg.block_size])?;
            let mut accs: HashMap<Capture, HessianAccumulator> = Capture::ALL
                .iter()
                .map(|c| (*c, HessianAccumulator::new(c.dim(&cfg))))
                .collect();
            for (ci, h) in hidden.iter().enumerate() {
                let valid = chunks.valid_rows[ci];
                if use_fused {
                    let outs = self
                        .rt
                        .run(
                            &fused_name,
                            &[
                                ArgValue::Cached(&blit),
                                ArgValue::F32(h.data()),
                                ArgValue::Scalar(valid as f32),
                            ],
                        )
                        .context("block_hess")?;
                    // outputs: hidden_out, H_qkv, H_wo, H_fc1, H_fc2
                    for cap in Capture::ALL {
                        accs.get_mut(&cap)
                            .unwrap()
                            .add(&outs[cap.output_index()], valid)?;
                    }
                } else {
                    let outs = self.block_fwd(&cfg.name, &block, h)?;
                    for cap in Capture::ALL {
                        let dim = cap.dim(&cfg);
                        let mut x = outs[cap.output_index()].clone();
                        CalibChunks::mask_padding(
                            x.data_mut(),
                            chunks.batch * chunks.seq,
                            dim,
                            valid,
                        );
                        let hcv = self
                            .rt
                            .run(&format!("hessian_{dim}"), &[ArgValue::F32(x.data())])
                            .context("hessian")?;
                        accs.get_mut(&cap).unwrap().add(&hcv[0], valid)?;
                    }
                }
            }
            hessian_secs += t0.elapsed().as_secs_f64();

            // 3. prepare inverse factors once per capture group, then solve
            let mut prepared: HashMap<Capture, Tensor> = HashMap::new();
            for kind in PRUNABLE_KINDS {
                if !opts.skip.should_prune(layer, kind, cfg.layers) {
                    reports.push(MatrixReport {
                        layer,
                        kind,
                        sparsity: 0.0,
                        skipped: true,
                        solver_secs: 0.0,
                        sq_error: None,
                        exact_vs_solver: None,
                    });
                    progress(&PipelineEvent::Matrix(reports.last().unwrap()));
                    continue;
                }
                let cap = kind.capture();
                let h = &accs[&cap].h;
                let t1 = Instant::now();
                let w = params.get_linear(kind, layer)?;
                let (w_new, mask) = match &opts.method {
                    PruneMethod::Magnitude { pattern } => match pattern {
                        Pattern::Unstructured(p) => magnitude_prune(&w, *p),
                        Pattern::NM(n, m) => magnitude_prune_nm(&w, *n, *m),
                    },
                    PruneMethod::AdaPrune { sparsity } => {
                        let (_, mask) = magnitude_prune(&w, *sparsity);
                        let lam = lambda_max(h, 0x5eed ^ layer as u64);
                        let lr = if lam > 0.0 { (1.0 / lam) as f32 } else { 0.0 };
                        let (r, c) = kind.shape(&cfg);
                        let out = self
                            .rt
                            .run(
                                &format!("adaprune_{r}x{c}"),
                                &[
                                    ArgValue::F32(w.data()),
                                    ArgValue::F32(mask.data()),
                                    ArgValue::F32(h.data()),
                                    ArgValue::Scalar(lr),
                                ],
                            )
                            .context("adaprune")?;
                        (out.into_iter().next().unwrap(), mask)
                    }
                    method => {
                        // SparseGPT variants need the inverse-Cholesky factor
                        let hc = match prepared.get(&cap) {
                            Some(hc) => hc.clone(),
                            None => {
                                let dim = cap.dim(&cfg);
                                let out = self
                                    .rt
                                    .run(
                                        &format!("hessian_prep_{dim}"),
                                        &[ArgValue::F32(h.data()), ArgValue::Scalar(opts.damp as f32)],
                                    )
                                    .context("hessian_prep")?;
                                let hc = out.into_iter().next().unwrap();
                                if !hc.data().iter().all(|x| x.is_finite()) {
                                    bail!(
                                        "hessian_prep produced non-finite factor \
                                         (layer {layer} {kind:?}); increase --damp"
                                    );
                                }
                                prepared.insert(cap, hc.clone());
                                hc
                            }
                        };
                        let (r, c) = kind.shape(&cfg);
                        let mut out = match method {
                            PruneMethod::SparseGpt { pattern, quant_bits } => {
                                let qlevels =
                                    quant_bits.map(|b| (1u32 << b) - 1).unwrap_or(0) as f32;
                                match pattern {
                                    Pattern::Unstructured(p) => self.rt.run(
                                        &format!("sparsegpt_{r}x{c}"),
                                        &[
                                            ArgValue::F32(w.data()),
                                            ArgValue::F32(hc.data()),
                                            ArgValue::Scalar(*p as f32),
                                            ArgValue::Scalar(qlevels),
                                        ],
                                    )?,
                                    Pattern::NM(n, m) => self.rt.run(
                                        &format!("sparsegpt{n}{m}_{r}x{c}"),
                                        &[
                                            ArgValue::F32(w.data()),
                                            ArgValue::F32(hc.data()),
                                            ArgValue::Scalar(qlevels),
                                        ],
                                    )?,
                                }
                            }
                            PruneMethod::SparseGptBs { sparsity, mask_blocksize } => {
                                // clamp Bs to the largest lowered variant that
                                // divides this layer's width (Fig-10 semantics:
                                // selection blocks never exceed the layer)
                                let name = self.bs_artifact(*mask_blocksize, r, c);
                                self.rt.run(
                                    &name,
                                    &[
                                        ArgValue::F32(w.data()),
                                        ArgValue::F32(hc.data()),
                                        ArgValue::Scalar(*sparsity as f32),
                                        ArgValue::Scalar(0.0),
                                    ],
                                )?
                            }
                            _ => unreachable!(),
                        };
                        let mask = out.pop().unwrap();
                        (out.pop().unwrap(), mask)
                    }
                };
                let dt = t1.elapsed().as_secs_f64();
                solver_secs += dt;
                let sq_error = opts.record_errors.then(|| layer_sq_error(&w, &w_new, h));
                let exact_vs_solver = match opts.exact_rows {
                    Some(nrows) => {
                        Some(exact_vs_solver_error(&w, &w_new, &mask, h, opts.damp, nrows)?)
                    }
                    None => None,
                };
                reports.push(MatrixReport {
                    layer,
                    kind,
                    sparsity: w_new.sparsity(),
                    skipped: false,
                    solver_secs: dt,
                    sq_error,
                    exact_vs_solver,
                });
                progress(&PipelineEvent::Matrix(reports.last().unwrap()));
                params.set_linear(kind, layer, &w_new)?;
            }

            // 4. propagate with pruned weights (block slice marshalled once;
            // the lean hidden-only artifact avoids copying dead captures)
            let t2 = Instant::now();
            let prop_name = format!("block_prop_{}", cfg.name);
            let prop_name = if self.rt.has_artifact(&prop_name) {
                prop_name
            } else {
                format!("block_fwd_{}", cfg.name)
            };
            let block = params.block_slice(layer)?;
            let blit = self.rt.cache_f32(&block, &[cfg.block_size])?;
            for h in hidden.iter_mut() {
                let outs = self
                    .rt
                    .run(&prop_name, &[ArgValue::Cached(&blit), ArgValue::F32(h.data())])
                    .context("block propagate")?;
                *h = outs.into_iter().next().unwrap();
            }
            propagate_secs += t2.elapsed().as_secs_f64();

            let (mut zeroed, mut numel) = (0.0f64, 0.0f64);
            for r in &reports[layer_report_start..] {
                let (rr, cc) = r.kind.shape(&cfg);
                let n = (rr * cc) as f64;
                zeroed += r.sparsity * n;
                numel += n;
            }
            progress(&PipelineEvent::BlockDone {
                layer,
                layers: cfg.layers,
                sparsity: if numel > 0.0 { zeroed / numel } else { 0.0 },
                secs: t_layer.elapsed().as_secs_f64(),
            });
        }

        Ok(PruneOutcome {
            params,
            reports,
            total_secs: t_total.elapsed().as_secs_f64(),
            hessian_secs,
            solver_secs,
            propagate_secs,
        })
    }

    /// Pick the Bs-ablation artifact for shape (r, c): exact if lowered,
    /// otherwise the largest lowered Bs <= min(bs, c) (falling back to the
    /// production Bs=128 solver).
    fn bs_artifact(&self, bs: usize, r: usize, c: usize) -> String {
        let exact = format!("sparsegpt_bs{bs}_{r}x{c}");
        if self.rt.has_artifact(&exact) {
            return exact;
        }
        // exact variant not lowered: search the backend's (finite) artifact
        // list for the best substitute. Open-vocabulary backends always hit
        // the exact path above.
        let mut best: Option<usize> = None;
        let prefix = "sparsegpt_bs";
        let suffix = format!("_{r}x{c}");
        for name in self.rt.artifact_names() {
            if let Some(rest) = name.strip_prefix(prefix) {
                if let Some(v) = rest.strip_suffix(&suffix) {
                    if let Ok(v) = v.parse::<usize>() {
                        if v <= bs.min(c) && best.map_or(true, |b| v > b) {
                            best = Some(v);
                        }
                    }
                }
            }
        }
        match best {
            Some(v) if v > 128 || bs.min(c) < 128 => format!("sparsegpt_bs{v}{suffix}"),
            _ => format!("sparsegpt_{r}x{c}"), // production Bs=128 path
        }
    }

    fn block_fwd(&self, cfg_name: &str, block: &[f32], hidden: &Tensor) -> Result<Vec<Tensor>> {
        self.rt
            .run(
                &format!("block_fwd_{cfg_name}"),
                &[ArgValue::F32(block), ArgValue::F32(hidden.data())],
            )
            .context("block_fwd")
    }
}
