//! Calibration batching: random corpus segments are grouped into fixed-size
//! chunks matching the `block_fwd`/`hessian` artifact shapes; short final
//! chunks are zero-padded and the padded activation rows are zeroed before
//! Hessian accumulation (zero rows contribute nothing to X^T X).

use anyhow::{bail, Result};

use crate::model::ModelCfg;

#[derive(Clone, Debug)]
pub struct CalibChunks {
    /// per chunk: eval_batch * seq token ids (padded with 0)
    pub tokens: Vec<Vec<i32>>,
    /// per chunk: number of valid activation rows (valid_segments * seq)
    pub valid_rows: Vec<usize>,
    pub seq: usize,
    pub batch: usize,
}

impl CalibChunks {
    pub fn new(cfg: &ModelCfg, segments: &[Vec<i32>]) -> Result<CalibChunks> {
        if segments.is_empty() {
            bail!("no calibration segments");
        }
        let (batch, seq) = (cfg.eval_batch, cfg.seq);
        let mut tokens = Vec::new();
        let mut valid_rows = Vec::new();
        for group in segments.chunks(batch) {
            let mut chunk = Vec::with_capacity(batch * seq);
            for s in group {
                if s.len() != seq {
                    bail!("calibration segment has {} tokens, expected {seq}", s.len());
                }
                chunk.extend_from_slice(s);
            }
            chunk.resize(batch * seq, 0); // zero-pad missing segments
            tokens.push(chunk);
            valid_rows.push(group.len() * seq);
        }
        Ok(CalibChunks { tokens, valid_rows, seq, batch })
    }

    pub fn n_chunks(&self) -> usize {
        self.tokens.len()
    }

    pub fn total_rows(&self) -> usize {
        self.valid_rows.iter().sum()
    }

    /// Zero all rows beyond `valid` in a (rows, dim) activation buffer.
    pub fn mask_padding(buf: &mut [f32], rows: usize, dim: usize, valid: usize) {
        debug_assert_eq!(buf.len(), rows * dim);
        if valid < rows {
            buf[valid * dim..].fill(0.0);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::layout::tests::tiny_cfg;

    #[test]
    fn chunks_pad_and_count() {
        let mut cfg = tiny_cfg();
        cfg.eval_batch = 2;
        cfg.seq = 4;
        let segs: Vec<Vec<i32>> = (0..3).map(|i| vec![i as i32; 4]).collect();
        let c = CalibChunks::new(&cfg, &segs).unwrap();
        assert_eq!(c.n_chunks(), 2);
        assert_eq!(c.valid_rows, vec![8, 4]);
        assert_eq!(c.tokens[1][..4], [2, 2, 2, 2]);
        assert_eq!(c.tokens[1][4..], [0, 0, 0, 0]);
        assert_eq!(c.total_rows(), 12);
    }

    #[test]
    fn rejects_bad_segment_length() {
        let mut cfg = tiny_cfg();
        cfg.eval_batch = 2;
        cfg.seq = 4;
        assert!(CalibChunks::new(&cfg, &[vec![0; 3]]).is_err());
        assert!(CalibChunks::new(&cfg, &[]).is_err());
    }

    #[test]
    fn mask_padding_zeroes_tail() {
        let mut buf = vec![1.0f32; 4 * 3];
        CalibChunks::mask_padding(&mut buf, 4, 3, 2);
        assert!(buf[..6].iter().all(|&x| x == 1.0));
        assert!(buf[6..].iter().all(|&x| x == 0.0));
    }
}
