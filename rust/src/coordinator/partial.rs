//! Partial-pruning policies for the sensitivity experiments.
//!
//! Fig. 7 prunes 2/3 of OPT-175B/BLOOM-176B to 2:4 while skipping either one
//! layer *type* (attention / fc1 / fc2) or one *third* of consecutive blocks
//! (front / middle / back); Tables 5–6 prune a prefix fraction of blocks and
//! keep the rest dense (exploiting the solver's sequential nature).

use crate::model::layout::LinearKind;

#[derive(Clone, Debug, PartialEq)]
pub enum SkipSpec {
    /// prune everything
    None,
    /// skip all linears of one type: "attn" | "fc1" | "fc2"
    LayerType(String),
    /// skip one third of consecutive blocks: 0 = front, 1 = middle, 2 = back
    Third(usize),
    /// prune only the first `ceil(frac * layers)` blocks (Tables 5-6)
    PrefixFraction(f64),
}

impl SkipSpec {
    pub fn should_prune(&self, layer: usize, kind: LinearKind, n_layers: usize) -> bool {
        match self {
            SkipSpec::None => true,
            SkipSpec::LayerType(t) => kind.layer_type() != t,
            SkipSpec::Third(t) => {
                let third = (layer * 3) / n_layers; // 0, 1, 2
                third != *t
            }
            SkipSpec::PrefixFraction(frac) => {
                let cutoff = (frac * n_layers as f64).ceil() as usize;
                layer < cutoff
            }
        }
    }

    pub fn label(&self) -> String {
        match self {
            SkipSpec::None => "full".into(),
            SkipSpec::LayerType(t) => format!("skip-{t}"),
            SkipSpec::Third(0) => "skip-front".into(),
            SkipSpec::Third(1) => "skip-middle".into(),
            SkipSpec::Third(2) => "skip-back".into(),
            SkipSpec::Third(t) => format!("skip-third-{t}"),
            SkipSpec::PrefixFraction(f) => format!("prefix-{f:.2}"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn none_prunes_everything() {
        for l in 0..12 {
            assert!(SkipSpec::None.should_prune(l, LinearKind::Wq, 12));
        }
    }

    #[test]
    fn layer_type_skips_exactly_that_type() {
        let s = SkipSpec::LayerType("fc1".into());
        assert!(!s.should_prune(0, LinearKind::Fc1, 12));
        assert!(s.should_prune(0, LinearKind::Fc2, 12));
        assert!(s.should_prune(0, LinearKind::Wq, 12));
        let a = SkipSpec::LayerType("attn".into());
        for k in [LinearKind::Wq, LinearKind::Wk, LinearKind::Wv, LinearKind::Wo] {
            assert!(!a.should_prune(3, k, 12));
        }
        assert!(a.should_prune(3, LinearKind::Fc1, 12));
    }

    #[test]
    fn thirds_partition_blocks() {
        let n = 12;
        for l in 0..n {
            let pruned_count = (0..3)
                .filter(|&t| SkipSpec::Third(t).should_prune(l, LinearKind::Wq, n))
                .count();
            assert_eq!(pruned_count, 2, "each layer skipped by exactly one third");
        }
        // front third = layers 0..4 for n=12
        let f = SkipSpec::Third(0);
        assert!(!f.should_prune(0, LinearKind::Wq, n));
        assert!(!f.should_prune(3, LinearKind::Wq, n));
        assert!(f.should_prune(4, LinearKind::Wq, n));
    }

    #[test]
    fn prefix_fraction_boundaries() {
        let s = SkipSpec::PrefixFraction(0.5);
        let n = 8;
        for l in 0..4 {
            assert!(s.should_prune(l, LinearKind::Fc2, n));
        }
        for l in 4..8 {
            assert!(!s.should_prune(l, LinearKind::Fc2, n));
        }
        assert!(SkipSpec::PrefixFraction(1.0).should_prune(7, LinearKind::Wo, 8));
        assert!(!SkipSpec::PrefixFraction(0.0).should_prune(0, LinearKind::Wo, 8));
    }
}
