//! The compression pipeline coordinator (Layer 3).
//!
//! Owns the paper's sequential layer-by-layer schedule (Sec. 4 "we sparsify
//! Transformer layers sequentially in order, which significantly reduces
//! memory requirements"): calibration activations are propagated block by
//! block, each block's Hessians are accumulated from its *own* inputs, the
//! block's six linears are compressed, and the pruned block produces the
//! next block's inputs. Python never runs here — every tensor operation is
//! an AOT artifact executed through the PJRT runtime.

pub mod calibration;
pub mod partial;
pub mod pipeline;
pub mod trainer;

pub use calibration::CalibChunks;
pub use partial::SkipSpec;
pub use pipeline::{MatrixReport, PipelineEvent, PruneMethod, PruneOptions, PruneOutcome, Pruner};
pub use trainer::{TrainEvent, TrainOptions, Trainer};
