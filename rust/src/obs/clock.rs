//! The time source behind every serve-path measurement.
//!
//! [`Clock`] hides whether time is real or simulated. The real variant
//! reads a monotonic [`Instant`] anchored at a process-wide origin; the
//! mock variant advances a shared atomic by a fixed tick on every read,
//! so durations become a pure function of *how many times* the code
//! under test looks at the clock — which makes metric and span output
//! golden-pinnable (see `tests/metrics_golden.rs`).

use std::fmt;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, OnceLock};
use std::time::Instant;

/// The process-wide origin for real-clock readings. Anchoring every
/// reading to one origin keeps `now_ns` values comparable across
/// threads and components for the whole process lifetime.
fn origin() -> Instant {
    static ORIGIN: OnceLock<Instant> = OnceLock::new();
    *ORIGIN.get_or_init(Instant::now)
}

/// A monotonic nanosecond clock: real time or a deterministic mock.
/// Cloning is cheap and clones of a mock share the same timeline.
#[derive(Clone)]
pub struct Clock(Inner);

#[derive(Clone)]
enum Inner {
    Real,
    Mock(Arc<MockState>),
}

struct MockState {
    now_ns: AtomicU64,
    tick_ns: u64,
}

impl Clock {
    /// Wall-clock time (monotonic, nanoseconds since the process origin).
    pub fn real() -> Clock {
        Clock(Inner::Real)
    }

    /// A deterministic clock that advances by `tick_ns` on every
    /// [`Clock::now_ns`] call. All clones share one timeline.
    pub fn mock(tick_ns: u64) -> Clock {
        Clock(Inner::Mock(Arc::new(MockState { now_ns: AtomicU64::new(0), tick_ns })))
    }

    /// Nanoseconds since an arbitrary fixed origin. The mock variant
    /// advances its timeline by one tick per call (the first call
    /// returns exactly one tick).
    pub fn now_ns(&self) -> u64 {
        match &self.0 {
            // u64 nanoseconds wrap after ~584 years of uptime
            Inner::Real => origin().elapsed().as_nanos() as u64,
            Inner::Mock(m) => m.now_ns.fetch_add(m.tick_ns, Ordering::Relaxed) + m.tick_ns,
        }
    }

    /// Seconds elapsed since a `now_ns` reading taken earlier (reads the
    /// clock once).
    pub fn secs_since(&self, start_ns: u64) -> f64 {
        self.now_ns().saturating_sub(start_ns) as f64 * 1e-9
    }

    pub fn is_mock(&self) -> bool {
        matches!(self.0, Inner::Mock(_))
    }
}

impl Default for Clock {
    fn default() -> Clock {
        Clock::real()
    }
}

impl fmt::Debug for Clock {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match &self.0 {
            Inner::Real => write!(f, "Clock::Real"),
            Inner::Mock(m) => write!(f, "Clock::Mock(tick={}ns)", m.tick_ns),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn real_clock_is_monotone() {
        let c = Clock::real();
        let a = c.now_ns();
        let b = c.now_ns();
        assert!(b >= a);
        assert!(!c.is_mock());
    }

    #[test]
    fn mock_clock_advances_one_tick_per_read() {
        let c = Clock::mock(1_000);
        assert_eq!(c.now_ns(), 1_000);
        assert_eq!(c.now_ns(), 2_000);
        assert!(c.is_mock());
        // clones share the timeline — a read through either advances both
        let d = c.clone();
        assert_eq!(d.now_ns(), 3_000);
        assert_eq!(c.now_ns(), 4_000);
    }

    #[test]
    fn secs_since_counts_exactly_one_read() {
        let c = Clock::mock(500);
        let t0 = c.now_ns(); // 500
        assert_eq!(c.secs_since(t0), 500.0 * 1e-9); // reads 1000
        assert_eq!(c.now_ns(), 1_500);
    }
}
