//! Lock-free metric primitives: counters, gauges and log-bucket
//! histograms, all plain `AtomicU64`s with `Relaxed` ordering.
//!
//! The memory-ordering contract (documented in DESIGN.md): every update
//! is a single relaxed atomic RMW, so the hot path costs one uncontended
//! atomic per event and can never block or fence. Each individual metric
//! is exactly counted (RMWs never lose increments) and monotone where it
//! should be; *cross*-metric skew while writers are running is bounded
//! by the histogram's read-until-stable retry, and every snapshot is
//! exact once the writers are quiescent (the drain path).

use std::sync::atomic::{AtomicU64, Ordering::Relaxed};

/// A monotonically increasing counter.
#[derive(Debug, Default)]
pub struct Counter(AtomicU64);

impl Counter {
    pub fn inc(&self) {
        self.add(1);
    }

    pub fn add(&self, n: u64) {
        self.0.fetch_add(n, Relaxed);
    }

    /// Raise the counter to `v` if it is currently lower (`fetch_max`),
    /// for counters mirrored from an external total (e.g. the event
    /// sink's drop count) — keeps the counter monotone even if the
    /// mirror is refreshed out of order.
    pub fn set_at_least(&self, v: u64) {
        self.0.fetch_max(v, Relaxed);
    }

    pub fn get(&self) -> u64 {
        self.0.load(Relaxed)
    }
}

/// A gauge: a value that can move both ways (queue depth, bytes in use).
#[derive(Debug, Default)]
pub struct Gauge(AtomicU64);

impl Gauge {
    pub fn set(&self, v: u64) {
        self.0.store(v, Relaxed);
    }

    /// Raise to `v` if currently lower — high-watermark tracking.
    pub fn set_max(&self, v: u64) {
        self.0.fetch_max(v, Relaxed);
    }

    pub fn inc(&self) {
        self.0.fetch_add(1, Relaxed);
    }

    /// Decrement; callers pair every `dec` with an earlier `inc`.
    pub fn dec(&self) {
        self.0.fetch_sub(1, Relaxed);
    }

    pub fn get(&self) -> u64 {
        self.0.load(Relaxed)
    }
}

/// Bucket count: one bucket per bit length of the observed value
/// (0, 1, 2–3, 4–7, …, so bucket `i` has upper bound `2^i - 1`), plus
/// the full-width top bucket.
pub const HIST_BUCKETS: usize = 65;

/// A fixed log-bucket histogram. `observe` is three relaxed RMWs; no
/// allocation, no locks, no float math.
#[derive(Debug)]
pub struct Histogram {
    // written bucket -> sum -> count, so a reader that sees `count`
    // include an observation also sees its bucket (on x86; elsewhere the
    // snapshot retry below still converges once writers pause)
    buckets: [AtomicU64; HIST_BUCKETS],
    sum: AtomicU64,
    count: AtomicU64,
}

impl Default for Histogram {
    fn default() -> Histogram {
        Histogram {
            buckets: std::array::from_fn(|_| AtomicU64::new(0)),
            sum: AtomicU64::new(0),
            count: AtomicU64::new(0),
        }
    }
}

impl Histogram {
    pub fn observe(&self, v: u64) {
        let idx = 64 - v.leading_zeros() as usize;
        self.buckets[idx].fetch_add(1, Relaxed);
        self.sum.fetch_add(v, Relaxed);
        self.count.fetch_add(1, Relaxed);
    }

    pub fn count(&self) -> u64 {
        self.count.load(Relaxed)
    }

    /// Read the histogram, retrying (bounded) until the bucket total
    /// matches `count` and `count` is stable across the read — a
    /// consistent snapshot whenever writers pause for an instant, and a
    /// best-effort one under sustained concurrent writes.
    pub fn snapshot(&self) -> HistSnapshot {
        let mut last = None;
        for _ in 0..8 {
            let count = self.count.load(Relaxed);
            let sum = self.sum.load(Relaxed);
            let buckets: Vec<(u64, u64)> = self
                .buckets
                .iter()
                .enumerate()
                .filter_map(|(i, b)| {
                    let n = b.load(Relaxed);
                    (n > 0).then(|| (bucket_le(i), n))
                })
                .collect();
            let total: u64 = buckets.iter().map(|(_, n)| n).sum();
            let snap = HistSnapshot { count, sum, buckets };
            if total == count && self.count.load(Relaxed) == count {
                return snap;
            }
            last = Some(snap);
        }
        last.expect("retry loop ran")
    }
}

/// Inclusive upper bound of bucket `i` (values of bit length `i`).
fn bucket_le(i: usize) -> u64 {
    if i >= 64 {
        u64::MAX
    } else {
        (1u64 << i) - 1
    }
}

/// A point-in-time histogram reading: total count, total sum, and the
/// non-empty buckets as `(inclusive upper bound, count)` pairs.
#[derive(Clone, Debug, PartialEq)]
pub struct HistSnapshot {
    pub count: u64,
    pub sum: u64,
    pub buckets: Vec<(u64, u64)>,
}

impl HistSnapshot {
    /// Fold another reading into this one: counts and sums add, buckets
    /// combine by upper bound (the result stays le-sorted) — how a
    /// multi-replica snapshot aggregates per-replica histograms into one
    /// totals row.
    pub fn merge(&mut self, other: &HistSnapshot) {
        self.count += other.count;
        self.sum += other.sum;
        let mut all: Vec<(u64, u64)> = std::mem::take(&mut self.buckets);
        all.extend(other.buckets.iter().copied());
        all.sort_by_key(|(le, _)| *le);
        for (le, n) in all {
            match self.buckets.last_mut() {
                Some((last_le, last_n)) if *last_le == le => *last_n += n,
                _ => self.buckets.push((le, n)),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counter_and_gauge_basics() {
        let c = Counter::default();
        c.inc();
        c.add(4);
        assert_eq!(c.get(), 5);
        c.set_at_least(3); // lower: no-op
        assert_eq!(c.get(), 5);
        c.set_at_least(9);
        assert_eq!(c.get(), 9);
        let g = Gauge::default();
        g.set(7);
        g.set_max(3); // lower: no-op
        assert_eq!(g.get(), 7);
        g.set_max(11);
        g.inc();
        g.dec();
        assert_eq!(g.get(), 11);
    }

    #[test]
    fn histogram_buckets_by_bit_length() {
        let h = Histogram::default();
        for v in [0, 1, 2, 3, 7, 8, 1000] {
            h.observe(v);
        }
        let s = h.snapshot();
        assert_eq!(s.count, 7);
        assert_eq!(s.sum, 1021);
        // 0 -> le 0; 1 -> le 1; 2,3 -> le 3; 7 -> le 7; 8 -> le 15; 1000 -> le 1023
        assert_eq!(s.buckets, vec![(0, 1), (1, 1), (3, 2), (7, 1), (15, 1), (1023, 1)]);
    }

    #[test]
    fn histogram_top_bucket_holds_max() {
        let h = Histogram::default();
        h.observe(u64::MAX);
        assert_eq!(h.snapshot().buckets, vec![(u64::MAX, 1)]);
    }

    #[test]
    fn empty_histogram_snapshot() {
        let s = Histogram::default().snapshot();
        assert_eq!(s, HistSnapshot { count: 0, sum: 0, buckets: vec![] });
    }

    #[test]
    fn hist_snapshot_merge_combines_buckets_by_le() {
        let mut a = HistSnapshot { count: 3, sum: 6, buckets: vec![(1, 2), (7, 1)] };
        let b = HistSnapshot { count: 2, sum: 18, buckets: vec![(3, 1), (15, 1)] };
        a.merge(&b);
        assert_eq!(a.count, 5);
        assert_eq!(a.sum, 24);
        assert_eq!(a.buckets, vec![(1, 2), (3, 1), (7, 1), (15, 1)]);
        // overlapping buckets add instead of duplicating
        let c = HistSnapshot { count: 1, sum: 1, buckets: vec![(1, 1)] };
        a.merge(&c);
        assert_eq!(a.buckets, vec![(1, 3), (3, 1), (7, 1), (15, 1)]);
        // merging an empty reading is a no-op on the buckets
        let before = a.clone();
        a.merge(&HistSnapshot { count: 0, sum: 0, buckets: vec![] });
        assert_eq!(a, before);
    }
}
