//! Process-wide serve-path telemetry: a lock-free metrics registry with
//! one snapshot type feeding three sinks.
//!
//! * [`Clock`] — real vs. deterministic mock time; every engine duration
//!   and phase span reads it, so metric output is golden-pinnable.
//! * [`Counter`] / [`Gauge`] / [`Histogram`] — plain relaxed atomics, no
//!   dependencies; updates are wait-free single RMWs (contract in
//!   DESIGN.md "Observability").
//! * [`Obs`] — the registry handle. Cheap to clone (an `Arc`); the serve
//!   engine, scheduler, KV budget, net front door and worker pool all
//!   write into one shared instance.
//! * [`Snapshot`] — a generation-stamped point-in-time reading, rendered
//!   as flat JSON (the `stats` TCP frame and the `metrics-snapshot`
//!   event) or Prometheus-style text exposition (`--metrics-file`).
//!
//! Phase spans ([`Obs::span`] / [`Obs::record_phase`]) feed fixed
//! log-bucket duration histograms per phase (prefill, decode, pack,
//! solve, net-read, net-write) — cheap enough to leave on everywhere.

pub mod clock;
pub mod metrics;

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

pub use clock::Clock;
pub use metrics::{Counter, Gauge, HistSnapshot, Histogram};

use crate::sparse::WorkerPool;
use crate::util::json::Json;

/// The instrumented phases of the serve path, each backed by a duration
/// histogram (`phase_<name>_ns`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Phase {
    /// Chunked prompt prefill through the packed linears.
    Prefill,
    /// One decode step over the active batch.
    Decode,
    /// Packing pruned params into a `SparseStore`.
    Pack,
    /// The one-shot prune (Hessian solve) before serving.
    Solve,
    /// Blocking socket reads on a net connection.
    NetRead,
    /// Frame writes back to a net client.
    NetWrite,
}

impl Phase {
    pub const ALL: [Phase; 6] = [
        Phase::Prefill,
        Phase::Decode,
        Phase::Pack,
        Phase::Solve,
        Phase::NetRead,
        Phase::NetWrite,
    ];

    /// The histogram key (`phase_*_ns`) this phase records into.
    pub fn metric_name(self) -> &'static str {
        match self {
            Phase::Prefill => "phase_prefill_ns",
            Phase::Decode => "phase_decode_ns",
            Phase::Pack => "phase_pack_ns",
            Phase::Solve => "phase_solve_ns",
            Phase::NetRead => "phase_net_read_ns",
            Phase::NetWrite => "phase_net_write_ns",
        }
    }
}

/// The fixed metric registry: every serve-path metric as a named field.
/// Fixed fields (not a string-keyed map) keep the hot path at one atomic
/// RMW with zero lookups, and make the snapshot schema a compile-time
/// fact.
#[derive(Debug, Default)]
pub struct Metrics {
    // counters
    pub tokens_decoded_total: Counter,
    pub tokens_prefilled_total: Counter,
    pub steps_total: Counter,
    pub requests_enqueued_total: Counter,
    pub requests_admitted_total: Counter,
    pub requests_finished_total: Counter,
    pub requests_cancelled_total: Counter,
    pub requests_rejected_total: Counter,
    pub cache_evictions_total: Counter,
    /// Events a sink failed to write (satellite of the silent
    /// `JsonlSink` error swallow); mirrored via `set_at_least`.
    pub events_dropped_total: Counter,
    /// ttft anchors missing from the engine's enqueue map — each one is
    /// a silently-zeroed ttft sample (should stay 0).
    pub ttft_anchor_missing_total: Counter,
    pub net_frames_read_total: Counter,
    pub net_bytes_read_total: Counter,
    pub net_frames_written_total: Counter,
    pub net_bytes_written_total: Counter,
    // gauges
    pub queue_depth: Gauge,
    pub queue_depth_peak: Gauge,
    pub cache_bytes_in_use: Gauge,
    pub cache_bytes_peak: Gauge,
    pub connections_open: Gauge,
    /// fleet variants currently resident (0 when no fleet is attached —
    /// the always-resident default model is not counted)
    pub models_resident: Gauge,
    /// weight bytes served straight from mapped `.spkt` pages, default
    /// model + resident fleet variants
    pub weight_bytes_mapped: Gauge,
    // histograms
    pub batch_size: Histogram,
    pub phase_prefill_ns: Histogram,
    pub phase_decode_ns: Histogram,
    pub phase_pack_ns: Histogram,
    pub phase_solve_ns: Histogram,
    pub phase_net_read_ns: Histogram,
    pub phase_net_write_ns: Histogram,
}

impl Metrics {
    pub fn phase_hist(&self, phase: Phase) -> &Histogram {
        match phase {
            Phase::Prefill => &self.phase_prefill_ns,
            Phase::Decode => &self.phase_decode_ns,
            Phase::Pack => &self.phase_pack_ns,
            Phase::Solve => &self.phase_solve_ns,
            Phase::NetRead => &self.phase_net_read_ns,
            Phase::NetWrite => &self.phase_net_write_ns,
        }
    }
}

struct ObsInner {
    clock: Clock,
    metrics: Metrics,
    /// Snapshot serial number; bumped per [`Obs::snapshot`].
    generation: AtomicU64,
    /// Pool whose per-worker stats ride in the snapshot (attached by the
    /// engine). The lock sits on the cold snapshot path only — metric
    /// updates never touch it.
    pool: Mutex<Option<WorkerPool>>,
    /// Per-replica registries attached by the admission router: each
    /// engine replica writes into its own `Obs`, and this (front-door)
    /// registry's snapshot folds them in — aggregated totals at the top
    /// level plus flat `replica_N_*` families. Empty for a bare engine,
    /// which keeps the single-registry renderings byte-identical.
    replicas: Mutex<Vec<Obs>>,
}

/// Shared handle to one telemetry registry. Clone freely; all clones
/// write into the same atomics.
#[derive(Clone)]
pub struct Obs {
    inner: Arc<ObsInner>,
}

impl Default for Obs {
    fn default() -> Obs {
        Obs::new(Clock::real())
    }
}

impl std::fmt::Debug for Obs {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Obs").field("clock", &self.inner.clock).finish()
    }
}

impl Obs {
    pub fn new(clock: Clock) -> Obs {
        Obs {
            inner: Arc::new(ObsInner {
                clock,
                metrics: Metrics::default(),
                generation: AtomicU64::new(0),
                pool: Mutex::new(None),
                replicas: Mutex::new(Vec::new()),
            }),
        }
    }

    pub fn clock(&self) -> &Clock {
        &self.inner.clock
    }

    /// The registry fields, for direct hot-path updates
    /// (`obs.metrics().tokens_decoded_total.inc()`).
    pub fn metrics(&self) -> &Metrics {
        &self.inner.metrics
    }

    /// Attach the worker pool whose per-worker busy/tile stats the
    /// snapshot should report (replaces any earlier attachment).
    pub fn attach_pool(&self, pool: WorkerPool) {
        *self.inner.pool.lock().unwrap() = Some(pool);
    }

    /// Attach the router's per-replica registries (replaces any earlier
    /// attachment): [`Obs::snapshot`] on this handle then reports
    /// aggregated totals (counters and non-peak gauges summed, `*_peak`
    /// gauges maxed, histograms merged) plus a `replica_N_*` family per
    /// replica, all in one snapshot.
    pub fn attach_replicas(&self, replicas: Vec<Obs>) {
        *self.inner.replicas.lock().unwrap() = replicas;
    }

    /// Record one completed phase duration.
    pub fn record_phase(&self, phase: Phase, ns: u64) {
        self.inner.metrics.phase_hist(phase).observe(ns);
    }

    /// Start a phase span; the duration (clock reads at start and drop)
    /// lands in the phase histogram when the guard drops.
    pub fn span(&self, phase: Phase) -> PhaseSpan<'_> {
        PhaseSpan { obs: self, phase, start_ns: self.inner.clock.now_ns() }
    }

    /// A generation-stamped point-in-time reading of every metric. With
    /// replica registries attached, the top-level values are aggregated
    /// across this registry and every replica (counters and non-peak
    /// gauges sum, `*_peak` gauges max, histograms merge bucket-wise),
    /// and each replica's own reading rides along in
    /// [`Snapshot::replicas`].
    pub fn snapshot(&self) -> Snapshot {
        let m = &self.inner.metrics;
        let generation = self.inner.generation.fetch_add(1, Ordering::Relaxed) + 1;
        let workers: Vec<WorkerSnap> = self
            .inner
            .pool
            .lock()
            .unwrap()
            .as_ref()
            .map(|p| p.stats())
            .unwrap_or_default()
            .into_iter()
            .enumerate()
            .map(|(i, (busy_ns, tiles))| WorkerSnap { worker: i, busy_ns, tiles })
            .collect();
        let mut counters = read_counters(m);
        let mut gauges = read_gauges(m);
        let mut hists = read_hists(m);
        let mut replicas = Vec::new();
        for (i, r) in self.inner.replicas.lock().unwrap().iter().enumerate() {
            let rm = r.metrics();
            let (rc, rg, rh) = (read_counters(rm), read_gauges(rm), read_hists(rm));
            for ((_, total), (_, v)) in counters.iter_mut().zip(&rc) {
                *total += v;
            }
            for ((name, total), (_, v)) in gauges.iter_mut().zip(&rg) {
                if name.ends_with("_peak") {
                    // a high watermark across replicas is the worst single
                    // replica, not the sum of per-replica peaks (the peaks
                    // need not have coincided in time)
                    *total = (*total).max(*v);
                } else {
                    *total += v;
                }
            }
            for ((_, total), (_, h)) in hists.iter_mut().zip(&rh) {
                total.merge(h);
            }
            replicas.push(ReplicaSnap { replica: i, counters: rc, gauges: rg, hists: rh });
        }
        Snapshot { generation, counters, gauges, hists, workers, replicas }
    }
}

/// The fixed counter schema, read in declaration order (shared by the
/// top-level registry and each attached replica, so aggregation can zip
/// the vectors index-wise).
fn read_counters(m: &Metrics) -> Vec<(&'static str, u64)> {
    vec![
        ("tokens_decoded_total", m.tokens_decoded_total.get()),
        ("tokens_prefilled_total", m.tokens_prefilled_total.get()),
        ("steps_total", m.steps_total.get()),
        ("requests_enqueued_total", m.requests_enqueued_total.get()),
        ("requests_admitted_total", m.requests_admitted_total.get()),
        ("requests_finished_total", m.requests_finished_total.get()),
        ("requests_cancelled_total", m.requests_cancelled_total.get()),
        ("requests_rejected_total", m.requests_rejected_total.get()),
        ("cache_evictions_total", m.cache_evictions_total.get()),
        ("events_dropped_total", m.events_dropped_total.get()),
        ("ttft_anchor_missing_total", m.ttft_anchor_missing_total.get()),
        ("net_frames_read_total", m.net_frames_read_total.get()),
        ("net_bytes_read_total", m.net_bytes_read_total.get()),
        ("net_frames_written_total", m.net_frames_written_total.get()),
        ("net_bytes_written_total", m.net_bytes_written_total.get()),
    ]
}

fn read_gauges(m: &Metrics) -> Vec<(&'static str, u64)> {
    vec![
        ("queue_depth", m.queue_depth.get()),
        ("queue_depth_peak", m.queue_depth_peak.get()),
        ("cache_bytes_in_use", m.cache_bytes_in_use.get()),
        ("cache_bytes_peak", m.cache_bytes_peak.get()),
        ("connections_open", m.connections_open.get()),
        ("models_resident", m.models_resident.get()),
        ("weight_bytes_mapped", m.weight_bytes_mapped.get()),
    ]
}

fn read_hists(m: &Metrics) -> Vec<(&'static str, HistSnapshot)> {
    let mut hs = vec![("batch_size", m.batch_size.snapshot())];
    for p in Phase::ALL {
        hs.push((p.metric_name(), m.phase_hist(p).snapshot()));
    }
    hs
}

/// Drop guard recording a phase duration (see [`Obs::span`]).
pub struct PhaseSpan<'a> {
    obs: &'a Obs,
    phase: Phase,
    start_ns: u64,
}

impl Drop for PhaseSpan<'_> {
    fn drop(&mut self) {
        let dt = self.obs.clock().now_ns().saturating_sub(self.start_ns);
        self.obs.record_phase(self.phase, dt);
    }
}

/// One worker's lifetime stats from the attached [`WorkerPool`].
#[derive(Clone, Debug, PartialEq)]
pub struct WorkerSnap {
    pub worker: usize,
    pub busy_ns: u64,
    pub tiles: u64,
}

/// One replica's registry reading inside a multi-replica [`Snapshot`]
/// (same schema as the top-level vectors).
#[derive(Clone, Debug, PartialEq)]
pub struct ReplicaSnap {
    pub replica: usize,
    pub counters: Vec<(&'static str, u64)>,
    pub gauges: Vec<(&'static str, u64)>,
    pub hists: Vec<(&'static str, HistSnapshot)>,
}

impl ReplicaSnap {
    pub fn counter(&self, name: &str) -> Option<u64> {
        self.counters.iter().find(|(n, _)| *n == name).map(|(_, v)| *v)
    }

    pub fn gauge(&self, name: &str) -> Option<u64> {
        self.gauges.iter().find(|(n, _)| *n == name).map(|(_, v)| *v)
    }
}

/// A point-in-time reading of the whole registry. One snapshot feeds all
/// three sinks: [`Snapshot::to_json`] (the `stats` frame and the
/// `metrics-snapshot` event) and [`Snapshot::to_prometheus`]
/// (`--metrics-file`).
#[derive(Clone, Debug, PartialEq)]
pub struct Snapshot {
    pub generation: u64,
    pub counters: Vec<(&'static str, u64)>,
    pub gauges: Vec<(&'static str, u64)>,
    pub hists: Vec<(&'static str, HistSnapshot)>,
    pub workers: Vec<WorkerSnap>,
    /// Per-replica readings when the router attached replica registries
    /// ([`Obs::attach_replicas`]); empty for a bare engine. The scalar
    /// top-level values already aggregate these.
    pub replicas: Vec<ReplicaSnap>,
}

impl Snapshot {
    pub fn counter(&self, name: &str) -> Option<u64> {
        self.counters.iter().find(|(n, _)| *n == name).map(|(_, v)| *v)
    }

    pub fn gauge(&self, name: &str) -> Option<u64> {
        self.gauges.iter().find(|(n, _)| *n == name).map(|(_, v)| *v)
    }

    pub fn hist(&self, name: &str) -> Option<&HistSnapshot> {
        self.hists.iter().find(|(n, _)| *n == name).map(|(_, h)| h)
    }

    /// Flat JSON object: scalar metrics as top-level keys (greppable,
    /// e.g. `"tokens_decoded_total":24`), histograms as
    /// `{buckets: [[le, n], ...], count, sum}`, worker stats under
    /// `"workers"`.
    pub fn to_json(&self) -> Json {
        let mut o = std::collections::BTreeMap::new();
        o.insert("generation".to_string(), Json::Num(self.generation as f64));
        for (name, v) in self.counters.iter().chain(self.gauges.iter()) {
            o.insert(name.to_string(), Json::Num(*v as f64));
        }
        for (name, h) in &self.hists {
            let buckets = h
                .buckets
                .iter()
                .map(|(le, n)| Json::Arr(vec![Json::Num(*le as f64), Json::Num(*n as f64)]))
                .collect();
            let mut ho = std::collections::BTreeMap::new();
            ho.insert("buckets".to_string(), Json::Arr(buckets));
            ho.insert("count".to_string(), Json::Num(h.count as f64));
            ho.insert("sum".to_string(), Json::Num(h.sum as f64));
            o.insert(name.to_string(), Json::Obj(ho));
        }
        let workers = self
            .workers
            .iter()
            .map(|w| {
                let mut wo = std::collections::BTreeMap::new();
                wo.insert("busy_ns".to_string(), Json::Num(w.busy_ns as f64));
                wo.insert("tiles".to_string(), Json::Num(w.tiles as f64));
                wo.insert("worker".to_string(), Json::Num(w.worker as f64));
                Json::Obj(wo)
            })
            .collect();
        o.insert("workers".to_string(), Json::Arr(workers));
        // flat per-replica scalar families (`replica_0_tokens_decoded_total`)
        // stay as greppable as the aggregated keys; per-replica histograms
        // are omitted — the merged top-level histograms carry the totals
        for r in &self.replicas {
            for (name, v) in r.counters.iter().chain(r.gauges.iter()) {
                o.insert(format!("replica_{}_{name}", r.replica), Json::Num(*v as f64));
            }
        }
        Json::Obj(o)
    }

    /// Prometheus-style text exposition (`# TYPE` lines, `sparsegpt_`
    /// prefix, cumulative histogram buckets, worker stats labelled
    /// `{worker="i"}`).
    pub fn to_prometheus(&self) -> String {
        use std::fmt::Write as _;
        let mut out = String::new();
        for (name, v) in &self.counters {
            let _ = writeln!(out, "# TYPE sparsegpt_{name} counter");
            let _ = writeln!(out, "sparsegpt_{name} {v}");
        }
        for (name, v) in &self.gauges {
            let _ = writeln!(out, "# TYPE sparsegpt_{name} gauge");
            let _ = writeln!(out, "sparsegpt_{name} {v}");
        }
        for (name, h) in &self.hists {
            let _ = writeln!(out, "# TYPE sparsegpt_{name} histogram");
            let mut cum = 0u64;
            for (le, n) in &h.buckets {
                cum += n;
                let _ = writeln!(out, "sparsegpt_{name}_bucket{{le=\"{le}\"}} {cum}");
            }
            let _ = writeln!(out, "sparsegpt_{name}_bucket{{le=\"+Inf\"}} {}", h.count);
            let _ = writeln!(out, "sparsegpt_{name}_sum {}", h.sum);
            let _ = writeln!(out, "sparsegpt_{name}_count {}", h.count);
        }
        let _ = writeln!(out, "# TYPE sparsegpt_snapshot_generation gauge");
        let _ = writeln!(out, "sparsegpt_snapshot_generation {}", self.generation);
        if !self.workers.is_empty() {
            let _ = writeln!(out, "# TYPE sparsegpt_worker_busy_ns counter");
            for w in &self.workers {
                let _ = writeln!(
                    out,
                    "sparsegpt_worker_busy_ns{{worker=\"{}\"}} {}",
                    w.worker, w.busy_ns
                );
            }
            let _ = writeln!(out, "# TYPE sparsegpt_worker_tiles_total counter");
            for w in &self.workers {
                let _ = writeln!(
                    out,
                    "sparsegpt_worker_tiles_total{{worker=\"{}\"}} {}",
                    w.worker, w.tiles
                );
            }
        }
        for r in &self.replicas {
            for (name, v) in &r.counters {
                let _ = writeln!(out, "# TYPE sparsegpt_replica_{}_{name} counter", r.replica);
                let _ = writeln!(out, "sparsegpt_replica_{}_{name} {v}", r.replica);
            }
            for (name, v) in &r.gauges {
                let _ = writeln!(out, "# TYPE sparsegpt_replica_{}_{name} gauge", r.replica);
                let _ = writeln!(out, "sparsegpt_replica_{}_{name} {v}", r.replica);
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn snapshot_generation_increments_per_read() {
        let obs = Obs::default();
        assert_eq!(obs.snapshot().generation, 1);
        assert_eq!(obs.snapshot().generation, 2);
        // clones share the registry (and its generation)
        assert_eq!(obs.clone().snapshot().generation, 3);
    }

    #[test]
    fn spans_and_counters_land_in_the_snapshot() {
        let obs = Obs::new(Clock::mock(1_000));
        obs.metrics().tokens_decoded_total.add(3);
        obs.metrics().queue_depth.set(2);
        obs.metrics().queue_depth_peak.set_max(5);
        obs.metrics().batch_size.observe(2);
        {
            let _span = obs.span(Phase::Decode); // start read + drop read = 1 tick
        }
        obs.record_phase(Phase::Prefill, 5);
        let s = obs.snapshot();
        assert_eq!(s.counter("tokens_decoded_total"), Some(3));
        assert_eq!(s.counter("requests_rejected_total"), Some(0));
        assert_eq!(s.gauge("queue_depth"), Some(2));
        assert_eq!(s.gauge("queue_depth_peak"), Some(5));
        let d = s.hist("phase_decode_ns").unwrap();
        assert_eq!((d.count, d.sum), (1, 1_000));
        assert_eq!(s.hist("phase_prefill_ns").unwrap().buckets, vec![(7, 1)]);
        assert!(s.workers.is_empty(), "no pool attached");
    }

    #[test]
    fn attached_replicas_aggregate_and_expose_flat_families() {
        let front = Obs::new(Clock::mock(1_000));
        let (r0, r1) = (Obs::new(Clock::mock(1_000)), Obs::new(Clock::mock(1_000)));
        front.metrics().requests_rejected_total.add(1); // router-side 429
        r0.metrics().tokens_decoded_total.add(10);
        r0.metrics().cache_bytes_peak.set_max(100);
        r0.metrics().batch_size.observe(2);
        r1.metrics().tokens_decoded_total.add(5);
        r1.metrics().cache_bytes_peak.set_max(40);
        r1.metrics().batch_size.observe(2);
        r1.metrics().batch_size.observe(8);
        front.attach_replicas(vec![r0, r1]);
        let s = front.snapshot();
        // counters sum across the front registry and both replicas
        assert_eq!(s.counter("tokens_decoded_total"), Some(15));
        assert_eq!(s.counter("requests_rejected_total"), Some(1));
        // peak gauges take the worst replica, not the sum
        assert_eq!(s.gauge("cache_bytes_peak"), Some(100));
        // histograms merge bucket-wise
        let b = s.hist("batch_size").unwrap();
        assert_eq!((b.count, b.sum), (3, 12));
        assert_eq!(b.buckets, vec![(3, 2), (15, 1)]);
        // each replica's own reading rides along, flat in both renderings
        assert_eq!(s.replicas.len(), 2);
        assert_eq!(s.replicas[0].counter("tokens_decoded_total"), Some(10));
        assert_eq!(s.replicas[1].counter("tokens_decoded_total"), Some(5));
        let j = s.to_json().to_string_compact();
        assert!(j.contains("\"replica_0_tokens_decoded_total\":10"));
        assert!(j.contains("\"replica_1_tokens_decoded_total\":5"));
        assert!(j.contains("\"tokens_decoded_total\":15"));
        let prom = s.to_prometheus();
        assert!(prom.contains("sparsegpt_replica_0_tokens_decoded_total 10\n"));
        assert!(prom.contains("sparsegpt_replica_1_cache_bytes_peak 40\n"));
    }

    #[test]
    fn attached_pool_stats_appear() {
        let obs = Obs::default();
        obs.attach_pool(crate::sparse::WorkerPool::new(2));
        let s = obs.snapshot();
        assert_eq!(s.workers.len(), 2);
        assert_eq!(s.workers[0], WorkerSnap { worker: 0, busy_ns: 0, tiles: 0 });
    }

    /// The snapshot's two renderings are the format contract for all
    /// three sinks — pinned byte-exactly under a hand-driven mock clock.
    #[test]
    fn rendered_formats_are_pinned() {
        let obs = Obs::new(Clock::mock(1_000));
        obs.metrics().tokens_decoded_total.add(24);
        obs.metrics().requests_finished_total.add(2);
        obs.metrics().queue_depth_peak.set_max(3);
        obs.metrics().batch_size.observe(2);
        obs.metrics().batch_size.observe(2);
        obs.record_phase(Phase::Decode, 1_000);
        let s = obs.snapshot();
        assert_eq!(
            s.to_json().to_string_compact(),
            concat!(
                "{\"batch_size\":{\"buckets\":[[3,2]],\"count\":2,\"sum\":4},",
                "\"cache_bytes_in_use\":0,",
                "\"cache_bytes_peak\":0,",
                "\"cache_evictions_total\":0,",
                "\"connections_open\":0,",
                "\"events_dropped_total\":0,",
                "\"generation\":1,",
                "\"models_resident\":0,",
                "\"net_bytes_read_total\":0,",
                "\"net_bytes_written_total\":0,",
                "\"net_frames_read_total\":0,",
                "\"net_frames_written_total\":0,",
                "\"phase_decode_ns\":{\"buckets\":[[1023,1]],\"count\":1,\"sum\":1000},",
                "\"phase_net_read_ns\":{\"buckets\":[],\"count\":0,\"sum\":0},",
                "\"phase_net_write_ns\":{\"buckets\":[],\"count\":0,\"sum\":0},",
                "\"phase_pack_ns\":{\"buckets\":[],\"count\":0,\"sum\":0},",
                "\"phase_prefill_ns\":{\"buckets\":[],\"count\":0,\"sum\":0},",
                "\"phase_solve_ns\":{\"buckets\":[],\"count\":0,\"sum\":0},",
                "\"queue_depth\":0,",
                "\"queue_depth_peak\":3,",
                "\"requests_admitted_total\":0,",
                "\"requests_cancelled_total\":0,",
                "\"requests_enqueued_total\":0,",
                "\"requests_finished_total\":2,",
                "\"requests_rejected_total\":0,",
                "\"steps_total\":0,",
                "\"tokens_decoded_total\":24,",
                "\"tokens_prefilled_total\":0,",
                "\"ttft_anchor_missing_total\":0,",
                "\"weight_bytes_mapped\":0,",
                "\"workers\":[]}"
            )
        );
        let prom = s.to_prometheus();
        assert!(prom.contains(
            "# TYPE sparsegpt_tokens_decoded_total counter\nsparsegpt_tokens_decoded_total 24\n"
        ));
        assert!(prom.contains(
            "# TYPE sparsegpt_queue_depth_peak gauge\nsparsegpt_queue_depth_peak 3\n"
        ));
        assert!(prom.contains(
            "# TYPE sparsegpt_phase_decode_ns histogram\n\
             sparsegpt_phase_decode_ns_bucket{le=\"1023\"} 1\n\
             sparsegpt_phase_decode_ns_bucket{le=\"+Inf\"} 1\n\
             sparsegpt_phase_decode_ns_sum 1000\n\
             sparsegpt_phase_decode_ns_count 1\n"
        ));
        assert!(prom.contains(
            "# TYPE sparsegpt_batch_size histogram\n\
             sparsegpt_batch_size_bucket{le=\"3\"} 2\n\
             sparsegpt_batch_size_bucket{le=\"+Inf\"} 2\n\
             sparsegpt_batch_size_sum 4\n\
             sparsegpt_batch_size_count 2\n"
        ));
        assert!(prom.ends_with(
            "# TYPE sparsegpt_snapshot_generation gauge\nsparsegpt_snapshot_generation 1\n"
        ));
    }
}
