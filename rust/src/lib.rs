//! # sparsegpt — a reproduction of *SparseGPT: Massive Language Models Can
//! be Accurately Pruned in One-Shot* (Frantar & Alistarh, ICML 2023)
//!
//! Four-layer architecture (Python never on the request path):
//!   * **L1** Pallas kernels (Algorithm 1 column sweep, Hessian accumulation)
//!   * **L2** JAX graphs (model fwd/bwd, layer solver, blocked linalg),
//!     AOT-lowered to HLO-text artifacts by `make artifacts`
//!   * **L3** this crate's substrate: the compression pipeline coordinator,
//!     everything the paper's evaluation needs (synthetic corpora, BPE
//!     tokenizer, trainer, perplexity/zero-shot eval, sparse inference
//!     engine, baselines) and the pluggable execution [`runtime`]: the
//!     PJRT `Runtime` that loads + executes compiled artifacts, or the
//!     pure-Rust `ReferenceBackend` interpreting the same vocabulary with
//!     zero build dependencies (`--backend reference`).
//!   * **L4** the [`api`] job layer: typed `JobSpec`s executed by a
//!     `Session` with a structured (human or JSON-lines) event stream —
//!     the single front door the CLI, examples and benches go through.
//!
//! See DESIGN.md for the system inventory and EXPERIMENTS.md for the
//! paper-vs-measured results.

pub mod api;
pub mod bench;
pub mod cli;
pub mod coordinator;
pub mod data;
pub mod eval;
pub mod harness;
pub mod model;
pub mod obs;
pub mod runtime;
pub mod serve;
pub mod solver;
pub mod sparse;
pub mod tensor;
pub mod util;
