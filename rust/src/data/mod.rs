//! Data substrate: synthetic corpora (the offline stand-ins for C4,
//! WikiText2 and PTB), a byte-level BPE tokenizer, and tokenized datasets
//! with training / evaluation / calibration samplers.

pub mod corpus;
pub mod dataset;
pub mod tokenizer;

pub use corpus::{CorpusStyle, Lexicon};
pub use dataset::Dataset;
pub use tokenizer::Tokenizer;
