//! Synthetic corpus generators.
//!
//! The paper calibrates on C4 and evaluates on raw-WikiText2, PTB and a C4
//! validation subset. Offline, we build three corpora with *distinct
//! distributions over a shared lexicon* so that (a) pruning calibration
//! never sees eval-distribution text (the paper's zero-shot property), and
//! (b) one BPE tokenizer covers all of them:
//!
//!   * `C4`  — mixed web-ish templates, varied punctuation and lengths
//!             (calibration + validation)
//!   * `Wiki` — encyclopedic templates with headings and definition forms
//!   * `Ptb` — newswire-ish, lowercase, no punctuation (the paper notes PTB
//!             is punctuation-free and concatenates without separators)
//!
//! Text is generated from a topic-Markov PCFG over an invented syllabic
//! lexicon: function-word syntax gives local structure, topic chains give
//! longer-range structure — enough signal that a small trained transformer
//! has meaningfully low perplexity, which is what layer-wise pruning needs
//! (activations with real correlational structure, i.e. non-trivial
//! Hessians with outlier directions).

use crate::util::prng::Rng;

pub const N_TOPICS: usize = 8;
const NOUNS_PER_TOPIC: usize = 24;
const VERBS_PER_TOPIC: usize = 12;
const ADJS_PER_TOPIC: usize = 12;
const SHARED_NOUNS: usize = 40;
const SHARED_VERBS: usize = 24;
const SHARED_ADJS: usize = 20;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CorpusStyle {
    C4,
    Wiki,
    Ptb,
}

impl CorpusStyle {
    pub fn name(&self) -> &'static str {
        match self {
            CorpusStyle::C4 => "synth-c4",
            CorpusStyle::Wiki => "synth-wiki",
            CorpusStyle::Ptb => "synth-ptb",
        }
    }

    pub fn all() -> [CorpusStyle; 3] {
        [CorpusStyle::C4, CorpusStyle::Wiki, CorpusStyle::Ptb]
    }
}

/// The shared invented vocabulary, organized by part of speech and topic.
#[derive(Clone, Debug)]
pub struct Lexicon {
    pub topic_nouns: Vec<Vec<String>>,
    pub topic_verbs: Vec<Vec<String>>,
    pub topic_adjs: Vec<Vec<String>>,
    pub shared_nouns: Vec<String>,
    pub shared_verbs: Vec<String>,
    pub shared_adjs: Vec<String>,
    pub names: Vec<String>,
}

fn make_word(rng: &mut Rng, syllables: usize) -> String {
    const ONSETS: [&str; 16] = [
        "b", "d", "f", "g", "k", "l", "m", "n", "p", "r", "s", "t", "v", "z", "st", "br",
    ];
    const VOWELS: [&str; 8] = ["a", "e", "i", "o", "u", "ai", "ea", "ou"];
    const CODAS: [&str; 8] = ["", "", "n", "r", "s", "l", "m", "k"];
    let mut w = String::new();
    for _ in 0..syllables {
        w.push_str(ONSETS[rng.below(ONSETS.len())]);
        w.push_str(VOWELS[rng.below(VOWELS.len())]);
        w.push_str(CODAS[rng.below(CODAS.len())]);
    }
    w
}

fn make_words(rng: &mut Rng, n: usize, syllables: std::ops::Range<usize>) -> Vec<String> {
    let mut out = Vec::with_capacity(n);
    let mut seen = std::collections::HashSet::new();
    while out.len() < n {
        let s = syllables.start + rng.below(syllables.end - syllables.start);
        let w = make_word(rng, s);
        if seen.insert(w.clone()) {
            out.push(w);
        }
    }
    out
}

impl Lexicon {
    /// Deterministic lexicon; all corpora and tasks share it.
    pub fn new(seed: u64) -> Lexicon {
        let mut rng = Rng::new(seed ^ 0x1e_c0de);
        Lexicon {
            topic_nouns: (0..N_TOPICS)
                .map(|_| make_words(&mut rng, NOUNS_PER_TOPIC, 2..4))
                .collect(),
            topic_verbs: (0..N_TOPICS)
                .map(|_| make_words(&mut rng, VERBS_PER_TOPIC, 2..3))
                .collect(),
            topic_adjs: (0..N_TOPICS)
                .map(|_| make_words(&mut rng, ADJS_PER_TOPIC, 2..3))
                .collect(),
            shared_nouns: make_words(&mut rng, SHARED_NOUNS, 1..3),
            shared_verbs: make_words(&mut rng, SHARED_VERBS, 1..3),
            shared_adjs: make_words(&mut rng, SHARED_ADJS, 1..3),
            names: make_words(&mut rng, 30, 2..4)
                .into_iter()
                .map(|w| {
                    let mut c = w.chars();
                    c.next().map(|f| f.to_uppercase().collect::<String>() + c.as_str()).unwrap()
                })
                .collect(),
        }
    }

    /// Zipf-ish sample from a topic-biased word class: with prob `bias`
    /// draw a topic word, otherwise a shared word; rank-weighted.
    fn sample<'a>(
        &'a self,
        rng: &mut Rng,
        topic_list: &'a [Vec<String>],
        shared: &'a [String],
        topic: usize,
        bias: f64,
    ) -> &'a str {
        let list: &[String] =
            if rng.f64() < bias { &topic_list[topic] } else { shared };
        // Zipf over ranks
        let weights: Vec<f64> = (0..list.len()).map(|i| 1.0 / (i + 1) as f64).collect();
        &list[rng.weighted(&weights)]
    }

    pub fn noun(&self, rng: &mut Rng, topic: usize, bias: f64) -> &str {
        self.sample(rng, &self.topic_nouns, &self.shared_nouns, topic, bias)
    }

    pub fn verb(&self, rng: &mut Rng, topic: usize, bias: f64) -> &str {
        self.sample(rng, &self.topic_verbs, &self.shared_verbs, topic, bias)
    }

    pub fn adj(&self, rng: &mut Rng, topic: usize, bias: f64) -> &str {
        self.sample(rng, &self.topic_adjs, &self.shared_adjs, topic, bias)
    }

    pub fn name(&self, rng: &mut Rng) -> &str {
        self.names[rng.below(self.names.len())].as_str()
    }
}

/// One generated sentence + the topic it was drawn from (tasks need this).
pub struct Sentence {
    pub text: String,
    pub topic: usize,
    /// the final content word (the cloze target for the lambada-like task)
    pub final_word: String,
}

pub fn gen_sentence(lex: &Lexicon, rng: &mut Rng, topic: usize, style: CorpusStyle) -> Sentence {
    let bias = 0.75;
    let n1 = lex.noun(rng, topic, bias).to_string();
    let v = lex.verb(rng, topic, bias).to_string();
    let a = lex.adj(rng, topic, bias).to_string();
    let n2 = lex.noun(rng, topic, bias).to_string();
    let nm = lex.name(rng).to_string();
    let template = rng.below(6);
    let (text, final_word) = match (style, template) {
        (CorpusStyle::Wiki, 0) => (format!("the {n1} of {n2} is a {a} {n1}"), n1.clone()),
        (CorpusStyle::Wiki, 1) => (format!("{nm} is known as the {n1} that {v} the {n2}"), n2.clone()),
        (CorpusStyle::Wiki, 2) => (format!("in the {n1} , the {a} {n2} {v}"), v.clone()),
        (CorpusStyle::Ptb, 0) => (format!("the {a} {n1} {v} the {n2}"), n2.clone()),
        (CorpusStyle::Ptb, 1) => (format!("{n1} and {n2} {v} in the {a} {n1}"), n1.clone()),
        (_, 0) => (format!("the {n1} {v} a {a} {n2}"), n2.clone()),
        (_, 1) => (format!("{nm} {v} the {n2} near the {a} {n1}"), n1.clone()),
        (_, 2) => (format!("a {a} {n1} always {v} the {n2}"), n2.clone()),
        (_, 3) => (format!("when the {n1} {v} , the {n2} is {a}"), a.clone()),
        (_, 4) => (format!("every {n2} in the {n1} {v}"), v.clone()),
        _ => (format!("the {n2} of the {a} {n1} {v}"), v.clone()),
    };
    // PTB is punctuation-free (the paper's preprocessing note)
    let text = if style == CorpusStyle::Ptb { text.replace(" ,", "") } else { text };
    Sentence { text, topic, final_word }
}

/// Generate a corpus of roughly `target_bytes` characters.
pub fn gen_corpus(lex: &Lexicon, style: CorpusStyle, seed: u64, target_bytes: usize) -> String {
    let mut rng = Rng::new(seed ^ 0xc0_4955 ^ style.name().len() as u64);
    let mut out = String::with_capacity(target_bytes + 4096);
    let mut topic = rng.below(N_TOPICS);
    while out.len() < target_bytes {
        // topic Markov chain: stay with prob .7
        if rng.f64() > 0.7 {
            topic = rng.below(N_TOPICS);
        }
        let n_sent = 3 + rng.below(9);
        match style {
            CorpusStyle::Wiki => {
                out.push_str(&format!("= {} =\n", lex.noun(&mut rng, topic, 0.9)));
                for _ in 0..n_sent {
                    let s = gen_sentence(lex, &mut rng, topic, style);
                    out.push_str(&s.text);
                    out.push_str(" . ");
                }
                out.push_str("\n\n");
            }
            CorpusStyle::Ptb => {
                // no punctuation, lowercase, direct concatenation
                for _ in 0..n_sent {
                    let s = gen_sentence(lex, &mut rng, topic, style);
                    out.push_str(&s.text.to_lowercase());
                    out.push(' ');
                }
                out.push('\n');
            }
            CorpusStyle::C4 => {
                for _ in 0..n_sent {
                    let s = gen_sentence(lex, &mut rng, topic, style);
                    out.push_str(&s.text);
                    match rng.below(4) {
                        0 => out.push_str(". "),
                        1 => out.push_str(" . "),
                        2 => out.push_str(", "),
                        _ => out.push_str(". "),
                    }
                }
                out.push('\n');
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lexicon_deterministic_and_disjoint_classes() {
        let a = Lexicon::new(1);
        let b = Lexicon::new(1);
        assert_eq!(a.topic_nouns, b.topic_nouns);
        assert_eq!(a.shared_verbs, b.shared_verbs);
        let c = Lexicon::new(2);
        assert_ne!(a.topic_nouns, c.topic_nouns);
    }

    #[test]
    fn corpora_have_distinct_styles() {
        let lex = Lexicon::new(0);
        let c4 = gen_corpus(&lex, CorpusStyle::C4, 0, 20_000);
        let wiki = gen_corpus(&lex, CorpusStyle::Wiki, 0, 20_000);
        let ptb = gen_corpus(&lex, CorpusStyle::Ptb, 0, 20_000);
        assert!(c4.len() >= 20_000);
        assert!(wiki.contains("= "));
        assert!(!ptb.contains('.') && !ptb.contains(','));
        assert_ne!(&c4[..1000], &wiki[..1000]);
    }

    #[test]
    fn sentences_expose_cloze_targets() {
        let lex = Lexicon::new(3);
        let mut rng = Rng::new(4);
        for _ in 0..50 {
            let s = gen_sentence(&lex, &mut rng, 2, CorpusStyle::C4);
            assert!(s.text.contains(&s.final_word));
            // final content word really is at the end of the sentence
            assert!(s.text.trim_end().ends_with(&s.final_word));
        }
    }

    #[test]
    fn corpus_deterministic() {
        let lex = Lexicon::new(0);
        assert_eq!(
            gen_corpus(&lex, CorpusStyle::C4, 7, 5_000),
            gen_corpus(&lex, CorpusStyle::C4, 7, 5_000)
        );
        assert_ne!(
            gen_corpus(&lex, CorpusStyle::C4, 7, 5_000),
            gen_corpus(&lex, CorpusStyle::C4, 8, 5_000)
        );
    }
}
