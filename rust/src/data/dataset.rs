//! Tokenized datasets: training batches (random windows), evaluation
//! segments (the HuggingFace full-stride procedure: concatenate, split into
//! non-overlapping seq-length pieces) and calibration sampling (the paper's
//! "random segments from the first shard of C4").

use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::data::tokenizer::Tokenizer;
use crate::util::prng::Rng;

#[derive(Clone, Debug)]
pub struct Dataset {
    pub name: String,
    pub tokens: Vec<i32>,
}

impl Dataset {
    pub fn from_text(name: &str, tok: &Tokenizer, text: &str) -> Dataset {
        Dataset { name: name.to_string(), tokens: tok.encode(text) }
    }

    pub fn load_tokens(name: &str, path: impl AsRef<Path>) -> Result<Dataset> {
        let bytes = std::fs::read(path.as_ref())
            .with_context(|| format!("reading token file {:?}", path.as_ref()))?;
        if bytes.len() % 4 != 0 {
            bail!("token file length not a multiple of 4");
        }
        let tokens = bytes
            .chunks_exact(4)
            .map(|c| i32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect();
        Ok(Dataset { name: name.to_string(), tokens })
    }

    pub fn save_tokens(&self, path: impl AsRef<Path>) -> Result<()> {
        let path = path.as_ref();
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        let mut bytes = Vec::with_capacity(self.tokens.len() * 4);
        for t in &self.tokens {
            bytes.extend_from_slice(&t.to_le_bytes());
        }
        std::fs::write(path, bytes)?;
        Ok(())
    }

    pub fn len(&self) -> usize {
        self.tokens.len()
    }

    pub fn is_empty(&self) -> bool {
        self.tokens.is_empty()
    }

    /// One training batch: `batch` random windows of `seq + 1` tokens,
    /// flattened row-major (what `train_step_<cfg>` consumes).
    pub fn train_batch(&self, rng: &mut Rng, batch: usize, seq: usize) -> Result<Vec<i32>> {
        let win = seq + 1;
        if self.tokens.len() < win {
            bail!("dataset {} too small for seq {}", self.name, seq);
        }
        let mut out = Vec::with_capacity(batch * win);
        for _ in 0..batch {
            let start = rng.below(self.tokens.len() - win + 1);
            out.extend_from_slice(&self.tokens[start..start + win]);
        }
        Ok(out)
    }

    /// Non-overlapping evaluation segments of `seq + 1` tokens (stride =
    /// seq, so each target token is scored exactly once), as rows.
    pub fn eval_segments(&self, seq: usize, max_segments: usize) -> Vec<Vec<i32>> {
        let win = seq + 1;
        let mut out = Vec::new();
        let mut start = 0;
        while start + win <= self.tokens.len() && out.len() < max_segments {
            out.push(self.tokens[start..start + win].to_vec());
            start += seq; // stride seq: segment k starts where k-1's targets ended
        }
        out
    }

    /// Calibration segments: `n` random `seq`-token windows (no targets
    /// needed — the solver only consumes activations).
    pub fn calibration_segments(&self, rng: &mut Rng, n: usize, seq: usize) -> Result<Vec<Vec<i32>>> {
        if self.tokens.len() < seq {
            bail!("dataset {} too small for calibration", self.name);
        }
        let mut out = Vec::with_capacity(n);
        for _ in 0..n {
            let start = rng.below(self.tokens.len() - seq + 1);
            out.push(self.tokens[start..start + seq].to_vec());
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::corpus::{gen_corpus, CorpusStyle, Lexicon};

    fn dataset() -> Dataset {
        let lex = Lexicon::new(0);
        let text = gen_corpus(&lex, CorpusStyle::C4, 1, 40_000);
        let tok = Tokenizer::train(&text[..20_000]);
        Dataset::from_text("t", &tok, &text)
    }

    #[test]
    fn train_batch_shapes_and_determinism() {
        let ds = dataset();
        let mut r1 = Rng::new(5);
        let mut r2 = Rng::new(5);
        let b1 = ds.train_batch(&mut r1, 4, 128).unwrap();
        let b2 = ds.train_batch(&mut r2, 4, 128).unwrap();
        assert_eq!(b1.len(), 4 * 129);
        assert_eq!(b1, b2);
    }

    #[test]
    fn eval_segments_stride_and_coverage() {
        let ds = dataset();
        let segs = ds.eval_segments(128, usize::MAX);
        assert!(!segs.is_empty());
        for w in segs.windows(2) {
            // consecutive segments overlap by exactly 1 token (context carry)
            assert_eq!(w[0][128], w[1][0]);
        }
        // each target position scored once: total targets == seq * n_segs
        let covered = segs.len() * 128;
        assert!(covered <= ds.len());
        assert!(covered + 129 + 128 > ds.len() - 1);
    }

    #[test]
    fn calibration_segments_in_range() {
        let ds = dataset();
        let mut rng = Rng::new(9);
        let segs = ds.calibration_segments(&mut rng, 16, 128).unwrap();
        assert_eq!(segs.len(), 16);
        for s in &segs {
            assert_eq!(s.len(), 128);
            assert!(s.iter().all(|&t| t >= 0 && (t as usize) < 512));
        }
    }

    #[test]
    fn token_file_roundtrip() {
        let ds = dataset();
        let dir = std::env::temp_dir().join(format!("sgpt_ds_{}", std::process::id()));
        let path = dir.join("t.tokens");
        ds.save_tokens(&path).unwrap();
        let back = Dataset::load_tokens("t", &path).unwrap();
        assert_eq!(ds.tokens, back.tokens);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn too_small_dataset_errors() {
        let ds = Dataset { name: "x".into(), tokens: vec![1, 2, 3] };
        let mut rng = Rng::new(0);
        assert!(ds.train_batch(&mut rng, 1, 128).is_err());
        assert!(ds.calibration_segments(&mut rng, 1, 128).is_err());
    }
}
