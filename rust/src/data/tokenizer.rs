//! Byte-level BPE tokenizer (vocab 512 = 256 byte tokens + 256 merges).
//!
//! Trained once on the calibration corpus, shared by all corpora and tasks.
//! Words (whitespace-split chunks, with the leading space attached GPT-2
//! style) are encoded independently with a per-word memo, which makes
//! encoding large corpora fast enough for this substrate.

use std::collections::HashMap;
use std::io::Write as _;
use std::path::Path;

use anyhow::{bail, Context, Result};

pub const VOCAB_SIZE: usize = 512;
const N_MERGES: usize = VOCAB_SIZE - 256;

#[derive(Clone, Debug)]
pub struct Tokenizer {
    /// merge list in rank order: (left, right) -> new token id 256 + rank
    pub merges: Vec<(u32, u32)>,
    rank: HashMap<(u32, u32), u32>,
}

impl Tokenizer {
    /// Train BPE merges on `text` (standard pair-frequency greedy merging
    /// over word chunks).
    pub fn train(text: &str) -> Tokenizer {
        // chunk -> count, each chunk as byte tokens
        let mut chunks: HashMap<Vec<u32>, usize> = HashMap::new();
        for word in split_chunks(text) {
            *chunks.entry(word.bytes().map(|b| b as u32).collect()).or_insert(0) += 1;
        }
        let mut merges = Vec::with_capacity(N_MERGES);
        let mut rank = HashMap::new();
        for m in 0..N_MERGES {
            let mut pair_counts: HashMap<(u32, u32), usize> = HashMap::new();
            for (toks, &count) in &chunks {
                for w in toks.windows(2) {
                    *pair_counts.entry((w[0], w[1])).or_insert(0) += count;
                }
            }
            // deterministic argmax: highest count, ties broken by pair value
            let Some((&best, _)) = pair_counts
                .iter()
                .max_by_key(|(pair, &count)| (count, std::cmp::Reverse(**pair)))
            else {
                break;
            };
            if pair_counts[&best] < 2 {
                break;
            }
            let new_id = 256 + m as u32;
            merges.push(best);
            rank.insert(best, new_id);
            // apply the merge to every chunk
            let old: Vec<(Vec<u32>, usize)> = chunks.drain().collect();
            for (toks, count) in old {
                let merged = apply_merge(&toks, best, new_id);
                *chunks.entry(merged).or_insert(0) += count;
            }
        }
        Tokenizer { merges, rank }
    }

    pub fn vocab_size(&self) -> usize {
        256 + self.merges.len()
    }

    /// Encode text to token ids.
    pub fn encode(&self, text: &str) -> Vec<i32> {
        let mut memo: HashMap<&str, Vec<i32>> = HashMap::new();
        let mut out = Vec::with_capacity(text.len() / 3);
        for word in split_chunks(text) {
            if let Some(toks) = memo.get(word) {
                out.extend_from_slice(toks);
                continue;
            }
            let toks = self.encode_chunk(word);
            out.extend_from_slice(&toks);
            memo.insert(word, toks);
        }
        out
    }

    fn encode_chunk(&self, chunk: &str) -> Vec<i32> {
        let mut toks: Vec<u32> = chunk.bytes().map(|b| b as u32).collect();
        loop {
            // find the lowest-rank applicable merge
            let mut best: Option<(u32, usize)> = None; // (new_id, pos)
            for (i, w) in toks.windows(2).enumerate() {
                if let Some(&id) = self.rank.get(&(w[0], w[1])) {
                    if best.map_or(true, |(b, _)| id < b) {
                        best = Some((id, i));
                    }
                }
            }
            let Some((id, _)) = best else { break };
            let pair = self.merges[(id - 256) as usize];
            toks = apply_merge(&toks, pair, id);
        }
        toks.into_iter().map(|t| t as i32).collect()
    }

    /// Decode token ids back to text (lossless byte-level round-trip).
    pub fn decode(&self, toks: &[i32]) -> String {
        let mut bytes = Vec::with_capacity(toks.len() * 2);
        for &t in toks {
            self.push_bytes(t as u32, &mut bytes);
        }
        String::from_utf8_lossy(&bytes).into_owned()
    }

    fn push_bytes(&self, t: u32, out: &mut Vec<u8>) {
        if t < 256 {
            out.push(t as u8);
        } else {
            let (l, r) = self.merges[(t - 256) as usize];
            self.push_bytes(l, out);
            self.push_bytes(r, out);
        }
    }

    // ---- persistence -------------------------------------------------------
    pub fn save(&self, path: impl AsRef<Path>) -> Result<()> {
        let path = path.as_ref();
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        let mut f = std::io::BufWriter::new(std::fs::File::create(path)?);
        writeln!(f, "sgpt-bpe-v1 {}", self.merges.len())?;
        for (l, r) in &self.merges {
            writeln!(f, "{l} {r}")?;
        }
        Ok(())
    }

    pub fn load(path: impl AsRef<Path>) -> Result<Tokenizer> {
        let text = std::fs::read_to_string(path.as_ref())
            .with_context(|| format!("reading tokenizer {:?}", path.as_ref()))?;
        let mut lines = text.lines();
        let header = lines.next().unwrap_or_default();
        let mut hp = header.split_whitespace();
        if hp.next() != Some("sgpt-bpe-v1") {
            bail!("bad tokenizer header {header:?}");
        }
        let n: usize = hp.next().unwrap_or("0").parse()?;
        let mut merges = Vec::with_capacity(n);
        let mut rank = HashMap::new();
        for (i, line) in lines.enumerate() {
            let mut it = line.split_whitespace();
            let l: u32 = it.next().context("merge line")?.parse()?;
            let r: u32 = it.next().context("merge line")?.parse()?;
            merges.push((l, r));
            rank.insert((l, r), 256 + i as u32);
        }
        if merges.len() != n {
            bail!("tokenizer truncated: header says {n}, found {}", merges.len());
        }
        Ok(Tokenizer { merges, rank })
    }
}

fn apply_merge(toks: &[u32], pair: (u32, u32), new_id: u32) -> Vec<u32> {
    let mut out = Vec::with_capacity(toks.len());
    let mut i = 0;
    while i < toks.len() {
        if i + 1 < toks.len() && toks[i] == pair.0 && toks[i + 1] == pair.1 {
            out.push(new_id);
            i += 2;
        } else {
            out.push(toks[i]);
            i += 1;
        }
    }
    out
}

/// GPT-2-style chunks: a word plus its leading whitespace.
fn split_chunks(text: &str) -> impl Iterator<Item = &str> {
    let bytes = text.as_bytes();
    let mut pos = 0;
    std::iter::from_fn(move || {
        if pos >= bytes.len() {
            return None;
        }
        let start = pos;
        // leading whitespace run
        while pos < bytes.len() && bytes[pos].is_ascii_whitespace() {
            pos += 1;
        }
        // word run
        while pos < bytes.len() && !bytes[pos].is_ascii_whitespace() {
            pos += 1;
        }
        Some(unsafe { std::str::from_utf8_unchecked(&bytes[start..pos]) })
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::corpus::{gen_corpus, CorpusStyle, Lexicon};

    fn sample_text() -> String {
        let lex = Lexicon::new(0);
        gen_corpus(&lex, CorpusStyle::C4, 0, 50_000)
    }

    #[test]
    fn roundtrip_lossless() {
        let text = sample_text();
        let tok = Tokenizer::train(&text[..30_000]);
        assert!(tok.vocab_size() > 300, "{}", tok.vocab_size());
        let enc = tok.encode(&text[..5_000]);
        assert_eq!(tok.decode(&enc), &text[..5_000]);
    }

    #[test]
    fn compresses_in_domain_text() {
        let text = sample_text();
        let tok = Tokenizer::train(&text[..30_000]);
        let enc = tok.encode(&text[30_000..40_000]);
        let ratio = 10_000.0 / enc.len() as f64;
        assert!(ratio > 2.0, "compression ratio {ratio}");
    }

    #[test]
    fn handles_unseen_bytes() {
        let tok = Tokenizer::train("aa bb aa bb");
        let enc = tok.encode("zq \u{00e9}!");
        assert_eq!(tok.decode(&enc), "zq \u{00e9}!");
    }

    #[test]
    fn save_load_identical() {
        let text = sample_text();
        let tok = Tokenizer::train(&text[..20_000]);
        let dir = std::env::temp_dir().join(format!("sgpt_tok_{}", std::process::id()));
        let path = dir.join("tok.txt");
        tok.save(&path).unwrap();
        let tok2 = Tokenizer::load(&path).unwrap();
        assert_eq!(tok.merges, tok2.merges);
        assert_eq!(tok.encode(&text[..2000]), tok2.encode(&text[..2000]));
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn all_ids_in_vocab_range() {
        let text = sample_text();
        let tok = Tokenizer::train(&text[..20_000]);
        for &t in &tok.encode(&text[..5000]) {
            assert!((t as usize) < VOCAB_SIZE);
        }
    }
}
