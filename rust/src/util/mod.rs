//! Small self-contained substrates: PRNG, JSON, timing.
//!
//! The build is fully offline (only the vendored `xla` + `anyhow` crates are
//! available), so the usual ecosystem crates (rand, serde, criterion) are
//! replaced by the minimal implementations in this module tree.

pub mod json;
pub mod mmap;
pub mod prng;
pub mod timer;
