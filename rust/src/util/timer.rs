//! Timing + summary statistics for the bench harness (criterion is not
//! available offline). Benches report min/median/mean over repeated runs
//! after a warmup, which is what the paper-style tables need.

use std::time::Instant;

pub struct Timer(Instant);

impl Timer {
    pub fn start() -> Self {
        Timer(Instant::now())
    }

    pub fn secs(&self) -> f64 {
        self.0.elapsed().as_secs_f64()
    }

    pub fn ms(&self) -> f64 {
        self.secs() * 1e3
    }
}

#[derive(Clone, Debug)]
pub struct Stats {
    pub n: usize,
    pub min: f64,
    pub max: f64,
    pub mean: f64,
    pub median: f64,
    pub std: f64,
}

impl Stats {
    pub fn from(mut xs: Vec<f64>) -> Stats {
        assert!(!xs.is_empty());
        xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let n = xs.len();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        let median = if n % 2 == 1 {
            xs[n / 2]
        } else {
            0.5 * (xs[n / 2 - 1] + xs[n / 2])
        };
        Stats { n, min: xs[0], max: xs[n - 1], mean, median, std: var.sqrt() }
    }
}

/// Time `f` `iters` times after `warmup` runs; returns per-run seconds.
pub fn bench_fn<F: FnMut()>(warmup: usize, iters: usize, mut f: F) -> Stats {
    for _ in 0..warmup {
        f();
    }
    let mut times = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t = Timer::start();
        f();
        times.push(t.secs());
    }
    Stats::from(times)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_basic() {
        let s = Stats::from(vec![3.0, 1.0, 2.0]);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 3.0);
        assert_eq!(s.median, 2.0);
        assert!((s.mean - 2.0).abs() < 1e-12);
    }

    #[test]
    fn bench_runs() {
        let mut count = 0;
        let s = bench_fn(2, 5, || count += 1);
        assert_eq!(count, 7);
        assert_eq!(s.n, 5);
    }
}
