//! Timing + summary statistics for the bench harness (criterion is not
//! available offline). Benches report min/median/mean over repeated runs
//! after a warmup, which is what the paper-style tables need.
//!
//! `Timer` reads a [`Clock`](crate::obs::Clock) rather than raw
//! `Instant::now()`, so timing-bearing output can be made deterministic
//! under the mock clock ([`Timer::with_clock`]); the plain
//! [`Timer::start`] keeps real-time behavior.

use crate::obs::Clock;

pub struct Timer {
    clock: Clock,
    start_ns: u64,
}

impl Timer {
    /// A real-time timer (the bench default).
    pub fn start() -> Self {
        Timer::with_clock(Clock::real())
    }

    /// A timer on an explicit clock — pass `Clock::mock(tick)` to make
    /// readings a pure function of how often the clock is consulted.
    pub fn with_clock(clock: Clock) -> Self {
        let start_ns = clock.now_ns();
        Timer { clock, start_ns }
    }

    pub fn secs(&self) -> f64 {
        self.clock.secs_since(self.start_ns)
    }

    pub fn ms(&self) -> f64 {
        self.secs() * 1e3
    }
}

#[derive(Clone, Debug)]
pub struct Stats {
    pub n: usize,
    pub min: f64,
    pub max: f64,
    pub mean: f64,
    pub median: f64,
    pub std: f64,
}

impl Stats {
    pub fn from(mut xs: Vec<f64>) -> Stats {
        assert!(!xs.is_empty());
        xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let n = xs.len();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        let median = if n % 2 == 1 {
            xs[n / 2]
        } else {
            0.5 * (xs[n / 2 - 1] + xs[n / 2])
        };
        Stats { n, min: xs[0], max: xs[n - 1], mean, median, std: var.sqrt() }
    }
}

/// Time `f` `iters` times after `warmup` runs; returns per-run seconds.
pub fn bench_fn<F: FnMut()>(warmup: usize, iters: usize, mut f: F) -> Stats {
    for _ in 0..warmup {
        f();
    }
    let mut times = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t = Timer::start();
        f();
        times.push(t.secs());
    }
    Stats::from(times)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_basic() {
        let s = Stats::from(vec![3.0, 1.0, 2.0]);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 3.0);
        assert_eq!(s.median, 2.0);
        assert!((s.mean - 2.0).abs() < 1e-12);
    }

    #[test]
    fn bench_runs() {
        let mut count = 0;
        let s = bench_fn(2, 5, || count += 1);
        assert_eq!(count, 7);
        assert_eq!(s.n, 5);
    }

    #[test]
    fn mock_clock_timer_is_deterministic() {
        let t = Timer::with_clock(Clock::mock(1_000_000)); // 1ms tick
        assert_eq!(t.secs(), 1e-3); // exactly one read after start
        assert_eq!(t.ms(), 2.0);
    }
}
