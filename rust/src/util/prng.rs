//! Deterministic PRNG: xoshiro256++ seeded via SplitMix64.
//!
//! Every stochastic component of the pipeline (corpus generation, parameter
//! init, calibration sampling, benchmark workloads) takes an explicit seed so
//! experiments are reproducible bit-for-bit; the App-A "sensitivity to random
//! seeds" ablation just varies this seed.

#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
    /// cached second normal deviate from Box-Muller
    spare: Option<f64>,
}

fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        let mut st = seed;
        let s = [
            splitmix64(&mut st),
            splitmix64(&mut st),
            splitmix64(&mut st),
            splitmix64(&mut st),
        ];
        Rng { s, spare: None }
    }

    /// Derive an independent stream (e.g. per layer / per worker).
    pub fn fork(&mut self, tag: u64) -> Rng {
        Rng::new(self.next_u64() ^ tag.wrapping_mul(0x9E37_79B9_7F4A_7C15))
    }

    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[0]
            .wrapping_add(s[3])
            .rotate_left(23)
            .wrapping_add(s[0]);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    pub fn f32(&mut self) -> f32 {
        self.f64() as f32
    }

    /// Uniform integer in [0, n).
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        // multiply-shift; bias negligible for our n << 2^64
        ((self.next_u64() as u128 * n as u128) >> 64) as usize
    }

    /// Standard normal via Box-Muller.
    pub fn normal(&mut self) -> f64 {
        if let Some(v) = self.spare.take() {
            return v;
        }
        let (u1, u2) = (self.f64().max(1e-300), self.f64());
        let r = (-2.0 * u1.ln()).sqrt();
        let (s, c) = (2.0 * std::f64::consts::PI * u2).sin_cos();
        self.spare = Some(r * s);
        r * c
    }

    pub fn normal_f32(&mut self) -> f32 {
        self.normal() as f32
    }

    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            xs.swap(i, self.below(i + 1));
        }
    }

    pub fn choice<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.below(xs.len())]
    }

    /// Sample an index from unnormalized non-negative weights.
    pub fn weighted(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        let mut x = self.f64() * total;
        for (i, w) in weights.iter().enumerate() {
            x -= w;
            if x <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let (mut a, mut b) = (Rng::new(1), Rng::new(2));
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn uniform_range_and_mean() {
        let mut r = Rng::new(7);
        let n = 20_000;
        let mean: f64 = (0..n).map(|_| r.f64()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.02, "mean {mean}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(9);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.03, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn below_bounds() {
        let mut r = Rng::new(3);
        for _ in 0..10_000 {
            assert!(r.below(17) < 17);
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(5);
        let mut v: Vec<usize> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(v, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn weighted_prefers_heavy() {
        let mut r = Rng::new(11);
        let w = [1.0, 0.0, 9.0];
        let mut counts = [0usize; 3];
        for _ in 0..10_000 {
            counts[r.weighted(&w)] += 1;
        }
        assert_eq!(counts[1], 0);
        assert!(counts[2] > 8 * counts[0] / 2);
    }
}
