//! Minimal JSON parser + writer (serde is not available offline).
//!
//! Parses the artifact manifest emitted by `python/compile/aot.py` and
//! serializes experiment reports. Supports the full JSON grammar except
//! `\u` surrogate pairs beyond the BMP (not needed for our data).

use std::collections::BTreeMap;
use std::fmt::Write as _;

use anyhow::{anyhow, bail, Result};

#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(text: &str) -> Result<Json> {
        let mut p = Parser { b: text.as_bytes(), i: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.b.len() {
            bail!("trailing data at byte {}", p.i);
        }
        Ok(v)
    }

    // ---- typed accessors -------------------------------------------------
    pub fn get(&self, key: &str) -> Result<&Json> {
        match self {
            Json::Obj(m) => m.get(key).ok_or_else(|| anyhow!("missing key {key:?}")),
            _ => bail!("not an object (looking up {key:?})"),
        }
    }

    pub fn opt(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Result<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Ok(m),
            _ => bail!("not an object"),
        }
    }

    pub fn as_arr(&self) -> Result<&[Json]> {
        match self {
            Json::Arr(v) => Ok(v),
            _ => bail!("not an array"),
        }
    }

    pub fn as_str(&self) -> Result<&str> {
        match self {
            Json::Str(s) => Ok(s),
            _ => bail!("not a string"),
        }
    }

    pub fn as_f64(&self) -> Result<f64> {
        match self {
            Json::Num(n) => Ok(*n),
            _ => bail!("not a number"),
        }
    }

    pub fn as_usize(&self) -> Result<usize> {
        let n = self.as_f64()?;
        if n < 0.0 || n.fract() != 0.0 {
            bail!("not a non-negative integer: {n}");
        }
        Ok(n as usize)
    }

    // ---- writer ------------------------------------------------------------
    pub fn to_string_pretty(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, 0);
        s
    }

    /// Serialize on a single line with no whitespace (JSON-lines friendly).
    /// Non-finite numbers (which JSON cannot represent) serialize as `null`.
    pub fn to_string_compact(&self) -> String {
        let mut s = String::new();
        self.write_compact(&mut s);
        s
    }

    fn write_compact(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if !n.is_finite() {
                    out.push_str("null");
                } else if n.fract() == 0.0 && n.abs() < 1e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(v) => {
                out.push('[');
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    x.write_compact(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, x)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(out, k);
                    out.push(':');
                    x.write_compact(out);
                }
                out.push('}');
            }
        }
    }

    fn write(&self, out: &mut String, indent: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(v) => {
                if v.is_empty() {
                    out.push_str("[]");
                    return;
                }
                out.push('[');
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    out.push('\n');
                    out.push_str(&" ".repeat(indent + 1));
                    x.write(out, indent + 1);
                }
                out.push('\n');
                out.push_str(&" ".repeat(indent));
                out.push(']');
            }
            Json::Obj(m) => {
                if m.is_empty() {
                    out.push_str("{}");
                    return;
                }
                out.push('{');
                for (i, (k, x)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    out.push('\n');
                    out.push_str(&" ".repeat(indent + 1));
                    write_escaped(out, k);
                    out.push_str(": ");
                    x.write(out, indent + 1);
                }
                out.push('\n');
                out.push_str(&" ".repeat(indent));
                out.push('}');
            }
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Result<u8> {
        self.b
            .get(self.i)
            .copied()
            .ok_or_else(|| anyhow!("unexpected end of input"))
    }

    fn expect(&mut self, c: u8) -> Result<()> {
        if self.peek()? != c {
            bail!("expected {:?} at byte {}", c as char, self.i);
        }
        self.i += 1;
        Ok(())
    }

    fn value(&mut self) -> Result<Json> {
        match self.peek()? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Json::Str(self.string()?)),
            b't' => self.lit("true", Json::Bool(true)),
            b'f' => self.lit("false", Json::Bool(false)),
            b'n' => self.lit("null", Json::Null),
            _ => self.number(),
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            bail!("invalid literal at byte {}", self.i)
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.i;
        while self.i < self.b.len()
            && matches!(self.b[self.i], b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E')
        {
            self.i += 1;
        }
        let s = std::str::from_utf8(&self.b[start..self.i])?;
        Ok(Json::Num(s.parse::<f64>().map_err(|e| anyhow!("bad number {s:?}: {e}"))?))
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            let c = self.peek()?;
            self.i += 1;
            match c {
                b'"' => return Ok(out),
                b'\\' => {
                    let e = self.peek()?;
                    self.i += 1;
                    match e {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'n' => out.push('\n'),
                        b't' => out.push('\t'),
                        b'r' => out.push('\r'),
                        b'b' => out.push('\u{8}'),
                        b'f' => out.push('\u{c}'),
                        b'u' => {
                            if self.i + 4 > self.b.len() {
                                bail!("truncated \\u escape");
                            }
                            let hex = std::str::from_utf8(&self.b[self.i..self.i + 4])?;
                            let code = u32::from_str_radix(hex, 16)?;
                            self.i += 4;
                            out.push(
                                char::from_u32(code)
                                    .ok_or_else(|| anyhow!("bad \\u escape {hex}"))?,
                            );
                        }
                        _ => bail!("bad escape at byte {}", self.i),
                    }
                }
                c => {
                    // re-decode UTF-8 multibyte sequences
                    if c < 0x80 {
                        out.push(c as char);
                    } else {
                        let start = self.i - 1;
                        let len = utf8_len(c);
                        let s = std::str::from_utf8(&self.b[start..start + len])?;
                        out.push_str(s);
                        self.i = start + len;
                    }
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.expect(b'[')?;
        let mut v = Vec::new();
        self.skip_ws();
        if self.peek()? == b']' {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.skip_ws();
            v.push(self.value()?);
            self.skip_ws();
            match self.peek()? {
                b',' => self.i += 1,
                b']' => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                c => bail!("expected , or ] got {:?} at byte {}", c as char, self.i),
            }
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek()? == b'}' {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            m.insert(k, self.value()?);
            self.skip_ws();
            match self.peek()? {
                b',' => self.i += 1,
                b'}' => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                c => bail!("expected , or }} got {:?} at byte {}", c as char, self.i),
            }
        }
    }
}

fn utf8_len(first: u8) -> usize {
    match first {
        0xC0..=0xDF => 2,
        0xE0..=0xEF => 3,
        _ => 4,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_basic() {
        let src = r#"{"a": [1, 2.5, -3e2], "b": {"c": null, "d": true}, "s": "x\n\"y\""}"#;
        let v = Json::parse(src).unwrap();
        let v2 = Json::parse(&v.to_string_pretty()).unwrap();
        assert_eq!(v, v2);
    }

    #[test]
    fn accessors() {
        let v = Json::parse(r#"{"n": 3, "arr": ["a"], "s": "hi"}"#).unwrap();
        assert_eq!(v.get("n").unwrap().as_usize().unwrap(), 3);
        assert_eq!(v.get("arr").unwrap().as_arr().unwrap().len(), 1);
        assert_eq!(v.get("s").unwrap().as_str().unwrap(), "hi");
        assert!(v.get("missing").is_err());
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("{} extra").is_err());
        assert!(Json::parse("nul").is_err());
    }

    #[test]
    fn compact_is_single_line_and_reparses() {
        let src = r#"{"a": [1, 2.5, -3e2], "b": {"c": null, "d": true}, "s": "x\n"}"#;
        let v = Json::parse(src).unwrap();
        let c = v.to_string_compact();
        assert!(!c.contains('\n') && !c.contains(": "), "{c}");
        assert_eq!(Json::parse(&c).unwrap(), v);
        assert_eq!(Json::Num(f64::INFINITY).to_string_compact(), "null");
        assert_eq!(Json::Num(0.5).to_string_compact(), "0.5");
        assert_eq!(Json::Num(3.0).to_string_compact(), "3");
    }

    #[test]
    fn unicode_and_escapes() {
        let v = Json::parse(r#""café ☕""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "café ☕");
    }

    #[test]
    fn parses_real_manifest_if_present() {
        let p = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts/manifest.json");
        if let Ok(text) = std::fs::read_to_string(p) {
            let v = Json::parse(&text).unwrap();
            assert!(v.get("artifacts").unwrap().as_obj().unwrap().len() > 50);
        }
    }
}
