//! Read-only memory-mapped file regions without `libc`.
//!
//! [`MmapRegion`] maps a whole file `PROT_READ`/`MAP_PRIVATE` through a thin
//! raw-syscall shim (x86_64 and aarch64 Linux), so `.spkt` weight sections can
//! be served straight from page cache instead of being copied into owned
//! buffers. Everywhere else — other targets, empty files, or a failed `mmap` —
//! it falls back to reading the file into an **8-byte-aligned owned buffer**,
//! so downstream alignment reasoning is identical on both paths:
//!
//! * the region base is always at least 8-aligned (page-aligned when mapped,
//!   `Vec<u64>`-backed when owned), and
//! * a section offset that is `align_of::<T>()`-aligned therefore yields a
//!   `T`-aligned pointer for every `T` with alignment ≤ 8.
//!
//! Tests exercise the owned path via [`MmapRegion::from_bytes`]; both paths
//! hand out bytes through the same [`ByteSource`] trait, so nothing downstream
//! can tell them apart. The safety contract for handing these bytes to
//! kernels lives in DESIGN.md ("Zero-copy mmap serving").

use std::path::Path;

use anyhow::{Context, Result};

/// Uniform byte access over mapped and owned regions. The one seam the
/// zero-copy loaders go through, so unit tests can run on owned buffers
/// while production serves from mapped pages.
pub trait ByteSource {
    fn bytes(&self) -> &[u8];
}

/// An immutable byte region backing one `.spkt` file: either live mapped
/// pages (unmapped on drop) or an owned 8-aligned copy.
pub struct MmapRegion {
    inner: Inner,
}

enum Inner {
    #[cfg(all(target_os = "linux", any(target_arch = "x86_64", target_arch = "aarch64")))]
    Mapped { ptr: *const u8, len: usize },
    /// `Vec<u64>` storage guarantees an 8-aligned base; `len` is the byte
    /// count actually used (the final word may be padding).
    Owned { words: Vec<u64>, len: usize },
}

// SAFETY: the mapped pages are PROT_READ and private; nothing ever writes
// through `ptr`, so sharing the region across threads is sound. The owned
// variant is a plain Vec.
unsafe impl Send for MmapRegion {}
unsafe impl Sync for MmapRegion {}

impl MmapRegion {
    /// Map `path` read-only; fall back to an owned aligned copy when mapping
    /// is unavailable (non-Linux target, empty file, or `mmap` failure).
    pub fn load(path: &Path) -> Result<Self> {
        #[cfg(all(target_os = "linux", any(target_arch = "x86_64", target_arch = "aarch64")))]
        {
            if let Some(r) = Self::try_map(path) {
                return Ok(r);
            }
        }
        let data =
            std::fs::read(path).with_context(|| format!("reading {}", path.display()))?;
        Ok(Self::from_bytes(&data))
    }

    /// Owned 8-aligned copy of `data` — the test-path constructor and the
    /// universal fallback.
    pub fn from_bytes(data: &[u8]) -> Self {
        let len = data.len();
        let mut words = vec![0u64; len.div_ceil(8)];
        // SAFETY: the word buffer spans at least `len` bytes and the ranges
        // cannot overlap (freshly allocated destination).
        unsafe {
            std::ptr::copy_nonoverlapping(data.as_ptr(), words.as_mut_ptr() as *mut u8, len);
        }
        MmapRegion { inner: Inner::Owned { words, len } }
    }

    /// True when the bytes are served from mapped pages rather than an
    /// owned copy.
    pub fn is_mapped(&self) -> bool {
        match &self.inner {
            #[cfg(all(
                target_os = "linux",
                any(target_arch = "x86_64", target_arch = "aarch64")
            ))]
            Inner::Mapped { .. } => true,
            Inner::Owned { .. } => false,
        }
    }

    pub fn len(&self) -> usize {
        match &self.inner {
            #[cfg(all(
                target_os = "linux",
                any(target_arch = "x86_64", target_arch = "aarch64")
            ))]
            Inner::Mapped { len, .. } => *len,
            Inner::Owned { len, .. } => *len,
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    #[cfg(all(target_os = "linux", any(target_arch = "x86_64", target_arch = "aarch64")))]
    fn try_map(path: &Path) -> Option<Self> {
        use std::os::unix::io::AsRawFd;
        let file = std::fs::File::open(path).ok()?;
        let len = file.metadata().ok()?.len();
        if len == 0 || len > usize::MAX as u64 {
            return None; // mmap(len=0) is EINVAL; empty stores use the owned path
        }
        let len = len as usize;
        let fd = file.as_raw_fd();
        // mmap(NULL, len, PROT_READ, MAP_PRIVATE, fd, 0); the mapping
        // outlives `file` — closing the descriptor does not unmap.
        let ret = unsafe {
            sys::syscall6(sys::SYS_MMAP, 0, len, sys::PROT_READ, sys::MAP_PRIVATE, fd as usize, 0)
        };
        if (-4095..0).contains(&ret) {
            return None;
        }
        Some(MmapRegion { inner: Inner::Mapped { ptr: ret as usize as *const u8, len } })
    }
}

impl ByteSource for MmapRegion {
    fn bytes(&self) -> &[u8] {
        match &self.inner {
            #[cfg(all(
                target_os = "linux",
                any(target_arch = "x86_64", target_arch = "aarch64")
            ))]
            // SAFETY: `ptr` spans `len` readable bytes for the life of the
            // mapping, which is the life of `self`.
            Inner::Mapped { ptr, len } => unsafe { std::slice::from_raw_parts(*ptr, *len) },
            Inner::Owned { words, len } => {
                // SAFETY: the word buffer spans at least `len` bytes.
                unsafe { std::slice::from_raw_parts(words.as_ptr() as *const u8, *len) }
            }
        }
    }
}

impl Drop for MmapRegion {
    fn drop(&mut self) {
        #[cfg(all(target_os = "linux", any(target_arch = "x86_64", target_arch = "aarch64")))]
        if let Inner::Mapped { ptr, len } = self.inner {
            // SAFETY: exactly the range returned by mmap, unmapped once.
            unsafe {
                sys::syscall6(sys::SYS_MUNMAP, ptr as usize, len, 0, 0, 0, 0);
            }
        }
    }
}

impl std::fmt::Debug for MmapRegion {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("MmapRegion")
            .field("len", &self.len())
            .field("mapped", &self.is_mapped())
            .finish()
    }
}

/// Raw Linux syscall shim — the repo builds fully offline with no `libc`
/// crate, so `mmap`/`munmap` go straight through the syscall instruction.
#[cfg(all(target_os = "linux", target_arch = "x86_64"))]
mod sys {
    pub const SYS_MMAP: usize = 9;
    pub const SYS_MUNMAP: usize = 11;
    pub const PROT_READ: usize = 1;
    pub const MAP_PRIVATE: usize = 2;

    /// # Safety
    /// Caller must uphold the contract of the invoked syscall.
    pub unsafe fn syscall6(
        nr: usize,
        a1: usize,
        a2: usize,
        a3: usize,
        a4: usize,
        a5: usize,
        a6: usize,
    ) -> isize {
        let ret: isize;
        std::arch::asm!(
            "syscall",
            inlateout("rax") nr as isize => ret,
            in("rdi") a1,
            in("rsi") a2,
            in("rdx") a3,
            in("r10") a4,
            in("r8") a5,
            in("r9") a6,
            lateout("rcx") _,
            lateout("r11") _,
            options(nostack),
        );
        ret
    }
}

#[cfg(all(target_os = "linux", target_arch = "aarch64"))]
mod sys {
    pub const SYS_MMAP: usize = 222;
    pub const SYS_MUNMAP: usize = 215;
    pub const PROT_READ: usize = 1;
    pub const MAP_PRIVATE: usize = 2;

    /// # Safety
    /// Caller must uphold the contract of the invoked syscall.
    pub unsafe fn syscall6(
        nr: usize,
        a1: usize,
        a2: usize,
        a3: usize,
        a4: usize,
        a5: usize,
        a6: usize,
    ) -> isize {
        let ret: isize;
        std::arch::asm!(
            "svc 0",
            in("x8") nr,
            inlateout("x0") a1 as isize => ret,
            in("x1") a2,
            in("x2") a3,
            in("x3") a4,
            in("x4") a5,
            in("x5") a6,
            options(nostack),
        );
        ret
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn owned_region_is_eight_aligned_and_exact() {
        let data: Vec<u8> = (0..23u8).collect();
        let r = MmapRegion::from_bytes(&data);
        assert_eq!(r.bytes(), &data[..]);
        assert_eq!(r.len(), 23);
        assert!(!r.is_mapped());
        assert_eq!(r.bytes().as_ptr() as usize % 8, 0);
    }

    #[test]
    fn empty_region_is_fine() {
        let r = MmapRegion::from_bytes(&[]);
        assert!(r.is_empty());
        assert_eq!(r.bytes(), &[] as &[u8]);
    }

    #[test]
    fn load_round_trips_a_real_file() {
        let dir = std::env::temp_dir().join(format!("mmap-test-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("region.bin");
        let data: Vec<u8> = (0..4097).map(|i| (i % 251) as u8).collect();
        std::fs::write(&path, &data).unwrap();
        let r = MmapRegion::load(&path).unwrap();
        assert_eq!(r.len(), data.len());
        assert_eq!(r.bytes(), &data[..]);
        assert_eq!(r.bytes().as_ptr() as usize % 8, 0, "base must be 8-aligned");
        #[cfg(all(target_os = "linux", any(target_arch = "x86_64", target_arch = "aarch64")))]
        assert!(r.is_mapped(), "linux path should map, not copy");
        drop(r); // munmap must not fault
        let _ = std::fs::remove_file(&path);
        let _ = std::fs::remove_dir(&dir);
    }

    #[test]
    fn load_missing_file_errors() {
        assert!(MmapRegion::load(Path::new("/no/such/file.spkt")).is_err());
    }
}
