//! Minimal argument parser for the launcher (clap is unavailable offline).
//! Supports `--flag value`, `--flag=value` and boolean `--flag`; duplicate
//! occurrences of a flag are rejected rather than silently last-wins.

use std::collections::BTreeMap;

use anyhow::{anyhow, bail, Result};

/// Boolean flags accepted by every `sparsegpt` subcommand. `--json`
/// switches the event stream from human log lines to JSON lines.
pub const GLOBAL_BOOL_FLAGS: &[&str] = &[
    "resume",
    "record-errors",
    "rt-stats",
    "json",
    "no-dense",
    "save",
    "pack",
    "shutdown",
    "shutdown-only",
    "stats",
    "stats-only",
];

#[derive(Clone, Debug, Default)]
pub struct Args {
    pub positional: Vec<String>,
    pub flags: BTreeMap<String, String>,
    pub bools: Vec<String>,
}

impl Args {
    pub fn parse(argv: &[String], bool_flags: &[&str]) -> Result<Args> {
        let mut out = Args::default();
        let mut i = 0;
        while i < argv.len() {
            let a = &argv[i];
            if let Some(name) = a.strip_prefix("--") {
                if let Some((k, v)) = name.split_once('=') {
                    if bool_flags.contains(&k) {
                        bail!("--{k} is a boolean flag and takes no value (got {v:?})");
                    }
                    if out.flags.insert(k.to_string(), v.to_string()).is_some() {
                        bail!("duplicate --{k} (each flag may be given once)");
                    }
                } else if bool_flags.contains(&name) {
                    if out.bools.iter().any(|b| b == name) {
                        bail!("duplicate --{name} (each flag may be given once)");
                    }
                    out.bools.push(name.to_string());
                } else {
                    let v = argv
                        .get(i + 1)
                        .ok_or_else(|| anyhow!("--{name} needs a value"))?;
                    if v.starts_with("--") {
                        bail!("--{name} needs a value (got {v})");
                    }
                    if out.flags.insert(name.to_string(), v.clone()).is_some() {
                        bail!("duplicate --{name} (each flag may be given once)");
                    }
                    i += 1;
                }
            } else {
                out.positional.push(a.clone());
            }
            i += 1;
        }
        Ok(out)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.flags.get(name).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }

    pub fn required(&self, name: &str) -> Result<&str> {
        self.get(name).ok_or_else(|| anyhow!("missing required --{name}"))
    }

    pub fn usize_or(&self, name: &str, default: usize) -> Result<usize> {
        match self.get(name) {
            Some(v) => v.parse().map_err(|e| anyhow!("--{name}: {e}")),
            None => Ok(default),
        }
    }

    pub fn f64_or(&self, name: &str, default: f64) -> Result<f64> {
        match self.get(name) {
            Some(v) => v.parse().map_err(|e| anyhow!("--{name}: {e}")),
            None => Ok(default),
        }
    }

    pub fn u64_or(&self, name: &str, default: u64) -> Result<u64> {
        match self.get(name) {
            Some(v) => v.parse().map_err(|e| anyhow!("--{name}: {e}")),
            None => Ok(default),
        }
    }

    pub fn has(&self, name: &str) -> bool {
        self.bools.iter().any(|b| b == name)
    }
}

/// Parse "2:4" into (2, 4).
pub fn parse_nm(s: &str) -> Result<(usize, usize)> {
    let (n, m) = s.split_once(':').ok_or_else(|| anyhow!("expected n:m, got {s}"))?;
    let (n, m): (usize, usize) = (n.parse()?, m.parse()?);
    if n == 0 || m == 0 || n >= m {
        bail!("invalid n:m pattern {s}");
    }
    Ok((n, m))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn v(xs: &[&str]) -> Vec<String> {
        xs.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn parses_flags_and_positionals() {
        let a = Args::parse(&v(&["prune", "--config", "nano", "--force", "--damp=0.1"]), &["force"]).unwrap();
        assert_eq!(a.positional, vec!["prune"]);
        assert_eq!(a.get("config"), Some("nano"));
        assert_eq!(a.f64_or("damp", 0.0).unwrap(), 0.1);
        assert!(a.has("force"));
        assert!(!a.has("other"));
    }

    #[test]
    fn missing_value_errors() {
        assert!(Args::parse(&v(&["--config"]), &[]).is_err());
        assert!(Args::parse(&v(&["--config", "--x"]), &[]).is_err());
    }

    #[test]
    fn duplicate_flags_rejected() {
        let e = Args::parse(&v(&["--config", "nano", "--config", "small"]), &[]).unwrap_err();
        assert!(format!("{e}").contains("duplicate --config"), "{e}");
        // =-form and space-form count as the same flag
        assert!(Args::parse(&v(&["--damp=0.1", "--damp", "0.2"]), &[]).is_err());
        // duplicate booleans are rejected too
        assert!(Args::parse(&v(&["--json", "--json"]), &["json"]).is_err());
        // distinct flags are of course fine
        let a = Args::parse(&v(&["--config", "nano", "--damp", "0.1"]), &[]).unwrap();
        assert_eq!(a.get("config"), Some("nano"));
    }

    #[test]
    fn global_bool_flags_include_json() {
        assert!(GLOBAL_BOOL_FLAGS.contains(&"json"));
        let a = Args::parse(&v(&["prune", "--json"]), GLOBAL_BOOL_FLAGS).unwrap();
        assert!(a.has("json"));
    }

    #[test]
    fn bool_flag_with_value_rejected() {
        // --json=true must not silently land in the value-flag map
        let e = Args::parse(&v(&["--json=true"]), &["json"]).unwrap_err();
        assert!(format!("{e}").contains("boolean flag"), "{e}");
        assert!(Args::parse(&v(&["--json=1", "--json"]), &["json"]).is_err());
    }

    #[test]
    fn nm_parsing() {
        assert_eq!(parse_nm("2:4").unwrap(), (2, 4));
        assert_eq!(parse_nm("4:8").unwrap(), (4, 8));
        assert!(parse_nm("4:2").is_err());
        assert!(parse_nm("24").is_err());
    }
}
