//! Per-request KV cache for incremental decode: one ring buffer of key and
//! value rows per transformer layer, capacity-bounded to the model's
//! attention window (`cfg.seq`) so sliding-window eviction is just slot
//! reuse.
//!
//! Position discipline: the token at absolute position `p` always lives in
//! slot `p % capacity`, and (because the capacity equals the positional
//! embedding table length) also always carries `pos_embed[p % seq]` — so a
//! cached key/value row stays valid forever and eviction exactly drops the
//! positions that leave the attention window. Writes happen per layer while
//! a token (or prefill chunk row) is being processed; [`KvCache::commit`]
//! then advances the logical clock once per token batch and reports how
//! many live entries were overwritten (the `cache-evicted` event feed).

/// Ring-buffered K/V rows for every layer of one request.
#[derive(Clone, Debug)]
pub struct KvCache {
    layers: usize,
    d: usize,
    cap: usize,
    /// resident entries (<= cap)
    len: usize,
    /// absolute position of the next token to be written
    next_pos: usize,
    /// layers * cap * d, layer-major, slot = pos % cap
    k: Vec<f32>,
    v: Vec<f32>,
}

impl KvCache {
    pub fn new(layers: usize, d: usize, cap: usize) -> KvCache {
        assert!(layers > 0 && d > 0 && cap > 0, "KvCache dims must be positive");
        KvCache {
            layers,
            d,
            cap,
            len: 0,
            next_pos: 0,
            k: vec![0.0; layers * cap * d],
            v: vec![0.0; layers * cap * d],
        }
    }

    /// Heap bytes a cache of these dimensions pins (the scheduler's
    /// cache-memory budget unit): K + V, f32, all layers.
    pub fn bytes_for(layers: usize, d: usize, cap: usize) -> u64 {
        (layers * cap * d * 2 * std::mem::size_of::<f32>()) as u64
    }

    pub fn bytes(&self) -> u64 {
        KvCache::bytes_for(self.layers, self.d, self.cap)
    }

    /// Resident entries (min(tokens committed, capacity)).
    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    pub fn capacity(&self) -> usize {
        self.cap
    }

    /// Absolute position the next appended token will occupy (= tokens
    /// committed so far).
    pub fn next_pos(&self) -> usize {
        self.next_pos
    }

    /// Oldest resident absolute position.
    pub fn first_pos(&self) -> usize {
        self.next_pos - self.len
    }

    /// Attention window for a query at absolute position `p`: positions
    /// `start..=p`, exactly the band the uncached re-forward uses.
    pub fn window_start(&self, p: usize) -> usize {
        p.saturating_sub(self.cap.saturating_sub(1))
    }

    fn idx(&self, layer: usize, pos: usize) -> usize {
        debug_assert!(layer < self.layers);
        (layer * self.cap + pos % self.cap) * self.d
    }

    /// Store the key/value rows of the token at absolute position `pos` for
    /// one layer. Callers write every layer of a token before [`commit`]ing
    /// it; interleaving writes with reads of *earlier* positions is safe
    /// because a write only reuses the slot of the position that just left
    /// the attention window.
    ///
    /// [`commit`]: KvCache::commit
    pub fn write(&mut self, layer: usize, pos: usize, k_row: &[f32], v_row: &[f32]) {
        debug_assert_eq!(k_row.len(), self.d);
        debug_assert_eq!(v_row.len(), self.d);
        let i = self.idx(layer, pos);
        self.k[i..i + self.d].copy_from_slice(k_row);
        self.v[i..i + self.d].copy_from_slice(v_row);
    }

    pub fn k_row(&self, layer: usize, pos: usize) -> &[f32] {
        let i = self.idx(layer, pos);
        &self.k[i..i + self.d]
    }

    pub fn v_row(&self, layer: usize, pos: usize) -> &[f32] {
        let i = self.idx(layer, pos);
        &self.v[i..i + self.d]
    }

    /// Advance the logical clock by `n` freshly written tokens; returns how
    /// many previously resident entries their slots evicted.
    pub fn commit(&mut self, n: usize) -> usize {
        let grown = (self.cap - self.len).min(n);
        self.len += grown;
        self.next_pos += n;
        n - grown
    }
}

/// Shared cache-memory accounting: the engine reserves a request's cache
/// bytes at admission and releases them at retirement, and the scheduler
/// reads the headroom to apply backpressure. `total == 0` means unlimited.
#[derive(Clone, Debug, Default)]
pub struct CacheBudget {
    total: u64,
    in_use: u64,
}

impl CacheBudget {
    pub fn new(total_bytes: u64) -> CacheBudget {
        CacheBudget { total: total_bytes, in_use: 0 }
    }

    pub fn total(&self) -> u64 {
        self.total
    }

    pub fn in_use(&self) -> u64 {
        self.in_use
    }

    /// How many `unit`-byte caches still fit; `None` when unlimited.
    pub fn free_slots(&self, unit: u64) -> Option<usize> {
        if self.total == 0 || unit == 0 {
            return None;
        }
        Some((self.total.saturating_sub(self.in_use) / unit) as usize)
    }

    pub fn reserve(&mut self, bytes: u64) {
        self.in_use += bytes;
    }

    pub fn release(&mut self, bytes: u64) {
        debug_assert!(bytes <= self.in_use, "releasing more cache bytes than reserved");
        self.in_use = self.in_use.saturating_sub(bytes);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ring_slots_and_clock() {
        let mut c = KvCache::new(2, 3, 4);
        assert_eq!(c.len(), 0);
        assert_eq!(c.next_pos(), 0);
        for pos in 0..6usize {
            let row: Vec<f32> = (0..3).map(|j| (pos * 10 + j) as f32).collect();
            for layer in 0..2 {
                c.write(layer, pos, &row, &row);
            }
            let evicted = c.commit(1);
            assert_eq!(evicted, usize::from(pos >= 4), "pos {pos}");
        }
        assert_eq!(c.len(), 4);
        assert_eq!(c.next_pos(), 6);
        assert_eq!(c.first_pos(), 2);
        // surviving positions 2..=5 read back exactly, on every layer
        for pos in 2..6 {
            for layer in 0..2 {
                assert_eq!(c.k_row(layer, pos)[0], (pos * 10) as f32);
                assert_eq!(c.v_row(layer, pos)[2], (pos * 10 + 2) as f32);
            }
        }
    }

    #[test]
    fn window_matches_band() {
        let c = KvCache::new(1, 1, 4);
        assert_eq!(c.window_start(0), 0);
        assert_eq!(c.window_start(3), 0);
        assert_eq!(c.window_start(4), 1);
        assert_eq!(c.window_start(9), 6);
    }

    #[test]
    fn commit_counts_multi_token_evictions() {
        let mut c = KvCache::new(1, 1, 4);
        assert_eq!(c.commit(3), 0); // 0..3 resident
        assert_eq!(c.commit(3), 2); // 3..6: positions 0,1 evicted
        assert_eq!(c.len(), 4);
        assert_eq!(c.commit(7), 7); // cache already full: all reuse
        assert_eq!(c.next_pos(), 13);
    }

    #[test]
    fn bytes_and_budget() {
        assert_eq!(KvCache::bytes_for(2, 3, 4), (2 * 3 * 4 * 2 * 4) as u64);
        let mut b = CacheBudget::new(100);
        assert_eq!(b.free_slots(40), Some(2));
        b.reserve(40);
        assert_eq!(b.in_use(), 40);
        assert_eq!(b.free_slots(40), Some(1));
        b.reserve(40);
        assert_eq!(b.free_slots(40), Some(0));
        b.release(40);
        b.release(40);
        assert_eq!(b.in_use(), 0);
        assert_eq!(CacheBudget::new(0).free_slots(40), None, "0 = unlimited");
    }

    #[test]
    fn zero_unit_budget_never_divides() {
        // a zero-byte cache unit (degenerate model) must not panic the
        // budget math — treat it as "always fits", like unlimited
        let mut b = CacheBudget::new(100);
        assert_eq!(b.free_slots(0), None);
        b.reserve(100);
        assert_eq!(b.free_slots(0), None);
    }

    #[test]
    #[should_panic(expected = "KvCache dims must be positive")]
    fn zero_capacity_cache_is_rejected_at_construction() {
        // cap == 0 would underflow window_start's `cap - 1` and make the
        // ring index `pos % 0` — construction is the place to fail
        let _ = KvCache::new(1, 1, 0);
    }
}
