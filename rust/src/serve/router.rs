//! The admission router: one intake fanned out to N [`ServeEngine`]
//! replicas, each on its own thread with a private worker pool, a private
//! KV [`CacheBudget`] slice, and shared read-only weights (the borrowed
//! [`SparseModel`] plus an optional `Arc`-shared [`ModelFleet`] — mapped
//! `.spkt` pages are immutable, so every replica aliases one mapping with
//! zero copy).
//!
//! The seam is [`RequestSource`]: to a replica engine, the router is just
//! another source; to the outer source (TCP [`NetSource`] or a synthetic
//! workload), the router looks like one big engine. The dispatcher runs on
//! the caller's thread — the outer source and the event sink are `&mut`
//! and never leave it — and talks to replica threads through two tiny
//! lock+condvar queues:
//!
//! * **downstream** (per replica): pending requests, pending cancels, and
//!   the closed flag, plus a *capacity hint* the replica refreshes at
//!   every poll (its bounded queue's free space minus what the router
//!   already sent). The dispatcher only routes to replicas with a
//!   positive hint, so engine-side capacity rejections never fire under
//!   the router.
//! * **upstream** (shared): lifecycle events and result-hook calls
//!   (accepted / token / finished / cancelled), relayed in order so the
//!   caller's sink and source observe a single serialized stream.
//!
//! Routing policy: **least outstanding tokens** — each replica's load is
//! the sum of `max_new_tokens` still unproduced across requests it owns —
//! with FIFO tie-break (lowest replica index wins). Ownership is sticky:
//! the request→replica map routes cancels and dead-client disconnects to
//! the owning replica. Backpressure stays 429-shaped: a submission is
//! rejected only when *every* replica's hint is zero.

use std::collections::{HashMap, VecDeque};
use std::sync::{Arc, Condvar, Mutex};
use std::time::Duration;

use anyhow::Result;

use crate::obs::Obs;
use crate::serve::engine::{
    EngineOptions, EngineOutcome, FinishedRequest, RequestSource, ServeEngine, ServeEvent,
    SyntheticSource,
};
use crate::serve::fleet::ModelFleet;
use crate::serve::model::SparseModel;
use crate::serve::scheduler::ServeRequest;

/// How long a parked side (replica intake or dispatcher relay) sleeps
/// before re-checking its queue — short enough that drain latency is
/// invisible, long enough that idle replicas cost ~nothing.
const PARK: Duration = Duration::from_millis(1);

/// What a drained router run produced: the aggregated totals plus each
/// replica's own [`EngineOutcome`] (the differential suites pin
/// per-replica invariants like `cache_bytes_in_use == 0`).
#[derive(Clone, Debug)]
pub struct RouterOutcome {
    /// Totals across replicas: `finished` concatenated (sorted by id),
    /// token/cancel/reject counts summed, wall-clock fields (`steps`,
    /// `decode_secs`, `prefill_secs`) taken as the max since replicas run
    /// in parallel — which is what lets `tokens_per_sec` show scale-out.
    pub total: EngineOutcome,
    /// Outcome of replica `i` at index `i`.
    pub per_replica: Vec<EngineOutcome>,
}

/// Admission router over N engine replicas. Construction mirrors
/// [`ServeEngine`]: borrow the model, take the per-replica
/// [`EngineOptions`] template, optionally share a fleet and an [`Obs`].
pub struct Router<'a> {
    model: &'a SparseModel,
    opts: EngineOptions,
    replicas: usize,
    fleet: Option<Arc<Mutex<ModelFleet>>>,
    /// front-door registry: router-level 429s/cancels land here, and the
    /// per-replica registries are attached so one snapshot reports
    /// aggregated totals plus `replica_N_*` families
    obs: Obs,
}

impl<'a> Router<'a> {
    /// `opts` is the template every replica runs with, except:
    /// `opts.replica` is overwritten with the replica index, and
    /// `opts.cache_budget_bytes` is treated as the *total* budget, split
    /// evenly — N replicas never hold more cache than one engine with the
    /// same setting would (a 1-replica router gets the whole budget,
    /// preserving parity with the bare engine).
    pub fn new(model: &'a SparseModel, opts: EngineOptions, replicas: usize) -> Router<'a> {
        Router { model, opts, replicas: replicas.max(1), fleet: None, obs: Obs::default() }
    }

    /// Share one [`ModelFleet`] registry across all replicas (wrapped for
    /// sharing; see [`ServeEngine::with_shared_fleet`]).
    pub fn with_fleet(mut self, fleet: ModelFleet) -> Router<'a> {
        self.fleet = Some(Arc::new(Mutex::new(fleet)));
        self
    }

    pub fn with_shared_fleet(mut self, fleet: Arc<Mutex<ModelFleet>>) -> Router<'a> {
        self.fleet = Some(fleet);
        self
    }

    /// Share the front-door [`Obs`]. Each replica still gets a private
    /// registry (same clock); [`Router::run_source`] attaches them here so
    /// the caller's snapshot carries the aggregate and the `replica_N_*`
    /// families.
    pub fn with_obs(mut self, obs: Obs) -> Router<'a> {
        self.obs = obs;
        self
    }

    /// Convenience mirror of [`ServeEngine::run`]: a preloaded synthetic
    /// workload routed across the replicas.
    pub fn run(
        &self,
        incoming: Vec<(usize, ServeRequest)>,
        on_event: &mut dyn FnMut(&ServeEvent),
    ) -> Result<RouterOutcome> {
        self.run_source(&mut SyntheticSource::new(incoming, Vec::new()), on_event)
    }

    /// Drain the outer source through the replica fleet. Replica threads
    /// are scoped to this call; the outer `source` and `on_event` only
    /// ever run on the caller's thread.
    pub fn run_source(
        &self,
        source: &mut dyn RequestSource,
        on_event: &mut dyn FnMut(&ServeEvent),
    ) -> Result<RouterOutcome> {
        let n = self.replicas;
        let queue_cap = self.opts.policy.queue_cap.max(1);
        let per_replica_budget = self.opts.cache_budget_bytes / n as u64;
        let replica_obs: Vec<Obs> =
            (0..n).map(|_| Obs::new(self.obs.clock().clone())).collect();
        self.obs.attach_replicas(replica_obs.clone());
        let downstream: Vec<Downstream> = (0..n).map(|_| Downstream::new(queue_cap)).collect();
        let relay = Relay::default();

        let mut dispatch = Dispatcher {
            downstream: &downstream,
            relay: &relay,
            hints: vec![queue_cap; n],
            outstanding: vec![0; n],
            dead: vec![false; n],
            live: HashMap::new(),
            done: 0,
            queue_cap,
            router_cancelled: 0,
            router_rejected: 0,
            intake_closed: false,
        };

        let outcomes: Vec<Result<EngineOutcome>> = std::thread::scope(|scope| {
            let handles: Vec<_> = (0..n)
                .map(|i| {
                    let mut opts = self.opts;
                    opts.replica = i;
                    opts.cache_budget_bytes = per_replica_budget;
                    let robs = replica_obs[i].clone();
                    let fleet = self.fleet.clone();
                    let (down, relay) = (&downstream[i], &relay);
                    scope.spawn(move || {
                        let mut engine = ServeEngine::new(self.model, opts).with_obs(robs);
                        if let Some(f) = fleet {
                            engine = engine.with_shared_fleet(f);
                        }
                        let mut src = ReplicaSource { down, relay };
                        let out = engine
                            .run_source(&mut src, &mut |ev| relay.push(Feedback::Event(ev.clone())));
                        // always announce — the dispatcher must not wait on
                        // a replica that died early
                        relay.push(Feedback::Done(i));
                        out
                    })
                })
                .collect();

            dispatch.run(source, on_event, &self.obs);

            handles.into_iter().map(|h| h.join().expect("replica thread panicked")).collect()
        });

        let mut per_replica = Vec::with_capacity(n);
        for out in outcomes {
            per_replica.push(out?);
        }
        let total = aggregate(&per_replica, dispatch.router_rejected, dispatch.router_cancelled);
        Ok(RouterOutcome { total, per_replica })
    }
}

/// Totals across replicas; see [`RouterOutcome::total`] for the
/// sum-vs-max conventions.
fn aggregate(per_replica: &[EngineOutcome], rejected: usize, cancelled: usize) -> EngineOutcome {
    let mut finished: Vec<FinishedRequest> =
        per_replica.iter().flat_map(|o| o.finished.iter().cloned()).collect();
    finished.sort_by_key(|f| f.id);
    EngineOutcome {
        finished,
        steps: per_replica.iter().map(|o| o.steps).max().unwrap_or(0),
        tokens: per_replica.iter().map(|o| o.tokens).sum(),
        cancelled: cancelled + per_replica.iter().map(|o| o.cancelled).sum::<usize>(),
        rejected: rejected + per_replica.iter().map(|o| o.rejected).sum::<usize>(),
        decode_secs: per_replica.iter().map(|o| o.decode_secs).fold(0.0, f64::max),
        prefill_secs: per_replica.iter().map(|o| o.prefill_secs).fold(0.0, f64::max),
        prefill_tokens: per_replica.iter().map(|o| o.prefill_tokens).sum(),
        cache_evictions: per_replica.iter().map(|o| o.cache_evictions).sum(),
        peak_cache_bytes: per_replica.iter().map(|o| o.peak_cache_bytes).sum(),
        cache_bytes_in_use: per_replica.iter().map(|o| o.cache_bytes_in_use).sum(),
    }
}

/// Dispatcher → replica queue: requests routed to this replica, cancels
/// for requests it owns, and the drain flag.
struct DownState {
    pending: VecDeque<ServeRequest>,
    cancels: Vec<u64>,
    closed: bool,
    /// how many more requests the dispatcher may push right now without
    /// overflowing this replica's bounded queue; refreshed by the replica
    /// at every poll, decremented by both sides as requests are routed
    hint: usize,
}

struct Downstream {
    state: Mutex<DownState>,
    cv: Condvar,
}

impl Downstream {
    fn new(queue_cap: usize) -> Downstream {
        Downstream {
            state: Mutex::new(DownState {
                pending: VecDeque::new(),
                cancels: Vec::new(),
                closed: false,
                hint: queue_cap,
            }),
            cv: Condvar::new(),
        }
    }

    fn push_request(&self, req: ServeRequest) {
        let mut s = self.state.lock().unwrap();
        s.pending.push_back(req);
        s.hint = s.hint.saturating_sub(1);
        self.cv.notify_one();
    }

    /// Deliver a cancel for a request this replica owns. A request still
    /// sitting in `pending` (the engine has not polled it yet) is yanked
    /// here instead — returns true, and the dispatcher retires it as
    /// cancelled-at-zero-tokens itself.
    fn push_cancel(&self, id: u64) -> bool {
        let mut s = self.state.lock().unwrap();
        if let Some(pos) = s.pending.iter().position(|r| r.id == id) {
            s.pending.remove(pos);
            s.hint += 1;
            return true;
        }
        s.cancels.push(id);
        self.cv.notify_one();
        false
    }

    fn close(&self) {
        self.state.lock().unwrap().closed = true;
        self.cv.notify_one();
    }
}

/// Replica → dispatcher relay: one shared in-order queue of lifecycle
/// events and result-hook calls.
enum Feedback {
    Event(ServeEvent),
    Accepted(ServeRequest),
    Rejected(ServeRequest, usize, usize),
    Token { id: u64, index: usize, token: i32 },
    Finished(Box<FinishedRequest>),
    Cancelled { id: u64, tokens: usize },
    /// replica `i`'s run returned (ok or err) — nothing follows from it
    Done(usize),
}

#[derive(Default)]
struct Relay {
    q: Mutex<VecDeque<Feedback>>,
    cv: Condvar,
}

impl Relay {
    fn push(&self, fb: Feedback) {
        self.q.lock().unwrap().push_back(fb);
        self.cv.notify_one();
    }

    /// Everything queued right now; parks up to [`PARK`] when empty.
    fn drain(&self) -> Vec<Feedback> {
        let mut q = self.q.lock().unwrap();
        if q.is_empty() {
            q = self.cv.wait_timeout(q, PARK).unwrap().0;
        }
        q.drain(..).collect()
    }
}

/// The [`RequestSource`] a replica engine drains: pulls from its
/// [`Downstream`] queue, relays every result hook upstream. `token`
/// always answers reachable — dead clients come back asynchronously as a
/// cancel from the dispatcher, which the engine retires next step.
struct ReplicaSource<'x> {
    down: &'x Downstream,
    relay: &'x Relay,
}

impl RequestSource for ReplicaSource<'_> {
    fn poll(&mut self, _step: usize, queue_free: usize) -> Vec<ServeRequest> {
        let mut s = self.down.state.lock().unwrap();
        let take = queue_free.min(s.pending.len());
        let out: Vec<ServeRequest> = s.pending.drain(..take).collect();
        s.hint = queue_free.saturating_sub(take + s.pending.len());
        out
    }

    fn take_cancelled(&mut self, _step: usize) -> Vec<u64> {
        std::mem::take(&mut self.down.state.lock().unwrap().cancels)
    }

    fn closed(&self) -> bool {
        let s = self.down.state.lock().unwrap();
        s.closed && s.pending.is_empty() && s.cancels.is_empty()
    }

    fn accepted(&mut self, req: &ServeRequest) {
        self.relay.push(Feedback::Accepted(req.clone()));
    }

    fn rejected(&mut self, req: &ServeRequest, queue: usize, cap: usize) {
        self.relay.push(Feedback::Rejected(req.clone(), queue, cap));
    }

    fn token(&mut self, id: u64, index: usize, token: i32) -> bool {
        self.relay.push(Feedback::Token { id, index, token });
        true
    }

    fn finished(&mut self, fin: &FinishedRequest) {
        self.relay.push(Feedback::Finished(Box::new(fin.clone())));
    }

    fn cancelled(&mut self, id: u64, tokens: usize) {
        self.relay.push(Feedback::Cancelled { id, tokens });
    }

    fn idle(&mut self) {
        let s = self.down.state.lock().unwrap();
        if s.pending.is_empty() && s.cancels.is_empty() && !s.closed {
            let _ = self.down.cv.wait_timeout(s, PARK).unwrap();
        }
    }
}

/// The caller-thread half: routes intake, relays feedback to the outer
/// source and event sink, tracks sticky ownership and per-replica load.
struct Dispatcher<'x> {
    downstream: &'x [Downstream],
    relay: &'x Relay,
    /// local copy of each replica's capacity hint, refreshed every tick
    hints: Vec<usize>,
    /// tokens still unproduced across requests each replica owns
    outstanding: Vec<usize>,
    /// replicas whose run returned while intake was still open (an error
    /// drain) — never routed to again
    dead: Vec<bool>,
    /// sticky ownership: id → (replica, tokens still unproduced)
    live: HashMap<u64, (usize, usize)>,
    done: usize,
    queue_cap: usize,
    router_cancelled: usize,
    router_rejected: usize,
    intake_closed: bool,
}

impl Dispatcher<'_> {
    fn run(
        &mut self,
        source: &mut dyn RequestSource,
        on_event: &mut dyn FnMut(&ServeEvent),
        obs: &Obs,
    ) {
        let n = self.downstream.len();
        let mut tick = 0usize;
        loop {
            let mut progressed = false;
            for fb in self.relay.drain() {
                progressed = true;
                self.feedback(fb, tick, source, on_event);
            }
            if self.done == n {
                break;
            }
            // sticky cancellation: the outer source's cancels go to the
            // owning replica; ids the router never routed are no-ops
            for id in source.take_cancelled(tick) {
                progressed = true;
                if let Some(&(r, _)) = self.live.get(&id) {
                    if self.downstream[r].push_cancel(id) {
                        // still queued router-side: retire it here — the
                        // engine never saw it, so the dispatcher owns the
                        // lifecycle narration
                        self.remove_live(id);
                        self.router_cancelled += 1;
                        obs.metrics().requests_cancelled_total.inc();
                        on_event(&ServeEvent::Cancelled { id, step: tick, tokens: 0, replica: r });
                        source.cancelled(id, 0);
                    }
                }
            }
            // refresh capacity hints: the shared copy is authoritative —
            // it is debited on every push and recomputed at every replica
            // poll, so it can never promise more than the bounded queue
            // can take (the local copy only tracks intra-tick routing)
            for (i, d) in self.downstream.iter().enumerate() {
                self.hints[i] = if self.dead[i] { 0 } else { d.state.lock().unwrap().hint };
            }
            let free: usize = self.hints.iter().sum();
            for req in source.poll(tick, free) {
                progressed = true;
                match self.pick_replica() {
                    Some(r) => {
                        self.hints[r] -= 1;
                        self.outstanding[r] += req.max_new_tokens;
                        self.live.insert(req.id, (r, req.max_new_tokens));
                        self.downstream[r].push_request(req);
                        // Accepted/Enqueued narration arrives upstream once
                        // the owning engine admits it to its bounded queue
                    }
                    None => {
                        // every replica's queue is full: 429, never block
                        self.router_rejected += 1;
                        obs.metrics().requests_rejected_total.inc();
                        let cap = self.queue_cap * n;
                        on_event(&ServeEvent::Rejected { id: req.id, step: tick, queue: cap, cap });
                        source.rejected(&req, cap, cap);
                    }
                }
            }
            // drain: intake closed and every routed request retired →
            // release the replicas (their own drain condition is a closed
            // flag plus empty queues)
            if !self.intake_closed && source.closed() && self.live.is_empty() {
                self.intake_closed = true;
                for d in self.downstream {
                    d.close();
                }
            }
            if !progressed {
                source.idle();
            }
            tick += 1;
        }
    }

    /// Least outstanding tokens among replicas with queue headroom, FIFO
    /// tie-break (lowest index).
    fn pick_replica(&self) -> Option<usize> {
        (0..self.downstream.len())
            .filter(|&i| self.hints[i] > 0 && !self.dead[i])
            .min_by_key(|&i| (self.outstanding[i], i))
    }

    fn remove_live(&mut self, id: u64) {
        if let Some((r, remaining)) = self.live.remove(&id) {
            self.outstanding[r] = self.outstanding[r].saturating_sub(remaining);
        }
    }

    fn feedback(
        &mut self,
        fb: Feedback,
        tick: usize,
        source: &mut dyn RequestSource,
        on_event: &mut dyn FnMut(&ServeEvent),
    ) {
        match fb {
            Feedback::Event(ev) => on_event(&ev),
            Feedback::Accepted(req) => source.accepted(&req),
            Feedback::Rejected(req, queue, cap) => {
                // engine-side shed (unknown model name; capacity sheds
                // can't fire under the hint discipline): ownership ends
                self.remove_live(req.id);
                source.rejected(&req, queue, cap);
            }
            Feedback::Token { id, index, token } => {
                if let Some(e) = self.live.get_mut(&id) {
                    e.1 = e.1.saturating_sub(1);
                    self.outstanding[e.0] = self.outstanding[e.0].saturating_sub(1);
                }
                if !source.token(id, index, token) {
                    // dead client: route the disconnect to the owner; the
                    // engine retires it as cancelled next step (a token
                    // came from the decode batch, so the request cannot
                    // still be sitting in the pending queue)
                    if let Some(&(r, _)) = self.live.get(&id) {
                        let _ = self.downstream[r].push_cancel(id);
                    }
                }
            }
            Feedback::Finished(fin) => {
                self.remove_live(fin.id);
                source.finished(&fin);
            }
            Feedback::Cancelled { id, tokens } => {
                self.remove_live(id);
                source.cancelled(id, tokens);
            }
            Feedback::Done(i) => {
                self.done += 1;
                // a replica that returned while intake is still open died
                // on an error: stop routing to it and drop the requests it
                // owned from the live map, so the drain condition can
                // still be met and the other replicas still release
                if !self.intake_closed {
                    self.dead[i] = true;
                    self.hints[i] = 0;
                    let orphans: Vec<u64> = self
                        .live
                        .iter()
                        .filter(|(_, &(r, _))| r == i)
                        .map(|(&id, _)| id)
                        .collect();
                    for id in orphans {
                        self.remove_live(id);
                    }
                }
            }
        }
    }
}
