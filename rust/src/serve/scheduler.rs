//! Continuous-batching request admission: a bounded FIFO queue plus the
//! batch-formation policy that decides, each decode step, which queued
//! requests join the running batch.
//!
//! Policy (the classic continuous-batching shape):
//! * while requests are in flight, free batch slots are filled *immediately*
//!   from the queue — joiners ride the next decode step;
//! * from idle, the engine waits up to `max_wait` steps for the queue to
//!   fill a whole batch before launching a partial one, trading first-token
//!   latency for step efficiency.

use std::collections::VecDeque;

use anyhow::{bail, Result};

/// One inference request (token ids in, token budget out).
#[derive(Clone, Debug, PartialEq)]
pub struct ServeRequest {
    pub id: u64,
    pub prompt: Vec<i32>,
    pub max_new_tokens: usize,
    /// per-request sampling stream seed
    pub seed: u64,
}

/// Batch-formation knobs.
#[derive(Clone, Copy, Debug)]
pub struct SchedulerPolicy {
    /// decode-batch capacity (concurrent requests per step)
    pub max_batch: usize,
    /// idle steps to wait for a full batch before launching a partial one
    pub max_wait: usize,
    /// bounded admission queue capacity
    pub queue_cap: usize,
}

impl Default for SchedulerPolicy {
    fn default() -> SchedulerPolicy {
        SchedulerPolicy { max_batch: 8, max_wait: 2, queue_cap: 64 }
    }
}

/// The bounded queue + admission state.
pub struct Scheduler {
    policy: SchedulerPolicy,
    queue: VecDeque<ServeRequest>,
    /// idle steps spent waiting for a full batch
    waited: usize,
}

impl Scheduler {
    pub fn new(policy: SchedulerPolicy) -> Scheduler {
        assert!(policy.max_batch > 0, "max_batch must be positive");
        Scheduler { policy, queue: VecDeque::new(), waited: 0 }
    }

    pub fn policy(&self) -> &SchedulerPolicy {
        &self.policy
    }

    /// Enqueue a request; errors when the bounded queue is full
    /// (backpressure — the caller decides whether to retry or shed).
    pub fn submit(&mut self, req: ServeRequest) -> Result<()> {
        if self.queue.len() >= self.policy.queue_cap {
            bail!(
                "request queue full ({} of {}); rejecting request {}",
                self.queue.len(),
                self.policy.queue_cap,
                req.id
            );
        }
        self.queue.push_back(req);
        Ok(())
    }

    pub fn queue_len(&self) -> usize {
        self.queue.len()
    }

    /// Whether the bounded queue can accept another request right now.
    pub fn has_capacity(&self) -> bool {
        self.queue.len() < self.policy.queue_cap
    }

    pub fn is_empty(&self) -> bool {
        self.queue.is_empty()
    }

    /// Batch formation for one step given `active` in-flight requests.
    /// Returns the requests that join this step (possibly empty).
    pub fn admit(&mut self, active: usize) -> Vec<ServeRequest> {
        let free = self.policy.max_batch.saturating_sub(active);
        if free == 0 || self.queue.is_empty() {
            return Vec::new();
        }
        let partial = self.queue.len() < self.policy.max_batch;
        if active == 0 && partial && self.waited < self.policy.max_wait {
            // idle engine, partial batch: hold for up to max_wait steps
            self.waited += 1;
            return Vec::new();
        }
        self.waited = 0;
        let n = free.min(self.queue.len());
        self.queue.drain(..n).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(id: u64) -> ServeRequest {
        ServeRequest { id, prompt: vec![1, 2], max_new_tokens: 4, seed: id }
    }

    #[test]
    fn bounded_queue_rejects_overflow() {
        let mut s = Scheduler::new(SchedulerPolicy { max_batch: 2, max_wait: 0, queue_cap: 2 });
        s.submit(req(0)).unwrap();
        s.submit(req(1)).unwrap();
        assert!(s.submit(req(2)).is_err());
        assert_eq!(s.queue_len(), 2);
    }

    #[test]
    fn idle_engine_waits_for_full_batch_then_launches_partial() {
        let mut s = Scheduler::new(SchedulerPolicy { max_batch: 4, max_wait: 2, queue_cap: 16 });
        s.submit(req(0)).unwrap();
        s.submit(req(1)).unwrap();
        assert!(s.admit(0).is_empty(), "first idle step waits");
        assert!(s.admit(0).is_empty(), "second idle step waits");
        let batch = s.admit(0);
        assert_eq!(batch.len(), 2, "max_wait exhausted -> partial batch");
        assert!(s.is_empty());
    }

    #[test]
    fn full_batch_launches_immediately() {
        let mut s = Scheduler::new(SchedulerPolicy { max_batch: 2, max_wait: 5, queue_cap: 16 });
        s.submit(req(0)).unwrap();
        s.submit(req(1)).unwrap();
        s.submit(req(2)).unwrap();
        let batch = s.admit(0);
        assert_eq!(batch.iter().map(|r| r.id).collect::<Vec<_>>(), vec![0, 1]);
        assert_eq!(s.queue_len(), 1, "overflow stays queued");
    }

    #[test]
    fn running_batch_joins_immediately_up_to_capacity() {
        let mut s = Scheduler::new(SchedulerPolicy { max_batch: 4, max_wait: 9, queue_cap: 16 });
        s.submit(req(0)).unwrap();
        // 3 slots busy, 1 free: the queued request joins with no wait
        assert_eq!(s.admit(3).len(), 1);
        // full batch: nothing joins even though requests are queued
        s.submit(req(1)).unwrap();
        assert!(s.admit(4).is_empty());
        assert_eq!(s.queue_len(), 1);
    }

    #[test]
    fn wait_counter_resets_after_launch() {
        let mut s = Scheduler::new(SchedulerPolicy { max_batch: 2, max_wait: 1, queue_cap: 16 });
        s.submit(req(0)).unwrap();
        assert!(s.admit(0).is_empty());
        assert_eq!(s.admit(0).len(), 1);
        // next idle arrival waits again (counter was reset)
        s.submit(req(1)).unwrap();
        assert!(s.admit(0).is_empty());
        assert_eq!(s.admit(0).len(), 1);
    }
}
