//! Continuous-batching request admission: a bounded FIFO queue plus the
//! batch-formation policy that decides, each decode step, which queued
//! requests join the running batch.
//!
//! Policy (the classic continuous-batching shape):
//! * while requests are in flight, free batch slots are filled *immediately*
//!   from the queue — joiners ride the next decode step;
//! * from idle, the engine waits up to `max_wait` steps for the queue to
//!   fill a whole batch before launching a partial one, trading first-token
//!   latency for step efficiency;
//! * admission is cost-aware: each step the engine hands the scheduler a
//!   [`StepLimits`] — how many prompt tokens this step's chunked prefill
//!   budget still covers and how many per-request KV caches the cache-memory
//!   budget can still hold — and joiners that do not fit stay queued
//!   (backpressure) instead of being dropped.

use std::collections::VecDeque;

use anyhow::{bail, Result};

/// One inference request (token ids in, token budget out).
#[derive(Clone, Debug, PartialEq)]
pub struct ServeRequest {
    pub id: u64,
    pub prompt: Vec<i32>,
    pub max_new_tokens: usize,
    /// per-request sampling stream seed
    pub seed: u64,
    /// fleet variant to decode on (`None` = the engine's default model);
    /// named variants are resolved through the [`ModelFleet`] at admission
    ///
    /// [`ModelFleet`]: crate::serve::fleet::ModelFleet
    pub model: Option<String>,
}

impl ServeRequest {
    /// Prompt tokens the prefill pass must process (at least one — an empty
    /// prompt is served as a single bos-like `0` token).
    pub fn prefill_cost(&self) -> usize {
        self.prompt.len().max(1)
    }
}

/// Batch-formation knobs.
#[derive(Clone, Copy, Debug)]
pub struct SchedulerPolicy {
    /// decode-batch capacity (concurrent requests per step)
    pub max_batch: usize,
    /// idle steps to wait for a full batch before launching a partial one
    pub max_wait: usize,
    /// bounded admission queue capacity
    pub queue_cap: usize,
    /// prompt tokens admission may hand to prompt processing per step
    /// (0 = unlimited); a burst of long prompts then spreads across steps
    /// instead of stalling the running batch behind one huge prefill pass.
    /// The engine translates this into [`StepLimits::prefill_tokens`] each
    /// step (in both decode modes — the uncached path pays prompt rows in
    /// every re-forward, so the throttle applies there too).
    pub max_prefill_tokens: usize,
}

impl Default for SchedulerPolicy {
    fn default() -> SchedulerPolicy {
        SchedulerPolicy { max_batch: 8, max_wait: 2, queue_cap: 64, max_prefill_tokens: 0 }
    }
}

/// What this step's budgets still allow admission to take on. `None`
/// means unconstrained — the scheduler applies exactly what it is
/// handed. The engine derives these each step from the policy's
/// `max_prefill_tokens`, the model's per-request cache size, and the
/// live [`CacheBudget`].
///
/// [`CacheBudget`]: crate::serve::kv::CacheBudget
#[derive(Clone, Copy, Debug, Default)]
pub struct StepLimits {
    /// prompt tokens admission may hand to prompt processing this step
    pub prefill_tokens: Option<usize>,
    /// additional per-request KV caches the memory budget can hold
    pub cache_slots: Option<usize>,
}

impl StepLimits {
    pub fn unlimited() -> StepLimits {
        StepLimits::default()
    }
}

/// The bounded queue + admission state.
pub struct Scheduler {
    policy: SchedulerPolicy,
    queue: VecDeque<ServeRequest>,
    /// idle steps spent waiting for a full batch
    waited: usize,
    /// deepest the queue has ever been (the telemetry high-watermark)
    peak_queue: usize,
}

impl Scheduler {
    pub fn new(policy: SchedulerPolicy) -> Scheduler {
        assert!(policy.max_batch > 0, "max_batch must be positive");
        Scheduler { policy, queue: VecDeque::new(), waited: 0, peak_queue: 0 }
    }

    pub fn policy(&self) -> &SchedulerPolicy {
        &self.policy
    }

    /// Enqueue a request; errors when the bounded queue is full
    /// (backpressure — the caller decides whether to retry or shed).
    pub fn submit(&mut self, req: ServeRequest) -> Result<()> {
        if self.queue.len() >= self.policy.queue_cap {
            bail!(
                "request queue full ({} of {}); rejecting request {}",
                self.queue.len(),
                self.policy.queue_cap,
                req.id
            );
        }
        self.queue.push_back(req);
        self.peak_queue = self.peak_queue.max(self.queue.len());
        Ok(())
    }

    pub fn queue_len(&self) -> usize {
        self.queue.len()
    }

    /// Deepest the bounded queue has ever been over this scheduler's
    /// lifetime (feeds the `queue_depth_peak` gauge).
    pub fn queue_peak(&self) -> usize {
        self.peak_queue
    }

    /// Remaining bounded-queue capacity — what the engine hands its
    /// request source as the backpressure signal each step.
    pub fn free_capacity(&self) -> usize {
        self.policy.queue_cap.saturating_sub(self.queue.len())
    }

    /// Remove a still-queued request (its client cancelled or disconnected
    /// before admission). Returns whether the id was found; in-flight and
    /// already-retired ids are the engine's business, not the queue's.
    pub fn cancel(&mut self, id: u64) -> bool {
        if let Some(i) = self.queue.iter().position(|r| r.id == id) {
            self.queue.remove(i);
            if self.queue.is_empty() {
                // the idle wait was for a batch that no longer exists; a
                // stale counter would short-change the next lone arrival's
                // max_wait window
                self.waited = 0;
            }
            true
        } else {
            false
        }
    }

    /// Whether the bounded queue can accept another request right now.
    pub fn has_capacity(&self) -> bool {
        self.queue.len() < self.policy.queue_cap
    }

    pub fn is_empty(&self) -> bool {
        self.queue.is_empty()
    }

    /// Batch formation for one step given `active` in-flight requests and
    /// this step's budget headroom. Returns the requests that join (FIFO
    /// order, possibly empty). The per-step prefill budget never starves a
    /// request whose prompt alone exceeds it: the first joiner of a step is
    /// always admitted (its prefill is still internally chunked).
    pub fn admit(&mut self, active: usize, limits: &StepLimits) -> Vec<ServeRequest> {
        let mut free = self.policy.max_batch.saturating_sub(active);
        if let Some(slots) = limits.cache_slots {
            free = free.min(slots);
        }
        if free == 0 || self.queue.is_empty() {
            return Vec::new();
        }
        // "partial" is judged against the *effective* cap for this step:
        // when the cache budget caps the batch below max_batch, a queue
        // that fills the capped batch is as full as this step can get —
        // waiting max_wait steps for a max_batch it can never form would
        // just burn idle steps
        let partial = self.queue.len() < free;
        if active == 0 && partial && self.waited < self.policy.max_wait {
            // idle engine, partial batch: hold for up to max_wait steps
            self.waited += 1;
            return Vec::new();
        }
        self.waited = 0;
        let budget = limits.prefill_tokens.unwrap_or(usize::MAX);
        let mut used = 0usize;
        let mut joined = Vec::new();
        while joined.len() < free {
            let Some(front) = self.queue.front() else { break };
            let cost = front.prefill_cost();
            if !joined.is_empty() && used.saturating_add(cost) > budget {
                break; // the rest of the burst prefills on later steps
            }
            used += cost;
            joined.push(self.queue.pop_front().unwrap());
        }
        joined
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(id: u64) -> ServeRequest {
        ServeRequest { id, prompt: vec![1, 2], max_new_tokens: 4, seed: id, model: None }
    }

    fn req_prompt(id: u64, prompt_len: usize) -> ServeRequest {
        ServeRequest { id, prompt: vec![1; prompt_len], max_new_tokens: 4, seed: id, model: None }
    }

    fn policy(max_batch: usize, max_wait: usize, queue_cap: usize) -> SchedulerPolicy {
        SchedulerPolicy { max_batch, max_wait, queue_cap, ..SchedulerPolicy::default() }
    }

    #[test]
    fn bounded_queue_rejects_overflow() {
        let mut s = Scheduler::new(policy(2, 0, 2));
        s.submit(req(0)).unwrap();
        s.submit(req(1)).unwrap();
        assert!(s.submit(req(2)).is_err());
        assert_eq!(s.queue_len(), 2);
    }

    #[test]
    fn queue_peak_is_a_lifetime_high_watermark() {
        let mut s = Scheduler::new(policy(2, 0, 4));
        assert_eq!(s.queue_peak(), 0);
        s.submit(req(0)).unwrap();
        s.submit(req(1)).unwrap();
        s.submit(req(2)).unwrap();
        assert_eq!(s.queue_peak(), 3);
        // draining the queue never lowers the watermark
        assert_eq!(s.admit(0, &StepLimits::unlimited()).len(), 2);
        assert!(s.cancel(2));
        assert_eq!(s.queue_len(), 0);
        assert_eq!(s.queue_peak(), 3);
    }

    #[test]
    fn cancel_removes_queued_request_and_frees_capacity() {
        let mut s = Scheduler::new(policy(2, 0, 2));
        s.submit(req(0)).unwrap();
        s.submit(req(1)).unwrap();
        assert_eq!(s.free_capacity(), 0);
        assert!(s.cancel(0), "queued id is removed");
        assert!(!s.cancel(0), "second cancel of the same id is a no-op");
        assert!(!s.cancel(9), "unknown id is a no-op");
        assert_eq!(s.queue_len(), 1);
        assert_eq!(s.free_capacity(), 1);
        // the freed slot is usable again and FIFO order holds for the rest
        s.submit(req(2)).unwrap();
        let batch = s.admit(1, &StepLimits::unlimited());
        assert_eq!(batch.iter().map(|r| r.id).collect::<Vec<_>>(), vec![1]);
    }

    #[test]
    fn cancel_draining_the_queue_resets_the_idle_wait() {
        // regression: a cancel() that emptied the queue mid-idle-wait left
        // `waited` stale, so the next lone arrival waited fewer than
        // max_wait steps before launching as a partial batch
        let mut s = Scheduler::new(policy(4, 3, 16));
        let lim = StepLimits::unlimited();
        s.submit(req(0)).unwrap();
        assert!(s.admit(0, &lim).is_empty(), "idle wait step 1");
        assert!(s.admit(0, &lim).is_empty(), "idle wait step 2");
        assert!(s.cancel(0), "queue drains via cancel mid-wait");
        s.submit(req(1)).unwrap();
        // the new arrival gets its full max_wait window...
        assert!(s.admit(0, &lim).is_empty(), "fresh wait step 1");
        assert!(s.admit(0, &lim).is_empty(), "fresh wait step 2");
        assert!(s.admit(0, &lim).is_empty(), "fresh wait step 3");
        // ...and only then launches as a partial batch
        let batch = s.admit(0, &lim);
        assert_eq!(batch.iter().map(|r| r.id).collect::<Vec<_>>(), vec![1]);
    }

    #[test]
    fn cancel_with_requests_left_keeps_the_wait_counter() {
        // counterpart: if the queue is NOT drained, the in-progress wait is
        // for a batch that still exists and must keep aging
        let mut s = Scheduler::new(policy(4, 2, 16));
        let lim = StepLimits::unlimited();
        s.submit(req(0)).unwrap();
        s.submit(req(1)).unwrap();
        assert!(s.admit(0, &lim).is_empty(), "idle wait step 1");
        assert!(s.cancel(0), "one of two cancelled — queue not empty");
        assert!(s.admit(0, &lim).is_empty(), "idle wait step 2");
        let batch = s.admit(0, &lim);
        assert_eq!(batch.iter().map(|r| r.id).collect::<Vec<_>>(), vec![1]);
    }

    #[test]
    fn idle_engine_waits_for_full_batch_then_launches_partial() {
        let mut s = Scheduler::new(policy(4, 2, 16));
        s.submit(req(0)).unwrap();
        s.submit(req(1)).unwrap();
        let lim = StepLimits::unlimited();
        assert!(s.admit(0, &lim).is_empty(), "first idle step waits");
        assert!(s.admit(0, &lim).is_empty(), "second idle step waits");
        let batch = s.admit(0, &lim);
        assert_eq!(batch.len(), 2, "max_wait exhausted -> partial batch");
        assert!(s.is_empty());
    }

    #[test]
    fn full_batch_launches_immediately() {
        let mut s = Scheduler::new(policy(2, 5, 16));
        s.submit(req(0)).unwrap();
        s.submit(req(1)).unwrap();
        s.submit(req(2)).unwrap();
        let batch = s.admit(0, &StepLimits::unlimited());
        assert_eq!(batch.iter().map(|r| r.id).collect::<Vec<_>>(), vec![0, 1]);
        assert_eq!(s.queue_len(), 1, "overflow stays queued");
    }

    #[test]
    fn running_batch_joins_immediately_up_to_capacity() {
        let mut s = Scheduler::new(policy(4, 9, 16));
        let lim = StepLimits::unlimited();
        s.submit(req(0)).unwrap();
        // 3 slots busy, 1 free: the queued request joins with no wait
        assert_eq!(s.admit(3, &lim).len(), 1);
        // full batch: nothing joins even though requests are queued
        s.submit(req(1)).unwrap();
        assert!(s.admit(4, &lim).is_empty());
        assert_eq!(s.queue_len(), 1);
    }

    #[test]
    fn wait_counter_resets_after_launch() {
        let mut s = Scheduler::new(policy(2, 1, 16));
        let lim = StepLimits::unlimited();
        s.submit(req(0)).unwrap();
        assert!(s.admit(0, &lim).is_empty());
        assert_eq!(s.admit(0, &lim).len(), 1);
        // next idle arrival waits again (counter was reset)
        s.submit(req(1)).unwrap();
        assert!(s.admit(0, &lim).is_empty());
        assert_eq!(s.admit(0, &lim).len(), 1);
    }

    #[test]
    fn prefill_budget_spreads_a_burst_across_steps() {
        let mut s = Scheduler::new(policy(4, 0, 16));
        for id in 0..3 {
            s.submit(req_prompt(id, 6)).unwrap();
        }
        // 6 + 6 > 10: only the first fits beside another this step — and
        // the first is always admitted, so exactly one joins per step
        let lim = StepLimits { prefill_tokens: Some(10), cache_slots: None };
        let a = s.admit(0, &lim);
        assert_eq!(a.iter().map(|r| r.id).collect::<Vec<_>>(), vec![0]);
        let b = s.admit(1, &lim);
        assert_eq!(b.iter().map(|r| r.id).collect::<Vec<_>>(), vec![1]);
        assert_eq!(s.queue_len(), 1);
    }

    #[test]
    fn oversized_prompt_is_never_starved() {
        let mut s = Scheduler::new(policy(2, 0, 16));
        s.submit(req_prompt(0, 100)).unwrap();
        let lim = StepLimits { prefill_tokens: Some(4), cache_slots: None };
        assert_eq!(s.admit(0, &lim).len(), 1, "first joiner ignores the budget");
    }

    #[test]
    fn prefill_budget_counts_prompt_tokens_exactly() {
        let mut s = Scheduler::new(policy(4, 0, 16));
        for id in 0..3 {
            s.submit(req_prompt(id, 5)).unwrap();
        }
        let lim = StepLimits { prefill_tokens: Some(10), cache_slots: None };
        assert_eq!(s.admit(0, &lim).len(), 2, "5 + 5 fills the 10-token limit");
        // and None really is unconstrained: the rest joins at once
        assert_eq!(s.admit(2, &StepLimits::unlimited()).len(), 1);
    }

    #[test]
    fn cache_capped_full_batch_launches_immediately() {
        // regression: `partial` compared queue.len() against max_batch even
        // when cache_slots already capped the step below it — an idle
        // engine whose queue filled the *cache-capped* batch burned
        // max_wait steps waiting for a full max_batch it could never form
        let mut s = Scheduler::new(policy(4, 3, 16));
        s.submit(req(0)).unwrap();
        s.submit(req(1)).unwrap();
        let lim = StepLimits { prefill_tokens: None, cache_slots: Some(2) };
        let batch = s.admit(0, &lim);
        assert_eq!(
            batch.iter().map(|r| r.id).collect::<Vec<_>>(),
            vec![0, 1],
            "two queued fill the two cache slots: launch now, no idle wait"
        );
        // a queue that does NOT fill the capped batch still waits
        s.submit(req(2)).unwrap();
        assert!(s.admit(0, &lim).is_empty(), "one of two slots: idle wait holds");
    }

    #[test]
    fn cache_slots_cap_joins_with_backpressure() {
        let mut s = Scheduler::new(policy(4, 0, 16));
        for id in 0..4 {
            s.submit(req(id)).unwrap();
        }
        let lim = StepLimits { prefill_tokens: None, cache_slots: Some(2) };
        assert_eq!(s.admit(0, &lim).len(), 2, "memory budget admits two");
        assert_eq!(s.queue_len(), 2, "the rest stay queued, not shed");
        let none = StepLimits { prefill_tokens: None, cache_slots: Some(0) };
        assert!(s.admit(2, &none).is_empty(), "no headroom, no joins");
    }
}
