//! Loopback client: the counterpart the CLI `client` subcommand, the
//! net-parity test, and the CI smoke job all drive. Blocking `std::net`
//! I/O, frames via the shared codec — deliberately the simplest correct
//! reader of the protocol so it doubles as documentation.
//!
//! The client submits every request up front, then consumes the server's
//! stream until each submission resolved (`finished`, `cancelled`, or
//! `rejected`). `disconnect_after` drops the socket cold after N `token`
//! frames — the tool the tests use to trigger the server's
//! disconnect-as-cancellation path on purpose.

use std::collections::{BTreeMap, VecDeque};
use std::io::{Read, Write};
use std::net::{Shutdown, TcpStream};
use std::time::{Duration, Instant};

use anyhow::{bail, Context, Result};

use crate::serve::net::protocol::{ClientFrame, FrameDecoder, ServerFrame};
use crate::util::json::Json;

/// One request to submit (the server assigns the id; `tag` correlates).
#[derive(Clone, Debug)]
pub struct ClientRequest {
    pub tag: Option<String>,
    pub prompt: Vec<i32>,
    pub max_new_tokens: usize,
    pub seed: u64,
    /// fleet variant to route to (`None` = the default checkpoint)
    pub model: Option<String>,
}

/// Client behavior knobs.
#[derive(Clone, Debug)]
pub struct ClientOptions {
    /// drop the connection cold after *exactly* this many `token` frames
    /// (total, across requests) — simulates a client vanishing mid-stream;
    /// `Some(0)` drops right after the submissions are on the wire, before
    /// any token frame is consumed; when set, `shutdown` is not sent
    pub disconnect_after: Option<usize>,
    /// send a `shutdown` frame once every request resolved (graceful
    /// server drain)
    pub shutdown: bool,
    /// overall deadline waiting for server frames
    pub timeout: Duration,
}

impl Default for ClientOptions {
    fn default() -> ClientOptions {
        ClientOptions { disconnect_after: None, shutdown: false, timeout: Duration::from_secs(60) }
    }
}

/// What one client session observed.
#[derive(Clone, Debug, Default)]
pub struct ClientOutcome {
    pub config: String,
    pub vocab: usize,
    /// request id → generated tokens, in stream order
    pub streams: BTreeMap<u64, Vec<i32>>,
    pub accepted: Vec<u64>,
    pub finished: Vec<u64>,
    /// (id, tokens already streamed) for requests the server cancelled
    pub cancelled: Vec<(u64, usize)>,
    pub rejected: usize,
    /// true when `disconnect_after` tripped and the socket was dropped
    pub disconnected: bool,
}

struct FrameReader {
    stream: TcpStream,
    dec: FrameDecoder,
    queue: VecDeque<String>,
    deadline: Instant,
}

impl FrameReader {
    fn next(&mut self, on_line: &mut dyn FnMut(&str)) -> Result<ServerFrame> {
        loop {
            if let Some(line) = self.queue.pop_front() {
                on_line(&line);
                return ServerFrame::parse(&line);
            }
            if Instant::now() > self.deadline {
                bail!("timed out waiting for a server frame");
            }
            let mut buf = [0u8; 4096];
            match self.stream.read(&mut buf) {
                Ok(0) => bail!("server closed the connection mid-session"),
                Ok(n) => self.queue.extend(self.dec.push(&buf[..n])?),
                Err(e)
                    if matches!(
                        e.kind(),
                        std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut
                    ) => {}
                Err(e) => return Err(e).context("reading from server"),
            }
        }
    }
}

/// Connect, submit `requests`, and consume the stream until every
/// submission resolved (or `disconnect_after` trips). Every raw received
/// line is handed to `on_line` before parsing — the CLI's `--json`
/// passthrough.
pub fn run_client(
    addr: &str,
    requests: &[ClientRequest],
    opts: &ClientOptions,
    on_line: &mut dyn FnMut(&str),
) -> Result<ClientOutcome> {
    let stream = TcpStream::connect(addr).with_context(|| format!("connecting to {addr}"))?;
    let _ = stream.set_nodelay(true);
    stream.set_read_timeout(Some(Duration::from_millis(100))).context("read timeout")?;
    let mut reader = FrameReader {
        stream,
        dec: FrameDecoder::new(),
        queue: VecDeque::new(),
        deadline: Instant::now() + opts.timeout,
    };
    let mut out = ClientOutcome::default();

    match reader.next(on_line)? {
        ServerFrame::Hello { config, vocab } => {
            out.config = config;
            out.vocab = vocab;
        }
        other => bail!("expected a hello frame, got {other:?}"),
    }

    for r in requests {
        let frame = ClientFrame::Request {
            tag: r.tag.clone(),
            prompt: r.prompt.clone(),
            max_new_tokens: r.max_new_tokens,
            seed: r.seed,
            model: r.model.clone(),
        };
        reader.stream.write_all(frame.encode().as_bytes()).context("submitting request")?;
    }

    if opts.disconnect_after == Some(0) {
        // "after zero token frames" means before consuming any: the >= k
        // check below only runs once a token frame arrived, so 0 would
        // otherwise behave like 1 (an off-by-one the net-parity golden's
        // cut point would inherit)
        out.disconnected = true;
        let _ = reader.stream.shutdown(Shutdown::Both);
        return Ok(out);
    }

    let mut unresolved = requests.len();
    let mut tokens_seen = 0usize;
    while unresolved > 0 {
        match reader.next(on_line)? {
            ServerFrame::Accepted { id, .. } => {
                out.accepted.push(id);
                out.streams.entry(id).or_default();
            }
            ServerFrame::Token { id, index, token } => {
                let stream = out.streams.entry(id).or_default();
                if index != stream.len() {
                    bail!(
                        "request {id}: token index {index} arrived out of order (have {})",
                        stream.len()
                    );
                }
                stream.push(token);
                // count the frame *before* the check: the k-th token frame
                // is consumed, then the socket drops — exactly k frames
                tokens_seen += 1;
                if opts.disconnect_after.is_some_and(|k| tokens_seen >= k) {
                    out.disconnected = true;
                    let _ = reader.stream.shutdown(Shutdown::Both);
                    return Ok(out);
                }
            }
            ServerFrame::Finished { id, tokens, .. } => {
                let have = out.streams.get(&id).map_or(0, |s| s.len());
                if have != tokens {
                    bail!("request {id}: finished claims {tokens} tokens, streamed {have}");
                }
                out.finished.push(id);
                unresolved -= 1;
            }
            ServerFrame::Cancelled { id, tokens } => {
                out.cancelled.push((id, tokens));
                unresolved -= 1;
            }
            ServerFrame::Rejected { .. } => {
                out.rejected += 1;
                unresolved -= 1;
            }
            ServerFrame::Error { message } => bail!("server error: {message}"),
            ServerFrame::Hello { .. } => bail!("unexpected second hello frame"),
            // only answers a stats ask; harmless if it ever interleaves
            ServerFrame::Stats { .. } => {}
        }
    }

    if opts.shutdown {
        reader
            .stream
            .write_all(ClientFrame::Shutdown.encode().as_bytes())
            .context("sending shutdown")?;
    }
    Ok(out)
}

/// Connect, ask for a metrics snapshot (`stats` frame), and return the
/// server's snapshot JSON — the CLI's `--stats` / `--stats-only` path.
pub fn fetch_stats(addr: &str, timeout: Duration) -> Result<Json> {
    let mut stream =
        TcpStream::connect(addr).with_context(|| format!("connecting to {addr}"))?;
    stream.set_read_timeout(Some(Duration::from_millis(100))).context("read timeout")?;
    let mut reader = FrameReader {
        stream: stream.try_clone().context("cloning stream")?,
        dec: FrameDecoder::new(),
        queue: VecDeque::new(),
        deadline: Instant::now() + timeout,
    };
    match reader.next(&mut |_| {})? {
        ServerFrame::Hello { .. } => {}
        other => bail!("expected a hello frame, got {other:?}"),
    }
    stream.write_all(ClientFrame::Stats.encode().as_bytes()).context("sending stats ask")?;
    loop {
        match reader.next(&mut |_| {})? {
            ServerFrame::Stats { snapshot } => return Ok(snapshot),
            ServerFrame::Error { message } => bail!("server error: {message}"),
            _ => {} // other traffic may interleave on a busy server
        }
    }
}

/// Connect and send only a `shutdown` frame — the CLI's remote off switch.
pub fn send_shutdown(addr: &str, timeout: Duration) -> Result<()> {
    let mut stream =
        TcpStream::connect(addr).with_context(|| format!("connecting to {addr}"))?;
    stream.set_read_timeout(Some(Duration::from_millis(100))).context("read timeout")?;
    let mut reader = FrameReader {
        stream: stream.try_clone().context("cloning stream")?,
        dec: FrameDecoder::new(),
        queue: VecDeque::new(),
        deadline: Instant::now() + timeout,
    };
    match reader.next(&mut |_| {})? {
        ServerFrame::Hello { .. } => {}
        other => bail!("expected a hello frame, got {other:?}"),
    }
    stream.write_all(ClientFrame::Shutdown.encode().as_bytes()).context("sending shutdown")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::config::ModelCfg;
    use crate::model::init::init_params;
    use crate::serve::engine::{EngineOptions, ServeEngine};
    use crate::serve::model::SparseModel;
    use crate::serve::net::server::{NetServer, NetServerOptions};
    use crate::serve::scheduler::ServeRequest;
    use crate::sparse::PackPolicy;

    fn model() -> SparseModel {
        let cfg = ModelCfg::from_dims("net-test", 8, 1, 2, 1, 1, 11, 4);
        SparseModel::from_params(&init_params(&cfg, 0), &PackPolicy::default()).unwrap()
    }

    #[test]
    fn loopback_stream_matches_in_process_run() {
        let m = model();
        let engine_opts = EngineOptions { temperature: 0.7, top_k: 4, ..Default::default() };
        let prompt = vec![1, 2, 3];
        // the reference: same request served without a socket in sight
        let expect = ServeEngine::new(&m, engine_opts)
            .run(
                vec![(
                    0,
                    ServeRequest {
                        id: 0,
                        prompt: prompt.clone(),
                        max_new_tokens: 5,
                        seed: 9,
                        model: None,
                    },
                )],
                &mut |_| {},
            )
            .unwrap()
            .finished[0]
            .tokens
            .clone();

        let srv = NetServer::bind("127.0.0.1:0", NetServerOptions::new("net-test".into(), 11))
            .unwrap();
        let addr = srv.local_addr().to_string();
        let client = std::thread::spawn(move || {
            run_client(
                &addr,
                &[ClientRequest {
                    tag: Some("t0".into()),
                    prompt,
                    max_new_tokens: 5,
                    seed: 9,
                    model: None,
                }],
                &ClientOptions { shutdown: true, ..Default::default() },
                &mut |_| {},
            )
            .unwrap()
        });
        let out = srv.serve(&m, engine_opts, &mut |_| {}).unwrap();
        let got = client.join().unwrap();
        assert_eq!(got.streams.get(&0).unwrap(), &expect, "wire tokens == in-process tokens");
        assert_eq!(got.finished, vec![0]);
        assert_eq!(got.accepted, vec![0]);
        assert_eq!(out.finished.len(), 1);
        assert_eq!(out.cache_bytes_in_use, 0);
    }

    #[test]
    fn disconnect_after_cuts_after_exactly_n_token_frames() {
        // pins the cut point the net-parity golden depends on: --disconnect-
        // after N consumes exactly N token frames, and N = 0 consumes none
        let m = model();
        // uncached decode over a long prompt keeps each step expensive, so
        // the reader registers the disconnect long before the 64-token
        // budget could drain into the dead socket
        let engine_opts =
            EngineOptions { temperature: 0.0, top_k: 0, kv_cache: false, ..Default::default() };
        for (k, want_tokens) in [(0usize, 0usize), (3, 3)] {
            let srv =
                NetServer::bind("127.0.0.1:0", NetServerOptions::new("net-test".into(), 11))
                    .unwrap();
            let addr = srv.local_addr().to_string();
            let client = std::thread::spawn(move || {
                let got = run_client(
                    &addr,
                    &[ClientRequest {
                        tag: Some("cut".into()),
                        prompt: vec![1; 100],
                        max_new_tokens: 64,
                        seed: 7,
                        model: None,
                    }],
                    &ClientOptions { disconnect_after: Some(k), ..Default::default() },
                    &mut |_| {},
                )
                .unwrap();
                // the disconnected socket cannot drain the server: a second
                // connection sends the shutdown frame
                send_shutdown(&addr, Duration::from_secs(30)).unwrap();
                got
            });
            let out = srv.serve(&m, engine_opts, &mut |_| {}).unwrap();
            let got = client.join().unwrap();
            assert!(got.disconnected, "k={k}: disconnect_after must trip");
            let streamed: usize = got.streams.values().map(|s| s.len()).sum();
            assert_eq!(streamed, want_tokens, "k={k}: exactly k token frames consumed");
            // server side: the vanished client retired as a cancellation,
            // never a finish, and the drain returned the budget
            assert_eq!(out.finished.len(), 0, "k={k}");
            assert_eq!(out.cancelled, 1, "k={k}");
            assert_eq!(out.cache_bytes_in_use, 0, "k={k}");
        }
    }
}
