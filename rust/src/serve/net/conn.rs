//! Connection lifecycle: one [`Conn`] per accepted socket, shared between
//! the reader thread (which owns the receive side) and the engine thread
//! (which streams frames back).
//!
//! The write half lives behind a mutex so whole frames from either thread
//! never interleave on the wire. A failed write flips the connection dead
//! and half-closes the socket — the engine observes the `false` return
//! from [`Conn::send`] and retires the client's requests as cancelled,
//! which is exactly how a disconnect becomes a cancellation without the
//! decode loop ever blocking on a dead peer.

use std::net::{Shutdown, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Mutex;

use crate::serve::net::protocol::ServerFrame;

/// One live client connection's shared state.
pub struct Conn {
    /// server-local connection id (distinct from request ids)
    pub id: u64,
    writer: Mutex<TcpStream>,
    alive: AtomicBool,
}

impl Conn {
    /// Wrap the write half of an accepted socket. The caller keeps the
    /// read half for its reader thread (`TcpStream::try_clone` shares one
    /// underlying socket, so shutdown on either half reaches both).
    pub fn new(id: u64, writer: TcpStream) -> Conn {
        Conn { id, writer: Mutex::new(writer), alive: AtomicBool::new(true) }
    }

    pub fn is_alive(&self) -> bool {
        self.alive.load(Ordering::SeqCst)
    }

    /// Write one frame; returns false when the client is unreachable (the
    /// connection is then marked dead and closed, and every later send is
    /// a cheap no-op false).
    pub fn send(&self, frame: &ServerFrame) -> bool {
        if !self.is_alive() {
            return false;
        }
        let line = frame.encode();
        let mut w = self.writer.lock().expect("conn writer lock");
        match std::io::Write::write_all(&mut *w, line.as_bytes()) {
            Ok(()) => true,
            Err(_) => {
                self.alive.store(false, Ordering::SeqCst);
                let _ = w.shutdown(Shutdown::Both);
                false
            }
        }
    }

    /// Mark dead and close both halves; the reader thread unblocks on the
    /// resulting EOF/error. Idempotent.
    pub fn close(&self) {
        self.alive.store(false, Ordering::SeqCst);
        let w = self.writer.lock().expect("conn writer lock");
        let _ = w.shutdown(Shutdown::Both);
    }
}
