//! Connection lifecycle: one [`Conn`] per accepted socket, shared between
//! the reader thread (which owns the receive side) and the engine thread
//! (which streams frames back).
//!
//! The write half lives behind a mutex so whole frames from either thread
//! never interleave on the wire. A failed write flips the connection dead
//! and half-closes the socket — the engine observes the `false` return
//! from [`Conn::send`] and retires the client's requests as cancelled,
//! which is exactly how a disconnect becomes a cancellation without the
//! decode loop ever blocking on a dead peer.
//!
//! Every frame written is double-counted: per-connection atomics here
//! (local accounting, unit-testable without a registry) and the shared
//! [`Obs`] frame/byte totals plus a net-write phase span (what the
//! `stats` frame and Prometheus dump report).

use std::net::{Shutdown, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Mutex;

use crate::obs::{Obs, Phase};
use crate::serve::net::protocol::ServerFrame;

/// One live client connection's shared state.
pub struct Conn {
    /// server-local connection id (distinct from request ids)
    pub id: u64,
    writer: Mutex<TcpStream>,
    alive: AtomicBool,
    obs: Obs,
    frames_written: AtomicU64,
    bytes_written: AtomicU64,
}

impl Conn {
    /// Wrap the write half of an accepted socket. The caller keeps the
    /// read half for its reader thread (`TcpStream::try_clone` shares one
    /// underlying socket, so shutdown on either half reaches both).
    pub fn new(id: u64, writer: TcpStream, obs: Obs) -> Conn {
        Conn {
            id,
            writer: Mutex::new(writer),
            alive: AtomicBool::new(true),
            obs,
            frames_written: AtomicU64::new(0),
            bytes_written: AtomicU64::new(0),
        }
    }

    pub fn is_alive(&self) -> bool {
        self.alive.load(Ordering::SeqCst)
    }

    /// Frames successfully written to this connection.
    pub fn frames_written(&self) -> u64 {
        self.frames_written.load(Ordering::Relaxed)
    }

    /// Bytes successfully written to this connection.
    pub fn bytes_written(&self) -> u64 {
        self.bytes_written.load(Ordering::Relaxed)
    }

    /// Write one frame; returns false when the client is unreachable (the
    /// connection is then marked dead and closed, and every later send is
    /// a cheap no-op false).
    pub fn send(&self, frame: &ServerFrame) -> bool {
        if !self.is_alive() {
            return false;
        }
        let line = frame.encode();
        let _span = self.obs.span(Phase::NetWrite);
        let mut w = self.writer.lock().expect("conn writer lock");
        match std::io::Write::write_all(&mut *w, line.as_bytes()) {
            Ok(()) => {
                self.frames_written.fetch_add(1, Ordering::Relaxed);
                self.bytes_written.fetch_add(line.len() as u64, Ordering::Relaxed);
                let m = self.obs.metrics();
                m.net_frames_written_total.inc();
                m.net_bytes_written_total.add(line.len() as u64);
                true
            }
            Err(_) => {
                self.alive.store(false, Ordering::SeqCst);
                let _ = w.shutdown(Shutdown::Both);
                false
            }
        }
    }

    /// Mark dead and close both halves; the reader thread unblocks on the
    /// resulting EOF/error. Idempotent.
    pub fn close(&self) {
        self.alive.store(false, Ordering::SeqCst);
        let w = self.writer.lock().expect("conn writer lock");
        let _ = w.shutdown(Shutdown::Both);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Read as _;
    use std::net::TcpListener;

    #[test]
    fn send_counts_frames_and_bytes_per_conn_and_in_obs() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let client = TcpStream::connect(addr).unwrap();
        let (server_side, _) = listener.accept().unwrap();

        let obs = Obs::default();
        let conn = Conn::new(1, server_side, obs.clone());
        let frame = ServerFrame::Cancelled { id: 3, tokens: 2 };
        let wire = frame.encode();
        assert!(conn.send(&frame));
        assert_eq!(conn.frames_written(), 1);
        assert_eq!(conn.bytes_written(), wire.len() as u64);
        let s = obs.snapshot();
        assert_eq!(s.counter("net_frames_written_total"), Some(1));
        assert_eq!(s.counter("net_bytes_written_total"), Some(wire.len() as u64));
        assert_eq!(s.hist("phase_net_write_ns").unwrap().count, 1);

        // the bytes really did land on the wire
        let mut buf = vec![0u8; wire.len()];
        let mut client = client;
        client.read_exact(&mut buf).unwrap();
        assert_eq!(buf, wire.as_bytes());

        // a closed connection drops sends without counting them
        conn.close();
        assert!(!conn.send(&frame));
        assert_eq!(conn.frames_written(), 1);
    }
}
