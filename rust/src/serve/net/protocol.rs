//! The wire protocol: framed newline-delimited JSON, one object per
//! `\n`-terminated line, discriminated by a `"reason"` field — the same
//! shape as the JSONL event stream (`api/events.rs`), so a client that can
//! read the event log can read the wire.
//!
//! Frame grammar (client → server):
//!
//! ```text
//! {"reason":"request","prompt":[1,2,3],"max_new_tokens":8,"seed":7,"tag":"a","model":"q4"}
//! {"reason":"cancel","id":4}
//! {"reason":"stats"}
//! {"reason":"shutdown"}
//! ```
//!
//! and server → client:
//!
//! ```text
//! {"reason":"hello","config":"tiny","vocab":101}
//! {"reason":"accepted","id":4,"tag":"a"}
//! {"reason":"token","id":4,"index":0,"token":17}
//! {"reason":"finished","id":4,"tokens":8,"ttft_ms":1.9,"gap_p50_ms":0.4,"gap_p95_ms":0.9}
//! {"reason":"rejected","id":5,"queue":64,"cap":64,"message":"..."}
//! {"reason":"cancelled","id":4,"tokens":3}
//! {"reason":"stats","snapshot":{"generation":3,"tokens_decoded_total":24,...}}
//! {"reason":"error","message":"..."}
//! ```
//!
//! `tag` is an optional client-chosen correlation string echoed on
//! `accepted`/`rejected` (the server assigns `id`s). `model` is an
//! optional fleet-variant name: omitted means the default checkpoint, an
//! unknown name is answered with a `rejected` frame. Integer fields ride
//! through JSON numbers (f64), so ids and seeds are capped at 2^53 — the
//! codec rejects larger values instead of silently rounding them.
//!
//! [`FrameDecoder`] reassembles lines from arbitrary read boundaries and
//! enforces [`MAX_FRAME_BYTES`]; any malformed input (overlong line,
//! invalid UTF-8, bad JSON, unknown reason, missing or out-of-range
//! fields) surfaces as a protocol `Err` — never a panic — which the
//! connection layer answers with an `error` frame before closing
//! (`tests/net_codec_props.rs` pins both properties).

use std::collections::BTreeMap;

use anyhow::{anyhow, bail, Result};

use crate::util::json::Json;

/// Hard per-frame ceiling: a line longer than this (with no newline in
/// sight) is a protocol error, bounding what a misbehaving peer can make
/// the decoder buffer.
pub const MAX_FRAME_BYTES: usize = 1 << 20;

/// Largest integer JSON numbers carry exactly (2^53).
const MAX_SAFE_INT: u64 = 1 << 53;

fn obj(entries: Vec<(&str, Json)>) -> Json {
    Json::Obj(entries.into_iter().map(|(k, v)| (k.to_string(), v)).collect::<BTreeMap<_, _>>())
}

fn num(n: u64) -> Json {
    Json::Num(n as f64)
}

fn get_u64(v: &Json, key: &str) -> Result<u64> {
    let n = v.get(key)?.as_f64()?;
    if !n.is_finite() || n < 0.0 || n.fract() != 0.0 || n > MAX_SAFE_INT as f64 {
        bail!("field {key:?} is not an integer in [0, 2^53]: {n}");
    }
    Ok(n as u64)
}

fn get_token(v: &Json) -> Result<i32> {
    let n = v.as_f64()?;
    if !n.is_finite() || n.fract() != 0.0 || n < i32::MIN as f64 || n > i32::MAX as f64 {
        bail!("token id is not an i32: {n}");
    }
    Ok(n as i32)
}

fn opt_str(v: &Json, key: &str) -> Result<Option<String>> {
    match v.opt(key) {
        Some(t) => Ok(Some(t.as_str()?.to_string())),
        None => Ok(None),
    }
}

fn opt_tag(v: &Json) -> Result<Option<String>> {
    opt_str(v, "tag")
}

fn tag_entry(entries: &mut Vec<(&str, Json)>, tag: &Option<String>) {
    if let Some(t) = tag {
        entries.push(("tag", Json::Str(t.clone())));
    }
}

/// What a client may send.
#[derive(Clone, Debug, PartialEq)]
pub enum ClientFrame {
    /// submit one inference request; the server replies `accepted` (with
    /// the assigned id) or `rejected`. `model` names a fleet variant
    /// (`None` = the default checkpoint).
    Request {
        tag: Option<String>,
        prompt: Vec<i32>,
        max_new_tokens: usize,
        seed: u64,
        model: Option<String>,
    },
    /// cancel a previously accepted request of this connection
    Cancel { id: u64 },
    /// ask for a metrics snapshot; the server replies with a `stats` frame
    Stats,
    /// graceful drain: stop admitting, finish in-flight requests, exit
    Shutdown,
}

impl ClientFrame {
    pub fn to_json(&self) -> Json {
        match self {
            ClientFrame::Request { tag, prompt, max_new_tokens, seed, model } => {
                let mut entries = vec![
                    ("reason", Json::Str("request".into())),
                    (
                        "prompt",
                        Json::Arr(prompt.iter().map(|t| Json::Num(*t as f64)).collect()),
                    ),
                    ("max_new_tokens", num(*max_new_tokens as u64)),
                    ("seed", num(*seed)),
                ];
                tag_entry(&mut entries, tag);
                if let Some(m) = model {
                    entries.push(("model", Json::Str(m.clone())));
                }
                obj(entries)
            }
            ClientFrame::Cancel { id } => {
                obj(vec![("reason", Json::Str("cancel".into())), ("id", num(*id))])
            }
            ClientFrame::Stats => obj(vec![("reason", Json::Str("stats".into()))]),
            ClientFrame::Shutdown => obj(vec![("reason", Json::Str("shutdown".into()))]),
        }
    }

    /// One wire line, newline-terminated.
    pub fn encode(&self) -> String {
        let mut s = self.to_json().to_string_compact();
        s.push('\n');
        s
    }

    pub fn parse(line: &str) -> Result<ClientFrame> {
        let v = Json::parse(line).map_err(|e| anyhow!("malformed frame: {e}"))?;
        let reason = v.get("reason")?.as_str()?.to_string();
        match reason.as_str() {
            "request" => {
                let prompt = v
                    .get("prompt")?
                    .as_arr()?
                    .iter()
                    .map(get_token)
                    .collect::<Result<Vec<i32>>>()?;
                let max_new_tokens = get_u64(&v, "max_new_tokens")? as usize;
                if max_new_tokens == 0 {
                    bail!("max_new_tokens must be positive");
                }
                let seed = match v.opt("seed") {
                    Some(_) => get_u64(&v, "seed")?,
                    None => 0,
                };
                Ok(ClientFrame::Request {
                    tag: opt_tag(&v)?,
                    prompt,
                    max_new_tokens,
                    seed,
                    model: opt_str(&v, "model")?,
                })
            }
            "cancel" => Ok(ClientFrame::Cancel { id: get_u64(&v, "id")? }),
            "stats" => Ok(ClientFrame::Stats),
            "shutdown" => Ok(ClientFrame::Shutdown),
            other => bail!("unknown client frame reason {other:?}"),
        }
    }
}

/// What the server sends back.
#[derive(Clone, Debug, PartialEq)]
pub enum ServerFrame {
    /// greeting on connect: which packed config is being served and its
    /// vocabulary size (prompt token ids must be in `0..vocab`)
    Hello { config: String, vocab: usize },
    /// the request entered the bounded queue under the assigned id
    Accepted { id: u64, tag: Option<String> },
    /// one generated token, streamed as the engine samples it; `index` is
    /// the token's position in the request's stream (0-based)
    Token { id: u64, index: usize, token: i32 },
    /// the request retired with its full budget; latency profile attached
    Finished { id: u64, tokens: usize, ttft_ms: f64, gap_p50_ms: f64, gap_p95_ms: f64 },
    /// the bounded queue was full (429 semantics) or the server is
    /// draining — the request was shed, not blocked
    Rejected { id: u64, tag: Option<String>, queue: usize, cap: usize, message: String },
    /// the request retired early (cancel frame or disconnect) with
    /// `tokens` already streamed
    Cancelled { id: u64, tokens: usize },
    /// a metrics snapshot (the `Obs` registry's flat JSON rendering),
    /// answering a client `stats` frame
    Stats { snapshot: Json },
    /// protocol violation; the server closes the connection after this
    Error { message: String },
}

impl ServerFrame {
    pub fn to_json(&self) -> Json {
        match self {
            ServerFrame::Hello { config, vocab } => obj(vec![
                ("reason", Json::Str("hello".into())),
                ("config", Json::Str(config.clone())),
                ("vocab", num(*vocab as u64)),
            ]),
            ServerFrame::Accepted { id, tag } => {
                let mut entries =
                    vec![("reason", Json::Str("accepted".into())), ("id", num(*id))];
                tag_entry(&mut entries, tag);
                obj(entries)
            }
            ServerFrame::Token { id, index, token } => obj(vec![
                ("reason", Json::Str("token".into())),
                ("id", num(*id)),
                ("index", num(*index as u64)),
                ("token", Json::Num(*token as f64)),
            ]),
            ServerFrame::Finished { id, tokens, ttft_ms, gap_p50_ms, gap_p95_ms } => obj(vec![
                ("reason", Json::Str("finished".into())),
                ("id", num(*id)),
                ("tokens", num(*tokens as u64)),
                ("ttft_ms", Json::Num(*ttft_ms)),
                ("gap_p50_ms", Json::Num(*gap_p50_ms)),
                ("gap_p95_ms", Json::Num(*gap_p95_ms)),
            ]),
            ServerFrame::Rejected { id, tag, queue, cap, message } => {
                let mut entries = vec![
                    ("reason", Json::Str("rejected".into())),
                    ("id", num(*id)),
                    ("queue", num(*queue as u64)),
                    ("cap", num(*cap as u64)),
                    ("message", Json::Str(message.clone())),
                ];
                tag_entry(&mut entries, tag);
                obj(entries)
            }
            ServerFrame::Cancelled { id, tokens } => obj(vec![
                ("reason", Json::Str("cancelled".into())),
                ("id", num(*id)),
                ("tokens", num(*tokens as u64)),
            ]),
            ServerFrame::Stats { snapshot } => obj(vec![
                ("reason", Json::Str("stats".into())),
                ("snapshot", snapshot.clone()),
            ]),
            ServerFrame::Error { message } => obj(vec![
                ("reason", Json::Str("error".into())),
                ("message", Json::Str(message.clone())),
            ]),
        }
    }

    /// One wire line, newline-terminated.
    pub fn encode(&self) -> String {
        let mut s = self.to_json().to_string_compact();
        s.push('\n');
        s
    }

    pub fn parse(line: &str) -> Result<ServerFrame> {
        let v = Json::parse(line).map_err(|e| anyhow!("malformed frame: {e}"))?;
        let reason = v.get("reason")?.as_str()?.to_string();
        match reason.as_str() {
            "hello" => Ok(ServerFrame::Hello {
                config: v.get("config")?.as_str()?.to_string(),
                vocab: get_u64(&v, "vocab")? as usize,
            }),
            "accepted" => {
                Ok(ServerFrame::Accepted { id: get_u64(&v, "id")?, tag: opt_tag(&v)? })
            }
            "token" => Ok(ServerFrame::Token {
                id: get_u64(&v, "id")?,
                index: get_u64(&v, "index")? as usize,
                token: get_token(v.get("token")?)?,
            }),
            "finished" => Ok(ServerFrame::Finished {
                id: get_u64(&v, "id")?,
                tokens: get_u64(&v, "tokens")? as usize,
                ttft_ms: v.get("ttft_ms")?.as_f64()?,
                gap_p50_ms: v.get("gap_p50_ms")?.as_f64()?,
                gap_p95_ms: v.get("gap_p95_ms")?.as_f64()?,
            }),
            "rejected" => Ok(ServerFrame::Rejected {
                id: get_u64(&v, "id")?,
                tag: opt_tag(&v)?,
                queue: get_u64(&v, "queue")? as usize,
                cap: get_u64(&v, "cap")? as usize,
                message: v.get("message")?.as_str()?.to_string(),
            }),
            "cancelled" => Ok(ServerFrame::Cancelled {
                id: get_u64(&v, "id")?,
                tokens: get_u64(&v, "tokens")? as usize,
            }),
            "stats" => Ok(ServerFrame::Stats { snapshot: v.get("snapshot")?.clone() }),
            "error" => {
                Ok(ServerFrame::Error { message: v.get("message")?.as_str()?.to_string() })
            }
            other => bail!("unknown server frame reason {other:?}"),
        }
    }
}

/// Reassembles newline-delimited frames from arbitrary read boundaries: a
/// TCP read may deliver half a frame or three and a half, so the decoder
/// buffers bytes and yields exactly the complete lines. Blank lines are
/// tolerated (keep-alive friendly) and a trailing `\r` is stripped so CRLF
/// peers work. The partial-line buffer is capped at [`MAX_FRAME_BYTES`].
#[derive(Default)]
pub struct FrameDecoder {
    buf: Vec<u8>,
}

impl FrameDecoder {
    pub fn new() -> FrameDecoder {
        FrameDecoder::default()
    }

    /// Bytes buffered waiting for their newline.
    pub fn pending_bytes(&self) -> usize {
        self.buf.len()
    }

    /// Feed freshly read bytes; returns the complete lines they finish
    /// (possibly none). Errors on an overlong frame or invalid UTF-8 —
    /// the caller should answer with an `error` frame and close.
    pub fn push(&mut self, bytes: &[u8]) -> Result<Vec<String>> {
        self.buf.extend_from_slice(bytes);
        let mut lines = Vec::new();
        while let Some(nl) = self.buf.iter().position(|&b| b == b'\n') {
            let mut line: Vec<u8> = self.buf.drain(..=nl).collect();
            line.pop(); // the newline
            if line.last() == Some(&b'\r') {
                line.pop();
            }
            if line.is_empty() {
                continue;
            }
            let s = String::from_utf8(line)
                .map_err(|_| anyhow!("frame is not valid UTF-8"))?;
            lines.push(s);
        }
        if self.buf.len() > MAX_FRAME_BYTES {
            bail!(
                "frame exceeds {} bytes without a newline ({} buffered)",
                MAX_FRAME_BYTES,
                self.buf.len()
            );
        }
        Ok(lines)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn client_frames_roundtrip() {
        let frames = vec![
            ClientFrame::Request {
                tag: Some("a".into()),
                prompt: vec![0, 5, -0, 99],
                max_new_tokens: 8,
                seed: 1234567,
                model: None,
            },
            ClientFrame::Request {
                tag: None,
                prompt: vec![],
                max_new_tokens: 1,
                seed: 0,
                model: Some("q4".into()),
            },
            ClientFrame::Cancel { id: 42 },
            ClientFrame::Stats,
            ClientFrame::Shutdown,
        ];
        for f in frames {
            let line = f.encode();
            assert!(line.ends_with('\n') && !line[..line.len() - 1].contains('\n'));
            assert_eq!(ClientFrame::parse(line.trim_end()).unwrap(), f);
        }
    }

    #[test]
    fn server_frames_roundtrip() {
        let frames = vec![
            ServerFrame::Hello { config: "tiny".into(), vocab: 101 },
            ServerFrame::Accepted { id: 3, tag: Some("x".into()) },
            ServerFrame::Accepted { id: 4, tag: None },
            ServerFrame::Token { id: 3, index: 0, token: -7 },
            ServerFrame::Finished {
                id: 3,
                tokens: 8,
                ttft_ms: 1.5,
                gap_p50_ms: 0.25,
                gap_p95_ms: 0.75,
            },
            ServerFrame::Rejected {
                id: 9,
                tag: None,
                queue: 64,
                cap: 64,
                message: "request queue full".into(),
            },
            ServerFrame::Cancelled { id: 3, tokens: 2 },
            ServerFrame::Stats {
                snapshot: Json::parse(r#"{"generation":3,"tokens_decoded_total":24}"#).unwrap(),
            },
            ServerFrame::Error { message: "bad \"frame\"\n".into() },
        ];
        for f in frames {
            assert_eq!(ServerFrame::parse(f.encode().trim_end()).unwrap(), f);
        }
    }

    #[test]
    fn decoder_reassembles_split_frames() {
        let wire = format!(
            "{}{}\r\n\n{}",
            ClientFrame::Shutdown.encode(),
            r#"{"reason":"cancel","id":7}"#,
            ClientFrame::Cancel { id: 8 }.encode()
        );
        // feed one byte at a time: every boundary is exercised
        let mut dec = FrameDecoder::new();
        let mut lines = Vec::new();
        for b in wire.as_bytes() {
            lines.extend(dec.push(&[*b]).unwrap());
        }
        assert_eq!(lines.len(), 3);
        assert_eq!(ClientFrame::parse(&lines[0]).unwrap(), ClientFrame::Shutdown);
        assert_eq!(ClientFrame::parse(&lines[1]).unwrap(), ClientFrame::Cancel { id: 7 });
        assert_eq!(ClientFrame::parse(&lines[2]).unwrap(), ClientFrame::Cancel { id: 8 });
        assert_eq!(dec.pending_bytes(), 0);
    }

    #[test]
    fn malformed_frames_error_never_panic() {
        for bad in [
            "",
            "{",
            "nul",
            "[]",
            r#"{"reason":"nope"}"#,
            r#"{"reason":"cancel"}"#,
            r#"{"reason":"cancel","id":-1}"#,
            r#"{"reason":"cancel","id":3.5}"#,
            r#"{"reason":"request","prompt":[1e40],"max_new_tokens":1}"#,
            r#"{"reason":"request","prompt":[0],"max_new_tokens":0}"#,
            r#"{"reason":"request","prompt":"hi","max_new_tokens":1}"#,
            r#"{"reason":"request","prompt":[0],"max_new_tokens":1,"model":7}"#,
            r#"{"reason":"token","id":0,"index":0,"token":null}"#,
        ] {
            assert!(ClientFrame::parse(bad).is_err(), "client accepted {bad:?}");
            assert!(ServerFrame::parse(bad).is_err(), "server accepted {bad:?}");
        }
    }

    #[test]
    fn oversized_frame_is_a_protocol_error() {
        let mut dec = FrameDecoder::new();
        let chunk = vec![b'x'; MAX_FRAME_BYTES / 4];
        for _ in 0..4 {
            assert!(dec.push(&chunk).is_ok());
        }
        assert!(dec.push(b"x").is_err(), "past the cap without a newline");
    }

    #[test]
    fn non_utf8_frame_is_a_protocol_error() {
        let mut dec = FrameDecoder::new();
        assert!(dec.push(&[0xff, 0xfe, b'\n']).is_err());
    }
}
