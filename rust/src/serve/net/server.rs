//! The TCP front door: a `std::net` listener whose accepted connections
//! each get a reader thread parsing client frames into a shared intake
//! queue, and a [`NetSource`] that feeds that intake to the engine's
//! step-driven loop on the caller's thread.
//!
//! Threading model (no async runtime — blocking I/O and scoped lifetimes):
//!
//! * **accept thread** — nonblocking `accept` polled every few ms (so it
//!   can observe shutdown; `std::net` has no way to unblock a blocking
//!   accept), greets each client with a `hello` frame and spawns its
//!   reader.
//! * **reader threads** (one per connection) — blocking reads with a
//!   short timeout, frames decoded via [`FrameDecoder`]; `request` frames
//!   are validated, assigned an id, and pushed to the intake; `cancel`
//!   and `shutdown` flip intake flags; EOF / read errors / protocol
//!   violations mark the connection dead and register a disconnect.
//! * **engine thread** (the `serve` caller) — [`ServeEngine::run_source`]
//!   drains the intake between batch steps and streams `token` /
//!   `finished` / `cancelled` / `rejected` frames back through each
//!   connection's locked writer.
//!
//! Backpressure is 429-shaped: the reader never blocks a client on the
//! bounded queue — overflow is answered with a `rejected` frame by the
//! engine the moment it polls the submission. Graceful drain: a
//! `shutdown` frame stops admission (readers reject new requests on
//! arrival), in-flight requests finish, the engine exits, and every
//! thread is joined before [`NetServer::serve`] returns — the budget
//! invariant (`cache_bytes_in_use == 0`) holds even when clients vanished
//! mid-stream. There is no SIGINT hook: `std` exposes no signal API and
//! the build is dependency-free by construction, so process signals kill
//! the process the usual way and graceful drain is the `shutdown` frame's
//! job (see DESIGN.md).

use std::collections::{HashMap, VecDeque};
use std::io::Read;
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::Duration;

use anyhow::{Context, Result};

use crate::obs::{Obs, Phase};
use crate::serve::engine::{
    EngineOptions, EngineOutcome, FinishedRequest, RequestSource, ServeEngine, ServeEvent,
};
use crate::serve::fleet::ModelFleet;
use crate::serve::model::SparseModel;
use crate::serve::net::conn::Conn;
use crate::serve::net::protocol::{ClientFrame, FrameDecoder, ServerFrame};
use crate::serve::router::Router;
use crate::serve::scheduler::ServeRequest;

/// Front-door knobs (the engine's own knobs stay in [`EngineOptions`]).
#[derive(Clone, Debug)]
pub struct NetServerOptions {
    /// config label echoed in the `hello` frame
    pub config: String,
    /// vocabulary size: prompts are validated against it on arrival
    pub vocab: usize,
    /// how long a frame write may block before the client counts as gone
    pub write_timeout: Duration,
    /// how long an idle engine step parks on the intake condvar
    pub idle_wait: Duration,
    /// telemetry registry shared with the engine and every connection
    /// (answers the `stats` frame); `None` gets a private real-clock one
    pub obs: Option<Obs>,
}

impl NetServerOptions {
    pub fn new(config: String, vocab: usize) -> NetServerOptions {
        NetServerOptions {
            config,
            vocab,
            write_timeout: Duration::from_secs(5),
            idle_wait: Duration::from_millis(2),
            obs: None,
        }
    }
}

/// One validated client submission waiting for the engine to poll it.
struct Submission {
    req: ServeRequest,
    tag: Option<String>,
    conn: Arc<Conn>,
}

/// Everything the reader threads and the engine share.
struct IntakeState {
    pending: VecDeque<Submission>,
    /// (connection id, request id) cancel frames — ownership is checked
    /// against the submitting connection before they reach the engine
    cancels: Vec<(u64, u64)>,
    /// connections that went away; every live request they own cancels
    dead_conns: Vec<u64>,
    /// stop admitting: readers reject new requests on arrival
    shutdown: bool,
    next_id: u64,
    /// live connections, for closing on drain (readers prune their own)
    conns: Vec<Arc<Conn>>,
}

struct Intake {
    state: Mutex<IntakeState>,
    cv: Condvar,
}

impl Intake {
    fn new() -> Intake {
        Intake {
            state: Mutex::new(IntakeState {
                pending: VecDeque::new(),
                cancels: Vec::new(),
                dead_conns: Vec::new(),
                shutdown: false,
                next_id: 0,
                conns: Vec::new(),
            }),
            cv: Condvar::new(),
        }
    }
}

/// The network as a [`RequestSource`]: live submissions polled between
/// batch steps, disconnects surfaced as cancellation, per-token streaming
/// through each request's connection.
struct NetSource {
    intake: Arc<Intake>,
    idle_wait: Duration,
    /// request id → (owning connection, client tag)
    live: HashMap<u64, (Arc<Conn>, Option<String>)>,
}

impl NetSource {
    fn new(intake: Arc<Intake>, idle_wait: Duration) -> NetSource {
        NetSource { intake, idle_wait, live: HashMap::new() }
    }
}

impl RequestSource for NetSource {
    fn poll(&mut self, _step: usize, _queue_free: usize) -> Vec<ServeRequest> {
        // the network cannot hold remote submissions back, so everything
        // pending is handed over and the engine sheds what does not fit
        let subs: Vec<Submission> = {
            let mut st = self.intake.state.lock().expect("intake lock");
            st.pending.drain(..).collect()
        };
        subs.into_iter()
            .map(|s| {
                self.live.insert(s.req.id, (s.conn, s.tag));
                s.req
            })
            .collect()
    }

    fn take_cancelled(&mut self, _step: usize) -> Vec<u64> {
        let (cancels, dead) = {
            let mut st = self.intake.state.lock().expect("intake lock");
            (std::mem::take(&mut st.cancels), std::mem::take(&mut st.dead_conns))
        };
        let mut out = Vec::new();
        for (conn_id, id) in cancels {
            if let Some((conn, _)) = self.live.get(&id) {
                if conn.id == conn_id {
                    out.push(id);
                }
            }
        }
        for conn_id in dead {
            out.extend(
                self.live
                    .iter()
                    .filter(|(_, (c, _))| c.id == conn_id)
                    .map(|(id, _)| *id),
            );
        }
        out.sort_unstable();
        out.dedup();
        out
    }

    fn closed(&self) -> bool {
        let st = self.intake.state.lock().expect("intake lock");
        st.shutdown && st.pending.is_empty()
    }

    fn accepted(&mut self, req: &ServeRequest) {
        if let Some((conn, tag)) = self.live.get(&req.id) {
            conn.send(&ServerFrame::Accepted { id: req.id, tag: tag.clone() });
        }
    }

    fn rejected(&mut self, req: &ServeRequest, queue: usize, cap: usize) {
        if let Some((conn, tag)) = self.live.remove(&req.id) {
            conn.send(&ServerFrame::Rejected {
                id: req.id,
                tag,
                queue,
                cap,
                message: format!("request queue full ({queue} of {cap})"),
            });
        }
    }

    fn token(&mut self, id: u64, index: usize, token: i32) -> bool {
        match self.live.get(&id) {
            Some((conn, _)) => conn.send(&ServerFrame::Token { id, index, token }),
            None => true,
        }
    }

    fn finished(&mut self, fin: &FinishedRequest) {
        if let Some((conn, _)) = self.live.remove(&fin.id) {
            conn.send(&ServerFrame::Finished {
                id: fin.id,
                tokens: fin.tokens.len(),
                ttft_ms: fin.ttft_secs * 1e3,
                gap_p50_ms: fin.gap_p50_secs * 1e3,
                gap_p95_ms: fin.gap_p95_secs * 1e3,
            });
        }
    }

    fn cancelled(&mut self, id: u64, tokens: usize) {
        if let Some((conn, _)) = self.live.remove(&id) {
            conn.send(&ServerFrame::Cancelled { id, tokens });
        }
    }

    fn idle(&mut self) {
        let st = self.intake.state.lock().expect("intake lock");
        let quiet = st.pending.is_empty()
            && st.cancels.is_empty()
            && st.dead_conns.is_empty()
            && !st.shutdown;
        if quiet {
            // parked until a reader notifies or the wait elapses — the
            // idle engine never busy-spins on an empty intake
            let _ = self.intake.cv.wait_timeout(st, self.idle_wait).expect("intake lock");
        }
    }
}

/// A bound listener ready to serve one engine run.
pub struct NetServer {
    listener: TcpListener,
    local: SocketAddr,
    intake: Arc<Intake>,
    opts: NetServerOptions,
    /// most reader-thread handles the accept loop ever held at once —
    /// pins the opportunistic reaping of finished readers (a long-lived
    /// server must not accumulate handles across short-lived connections)
    reader_peak: Arc<AtomicUsize>,
}

impl NetServer {
    /// Bind `addr` (e.g. `127.0.0.1:0` for an ephemeral port — read the
    /// actual address back with [`NetServer::local_addr`]).
    pub fn bind(addr: &str, opts: NetServerOptions) -> Result<NetServer> {
        let listener =
            TcpListener::bind(addr).with_context(|| format!("binding listener on {addr}"))?;
        let local = listener.local_addr().context("reading bound address")?;
        Ok(NetServer {
            listener,
            local,
            intake: Arc::new(Intake::new()),
            opts,
            reader_peak: Arc::new(AtomicUsize::new(0)),
        })
    }

    pub fn local_addr(&self) -> SocketAddr {
        self.local
    }

    /// Accept clients and run the engine until a `shutdown` frame drains
    /// it. Returns with every spawned thread joined and every connection
    /// closed.
    pub fn serve(
        &self,
        model: &SparseModel,
        engine_opts: EngineOptions,
        on_event: &mut dyn FnMut(&ServeEvent),
    ) -> Result<EngineOutcome> {
        self.serve_with_fleet(model, engine_opts, None, on_event)
    }

    /// [`NetServer::serve`] with a [`ModelFleet`] of named variants
    /// attached: request frames carrying `model=<name>` decode on that
    /// variant, unnamed requests keep the default model.
    pub fn serve_with_fleet(
        &self,
        model: &SparseModel,
        engine_opts: EngineOptions,
        fleet: Option<ModelFleet>,
        on_event: &mut dyn FnMut(&ServeEvent),
    ) -> Result<EngineOutcome> {
        self.with_accept_loop(|source, obs| {
            let mut engine = ServeEngine::new(model, engine_opts).with_obs(obs);
            if let Some(f) = fleet {
                engine = engine.with_fleet(f);
            }
            engine.run_source(source, on_event)
        })
    }

    /// [`NetServer::serve_with_fleet`] fanned out over `replicas` engine
    /// replicas behind the admission [`Router`]: the intake load-balances
    /// by least outstanding tokens, sticky cancels reach the owning
    /// replica, and a submission is rejected only when every replica's
    /// bounded queue is full. `replicas <= 1` keeps the bare engine path.
    pub fn serve_router(
        &self,
        model: &SparseModel,
        engine_opts: EngineOptions,
        replicas: usize,
        fleet: Option<ModelFleet>,
        on_event: &mut dyn FnMut(&ServeEvent),
    ) -> Result<EngineOutcome> {
        if replicas <= 1 {
            return self.serve_with_fleet(model, engine_opts, fleet, on_event);
        }
        self.with_accept_loop(|source, obs| {
            let mut router = Router::new(model, engine_opts, replicas).with_obs(obs);
            if let Some(f) = fleet {
                router = router.with_fleet(f);
            }
            router.run_source(source, on_event).map(|o| o.total)
        })
    }

    /// Shared serve scaffold: spin up the accept thread, hand the
    /// [`NetSource`] to `run` on the caller's thread, then the drain
    /// epilogue — stop accepting, close every connection so its reader
    /// unblocks, and join the whole thread tree.
    fn with_accept_loop(
        &self,
        run: impl FnOnce(&mut NetSource, Obs) -> Result<EngineOutcome>,
    ) -> Result<EngineOutcome> {
        self.listener.set_nonblocking(true).context("nonblocking listener")?;
        let obs = self.opts.obs.clone().unwrap_or_default();
        let done = Arc::new(AtomicBool::new(false));
        let accept_thread = {
            let listener = self.listener.try_clone().context("cloning listener")?;
            let intake = self.intake.clone();
            let opts = self.opts.clone();
            let done = done.clone();
            let obs = obs.clone();
            let reader_peak = self.reader_peak.clone();
            std::thread::spawn(move || accept_loop(listener, intake, opts, done, obs, reader_peak))
        };

        let mut source = NetSource::new(self.intake.clone(), self.opts.idle_wait);
        let outcome = run(&mut source, obs);

        done.store(true, Ordering::SeqCst);
        let conns: Vec<Arc<Conn>> = {
            let mut st = self.intake.state.lock().expect("intake lock");
            st.shutdown = true;
            st.conns.clone()
        };
        for c in &conns {
            c.close();
        }
        accept_thread.join().expect("accept thread panicked");
        outcome
    }
}

fn accept_loop(
    listener: TcpListener,
    intake: Arc<Intake>,
    opts: NetServerOptions,
    done: Arc<AtomicBool>,
    obs: Obs,
    reader_peak: Arc<AtomicUsize>,
) {
    let mut readers: Vec<std::thread::JoinHandle<()>> = Vec::new();
    let mut next_conn = 0u64;
    while !done.load(Ordering::SeqCst) {
        // reap finished readers each tick: joining here keeps the handle
        // list proportional to *live* connections, not to every connection
        // the server ever accepted (join consumes the handle, so this is a
        // swap_remove sweep rather than a retain)
        let mut i = 0;
        while i < readers.len() {
            if readers[i].is_finished() {
                let _ = readers.swap_remove(i).join();
            } else {
                i += 1;
            }
        }
        reader_peak.fetch_max(readers.len(), Ordering::Relaxed);
        match listener.accept() {
            Ok((stream, _peer)) => {
                // accepted sockets do not inherit the listener's
                // nonblocking flag on every platform — pin both halves to
                // the blocking discipline the reader expects
                if stream.set_nonblocking(false).is_err() {
                    continue;
                }
                let _ = stream.set_nodelay(true);
                let _ = stream.set_read_timeout(Some(Duration::from_millis(100)));
                let _ = stream.set_write_timeout(Some(opts.write_timeout));
                let Ok(writer) = stream.try_clone() else { continue };
                let conn = Arc::new(Conn::new(next_conn, writer, obs.clone()));
                next_conn += 1;
                if !conn.send(&ServerFrame::Hello {
                    config: opts.config.clone(),
                    vocab: opts.vocab,
                }) {
                    continue; // died during the greeting
                }
                obs.metrics().connections_open.inc();
                intake.state.lock().expect("intake lock").conns.push(conn.clone());
                let intake = intake.clone();
                let vocab = opts.vocab;
                let obs = obs.clone();
                readers.push(std::thread::spawn(move || {
                    reader_loop(conn, stream, intake, vocab, obs)
                }));
                reader_peak.fetch_max(readers.len(), Ordering::Relaxed);
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                std::thread::sleep(Duration::from_millis(5));
            }
            Err(_) => break,
        }
    }
    for r in readers {
        let _ = r.join();
    }
}

/// Parse one connection's inbound bytes until EOF, error, protocol
/// violation, or server drain; then mark the connection dead and register
/// the disconnect so the engine cancels whatever the client still owned.
fn reader_loop(
    conn: Arc<Conn>,
    mut stream: TcpStream,
    intake: Arc<Intake>,
    vocab: usize,
    obs: Obs,
) {
    let mut dec = FrameDecoder::new();
    let mut buf = [0u8; 4096];
    'read: while conn.is_alive() {
        let t0 = obs.clock().now_ns();
        let n = match stream.read(&mut buf) {
            Ok(0) => break, // EOF: client closed its half
            Ok(n) => n,
            Err(e)
                if matches!(
                    e.kind(),
                    std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut
                ) =>
            {
                continue; // timeout tick: re-check liveness (not a net-read
                          // sample — idle ticks would drown the histogram)
            }
            Err(_) => break,
        };
        obs.record_phase(Phase::NetRead, obs.clock().now_ns().saturating_sub(t0));
        obs.metrics().net_bytes_read_total.add(n as u64);
        let lines = match dec.push(&buf[..n]) {
            Ok(lines) => lines,
            Err(e) => {
                conn.send(&ServerFrame::Error { message: format!("{e}") });
                break;
            }
        };
        for line in lines {
            obs.metrics().net_frames_read_total.inc();
            let frame = match ClientFrame::parse(&line) {
                Ok(f) => f,
                Err(e) => {
                    conn.send(&ServerFrame::Error { message: format!("{e}") });
                    break 'read;
                }
            };
            if !handle_frame(&conn, &intake, vocab, &obs, frame) {
                break 'read;
            }
        }
    }
    conn.close();
    obs.metrics().connections_open.dec();
    {
        let mut st = intake.state.lock().expect("intake lock");
        st.dead_conns.push(conn.id);
        st.conns.retain(|c| c.id != conn.id);
    }
    intake.cv.notify_one();
}

/// Dispatch one parsed frame; returns false when the connection must
/// close (protocol violation).
fn handle_frame(
    conn: &Arc<Conn>,
    intake: &Arc<Intake>,
    vocab: usize,
    obs: &Obs,
    frame: ClientFrame,
) -> bool {
    match frame {
        ClientFrame::Request { tag, prompt, max_new_tokens, seed, model } => {
            if let Some(&t) = prompt.iter().find(|&&t| t < 0 || t as usize >= vocab) {
                conn.send(&ServerFrame::Error {
                    message: format!("prompt token {t} outside the served vocab 0..{vocab}"),
                });
                return false;
            }
            let reply = {
                let mut st = intake.state.lock().expect("intake lock");
                let id = st.next_id;
                st.next_id += 1;
                if st.shutdown {
                    Some(ServerFrame::Rejected {
                        id,
                        tag,
                        queue: 0,
                        cap: 0,
                        message: "server is draining; request not admitted".into(),
                    })
                } else {
                    st.pending.push_back(Submission {
                        req: ServeRequest { id, prompt, max_new_tokens, seed, model },
                        tag,
                        conn: conn.clone(),
                    });
                    None
                }
            };
            match reply {
                Some(r) => {
                    conn.send(&r);
                }
                None => intake.cv.notify_one(),
            }
            true
        }
        ClientFrame::Cancel { id } => {
            intake.state.lock().expect("intake lock").cancels.push((conn.id, id));
            intake.cv.notify_one();
            true
        }
        ClientFrame::Stats => {
            // answered from the reader thread — a consistent snapshot of
            // the shared registry needs no engine round-trip
            conn.send(&ServerFrame::Stats { snapshot: obs.snapshot().to_json() });
            true
        }
        ClientFrame::Shutdown => {
            intake.state.lock().expect("intake lock").shutdown = true;
            intake.cv.notify_all();
            true
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::config::ModelCfg;
    use crate::model::init::init_params;
    use crate::sparse::PackPolicy;

    fn model() -> SparseModel {
        let cfg = ModelCfg::from_dims("net-test", 8, 1, 2, 1, 1, 11, 4);
        SparseModel::from_params(&init_params(&cfg, 0), &PackPolicy::default()).unwrap()
    }

    #[test]
    fn shutdown_frame_drains_an_idle_server() {
        let m = model();
        let srv = NetServer::bind("127.0.0.1:0", NetServerOptions::new("net-test".into(), 11))
            .unwrap();
        let addr = srv.local_addr();
        let client = std::thread::spawn(move || {
            let mut s = TcpStream::connect(addr).unwrap();
            s.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
            // wait for the greeting so the reader thread is certainly up
            let mut dec = FrameDecoder::new();
            let mut buf = [0u8; 256];
            let hello = loop {
                let n = stream_read(&mut s, &mut buf);
                if let Some(line) = dec.push(&buf[..n]).unwrap().into_iter().next() {
                    break ServerFrame::parse(&line).unwrap();
                }
            };
            assert!(matches!(hello, ServerFrame::Hello { vocab: 11, .. }));
            std::io::Write::write_all(&mut s, ClientFrame::Shutdown.encode().as_bytes())
                .unwrap();
        });
        let mut drained = 0;
        let out = srv
            .serve(&m, EngineOptions { temperature: 0.0, top_k: 0, ..Default::default() }, &mut |e| {
                if matches!(e, ServeEvent::Drained { .. }) {
                    drained += 1;
                }
            })
            .unwrap();
        client.join().unwrap();
        assert_eq!(out.finished.len(), 0);
        assert_eq!(out.cancelled, 0);
        assert_eq!(drained, 1);
        assert_eq!(out.cache_bytes_in_use, 0);
    }

    #[test]
    fn sequential_connections_keep_the_reader_handle_list_bounded() {
        // regression: accept_loop used to push every reader handle and only
        // join at drain, so 100 short-lived connections left 100 finished
        // handles resident; opportunistic reaping must keep the list
        // proportional to live connections
        let m = model();
        let srv = NetServer::bind("127.0.0.1:0", NetServerOptions::new("net-test".into(), 11))
            .unwrap();
        let addr = srv.local_addr();
        let peak = srv.reader_peak.clone();
        let client = std::thread::spawn(move || {
            let await_hello = |s: &mut TcpStream| {
                s.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
                let mut dec = FrameDecoder::new();
                let mut buf = [0u8; 256];
                loop {
                    let n = stream_read(s, &mut buf);
                    if let Some(line) = dec.push(&buf[..n]).unwrap().into_iter().next() {
                        let f = ServerFrame::parse(&line).unwrap();
                        assert!(matches!(f, ServerFrame::Hello { .. }));
                        return;
                    }
                }
            };
            for _ in 0..100 {
                let mut s = TcpStream::connect(addr).unwrap();
                await_hello(&mut s);
                // drop cold: the reader sees EOF and exits
            }
            let mut s = TcpStream::connect(addr).unwrap();
            await_hello(&mut s);
            std::io::Write::write_all(&mut s, ClientFrame::Shutdown.encode().as_bytes())
                .unwrap();
        });
        srv.serve(
            &m,
            EngineOptions { temperature: 0.0, top_k: 0, ..Default::default() },
            &mut |_| {},
        )
        .unwrap();
        client.join().unwrap();
        let peak = peak.load(Ordering::SeqCst);
        assert!(
            peak <= 16,
            "reader handle list must stay bounded across 100 sequential \
             connections (peaked at {peak})"
        );
    }

    #[test]
    fn stats_frame_answers_with_a_snapshot() {
        let m = model();
        let mut opts = NetServerOptions::new("net-test".into(), 11);
        let obs = Obs::default();
        opts.obs = Some(obs.clone());
        let srv = NetServer::bind("127.0.0.1:0", opts).unwrap();
        let addr = srv.local_addr();
        let client = std::thread::spawn(move || {
            let mut s = TcpStream::connect(addr).unwrap();
            s.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
            let mut dec = FrameDecoder::new();
            let mut buf = [0u8; 4096];
            let mut frames = Vec::new();
            std::io::Write::write_all(&mut s, ClientFrame::Stats.encode().as_bytes()).unwrap();
            // hello + stats, then shut the server down
            while frames.len() < 2 {
                let n = stream_read(&mut s, &mut buf);
                for line in dec.push(&buf[..n]).unwrap() {
                    frames.push(ServerFrame::parse(&line).unwrap());
                }
            }
            std::io::Write::write_all(&mut s, ClientFrame::Shutdown.encode().as_bytes())
                .unwrap();
            frames
        });
        srv.serve(
            &m,
            EngineOptions { temperature: 0.0, top_k: 0, ..Default::default() },
            &mut |_| {},
        )
        .unwrap();
        let frames = client.join().unwrap();
        assert!(matches!(frames[0], ServerFrame::Hello { .. }));
        match &frames[1] {
            ServerFrame::Stats { snapshot } => {
                let gen = snapshot.get("generation").unwrap().as_f64().unwrap();
                assert!(gen >= 1.0, "stamped snapshot");
                assert!(snapshot.get("tokens_decoded_total").is_ok());
            }
            other => panic!("expected a stats frame, got {other:?}"),
        }
        // the shared registry saw the connection's traffic
        let s = obs.snapshot();
        assert!(s.counter("net_frames_read_total").unwrap() >= 2, "stats + shutdown");
        assert!(s.counter("net_frames_written_total").unwrap() >= 2, "hello + stats");
        assert_eq!(s.gauge("connections_open"), Some(0), "reader exit closed it out");
    }

    fn stream_read(s: &mut TcpStream, buf: &mut [u8]) -> usize {
        loop {
            match s.read(buf) {
                Ok(n) => return n,
                Err(e)
                    if matches!(
                        e.kind(),
                        std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut
                    ) => {}
                Err(e) => panic!("read failed: {e}"),
            }
        }
    }
}
