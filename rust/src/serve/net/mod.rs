//! The network front door: serve packed sparse checkpoints over TCP with
//! per-token streaming, cancellation on disconnect, and 429-style
//! backpressure — the ROADMAP's "network front door with streaming
//! responses" built from `std::net` alone (no async runtime, no new
//! dependencies).
//!
//! * [`protocol`] — the framed newline-delimited-JSON wire format
//!   ([`ClientFrame`] / [`ServerFrame`]) and the read-boundary-proof
//!   [`FrameDecoder`].
//! * [`conn`] — per-connection shared state ([`Conn`]): a locked writer
//!   whose failed writes become cancellations.
//! * [`server`] — [`NetServer`]: the listener, per-connection reader
//!   threads, and the `NetSource` adapter that feeds the engine's
//!   step-driven intake loop.
//! * [`client`] — [`run_client`]: the loopback client the CLI, the
//!   net-parity test, and the CI smoke job drive.

pub mod client;
pub mod conn;
pub mod protocol;
pub mod server;

pub use client::{
    fetch_stats, run_client, send_shutdown, ClientOptions, ClientOutcome, ClientRequest,
};
pub use conn::Conn;
pub use protocol::{ClientFrame, FrameDecoder, ServerFrame, MAX_FRAME_BYTES};
pub use server::{NetServer, NetServerOptions};
