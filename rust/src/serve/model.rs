//! The sparse decode path: a transformer whose prunable linears execute in
//! their packed serving formats (CSR / n:m / dense — see
//! [`crate::sparse::pack`]) instead of dense GEMM.
//!
//! The forward mirrors `runtime/ref_ops.rs` structurally (OPT block, tanh
//! GELU, causal softmax attention, tied LM head) but runs in f32 on the
//! Table-7/8 CPU kernels, which is the whole point: next-token cost scales
//! with surviving weights. All formats share one code path that differs
//! only in the [`PackedMatrix`] dispatch, and the kernels visit surviving
//! weights in the same order — so packed decode is *element-identical* to
//! dense decode of the same pruned parameters (pinned by proptests).

use std::collections::BTreeMap;

use anyhow::{anyhow, bail, Result};

use crate::model::config::ModelCfg;
use crate::model::layout::{FlatParams, LinearKind, PRUNABLE_KINDS};
use crate::model::sparse_store::SparseStore;
use crate::sparse::{dense_layer, PackPolicy, PackedMatrix};
use crate::tensor::Tensor;

const LN_EPS: f32 = 1e-5;
/// sqrt(2/pi) of the tanh GELU approximation (f32 twin of ref_ops).
const GELU_C: f32 = 0.797_884_6;

/// One block's serving-format weights.
struct ServeBlock {
    ln1_g: Vec<f32>,
    ln1_b: Vec<f32>,
    wq: PackedMatrix,
    wk: PackedMatrix,
    wv: PackedMatrix,
    wo: PackedMatrix,
    ln2_g: Vec<f32>,
    ln2_b: Vec<f32>,
    fc1: PackedMatrix,
    fc2: PackedMatrix,
}

/// A model ready to decode through the sparse kernels.
pub struct SparseModel {
    pub cfg: ModelCfg,
    tok_embed: Vec<f32>,
    pos_embed: Vec<f32>,
    lnf_g: Vec<f32>,
    lnf_b: Vec<f32>,
    blocks: Vec<ServeBlock>,
    /// tied LM head: tok_embed as a (vocab, d) matrix, built once
    head: Tensor,
    density: f64,
    format_summary: String,
}

impl SparseModel {
    /// Build from a packed checkpoint without materializing dense linears.
    pub fn from_store(store: &SparseStore, cfg: &ModelCfg) -> Result<SparseModel> {
        if cfg.name != store.config_name {
            bail!(
                "packed checkpoint is for config {:?}, expected {:?}",
                store.config_name,
                cfg.name
            );
        }
        // slice the dense remainder back into named regions (layout order)
        let mut rest: BTreeMap<&str, &[f32]> = BTreeMap::new();
        let mut off = 0usize;
        for e in &cfg.param_layout {
            if PRUNABLE_KINDS.iter().any(|k| k.param_name() == e.name) {
                continue;
            }
            let n = e.numel();
            if off + n > store.rest.len() {
                bail!("packed checkpoint remainder too short for region {:?}", e.name);
            }
            rest.insert(e.name.as_str(), &store.rest[off..off + n]);
            off += n;
        }
        fn region<'a>(rest: &BTreeMap<&str, &'a [f32]>, name: &str) -> Result<&'a [f32]> {
            rest.get(name).copied().ok_or_else(|| anyhow!("missing region {name:?}"))
        }
        fn layer_slice(
            rest: &BTreeMap<&str, &[f32]>,
            layers: usize,
            name: &str,
            l: usize,
        ) -> Result<Vec<f32>> {
            let r = region(rest, name)?;
            let per = r.len() / layers;
            Ok(r[l * per..(l + 1) * per].to_vec())
        }
        let mut matrices: BTreeMap<(usize, &'static str), PackedMatrix> = BTreeMap::new();
        for e in &store.entries {
            let (rows, cols) = e.kind.shape(cfg);
            if e.matrix.rows() != rows || e.matrix.cols() != cols {
                bail!(
                    "layer {} {} is {}x{}, config {} needs {rows}x{cols}",
                    e.layer,
                    e.kind.label(),
                    e.matrix.rows(),
                    e.matrix.cols(),
                    cfg.name
                );
            }
            matrices.insert((e.layer, e.kind.param_name()), e.matrix.clone());
        }
        let mut take = |l: usize, kind: LinearKind| -> Result<PackedMatrix> {
            matrices
                .remove(&(l, kind.param_name()))
                .ok_or_else(|| anyhow!("packed checkpoint missing layer {l} {}", kind.label()))
        };
        let mut blocks = Vec::with_capacity(cfg.layers);
        for l in 0..cfg.layers {
            blocks.push(ServeBlock {
                ln1_g: layer_slice(&rest, cfg.layers, "ln1_g", l)?,
                ln1_b: layer_slice(&rest, cfg.layers, "ln1_b", l)?,
                wq: take(l, LinearKind::Wq)?,
                wk: take(l, LinearKind::Wk)?,
                wv: take(l, LinearKind::Wv)?,
                wo: take(l, LinearKind::Wo)?,
                ln2_g: layer_slice(&rest, cfg.layers, "ln2_g", l)?,
                ln2_b: layer_slice(&rest, cfg.layers, "ln2_b", l)?,
                fc1: take(l, LinearKind::Fc1)?,
                fc2: take(l, LinearKind::Fc2)?,
            });
        }
        let tok_embed = region(&rest, "tok_embed")?.to_vec();
        if tok_embed.len() != cfg.vocab * cfg.d {
            bail!("tok_embed region has {} elements, expected vocab*d", tok_embed.len());
        }
        let head = Tensor::new(vec![cfg.vocab, cfg.d], tok_embed.clone());
        Ok(SparseModel {
            cfg: cfg.clone(),
            tok_embed,
            pos_embed: region(&rest, "pos_embed")?.to_vec(),
            lnf_g: region(&rest, "lnf_g")?.to_vec(),
            lnf_b: region(&rest, "lnf_b")?.to_vec(),
            blocks,
            head,
            density: store.density(),
            format_summary: store.format_summary(),
        })
    }

    /// Pack parameters on the fly and build the serving model.
    pub fn from_params(params: &FlatParams, policy: &PackPolicy) -> Result<SparseModel> {
        let store = SparseStore::pack(params, policy, "in-memory")?;
        SparseModel::from_store(&store, &params.cfg)
    }

    /// Density over the packed prunable weights.
    pub fn density(&self) -> f64 {
        self.density
    }

    /// "csr:10 dense:2"-style pack summary.
    pub fn format_summary(&self) -> &str {
        &self.format_summary
    }

    /// One batched next-token step: `windows` is `batch` concatenated
    /// context windows of exactly `cfg.seq` token ids; returns logits
    /// (batch, vocab) for the last position of each window.
    pub fn decode_step(&self, windows: &[i32], batch: usize) -> Result<Tensor> {
        let cfg = &self.cfg;
        let (seq, d) = (cfg.seq, cfg.d);
        if batch == 0 || windows.len() != batch * seq {
            bail!(
                "decode_step: {} tokens is not {batch} windows of seq={seq}",
                windows.len()
            );
        }
        let rows = batch * seq;
        // ---- embed ----
        let mut x = vec![0.0f32; rows * d];
        for (r, &t) in windows.iter().enumerate() {
            if t < 0 || t as usize >= cfg.vocab {
                bail!("token id {t} out of range (vocab {})", cfg.vocab);
            }
            let te = &self.tok_embed[t as usize * d..(t as usize + 1) * d];
            let pe = &self.pos_embed[(r % seq) * d..(r % seq + 1) * d];
            let xr = &mut x[r * d..(r + 1) * d];
            for i in 0..d {
                xr[i] = te[i] + pe[i];
            }
        }
        // ---- blocks ----
        for blk in &self.blocks {
            let a = layer_norm(&x, d, &blk.ln1_g, &blk.ln1_b);
            let a = Tensor::new(vec![rows, d], a);
            let q = blk.wq.layer(&a);
            let k = blk.wk.layer(&a);
            let v = blk.wv.layer(&a);
            let attn = attention(q.data(), k.data(), v.data(), batch, seq, d, cfg.heads);
            let wo_out = blk.wo.layer(&Tensor::new(vec![rows, d], attn));
            for (xi, oi) in x.iter_mut().zip(wo_out.data()) {
                *xi += oi;
            }
            let u = layer_norm(&x, d, &blk.ln2_g, &blk.ln2_b);
            let z = blk.fc1.layer(&Tensor::new(vec![rows, d], u));
            let g: Vec<f32> = z.data().iter().map(|&zz| gelu(zz)).collect();
            let w2_out = blk.fc2.layer(&Tensor::new(vec![rows, cfg.ffn], g));
            for (xi, oi) in x.iter_mut().zip(w2_out.data()) {
                *xi += oi;
            }
        }
        // ---- final norm + tied head on each window's last position ----
        let h = layer_norm(&x, d, &self.lnf_g, &self.lnf_b);
        let mut last = vec![0.0f32; batch * d];
        for b in 0..batch {
            let r = b * seq + (seq - 1);
            last[b * d..(b + 1) * d].copy_from_slice(&h[r * d..(r + 1) * d]);
        }
        Ok(dense_layer(&Tensor::new(vec![batch, d], last), &self.head))
    }
}

/// Row-wise LayerNorm (f32; cf. the f64 twin in ref_ops).
fn layer_norm(x: &[f32], d: usize, g: &[f32], b: &[f32]) -> Vec<f32> {
    let rows = x.len() / d;
    let mut y = vec![0.0f32; x.len()];
    for r in 0..rows {
        let xr = &x[r * d..(r + 1) * d];
        let mu = xr.iter().sum::<f32>() / d as f32;
        let var = xr.iter().map(|&v| (v - mu) * (v - mu)).sum::<f32>() / d as f32;
        let rstd = 1.0 / (var + LN_EPS).sqrt();
        let yr = &mut y[r * d..(r + 1) * d];
        for i in 0..d {
            yr[i] = (xr[i] - mu) * rstd * g[i] + b[i];
        }
    }
    y
}

fn gelu(z: f32) -> f32 {
    0.5 * z * (1.0 + (GELU_C * (z + 0.044715 * z * z * z)).tanh())
}

/// Causal multi-head attention (f32; heads in contiguous column stripes).
fn attention(
    q: &[f32],
    k: &[f32],
    v: &[f32],
    batch: usize,
    seq: usize,
    d: usize,
    heads: usize,
) -> Vec<f32> {
    let hd = d / heads;
    let scale = 1.0 / (hd as f32).sqrt();
    let mut out = vec![0.0f32; batch * seq * d];
    let mut scores = vec![0.0f32; seq];
    for b in 0..batch {
        for h in 0..heads {
            let hoff = h * hd;
            for t in 0..seq {
                let qoff = (b * seq + t) * d + hoff;
                let qrow = &q[qoff..qoff + hd];
                let mut maxv = f32::NEG_INFINITY;
                for (s, sc) in scores.iter_mut().enumerate().take(t + 1) {
                    let koff = (b * seq + s) * d + hoff;
                    let krow = &k[koff..koff + hd];
                    let mut dot = 0.0f32;
                    for j in 0..hd {
                        dot += qrow[j] * krow[j];
                    }
                    *sc = dot * scale;
                    maxv = maxv.max(*sc);
                }
                let mut denom = 0.0f32;
                for sc in scores.iter_mut().take(t + 1) {
                    *sc = (*sc - maxv).exp();
                    denom += *sc;
                }
                let orow_off = (b * seq + t) * d + hoff;
                for s in 0..=t {
                    let p = scores[s] / denom;
                    if p == 0.0 {
                        continue;
                    }
                    let voff = (b * seq + s) * d + hoff;
                    let vrow = &v[voff..voff + hd];
                    for j in 0..hd {
                        out[orow_off + j] += p * vrow[j];
                    }
                }
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::init::init_params;
    use crate::solver::magnitude::magnitude_prune;
    use crate::sparse::PackFormat;
    use crate::util::prng::Rng;

    fn test_cfg() -> ModelCfg {
        ModelCfg::from_dims("serve-test", 8, 2, 2, 1, 1, 13, 6)
    }

    fn pruned(cfg: &ModelCfg, p: f64, seed: u64) -> FlatParams {
        let mut fp = init_params(cfg, seed);
        for layer in 0..cfg.layers {
            for kind in PRUNABLE_KINDS {
                let mut w = magnitude_prune(&fp.get_linear(kind, layer).unwrap(), p).0;
                // keep one dense 8-wide run so Auto can never pick n:m
                for j in 0..8.min(w.cols()) {
                    w.set2(0, j, 1.0 + j as f32);
                }
                fp.set_linear(kind, layer, &w).unwrap();
            }
        }
        fp
    }

    fn windows(cfg: &ModelCfg, batch: usize, seed: u64) -> Vec<i32> {
        let mut rng = Rng::new(seed);
        (0..batch * cfg.seq).map(|_| rng.below(cfg.vocab) as i32).collect()
    }

    #[test]
    fn packed_decode_is_element_identical_to_dense_decode() {
        let cfg = test_cfg();
        let fp = pruned(&cfg, 0.6, 7);
        let dense = SparseModel::from_params(&fp, &PackPolicy::with_format(PackFormat::Dense))
            .unwrap();
        let csr =
            SparseModel::from_params(&fp, &PackPolicy::with_format(PackFormat::Csr)).unwrap();
        let w = windows(&cfg, 3, 1);
        let a = dense.decode_step(&w, 3).unwrap();
        let b = csr.decode_step(&w, 3).unwrap();
        assert_eq!(a.shape(), &[3, cfg.vocab]);
        assert_eq!(a.data(), b.data());
    }

    #[test]
    fn from_store_matches_from_params() {
        let cfg = test_cfg();
        let fp = pruned(&cfg, 0.5, 3);
        let store = SparseStore::pack(&fp, &PackPolicy::default(), "magnitude-50%").unwrap();
        let m1 = SparseModel::from_store(&store, &cfg).unwrap();
        let m2 = SparseModel::from_params(&fp, &PackPolicy::default()).unwrap();
        let w = windows(&cfg, 2, 9);
        assert_eq!(m1.decode_step(&w, 2).unwrap(), m2.decode_step(&w, 2).unwrap());
        assert_eq!(m1.format_summary(), "csr:12");
        assert!((m1.density() - 0.5).abs() < 0.1);
    }

    #[test]
    fn decode_step_validates_inputs() {
        let cfg = test_cfg();
        let fp = init_params(&cfg, 0);
        let m = SparseModel::from_params(&fp, &PackPolicy::default()).unwrap();
        assert!(m.decode_step(&[0; 5], 1).is_err()); // wrong window length
        assert!(m.decode_step(&[], 0).is_err());
        let mut w = windows(&cfg, 1, 0);
        w[0] = 999; // out-of-vocab token
        assert!(m.decode_step(&w, 1).is_err());
    }

    #[test]
    fn decode_depends_on_last_tokens_causally() {
        // editing the final window token must change logits; editing only
        // the first token of a window also may — but a *different* batch
        // row must never affect another row
        let cfg = test_cfg();
        let fp = pruned(&cfg, 0.5, 5);
        let m = SparseModel::from_params(&fp, &PackPolicy::default()).unwrap();
        let w = windows(&cfg, 2, 11);
        let base = m.decode_step(&w, 2).unwrap();
        let mut w2 = w.clone();
        w2[cfg.seq] = (w2[cfg.seq] + 1) % cfg.vocab as i32; // row 1's first token
        let edited = m.decode_step(&w2, 2).unwrap();
        // row 0 untouched
        assert_eq!(&base.data()[..cfg.vocab], &edited.data()[..cfg.vocab]);
        // row 1 changed
        assert_ne!(&base.data()[cfg.vocab..], &edited.data()[cfg.vocab..]);
    }
}
