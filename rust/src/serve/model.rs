//! The sparse decode path: a transformer whose prunable linears execute in
//! their packed serving formats (CSR / n:m / dense, f32 or quantized —
//! see [`crate::sparse::pack`]) instead of dense GEMM. Quantized linears
//! run through the dequant-fused kernels of [`crate::sparse::quant`]: no
//! f32 weight matrix is materialized, and decode is element-identical to
//! quantize-then-dense-decode (pinned by `tests/quant_parity.rs`).
//!
//! The forward mirrors `runtime/ref_ops.rs` structurally (OPT block, tanh
//! GELU, softmax attention, tied LM head) but runs in f32 on the
//! Table-7/8 CPU kernels, which is the whole point: next-token cost scales
//! with surviving weights. All formats share one code path that differs
//! only in the [`PackedMatrix`] dispatch, and the kernels visit surviving
//! weights in the same order — so packed decode is *element-identical* to
//! dense decode of the same pruned parameters (pinned by proptests).
//!
//! Serving semantics (shared by both decode paths): a request's context is
//! its prompt plus everything generated, at absolute positions 0, 1, 2, …;
//! the token at position `p` carries `pos_embed[p % seq]` and attends over
//! the sliding window `max(0, p-seq+1) ..= p` (banded causal attention).
//! Two executions of that definition exist:
//!
//! * [`SparseModel::forward_logits`] — the **uncached reference path**: a
//!   full re-forward of each context, O(ctx · layers) per token;
//! * [`SparseModel::prefill`] + [`SparseModel::decode_cached`] — the
//!   **incremental path**: key/value rows live in a per-request
//!   [`KvCache`] ring buffer, so a decode step runs each new token through
//!   the packed linears once, O(layers) per token.
//!
//! Both paths perform identical f32 operations in identical order per row
//! (same kernels, same banded window iterated oldest → newest), so cached
//! decode is *token-for-token identical* to the uncached re-forward —
//! including after ring eviction, because eviction drops exactly the
//! positions that leave the band (pinned by `tests/serve_kv_parity.rs`).

use std::collections::BTreeMap;

use anyhow::{anyhow, bail, Result};

use crate::model::config::ModelCfg;
use crate::model::layout::{FlatParams, LinearKind, PRUNABLE_KINDS};
use crate::model::sparse_store::SparseStore;
use crate::serve::kv::KvCache;
use crate::sparse::{dense_layer, PackPolicy, PackedMatrix};
use crate::tensor::Tensor;

const LN_EPS: f32 = 1e-5;
/// sqrt(2/pi) of the tanh GELU approximation (f32 twin of ref_ops).
const GELU_C: f32 = 0.797_884_6;

/// One block's serving-format weights.
struct ServeBlock {
    ln1_g: Vec<f32>,
    ln1_b: Vec<f32>,
    wq: PackedMatrix,
    wk: PackedMatrix,
    wv: PackedMatrix,
    wo: PackedMatrix,
    ln2_g: Vec<f32>,
    ln2_b: Vec<f32>,
    fc1: PackedMatrix,
    fc2: PackedMatrix,
}

/// A model ready to decode through the sparse kernels.
pub struct SparseModel {
    pub cfg: ModelCfg,
    tok_embed: Vec<f32>,
    pos_embed: Vec<f32>,
    lnf_g: Vec<f32>,
    lnf_b: Vec<f32>,
    blocks: Vec<ServeBlock>,
    /// tied LM head: tok_embed as a (vocab, d) matrix, built once
    head: Tensor,
    density: f64,
    format_summary: String,
    effective_bits: f64,
    /// total packed weight-stream bytes behind the prunable linears
    weight_bytes: u64,
    /// how many of those bytes are zero-copy views into a mapped `.spkt`
    mapped_bytes: u64,
}

impl SparseModel {
    /// Build from a packed checkpoint without materializing dense linears.
    pub fn from_store(store: &SparseStore, cfg: &ModelCfg) -> Result<SparseModel> {
        if cfg.name != store.config_name {
            bail!(
                "packed checkpoint is for config {:?}, expected {:?}",
                store.config_name,
                cfg.name
            );
        }
        // a degenerate config would hit zero-sized rings and
        // divide-by-zero position math deep in the decode path — reject
        // it here with a message that names the field
        for (v, what) in [
            (cfg.d, "model width d"),
            (cfg.layers, "layer count"),
            (cfg.seq, "context length seq"),
            (cfg.vocab, "vocab size"),
        ] {
            if v == 0 {
                bail!("config {:?} has zero {what}; cannot serve", cfg.name);
            }
        }
        // slice the dense remainder back into named regions (layout order)
        let mut rest: BTreeMap<&str, &[f32]> = BTreeMap::new();
        let mut off = 0usize;
        for e in &cfg.param_layout {
            if PRUNABLE_KINDS.iter().any(|k| k.param_name() == e.name) {
                continue;
            }
            let n = e.numel();
            if off + n > store.rest.len() {
                bail!("packed checkpoint remainder too short for region {:?}", e.name);
            }
            rest.insert(e.name.as_str(), &store.rest[off..off + n]);
            off += n;
        }
        fn region<'a>(rest: &BTreeMap<&str, &'a [f32]>, name: &str) -> Result<&'a [f32]> {
            rest.get(name).copied().ok_or_else(|| anyhow!("missing region {name:?}"))
        }
        fn layer_slice(
            rest: &BTreeMap<&str, &[f32]>,
            layers: usize,
            name: &str,
            l: usize,
        ) -> Result<Vec<f32>> {
            let r = region(rest, name)?;
            let per = r.len() / layers;
            Ok(r[l * per..(l + 1) * per].to_vec())
        }
        let mut matrices: BTreeMap<(usize, &'static str), PackedMatrix> = BTreeMap::new();
        for e in &store.entries {
            let (rows, cols) = e.kind.shape(cfg);
            if e.matrix.rows() != rows || e.matrix.cols() != cols {
                bail!(
                    "layer {} {} is {}x{}, config {} needs {rows}x{cols}",
                    e.layer,
                    e.kind.label(),
                    e.matrix.rows(),
                    e.matrix.cols(),
                    cfg.name
                );
            }
            matrices.insert((e.layer, e.kind.param_name()), e.matrix.clone());
        }
        let mut take = |l: usize, kind: LinearKind| -> Result<PackedMatrix> {
            matrices
                .remove(&(l, kind.param_name()))
                .ok_or_else(|| anyhow!("packed checkpoint missing layer {l} {}", kind.label()))
        };
        let mut blocks = Vec::with_capacity(cfg.layers);
        for l in 0..cfg.layers {
            blocks.push(ServeBlock {
                ln1_g: layer_slice(&rest, cfg.layers, "ln1_g", l)?,
                ln1_b: layer_slice(&rest, cfg.layers, "ln1_b", l)?,
                wq: take(l, LinearKind::Wq)?,
                wk: take(l, LinearKind::Wk)?,
                wv: take(l, LinearKind::Wv)?,
                wo: take(l, LinearKind::Wo)?,
                ln2_g: layer_slice(&rest, cfg.layers, "ln2_g", l)?,
                ln2_b: layer_slice(&rest, cfg.layers, "ln2_b", l)?,
                fc1: take(l, LinearKind::Fc1)?,
                fc2: take(l, LinearKind::Fc2)?,
            });
        }
        let tok_embed = region(&rest, "tok_embed")?.to_vec();
        if tok_embed.len() != cfg.vocab * cfg.d {
            bail!("tok_embed region has {} elements, expected vocab*d", tok_embed.len());
        }
        let head = Tensor::new(vec![cfg.vocab, cfg.d], tok_embed.clone());
        Ok(SparseModel {
            cfg: cfg.clone(),
            tok_embed,
            pos_embed: region(&rest, "pos_embed")?.to_vec(),
            lnf_g: region(&rest, "lnf_g")?.to_vec(),
            lnf_b: region(&rest, "lnf_b")?.to_vec(),
            blocks,
            head,
            density: store.density(),
            format_summary: store.format_summary(),
            effective_bits: store.effective_bits(),
            weight_bytes: store.payload_bytes(),
            mapped_bytes: store.mapped_bytes(),
        })
    }

    /// Pack parameters on the fly and build the serving model.
    pub fn from_params(params: &FlatParams, policy: &PackPolicy) -> Result<SparseModel> {
        let store = SparseStore::pack(params, policy, "in-memory")?;
        SparseModel::from_store(&store, &params.cfg)
    }

    /// Density over the packed prunable weights.
    pub fn density(&self) -> f64 {
        self.density
    }

    /// "csr:10 dense:2"-style pack summary.
    pub fn format_summary(&self) -> &str {
        &self.format_summary
    }

    /// Size-weighted storage bits per packed weight (Fig.-6 accounting):
    /// 3.0 for the 50%-sparse 4-bit configuration the paper highlights.
    pub fn effective_bits(&self) -> f64 {
        self.effective_bits
    }

    /// Packed weight-stream bytes behind the prunable linears (the
    /// fleet-residency budget unit).
    pub fn weight_bytes(&self) -> u64 {
        self.weight_bytes
    }

    /// How many of those bytes are served straight from mapped `.spkt`
    /// pages (0 for owned loads and in-memory packs).
    pub fn mapped_bytes(&self) -> u64 {
        self.mapped_bytes
    }

    /// A fresh per-request KV cache sized for this model (one ring of
    /// `cfg.seq` K/V rows per layer).
    pub fn new_cache(&self) -> KvCache {
        KvCache::new(self.cfg.layers, self.cfg.d, self.cfg.seq)
    }

    /// Heap bytes one request's KV cache pins (the cache-budget unit).
    pub fn cache_bytes(&self) -> u64 {
        KvCache::bytes_for(self.cfg.layers, self.cfg.d, self.cfg.seq)
    }

    fn check_token(&self, t: i32) -> Result<usize> {
        if t < 0 || t as usize >= self.cfg.vocab {
            bail!("token id {t} out of range (vocab {})", self.cfg.vocab);
        }
        Ok(t as usize)
    }

    fn check_cache(&self, cache: &KvCache) -> Result<()> {
        if cache.capacity() != self.cfg.seq || cache.bytes() != self.cache_bytes() {
            bail!(
                "KV cache was sized for a different model (capacity {}, expected {})",
                cache.capacity(),
                self.cfg.seq
            );
        }
        Ok(())
    }

    /// Embed `tokens` starting at absolute position `first_pos` into a
    /// `rows x d` activation buffer appended to `x`.
    fn embed_rows(&self, tokens: &[i32], first_pos: usize, x: &mut [f32]) -> Result<()> {
        let d = self.cfg.d;
        for (i, &t) in tokens.iter().enumerate() {
            let t = self.check_token(t)?;
            let pos = (first_pos + i) % self.cfg.seq;
            let te = &self.tok_embed[t * d..(t + 1) * d];
            let pe = &self.pos_embed[pos * d..(pos + 1) * d];
            let xr = &mut x[i * d..(i + 1) * d];
            for j in 0..d {
                xr[j] = te[j] + pe[j];
            }
        }
        Ok(())
    }

    /// The row-local second half of a block (everything after attention):
    /// Wo + residual, LN2, FC1, GELU, FC2 + residual.
    fn block_tail(&self, blk: &ServeBlock, rows: usize, attn: Vec<f32>, x: &mut [f32]) {
        let d = self.cfg.d;
        let wo_out = blk.wo.layer(&Tensor::new(vec![rows, d], attn));
        for (xi, oi) in x.iter_mut().zip(wo_out.data()) {
            *xi += oi;
        }
        let u = layer_norm(x, d, &blk.ln2_g, &blk.ln2_b);
        let z = blk.fc1.layer(&Tensor::new(vec![rows, d], u));
        let g: Vec<f32> = z.data().iter().map(|&zz| gelu(zz)).collect();
        let w2_out = blk.fc2.layer(&Tensor::new(vec![rows, self.cfg.ffn], g));
        for (xi, oi) in x.iter_mut().zip(w2_out.data()) {
            *xi += oi;
        }
    }

    /// **Uncached reference path**: run each request's full context through
    /// the model with banded causal attention (window `cfg.seq`) and return
    /// next-token logits `(batch, vocab)` for the last position of each.
    /// O(ctx · layers) per call — [`prefill`]/[`decode_cached`] compute the
    /// exact same logits incrementally.
    ///
    /// [`prefill`]: SparseModel::prefill
    /// [`decode_cached`]: SparseModel::decode_cached
    pub fn forward_logits(&self, seqs: &[&[i32]]) -> Result<Tensor> {
        let cfg = &self.cfg;
        let (cap, d) = (cfg.seq, cfg.d);
        if seqs.is_empty() || seqs.iter().any(|s| s.is_empty()) {
            bail!("forward_logits needs at least one non-empty token sequence");
        }
        let rows: usize = seqs.iter().map(|s| s.len()).sum();
        // ---- embed (positions are absolute within each sequence) ----
        let mut x = vec![0.0f32; rows * d];
        let mut off = 0;
        for s in seqs {
            self.embed_rows(s, 0, &mut x[off * d..(off + s.len()) * d])?;
            off += s.len();
        }
        // ---- blocks ----
        for blk in &self.blocks {
            let a = layer_norm(&x, d, &blk.ln1_g, &blk.ln1_b);
            let a = Tensor::new(vec![rows, d], a);
            let q = blk.wq.layer(&a);
            let k = blk.wk.layer(&a);
            let v = blk.wv.layer(&a);
            let mut attn = vec![0.0f32; rows * d];
            let mut off = 0;
            for s in seqs {
                let n = s.len();
                let (lo, hi) = (off * d, (off + n) * d);
                attention_banded(
                    &q.data()[lo..hi],
                    &k.data()[lo..hi],
                    &v.data()[lo..hi],
                    n,
                    d,
                    cfg.heads,
                    cap,
                    &mut attn[lo..hi],
                );
                off += n;
            }
            self.block_tail(blk, rows, attn, &mut x);
        }
        // ---- final norm + tied head on each sequence's last position ----
        let h = layer_norm(&x, d, &self.lnf_g, &self.lnf_b);
        let mut last = vec![0.0f32; seqs.len() * d];
        let mut off = 0;
        for (b, s) in seqs.iter().enumerate() {
            let r = off + s.len() - 1;
            last[b * d..(b + 1) * d].copy_from_slice(&h[r * d..(r + 1) * d]);
            off += s.len();
        }
        Ok(dense_layer(&Tensor::new(vec![seqs.len(), d], last), &self.head))
    }

    /// **Chunked prefill**: stream `tokens` (absolute positions continuing
    /// from `cache.next_pos()`) through the model in chunks of at most
    /// `chunk` rows (0 = one chunk), populating the cache, and return the
    /// logits at the last position plus the number of ring entries evicted.
    /// The chunking is numerically invisible: any chunk size produces the
    /// same cache contents and logits.
    pub fn prefill(
        &self,
        tokens: &[i32],
        cache: &mut KvCache,
        chunk: usize,
    ) -> Result<(Vec<f32>, usize)> {
        if tokens.is_empty() {
            bail!("prefill needs at least one token");
        }
        self.check_cache(cache)?;
        let chunk = if chunk == 0 { tokens.len() } else { chunk };
        let mut evicted = 0usize;
        let mut last = Vec::new();
        for c in tokens.chunks(chunk) {
            let (logits, ev) = self.run_chunk_cached(c, cache)?;
            evicted += ev;
            last = logits;
        }
        Ok((last, evicted))
    }

    /// One chunk of consecutive tokens through all blocks, appending every
    /// row's K/V to the cache. Writes interleave with attention row by row
    /// so a row never reads a slot that a *later* row of the same chunk
    /// will reuse; [`KvCache::commit`] advances the clock once at the end.
    fn run_chunk_cached(
        &self,
        tokens: &[i32],
        cache: &mut KvCache,
    ) -> Result<(Vec<f32>, usize)> {
        let cfg = &self.cfg;
        let (n, d) = (tokens.len(), cfg.d);
        let p0 = cache.next_pos();
        let mut x = vec![0.0f32; n * d];
        self.embed_rows(tokens, p0, &mut x)?;
        let mut scores = vec![0.0f32; cfg.seq];
        for (l, blk) in self.blocks.iter().enumerate() {
            let a = layer_norm(&x, d, &blk.ln1_g, &blk.ln1_b);
            let a = Tensor::new(vec![n, d], a);
            let q = blk.wq.layer(&a);
            let k = blk.wk.layer(&a);
            let v = blk.wv.layer(&a);
            let mut attn = vec![0.0f32; n * d];
            for i in 0..n {
                cache.write(l, p0 + i, k.row(i), v.row(i));
                attention_cached(
                    q.row(i),
                    cache,
                    l,
                    p0 + i,
                    cfg.heads,
                    &mut scores,
                    &mut attn[i * d..(i + 1) * d],
                );
            }
            self.block_tail(blk, n, attn, &mut x);
        }
        let evicted = cache.commit(n);
        let h = layer_norm(&x[(n - 1) * d..], d, &self.lnf_g, &self.lnf_b);
        let logits = dense_layer(&Tensor::new(vec![1, d], h), &self.head);
        Ok((logits.into_data(), evicted))
    }

    /// **Incremental decode**: one batched next-token step — `tokens[i]` is
    /// request `i`'s newest token, appended to `caches[i]` and attended
    /// against its cached keys/values. Returns logits `(batch, vocab)` and
    /// the per-request eviction counts. O(layers) per token: the packed
    /// linears see one row per request instead of a full context.
    pub fn decode_cached(
        &self,
        tokens: &[i32],
        caches: &mut [&mut KvCache],
    ) -> Result<(Tensor, Vec<usize>)> {
        let cfg = &self.cfg;
        let (b, d) = (tokens.len(), cfg.d);
        if b == 0 || caches.len() != b {
            bail!("decode_cached: {} tokens for {} caches", tokens.len(), caches.len());
        }
        let mut x = vec![0.0f32; b * d];
        for (i, &t) in tokens.iter().enumerate() {
            self.check_cache(caches[i])?;
            self.embed_rows(&[t], caches[i].next_pos(), &mut x[i * d..(i + 1) * d])?;
        }
        let mut scores = vec![0.0f32; cfg.seq];
        for (l, blk) in self.blocks.iter().enumerate() {
            let a = layer_norm(&x, d, &blk.ln1_g, &blk.ln1_b);
            let a = Tensor::new(vec![b, d], a);
            let q = blk.wq.layer(&a);
            let k = blk.wk.layer(&a);
            let v = blk.wv.layer(&a);
            let mut attn = vec![0.0f32; b * d];
            for i in 0..b {
                let pos = caches[i].next_pos();
                caches[i].write(l, pos, k.row(i), v.row(i));
                attention_cached(
                    q.row(i),
                    &*caches[i],
                    l,
                    pos,
                    cfg.heads,
                    &mut scores,
                    &mut attn[i * d..(i + 1) * d],
                );
            }
            self.block_tail(blk, b, attn, &mut x);
        }
        let evictions: Vec<usize> = caches.iter_mut().map(|c| c.commit(1)).collect();
        let h = layer_norm(&x, d, &self.lnf_g, &self.lnf_b);
        let logits = dense_layer(&Tensor::new(vec![b, d], h), &self.head);
        Ok((logits, evictions))
    }
}

/// Row-wise LayerNorm (f32; cf. the f64 twin in ref_ops).
fn layer_norm(x: &[f32], d: usize, g: &[f32], b: &[f32]) -> Vec<f32> {
    let rows = x.len() / d;
    let mut y = vec![0.0f32; x.len()];
    for r in 0..rows {
        let xr = &x[r * d..(r + 1) * d];
        let mu = xr.iter().sum::<f32>() / d as f32;
        let var = xr.iter().map(|&v| (v - mu) * (v - mu)).sum::<f32>() / d as f32;
        let rstd = 1.0 / (var + LN_EPS).sqrt();
        let yr = &mut y[r * d..(r + 1) * d];
        for i in 0..d {
            yr[i] = (xr[i] - mu) * rstd * g[i] + b[i];
        }
    }
    y
}

fn gelu(z: f32) -> f32 {
    0.5 * z * (1.0 + (GELU_C * (z + 0.044715 * z * z * z)).tanh())
}

#[inline]
fn dot(a: &[f32], b: &[f32]) -> f32 {
    let mut s = 0.0f32;
    for j in 0..a.len() {
        s += a[j] * b[j];
    }
    s
}

/// Banded causal multi-head attention over one contiguous segment of `n`
/// rows: row `t` attends positions `max(0, t-cap+1) ..= t`, oldest first.
/// The cached twin ([`attention_cached`]) performs these exact operations
/// in this exact order against ring-buffered K/V — keep them in lockstep.
#[allow(clippy::too_many_arguments)]
fn attention_banded(
    q: &[f32],
    k: &[f32],
    v: &[f32],
    n: usize,
    d: usize,
    heads: usize,
    cap: usize,
    out: &mut [f32],
) {
    let hd = d / heads;
    let scale = 1.0 / (hd as f32).sqrt();
    let mut scores = vec![0.0f32; cap.min(n)];
    for h in 0..heads {
        let hoff = h * hd;
        for t in 0..n {
            let start = t.saturating_sub(cap - 1);
            let w = t + 1 - start;
            let qrow = &q[t * d + hoff..t * d + hoff + hd];
            let mut maxv = f32::NEG_INFINITY;
            for (j, s) in (start..=t).enumerate() {
                let krow = &k[s * d + hoff..s * d + hoff + hd];
                let sc = dot(qrow, krow) * scale;
                scores[j] = sc;
                maxv = maxv.max(sc);
            }
            let mut denom = 0.0f32;
            for sc in scores.iter_mut().take(w) {
                *sc = (*sc - maxv).exp();
                denom += *sc;
            }
            let orow = &mut out[t * d + hoff..t * d + hoff + hd];
            for (j, s) in (start..=t).enumerate() {
                let p = scores[j] / denom;
                if p == 0.0 {
                    continue;
                }
                let vrow = &v[s * d + hoff..s * d + hoff + hd];
                for jj in 0..hd {
                    orow[jj] += p * vrow[jj];
                }
            }
        }
    }
}

/// Cache-backed attention for one query row at absolute position `pos`:
/// the incremental twin of [`attention_banded`] — identical window,
/// identical operation order, K/V read from the ring buffer.
fn attention_cached(
    q_row: &[f32],
    cache: &KvCache,
    layer: usize,
    pos: usize,
    heads: usize,
    scores: &mut [f32],
    out_row: &mut [f32],
) {
    let d = q_row.len();
    let hd = d / heads;
    let scale = 1.0 / (hd as f32).sqrt();
    let start = cache.window_start(pos);
    let w = pos + 1 - start;
    for h in 0..heads {
        let hoff = h * hd;
        let qrow = &q_row[hoff..hoff + hd];
        let mut maxv = f32::NEG_INFINITY;
        for (j, s) in (start..=pos).enumerate() {
            let krow = &cache.k_row(layer, s)[hoff..hoff + hd];
            let sc = dot(qrow, krow) * scale;
            scores[j] = sc;
            maxv = maxv.max(sc);
        }
        let mut denom = 0.0f32;
        for sc in scores.iter_mut().take(w) {
            *sc = (*sc - maxv).exp();
            denom += *sc;
        }
        let orow = &mut out_row[hoff..hoff + hd];
        for (j, s) in (start..=pos).enumerate() {
            let p = scores[j] / denom;
            if p == 0.0 {
                continue;
            }
            let vrow = &cache.v_row(layer, s)[hoff..hoff + hd];
            for jj in 0..hd {
                orow[jj] += p * vrow[jj];
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::init::init_params;
    use crate::solver::magnitude::magnitude_prune;
    use crate::sparse::PackFormat;
    use crate::util::prng::Rng;

    fn test_cfg() -> ModelCfg {
        ModelCfg::from_dims("serve-test", 8, 2, 2, 1, 1, 13, 6)
    }

    fn pruned(cfg: &ModelCfg, p: f64, seed: u64) -> FlatParams {
        let mut fp = init_params(cfg, seed);
        for layer in 0..cfg.layers {
            for kind in PRUNABLE_KINDS {
                let mut w = magnitude_prune(&fp.get_linear(kind, layer).unwrap(), p).0;
                // keep one dense 8-wide run so Auto can never pick n:m
                for j in 0..8.min(w.cols()) {
                    w.set2(0, j, 1.0 + j as f32);
                }
                fp.set_linear(kind, layer, &w).unwrap();
            }
        }
        fp
    }

    fn tokens(cfg: &ModelCfg, n: usize, seed: u64) -> Vec<i32> {
        let mut rng = Rng::new(seed);
        (0..n).map(|_| rng.below(cfg.vocab) as i32).collect()
    }

    /// Drive the incremental path over a whole context: prefill everything
    /// but the last token, then decode it — returns the final logits.
    fn incremental_logits(m: &SparseModel, ctx: &[i32], chunk: usize) -> Vec<f32> {
        let mut cache = m.new_cache();
        if ctx.len() == 1 {
            return m.prefill(ctx, &mut cache, chunk).unwrap().0;
        }
        m.prefill(&ctx[..ctx.len() - 1], &mut cache, chunk).unwrap();
        let (logits, _) = m
            .decode_cached(&[ctx[ctx.len() - 1]], &mut [&mut cache])
            .unwrap();
        logits.into_data()
    }

    #[test]
    fn packed_decode_is_element_identical_to_dense_decode() {
        let cfg = test_cfg();
        let fp = pruned(&cfg, 0.6, 7);
        let dense = SparseModel::from_params(&fp, &PackPolicy::with_format(PackFormat::Dense))
            .unwrap();
        let csr =
            SparseModel::from_params(&fp, &PackPolicy::with_format(PackFormat::Csr)).unwrap();
        // mixed context lengths, including one past the attention window
        let (s0, s1, s2) = (tokens(&cfg, 3, 1), tokens(&cfg, cfg.seq, 2), tokens(&cfg, 9, 3));
        let seqs: Vec<&[i32]> = vec![&s0, &s1, &s2];
        let a = dense.forward_logits(&seqs).unwrap();
        let b = csr.forward_logits(&seqs).unwrap();
        assert_eq!(a.shape(), &[3, cfg.vocab]);
        assert_eq!(a.data(), b.data());
    }

    #[test]
    fn quantized_decode_matches_quantize_then_dense_decode() {
        // module-level spot check of the quant contract (the broad
        // differential sweep lives in tests/quant_parity.rs): a q4 CSR
        // model decodes element-identically to the model built from the
        // same weights quantized on the same grid and packed dense
        use crate::solver::quant::QuantGrid;
        let cfg = test_cfg();
        let fp = pruned(&cfg, 0.6, 23);
        let q = SparseModel::from_params(
            &fp,
            &PackPolicy::with_format(PackFormat::QCsr { bits: 4, group: 0 }),
        )
        .unwrap();
        let mut reference = fp.clone();
        for layer in 0..cfg.layers {
            for kind in PRUNABLE_KINDS {
                let w = fp.get_linear(kind, layer).unwrap();
                let grid = QuantGrid::from_weights_grouped(&w, 15, 0);
                reference.set_linear(kind, layer, &grid.quantize_surviving(&w)).unwrap();
            }
        }
        let d = SparseModel::from_params(&reference, &PackPolicy::with_format(PackFormat::Dense))
            .unwrap();
        let (s0, s1) = (tokens(&cfg, 5, 31), tokens(&cfg, cfg.seq + 2, 32));
        let seqs: Vec<&[i32]> = vec![&s0, &s1];
        let (want, got) = (d.forward_logits(&seqs).unwrap(), q.forward_logits(&seqs).unwrap());
        assert_eq!(want.data(), got.data());
        assert_eq!(q.format_summary(), "qcsr:12");
        assert!((q.effective_bits() - (q.density() * 4.0 + 1.0)).abs() < 1e-9);
    }

    #[test]
    fn from_store_matches_from_params() {
        let cfg = test_cfg();
        let fp = pruned(&cfg, 0.5, 3);
        let store = SparseStore::pack(&fp, &PackPolicy::default(), "magnitude-50%").unwrap();
        let m1 = SparseModel::from_store(&store, &cfg).unwrap();
        let m2 = SparseModel::from_params(&fp, &PackPolicy::default()).unwrap();
        let (s0, s1) = (tokens(&cfg, 5, 9), tokens(&cfg, 7, 10));
        let seqs: Vec<&[i32]> = vec![&s0, &s1];
        assert_eq!(m1.forward_logits(&seqs).unwrap(), m2.forward_logits(&seqs).unwrap());
        assert_eq!(m1.format_summary(), "csr:12");
        assert!((m1.density() - 0.5).abs() < 0.1);
    }

    #[test]
    fn cached_decode_matches_uncached_reforward() {
        // the tentpole invariant at model level: prefill + incremental
        // decode equals the banded full re-forward bit-for-bit, for every
        // chunk size and far past the eviction horizon (seq = 6 here)
        let cfg = test_cfg();
        let fp = pruned(&cfg, 0.5, 21);
        let m = SparseModel::from_params(&fp, &PackPolicy::default()).unwrap();
        let ctx = tokens(&cfg, 4 * cfg.seq + 1, 5);
        for len in [1, 2, cfg.seq, cfg.seq + 1, 2 * cfg.seq + 3, ctx.len()] {
            let want = m.forward_logits(&[&ctx[..len]]).unwrap();
            for chunk in [1, 2, 4, 0] {
                let got = incremental_logits(&m, &ctx[..len], chunk);
                assert_eq!(want.data(), &got[..], "len {len} chunk {chunk}");
            }
        }
    }

    #[test]
    fn prefill_reports_evictions_and_chunking_is_invisible() {
        let cfg = test_cfg();
        let m = SparseModel::from_params(&init_params(&cfg, 0), &PackPolicy::default()).unwrap();
        let ctx = tokens(&cfg, cfg.seq + 4, 11);
        let mut c1 = m.new_cache();
        let (l1, ev1) = m.prefill(&ctx, &mut c1, 0).unwrap();
        let mut c2 = m.new_cache();
        let (l2, ev2) = m.prefill(&ctx, &mut c2, 3).unwrap();
        assert_eq!(ev1, 4, "seq+4 tokens into a seq ring evict 4");
        assert_eq!(ev1, ev2);
        assert_eq!(l1, l2);
        assert_eq!(c1.len(), cfg.seq);
        assert_eq!(c1.next_pos(), cfg.seq + 4);
    }

    #[test]
    fn decode_cached_is_batch_order_independent() {
        // a request's logits depend only on its own cache, not on which
        // other requests share the batched step
        let cfg = test_cfg();
        let fp = pruned(&cfg, 0.5, 13);
        let m = SparseModel::from_params(&fp, &PackPolicy::default()).unwrap();
        let (a, b) = (tokens(&cfg, 5, 1), tokens(&cfg, 8, 2));
        let mk = |ctx: &[i32]| {
            let mut c = m.new_cache();
            m.prefill(ctx, &mut c, 2).unwrap();
            c
        };
        let (mut ca, mut cb) = (mk(&a), mk(&b));
        let (batched, _) = m.decode_cached(&[3, 4], &mut [&mut ca, &mut cb]).unwrap();
        let (mut ca2, mut cb2) = (mk(&a), mk(&b));
        let (solo_a, _) = m.decode_cached(&[3], &mut [&mut ca2]).unwrap();
        let (solo_b, _) = m.decode_cached(&[4], &mut [&mut cb2]).unwrap();
        assert_eq!(&batched.data()[..cfg.vocab], solo_a.data());
        assert_eq!(&batched.data()[cfg.vocab..], solo_b.data());
    }

    #[test]
    fn inputs_are_validated() {
        let cfg = test_cfg();
        let m = SparseModel::from_params(&init_params(&cfg, 0), &PackPolicy::default()).unwrap();
        assert!(m.forward_logits(&[]).is_err());
        assert!(m.forward_logits(&[&[][..]]).is_err());
        assert!(m.forward_logits(&[&[999][..]]).is_err()); // out-of-vocab
        let mut cache = m.new_cache();
        assert!(m.prefill(&[], &mut cache, 0).is_err());
        assert!(m.prefill(&[999], &mut cache, 0).is_err());
        assert!(m.decode_cached(&[], &mut []).is_err());
        let mut wrong = KvCache::new(cfg.layers, cfg.d, cfg.seq + 1);
        assert!(m.prefill(&[0], &mut wrong, 0).is_err(), "mis-sized cache rejected");
    }

    #[test]
    fn batch_rows_are_independent_and_causal() {
        // editing one sequence must not perturb another's logits row
        let cfg = test_cfg();
        let fp = pruned(&cfg, 0.5, 5);
        let m = SparseModel::from_params(&fp, &PackPolicy::default()).unwrap();
        let (s0, mut s1) = (tokens(&cfg, 6, 11), tokens(&cfg, 6, 12));
        let base = m.forward_logits(&[&s0, &s1]).unwrap();
        s1[0] = (s1[0] + 1) % cfg.vocab as i32;
        let edited = m.forward_logits(&[&s0, &s1]).unwrap();
        assert_eq!(&base.data()[..cfg.vocab], &edited.data()[..cfg.vocab]);
        assert_ne!(&base.data()[cfg.vocab..], &edited.data()[cfg.vocab..]);
    }

    #[test]
    fn eviction_forgets_tokens_outside_the_window() {
        // once a token leaves the band, it cannot influence the next logits
        let cfg = test_cfg();
        let fp = pruned(&cfg, 0.5, 17);
        let m = SparseModel::from_params(&fp, &PackPolicy::default()).unwrap();
        let mut ctx = tokens(&cfg, 3 * cfg.seq, 19);
        let base = m.forward_logits(&[&ctx[..]]).unwrap();
        ctx[0] = (ctx[0] + 1) % cfg.vocab as i32; // far outside the window
        let edited = m.forward_logits(&[&ctx[..]]).unwrap();
        assert_eq!(base.data(), edited.data());
    }
}
