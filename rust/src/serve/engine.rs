//! The continuous-batching decode loop: pulls newly arrived requests from a
//! [`RequestSource`] between batch steps, joins them into the running batch,
//! decodes one token for every in-flight request through the sparse model,
//! retires finished requests, and narrates the lifecycle (`Enqueued` →
//! `BatchFormed` → `PrefillStarted` → `CacheEvicted` → `Finished` /
//! `Cancelled` → `Drained`) through a hook the api layer maps onto the
//! structured event stream.
//!
//! Intake is *live*: the loop is not handed a frozen workload up front but
//! polls its source at every step, so requests arriving over the network
//! while a batch is mid-decode join the very next step. Client disconnects
//! propagate as cancellation — the request retires immediately and its
//! [`CacheBudget`] reservation is released — and submissions that land on a
//! full bounded queue are rejected (429 semantics) instead of blocking the
//! decode loop. The preloaded synthetic workload of earlier PRs is now just
//! one source ([`SyntheticSource`], via [`ServeEngine::run`]); the TCP front
//! door (`serve::net`) is another.
//!
//! Two decode modes share one loop and produce token-for-token identical
//! streams (pinned by `tests/serve_kv_parity.rs`):
//!
//! * **KV-cached** (default): a joiner runs a *chunked prefill* over its
//!   prompt into a per-request [`KvCache`] and samples its first token from
//!   the prefill logits; every later step runs just its newest token
//!   through the packed linears ([`SparseModel::decode_cached`]) —
//!   O(layers) per token. Retiring a request frees its cache, returning
//!   its bytes to the [`CacheBudget`] the scheduler applies backpressure
//!   against.
//! * **Uncached**: every step re-forwards each request's whole context
//!   with banded attention ([`SparseModel::forward_logits`]) —
//!   O(ctx · layers) per token. The reference the cached path must match.
//!
//! Batch ordering is decided once, at admission: joiners append to the
//! tail of the active batch and retirement compacts in place, so decode
//! order is join order — the hot loop never re-sorts (pinned by the
//! order-stability test below). Per-request token streams depend only on
//! the request's own prompt and seed (row-independent kernels, per-request
//! attention and sampling rng), never on batch composition — which is what
//! makes the network path's nondeterministic arrival timing compatible
//! with the byte-exact net-parity test.

use std::collections::{BTreeMap, HashMap};
use std::sync::{Arc, Mutex};

use anyhow::Result;

use crate::eval::generate::pick_token;
use crate::obs::{Obs, Phase};
use crate::serve::fleet::{FleetEvent, ModelFleet};
use crate::serve::kv::{CacheBudget, KvCache};
use crate::serve::model::SparseModel;
use crate::serve::scheduler::{Scheduler, SchedulerPolicy, ServeRequest, StepLimits};
use crate::sparse::pool::WorkerPool;
use crate::util::json::Json;
use crate::util::prng::Rng;

/// Default prefill chunk rows — the single source of truth; `ServeSpec`
/// re-exports it so the API/CLI default can never drift from the engine's.
pub const DEFAULT_PREFILL_CHUNK: usize = 32;

/// Sampling + batching + cache knobs shared by every request of a run.
#[derive(Clone, Copy, Debug)]
pub struct EngineOptions {
    pub policy: SchedulerPolicy,
    pub temperature: f64,
    pub top_k: usize,
    /// incremental KV-cached decode (true, the serving path) or the full
    /// re-forward reference path (false)
    pub kv_cache: bool,
    /// prefill chunk rows (0 = the whole prompt in one chunk)
    pub prefill_chunk: usize,
    /// cache-memory budget in bytes (0 = unlimited); admission defers
    /// joins that would exceed it until retirements free caches
    pub cache_budget_bytes: u64,
    /// kernel worker-pool size for this engine: 0 shares the process
    /// global pool (sized from `SPARSEGPT_THREADS` at startup), n > 0
    /// gives the engine a private pool of n workers — two engines in one
    /// process can run with different counts
    pub workers: usize,
    /// emit a [`ServeEvent::MetricsSnapshot`] every n steps and once at
    /// drain (0 = no snapshot events)
    pub snap_every: usize,
    /// which router replica this engine is (0 for a bare engine): stamped
    /// into every lifecycle event and [`FinishedRequest`] so a multi-replica
    /// run's event stream attributes each request to its owner
    pub replica: usize,
}

impl Default for EngineOptions {
    fn default() -> EngineOptions {
        EngineOptions {
            policy: SchedulerPolicy::default(),
            temperature: 0.8,
            top_k: 40,
            kv_cache: true,
            prefill_chunk: DEFAULT_PREFILL_CHUNK,
            cache_budget_bytes: 0,
            workers: 0,
            snap_every: 0,
            replica: 0,
        }
    }
}

/// Lifecycle notifications (the api layer turns these into
/// `request-enqueued` / `batch-formed` / `prefill-started` /
/// `cache-evicted` / `request-finished` / `request-cancelled` /
/// `request-rejected` / `engine-drained` JSONL events).
#[derive(Clone, Debug)]
pub enum ServeEvent {
    Enqueued { id: u64, step: usize, prompt_tokens: usize, max_new_tokens: usize, replica: usize },
    BatchFormed { step: usize, joined: usize, batch: usize, replica: usize },
    /// a joiner's chunked prefill pass began populating its KV cache
    PrefillStarted { id: u64, step: usize, prompt_tokens: usize, chunks: usize, replica: usize },
    /// a request's ring buffer evicted `evicted` positions this step
    CacheEvicted { id: u64, step: usize, evicted: usize, replica: usize },
    /// a fleet variant became resident (lazy mmap-backed load at
    /// admission); `mapped` of its `bytes` are served from mapped pages
    ModelLoaded { name: String, step: usize, bytes: u64, mapped: u64 },
    /// the weight-residency budget (LRU) or the drain dropped a variant
    ModelEvicted { name: String, step: usize, bytes: u64 },
    Finished { id: u64, step: usize, tokens: usize, replica: usize },
    /// the client went away (disconnect or explicit cancel frame): the
    /// request retired early with `tokens` already generated and its cache
    /// reservation returned to the budget
    Cancelled { id: u64, step: usize, tokens: usize, replica: usize },
    /// a submission landed on a full bounded queue and was shed with
    /// 429 semantics instead of blocking the decode loop
    Rejected { id: u64, step: usize, queue: usize, cap: usize },
    /// periodic metrics snapshot ([`EngineOptions::snap_every`]): the full
    /// [`Obs`] registry rendered to JSON, also emitted once at drain
    MetricsSnapshot { snapshot: Json },
    Drained {
        steps: usize,
        requests: usize,
        tokens: usize,
        decode_secs: f64,
        cancelled: usize,
        /// cache memory still reserved — always 0 after a clean drain,
        /// including runs with mid-stream disconnects
        cache_bytes_in_use: u64,
        replica: usize,
    },
}

/// One retired request with its generated tokens and latency profile.
#[derive(Clone, Debug)]
pub struct FinishedRequest {
    pub id: u64,
    pub prompt_tokens: usize,
    pub tokens: Vec<i32>,
    pub joined_step: usize,
    pub finished_step: usize,
    /// router replica that decoded this request (0 for a bare engine)
    pub replica: usize,
    /// enqueue → first generated token wall time
    pub ttft_secs: f64,
    /// median inter-token gap (0.0 with fewer than two tokens)
    pub gap_p50_secs: f64,
    /// p95 inter-token gap (0.0 with fewer than two tokens)
    pub gap_p95_secs: f64,
}

/// What a drained engine run produced.
#[derive(Clone, Debug)]
pub struct EngineOutcome {
    pub finished: Vec<FinishedRequest>,
    pub steps: usize,
    pub tokens: usize,
    /// requests retired early because their client went away
    pub cancelled: usize,
    /// submissions shed because the bounded queue was full
    pub rejected: usize,
    /// wall time inside batched decode steps only (prefill + scheduling
    /// excluded)
    pub decode_secs: f64,
    /// wall time inside prefill passes (KV-cached mode only)
    pub prefill_secs: f64,
    /// prompt tokens streamed through prefill (KV-cached mode only)
    pub prefill_tokens: usize,
    /// ring-buffer evictions across all requests (prefill + decode)
    pub cache_evictions: usize,
    /// high-water mark of reserved cache memory
    pub peak_cache_bytes: u64,
    /// cache memory still reserved after the drain — always 0: retiring a
    /// request (finished *or* cancelled) returns its bytes to the budget
    pub cache_bytes_in_use: u64,
}

impl EngineOutcome {
    pub fn tokens_per_sec(&self) -> f64 {
        if self.decode_secs > 0.0 {
            self.tokens as f64 / self.decode_secs
        } else {
            0.0
        }
    }
}

/// Nearest-rank percentile of an ascending-sorted slice (0.0 when empty).
/// Shared by the engine's per-request gap stats and the report's
/// cross-request aggregates.
pub fn percentile_sorted(sorted: &[f64], q: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    let idx = ((sorted.len() - 1) as f64 * q).round() as usize;
    sorted[idx.min(sorted.len() - 1)]
}

/// Where the decode loop gets its work and where per-token results go.
///
/// The engine calls `poll`/`take_cancelled` at the top of every step and
/// streams results back through `token`/`finished`/`cancelled`, so a source
/// backed by live connections sees tokens as they are sampled, not after
/// the drain. All result hooks default to no-ops — a synthetic workload
/// only has to describe arrivals.
pub trait RequestSource {
    /// Requests newly visible at `step`. `queue_free` is the bounded
    /// queue's remaining capacity: a source that respects it (the synthetic
    /// workload) gets backpressure by deferral, while a source that cannot
    /// hold submissions back (the network) may return more — the engine
    /// sheds the overflow through [`RequestSource::rejected`].
    fn poll(&mut self, step: usize, queue_free: usize) -> Vec<ServeRequest>;
    /// Request ids whose clients cancelled or disconnected since the last
    /// step. Ids that are unknown or already retired are ignored.
    fn take_cancelled(&mut self, step: usize) -> Vec<u64>;
    /// No further requests will ever arrive — the drain condition. A
    /// network source reports closed only once a shutdown was requested
    /// and its intake is empty.
    fn closed(&self) -> bool;
    /// `req` entered the bounded queue (paired with the `Enqueued` event).
    fn accepted(&mut self, _req: &ServeRequest) {}
    /// `req` was shed because the queue held `queue` of `cap` entries.
    fn rejected(&mut self, _req: &ServeRequest, _queue: usize, _cap: usize) {}
    /// One generated token, streamed as it is sampled. Returning false
    /// marks the client unreachable — the engine retires the request as
    /// cancelled in the same step's retire scan.
    fn token(&mut self, _id: u64, _index: usize, _token: i32) -> bool {
        true
    }
    /// The request retired with its full token budget.
    fn finished(&mut self, _fin: &FinishedRequest) {}
    /// The request retired early with `tokens` generated.
    fn cancelled(&mut self, _id: u64, _tokens: usize) {}
    /// An idle tick: nothing in flight and nothing admitted this step. A
    /// network source blocks here briefly instead of busy-spinning.
    fn idle(&mut self) {}
}

/// The preloaded workload of earlier PRs as a [`RequestSource`]: requests
/// become visible at their scripted arrival step (FIFO within a step),
/// held back while the bounded queue is full (backpressure by deferral,
/// never rejection), plus an optional scripted cancel schedule — `(step,
/// id)` pairs that model a client disconnecting at that step, which is how
/// a deterministic run (and the pinned event golden) exercises the
/// disconnect path without sockets.
pub struct SyntheticSource {
    incoming: Vec<(usize, ServeRequest)>,
    next: usize,
    cancels: Vec<(usize, u64)>,
    next_cancel: usize,
}

impl SyntheticSource {
    pub fn new(mut incoming: Vec<(usize, ServeRequest)>, mut cancels: Vec<(usize, u64)>) -> Self {
        // ordering is decided here, once: arrivals sort stably (FIFO within
        // a step), joiners append, retirement compacts — the decode loop
        // never re-sorts the batch
        incoming.sort_by_key(|(step, _)| *step);
        cancels.sort_by_key(|(step, _)| *step);
        SyntheticSource { incoming, next: 0, cancels, next_cancel: 0 }
    }
}

impl RequestSource for SyntheticSource {
    fn poll(&mut self, step: usize, queue_free: usize) -> Vec<ServeRequest> {
        let mut out = Vec::new();
        while self.next < self.incoming.len()
            && self.incoming[self.next].0 <= step
            && out.len() < queue_free
        {
            out.push(self.incoming[self.next].1.clone());
            self.next += 1;
        }
        out
    }

    fn take_cancelled(&mut self, step: usize) -> Vec<u64> {
        let mut out = Vec::new();
        while self.next_cancel < self.cancels.len() && self.cancels[self.next_cancel].0 <= step {
            out.push(self.cancels[self.next_cancel].1);
            self.next_cancel += 1;
        }
        out
    }

    fn closed(&self) -> bool {
        self.next >= self.incoming.len()
    }
}

/// A request currently in the decode batch.
struct Active {
    req: ServeRequest,
    /// effective prompt (empty prompts serve a single `0`) + generated
    ctx: Vec<i32>,
    generated: Vec<i32>,
    rng: Rng,
    joined_step: usize,
    /// resolved fleet variant this request decodes on (`None` = the
    /// engine's default model); the `Arc` keeps the variant — and its
    /// mapped pages — alive across a registry eviction
    model: Option<Arc<SparseModel>>,
    /// per-request KV cache (KV-cached mode)
    cache: Option<KvCache>,
    /// next-token logits awaiting sampling (from prefill or the last
    /// batched decode)
    pending: Option<Vec<f32>>,
    /// when the request entered the bounded queue, in [`Obs`] clock
    /// nanoseconds (ttft anchor)
    enqueued_at: u64,
    ttft_secs: f64,
    last_token_at: Option<u64>,
    /// inter-token gaps, seconds
    gaps: Vec<f64>,
}

impl Active {
    fn new(
        req: ServeRequest,
        joined_step: usize,
        enqueued_at: u64,
        model: Option<Arc<SparseModel>>,
    ) -> Active {
        let ctx = if req.prompt.is_empty() { vec![0] } else { req.prompt.clone() };
        Active {
            ctx,
            generated: Vec::with_capacity(req.max_new_tokens),
            rng: Rng::new(req.seed ^ 0x5e21e),
            joined_step,
            model,
            cache: None,
            pending: None,
            enqueued_at,
            ttft_secs: 0.0,
            last_token_at: None,
            gaps: Vec::new(),
            req,
        }
    }

    fn retire_finished(mut self, step: usize, replica: usize) -> FinishedRequest {
        self.gaps.sort_by(|a, b| a.total_cmp(b));
        FinishedRequest {
            id: self.req.id,
            prompt_tokens: self.req.prompt.len(),
            tokens: self.generated,
            joined_step: self.joined_step,
            finished_step: step,
            replica,
            ttft_secs: self.ttft_secs,
            gap_p50_secs: percentile_sorted(&self.gaps, 0.50),
            gap_p95_secs: percentile_sorted(&self.gaps, 0.95),
        }
    }
}

/// The serving engine: owns the scheduler and its kernel worker pool,
/// borrows the model.
pub struct ServeEngine<'a> {
    model: &'a SparseModel,
    opts: EngineOptions,
    /// pool the step loop installs around every forward (private when
    /// `opts.workers > 0`, else a handle to the shared global pool)
    pool: WorkerPool,
    /// metrics registry + clock; a private real-clock default unless the
    /// caller shares one via [`ServeEngine::with_obs`]
    obs: Obs,
    /// named model variants requests can route to ([`ServeRequest::model`]);
    /// the mutex serializes lazy loads/evictions against the step loop. An
    /// `Arc` so router replicas can share one registry — mapped pages are
    /// read-only, so N replicas alias one mapping with zero copy (eviction
    /// only drops the registry `Arc`; a replica's held model stays valid)
    fleet: Option<Arc<Mutex<ModelFleet>>>,
}

impl<'a> ServeEngine<'a> {
    pub fn new(model: &'a SparseModel, opts: EngineOptions) -> ServeEngine<'a> {
        let pool = match opts.workers {
            0 => WorkerPool::current(),
            n => WorkerPool::new(n),
        };
        let obs = Obs::default();
        obs.attach_pool(pool.clone());
        ServeEngine { model, opts, pool, obs, fleet: None }
    }

    /// Attach a [`ModelFleet`] of named variants. Requests whose
    /// [`ServeRequest::model`] names a fleet entry decode on that variant
    /// (loaded lazily at admission); unnamed requests keep the default
    /// model, byte-for-byte unaffected.
    pub fn with_fleet(mut self, fleet: ModelFleet) -> ServeEngine<'a> {
        self.fleet = Some(Arc::new(Mutex::new(fleet)));
        self
    }

    /// Share an externally owned fleet registry across engines: every
    /// router replica resolves variants through (and charges the residency
    /// budget of) the same registry, while the mapped weight pages are
    /// aliased read-only — N replicas, one copy of the bytes.
    pub fn with_shared_fleet(mut self, fleet: Arc<Mutex<ModelFleet>>) -> ServeEngine<'a> {
        self.fleet = Some(fleet);
        self
    }

    /// Share an externally owned [`Obs`] (registry + clock): the engine
    /// records into it, and its pool becomes the snapshot's worker table.
    /// With a mock clock every duration in the run becomes deterministic.
    pub fn with_obs(mut self, obs: Obs) -> ServeEngine<'a> {
        obs.attach_pool(self.pool.clone());
        self.obs = obs;
        self
    }

    /// Worker count of the pool this engine's kernels run on.
    pub fn workers(&self) -> usize {
        self.pool.workers()
    }

    /// Run a preloaded workload to drain: `incoming` is (arrival step,
    /// request) pairs — requests become visible to the scheduler at their
    /// arrival step, which is how a synthetic run exercises join/retire
    /// churn. Convenience wrapper over [`ServeEngine::run_source`] with a
    /// [`SyntheticSource`] and no cancels.
    pub fn run(
        &self,
        incoming: Vec<(usize, ServeRequest)>,
        on_event: &mut dyn FnMut(&ServeEvent),
    ) -> Result<EngineOutcome> {
        self.run_source(&mut SyntheticSource::new(incoming, Vec::new()), on_event)
    }

    /// The step-driven live-intake loop. Each step: propagate cancels,
    /// poll arrivals (shedding overflow), form the batch (chunked prefill
    /// for joiners), decode one token per in-flight request and stream it
    /// to the source, retire satisfied or disconnected requests. Runs
    /// until the source is closed and every queue is empty. The engine's
    /// worker pool is installed for the duration, so every kernel under
    /// the loop fans out over this engine's workers.
    pub fn run_source(
        &self,
        source: &mut dyn RequestSource,
        on_event: &mut dyn FnMut(&ServeEvent),
    ) -> Result<EngineOutcome> {
        let pool = self.pool.clone();
        pool.install(|| self.run_steps(source, on_event))
    }

    fn run_steps(
        &self,
        source: &mut dyn RequestSource,
        on_event: &mut dyn FnMut(&ServeEvent),
    ) -> Result<EngineOutcome> {
        let vocab = self.model.cfg.vocab;
        let unit = self.model.cache_bytes();
        let replica = self.opts.replica;
        let obs = &self.obs;
        let clock = obs.clock().clone();
        let m = obs.metrics();
        let mut sched = Scheduler::new(self.opts.policy);
        let mut budget = CacheBudget::new(self.opts.cache_budget_bytes);
        let mut active: Vec<Active> = Vec::new();
        let mut finished: Vec<FinishedRequest> = Vec::new();
        let mut enqueued_at: HashMap<u64, u64> = HashMap::new();
        let mut step = 0usize;
        let mut tokens = 0usize;
        let mut cancelled = 0usize;
        let mut rejected = 0usize;
        let mut decode_secs = 0.0f64;
        let mut prefill_secs = 0.0f64;
        let mut prefill_tokens = 0usize;
        let mut cache_evictions = 0usize;
        let mut peak_cache_bytes = 0u64;
        m.models_resident.set(
            self.fleet
                .as_ref()
                .map(|f| f.lock().unwrap().resident_models() as u64)
                .unwrap_or(0),
        );
        m.weight_bytes_mapped.set(self.model.mapped_bytes());

        loop {
            // disconnects and cancel frames observed since the last step
            // retire first, so the budget headroom they free is visible to
            // this step's admission; unknown or already-retired ids are
            // no-ops
            for id in source.take_cancelled(step) {
                if let Some(i) = active.iter().position(|a| a.req.id == id) {
                    let mut a = active.remove(i);
                    if a.cache.take().is_some() {
                        budget.release(unit);
                        m.cache_bytes_in_use.set(budget.in_use());
                    }
                    cancelled += 1;
                    m.requests_cancelled_total.inc();
                    on_event(&ServeEvent::Cancelled {
                        id,
                        step,
                        tokens: a.generated.len(),
                        replica,
                    });
                    source.cancelled(id, a.generated.len());
                } else if sched.cancel(id) {
                    enqueued_at.remove(&id);
                    cancelled += 1;
                    m.requests_cancelled_total.inc();
                    on_event(&ServeEvent::Cancelled { id, step, tokens: 0, replica });
                    source.cancelled(id, 0);
                }
            }
            // arrivals visible at this step enter the bounded queue. A
            // source that respects `queue_free` (the synthetic workload)
            // holds its own arrivals back and retries on later steps once
            // decode drains the queue; anything beyond capacity is shed
            // with an explicit rejection instead of blocking the loop
            for req in source.poll(step, sched.free_capacity()) {
                // membership is validated at enqueue so a typo'd model
                // name is shed immediately, not discovered at admission
                if let Some(name) = req.model.as_deref() {
                    let known = self
                        .fleet
                        .as_ref()
                        .map(|f| f.lock().unwrap().contains(name))
                        .unwrap_or(false);
                    if !known {
                        rejected += 1;
                        m.requests_rejected_total.inc();
                        let (queue, cap) = (sched.queue_len(), sched.policy().queue_cap);
                        on_event(&ServeEvent::Rejected { id: req.id, step, queue, cap });
                        source.rejected(&req, queue, cap);
                        continue;
                    }
                }
                if !sched.has_capacity() {
                    rejected += 1;
                    m.requests_rejected_total.inc();
                    let (queue, cap) = (sched.queue_len(), sched.policy().queue_cap);
                    on_event(&ServeEvent::Rejected { id: req.id, step, queue, cap });
                    source.rejected(&req, queue, cap);
                    continue;
                }
                let (id, prompt_tokens, max_new_tokens) =
                    (req.id, req.prompt.len(), req.max_new_tokens);
                enqueued_at.insert(id, clock.now_ns());
                sched.submit(req.clone())?;
                m.requests_enqueued_total.inc();
                on_event(&ServeEvent::Enqueued { id, step, prompt_tokens, max_new_tokens, replica });
                source.accepted(&req);
            }
            // batch formation: joiners ride this very step, capped by the
            // per-step prompt-token budget (both modes pay prompt cost) and
            // by the cache-memory headroom in KV-cached mode
            let prefill_budget = match self.opts.policy.max_prefill_tokens {
                0 => None,
                n => Some(n),
            };
            let cache_slots = if self.opts.kv_cache {
                let mut slots = budget.free_slots(unit);
                if slots == Some(0) && active.is_empty() {
                    // floor: a budget below one cache must still make
                    // progress — serve one request at a time
                    slots = Some(1);
                }
                slots
            } else {
                None
            };
            let limits = StepLimits { prefill_tokens: prefill_budget, cache_slots };
            let joined = sched.admit(active.len(), &limits);
            m.queue_depth.set(sched.queue_len() as u64);
            m.queue_depth_peak.set_max(sched.queue_peak() as u64);
            if !joined.is_empty() {
                m.requests_admitted_total.add(joined.len() as u64);
                on_event(&ServeEvent::BatchFormed {
                    step,
                    joined: joined.len(),
                    batch: active.len() + joined.len(),
                    replica,
                });
                for req in joined {
                    let t_enq = enqueued_at.remove(&req.id).unwrap_or_else(|| {
                        // admission without an enqueue record should be
                        // impossible; the counter makes a regression visible
                        // instead of silently zeroing the request's ttft
                        m.ttft_anchor_missing_total.inc();
                        clock.now_ns()
                    });
                    // route to the fleet variant (lazy load + LRU now,
                    // while the request's admission is being paid anyway)
                    let handle = match req.model.as_deref() {
                        None => None,
                        Some(name) => {
                            let fleet =
                                self.fleet.as_ref().expect("membership validated at enqueue");
                            let mut fleet = fleet.lock().unwrap();
                            let mut fev = Vec::new();
                            let resolved = fleet.resolve(name, &mut fev)?;
                            m.models_resident.set(fleet.resident_models() as u64);
                            m.weight_bytes_mapped
                                .set(self.model.mapped_bytes() + fleet.mapped_bytes());
                            drop(fleet);
                            for ev in fev {
                                match ev {
                                    FleetEvent::Loaded { name, bytes, mapped } => on_event(
                                        &ServeEvent::ModelLoaded { name, step, bytes, mapped },
                                    ),
                                    FleetEvent::Evicted { name, bytes } => on_event(
                                        &ServeEvent::ModelEvicted { name, step, bytes },
                                    ),
                                }
                            }
                            Some(resolved)
                        }
                    };
                    let mut a = Active::new(req, step, t_enq, handle);
                    let model = a.model.as_deref().unwrap_or(self.model);
                    if self.opts.kv_cache {
                        let mut cache = model.new_cache();
                        budget.reserve(unit);
                        peak_cache_bytes = peak_cache_bytes.max(budget.in_use());
                        m.cache_bytes_in_use.set(budget.in_use());
                        m.cache_bytes_peak.set_max(budget.in_use());
                        let chunk = if self.opts.prefill_chunk == 0 {
                            a.ctx.len()
                        } else {
                            self.opts.prefill_chunk
                        };
                        on_event(&ServeEvent::PrefillStarted {
                            id: a.req.id,
                            step,
                            prompt_tokens: a.ctx.len(),
                            chunks: (a.ctx.len() + chunk - 1) / chunk,
                            replica,
                        });
                        let t0 = clock.now_ns();
                        let (logits, evicted) =
                            model.prefill(&a.ctx, &mut cache, self.opts.prefill_chunk)?;
                        let dt = clock.now_ns().saturating_sub(t0);
                        obs.record_phase(Phase::Prefill, dt);
                        prefill_secs += dt as f64 * 1e-9;
                        prefill_tokens += a.ctx.len();
                        m.tokens_prefilled_total.add(a.ctx.len() as u64);
                        if evicted > 0 {
                            cache_evictions += evicted;
                            m.cache_evictions_total.add(evicted as u64);
                            on_event(&ServeEvent::CacheEvicted {
                                id: a.req.id,
                                step,
                                evicted,
                                replica,
                            });
                        }
                        a.cache = Some(cache);
                        a.pending = Some(logits);
                    }
                    active.push(a);
                }
            }
            if active.is_empty() {
                if source.closed() && sched.is_empty() {
                    break; // drained
                }
                step += 1; // idle tick: waiting on arrivals or the batch window
                m.steps_total.inc();
                if self.opts.snap_every > 0 && step % self.opts.snap_every == 0 {
                    on_event(&ServeEvent::MetricsSnapshot { snapshot: obs.snapshot().to_json() });
                }
                source.idle();
                continue;
            }
            m.batch_size.observe(active.len() as u64);

            // one next-token step for every in-flight request
            if self.opts.kv_cache {
                // fresh joiners already hold their prefill logits; everyone
                // else advances by one incremental token. Decode runs in
                // per-model groups, deterministically ordered (default
                // model first — `None < Some` — then variants by name), so
                // a single-model run is one group and byte-identical to
                // the ungrouped loop.
                let mut groups: BTreeMap<Option<String>, Vec<usize>> = BTreeMap::new();
                for (i, a) in active.iter().enumerate() {
                    if a.pending.is_none() {
                        groups.entry(a.req.model.clone()).or_default().push(i);
                    }
                }
                for (_, idxs) in groups {
                    let toks: Vec<i32> = idxs
                        .iter()
                        .map(|&i| *active[i].ctx.last().expect("context never empty"))
                        .collect();
                    let handle = active[idxs[0]].model.clone();
                    let model = handle.as_deref().unwrap_or(self.model);
                    let t0 = clock.now_ns();
                    let (logits, evictions) = {
                        let mut caches: Vec<&mut KvCache> = active
                            .iter_mut()
                            .enumerate()
                            .filter(|(i, _)| idxs.binary_search(i).is_ok())
                            .map(|(_, a)| a.cache.as_mut().expect("cached mode"))
                            .collect();
                        model.decode_cached(&toks, &mut caches)?
                    };
                    let dt = clock.now_ns().saturating_sub(t0);
                    obs.record_phase(Phase::Decode, dt);
                    decode_secs += dt as f64 * 1e-9;
                    for (row, &i) in idxs.iter().enumerate() {
                        active[i].pending =
                            Some(logits.data()[row * vocab..(row + 1) * vocab].to_vec());
                        if evictions[row] > 0 {
                            cache_evictions += evictions[row];
                            m.cache_evictions_total.add(evictions[row] as u64);
                            on_event(&ServeEvent::CacheEvicted {
                                id: active[i].req.id,
                                step,
                                evicted: evictions[row],
                                replica,
                            });
                        }
                    }
                }
            } else {
                let mut groups: BTreeMap<Option<String>, Vec<usize>> = BTreeMap::new();
                for (i, a) in active.iter().enumerate() {
                    groups.entry(a.req.model.clone()).or_default().push(i);
                }
                for (_, idxs) in groups {
                    let handle = active[idxs[0]].model.clone();
                    let model = handle.as_deref().unwrap_or(self.model);
                    let seqs: Vec<&[i32]> =
                        idxs.iter().map(|&i| active[i].ctx.as_slice()).collect();
                    let t0 = clock.now_ns();
                    let logits = model.forward_logits(&seqs)?;
                    let dt = clock.now_ns().saturating_sub(t0);
                    obs.record_phase(Phase::Decode, dt);
                    decode_secs += dt as f64 * 1e-9;
                    for (row, &i) in idxs.iter().enumerate() {
                        active[i].pending =
                            Some(logits.data()[row * vocab..(row + 1) * vocab].to_vec());
                    }
                }
            }
            // sample + stream: each token goes to the source as it is
            // produced; a failed write means the client is gone, and the
            // request retires as cancelled in this step's retire scan
            let mut dead: Vec<u64> = Vec::new();
            for a in active.iter_mut() {
                let logits = a.pending.take().expect("every in-flight request has logits");
                let t = pick_token(&logits, self.opts.temperature, self.opts.top_k, &mut a.rng);
                a.ctx.push(t);
                a.generated.push(t);
                tokens += 1;
                m.tokens_decoded_total.inc();
                let now = clock.now_ns();
                match a.last_token_at {
                    None => a.ttft_secs = now.saturating_sub(a.enqueued_at) as f64 * 1e-9,
                    Some(prev) => a.gaps.push(now.saturating_sub(prev) as f64 * 1e-9),
                }
                a.last_token_at = Some(now);
                if !source.token(a.req.id, a.generated.len() - 1, t) {
                    dead.push(a.req.id);
                }
            }
            // retire satisfied and unreachable requests (batch order
            // preserved for the rest); dropping the cache returns its bytes
            // to the budget
            let mut i = 0;
            while i < active.len() {
                if dead.contains(&active[i].req.id) {
                    let mut a = active.remove(i);
                    if a.cache.take().is_some() {
                        budget.release(unit);
                        m.cache_bytes_in_use.set(budget.in_use());
                    }
                    cancelled += 1;
                    m.requests_cancelled_total.inc();
                    on_event(&ServeEvent::Cancelled {
                        id: a.req.id,
                        step,
                        tokens: a.generated.len(),
                        replica,
                    });
                    source.cancelled(a.req.id, a.generated.len());
                } else if active[i].generated.len() >= active[i].req.max_new_tokens {
                    let mut a = active.remove(i);
                    if a.cache.take().is_some() {
                        budget.release(unit);
                        m.cache_bytes_in_use.set(budget.in_use());
                    }
                    m.requests_finished_total.inc();
                    on_event(&ServeEvent::Finished {
                        id: a.req.id,
                        step,
                        tokens: a.generated.len(),
                        replica,
                    });
                    let fin = a.retire_finished(step, replica);
                    source.finished(&fin);
                    finished.push(fin);
                } else {
                    i += 1;
                }
            }
            step += 1;
            m.steps_total.inc();
            if self.opts.snap_every > 0 && step % self.opts.snap_every == 0 {
                on_event(&ServeEvent::MetricsSnapshot { snapshot: obs.snapshot().to_json() });
            }
        }
        debug_assert_eq!(budget.in_use(), 0, "retire must return every cache to the budget");
        // drain the fleet: residency returns to zero with an eviction
        // event per resident variant, mirroring the cache-budget contract
        if let Some(fleet) = &self.fleet {
            let mut fleet = fleet.lock().unwrap();
            let mut fev = Vec::new();
            fleet.evict_all(&mut fev);
            debug_assert_eq!(fleet.resident_bytes(), 0, "drain must empty the fleet budget");
            drop(fleet);
            m.models_resident.set(0);
            m.weight_bytes_mapped.set(self.model.mapped_bytes());
            for ev in fev {
                if let FleetEvent::Evicted { name, bytes } = ev {
                    on_event(&ServeEvent::ModelEvicted { name, step, bytes });
                }
            }
        }
        let outcome = EngineOutcome {
            finished,
            steps: step,
            tokens,
            cancelled,
            rejected,
            decode_secs,
            prefill_secs,
            prefill_tokens,
            cache_evictions,
            peak_cache_bytes,
            cache_bytes_in_use: budget.in_use(),
        };
        m.queue_depth.set(sched.queue_len() as u64);
        m.cache_bytes_in_use.set(budget.in_use());
        if self.opts.snap_every > 0 {
            on_event(&ServeEvent::MetricsSnapshot { snapshot: obs.snapshot().to_json() });
        }
        on_event(&ServeEvent::Drained {
            steps: outcome.steps,
            requests: outcome.finished.len(),
            tokens: outcome.tokens,
            decode_secs: outcome.decode_secs,
            cancelled: outcome.cancelled,
            cache_bytes_in_use: outcome.cache_bytes_in_use,
            replica,
        });
        Ok(outcome)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::config::ModelCfg;
    use crate::model::init::init_params;
    use crate::sparse::PackPolicy;
    use crate::util::prng::Rng as TestRng;

    fn model() -> SparseModel {
        let cfg = ModelCfg::from_dims("engine-test", 8, 1, 2, 1, 1, 11, 4);
        SparseModel::from_params(&init_params(&cfg, 0), &PackPolicy::default()).unwrap()
    }

    fn policy(max_batch: usize, max_wait: usize, queue_cap: usize) -> SchedulerPolicy {
        SchedulerPolicy { max_batch, max_wait, queue_cap, ..SchedulerPolicy::default() }
    }

    fn requests(n: usize, tokens: usize, vocab: usize) -> Vec<(usize, ServeRequest)> {
        let mut rng = TestRng::new(0);
        (0..n)
            .map(|i| {
                let prompt: Vec<i32> = (0..3).map(|_| rng.below(vocab) as i32).collect();
                let req = ServeRequest {
                    id: i as u64,
                    prompt,
                    max_new_tokens: tokens,
                    seed: i as u64,
                    model: None,
                };
                (i, req)
            })
            .collect()
    }

    #[test]
    fn drains_all_requests_and_counts_tokens() {
        let m = model();
        let opts = EngineOptions {
            policy: policy(2, 1, 16),
            temperature: 0.0,
            top_k: 0,
            ..EngineOptions::default()
        };
        let mut events = Vec::new();
        let out = ServeEngine::new(&m, opts)
            .run(requests(5, 3, 11), &mut |e| events.push(e.clone()))
            .unwrap();
        assert_eq!(out.finished.len(), 5);
        assert_eq!(out.tokens, 15);
        assert!(out.finished.iter().all(|f| f.tokens.len() == 3));
        assert_eq!(out.prefill_tokens, 15, "5 prompts of 3 tokens prefilled");
        assert_eq!(out.cache_bytes_in_use, 0, "retire returned every cache");
        assert_eq!(out.cancelled, 0);
        assert_eq!(out.rejected, 0);
        // ids all retire exactly once
        let mut ids: Vec<u64> = out.finished.iter().map(|f| f.id).collect();
        ids.sort_unstable();
        assert_eq!(ids, vec![0, 1, 2, 3, 4]);
        // lifecycle shape: 5 enqueues, >=1 batch, 5 prefills, 5 finishes, 1 drain
        let count = |f: fn(&ServeEvent) -> bool| events.iter().filter(|e| f(e)).count();
        assert_eq!(count(|e| matches!(e, ServeEvent::Enqueued { .. })), 5);
        assert!(count(|e| matches!(e, ServeEvent::BatchFormed { .. })) >= 2);
        assert_eq!(count(|e| matches!(e, ServeEvent::PrefillStarted { .. })), 5);
        assert_eq!(count(|e| matches!(e, ServeEvent::Finished { .. })), 5);
        assert_eq!(count(|e| matches!(e, ServeEvent::Drained { .. })), 1);
    }

    #[test]
    fn staggered_arrivals_join_mid_flight() {
        let m = model();
        let opts = EngineOptions {
            policy: policy(4, 0, 16),
            temperature: 0.0,
            top_k: 0,
            ..EngineOptions::default()
        };
        // request 1 arrives while request 0 is mid-decode
        let mut reqs = requests(2, 4, 11);
        reqs[1].0 = 2;
        let mut joins = Vec::new();
        let out = ServeEngine::new(&m, opts)
            .run(reqs, &mut |e| {
                if let ServeEvent::BatchFormed { batch, .. } = e {
                    joins.push(*batch);
                }
            })
            .unwrap();
        assert_eq!(out.finished.len(), 2);
        assert_eq!(joins, vec![1, 2], "second request joined the running batch");
    }

    #[test]
    fn full_queue_defers_arrivals_instead_of_failing() {
        let m = model();
        let opts = EngineOptions {
            policy: policy(2, 0, 2),
            temperature: 0.0,
            top_k: 0,
            ..EngineOptions::default()
        };
        // 6 requests bunched at step 0 against 2 queue slots: the engine
        // must hold arrivals back and still drain everything
        let mut reqs = requests(6, 2, 11);
        for r in reqs.iter_mut() {
            r.0 = 0;
        }
        let out = ServeEngine::new(&m, opts).run(reqs, &mut |_| {}).unwrap();
        assert_eq!(out.finished.len(), 6);
        assert_eq!(out.tokens, 12);
        assert_eq!(out.rejected, 0, "a deferring source is never shed");
    }

    #[test]
    fn deterministic_given_seeds() {
        let m = model();
        let opts = EngineOptions {
            policy: policy(2, 1, 16),
            temperature: 0.8,
            top_k: 5,
            ..EngineOptions::default()
        };
        let run = || {
            ServeEngine::new(&m, opts)
                .run(requests(3, 4, 11), &mut |_| {})
                .unwrap()
                .finished
                .iter()
                .map(|f| (f.id, f.tokens.clone()))
                .collect::<Vec<_>>()
        };
        assert_eq!(run(), run());
    }

    #[test]
    fn cached_and_uncached_modes_agree_token_for_token() {
        // engine-level spot check of the tentpole invariant (the broad
        // differential sweep lives in tests/serve_kv_parity.rs): seq is 4
        // here, so 6 generated tokens push every request past eviction
        let m = model();
        let mut streams = Vec::new();
        for kv_cache in [true, false] {
            let opts = EngineOptions {
                policy: policy(2, 1, 16),
                temperature: 0.7,
                top_k: 4,
                kv_cache,
                prefill_chunk: 2,
                ..EngineOptions::default()
            };
            let mut out = ServeEngine::new(&m, opts)
                .run(requests(4, 6, 11), &mut |_| {})
                .unwrap()
                .finished
                .iter()
                .map(|f| (f.id, f.tokens.clone()))
                .collect::<Vec<_>>();
            out.sort_by_key(|(id, _)| *id);
            streams.push(out);
        }
        assert_eq!(streams[0], streams[1]);
    }

    #[test]
    fn batch_order_is_join_order_never_resorted() {
        // ids join in the order 5, 2, then 1 (id order != join order); all
        // three retire on the same step, and the retire scan walks the
        // batch in order — so the Finished events of that step must come
        // out 5, 2, 1. A decode loop that re-sorted the batch (by id,
        // arrival, or remaining budget) would reorder them.
        let m = model();
        let opts = EngineOptions {
            policy: policy(3, 0, 16),
            temperature: 0.0,
            top_k: 0,
            ..EngineOptions::default()
        };
        let reqs = vec![
            (
                0,
                ServeRequest { id: 5, prompt: vec![1, 2], max_new_tokens: 6, seed: 5, model: None },
            ),
            (0, ServeRequest { id: 2, prompt: vec![3], max_new_tokens: 6, seed: 2, model: None }),
            (
                2,
                ServeRequest { id: 1, prompt: vec![4, 5], max_new_tokens: 4, seed: 1, model: None },
            ),
        ];
        let mut finish_order = Vec::new();
        let out = ServeEngine::new(&m, opts)
            .run(reqs, &mut |e| {
                if let ServeEvent::Finished { id, step, .. } = e {
                    finish_order.push((*id, *step));
                }
            })
            .unwrap();
        assert_eq!(out.finished.len(), 3);
        assert_eq!(
            finish_order,
            vec![(5, 5), (2, 5), (1, 5)],
            "same-step retirements surface in join order"
        );
    }

    #[test]
    fn cache_budget_applies_backpressure_and_drains() {
        let m = model();
        let unit = m.cache_bytes();
        let opts = EngineOptions {
            policy: policy(4, 0, 16),
            temperature: 0.0,
            top_k: 0,
            cache_budget_bytes: 2 * unit, // room for 2 of the 4 requests
            ..EngineOptions::default()
        };
        let mut reqs = requests(4, 3, 11);
        for r in reqs.iter_mut() {
            r.0 = 0;
        }
        let mut max_batch_seen = 0;
        let out = ServeEngine::new(&m, opts)
            .run(reqs, &mut |e| {
                if let ServeEvent::BatchFormed { batch, .. } = e {
                    max_batch_seen = max_batch_seen.max(*batch);
                }
            })
            .unwrap();
        assert_eq!(out.finished.len(), 4, "deferred joins still drain");
        assert_eq!(max_batch_seen, 2, "memory budget caps concurrency below max_batch");
        assert_eq!(out.peak_cache_bytes, 2 * unit);
        assert_eq!(out.cache_bytes_in_use, 0);
    }

    #[test]
    fn starved_budget_still_serves_one_at_a_time() {
        let m = model();
        let opts = EngineOptions {
            policy: policy(4, 0, 16),
            temperature: 0.0,
            top_k: 0,
            cache_budget_bytes: 1, // below a single cache
            ..EngineOptions::default()
        };
        let out = ServeEngine::new(&m, opts).run(requests(3, 2, 11), &mut |_| {}).unwrap();
        assert_eq!(out.finished.len(), 3);
        assert_eq!(out.peak_cache_bytes, m.cache_bytes(), "never more than one cache live");
    }

    #[test]
    fn evictions_surface_once_contexts_outgrow_the_window() {
        // seq = 4 and prompts are 3 tokens: the second generated token
        // already overwrites ring slots
        let m = model();
        let opts = EngineOptions {
            policy: policy(2, 0, 16),
            temperature: 0.0,
            top_k: 0,
            ..EngineOptions::default()
        };
        let mut evicted = 0usize;
        let out = ServeEngine::new(&m, opts)
            .run(requests(2, 4, 11), &mut |e| {
                if let ServeEvent::CacheEvicted { evicted: n, .. } = e {
                    evicted += n;
                }
            })
            .unwrap();
        assert_eq!(out.cache_evictions, evicted, "outcome mirrors the event stream");
        // prefill fills positions 0..=2; decode appends 3, 4, 5 (the final
        // sampled token retires unprocessed) — positions 4 and 5 evict
        assert_eq!(evicted, 4);
    }

    #[test]
    fn scripted_cancel_retires_active_request_and_frees_budget() {
        // id 0 is cancelled at step 2, mid-stream with 2 of 4 tokens out;
        // ids 1 and 2 run to completion and the budget drains to zero
        let m = model();
        let opts = EngineOptions {
            policy: policy(2, 0, 16),
            temperature: 0.0,
            top_k: 0,
            ..EngineOptions::default()
        };
        let mut cancel_events = Vec::new();
        let mut src = SyntheticSource::new(requests(3, 4, 11), vec![(2, 0)]);
        let out = ServeEngine::new(&m, opts)
            .run_source(&mut src, &mut |e| {
                if let ServeEvent::Cancelled { id, step, tokens, .. } = e {
                    cancel_events.push((*id, *step, *tokens));
                }
            })
            .unwrap();
        assert_eq!(out.cancelled, 1);
        assert_eq!(cancel_events, vec![(0, 2, 2)], "disconnect lands mid-stream");
        let mut ids: Vec<u64> = out.finished.iter().map(|f| f.id).collect();
        ids.sort_unstable();
        assert_eq!(ids, vec![1, 2], "untouched requests still finish");
        assert_eq!(out.tokens, 2 + 4 + 4, "partial stream still counted");
        assert_eq!(out.cache_bytes_in_use, 0, "cancel returned the reservation");
    }

    #[test]
    fn cancel_of_queued_request_removes_it_before_admission() {
        let m = model();
        let opts = EngineOptions {
            policy: policy(1, 0, 16),
            temperature: 0.0,
            top_k: 0,
            ..EngineOptions::default()
        };
        // max_batch 1: id 1 arrives at step 1 and queues behind id 0, then
        // its client disconnects at step 2, before it was ever admitted
        let mut reqs = requests(2, 3, 11);
        reqs[1].0 = 1;
        let mut cancel_events = Vec::new();
        let mut src = SyntheticSource::new(reqs, vec![(2, 1)]);
        let out = ServeEngine::new(&m, opts)
            .run_source(&mut src, &mut |e| {
                if let ServeEvent::Cancelled { id, step, tokens, .. } = e {
                    cancel_events.push((*id, *step, *tokens));
                }
            })
            .unwrap();
        assert_eq!(out.cancelled, 1);
        assert_eq!(cancel_events, vec![(1, 2, 0)], "queued cancel has zero tokens");
        assert_eq!(out.finished.len(), 1);
        assert_eq!(out.finished[0].id, 0);
        assert_eq!(out.cache_bytes_in_use, 0);
    }

    /// A source that dumps its whole burst at step 0, ignoring the queue's
    /// remaining capacity — the shape of a network source, which cannot
    /// hold remote submissions back.
    struct Burst {
        reqs: Vec<ServeRequest>,
        sent: bool,
        shed: Vec<u64>,
    }

    impl RequestSource for Burst {
        fn poll(&mut self, _step: usize, _queue_free: usize) -> Vec<ServeRequest> {
            if self.sent {
                Vec::new()
            } else {
                self.sent = true;
                std::mem::take(&mut self.reqs)
            }
        }
        fn take_cancelled(&mut self, _step: usize) -> Vec<u64> {
            Vec::new()
        }
        fn closed(&self) -> bool {
            self.sent
        }
        fn rejected(&mut self, req: &ServeRequest, _queue: usize, _cap: usize) {
            self.shed.push(req.id);
        }
    }

    #[test]
    fn overflowing_burst_is_rejected_not_blocked() {
        let m = model();
        let opts = EngineOptions {
            policy: policy(1, 0, 2), // queue_cap 2
            temperature: 0.0,
            top_k: 0,
            ..EngineOptions::default()
        };
        let reqs: Vec<ServeRequest> =
            requests(4, 2, 11).into_iter().map(|(_, r)| r).collect();
        let mut src = Burst { reqs, sent: false, shed: Vec::new() };
        let mut rejected_events = Vec::new();
        let out = ServeEngine::new(&m, opts)
            .run_source(&mut src, &mut |e| {
                if let ServeEvent::Rejected { id, queue, cap, .. } = e {
                    rejected_events.push((*id, *queue, *cap));
                }
            })
            .unwrap();
        assert_eq!(out.rejected, 2, "burst of 4 against 2 queue slots sheds 2");
        assert_eq!(src.shed, vec![2, 3], "the overflow tail is shed in order");
        assert_eq!(rejected_events, vec![(2, 2, 2), (3, 2, 2)]);
        let mut ids: Vec<u64> = out.finished.iter().map(|f| f.id).collect();
        ids.sort_unstable();
        assert_eq!(ids, vec![0, 1], "accepted requests still drain");
        assert_eq!(out.cache_bytes_in_use, 0);
    }

    #[test]
    fn latency_stats_populate_on_finish() {
        let m = model();
        let opts = EngineOptions {
            policy: policy(2, 0, 16),
            temperature: 0.0,
            top_k: 0,
            ..EngineOptions::default()
        };
        let out = ServeEngine::new(&m, opts).run(requests(1, 4, 11), &mut |_| {}).unwrap();
        let f = &out.finished[0];
        assert!(f.ttft_secs > 0.0, "first token lands after enqueue");
        assert!(f.gap_p50_secs >= 0.0 && f.gap_p95_secs >= f.gap_p50_secs);
    }

    #[test]
    fn obs_counters_and_gauges_track_the_run() {
        let m = model();
        let opts = EngineOptions {
            policy: policy(2, 1, 16),
            temperature: 0.0,
            top_k: 0,
            ..EngineOptions::default()
        };
        let obs = Obs::new(crate::obs::Clock::mock(1_000));
        let out = ServeEngine::new(&m, opts)
            .with_obs(obs.clone())
            .run(requests(5, 3, 11), &mut |_| {})
            .unwrap();
        let s = obs.snapshot();
        assert_eq!(s.counter("tokens_decoded_total"), Some(out.tokens as u64));
        assert_eq!(s.counter("tokens_prefilled_total"), Some(out.prefill_tokens as u64));
        assert_eq!(s.counter("steps_total"), Some(out.steps as u64));
        assert_eq!(s.counter("requests_enqueued_total"), Some(5));
        assert_eq!(s.counter("requests_admitted_total"), Some(5));
        assert_eq!(s.counter("requests_finished_total"), Some(5));
        assert_eq!(s.counter("cache_evictions_total"), Some(out.cache_evictions as u64));
        assert_eq!(s.counter("ttft_anchor_missing_total"), Some(0));
        assert_eq!(s.gauge("queue_depth"), Some(0), "drained queue");
        assert_eq!(s.gauge("cache_bytes_in_use"), Some(0), "drained budget");
        assert_eq!(s.gauge("cache_bytes_peak"), Some(out.peak_cache_bytes));
        assert!(s.gauge("queue_depth_peak").unwrap() >= 1);
        assert!(s.hist("batch_size").unwrap().count > 0);
        assert!(s.hist("phase_decode_ns").unwrap().count > 0);
        // mock clock: each timed phase is exactly one tick, so the prefill
        // histogram sums to one tick per admitted request
        assert_eq!(s.hist("phase_prefill_ns").unwrap().sum, 5 * 1_000);
        assert!(!s.workers.is_empty(), "engine pool rides in the snapshot");
    }

    #[test]
    fn snap_every_emits_periodic_and_drain_snapshots() {
        let m = model();
        let opts = EngineOptions {
            policy: policy(2, 1, 16),
            temperature: 0.0,
            top_k: 0,
            snap_every: 1,
            ..EngineOptions::default()
        };
        let obs = Obs::new(crate::obs::Clock::mock(1_000));
        let mut snaps = Vec::new();
        let out = ServeEngine::new(&m, opts)
            .with_obs(obs)
            .run(requests(3, 2, 11), &mut |e| {
                if let ServeEvent::MetricsSnapshot { snapshot } = e {
                    snaps.push(snapshot.clone());
                }
            })
            .unwrap();
        // one per step (idle ticks included) plus the drain snapshot
        assert_eq!(snaps.len(), out.steps + 1);
        let last = snaps.last().unwrap();
        match last {
            Json::Obj(o) => {
                assert_eq!(o.get("tokens_decoded_total"), Some(&Json::Num(out.tokens as f64)));
                // generations stamp the emission order, one per snapshot
                assert_eq!(o.get("generation"), Some(&Json::Num((out.steps + 1) as f64)));
            }
            other => panic!("snapshot event carries an object, got {other:?}"),
        }
    }

    fn save_fleet_variants(dir: &std::path::Path) -> Vec<(String, std::path::PathBuf)> {
        use crate::model::sparse_store::SparseStore;
        use crate::sparse::PackFormat;
        let cfg = ModelCfg::from_dims("engine-test", 8, 1, 2, 1, 1, 11, 4);
        std::fs::create_dir_all(dir).unwrap();
        let mut out = Vec::new();
        for (name, fmt) in [("va", PackFormat::Dense), ("vb", PackFormat::Csr)] {
            let fp = init_params(&cfg, 0);
            let store = SparseStore::pack(&fp, &PackPolicy::with_format(fmt), name).unwrap();
            let path = dir.join(format!("{name}.spkt"));
            store.save(&path).unwrap();
            out.push((name.to_string(), path));
        }
        out
    }

    #[test]
    fn fleet_routes_per_request_and_drains_residency() {
        use crate::serve::fleet::ModelFleet;
        let dir = std::env::temp_dir()
            .join(format!("sgpt_engine_fleet_{}", std::process::id()));
        let variants = save_fleet_variants(&dir);
        let m = model();
        let fleet = ModelFleet::new(&m.cfg, &variants, 0).unwrap();
        let opts = EngineOptions {
            policy: policy(4, 0, 16),
            temperature: 0.0,
            top_k: 0,
            ..EngineOptions::default()
        };
        let mut reqs = requests(3, 2, 11);
        reqs[1].1.model = Some("va".to_string());
        reqs[2].1.model = Some("vb".to_string());
        let (mut loaded, mut evicted) = (Vec::new(), Vec::new());
        let out = ServeEngine::new(&m, opts)
            .with_fleet(fleet)
            .run(reqs, &mut |e| match e {
                ServeEvent::ModelLoaded { name, .. } => loaded.push(name.clone()),
                ServeEvent::ModelEvicted { name, .. } => evicted.push(name.clone()),
                _ => {}
            })
            .unwrap();
        std::fs::remove_dir_all(&dir).ok();
        assert_eq!(out.finished.len(), 3, "routed and default requests all drain");
        loaded.sort();
        assert_eq!(loaded, vec!["va", "vb"], "each variant loads lazily, once");
        evicted.sort();
        assert_eq!(evicted, vec!["va", "vb"], "drain evicts every resident variant");
    }

    #[test]
    fn unknown_model_name_is_rejected_at_enqueue() {
        let m = model();
        let opts = EngineOptions {
            policy: policy(2, 0, 16),
            temperature: 0.0,
            top_k: 0,
            ..EngineOptions::default()
        };
        // no fleet attached: any named model is unknown and must shed
        // immediately instead of failing the run at admission
        let mut reqs = requests(2, 2, 11);
        reqs[1].1.model = Some("ghost".to_string());
        let mut shed = Vec::new();
        let out = ServeEngine::new(&m, opts)
            .run(reqs, &mut |e| {
                if let ServeEvent::Rejected { id, .. } = e {
                    shed.push(*id);
                }
            })
            .unwrap();
        assert_eq!(out.rejected, 1);
        assert_eq!(shed, vec![1]);
        assert_eq!(out.finished.len(), 1);
        assert_eq!(out.finished[0].id, 0, "the default-model request still drains");
    }

    #[test]
    fn percentile_is_nearest_rank() {
        assert_eq!(percentile_sorted(&[], 0.5), 0.0);
        assert_eq!(percentile_sorted(&[7.0], 0.95), 7.0);
        assert_eq!(percentile_sorted(&[1.0, 2.0, 3.0], 0.5), 2.0);
        assert_eq!(percentile_sorted(&[1.0, 2.0, 3.0, 4.0], 0.95), 4.0);
    }
}
