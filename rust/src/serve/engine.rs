//! The continuous-batching decode loop: joins queued requests into the
//! running batch each step, decodes one token for every in-flight request
//! through the sparse model, retires finished requests, and narrates the
//! lifecycle (`Enqueued` → `BatchFormed` → `Finished` → `Drained`) through
//! a hook the api layer maps onto the structured event stream.

use std::time::Instant;

use anyhow::Result;

use crate::eval::generate::pick_token;
use crate::serve::model::SparseModel;
use crate::serve::scheduler::{Scheduler, SchedulerPolicy, ServeRequest};
use crate::util::prng::Rng;

/// Sampling + batching knobs shared by every request of a run.
#[derive(Clone, Copy, Debug)]
pub struct EngineOptions {
    pub policy: SchedulerPolicy,
    pub temperature: f64,
    pub top_k: usize,
}

impl Default for EngineOptions {
    fn default() -> EngineOptions {
        EngineOptions { policy: SchedulerPolicy::default(), temperature: 0.8, top_k: 40 }
    }
}

/// Lifecycle notifications (the api layer turns these into
/// `request-enqueued` / `batch-formed` / `request-finished` /
/// `engine-drained` JSONL events).
#[derive(Clone, Debug)]
pub enum ServeEvent {
    Enqueued { id: u64, step: usize, prompt_tokens: usize, max_new_tokens: usize },
    BatchFormed { step: usize, joined: usize, batch: usize },
    Finished { id: u64, step: usize, tokens: usize },
    Drained { steps: usize, requests: usize, tokens: usize, decode_secs: f64 },
}

/// One retired request with its generated tokens.
#[derive(Clone, Debug)]
pub struct FinishedRequest {
    pub id: u64,
    pub prompt_tokens: usize,
    pub tokens: Vec<i32>,
    pub joined_step: usize,
    pub finished_step: usize,
}

/// What a drained engine run produced.
#[derive(Clone, Debug)]
pub struct EngineOutcome {
    pub finished: Vec<FinishedRequest>,
    pub steps: usize,
    pub tokens: usize,
    /// wall time inside `decode_step` only (scheduling excluded)
    pub decode_secs: f64,
}

impl EngineOutcome {
    pub fn tokens_per_sec(&self) -> f64 {
        if self.decode_secs > 0.0 {
            self.tokens as f64 / self.decode_secs
        } else {
            0.0
        }
    }
}

/// A request currently in the decode batch.
struct Active {
    req: ServeRequest,
    /// full sliding context (left-filled prompt + generated tokens)
    ctx: Vec<i32>,
    generated: Vec<i32>,
    rng: Rng,
    joined_step: usize,
}

/// Left-fill a prompt to a full `seq` window by repeating it (the model has
/// no pad token — same convention as `eval::generate::sample`).
pub fn left_fill_window(prompt: &[i32], seq: usize) -> Vec<i32> {
    let mut ctx: Vec<i32> = prompt.to_vec();
    while ctx.len() < seq {
        let take = (seq - ctx.len()).min(prompt.len().max(1));
        ctx.splice(0..0, prompt.iter().cloned().take(take));
        if prompt.is_empty() {
            ctx.splice(0..0, [0]);
        }
    }
    ctx
}

/// The serving engine: owns the scheduler, borrows the model.
pub struct ServeEngine<'a> {
    model: &'a SparseModel,
    opts: EngineOptions,
}

impl<'a> ServeEngine<'a> {
    pub fn new(model: &'a SparseModel, opts: EngineOptions) -> ServeEngine<'a> {
        ServeEngine { model, opts }
    }

    /// Run the workload to drain: `incoming` is (arrival step, request)
    /// pairs — requests become visible to the scheduler at their arrival
    /// step, which is how a synthetic run exercises join/retire churn.
    pub fn run(
        &self,
        mut incoming: Vec<(usize, ServeRequest)>,
        on_event: &mut dyn FnMut(&ServeEvent),
    ) -> Result<EngineOutcome> {
        incoming.sort_by_key(|(step, _)| *step); // stable: FIFO within a step
        let seq = self.model.cfg.seq;
        let vocab = self.model.cfg.vocab;
        let mut sched = Scheduler::new(self.opts.policy);
        let mut active: Vec<Active> = Vec::new();
        let mut finished: Vec<FinishedRequest> = Vec::new();
        let mut next_arrival = 0usize;
        let mut step = 0usize;
        let mut tokens = 0usize;
        let mut decode_secs = 0.0f64;

        loop {
            // arrivals visible at this step enter the bounded queue; when it
            // is full, the engine holds its own arrivals back (backpressure)
            // and retries them on later steps once decode drains the queue
            while next_arrival < incoming.len() && incoming[next_arrival].0 <= step {
                if !sched.has_capacity() {
                    break;
                }
                let req = incoming[next_arrival].1.clone();
                let (id, prompt_tokens, max_new_tokens) =
                    (req.id, req.prompt.len(), req.max_new_tokens);
                sched.submit(req)?;
                on_event(&ServeEvent::Enqueued { id, step, prompt_tokens, max_new_tokens });
                next_arrival += 1;
            }
            // batch formation: joiners ride this very step
            let joined = sched.admit(active.len());
            if !joined.is_empty() {
                let n = joined.len();
                for req in joined {
                    active.push(Active {
                        ctx: left_fill_window(&req.prompt, seq),
                        generated: Vec::with_capacity(req.max_new_tokens),
                        rng: Rng::new(req.seed ^ 0x5e21e),
                        joined_step: step,
                        req,
                    });
                }
                on_event(&ServeEvent::BatchFormed { step, joined: n, batch: active.len() });
            }
            if active.is_empty() {
                if next_arrival >= incoming.len() && sched.is_empty() {
                    break; // drained
                }
                step += 1; // idle tick: waiting on arrivals or the batch window
                continue;
            }

            // one batched next-token step for every in-flight request
            let mut windows = Vec::with_capacity(active.len() * seq);
            for a in &active {
                windows.extend_from_slice(&a.ctx[a.ctx.len() - seq..]);
            }
            let t0 = Instant::now();
            let logits = self.model.decode_step(&windows, active.len())?;
            decode_secs += t0.elapsed().as_secs_f64();
            for (i, a) in active.iter_mut().enumerate() {
                let row = &logits.data()[i * vocab..(i + 1) * vocab];
                let t = pick_token(row, self.opts.temperature, self.opts.top_k, &mut a.rng);
                a.ctx.push(t);
                a.generated.push(t);
                tokens += 1;
            }
            // retire satisfied requests (batch order preserved for the rest)
            let mut i = 0;
            while i < active.len() {
                if active[i].generated.len() >= active[i].req.max_new_tokens {
                    let a = active.remove(i);
                    on_event(&ServeEvent::Finished {
                        id: a.req.id,
                        step,
                        tokens: a.generated.len(),
                    });
                    finished.push(FinishedRequest {
                        id: a.req.id,
                        prompt_tokens: a.req.prompt.len(),
                        tokens: a.generated,
                        joined_step: a.joined_step,
                        finished_step: step,
                    });
                } else {
                    i += 1;
                }
            }
            step += 1;
        }
        let outcome = EngineOutcome { finished, steps: step, tokens, decode_secs };
        on_event(&ServeEvent::Drained {
            steps: outcome.steps,
            requests: outcome.finished.len(),
            tokens: outcome.tokens,
            decode_secs: outcome.decode_secs,
        });
        Ok(outcome)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::config::ModelCfg;
    use crate::model::init::init_params;
    use crate::sparse::PackPolicy;
    use crate::util::prng::Rng as TestRng;

    fn model() -> SparseModel {
        let cfg = ModelCfg::from_dims("engine-test", 8, 1, 2, 1, 1, 11, 4);
        SparseModel::from_params(&init_params(&cfg, 0), &PackPolicy::default()).unwrap()
    }

    fn requests(n: usize, tokens: usize, vocab: usize) -> Vec<(usize, ServeRequest)> {
        let mut rng = TestRng::new(0);
        (0..n)
            .map(|i| {
                let prompt: Vec<i32> = (0..3).map(|_| rng.below(vocab) as i32).collect();
                (i, ServeRequest { id: i as u64, prompt, max_new_tokens: tokens, seed: i as u64 })
            })
            .collect()
    }

    #[test]
    fn drains_all_requests_and_counts_tokens() {
        let m = model();
        let opts = EngineOptions {
            policy: SchedulerPolicy { max_batch: 2, max_wait: 1, queue_cap: 16 },
            temperature: 0.0,
            top_k: 0,
        };
        let mut events = Vec::new();
        let out = ServeEngine::new(&m, opts)
            .run(requests(5, 3, 11), &mut |e| events.push(e.clone()))
            .unwrap();
        assert_eq!(out.finished.len(), 5);
        assert_eq!(out.tokens, 15);
        assert!(out.finished.iter().all(|f| f.tokens.len() == 3));
        // ids all retire exactly once
        let mut ids: Vec<u64> = out.finished.iter().map(|f| f.id).collect();
        ids.sort_unstable();
        assert_eq!(ids, vec![0, 1, 2, 3, 4]);
        // lifecycle shape: 5 enqueues, >=1 batch, 5 finishes, 1 drain
        let count = |f: fn(&ServeEvent) -> bool| events.iter().filter(|e| f(e)).count();
        assert_eq!(count(|e| matches!(e, ServeEvent::Enqueued { .. })), 5);
        assert!(count(|e| matches!(e, ServeEvent::BatchFormed { .. })) >= 2);
        assert_eq!(count(|e| matches!(e, ServeEvent::Finished { .. })), 5);
        assert_eq!(count(|e| matches!(e, ServeEvent::Drained { .. })), 1);
    }

    #[test]
    fn staggered_arrivals_join_mid_flight() {
        let m = model();
        let opts = EngineOptions {
            policy: SchedulerPolicy { max_batch: 4, max_wait: 0, queue_cap: 16 },
            temperature: 0.0,
            top_k: 0,
        };
        // request 1 arrives while request 0 is mid-decode
        let mut reqs = requests(2, 4, 11);
        reqs[1].0 = 2;
        let mut joins = Vec::new();
        let out = ServeEngine::new(&m, opts)
            .run(reqs, &mut |e| {
                if let ServeEvent::BatchFormed { batch, .. } = e {
                    joins.push(*batch);
                }
            })
            .unwrap();
        assert_eq!(out.finished.len(), 2);
        assert_eq!(joins, vec![1, 2], "second request joined the running batch");
    }

    #[test]
    fn full_queue_defers_arrivals_instead_of_failing() {
        let m = model();
        let opts = EngineOptions {
            policy: SchedulerPolicy { max_batch: 2, max_wait: 0, queue_cap: 2 },
            temperature: 0.0,
            top_k: 0,
        };
        // 6 requests bunched at step 0 against 2 queue slots: the engine
        // must hold arrivals back and still drain everything
        let mut reqs = requests(6, 2, 11);
        for r in reqs.iter_mut() {
            r.0 = 0;
        }
        let out = ServeEngine::new(&m, opts).run(reqs, &mut |_| {}).unwrap();
        assert_eq!(out.finished.len(), 6);
        assert_eq!(out.tokens, 12);
    }

    #[test]
    fn deterministic_given_seeds() {
        let m = model();
        let opts = EngineOptions {
            policy: SchedulerPolicy { max_batch: 2, max_wait: 1, queue_cap: 16 },
            temperature: 0.8,
            top_k: 5,
        };
        let run = || {
            ServeEngine::new(&m, opts)
                .run(requests(3, 4, 11), &mut |_| {})
                .unwrap()
                .finished
                .iter()
                .map(|f| (f.id, f.tokens.clone()))
                .collect::<Vec<_>>()
        };
        assert_eq!(run(), run());
    }

    #[test]
    fn left_fill_repeats_prompt() {
        assert_eq!(left_fill_window(&[7, 8], 5), vec![7, 7, 8, 7, 8]);
        assert_eq!(left_fill_window(&[1, 2, 3, 4, 5, 6], 4), vec![1, 2, 3, 4, 5, 6]);
        assert_eq!(left_fill_window(&[], 3), vec![0, 0, 0]);
    }
}
