//! The serving subsystem: packed sparse checkpoints executed through the
//! Table-7/8 CPU sparse kernels behind a continuous-batching scheduler —
//! the paper's "more than 100 billion weights can be ignored at inference
//! time" made operational.
//!
//! * [`SparseModel`] (`model.rs`) — the sparse decode path: every prunable
//!   linear runs in its packed format (CSR / n:m / dense fallback), one
//!   shared forward so packed decode is element-identical to dense decode.
//!   Two executions of the same banded-attention definition: the uncached
//!   full re-forward ([`SparseModel::forward_logits`]) and the incremental
//!   KV-cached path ([`SparseModel::prefill`] +
//!   [`SparseModel::decode_cached`]) — token-for-token identical.
//! * [`KvCache`] (`kv.rs`) — per-request ring-buffered key/value rows
//!   (capacity `cfg.seq`, eviction = slot reuse) plus the [`CacheBudget`]
//!   memory accounting the scheduler applies backpressure against.
//! * [`Scheduler`] (`scheduler.rs`) — bounded request queue + cost-aware
//!   batch formation (join running batches immediately, wait bounded time
//!   for a full batch from idle, spread prefill bursts, respect the
//!   cache-memory budget).
//! * [`ModelFleet`] (`fleet.rs`) — named `.spkt` variants of one config
//!   served from one process: per-request `model=` routing, lazy
//!   mmap-backed loads, LRU weight-residency budget.
//! * [`ServeEngine`] (`engine.rs`) — the decode loop: poll the
//!   [`RequestSource`] for live intake, admit, chunked prefill on join,
//!   one incremental token per request per step, retire (freeing the
//!   cache), propagate disconnects as cancellation, narrate lifecycle
//!   events.
//! * [`Router`] (`router.rs`) — the admission router: one intake fanned
//!   out to N engine replicas (each with its own worker pool and KV
//!   budget slice, sharing read-only mapped weights), least-outstanding-
//!   tokens routing with sticky request→replica ownership, 429s only
//!   when every replica's bounded queue is full.
//! * `net` (`net/`) — the TCP front door: a framed newline-delimited-JSON
//!   protocol (`net/protocol.rs`), a `std::net` listener with per-connection
//!   reader threads feeding the engine's intake queue (`net/server.rs`,
//!   `net/conn.rs`), and the loopback client the CLI/tests drive it with
//!   (`net/client.rs`).
//!
//! Telemetry: every layer writes into a shared [`Obs`](crate::obs::Obs)
//! registry — engine counters/phase spans, scheduler queue depth, cache
//! gauges, per-connection net traffic — and one snapshot feeds the `stats`
//! frame, the `metrics-snapshot` event, and the Prometheus text dump.

pub mod engine;
pub mod fleet;
pub mod kv;
pub mod model;
pub mod net;
pub mod router;
pub mod scheduler;

pub use engine::{
    percentile_sorted, EngineOptions, EngineOutcome, FinishedRequest, RequestSource, ServeEngine,
    ServeEvent, SyntheticSource, DEFAULT_PREFILL_CHUNK,
};
pub use fleet::{FleetEvent, ModelFleet};
pub use kv::{CacheBudget, KvCache};
pub use model::SparseModel;
pub use router::{Router, RouterOutcome};
pub use scheduler::{Scheduler, SchedulerPolicy, ServeRequest, StepLimits};
