//! The serving subsystem: packed sparse checkpoints executed through the
//! Table-7/8 CPU sparse kernels behind a continuous-batching scheduler —
//! the paper's "more than 100 billion weights can be ignored at inference
//! time" made operational.
//!
//! * [`SparseModel`] (`model.rs`) — the sparse decode path: every prunable
//!   linear runs in its packed format (CSR / n:m / dense fallback), one
//!   shared forward so packed decode is element-identical to dense decode.
//! * [`Scheduler`] (`scheduler.rs`) — bounded request queue + batch
//!   formation (join running batches immediately, wait bounded time for a
//!   full batch from idle).
//! * [`ServeEngine`] (`engine.rs`) — the decode loop: admit, batch-decode
//!   one token per request per step, retire, narrate lifecycle events.

pub mod engine;
pub mod model;
pub mod scheduler;

pub use engine::{
    left_fill_window, EngineOptions, EngineOutcome, FinishedRequest, ServeEngine, ServeEvent,
};
pub use model::SparseModel;
pub use scheduler::{Scheduler, SchedulerPolicy, ServeRequest};
