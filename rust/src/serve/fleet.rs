//! Multi-model residency for one serve process: a registry of named `.spkt`
//! variants of the *same* config (e.g. the dense baseline next to 50%
//! SparseGPT and 2:4+4-bit — the paper's Table-7/8 grid served side by
//! side), loaded lazily on first request and held under an LRU
//! weight-residency budget.
//!
//! The default model (the one the engine was built with) is *not* a fleet
//! entry: it is always resident and requests that name no model route to
//! it, so single-model runs are byte-for-byte unaffected by the fleet's
//! existence. Named variants resolve at admission: a cache hit just bumps
//! LRU recency; a miss maps the variant's `.spkt` ([`SparseStore::load`] —
//! weights served straight from the mapped pages) and, if the resident
//! bytes would exceed the budget, evicts least-recently-used variants
//! first. Eviction drops the registry's `Arc` only — in-flight requests
//! keep their model (and its mapped pages) alive until they retire, so
//! eviction can never corrupt a running decode.
//!
//! Accounting reuses [`CacheBudget`] with weight bytes as the unit, the
//! same pattern the KV path uses for cache memory: `total == 0` means
//! unlimited, and a budget smaller than a single variant still serves one
//! at a time (floor of one resident, mirroring the engine's cache floor).

use std::collections::BTreeMap;
use std::path::PathBuf;
use std::sync::Arc;

use anyhow::{anyhow, bail, Context, Result};

use crate::model::config::ModelCfg;
use crate::model::sparse_store::SparseStore;
use crate::serve::kv::CacheBudget;
use crate::serve::model::SparseModel;

/// Residency changes from one [`ModelFleet::resolve`] or
/// [`ModelFleet::evict_all`] — the engine forwards these as
/// `model-loaded` / `model-evicted` events.
#[derive(Clone, Debug, PartialEq)]
pub enum FleetEvent {
    Loaded { name: String, bytes: u64, mapped: u64 },
    Evicted { name: String, bytes: u64 },
}

struct FleetEntry {
    path: PathBuf,
    model: Option<Arc<SparseModel>>,
    /// weight bytes reserved while resident (0 otherwise)
    bytes: u64,
    /// resolve tick of the last request that touched this variant
    last_used: u64,
}

/// Named model variants behind one serve process (see module docs).
pub struct ModelFleet {
    /// the default model's config — every variant must serve it, so all
    /// variants share vocab/seq/d and one KV-cache geometry
    cfg: ModelCfg,
    entries: BTreeMap<String, FleetEntry>,
    budget: CacheBudget,
    tick: u64,
}

impl ModelFleet {
    /// Register `variants` as (name, `.spkt` path) pairs under a resident
    /// weight budget in bytes (0 = unlimited). Nothing is loaded yet.
    pub fn new(
        cfg: &ModelCfg,
        variants: &[(String, PathBuf)],
        budget_bytes: u64,
    ) -> Result<ModelFleet> {
        let mut entries = BTreeMap::new();
        for (name, path) in variants {
            if name.is_empty() {
                bail!("fleet model name must be non-empty");
            }
            let entry =
                FleetEntry { path: path.clone(), model: None, bytes: 0, last_used: 0 };
            if entries.insert(name.clone(), entry).is_some() {
                bail!("duplicate fleet model name {name:?}");
            }
        }
        Ok(ModelFleet {
            cfg: cfg.clone(),
            entries,
            budget: CacheBudget::new(budget_bytes),
            tick: 0,
        })
    }

    /// Registered variant names, sorted.
    pub fn names(&self) -> Vec<String> {
        self.entries.keys().cloned().collect()
    }

    pub fn contains(&self, name: &str) -> bool {
        self.entries.contains_key(name)
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Variants currently resident (the `models_resident` gauge).
    pub fn resident_models(&self) -> usize {
        self.entries.values().filter(|e| e.model.is_some()).count()
    }

    /// Weight bytes reserved by resident variants.
    pub fn resident_bytes(&self) -> u64 {
        self.budget.in_use()
    }

    /// Resident weight bytes served straight from mapped `.spkt` pages
    /// (feeds the `weight_bytes_mapped` gauge alongside the default
    /// model's own mapping).
    pub fn mapped_bytes(&self) -> u64 {
        self.entries
            .values()
            .filter_map(|e| e.model.as_ref())
            .map(|m| m.mapped_bytes())
            .sum()
    }

    /// Resolve a variant by name: bump recency on a hit; on a miss, map
    /// its `.spkt`, validate it against the default config, evict LRU
    /// residents until the budget fits (never the variant being loaded),
    /// and make it resident. Residency changes append to `events`.
    pub fn resolve(
        &mut self,
        name: &str,
        events: &mut Vec<FleetEvent>,
    ) -> Result<Arc<SparseModel>> {
        self.tick += 1;
        let tick = self.tick;
        let entry = self
            .entries
            .get_mut(name)
            .ok_or_else(|| anyhow!("unknown fleet model {name:?}"))?;
        if let Some(m) = &entry.model {
            entry.last_used = tick;
            return Ok(m.clone());
        }
        let path = entry.path.clone();
        let store = SparseStore::load(&path)
            .with_context(|| format!("loading fleet model {name:?}"))?;
        let model = Arc::new(SparseModel::from_store(&store, &self.cfg).with_context(|| {
            format!("fleet model {name:?} does not serve config {:?}", self.cfg.name)
        })?);
        // a packed store is never truly free; a 1-byte floor keeps the
        // LRU ordering meaningful even for degenerate test fixtures
        let bytes = model.weight_bytes().max(1);
        while self.budget.total() > 0
            && self.budget.in_use() > 0
            && self.budget.in_use() + bytes > self.budget.total()
        {
            let victim = self
                .entries
                .iter()
                .filter(|(n, e)| e.model.is_some() && n.as_str() != name)
                .min_by_key(|(_, e)| e.last_used)
                .map(|(n, _)| n.clone());
            let Some(victim) = victim else { break };
            self.evict(&victim, events);
        }
        let entry = self.entries.get_mut(name).expect("checked above");
        entry.model = Some(model.clone());
        entry.bytes = bytes;
        entry.last_used = tick;
        self.budget.reserve(bytes);
        events.push(FleetEvent::Loaded {
            name: name.to_string(),
            bytes,
            mapped: model.mapped_bytes(),
        });
        Ok(model)
    }

    fn evict(&mut self, name: &str, events: &mut Vec<FleetEvent>) {
        let Some(entry) = self.entries.get_mut(name) else { return };
        if entry.model.take().is_some() {
            self.budget.release(entry.bytes);
            events.push(FleetEvent::Evicted { name: name.to_string(), bytes: entry.bytes });
            entry.bytes = 0;
        }
    }

    /// Drop every resident variant (the engine's drain path): the
    /// residency budget must return to zero.
    pub fn evict_all(&mut self, events: &mut Vec<FleetEvent>) {
        let names: Vec<String> = self.entries.keys().cloned().collect();
        for name in names {
            self.evict(&name, events);
        }
        debug_assert_eq!(self.budget.in_use(), 0, "evict_all must drain the residency budget");
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::init::init_params;
    use crate::model::layout::PRUNABLE_KINDS;
    use crate::solver::magnitude::magnitude_prune;
    use crate::sparse::{PackFormat, PackPolicy};

    fn test_cfg() -> ModelCfg {
        ModelCfg::from_dims("fleet-test", 8, 2, 2, 1, 1, 13, 6)
    }

    /// Save one variant per pack format into `dir`; returns (name, path).
    fn save_variants(dir: &std::path::Path) -> Vec<(String, PathBuf)> {
        let cfg = test_cfg();
        let mut fp = init_params(&cfg, 3);
        for layer in 0..cfg.layers {
            for kind in PRUNABLE_KINDS {
                let w = magnitude_prune(&fp.get_linear(kind, layer).unwrap(), 0.5).0;
                fp.set_linear(kind, layer, &w).unwrap();
            }
        }
        let mut out = Vec::new();
        for (name, fmt) in [
            ("dense", PackFormat::Dense),
            ("csr", PackFormat::Csr),
            ("q4", PackFormat::QCsr { bits: 4, group: 4 }),
        ] {
            let store =
                SparseStore::pack(&fp, &PackPolicy::with_format(fmt), name).unwrap();
            let path = dir.join(format!("{name}.spkt"));
            store.save(&path).unwrap();
            out.push((name.to_string(), path));
        }
        out
    }

    fn tmp(tag: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!("sgpt_fleet_{tag}_{}", std::process::id()));
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    #[test]
    fn lazy_load_hit_and_unknown_name() {
        let dir = tmp("lazy");
        let variants = save_variants(&dir);
        let mut fleet = ModelFleet::new(&test_cfg(), &variants, 0).unwrap();
        assert_eq!(fleet.resident_models(), 0, "nothing loads at registration");

        let mut ev = Vec::new();
        let a = fleet.resolve("csr", &mut ev).unwrap();
        assert_eq!(fleet.resident_models(), 1);
        assert_eq!(ev.len(), 1);
        assert!(matches!(&ev[0], FleetEvent::Loaded { name, .. } if name == "csr"));

        // a hit returns the same Arc and emits nothing
        ev.clear();
        let b = fleet.resolve("csr", &mut ev).unwrap();
        assert!(Arc::ptr_eq(&a, &b));
        assert!(ev.is_empty());

        assert!(fleet.resolve("nope", &mut ev).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn lru_eviction_under_budget_and_drain_to_zero() {
        let dir = tmp("lru");
        let variants = save_variants(&dir);
        let mut fleet = ModelFleet::new(&test_cfg(), &variants, 0).unwrap();
        // budget sized for roughly one variant: find one variant's bytes
        let mut ev = Vec::new();
        let one = fleet.resolve("csr", &mut ev).unwrap().weight_bytes();
        fleet.evict_all(&mut ev);
        ev.clear();

        let mut fleet =
            ModelFleet::new(&test_cfg(), &variants, one + one / 2).unwrap();
        fleet.resolve("csr", &mut ev).unwrap();
        fleet.resolve("dense", &mut ev).unwrap();
        // the second load must have pushed out the least-recent (csr)
        assert!(
            ev.iter().any(|e| matches!(e, FleetEvent::Evicted { name, .. } if name == "csr")),
            "{ev:?}"
        );
        assert!(fleet.resident_bytes() <= one + one / 2);

        // touch dense, load q4: dense is now most recent, csr not resident
        ev.clear();
        fleet.resolve("dense", &mut ev).unwrap();
        fleet.resolve("q4", &mut ev).unwrap();
        assert!(fleet.resident_models() >= 1);

        // drain: residency budget returns to zero, one Evicted per resident
        ev.clear();
        fleet.evict_all(&mut ev);
        assert_eq!(fleet.resident_models(), 0);
        assert_eq!(fleet.resident_bytes(), 0);
        assert!(!ev.is_empty());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn eviction_never_invalidates_a_held_model() {
        let dir = tmp("held");
        let variants = save_variants(&dir);
        let mut fleet = ModelFleet::new(&test_cfg(), &variants, 1).unwrap();
        let mut ev = Vec::new();
        let held = fleet.resolve("csr", &mut ev).unwrap();
        // 1-byte budget: loading dense evicts csr from the registry...
        fleet.resolve("dense", &mut ev).unwrap();
        assert!(
            ev.iter().any(|e| matches!(e, FleetEvent::Evicted { name, .. } if name == "csr"))
        );
        // ...but the held Arc still decodes (mapped pages stay alive)
        assert!(held.weight_bytes() > 0);
        assert_eq!(held.cfg.name, "fleet-test");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn rejects_duplicate_and_empty_names() {
        let cfg = test_cfg();
        let v = |n: &str| (n.to_string(), PathBuf::from("/x.spkt"));
        assert!(ModelFleet::new(&cfg, &[v("a"), v("a")], 0).is_err());
        assert!(ModelFleet::new(&cfg, &[v("")], 0).is_err());
        let fleet = ModelFleet::new(&cfg, &[v("a"), v("b")], 0).unwrap();
        assert_eq!(fleet.names(), vec!["a", "b"]);
        assert!(fleet.contains("a") && !fleet.contains("c"));
    }

    #[test]
    fn wrong_config_variant_fails_resolve() {
        let dir = tmp("wrongcfg");
        let variants = save_variants(&dir);
        let other = ModelCfg::from_dims("other-cfg", 8, 2, 2, 1, 1, 13, 6);
        let mut fleet = ModelFleet::new(&other, &variants, 0).unwrap();
        let mut ev = Vec::new();
        assert!(fleet.resolve("csr", &mut ev).is_err());
        assert_eq!(fleet.resident_models(), 0);
        std::fs::remove_dir_all(&dir).ok();
    }
}
