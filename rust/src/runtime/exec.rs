//! Artifact execution: manifest-driven marshalling, compile cache, stats.
//!
//! All artifacts are lowered with `return_tuple=True`, so every execution
//! unwraps one tuple literal into the manifest-declared outputs. Shapes and
//! dtypes are validated against the manifest on both directions — a mismatch
//! is a build-system bug and fails loudly rather than corrupting data.

use std::cell::RefCell;
use std::collections::BTreeMap;
use std::rc::Rc;
use std::time::Instant;

use anyhow::{anyhow, bail, Context, Result};

use crate::model::config::ModelCfg;
use crate::model::manifest::{ArtifactSpec, DType, Manifest};
use crate::runtime::backend::{ArgValue, Backend, CachedLiteral, RuntimeStats};
use crate::tensor::Tensor;

/// An output value: f32 tensor (all artifact outputs are f32).
pub type OutValue = Tensor;

pub struct Runtime {
    client: xla::PjRtClient,
    pub manifest: Manifest,
    cache: RefCell<BTreeMap<String, Rc<xla::PjRtLoadedExecutable>>>,
    stats: RefCell<RuntimeStats>,
}

impl Runtime {
    /// Create a runtime over the default artifacts directory
    /// (`$SPARSEGPT_ARTIFACTS` or `./artifacts`).
    pub fn new() -> Result<Runtime> {
        Self::with_dir(Manifest::default_dir())
    }

    pub fn with_dir(dir: impl AsRef<std::path::Path>) -> Result<Runtime> {
        let manifest = Manifest::load(dir)?;
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("PJRT CPU client: {e:?}"))?;
        Ok(Runtime {
            client,
            manifest,
            cache: RefCell::new(BTreeMap::new()),
            stats: RefCell::new(BTreeMap::new()),
        })
    }

    /// Compile (or fetch from cache) an artifact's executable.
    pub fn executable(&self, name: &str) -> Result<Rc<xla::PjRtLoadedExecutable>> {
        if let Some(exe) = self.cache.borrow().get(name) {
            return Ok(exe.clone());
        }
        let spec = self.manifest.artifact(name)?;
        let t0 = Instant::now();
        let proto = xla::HloModuleProto::from_text_file(
            spec.file
                .to_str()
                .ok_or_else(|| anyhow!("non-utf8 artifact path {:?}", spec.file))?,
        )
        .map_err(|e| anyhow!("parsing HLO text {:?}: {e:?}", spec.file))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow!("XLA compile of {name:?}: {e:?}"))?;
        let exe = Rc::new(exe);
        self.cache.borrow_mut().insert(name.to_string(), exe.clone());
        let mut st = self.stats.borrow_mut();
        let e = st.entry(name.to_string()).or_default();
        e.compiles += 1;
        e.compile_secs += t0.elapsed().as_secs_f64();
        Ok(exe)
    }

    /// Drop a compiled executable (memory control for one-shot artifacts).
    pub fn evict(&self, name: &str) {
        self.cache.borrow_mut().remove(name);
    }

    pub fn stats(&self) -> RuntimeStats {
        self.stats.borrow().clone()
    }

    pub fn reset_stats(&self) {
        self.stats.borrow_mut().clear();
    }

    /// Marshal an f32 buffer once for reuse across many `run` calls (pass
    /// it as `ArgValue::Cached`). `shape` must match the artifact input it
    /// will be bound to.
    ///
    /// Note: inputs are marshalled to PjRt *buffers* and executed via
    /// `execute_b`, never via `execute(literals)` — the crate's C++ shim for
    /// the latter leaks every input buffer it creates (`buffer.release()`
    /// without a matching delete), which OOM-kills long training loops.
    pub fn cache_f32(&self, data: &[f32], shape: &[usize]) -> Result<CachedLiteral> {
        if shape.iter().product::<usize>() != data.len() {
            bail!("cache_f32: {} elements vs shape {shape:?}", data.len());
        }
        // buffer_from_host_buffer (typed) converts ElementType->PrimitiveType
        // correctly; the raw_bytes variant passes the wrong enum to the C ABI
        let buf = self.client.buffer_from_host_buffer(data, shape, None)?;
        Ok(CachedLiteral::Device { buf, numel: data.len(), dtype: DType::F32 })
    }

    /// Execute an artifact with manifest-validated inputs; returns the
    /// manifest-declared outputs as f32 tensors.
    pub fn run(&self, name: &str, args: &[ArgValue]) -> Result<Vec<Tensor>> {
        let spec = self.manifest.artifact(name)?.clone();
        let exe = self.executable(name)?;
        let tm = Instant::now();
        let owned = self
            .marshal_inputs(&spec, args)
            .with_context(|| format!("marshalling inputs of {name:?}"))?;
        // assemble the argument list, borrowing cached buffers in place
        let mut refs: Vec<&xla::PjRtBuffer> = Vec::with_capacity(args.len());
        for (arg, own) in args.iter().zip(&owned) {
            match (arg, own) {
                (ArgValue::Cached(CachedLiteral::Device { buf, .. }), _) => refs.push(buf),
                (_, Some(buf)) => refs.push(buf),
                _ => unreachable!("marshal_inputs fills every non-cached slot"),
            }
        }
        let marshal_in = tm.elapsed().as_secs_f64();

        let t0 = Instant::now();
        let result = exe
            .execute_b::<&xla::PjRtBuffer>(&refs)
            .map_err(|e| anyhow!("executing {name:?}: {e:?}"))?;
        let run_secs = t0.elapsed().as_secs_f64();

        let tm2 = Instant::now();
        let tuple = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetching result of {name:?}: {e:?}"))?;
        let parts = tuple
            .to_tuple()
            .map_err(|e| anyhow!("untupling result of {name:?}: {e:?}"))?;
        if parts.len() != spec.outputs.len() {
            bail!(
                "{name:?}: executable returned {} outputs, manifest declares {}",
                parts.len(),
                spec.outputs.len()
            );
        }
        let mut outs = Vec::with_capacity(parts.len());
        for (lit, ospec) in parts.iter().zip(&spec.outputs) {
            if ospec.dtype != DType::F32 {
                bail!("{name:?}: non-f32 outputs unsupported");
            }
            let mut data = vec![0f32; ospec.numel()];
            lit.copy_raw_to(&mut data)
                .map_err(|e| anyhow!("copying output of {name:?}: {e:?}"))?;
            let shape = if ospec.shape.is_empty() { vec![1] } else { ospec.shape.clone() };
            outs.push(Tensor::new(shape, data));
        }
        let marshal_secs = marshal_in + tm2.elapsed().as_secs_f64();

        let mut st = self.stats.borrow_mut();
        let e = st.entry(name.to_string()).or_default();
        e.runs += 1;
        e.run_secs += run_secs;
        e.marshal_secs += marshal_secs;
        Ok(outs)
    }
}

#[allow(dead_code)]
fn as_bytes<T>(xs: &[T]) -> &[u8] {
    unsafe { std::slice::from_raw_parts(xs.as_ptr() as *const u8, std::mem::size_of_val(xs)) }
}

impl Runtime {
    fn marshal_inputs(
        &self,
        spec: &ArtifactSpec,
        args: &[ArgValue],
    ) -> Result<Vec<Option<xla::PjRtBuffer>>> {
        if args.len() != spec.inputs.len() {
            bail!("expected {} inputs, got {}", spec.inputs.len(), args.len());
        }
        let mut buffers = Vec::with_capacity(args.len());
        for (i, (arg, ispec)) in args.iter().zip(&spec.inputs).enumerate() {
            let buf = match (arg, ispec.dtype) {
                (ArgValue::Cached(CachedLiteral::Device { numel, dtype, .. }), dt) => {
                    if *dtype != dt || *numel != ispec.numel() {
                        bail!(
                            "input {i}: cached buffer has {numel} elements, expected {} {:?}",
                            ispec.numel(),
                            ispec.shape
                        );
                    }
                    buffers.push(None);
                    continue;
                }
                (ArgValue::Cached(CachedLiteral::Host { .. }), _) => {
                    bail!("input {i}: host-cached literal passed to the PJRT backend");
                }
                (ArgValue::F32(xs), DType::F32) => {
                    if xs.len() != ispec.numel() {
                        bail!("input {i}: {} elements, expected {} {:?}", xs.len(), ispec.numel(), ispec.shape);
                    }
                    self.client.buffer_from_host_buffer(xs, &ispec.shape, None)?
                }
                (ArgValue::I32(xs), DType::I32) => {
                    if xs.len() != ispec.numel() {
                        bail!("input {i}: {} elements, expected {} {:?}", xs.len(), ispec.numel(), ispec.shape);
                    }
                    self.client.buffer_from_host_buffer(xs, &ispec.shape, None)?
                }
                (ArgValue::Scalar(x), DType::F32) => {
                    if !ispec.shape.is_empty() {
                        bail!("input {i}: scalar passed for shaped input {:?}", ispec.shape);
                    }
                    self.client.buffer_from_host_buffer(std::slice::from_ref(x), &[], None)?
                }
                _ => bail!("input {i}: dtype mismatch"),
            };
            buffers.push(Some(buf));
        }
        Ok(buffers)
    }
}

impl Backend for Runtime {
    fn name(&self) -> &'static str {
        "pjrt"
    }

    fn config(&self, name: &str) -> Result<ModelCfg> {
        Ok(self.manifest.config(name)?.clone())
    }

    fn has_artifact(&self, name: &str) -> bool {
        self.manifest.artifacts.contains_key(name)
    }

    fn artifact_names(&self) -> Vec<String> {
        self.manifest.artifacts.keys().cloned().collect()
    }

    fn run(&self, name: &str, args: &[ArgValue]) -> Result<Vec<Tensor>> {
        Runtime::run(self, name, args)
    }

    fn cache_f32(&self, data: &[f32], shape: &[usize]) -> Result<CachedLiteral> {
        Runtime::cache_f32(self, data, shape)
    }

    fn prepare(&self, name: &str) -> Result<()> {
        self.executable(name).map(|_| ())
    }

    fn evict(&self, name: &str) {
        Runtime::evict(self, name)
    }

    fn stats(&self) -> RuntimeStats {
        Runtime::stats(self)
    }

    fn reset_stats(&self) {
        Runtime::reset_stats(self)
    }
}
