//! Pure-Rust implementations of the model-side artifact vocabulary, used by
//! [`crate::runtime::ReferenceBackend`]: embedding, transformer block
//! forward (with activation captures and fused Hessian accumulation), NLL
//! evaluation, next-token logits, AdaPrune reconstruction, and a full
//! forward + backward + Adam training step.
//!
//! Semantics mirror `python/compile/model.py` / `train.py` exactly (OPT
//! block structure, tanh GELU, causal softmax attention, tied LM head,
//! App-A constants); math runs in f64 internally and converts to f32 at the
//! artifact boundary, so the interpreter is a *numerically stronger* oracle
//! than the f32 HLO path it stands in for.

use anyhow::{anyhow, bail, Result};

use crate::model::config::ModelCfg;
use crate::tensor::Tensor;

const LN_EPS: f64 = 1e-5;
/// sqrt(2/pi) of the tanh GELU approximation (model.py `gelu_tanh`).
const GELU_C: f64 = 0.797_884_560_802_865_4;
/// GD steps of the AdaPrune reconstruction artifact (adaprune.py).
pub const ADAPRUNE_STEPS: usize = 256;
const ADAM_B1: f64 = 0.9;
const ADAM_B2: f64 = 0.95;
const ADAM_EPS: f64 = 1e-8;
const GRAD_CLIP: f64 = 1.0;

fn f64v(xs: &[f32]) -> Vec<f64> {
    xs.iter().map(|&x| x as f64).collect()
}

fn f32v(xs: &[f64]) -> Vec<f32> {
    xs.iter().map(|&x| x as f32).collect()
}

// --------------------------------------------------------------------------
// parameter views
// --------------------------------------------------------------------------

/// Named access into a full flat parameter vector.
struct ParamView<'a> {
    cfg: &'a ModelCfg,
    flat: &'a [f32],
}

impl<'a> ParamView<'a> {
    fn new(cfg: &'a ModelCfg, flat: &'a [f32]) -> Result<ParamView<'a>> {
        if flat.len() != cfg.n_params {
            bail!(
                "parameter vector has {} elements, config {} needs {}",
                flat.len(),
                cfg.name,
                cfg.n_params
            );
        }
        Ok(ParamView { cfg, flat })
    }

    fn region(&self, name: &str) -> Result<&'a [f32]> {
        let e = self.cfg.param_entry(name).ok_or_else(|| anyhow!("no param {name:?}"))?;
        Ok(&self.flat[e.offset..e.offset + e.numel()])
    }

    /// Per-layer slice of a stacked (L, ...) region.
    fn layer(&self, name: &str, l: usize) -> Result<&'a [f32]> {
        let e = self.cfg.param_entry(name).ok_or_else(|| anyhow!("no param {name:?}"))?;
        let per = e.numel() / self.cfg.layers;
        let start = e.offset + l * per;
        Ok(&self.flat[start..start + per])
    }
}

/// One block's parameters as f64 (converted once, reused fwd + bwd).
struct BlockParams {
    ln1_g: Vec<f64>,
    ln1_b: Vec<f64>,
    wq: Vec<f64>,
    wk: Vec<f64>,
    wv: Vec<f64>,
    wo: Vec<f64>,
    ln2_g: Vec<f64>,
    ln2_b: Vec<f64>,
    w1: Vec<f64>,
    w2: Vec<f64>,
}

impl BlockParams {
    /// From a flat per-block slice (the `block_fwd` artifact input).
    fn from_slice(cfg: &ModelCfg, slice: &[f32]) -> Result<BlockParams> {
        if slice.len() != cfg.block_size {
            bail!(
                "block slice has {} elements, config {} needs {}",
                slice.len(),
                cfg.name,
                cfg.block_size
            );
        }
        let get = |name: &str| -> Result<Vec<f64>> {
            let e = cfg
                .block_entry(name)
                .ok_or_else(|| anyhow!("no block param {name:?}"))?;
            Ok(f64v(&slice[e.offset..e.offset + e.numel()]))
        };
        Ok(BlockParams {
            ln1_g: get("ln1_g")?,
            ln1_b: get("ln1_b")?,
            wq: get("wq")?,
            wk: get("wk")?,
            wv: get("wv")?,
            wo: get("wo")?,
            ln2_g: get("ln2_g")?,
            ln2_b: get("ln2_b")?,
            w1: get("w1")?,
            w2: get("w2")?,
        })
    }

    /// Layer `l`'s parameters out of the full stacked vector.
    fn from_params(view: &ParamView, l: usize) -> Result<BlockParams> {
        let get = |name: &str| -> Result<Vec<f64>> { Ok(f64v(view.layer(name, l)?)) };
        Ok(BlockParams {
            ln1_g: get("ln1_g")?,
            ln1_b: get("ln1_b")?,
            wq: get("wq")?,
            wk: get("wk")?,
            wv: get("wv")?,
            wo: get("wo")?,
            ln2_g: get("ln2_g")?,
            ln2_b: get("ln2_b")?,
            w1: get("w1")?,
            w2: get("w2")?,
        })
    }
}

// --------------------------------------------------------------------------
// primitives
// --------------------------------------------------------------------------

/// y = x @ w^T; x (rows, k), w (n, k) -> (rows, n).
fn matmul_wt(x: &[f64], rows: usize, k: usize, w: &[f64], n: usize) -> Vec<f64> {
    debug_assert_eq!(x.len(), rows * k);
    debug_assert_eq!(w.len(), n * k);
    let mut y = vec![0.0; rows * n];
    for r in 0..rows {
        let xr = &x[r * k..(r + 1) * k];
        let yr = &mut y[r * n..(r + 1) * n];
        for (o, yv) in yr.iter_mut().enumerate() {
            let wr = &w[o * k..(o + 1) * k];
            let mut s = 0.0;
            for i in 0..k {
                s += xr[i] * wr[i];
            }
            *yv = s;
        }
    }
    y
}

/// y = x @ w; x (rows, k), w (k, n) row-major -> (rows, n).
fn matmul(x: &[f64], rows: usize, k: usize, w: &[f64], n: usize) -> Vec<f64> {
    debug_assert_eq!(x.len(), rows * k);
    debug_assert_eq!(w.len(), k * n);
    let mut y = vec![0.0; rows * n];
    for r in 0..rows {
        let xr = &x[r * k..(r + 1) * k];
        let yr = &mut y[r * n..(r + 1) * n];
        for (i, &xv) in xr.iter().enumerate() {
            if xv == 0.0 {
                continue;
            }
            let wr = &w[i * n..(i + 1) * n];
            for o in 0..n {
                yr[o] += xv * wr[o];
            }
        }
    }
    y
}

/// x^T @ y; x (rows, cx), y (rows, cy) -> (cx, cy).
fn matmul_tn(x: &[f64], rows: usize, cx: usize, y: &[f64], cy: usize) -> Vec<f64> {
    debug_assert_eq!(x.len(), rows * cx);
    debug_assert_eq!(y.len(), rows * cy);
    let mut out = vec![0.0; cx * cy];
    for r in 0..rows {
        let xr = &x[r * cx..(r + 1) * cx];
        let yr = &y[r * cy..(r + 1) * cy];
        for (i, &xv) in xr.iter().enumerate() {
            if xv == 0.0 {
                continue;
            }
            let orow = &mut out[i * cy..(i + 1) * cy];
            for o in 0..cy {
                orow[o] += xv * yr[o];
            }
        }
    }
    out
}

/// Row-wise LayerNorm; returns (y, per-row (mu, rstd)).
fn layer_norm(x: &[f64], d: usize, g: &[f64], b: &[f64]) -> (Vec<f64>, Vec<(f64, f64)>) {
    let rows = x.len() / d;
    let mut y = vec![0.0; x.len()];
    let mut stats = Vec::with_capacity(rows);
    for r in 0..rows {
        let xr = &x[r * d..(r + 1) * d];
        let mu = xr.iter().sum::<f64>() / d as f64;
        let var = xr.iter().map(|&v| (v - mu) * (v - mu)).sum::<f64>() / d as f64;
        let rstd = 1.0 / (var + LN_EPS).sqrt();
        let yr = &mut y[r * d..(r + 1) * d];
        for i in 0..d {
            yr[i] = (xr[i] - mu) * rstd * g[i] + b[i];
        }
        stats.push((mu, rstd));
    }
    (y, stats)
}

/// LayerNorm backward; accumulates gain/shift grads, returns dx.
fn layer_norm_bwd(
    x: &[f64],
    stats: &[(f64, f64)],
    d: usize,
    g: &[f64],
    dy: &[f64],
    dg: &mut [f64],
    db: &mut [f64],
) -> Vec<f64> {
    let rows = x.len() / d;
    let mut dx = vec![0.0; x.len()];
    for r in 0..rows {
        let (mu, rstd) = stats[r];
        let xr = &x[r * d..(r + 1) * d];
        let dyr = &dy[r * d..(r + 1) * d];
        let mut m1 = 0.0;
        let mut m2 = 0.0;
        for i in 0..d {
            let xhat = (xr[i] - mu) * rstd;
            let dxh = dyr[i] * g[i];
            m1 += dxh;
            m2 += dxh * xhat;
            dg[i] += dyr[i] * xhat;
            db[i] += dyr[i];
        }
        m1 /= d as f64;
        m2 /= d as f64;
        let dxr = &mut dx[r * d..(r + 1) * d];
        for i in 0..d {
            let xhat = (xr[i] - mu) * rstd;
            dxr[i] = rstd * (dyr[i] * g[i] - m1 - xhat * m2);
        }
    }
    dx
}

fn gelu(z: f64) -> f64 {
    0.5 * z * (1.0 + (GELU_C * (z + 0.044715 * z * z * z)).tanh())
}

fn gelu_grad(z: f64) -> f64 {
    let t = (GELU_C * (z + 0.044715 * z * z * z)).tanh();
    0.5 * (1.0 + t) + 0.5 * z * (1.0 - t * t) * GELU_C * (1.0 + 3.0 * 0.044715 * z * z)
}

/// Causal multi-head attention. q/k/v: (batch*seq, d) with heads occupying
/// contiguous column stripes. Returns (concatenated head outputs, softmax
/// probabilities (batch, heads, seq, seq) — zero above the diagonal).
fn attention_fwd(
    q: &[f64],
    k: &[f64],
    v: &[f64],
    batch: usize,
    seq: usize,
    d: usize,
    heads: usize,
) -> (Vec<f64>, Vec<f64>) {
    let hd = d / heads;
    let scale = 1.0 / (hd as f64).sqrt();
    let mut out = vec![0.0; batch * seq * d];
    let mut probs = vec![0.0; batch * heads * seq * seq];
    let mut scores = vec![0.0; seq];
    for b in 0..batch {
        for h in 0..heads {
            let hoff = h * hd;
            for t in 0..seq {
                let qoff = (b * seq + t) * d + hoff;
                let qrow = &q[qoff..qoff + hd];
                let mut maxv = f64::NEG_INFINITY;
                for (s, sc) in scores.iter_mut().enumerate().take(t + 1) {
                    let koff = (b * seq + s) * d + hoff;
                    let krow = &k[koff..koff + hd];
                    let mut dot = 0.0;
                    for j in 0..hd {
                        dot += qrow[j] * krow[j];
                    }
                    *sc = dot * scale;
                    maxv = maxv.max(*sc);
                }
                let mut denom = 0.0;
                for sc in scores.iter_mut().take(t + 1) {
                    *sc = (*sc - maxv).exp();
                    denom += *sc;
                }
                let poff = ((b * heads + h) * seq + t) * seq;
                let orow_off = (b * seq + t) * d + hoff;
                for s in 0..=t {
                    let p = scores[s] / denom;
                    probs[poff + s] = p;
                    if p == 0.0 {
                        continue;
                    }
                    let voff = (b * seq + s) * d + hoff;
                    let vrow = &v[voff..voff + hd];
                    for j in 0..hd {
                        out[orow_off + j] += p * vrow[j];
                    }
                }
            }
        }
    }
    (out, probs)
}

/// Attention backward: (dq, dk, dv) from the saved probabilities.
fn attention_bwd(
    q: &[f64],
    k: &[f64],
    v: &[f64],
    probs: &[f64],
    dout: &[f64],
    batch: usize,
    seq: usize,
    d: usize,
    heads: usize,
) -> (Vec<f64>, Vec<f64>, Vec<f64>) {
    let hd = d / heads;
    let scale = 1.0 / (hd as f64).sqrt();
    let mut dq = vec![0.0; q.len()];
    let mut dk = vec![0.0; k.len()];
    let mut dv = vec![0.0; v.len()];
    let mut dprobs = vec![0.0; seq];
    for b in 0..batch {
        for h in 0..heads {
            let hoff = h * hd;
            for t in 0..seq {
                let poff = ((b * heads + h) * seq + t) * seq;
                let prow = &probs[poff..poff + seq];
                let dooff = (b * seq + t) * d + hoff;
                let dorow = &dout[dooff..dooff + hd];
                for s in 0..=t {
                    let voff = (b * seq + s) * d + hoff;
                    let vrow = &v[voff..voff + hd];
                    let mut acc = 0.0;
                    for j in 0..hd {
                        acc += dorow[j] * vrow[j];
                    }
                    dprobs[s] = acc;
                    let p = prow[s];
                    if p != 0.0 {
                        let dvrow = &mut dv[voff..voff + hd];
                        for j in 0..hd {
                            dvrow[j] += p * dorow[j];
                        }
                    }
                }
                let mut row_dot = 0.0;
                for s in 0..=t {
                    row_dot += dprobs[s] * prow[s];
                }
                let qoff = (b * seq + t) * d + hoff;
                for s in 0..=t {
                    let ds = prow[s] * (dprobs[s] - row_dot) * scale;
                    if ds == 0.0 {
                        continue;
                    }
                    let koff = (b * seq + s) * d + hoff;
                    for j in 0..hd {
                        dq[qoff + j] += ds * k[koff + j];
                        dk[koff + j] += ds * q[qoff + j];
                    }
                }
            }
        }
    }
    (dq, dk, dv)
}

// --------------------------------------------------------------------------
// block forward / backward
// --------------------------------------------------------------------------

/// All intermediates of one block forward (kept for the backward pass; the
/// `a`/`attn`/`u`/`g` members are also the four activation captures).
struct BlockCache {
    x_in: Vec<f64>,
    ln1: Vec<(f64, f64)>,
    a: Vec<f64>,
    q: Vec<f64>,
    k: Vec<f64>,
    v: Vec<f64>,
    probs: Vec<f64>,
    attn: Vec<f64>,
    x_mid: Vec<f64>,
    ln2: Vec<(f64, f64)>,
    u: Vec<f64>,
    z: Vec<f64>,
    g: Vec<f64>,
    x_out: Vec<f64>,
}

fn block_fwd_cached(cfg: &ModelCfg, bp: &BlockParams, x: Vec<f64>, batch: usize) -> BlockCache {
    let d = cfg.d;
    let rows = batch * cfg.seq;
    let (a, ln1) = layer_norm(&x, d, &bp.ln1_g, &bp.ln1_b);
    let q = matmul_wt(&a, rows, d, &bp.wq, d);
    let k = matmul_wt(&a, rows, d, &bp.wk, d);
    let v = matmul_wt(&a, rows, d, &bp.wv, d);
    let (attn, probs) = attention_fwd(&q, &k, &v, batch, cfg.seq, d, cfg.heads);
    let wo_out = matmul_wt(&attn, rows, d, &bp.wo, d);
    let mut x_mid = x.clone();
    for (xm, o) in x_mid.iter_mut().zip(&wo_out) {
        *xm += o;
    }
    let (u, ln2) = layer_norm(&x_mid, d, &bp.ln2_g, &bp.ln2_b);
    let z = matmul_wt(&u, rows, d, &bp.w1, cfg.ffn);
    let g: Vec<f64> = z.iter().map(|&zz| gelu(zz)).collect();
    let w2_out = matmul_wt(&g, rows, cfg.ffn, &bp.w2, d);
    let mut x_out = x_mid.clone();
    for (xo, o) in x_out.iter_mut().zip(&w2_out) {
        *xo += o;
    }
    BlockCache { x_in: x, ln1, a, q, k, v, probs, attn, x_mid, ln2, u, z, g, x_out }
}

struct BlockGrads {
    dln1_g: Vec<f64>,
    dln1_b: Vec<f64>,
    dwq: Vec<f64>,
    dwk: Vec<f64>,
    dwv: Vec<f64>,
    dwo: Vec<f64>,
    dln2_g: Vec<f64>,
    dln2_b: Vec<f64>,
    dw1: Vec<f64>,
    dw2: Vec<f64>,
}

fn block_bwd(
    cfg: &ModelCfg,
    bp: &BlockParams,
    cache: &BlockCache,
    dx_out: &[f64],
    batch: usize,
) -> (Vec<f64>, BlockGrads) {
    let d = cfg.d;
    let f = cfg.ffn;
    let rows = batch * cfg.seq;

    // x_out = x_mid + g @ W2^T
    let mut dz = matmul(dx_out, rows, d, &bp.w2, f); // = dg, then chain rule
    let dw2 = matmul_tn(dx_out, rows, d, &cache.g, f);
    for (dzv, &zv) in dz.iter_mut().zip(&cache.z) {
        *dzv *= gelu_grad(zv);
    }
    let dw1 = matmul_tn(&dz, rows, f, &cache.u, d);
    let du = matmul(&dz, rows, f, &bp.w1, d);
    let mut dln2_g = vec![0.0; d];
    let mut dln2_b = vec![0.0; d];
    let d_from_ln2 =
        layer_norm_bwd(&cache.x_mid, &cache.ln2, d, &bp.ln2_g, &du, &mut dln2_g, &mut dln2_b);
    let mut dx_mid = dx_out.to_vec();
    for (a, b) in dx_mid.iter_mut().zip(&d_from_ln2) {
        *a += b;
    }

    // x_mid = x_in + attn @ Wo^T
    let dattn = matmul(&dx_mid, rows, d, &bp.wo, d);
    let dwo = matmul_tn(&dx_mid, rows, d, &cache.attn, d);
    let (dq, dk, dv) = attention_bwd(
        &cache.q,
        &cache.k,
        &cache.v,
        &cache.probs,
        &dattn,
        batch,
        cfg.seq,
        d,
        cfg.heads,
    );
    let dwq = matmul_tn(&dq, rows, d, &cache.a, d);
    let dwk = matmul_tn(&dk, rows, d, &cache.a, d);
    let dwv = matmul_tn(&dv, rows, d, &cache.a, d);
    let mut da = matmul(&dq, rows, d, &bp.wq, d);
    let da_k = matmul(&dk, rows, d, &bp.wk, d);
    let da_v = matmul(&dv, rows, d, &bp.wv, d);
    for i in 0..da.len() {
        da[i] += da_k[i] + da_v[i];
    }
    let mut dln1_g = vec![0.0; d];
    let mut dln1_b = vec![0.0; d];
    let d_from_ln1 =
        layer_norm_bwd(&cache.x_in, &cache.ln1, d, &bp.ln1_g, &da, &mut dln1_g, &mut dln1_b);
    let mut dx_in = dx_mid;
    for (a, b) in dx_in.iter_mut().zip(&d_from_ln1) {
        *a += b;
    }
    (
        dx_in,
        BlockGrads { dln1_g, dln1_b, dwq, dwk, dwv, dwo, dln2_g, dln2_b, dw1, dw2 },
    )
}

// --------------------------------------------------------------------------
// artifact entry points
// --------------------------------------------------------------------------

fn embed_rows(cfg: &ModelCfg, view: &ParamView, tokens: &[i32]) -> Result<Vec<f64>> {
    let tok = view.region("tok_embed")?;
    let pos = view.region("pos_embed")?;
    let d = cfg.d;
    let seq = cfg.seq;
    let mut x = vec![0.0f64; tokens.len() * d];
    for (r, &t) in tokens.iter().enumerate() {
        if t < 0 || t as usize >= cfg.vocab {
            bail!("token id {t} out of range (vocab {})", cfg.vocab);
        }
        let te = &tok[t as usize * d..(t as usize + 1) * d];
        let pe = &pos[(r % seq) * d..(r % seq + 1) * d];
        let xr = &mut x[r * d..(r + 1) * d];
        for i in 0..d {
            xr[i] = te[i] as f64 + pe[i] as f64;
        }
    }
    Ok(x)
}

/// `embed_<cfg>`: (flat params, tokens (B, S)) -> hidden (B, S, d).
pub fn embed(cfg: &ModelCfg, flat: &[f32], tokens: &[i32]) -> Result<Tensor> {
    let view = ParamView::new(cfg, flat)?;
    if tokens.is_empty() || tokens.len() % cfg.seq != 0 {
        bail!(
            "embed_{}: {} tokens is not a whole number of seq={} rows",
            cfg.name,
            tokens.len(),
            cfg.seq
        );
    }
    let batch = tokens.len() / cfg.seq;
    let x = embed_rows(cfg, &view, tokens)?;
    Ok(Tensor::new(vec![batch, cfg.seq, cfg.d], f32v(&x)))
}

fn hidden_batch(cfg: &ModelCfg, hidden: &[f32]) -> Result<usize> {
    let per = cfg.seq * cfg.d;
    if hidden.is_empty() || hidden.len() % per != 0 {
        bail!(
            "hidden buffer of {} elements is not a whole number of (seq={}, d={}) chunks",
            hidden.len(),
            cfg.seq,
            cfg.d
        );
    }
    Ok(hidden.len() / per)
}

/// `block_fwd_<cfg>`: (block slice, hidden) ->
/// (hidden', x_qkv, x_wo, x_fc1, x_fc2).
pub fn block_fwd(cfg: &ModelCfg, block: &[f32], hidden: &[f32]) -> Result<Vec<Tensor>> {
    let batch = hidden_batch(cfg, hidden)?;
    let bp = BlockParams::from_slice(cfg, block)?;
    let cache = block_fwd_cached(cfg, &bp, f64v(hidden), batch);
    let rows = batch * cfg.seq;
    Ok(vec![
        Tensor::new(vec![batch, cfg.seq, cfg.d], f32v(&cache.x_out)),
        Tensor::new(vec![rows, cfg.d], f32v(&cache.a)),
        Tensor::new(vec![rows, cfg.d], f32v(&cache.attn)),
        Tensor::new(vec![rows, cfg.d], f32v(&cache.u)),
        Tensor::new(vec![rows, cfg.ffn], f32v(&cache.g)),
    ])
}

/// `block_prop_<cfg>`: (block slice, hidden) -> hidden' only.
pub fn block_prop(cfg: &ModelCfg, block: &[f32], hidden: &[f32]) -> Result<Tensor> {
    let batch = hidden_batch(cfg, hidden)?;
    let bp = BlockParams::from_slice(cfg, block)?;
    let cache = block_fwd_cached(cfg, &bp, f64v(hidden), batch);
    Ok(Tensor::new(vec![batch, cfg.seq, cfg.d], f32v(&cache.x_out)))
}

fn masked_hessian(x: &[f64], rows: usize, dim: usize, valid: usize) -> Tensor {
    let mut h = vec![0.0f64; dim * dim];
    for r in 0..valid.min(rows) {
        let xr = &x[r * dim..(r + 1) * dim];
        for (i, &xi) in xr.iter().enumerate() {
            if xi == 0.0 {
                continue;
            }
            let hrow = &mut h[i * dim..(i + 1) * dim];
            for j in 0..dim {
                hrow[j] += xi * xr[j];
            }
        }
    }
    Tensor::new(vec![dim, dim], f32v(&h))
}

/// `block_hess_<cfg>`: fused capture + per-chunk Hessians
/// (block slice, hidden, valid_rows) -> (hidden', H_qkv, H_wo, H_fc1, H_fc2).
pub fn block_hess(
    cfg: &ModelCfg,
    block: &[f32],
    hidden: &[f32],
    valid_rows: f32,
) -> Result<Vec<Tensor>> {
    let batch = hidden_batch(cfg, hidden)?;
    let bp = BlockParams::from_slice(cfg, block)?;
    let cache = block_fwd_cached(cfg, &bp, f64v(hidden), batch);
    let rows = batch * cfg.seq;
    let valid = (valid_rows.max(0.0) as usize).min(rows);
    Ok(vec![
        Tensor::new(vec![batch, cfg.seq, cfg.d], f32v(&cache.x_out)),
        masked_hessian(&cache.a, rows, cfg.d, valid),
        masked_hessian(&cache.attn, rows, cfg.d, valid),
        masked_hessian(&cache.u, rows, cfg.d, valid),
        masked_hessian(&cache.g, rows, cfg.ffn, valid),
    ])
}

/// `hessian_<dim>`: X (rows, dim) -> X^T X.
pub fn hessian_chunk(x: &[f32], dim: usize) -> Result<Tensor> {
    if dim == 0 || x.len() % dim != 0 {
        bail!("hessian_{dim}: buffer of {} elements is not (rows, {dim})", x.len());
    }
    let rows = x.len() / dim;
    Ok(masked_hessian(&f64v(x), rows, dim, rows))
}

fn forward_hidden(cfg: &ModelCfg, view: &ParamView, inp: &[i32], batch: usize) -> Result<Vec<f64>> {
    let mut x = embed_rows(cfg, view, inp)?;
    for l in 0..cfg.layers {
        let bp = BlockParams::from_params(view, l)?;
        let cache = block_fwd_cached(cfg, &bp, x, batch);
        x = cache.x_out;
    }
    let gf = f64v(view.region("lnf_g")?);
    let bf = f64v(view.region("lnf_b")?);
    let (h, _) = layer_norm(&x, cfg.d, &gf, &bf);
    Ok(h)
}

/// `nll_<cfg>`: (flat params, tokens (B, S+1)) -> per-position NLL (B, S).
pub fn nll(cfg: &ModelCfg, flat: &[f32], tokens: &[i32]) -> Result<Tensor> {
    let view = ParamView::new(cfg, flat)?;
    let row = cfg.seq + 1;
    if tokens.is_empty() || tokens.len() % row != 0 {
        bail!(
            "nll_{}: {} tokens is not a whole number of seq+1={row} rows",
            cfg.name,
            tokens.len()
        );
    }
    let batch = tokens.len() / row;
    let mut inp = Vec::with_capacity(batch * cfg.seq);
    let mut tgt = Vec::with_capacity(batch * cfg.seq);
    for b in 0..batch {
        let r = &tokens[b * row..(b + 1) * row];
        inp.extend_from_slice(&r[..cfg.seq]);
        tgt.extend_from_slice(&r[1..]);
    }
    let h = forward_hidden(cfg, &view, &inp, batch)?;
    let tok = view.region("tok_embed")?;
    let (d, vocab) = (cfg.d, cfg.vocab);
    let mut out = vec![0.0f32; batch * cfg.seq];
    let mut logits = vec![0.0f64; vocab];
    for (r, &t) in tgt.iter().enumerate() {
        if t < 0 || (t as usize) >= vocab {
            bail!("target token {t} out of range (vocab {vocab})");
        }
        let hr = &h[r * d..(r + 1) * d];
        let mut maxv = f64::NEG_INFINITY;
        for (vtok, lg) in logits.iter_mut().enumerate() {
            let er = &tok[vtok * d..(vtok + 1) * d];
            let mut s = 0.0;
            for i in 0..d {
                s += hr[i] * er[i] as f64;
            }
            *lg = s;
            maxv = maxv.max(s);
        }
        let denom: f64 = logits.iter().map(|&x| (x - maxv).exp()).sum();
        out[r] = ((maxv + denom.ln()) - logits[t as usize]) as f32;
    }
    Ok(Tensor::new(vec![batch, cfg.seq], out))
}

/// `next_logits_<cfg>`: (flat params, tokens (1, S)) -> logits (vocab,).
pub fn next_logits(cfg: &ModelCfg, flat: &[f32], tokens: &[i32]) -> Result<Tensor> {
    let view = ParamView::new(cfg, flat)?;
    if tokens.len() != cfg.seq {
        bail!(
            "next_logits_{}: window of {} tokens, expected {}",
            cfg.name,
            tokens.len(),
            cfg.seq
        );
    }
    let h = forward_hidden(cfg, &view, tokens, 1)?;
    let tok = view.region("tok_embed")?;
    let hr = &h[(cfg.seq - 1) * cfg.d..cfg.seq * cfg.d];
    let mut logits = vec![0.0f32; cfg.vocab];
    for (vtok, lg) in logits.iter_mut().enumerate() {
        let er = &tok[vtok * cfg.d..(vtok + 1) * cfg.d];
        let mut s = 0.0f64;
        for i in 0..cfg.d {
            s += hr[i] * er[i] as f64;
        }
        *lg = s as f32;
    }
    Ok(Tensor::new(vec![cfg.vocab], logits))
}

/// `adaprune_<r>x<c>`: (W, keep mask, H, lr) -> reconstructed W_hat — 256
/// masked GD steps on f(W) = 1/2 tr((W - W0) H (W - W0)^T).
pub fn adaprune(w: &[f32], mask: &[f32], h: &[f32], lr: f32, r: usize, c: usize) -> Result<Tensor> {
    if w.len() != r * c || mask.len() != r * c {
        bail!("adaprune_{r}x{c}: W has {} and mask {} elements", w.len(), mask.len());
    }
    if h.len() != c * c {
        bail!("adaprune_{r}x{c}: H has {} elements, expected {}", h.len(), c * c);
    }
    let wf = f64v(w);
    let mf = f64v(mask);
    let hf = f64v(h);
    let lr = lr as f64;
    let mut wh: Vec<f64> = wf.iter().zip(&mf).map(|(a, m)| a * m).collect();
    let mut diff = vec![0.0f64; c];
    let mut grow = vec![0.0f64; c];
    for _ in 0..ADAPRUNE_STEPS {
        for row in 0..r {
            let base = row * c;
            for j in 0..c {
                diff[j] = wh[base + j] - wf[base + j];
            }
            grow.iter_mut().for_each(|x| *x = 0.0);
            for (jcol, &dv) in diff.iter().enumerate() {
                if dv == 0.0 {
                    continue;
                }
                let hrow = &hf[jcol * c..(jcol + 1) * c];
                for j in 0..c {
                    grow[j] += dv * hrow[j];
                }
            }
            for j in 0..c {
                wh[base + j] -= lr * grow[j] * mf[base + j];
            }
        }
    }
    Ok(Tensor::new(vec![r, c], f32v(&wh)))
}

// --------------------------------------------------------------------------
// training step
// --------------------------------------------------------------------------

fn acc(grad: &mut [f64], off: usize, src: &[f64]) {
    for (g, s) in grad[off..off + src.len()].iter_mut().zip(src) {
        *g += s;
    }
}

/// Mean NLL over a (B, S+1) token batch and its gradient wrt the flat
/// parameter vector (full backprop through the tied-head transformer).
pub(crate) fn loss_and_grad(
    cfg: &ModelCfg,
    flat: &[f32],
    tokens: &[i32],
) -> Result<(f64, Vec<f64>)> {
    let view = ParamView::new(cfg, flat)?;
    let row = cfg.seq + 1;
    if tokens.is_empty() || tokens.len() % row != 0 {
        bail!(
            "train_step_{}: {} tokens is not a whole number of seq+1={row} rows",
            cfg.name,
            tokens.len()
        );
    }
    let batch = tokens.len() / row;
    let (seq, d, vocab) = (cfg.seq, cfg.d, cfg.vocab);
    let rows = batch * seq;
    let mut inp = Vec::with_capacity(rows);
    let mut tgt = Vec::with_capacity(rows);
    for b in 0..batch {
        let r = &tokens[b * row..(b + 1) * row];
        inp.extend_from_slice(&r[..seq]);
        tgt.extend_from_slice(&r[1..]);
    }

    // ---- forward, caching every intermediate ----
    let mut x = embed_rows(cfg, &view, &inp)?;
    let mut bps = Vec::with_capacity(cfg.layers);
    let mut caches: Vec<BlockCache> = Vec::with_capacity(cfg.layers);
    for l in 0..cfg.layers {
        let bp = BlockParams::from_params(&view, l)?;
        let cache = block_fwd_cached(cfg, &bp, x, batch);
        x = cache.x_out.clone();
        caches.push(cache);
        bps.push(bp);
    }
    let x_last = x;
    let gf = f64v(view.region("lnf_g")?);
    let bf = f64v(view.region("lnf_b")?);
    let (hfin, lnf_stats) = layer_norm(&x_last, d, &gf, &bf);

    // ---- loss + head backward (tied embeddings) ----
    let tokemb = view.region("tok_embed")?;
    let te_off = cfg.param_entry("tok_embed").unwrap().offset;
    let mut grad = vec![0.0f64; cfg.n_params];
    let mut dh = vec![0.0f64; rows * d];
    let inv_n = 1.0 / rows as f64;
    let mut loss = 0.0f64;
    let mut logits = vec![0.0f64; vocab];
    for (r, &t) in tgt.iter().enumerate() {
        if t < 0 || (t as usize) >= vocab {
            bail!("target token {t} out of range (vocab {vocab})");
        }
        let hr = &hfin[r * d..(r + 1) * d];
        let mut maxv = f64::NEG_INFINITY;
        for (vtok, lg) in logits.iter_mut().enumerate() {
            let er = &tokemb[vtok * d..(vtok + 1) * d];
            let mut s = 0.0;
            for i in 0..d {
                s += hr[i] * er[i] as f64;
            }
            *lg = s;
            maxv = maxv.max(s);
        }
        let logit_t = logits[t as usize];
        let mut denom = 0.0;
        for lg in logits.iter_mut() {
            *lg = (*lg - maxv).exp();
            denom += *lg;
        }
        loss += (maxv + denom.ln() - logit_t) * inv_n;
        let dhr = &mut dh[r * d..(r + 1) * d];
        for (vtok, &e) in logits.iter().enumerate() {
            let mut dl = e / denom * inv_n; // softmax prob / N
            if vtok == t as usize {
                dl -= inv_n;
            }
            if dl == 0.0 {
                continue;
            }
            let er = &tokemb[vtok * d..(vtok + 1) * d];
            let ge = &mut grad[te_off + vtok * d..te_off + (vtok + 1) * d];
            for i in 0..d {
                dhr[i] += dl * er[i] as f64;
                ge[i] += dl * hr[i];
            }
        }
    }

    // ---- final layer norm backward ----
    let mut dgf = vec![0.0f64; d];
    let mut dbf = vec![0.0f64; d];
    let mut dx = layer_norm_bwd(&x_last, &lnf_stats, d, &gf, &dh, &mut dgf, &mut dbf);
    acc(&mut grad, cfg.param_entry("lnf_g").unwrap().offset, &dgf);
    acc(&mut grad, cfg.param_entry("lnf_b").unwrap().offset, &dbf);

    // ---- blocks in reverse ----
    for l in (0..cfg.layers).rev() {
        let (dx_in, bg) = block_bwd(cfg, &bps[l], &caches[l], &dx, batch);
        dx = dx_in;
        let parts: [(&str, &Vec<f64>); 10] = [
            ("ln1_g", &bg.dln1_g),
            ("ln1_b", &bg.dln1_b),
            ("wq", &bg.dwq),
            ("wk", &bg.dwk),
            ("wv", &bg.dwv),
            ("wo", &bg.dwo),
            ("ln2_g", &bg.dln2_g),
            ("ln2_b", &bg.dln2_b),
            ("w1", &bg.dw1),
            ("w2", &bg.dw2),
        ];
        for (name, g) in parts {
            let e = cfg.param_entry(name).unwrap();
            let per = e.numel() / cfg.layers;
            acc(&mut grad, e.offset + l * per, g);
        }
    }

    // ---- embedding backward ----
    let pe_off = cfg.param_entry("pos_embed").unwrap().offset;
    for (r, &t) in inp.iter().enumerate() {
        let dxr = &dx[r * d..(r + 1) * d];
        let toff = te_off + (t as usize) * d;
        let poff = pe_off + (r % seq) * d;
        for i in 0..d {
            grad[toff + i] += dxr[i];
            grad[poff + i] += dxr[i];
        }
    }
    Ok((loss, grad))
}

/// `train_step_<cfg>`: (params, adam m, adam v, step, lr, tokens (B, S+1))
/// -> (params', m', v', loss). Global-norm clip at 1.0; Adam with the App-A
/// constants and bias correction, matching `python/compile/train.py`.
pub fn train_step(
    cfg: &ModelCfg,
    p: &[f32],
    m: &[f32],
    v: &[f32],
    step: f32,
    lr: f32,
    tokens: &[i32],
) -> Result<(Vec<f32>, Vec<f32>, Vec<f32>, f32)> {
    let n = cfg.n_params;
    if m.len() != n || v.len() != n {
        bail!("train_step_{}: adam state length mismatch", cfg.name);
    }
    let (loss, mut g) = loss_and_grad(cfg, p, tokens)?;
    let gnorm = (g.iter().map(|x| x * x).sum::<f64>() + 1e-12).sqrt();
    let scale = (GRAD_CLIP / gnorm).min(1.0);
    if scale < 1.0 {
        for x in g.iter_mut() {
            *x *= scale;
        }
    }
    let step = step as f64;
    let bc1 = 1.0 - ADAM_B1.powf(step);
    let bc2 = 1.0 - ADAM_B2.powf(step);
    let lr = lr as f64;
    let mut p2 = vec![0.0f32; n];
    let mut m2 = vec![0.0f32; n];
    let mut v2 = vec![0.0f32; n];
    for i in 0..n {
        let gi = g[i];
        let mi = ADAM_B1 * m[i] as f64 + (1.0 - ADAM_B1) * gi;
        let vi = ADAM_B2 * v[i] as f64 + (1.0 - ADAM_B2) * gi * gi;
        let mhat = mi / bc1;
        let vhat = vi / bc2;
        p2[i] = (p[i] as f64 - lr * mhat / (vhat.sqrt() + ADAM_EPS)) as f32;
        m2[i] = mi as f32;
        v2[i] = vi as f32;
    }
    Ok((p2, m2, v2, loss as f32))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::init::init_params;
    use crate::util::prng::Rng;

    fn test_cfg() -> ModelCfg {
        ModelCfg::from_dims("reftest", 8, 2, 2, 2, 2, 13, 6)
    }

    fn random_tokens(rng: &mut Rng, n: usize, vocab: usize) -> Vec<i32> {
        (0..n).map(|_| rng.below(vocab) as i32).collect()
    }

    #[test]
    fn gradient_matches_finite_differences() {
        let cfg = test_cfg();
        let fp = init_params(&cfg, 3);
        let mut rng = Rng::new(7);
        let tokens = random_tokens(&mut rng, 2 * (cfg.seq + 1), cfg.vocab);
        let (loss, grad) = loss_and_grad(&cfg, &fp.data, &tokens).unwrap();
        assert!(loss.is_finite() && loss > 0.0);
        let eps = 1e-3f32;
        for _ in 0..60 {
            let i = rng.below(cfg.n_params);
            let mut plus = fp.data.clone();
            plus[i] += eps;
            let mut minus = fp.data.clone();
            minus[i] -= eps;
            let (lp, _) = loss_and_grad(&cfg, &plus, &tokens).unwrap();
            let (lm, _) = loss_and_grad(&cfg, &minus, &tokens).unwrap();
            let num = (lp - lm) / (2.0 * eps as f64);
            let ana = grad[i];
            assert!(
                (ana - num).abs() <= 5e-4 + 5e-2 * num.abs(),
                "param {i}: analytic {ana} vs numeric {num}"
            );
        }
    }

    #[test]
    fn gelu_grad_matches_finite_differences() {
        for z in [-3.0, -1.0, -0.1, 0.0, 0.2, 1.5, 4.0] {
            let eps = 1e-6;
            let num = (gelu(z + eps) - gelu(z - eps)) / (2.0 * eps);
            assert!((gelu_grad(z) - num).abs() < 1e-6, "z={z}");
        }
    }

    #[test]
    fn training_reduces_loss_on_fixed_pattern() {
        let cfg = test_cfg();
        let mut p = init_params(&cfg, 0).data;
        let n = cfg.n_params;
        let mut m = vec![0.0f32; n];
        let mut v = vec![0.0f32; n];
        // a deterministic cyclic sequence the model can memorize
        let mut toks = Vec::new();
        for b in 0..2usize {
            for i in 0..=cfg.seq {
                toks.push(((b + 2 * i) % cfg.vocab) as i32);
            }
        }
        let mut losses = Vec::new();
        for s in 1..=80 {
            let (p2, m2, v2, loss) = train_step(&cfg, &p, &m, &v, s as f32, 1e-2, &toks).unwrap();
            p = p2;
            m = m2;
            v = v2;
            losses.push(loss);
        }
        assert!(losses.iter().all(|l| l.is_finite()));
        assert!(
            losses[losses.len() - 1] < losses[0] * 0.8,
            "loss {} -> {}",
            losses[0],
            losses[losses.len() - 1]
        );
        assert!(p.iter().all(|x| x.is_finite()));
    }

    #[test]
    fn nll_mean_matches_training_loss() {
        let cfg = test_cfg();
        let fp = init_params(&cfg, 5);
        let mut rng = Rng::new(11);
        let tokens = random_tokens(&mut rng, 2 * (cfg.seq + 1), cfg.vocab);
        let (loss, _) = loss_and_grad(&cfg, &fp.data, &tokens).unwrap();
        let nll_t = nll(&cfg, &fp.data, &tokens).unwrap();
        let mean =
            nll_t.data().iter().map(|&x| x as f64).sum::<f64>() / nll_t.len() as f64;
        assert!((mean - loss).abs() < 1e-4, "nll mean {mean} vs loss {loss}");
        // ballpark: random init predicts roughly uniformly
        assert!((mean - (cfg.vocab as f64).ln()).abs() < 1.0, "mean {mean}");
    }

    #[test]
    fn model_is_causal() {
        // editing a later input token must not change earlier NLL positions
        let cfg = test_cfg();
        let fp = init_params(&cfg, 9);
        let mut rng = Rng::new(13);
        let mut tokens = random_tokens(&mut rng, cfg.seq + 1, cfg.vocab);
        let a = nll(&cfg, &fp.data, &tokens).unwrap();
        let edit = cfg.seq - 1; // input position seq-1 affects targets >= seq-1 only
        tokens[edit] = (tokens[edit] + 1) % cfg.vocab as i32;
        let b = nll(&cfg, &fp.data, &tokens).unwrap();
        for pos in 0..edit - 1 {
            assert_eq!(a.data()[pos], b.data()[pos], "position {pos} changed");
        }
        assert_ne!(a.data()[edit - 1], b.data()[edit - 1], "edited target did not change");
    }

    #[test]
    fn block_artifacts_shapes_and_consistency() {
        let cfg = test_cfg();
        let fp = init_params(&cfg, 1);
        let view = ParamView::new(&cfg, &fp.data).unwrap();
        let mut block = Vec::new();
        for e in &cfg.block_layout {
            block.extend_from_slice(view.layer(&e.name, 0).unwrap());
        }
        let mut rng = Rng::new(2);
        let hidden: Vec<f32> =
            (0..2 * cfg.seq * cfg.d).map(|_| rng.normal_f32() * 0.1).collect();
        let outs = block_fwd(&cfg, &block, &hidden).unwrap();
        assert_eq!(outs.len(), 5);
        assert_eq!(outs[0].shape(), &[2, cfg.seq, cfg.d]);
        assert_eq!(outs[1].shape(), &[2 * cfg.seq, cfg.d]);
        assert_eq!(outs[4].shape(), &[2 * cfg.seq, cfg.ffn]);
        // block_prop returns exactly the propagation output
        let prop = block_prop(&cfg, &block, &hidden).unwrap();
        assert_eq!(prop, outs[0]);
        // fused Hessians equal X^T X of the captures, honoring valid_rows
        let rows = 2 * cfg.seq;
        let fused = block_hess(&cfg, &block, &hidden, rows as f32).unwrap();
        assert_eq!(fused[0], outs[0]);
        for (cap, hx) in [(1usize, 1usize), (2, 2), (3, 3), (4, 4)] {
            let dim = outs[cap].cols();
            let href = hessian_chunk(outs[cap].data(), dim).unwrap();
            for (a, b) in fused[hx].data().iter().zip(href.data()) {
                assert!((a - b).abs() < 1e-4 * (1.0 + b.abs()), "{a} vs {b}");
            }
        }
        // masking away the second chunk = computing on the first chunk only
        let half = cfg.seq;
        let masked = block_hess(&cfg, &block, &hidden, half as f32).unwrap();
        let first_rows = &outs[1].data()[..half * cfg.d];
        let href = hessian_chunk(first_rows, cfg.d).unwrap();
        for (a, b) in masked[1].data().iter().zip(href.data()) {
            assert!((a - b).abs() < 1e-4 * (1.0 + b.abs()));
        }
    }

    #[test]
    fn adaprune_improves_on_magnitude_mask() {
        use crate::solver::hessian::{lambda_max, layer_sq_error};
        use crate::solver::magnitude::magnitude_prune;
        let mut rng = Rng::new(4);
        let (r, c) = (12, 24);
        let w = Tensor::new(vec![r, c], (0..r * c).map(|_| rng.normal_f32()).collect());
        let x = Tensor::new(vec![2 * c, c], (0..2 * c * c).map(|_| rng.normal_f32()).collect());
        let h = x.transpose2().matmul(&x);
        let (wz, mask) = magnitude_prune(&w, 0.5);
        let lam = lambda_max(&h, 0);
        let lr = (1.0 / lam) as f32;
        let wa = adaprune(w.data(), mask.data(), h.data(), lr, r, c).unwrap();
        // pruned entries stay exactly zero
        for (a, m) in wa.data().iter().zip(mask.data()) {
            if *m == 0.0 {
                assert_eq!(*a, 0.0);
            }
        }
        let e_ada = layer_sq_error(&w, &wa, &h);
        let e_zero = layer_sq_error(&w, &wz, &h);
        assert!(e_ada < e_zero, "adaprune {e_ada} vs masked-only {e_zero}");
    }

    #[test]
    fn bad_inputs_are_clean_errors() {
        let cfg = test_cfg();
        let fp = init_params(&cfg, 0);
        assert!(nll(&cfg, &fp.data, &[0; 5]).is_err()); // not a multiple of S+1
        assert!(nll(&cfg, &fp.data[1..], &[0; 7]).is_err()); // short params
        assert!(embed(&cfg, &fp.data, &[999; 6]).is_err()); // token out of range
        assert!(next_logits(&cfg, &fp.data, &[0; 3]).is_err()); // wrong window
        assert!(hessian_chunk(&[0.0; 7], 2).is_err());
        assert!(adaprune(&[0.0; 4], &[0.0; 4], &[0.0; 3], 0.1, 2, 2).is_err());
    }
}
