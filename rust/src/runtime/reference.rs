//! The pure-Rust reference backend: interprets the artifact vocabulary
//! directly on `tensor`/`solver` math, deriving every shape from
//! [`ModelCfg`] instead of a compiled manifest.
//!
//! No PJRT, no artifacts directory, no Python: the full prune → eval
//! pipeline runs on a fresh checkout (`--backend reference` or
//! `SPARSEGPT_BACKEND=reference`). The vocabulary is *open* along its
//! parameter axes — `sparsegpt_<r>x<c>` for arbitrary shapes,
//! `sparsegpt_bs<Bs>_...` for any blocksize, `sparsegpt<n><m>_...` for any
//! single-digit n < m pair — so solver variants and tests are not limited
//! to the combinations the AOT build lowered.

use std::cell::RefCell;
use std::collections::BTreeMap;
use std::time::Instant;

use anyhow::{anyhow, bail, Result};

use crate::model::config::{ModelCfg, BUILTIN_BLOCKSIZE};
use crate::runtime::backend::{ArgValue, Backend, CachedLiteral, RuntimeStats};
use crate::runtime::ref_ops;
use crate::solver::hessian::dampened_hinv_chol_f64;
use crate::solver::sparsegpt_ref::{ref_sparsegpt, Pattern};
use crate::tensor::Tensor;

pub struct ReferenceBackend {
    configs: BTreeMap<String, ModelCfg>,
    stats: RefCell<RuntimeStats>,
}

impl ReferenceBackend {
    /// A backend over the built-in model family (nano..large).
    pub fn new() -> ReferenceBackend {
        let configs = ModelCfg::builtin_names()
            .iter()
            .map(|n| (n.to_string(), ModelCfg::builtin(n).unwrap()))
            .collect();
        ReferenceBackend { configs, stats: RefCell::new(BTreeMap::new()) }
    }

    /// A backend over explicit configs (tests with custom-sized models).
    pub fn with_configs(configs: impl IntoIterator<Item = ModelCfg>) -> ReferenceBackend {
        ReferenceBackend {
            configs: configs.into_iter().map(|c| (c.name.clone(), c)).collect(),
            stats: RefCell::new(BTreeMap::new()),
        }
    }

    fn cfg(&self, name: &str) -> Result<&ModelCfg> {
        self.configs.get(name).ok_or_else(|| {
            anyhow!(
                "config {name:?} unknown to the reference backend (have {:?})",
                self.configs.keys().collect::<Vec<_>>()
            )
        })
    }

    fn dispatch(&self, name: &str, args: &[ArgValue]) -> Result<Vec<Tensor>> {
        let parsed = parse_artifact(name).ok_or_else(|| {
            anyhow!("artifact {name:?} is not in the reference backend's vocabulary")
        })?;
        match parsed {
            Parsed::TrainStep(c) => {
                let cfg = self.cfg(c)?;
                expect_args(name, args, 6)?;
                let (p2, m2, v2, loss) = ref_ops::train_step(
                    cfg,
                    f32_arg(name, args, 0)?,
                    f32_arg(name, args, 1)?,
                    f32_arg(name, args, 2)?,
                    scalar_arg(name, args, 3)?,
                    scalar_arg(name, args, 4)?,
                    i32_arg(name, args, 5)?,
                )?;
                let n = cfg.n_params;
                Ok(vec![
                    Tensor::new(vec![n], p2),
                    Tensor::new(vec![n], m2),
                    Tensor::new(vec![n], v2),
                    Tensor::new(vec![1], vec![loss]),
                ])
            }
            Parsed::Embed(c) => {
                let cfg = self.cfg(c)?;
                expect_args(name, args, 2)?;
                let out = ref_ops::embed(cfg, f32_arg(name, args, 0)?, i32_arg(name, args, 1)?)?;
                Ok(vec![out])
            }
            Parsed::BlockFwd(c) => {
                let cfg = self.cfg(c)?;
                expect_args(name, args, 2)?;
                ref_ops::block_fwd(cfg, f32_arg(name, args, 0)?, f32_arg(name, args, 1)?)
            }
            Parsed::BlockProp(c) => {
                let cfg = self.cfg(c)?;
                expect_args(name, args, 2)?;
                let out =
                    ref_ops::block_prop(cfg, f32_arg(name, args, 0)?, f32_arg(name, args, 1)?)?;
                Ok(vec![out])
            }
            Parsed::BlockHess(c) => {
                let cfg = self.cfg(c)?;
                expect_args(name, args, 3)?;
                ref_ops::block_hess(
                    cfg,
                    f32_arg(name, args, 0)?,
                    f32_arg(name, args, 1)?,
                    scalar_arg(name, args, 2)?,
                )
            }
            Parsed::Nll(c) => {
                let cfg = self.cfg(c)?;
                expect_args(name, args, 2)?;
                let out = ref_ops::nll(cfg, f32_arg(name, args, 0)?, i32_arg(name, args, 1)?)?;
                Ok(vec![out])
            }
            Parsed::NextLogits(c) => {
                let cfg = self.cfg(c)?;
                expect_args(name, args, 2)?;
                let out =
                    ref_ops::next_logits(cfg, f32_arg(name, args, 0)?, i32_arg(name, args, 1)?)?;
                Ok(vec![out])
            }
            Parsed::HessianPrep(dim) => {
                expect_args(name, args, 2)?;
                let h = f32_tensor(name, args, 0, dim, dim)?;
                let damp = scalar_arg(name, args, 1)? as f64;
                let u = dampened_hinv_chol_f64(&h, damp).ok_or_else(|| {
                    anyhow!("{name}: Hessian not SPD even after dampening; increase --damp")
                })?;
                Ok(vec![u])
            }
            Parsed::Hessian(dim) => {
                expect_args(name, args, 1)?;
                Ok(vec![ref_ops::hessian_chunk(f32_arg(name, args, 0)?, dim)?])
            }
            Parsed::Adaprune(r, c) => {
                expect_args(name, args, 4)?;
                Ok(vec![ref_ops::adaprune(
                    f32_arg(name, args, 0)?,
                    f32_arg(name, args, 1)?,
                    f32_arg(name, args, 2)?,
                    scalar_arg(name, args, 3)?,
                    r,
                    c,
                )?])
            }
            Parsed::SolveNm { n, m, r, c } => {
                expect_args(name, args, 3)?;
                let qlevels = scalar_arg(name, args, 2)?;
                self.solve(name, args, r, c, Pattern::NM(n, m), qlevels, BUILTIN_BLOCKSIZE)
            }
            Parsed::SolveUnstructured { blocksize, r, c } => {
                expect_args(name, args, 4)?;
                let p = scalar_arg(name, args, 2)? as f64;
                let qlevels = scalar_arg(name, args, 3)?;
                self.solve(name, args, r, c, Pattern::Unstructured(p), qlevels, blocksize)
            }
        }
    }

    /// Shared SparseGPT solver entry: args[0] = W (r, c), args[1] = inverse
    /// Cholesky factor (c, c); returns (W_hat, keep mask).
    fn solve(
        &self,
        name: &str,
        args: &[ArgValue],
        r: usize,
        c: usize,
        pattern: Pattern,
        qlevels: f32,
        blocksize: usize,
    ) -> Result<Vec<Tensor>> {
        let w = f32_tensor(name, args, 0, r, c)?;
        let hc = f32_tensor(name, args, 1, c, c)?;
        let qlevels = qlevels.max(0.0).round() as u32;
        let (w_hat, mask) = ref_sparsegpt(&w, &hc, pattern, qlevels, blocksize);
        Ok(vec![w_hat, mask])
    }

    /// Whether `name` parses as an executable artifact for this backend —
    /// the same grammar `dispatch` executes ([`parse_artifact`] is the
    /// single definition of both), plus a config-table check for the
    /// model-typed artifacts.
    fn recognizes(&self, name: &str) -> bool {
        match parse_artifact(name) {
            Some(
                Parsed::TrainStep(c)
                | Parsed::Embed(c)
                | Parsed::BlockFwd(c)
                | Parsed::BlockProp(c)
                | Parsed::BlockHess(c)
                | Parsed::Nll(c)
                | Parsed::NextLogits(c),
            ) => self.configs.contains_key(c),
            Some(_) => true,
            None => false,
        }
    }
}

/// A parsed artifact name: the single grammar shared by `dispatch` (what
/// executes) and `recognizes` (what `has_artifact` reports) — they cannot
/// drift apart.
enum Parsed<'a> {
    TrainStep(&'a str),
    Embed(&'a str),
    BlockFwd(&'a str),
    BlockProp(&'a str),
    BlockHess(&'a str),
    Nll(&'a str),
    NextLogits(&'a str),
    HessianPrep(usize),
    Hessian(usize),
    Adaprune(usize, usize),
    /// `sparsegpt<n><m>_<r>x<c>` — any single-digit 0 < n < m pair (the
    /// AOT build lowers 24 and 48; the interpreter accepts the family)
    SolveNm { n: usize, m: usize, r: usize, c: usize },
    /// `sparsegpt_<r>x<c>` (production Bs) or `sparsegpt_bs<Bs>_<r>x<c>`
    SolveUnstructured { blocksize: usize, r: usize, c: usize },
}

fn parse_artifact(name: &str) -> Option<Parsed> {
    if let Some(c) = name.strip_prefix("train_step_") {
        return Some(Parsed::TrainStep(c));
    }
    if let Some(c) = name.strip_prefix("embed_") {
        return Some(Parsed::Embed(c));
    }
    if let Some(c) = name.strip_prefix("block_fwd_") {
        return Some(Parsed::BlockFwd(c));
    }
    if let Some(c) = name.strip_prefix("block_prop_") {
        return Some(Parsed::BlockProp(c));
    }
    if let Some(c) = name.strip_prefix("block_hess_") {
        return Some(Parsed::BlockHess(c));
    }
    if let Some(c) = name.strip_prefix("nll_") {
        return Some(Parsed::Nll(c));
    }
    if let Some(c) = name.strip_prefix("next_logits_") {
        return Some(Parsed::NextLogits(c));
    }
    if let Some(d) = name.strip_prefix("hessian_prep_") {
        return Some(Parsed::HessianPrep(d.parse().ok()?));
    }
    if let Some(d) = name.strip_prefix("hessian_") {
        return Some(Parsed::Hessian(d.parse().ok()?));
    }
    if let Some(s) = name.strip_prefix("adaprune_") {
        let (r, c) = shape_of(s)?;
        return Some(Parsed::Adaprune(r, c));
    }
    if let Some(rest) = name.strip_prefix("sparsegpt_bs") {
        let (bs, s) = rest.split_once('_')?;
        let blocksize = bs.parse::<usize>().ok().filter(|&b| b > 0)?;
        let (r, c) = shape_of(s)?;
        return Some(Parsed::SolveUnstructured { blocksize, r, c });
    }
    if let Some(s) = name.strip_prefix("sparsegpt_") {
        let (r, c) = shape_of(s)?;
        return Some(Parsed::SolveUnstructured { blocksize: BUILTIN_BLOCKSIZE, r, c });
    }
    if let Some(rest) = name.strip_prefix("sparsegpt") {
        // digit-pair n:m variants: "sparsegpt24_64x64", "sparsegpt48_..."
        let (nm, s) = rest.split_once('_')?;
        let digits: Vec<u32> = nm.chars().map(|ch| ch.to_digit(10)).collect::<Option<_>>()?;
        if let [n, m] = digits[..] {
            let (n, m) = (n as usize, m as usize);
            if n > 0 && n < m {
                let (r, c) = shape_of(s)?;
                return Some(Parsed::SolveNm { n, m, r, c });
            }
        }
        return None;
    }
    None
}

impl Default for ReferenceBackend {
    fn default() -> ReferenceBackend {
        ReferenceBackend::new()
    }
}

impl Backend for ReferenceBackend {
    fn name(&self) -> &'static str {
        "reference"
    }

    fn config(&self, name: &str) -> Result<ModelCfg> {
        self.cfg(name).cloned()
    }

    fn has_artifact(&self, name: &str) -> bool {
        self.recognizes(name)
    }

    fn artifact_names(&self) -> Vec<String> {
        Vec::new() // open vocabulary: nothing to enumerate
    }

    fn run(&self, name: &str, args: &[ArgValue]) -> Result<Vec<Tensor>> {
        let t0 = Instant::now();
        let out = self.dispatch(name, args)?;
        let mut st = self.stats.borrow_mut();
        let e = st.entry(name.to_string()).or_default();
        e.runs += 1;
        e.run_secs += t0.elapsed().as_secs_f64();
        Ok(out)
    }

    fn cache_f32(&self, data: &[f32], shape: &[usize]) -> Result<CachedLiteral> {
        if shape.iter().product::<usize>() != data.len() {
            bail!("cache_f32: {} elements vs shape {shape:?}", data.len());
        }
        Ok(CachedLiteral::Host { data: data.to_vec(), shape: shape.to_vec() })
    }

    fn prepare(&self, name: &str) -> Result<()> {
        if self.recognizes(name) {
            Ok(())
        } else {
            Err(anyhow!("artifact {name:?} is not in the reference backend's vocabulary"))
        }
    }

    fn evict(&self, _name: &str) {}

    fn stats(&self) -> RuntimeStats {
        self.stats.borrow().clone()
    }

    fn reset_stats(&self) {
        self.stats.borrow_mut().clear();
    }
}

// --------------------------------------------------------------------------
// argument helpers
// --------------------------------------------------------------------------

fn expect_args(name: &str, args: &[ArgValue], n: usize) -> Result<()> {
    if args.len() != n {
        bail!("{name}: expected {n} inputs, got {}", args.len());
    }
    Ok(())
}

fn f32_arg<'a>(name: &str, args: &'a [ArgValue<'a>], i: usize) -> Result<&'a [f32]> {
    match args.get(i) {
        Some(ArgValue::F32(x)) => Ok(*x),
        Some(ArgValue::Cached(CachedLiteral::Host { data, .. })) => Ok(data.as_slice()),
        Some(ArgValue::Cached(CachedLiteral::Device { .. })) => {
            bail!("{name}: input {i} is a device literal (passed to the reference backend)")
        }
        Some(_) => bail!("{name}: input {i} must be an f32 buffer"),
        None => bail!("{name}: missing input {i}"),
    }
}

fn i32_arg<'a>(name: &str, args: &'a [ArgValue<'a>], i: usize) -> Result<&'a [i32]> {
    match args.get(i) {
        Some(ArgValue::I32(x)) => Ok(*x),
        Some(_) => bail!("{name}: input {i} must be an i32 buffer"),
        None => bail!("{name}: missing input {i}"),
    }
}

fn scalar_arg(name: &str, args: &[ArgValue], i: usize) -> Result<f32> {
    match args.get(i) {
        Some(ArgValue::Scalar(x)) => Ok(*x),
        Some(_) => bail!("{name}: input {i} must be a scalar"),
        None => bail!("{name}: missing input {i}"),
    }
}

/// Fetch args[i] as an (r, c) f32 tensor with an exact length check.
fn f32_tensor(name: &str, args: &[ArgValue], i: usize, r: usize, c: usize) -> Result<Tensor> {
    let data = f32_arg(name, args, i)?;
    if data.len() != r * c {
        bail!("{name}: input {i} has {} elements, expected {r}x{c}", data.len());
    }
    Ok(Tensor::new(vec![r, c], data.to_vec()))
}

fn shape_of(s: &str) -> Option<(usize, usize)> {
    let (r, c) = s.split_once('x')?;
    let (r, c) = (r.parse::<usize>().ok()?, c.parse::<usize>().ok()?);
    if r == 0 || c == 0 {
        None
    } else {
        Some((r, c))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Rng;

    #[test]
    fn vocabulary_recognition() {
        let be = ReferenceBackend::new();
        for good in [
            "embed_nano",
            "block_fwd_micro",
            "block_hess_nano",
            "block_prop_small",
            "nll_nano",
            "next_logits_large",
            "train_step_nano",
            "hessian_64",
            "hessian_prep_256",
            "sparsegpt_64x64",
            "sparsegpt_bs32_16x64",
            "sparsegpt24_256x64",
            "sparsegpt48_64x256",
            "sparsegpt12_16x32", // any single-digit n<m pair, not just 2:4/4:8
            "adaprune_64x64",
        ] {
            assert!(be.has_artifact(good), "{good}");
            assert!(be.prepare(good).is_ok(), "{good}");
        }
        for bad in [
            "embed_giant",
            "sparsegpt_64",
            "sparsegpt_ax64",
            "sparsegpt_bs_64x64",
            "sparsegpt_bs0_64x64", // a zero blocksize is malformed, not Bs=1
            "sparsegpt42_16x32",   // n >= m is not a valid pattern
            "hessian_x",
            "unknown",
        ] {
            assert!(!be.has_artifact(bad), "{bad}");
            assert!(be.prepare(bad).is_err(), "{bad}");
        }
        assert!(be.artifact_names().is_empty());
        assert!(be.config("nano").is_ok());
        assert!(be.config("giant").is_err());
    }

    #[test]
    fn stats_and_cache_roundtrip() {
        let be = ReferenceBackend::new();
        let mut rng = Rng::new(0);
        let x: Vec<f32> = (0..32 * 8).map(|_| rng.normal_f32()).collect();
        let lit = be.cache_f32(&x, &[32, 8]).unwrap();
        assert!(be.cache_f32(&x, &[3, 3]).is_err());
        let out = be.run("hessian_8", &[ArgValue::Cached(&lit)]).unwrap();
        assert_eq!(out[0].shape(), &[8, 8]);
        let st = be.stats();
        assert_eq!(st.get("hessian_8").unwrap().runs, 1);
        be.reset_stats();
        assert!(be.stats().is_empty());
        // evict is a harmless no-op
        be.evict("hessian_8");
        // unknown artifacts error cleanly
        assert!(be.run("nope", &[]).is_err());
    }
}
