//! The execution-backend abstraction: one trait, two implementations.
//!
//! Every tensor program the coordinator/eval layers dispatch is named by an
//! *artifact* (`embed_<cfg>`, `block_hess_<cfg>`, `sparsegpt_<r>x<c>`, ...).
//! A [`Backend`] executes artifacts by name:
//!
//! * [`crate::runtime::Runtime`] — the production path: AOT-compiled HLO
//!   text executed on the PJRT CPU client (shapes validated against the
//!   compiled manifest).
//! * [`crate::runtime::ReferenceBackend`] — a pure-Rust interpreter of the
//!   same vocabulary on `tensor`/`solver` math, deriving shapes from
//!   [`ModelCfg`] instead of a compiled manifest. Slower, dependency-free,
//!   and available on a fresh checkout — the executable oracle the
//!   integration suite runs against.
//!
//! Backend selection ([`BackendKind::resolve`]) is CLI `--backend` >
//! `SPARSEGPT_BACKEND` env var > default (`pjrt`).

use std::collections::BTreeMap;

use anyhow::{anyhow, Result};

use crate::model::config::ModelCfg;
use crate::model::manifest::DType;
use crate::tensor::Tensor;

/// An input argument; shapes come from the backend (manifest or config).
pub enum ArgValue<'a> {
    F32(&'a [f32]),
    I32(&'a [i32]),
    Scalar(f32),
    /// a pre-marshalled buffer (perf path: marshal once, execute many —
    /// e.g. the flat parameter vector during evaluation)
    Cached(&'a CachedLiteral),
}

/// An input buffer marshalled once and reused across executions. Each
/// backend produces (and accepts only) its own variant.
pub enum CachedLiteral {
    /// a PJRT device buffer (see `exec.rs` for why buffers, not literals)
    Device {
        buf: xla::PjRtBuffer,
        numel: usize,
        dtype: DType,
    },
    /// a host-resident copy for the reference interpreter
    Host { data: Vec<f32>, shape: Vec<usize> },
}

#[derive(Clone, Debug, Default)]
pub struct ArtifactStats {
    pub compiles: usize,
    pub compile_secs: f64,
    pub runs: usize,
    pub run_secs: f64,
    pub marshal_secs: f64,
}

pub type RuntimeStats = BTreeMap<String, ArtifactStats>;

/// An artifact executor. Object-safe: the whole stack holds `&dyn Backend`
/// (or `Box<dyn Backend>` in the `Workspace`), so GPU/sharded backends can
/// slot in behind the same vocabulary.
pub trait Backend {
    /// Stable identifier ("pjrt", "reference").
    fn name(&self) -> &'static str;

    /// The model configuration `name` as this backend knows it (manifest
    /// entry for PJRT, built-in family table for the reference backend).
    fn config(&self, name: &str) -> Result<ModelCfg>;

    /// Whether `name` is executable on this backend (used for fast-path
    /// selection, e.g. the fused `block_hess` capture).
    fn has_artifact(&self, name: &str) -> bool;

    /// Enumerable artifact names. Backends with an *open* vocabulary (the
    /// reference interpreter accepts any well-formed name) return an empty
    /// list; callers must treat this as "nothing to enumerate", not
    /// "nothing executable", and rely on [`Backend::has_artifact`].
    fn artifact_names(&self) -> Vec<String>;

    /// Execute an artifact; returns its outputs as f32 tensors.
    fn run(&self, name: &str, args: &[ArgValue]) -> Result<Vec<Tensor>>;

    /// Marshal an f32 buffer once for reuse across many `run` calls.
    fn cache_f32(&self, data: &[f32], shape: &[usize]) -> Result<CachedLiteral>;

    /// Pay any one-time setup cost for `name` now (PJRT: compile + cache);
    /// benchmarks call this so timed runs exclude compilation.
    fn prepare(&self, name: &str) -> Result<()>;

    /// Drop per-artifact cached state (memory control for one-shot
    /// artifacts); a no-op on backends that cache nothing.
    fn evict(&self, name: &str);

    fn stats(&self) -> RuntimeStats;

    fn reset_stats(&self);
}

/// Which backend to construct. Selection order: explicit (CLI `--backend`)
/// > `SPARSEGPT_BACKEND` env var > default (`Pjrt`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BackendKind {
    /// compiled HLO artifacts on the PJRT CPU client (default)
    Pjrt,
    /// pure-Rust reference interpreter (no artifacts required)
    Reference,
}

impl BackendKind {
    pub fn parse(s: &str) -> Result<BackendKind> {
        match s {
            "pjrt" => Ok(BackendKind::Pjrt),
            "reference" | "ref" => Ok(BackendKind::Reference),
            _ => Err(anyhow!(
                "unknown backend {s:?} (expected \"pjrt\" or \"reference\")"
            )),
        }
    }

    pub fn label(&self) -> &'static str {
        match self {
            BackendKind::Pjrt => "pjrt",
            BackendKind::Reference => "reference",
        }
    }

    /// Resolve the effective kind: `explicit` (the CLI flag) wins, then the
    /// `SPARSEGPT_BACKEND` env var, then the PJRT default.
    pub fn resolve(explicit: Option<BackendKind>) -> Result<BackendKind> {
        if let Some(kind) = explicit {
            return Ok(kind);
        }
        match std::env::var("SPARSEGPT_BACKEND") {
            Ok(v) if !v.is_empty() => {
                Self::parse(&v).map_err(|e| anyhow!("SPARSEGPT_BACKEND: {e:#}"))
            }
            _ => Ok(BackendKind::Pjrt),
        }
    }

    /// Construct the backend this kind names.
    pub fn open(&self) -> Result<Box<dyn Backend>> {
        Ok(match self {
            BackendKind::Pjrt => Box::new(crate::runtime::Runtime::new()?),
            BackendKind::Reference => Box::new(crate::runtime::ReferenceBackend::new()),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_and_label_round_trip() {
        assert_eq!(BackendKind::parse("pjrt").unwrap(), BackendKind::Pjrt);
        assert_eq!(BackendKind::parse("reference").unwrap(), BackendKind::Reference);
        assert_eq!(BackendKind::parse("ref").unwrap(), BackendKind::Reference);
        assert!(BackendKind::parse("tpu").is_err());
        for k in [BackendKind::Pjrt, BackendKind::Reference] {
            assert_eq!(BackendKind::parse(k.label()).unwrap(), k);
        }
    }

    #[test]
    fn explicit_selection_wins() {
        // the explicit kind must win regardless of the environment
        assert_eq!(
            BackendKind::resolve(Some(BackendKind::Reference)).unwrap(),
            BackendKind::Reference
        );
        assert_eq!(BackendKind::resolve(Some(BackendKind::Pjrt)).unwrap(), BackendKind::Pjrt);
    }
}
