//! Artifact execution backends.
//!
//! The [`Backend`] trait abstracts *how* the artifact vocabulary executes;
//! the rest of the stack (coordinator, trainer, eval, api) holds
//! `&dyn Backend` and never knows which implementation it is driving:
//!
//! * [`Runtime`] — the production PJRT path: loads the AOT HLO-text
//!   artifacts, compiles them on the CPU PJRT client (once, cached) and
//!   executes them from the coordinator's hot path. The `runtime` module
//!   is the only place in the crate that touches the `xla` crate (the
//!   execution logic in `exec.rs`, plus the device-buffer variant of
//!   [`CachedLiteral`]).
//! * [`ReferenceBackend`] — a pure-Rust interpreter of the same vocabulary
//!   on `tensor`/`solver` math (shapes derived from `ModelCfg`, no
//!   compiled manifest): the executable oracle for tests and the
//!   zero-setup `--backend reference` path.
//!
//! Selection order ([`BackendKind::resolve`]): CLI `--backend` >
//! `SPARSEGPT_BACKEND` env var > default (`pjrt`).

mod backend;
mod exec;
mod ref_ops;
mod reference;

pub use backend::{ArgValue, ArtifactStats, Backend, BackendKind, CachedLiteral, RuntimeStats};
pub use exec::{OutValue, Runtime};
pub use ref_ops::ADAPRUNE_STEPS;
pub use reference::ReferenceBackend;
