//! The PJRT runtime: loads the AOT HLO-text artifacts, compiles them on the
//! CPU PJRT client (once, cached) and executes them from the coordinator's
//! hot path. This is the only module that touches the `xla` crate.

mod exec;

pub use exec::{ArgValue, CachedLiteral, OutValue, Runtime, RuntimeStats};
