//! 2:4 (general n:m) structured storage + kernel — the CPU analog of the
//! Ampere sparse-tensor-core regime benchmarked in Table 8. Exactly n
//! values survive per group of m consecutive inputs, so values pack densely
//! and indices fit in a u8 per kept value; the inner loop is fully regular
//! (no per-row length variation), which is what makes the format fast in
//! hardware.

use anyhow::{bail, Result};

use crate::sparse::buf::SectionBuf;
use crate::sparse::threads::{for_each_token_tile, TOKEN_TILE};
use crate::tensor::Tensor;

#[derive(Clone, Debug)]
pub struct NmMatrix {
    pub n: usize,
    pub m: usize,
    pub rows: usize,
    pub cols: usize,
    /// (rows * cols/m * n) packed kept values. Always owned in practice:
    /// the `.spkt` byte layout (masks + kept values) differs from this
    /// zero-padded in-memory layout, so n:m decode is a real transform,
    /// not a view — see DESIGN.md "Zero-copy mmap serving".
    pub values: SectionBuf<f32>,
    /// within-group column offsets of each kept value
    pub offsets: SectionBuf<u8>,
}

impl NmMatrix {
    /// Pack a dense matrix that satisfies the n:m constraint (exactly
    /// m - n zeros per group — as produced by the n:m solvers).
    pub fn from_dense(w: &Tensor, n: usize, m: usize) -> Result<NmMatrix> {
        let (rows, cols) = (w.rows(), w.cols());
        if cols % m != 0 {
            bail!("cols {cols} not divisible by m {m}");
        }
        let groups = cols / m;
        let mut values = Vec::with_capacity(rows * groups * n);
        let mut offsets = Vec::with_capacity(rows * groups * n);
        for r in 0..rows {
            let row = w.row(r);
            for g in 0..groups {
                let base = g * m;
                let mut kept = 0;
                for j in 0..m {
                    let v = row[base + j];
                    if v != 0.0 {
                        if kept == n {
                            bail!("row {r} group {g} violates {n}:{m} (too many nonzeros)");
                        }
                        values.push(v);
                        offsets.push(j as u8);
                        kept += 1;
                    }
                }
                // pad groups with fewer than n nonzeros (zeros are valid)
                while kept < n {
                    values.push(0.0);
                    offsets.push(0);
                    kept += 1;
                }
            }
        }
        Ok(NmMatrix { n, m, rows, cols, values: values.into(), offsets: offsets.into() })
    }

    pub fn to_dense(&self) -> Tensor {
        let mut out = vec![0.0f32; self.rows * self.cols];
        let groups = self.cols / self.m;
        for r in 0..self.rows {
            for g in 0..groups {
                for i in 0..self.n {
                    let k = (r * groups + g) * self.n + i;
                    let v = self.values[k];
                    if v != 0.0 {
                        out[r * self.cols + g * self.m + self.offsets[k] as usize] = v;
                    }
                }
            }
        }
        Tensor::new(vec![self.rows, self.cols], out)
    }

    /// y = x @ W^T on the token-major layout (cf. `CsrMatrix::layer`): each
    /// kept value contributes a contiguous vectorizable axpy over the token
    /// tile — the CPU analog of the sparse-tensor-core dataflow. Token
    /// tiles are stolen by the current worker pool (see `sparse::threads`).
    ///
    /// Kept values are paired up so two axpy rows stay in registers per
    /// pass; the flush issues one fused `+=` per value in kept order, so
    /// every output element sees the exact accumulation sequence of the
    /// scalar loop (bit-exactness contract — see DESIGN.md).
    pub fn layer(&self, x: &Tensor) -> Tensor {
        let (t_n, k_n) = (x.rows(), x.cols());
        assert_eq!(k_n, self.cols);
        let o_n = self.rows;
        let groups = self.cols / self.m;
        let per_row = groups * self.n;
        let xt = x.transpose2();
        let xd = xt.data();
        let mut y = vec![0.0f32; t_n * o_n];
        for_each_token_tile(t_n, o_n, &mut y, |t0, yrows| {
            let tb = yrows.len() / o_n;
            let mut acc = [0.0f32; TOKEN_TILE];
            for o in 0..o_n {
                let base = o * per_row;
                let a = &mut acc[..tb];
                a.fill(0.0);
                // pending first half of an axpy pair (padding zeros skip)
                let mut pk = 0usize;
                let mut pv = 0.0f32;
                let mut have = false;
                for g in 0..groups {
                    let gb = g * self.m;
                    for i in 0..self.n {
                        let idx = base + g * self.n + i;
                        let v = self.values[idx];
                        if v == 0.0 {
                            continue;
                        }
                        let k = gb + self.offsets[idx] as usize;
                        if !have {
                            (pk, pv, have) = (k, v, true);
                            continue;
                        }
                        let xp = &xd[pk * t_n + t0..][..tb];
                        let xc = &xd[k * t_n + t0..][..tb];
                        for tt in 0..tb {
                            let mut s = a[tt];
                            s += pv * xp[tt];
                            s += v * xc[tt];
                            a[tt] = s;
                        }
                        have = false;
                    }
                }
                if have {
                    let xp = &xd[pk * t_n + t0..][..tb];
                    for (av, xv) in a.iter_mut().zip(xp) {
                        *av += pv * xv;
                    }
                }
                for (tt, &av) in a.iter().enumerate() {
                    yrows[tt * o_n + o] = av;
                }
            }
        });
        Tensor::new(vec![t_n, o_n], y)
    }

    /// Scalar gather variant (kept for reference / tiny batches).
    pub fn layer_gather(&self, x: &Tensor) -> Tensor {
        let (t_n, k_n) = (x.rows(), x.cols());
        assert_eq!(k_n, self.cols);
        let o_n = self.rows;
        let groups = self.cols / self.m;
        let mut y = vec![0.0f32; t_n * o_n];
        let xd = x.data();
        if self.n == 2 {
            // 4-token blocking amortizes the offset decode (cf. csr.rs)
            for o in 0..o_n {
                let base = o * groups * 2;
                let vals = &self.values[base..base + groups * 2];
                let offs = &self.offsets[base..base + groups * 2];
                let mut t = 0;
                while t + 4 <= t_n {
                    let (x0, rest) = xd[t * k_n..].split_at(k_n);
                    let (x1, rest) = rest.split_at(k_n);
                    let (x2, rest) = rest.split_at(k_n);
                    let x3 = &rest[..k_n];
                    let (mut a0, mut a1, mut a2, mut a3) = (0f32, 0f32, 0f32, 0f32);
                    for g in 0..groups {
                        let gb = g * self.m;
                        let i = g * 2;
                        let (k0, v0) = (gb + offs[i] as usize, vals[i]);
                        let (k1, v1) = (gb + offs[i + 1] as usize, vals[i + 1]);
                        a0 += v0 * x0[k0] + v1 * x0[k1];
                        a1 += v0 * x1[k0] + v1 * x1[k1];
                        a2 += v0 * x2[k0] + v1 * x2[k1];
                        a3 += v0 * x3[k0] + v1 * x3[k1];
                    }
                    y[t * o_n + o] = a0;
                    y[(t + 1) * o_n + o] = a1;
                    y[(t + 2) * o_n + o] = a2;
                    y[(t + 3) * o_n + o] = a3;
                    t += 4;
                }
                while t < t_n {
                    let xr = &xd[t * k_n..(t + 1) * k_n];
                    let mut acc = 0f32;
                    for g in 0..groups {
                        let gb = g * self.m;
                        let i = g * 2;
                        acc += vals[i] * xr[gb + offs[i] as usize]
                            + vals[i + 1] * xr[gb + offs[i + 1] as usize];
                    }
                    y[t * o_n + o] = acc;
                    t += 1;
                }
            }
        } else {
            for o in 0..o_n {
                let base = o * groups * self.n;
                for t in 0..t_n {
                    let xr = &xd[t * k_n..(t + 1) * k_n];
                    let mut acc = 0f32;
                    for g in 0..groups {
                        let gb = g * self.m;
                        for i in 0..self.n {
                            let k = base + g * self.n + i;
                            acc += self.values[k] * xr[gb + self.offsets[k] as usize];
                        }
                    }
                    y[t * o_n + o] = acc;
                }
            }
        }
        Tensor::new(vec![t_n, o_n], y)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::solver::magnitude::magnitude_prune_nm;
    use crate::sparse::gemm::dense_layer;
    use crate::util::prng::Rng;

    #[test]
    fn pack_roundtrip_and_layer_match() {
        let mut rng = Rng::new(0);
        let w = Tensor::new(vec![16, 32], (0..512).map(|_| rng.normal_f32()).collect());
        let (w24, _) = magnitude_prune_nm(&w, 2, 4);
        let nm = NmMatrix::from_dense(&w24, 2, 4).unwrap();
        assert_eq!(nm.to_dense(), w24);
        let x = Tensor::new(vec![5, 32], (0..160).map(|_| rng.normal_f32()).collect());
        let a = nm.layer(&x);
        let b = dense_layer(&x, &w24);
        for (p, q) in a.data().iter().zip(b.data()) {
            assert!((p - q).abs() < 1e-3);
        }
    }

    #[test]
    fn rejects_violations() {
        let w = Tensor::ones(vec![2, 4]); // fully dense violates 2:4
        assert!(NmMatrix::from_dense(&w, 2, 4).is_err());
    }

    #[test]
    fn accepts_extra_zeros() {
        let w = Tensor::new(vec![1, 4], vec![1.0, 0.0, 0.0, 0.0]);
        let nm = NmMatrix::from_dense(&w, 2, 4).unwrap();
        assert_eq!(nm.to_dense(), w);
    }

    #[test]
    fn four_eight_pattern() {
        let mut rng = Rng::new(1);
        let w = Tensor::new(vec![8, 16], (0..128).map(|_| rng.normal_f32()).collect());
        let (w48, _) = magnitude_prune_nm(&w, 4, 8);
        let nm = NmMatrix::from_dense(&w48, 4, 8).unwrap();
        assert_eq!(nm.to_dense(), w48);
        let x = Tensor::new(vec![3, 16], (0..48).map(|_| rng.normal_f32()).collect());
        let a = nm.layer(&x);
        let b = dense_layer(&x, &w48);
        for (p, q) in a.data().iter().zip(b.data()) {
            assert!((p - q).abs() < 1e-3);
        }
    }
}
