//! CSR storage + sparse layer kernel for unstructured sparsity (the
//! DeepSparse-style regime of Table 7). Skips zero weights entirely, so
//! runtime scales with density; at 50% sparsity the ideal speedup is 2x
//! minus index-overhead.

use crate::sparse::threads::{for_each_token_tile, TOKEN_TILE};
use crate::tensor::Tensor;

#[derive(Clone, Debug)]
pub struct CsrMatrix {
    pub rows: usize,
    pub cols: usize,
    pub row_ptr: Vec<u32>,
    pub col_idx: Vec<u32>,
    pub values: Vec<f32>,
}

impl CsrMatrix {
    pub fn from_dense(w: &Tensor) -> CsrMatrix {
        let (rows, cols) = (w.rows(), w.cols());
        let mut row_ptr = Vec::with_capacity(rows + 1);
        let mut col_idx = Vec::new();
        let mut values = Vec::new();
        row_ptr.push(0u32);
        for r in 0..rows {
            for (c, &v) in w.row(r).iter().enumerate() {
                if v != 0.0 {
                    col_idx.push(c as u32);
                    values.push(v);
                }
            }
            row_ptr.push(col_idx.len() as u32);
        }
        CsrMatrix { rows, cols, row_ptr, col_idx, values }
    }

    pub fn nnz(&self) -> usize {
        self.values.len()
    }

    pub fn density(&self) -> f64 {
        self.nnz() as f64 / (self.rows * self.cols) as f64
    }

    pub fn to_dense(&self) -> Tensor {
        let mut out = vec![0.0f32; self.rows * self.cols];
        for r in 0..self.rows {
            for i in self.row_ptr[r] as usize..self.row_ptr[r + 1] as usize {
                out[r * self.cols + self.col_idx[i] as usize] = self.values[i];
            }
        }
        Tensor::new(vec![self.rows, self.cols], out)
    }

    /// y = x @ W^T with W in CSR, on a token-major (transposed) activation
    /// layout: for each nonzero w[o][k], the contribution to ALL tokens is
    /// `v * xT[k, :]` — a contiguous, auto-vectorizable axpy. This is the
    /// layout trick real CPU sparse engines (DeepSparse) use: sparsity in
    /// the weights, SIMD across the batch. The one-time transpose of x is
    /// O(T·K) against the O(nnz·T) kernel. Token tiles fan out over
    /// `SPARSEGPT_THREADS` workers (default 1).
    pub fn layer(&self, x: &Tensor) -> Tensor {
        let (t_n, k_n) = (x.rows(), x.cols());
        assert_eq!(k_n, self.cols);
        let o_n = self.rows;
        let xt = x.transpose2(); // (k_n, t_n): token dim contiguous
        let xd = xt.data();
        let mut y = vec![0.0f32; t_n * o_n];
        for_each_token_tile(t_n, o_n, &mut y, |t0, yrows| {
            let tb = yrows.len() / o_n;
            let mut acc = [0.0f32; TOKEN_TILE];
            for o in 0..o_n {
                let lo = self.row_ptr[o] as usize;
                let hi = self.row_ptr[o + 1] as usize;
                let a = &mut acc[..tb];
                a.fill(0.0);
                for i in lo..hi {
                    let v = self.values[i];
                    let k = self.col_idx[i] as usize;
                    let xr = &xd[k * t_n + t0..k * t_n + t0 + tb];
                    for (av, xv) in a.iter_mut().zip(xr) {
                        *av += v * xv; // vectorized axpy
                    }
                }
                for (tt, &av) in a.iter().enumerate() {
                    yrows[tt * o_n + o] = av;
                }
            }
        });
        Tensor::new(vec![t_n, o_n], y)
    }

    /// Scalar gather variant (kept for reference / tiny batches).
    pub fn layer_gather(&self, x: &Tensor) -> Tensor {
        let (t_n, k_n) = (x.rows(), x.cols());
        assert_eq!(k_n, self.cols);
        let o_n = self.rows;
        let mut y = vec![0.0f32; t_n * o_n];
        let xd = x.data();
        for o in 0..o_n {
            let lo = self.row_ptr[o] as usize;
            let hi = self.row_ptr[o + 1] as usize;
            let idx = &self.col_idx[lo..hi];
            let val = &self.values[lo..hi];
            let mut t = 0;
            while t + 4 <= t_n {
                let (x0, rest) = xd[t * k_n..].split_at(k_n);
                let (x1, rest) = rest.split_at(k_n);
                let (x2, rest) = rest.split_at(k_n);
                let x3 = &rest[..k_n];
                let (mut a0, mut a1, mut a2, mut a3) = (0f32, 0f32, 0f32, 0f32);
                for (&k, &v) in idx.iter().zip(val) {
                    let k = k as usize;
                    a0 += v * x0[k];
                    a1 += v * x1[k];
                    a2 += v * x2[k];
                    a3 += v * x3[k];
                }
                y[t * o_n + o] = a0;
                y[(t + 1) * o_n + o] = a1;
                y[(t + 2) * o_n + o] = a2;
                y[(t + 3) * o_n + o] = a3;
                t += 4;
            }
            while t < t_n {
                let xr = &xd[t * k_n..(t + 1) * k_n];
                let mut acc = 0f32;
                for (&k, &v) in idx.iter().zip(val) {
                    acc += v * xr[k as usize];
                }
                y[t * o_n + o] = acc;
                t += 1;
            }
        }
        Tensor::new(vec![t_n, o_n], y)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::solver::magnitude::magnitude_prune;
    use crate::sparse::gemm::dense_layer;
    use crate::util::prng::Rng;

    fn sparse_w(seed: u64, o: usize, k: usize, p: f64) -> Tensor {
        let mut rng = Rng::new(seed);
        let w = Tensor::new(vec![o, k], (0..o * k).map(|_| rng.normal_f32()).collect());
        magnitude_prune(&w, p).0
    }

    #[test]
    fn roundtrip_dense() {
        let w = sparse_w(0, 17, 23, 0.6);
        let csr = CsrMatrix::from_dense(&w);
        assert_eq!(csr.to_dense(), w);
        assert!((csr.density() - 0.4).abs() < 0.05);
    }

    #[test]
    fn layer_matches_dense_gemm() {
        let mut rng = Rng::new(1);
        let w = sparse_w(2, 32, 48, 0.5);
        let x = Tensor::new(vec![7, 48], (0..7 * 48).map(|_| rng.normal_f32()).collect());
        let a = CsrMatrix::from_dense(&w).layer(&x);
        let b = dense_layer(&x, &w);
        for (p, q) in a.data().iter().zip(b.data()) {
            assert!((p - q).abs() < 1e-3);
        }
    }

    #[test]
    fn empty_rows_ok() {
        let w = Tensor::new(vec![3, 4], vec![0.0; 12]);
        let csr = CsrMatrix::from_dense(&w);
        assert_eq!(csr.nnz(), 0);
        let x = Tensor::ones(vec![2, 4]);
        assert!(csr.layer(&x).data().iter().all(|&v| v == 0.0));
    }
}
