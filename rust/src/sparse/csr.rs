//! CSR storage + sparse layer kernel for unstructured sparsity (the
//! DeepSparse-style regime of Table 7). Skips zero weights entirely, so
//! runtime scales with density; at 50% sparsity the ideal speedup is 2x
//! minus index-overhead.
//!
//! Two layouts share the struct: the natural row order, and an optional
//! row-reordered layout (`perm`) that stores rows sorted by nonzero count
//! (ROSE-style permutation plumbing) — heavy rows stream the value/index
//! arrays together at the front of the pass, and the kernel scatters each
//! stored row back to its logical output column. Per-output-element f32
//! accumulation order is identical in both layouts (a row's nonzero list
//! does not change, only where it lives), so permuted and natural results
//! are bit-identical.

use anyhow::{bail, Result};

use crate::sparse::buf::SectionBuf;
use crate::sparse::threads::{for_each_token_tile, TOKEN_TILE};
use crate::tensor::Tensor;

#[derive(Clone, Debug)]
pub struct CsrMatrix {
    pub rows: usize,
    pub cols: usize,
    pub row_ptr: SectionBuf<u32>,
    pub col_idx: SectionBuf<u32>,
    pub values: SectionBuf<f32>,
    /// Row reordering: `perm[i]` = logical row stored at slot i (None =
    /// natural order). Applied at pack time, inverted at output scatter.
    pub perm: Option<SectionBuf<u32>>,
}

impl CsrMatrix {
    pub fn from_dense(w: &Tensor) -> Result<CsrMatrix> {
        Self::build(w, None)
    }

    /// Pack with rows stored in descending nonzero-count order (stable, so
    /// equal-weight rows keep their relative position). Bit-identical
    /// results to [`CsrMatrix::from_dense`]; better locality for skewed
    /// per-row densities.
    pub fn from_dense_permuted(w: &Tensor) -> Result<CsrMatrix> {
        let rows = w.rows();
        let mut order: Vec<u32> = (0..rows as u32).collect();
        let nnz_of = |r: &u32| w.row(*r as usize).iter().filter(|v| **v != 0.0).count();
        order.sort_by_key(|r| std::cmp::Reverse(nnz_of(r)));
        Self::build(w, Some(order))
    }

    fn build(w: &Tensor, perm: Option<Vec<u32>>) -> Result<CsrMatrix> {
        let (rows, cols) = (w.rows(), w.cols());
        let mut row_ptr = Vec::with_capacity(rows + 1);
        let mut col_idx = Vec::new();
        let mut values = Vec::new();
        row_ptr.push(0u32);
        for slot in 0..rows {
            let r = perm.as_ref().map_or(slot, |p| p[slot] as usize);
            for (c, &v) in w.row(r).iter().enumerate() {
                if v != 0.0 {
                    col_idx.push(c as u32);
                    values.push(v);
                }
            }
            // u32 row_ptr: >2^32 nonzeros used to truncate silently and
            // corrupt every later row's extent
            if col_idx.len() > u32::MAX as usize {
                bail!(
                    "CSR nonzero count {} exceeds the u32 index space \
                     ({rows}x{cols} matrix)",
                    col_idx.len()
                );
            }
            row_ptr.push(col_idx.len() as u32);
        }
        Ok(CsrMatrix {
            rows,
            cols,
            row_ptr: row_ptr.into(),
            col_idx: col_idx.into(),
            values: values.into(),
            perm: perm.map(Into::into),
        })
    }

    pub fn nnz(&self) -> usize {
        self.values.len()
    }

    pub fn density(&self) -> f64 {
        if self.rows == 0 || self.cols == 0 {
            return 0.0; // a degenerate matrix is empty, not NaN
        }
        self.nnz() as f64 / (self.rows * self.cols) as f64
    }

    /// Logical output row stored at slot `i`.
    #[inline]
    fn logical_row(&self, i: usize) -> usize {
        match &self.perm {
            Some(p) => p[i] as usize,
            None => i,
        }
    }

    pub fn to_dense(&self) -> Tensor {
        let mut out = vec![0.0f32; self.rows * self.cols];
        for slot in 0..self.rows {
            let r = self.logical_row(slot);
            for i in self.row_ptr[slot] as usize..self.row_ptr[slot + 1] as usize {
                out[r * self.cols + self.col_idx[i] as usize] = self.values[i];
            }
        }
        Tensor::new(vec![self.rows, self.cols], out)
    }

    /// y = x @ W^T with W in CSR, on a token-major (transposed) activation
    /// layout: for each nonzero w[o][k], the contribution to ALL tokens is
    /// `v * xT[k, :]` — a contiguous, auto-vectorizable axpy. This is the
    /// layout trick real CPU sparse engines (DeepSparse) use: sparsity in
    /// the weights, SIMD across the batch. The one-time transpose of x is
    /// O(T·K) against the O(nnz·T) kernel. Token tiles are stolen by the
    /// current worker pool (see `sparse::threads`).
    ///
    /// The nonzero loop is unrolled 4 wide with one fused `+=` per term, so
    /// each output element sees the exact accumulation sequence of the
    /// scalar loop (bit-exactness contract — see DESIGN.md) while the four
    /// axpy rows stay resident in registers together.
    pub fn layer(&self, x: &Tensor) -> Tensor {
        let (t_n, k_n) = (x.rows(), x.cols());
        assert_eq!(k_n, self.cols);
        let o_n = self.rows;
        let xt = x.transpose2(); // (k_n, t_n): token dim contiguous
        let xd = xt.data();
        let mut y = vec![0.0f32; t_n * o_n];
        for_each_token_tile(t_n, o_n, &mut y, |t0, yrows| {
            let tb = yrows.len() / o_n;
            let mut acc = [0.0f32; TOKEN_TILE];
            for slot in 0..o_n {
                let lo = self.row_ptr[slot] as usize;
                let hi = self.row_ptr[slot + 1] as usize;
                let a = &mut acc[..tb];
                a.fill(0.0);
                let mut i = lo;
                while i + 4 <= hi {
                    let (v0, v1, v2, v3) = (
                        self.values[i],
                        self.values[i + 1],
                        self.values[i + 2],
                        self.values[i + 3],
                    );
                    let x0 = &xd[self.col_idx[i] as usize * t_n + t0..][..tb];
                    let x1 = &xd[self.col_idx[i + 1] as usize * t_n + t0..][..tb];
                    let x2 = &xd[self.col_idx[i + 2] as usize * t_n + t0..][..tb];
                    let x3 = &xd[self.col_idx[i + 3] as usize * t_n + t0..][..tb];
                    // one += per term keeps the per-element f32 order of
                    // the serial loop (do NOT fold into one expression)
                    for tt in 0..tb {
                        let mut s = a[tt];
                        s += v0 * x0[tt];
                        s += v1 * x1[tt];
                        s += v2 * x2[tt];
                        s += v3 * x3[tt];
                        a[tt] = s;
                    }
                    i += 4;
                }
                while i < hi {
                    let v = self.values[i];
                    let k = self.col_idx[i] as usize;
                    let xr = &xd[k * t_n + t0..][..tb];
                    for (av, xv) in a.iter_mut().zip(xr) {
                        *av += v * xv; // vectorized axpy
                    }
                    i += 1;
                }
                let o = self.logical_row(slot);
                for (tt, &av) in a.iter().enumerate() {
                    yrows[tt * o_n + o] = av;
                }
            }
        });
        Tensor::new(vec![t_n, o_n], y)
    }

    /// Scalar gather variant (kept as the bit-exactness reference and for
    /// tiny batches).
    pub fn layer_gather(&self, x: &Tensor) -> Tensor {
        let (t_n, k_n) = (x.rows(), x.cols());
        assert_eq!(k_n, self.cols);
        let o_n = self.rows;
        let mut y = vec![0.0f32; t_n * o_n];
        let xd = x.data();
        for slot in 0..o_n {
            let lo = self.row_ptr[slot] as usize;
            let hi = self.row_ptr[slot + 1] as usize;
            let idx = &self.col_idx[lo..hi];
            let val = &self.values[lo..hi];
            let o = self.logical_row(slot);
            let mut t = 0;
            while t + 4 <= t_n {
                let (x0, rest) = xd[t * k_n..].split_at(k_n);
                let (x1, rest) = rest.split_at(k_n);
                let (x2, rest) = rest.split_at(k_n);
                let x3 = &rest[..k_n];
                let (mut a0, mut a1, mut a2, mut a3) = (0f32, 0f32, 0f32, 0f32);
                for (&k, &v) in idx.iter().zip(val) {
                    let k = k as usize;
                    a0 += v * x0[k];
                    a1 += v * x1[k];
                    a2 += v * x2[k];
                    a3 += v * x3[k];
                }
                y[t * o_n + o] = a0;
                y[(t + 1) * o_n + o] = a1;
                y[(t + 2) * o_n + o] = a2;
                y[(t + 3) * o_n + o] = a3;
                t += 4;
            }
            while t < t_n {
                let xr = &xd[t * k_n..(t + 1) * k_n];
                let mut acc = 0f32;
                for (&k, &v) in idx.iter().zip(val) {
                    acc += v * xr[k as usize];
                }
                y[t * o_n + o] = acc;
                t += 1;
            }
        }
        Tensor::new(vec![t_n, o_n], y)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::solver::magnitude::magnitude_prune;
    use crate::sparse::gemm::dense_layer;
    use crate::util::prng::Rng;

    fn sparse_w(seed: u64, o: usize, k: usize, p: f64) -> Tensor {
        let mut rng = Rng::new(seed);
        let w = Tensor::new(vec![o, k], (0..o * k).map(|_| rng.normal_f32()).collect());
        magnitude_prune(&w, p).0
    }

    #[test]
    fn roundtrip_dense() {
        let w = sparse_w(0, 17, 23, 0.6);
        let csr = CsrMatrix::from_dense(&w).unwrap();
        assert_eq!(csr.to_dense(), w);
        assert!((csr.density() - 0.4).abs() < 0.05);
    }

    #[test]
    fn layer_matches_dense_gemm() {
        let mut rng = Rng::new(1);
        let w = sparse_w(2, 32, 48, 0.5);
        let x = Tensor::new(vec![7, 48], (0..7 * 48).map(|_| rng.normal_f32()).collect());
        let a = CsrMatrix::from_dense(&w).unwrap().layer(&x);
        let b = dense_layer(&x, &w);
        for (p, q) in a.data().iter().zip(b.data()) {
            assert!((p - q).abs() < 1e-3);
        }
    }

    #[test]
    fn empty_rows_ok() {
        let w = Tensor::new(vec![3, 4], vec![0.0; 12]);
        let csr = CsrMatrix::from_dense(&w).unwrap();
        assert_eq!(csr.nnz(), 0);
        let x = Tensor::ones(vec![2, 4]);
        assert!(csr.layer(&x).data().iter().all(|&v| v == 0.0));
    }

    #[test]
    fn degenerate_shapes_have_zero_density() {
        // regression: 0 x N used to return NaN (0/0)
        let w = Tensor::new(vec![0, 4], vec![]);
        let csr = CsrMatrix::from_dense(&w).unwrap();
        assert_eq!(csr.density(), 0.0);
        assert!(!csr.density().is_nan());
    }

    #[test]
    fn permuted_layout_is_bit_identical() {
        let w = sparse_w(7, 29, 40, 0.55);
        let nat = CsrMatrix::from_dense(&w).unwrap();
        let per = CsrMatrix::from_dense_permuted(&w).unwrap();
        assert!(per.perm.is_some());
        assert_eq!(per.to_dense(), w);
        assert_eq!(per.nnz(), nat.nnz());
        let mut rng = Rng::new(8);
        let x = Tensor::new(vec![11, 40], (0..11 * 40).map(|_| rng.normal_f32()).collect());
        // bit-identical, not merely close: same per-element f32 op order
        assert_eq!(per.layer(&x).data(), nat.layer(&x).data());
        assert_eq!(per.layer_gather(&x).data(), nat.layer_gather(&x).data());
    }

    #[test]
    fn permutation_sorts_rows_by_weight() {
        let w = Tensor::new(
            vec![3, 4],
            vec![1.0, 0.0, 0.0, 0.0, 1.0, 2.0, 3.0, 4.0, 1.0, 2.0, 0.0, 0.0],
        );
        let per = CsrMatrix::from_dense_permuted(&w).unwrap();
        assert_eq!(per.perm.as_deref(), Some(&[1u32, 2, 0][..]));
    }

    #[test]
    fn blocked_layer_matches_gather_bitwise() {
        // the unrolled token-major kernel and the scalar gather reference
        // must agree exactly (shared accumulation-order contract)
        for (o, k, t) in [(5, 9, 3), (33, 64, 17), (48, 31, 9)] {
            let w = sparse_w(o as u64, o, k, 0.5);
            let mut rng = Rng::new(99);
            let x = Tensor::new(vec![t, k], (0..t * k).map(|_| rng.normal_f32()).collect());
            let csr = CsrMatrix::from_dense(&w).unwrap();
            assert_eq!(csr.layer(&x).data(), csr.layer_gather(&x).data());
        }
    }
}
