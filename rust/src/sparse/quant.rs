//! Quantized packed matrices: the three serving formats of
//! [`crate::sparse::pack`] with u8-coded values at 2..=8 bits instead of
//! f32 — bit-packed code streams alongside the existing index/bitmask
//! streams, with per-row or per-group (scale, zero) pairs from
//! [`QuantGrid`]. This is what makes the paper's Fig.-6 size argument
//! (50% sparse + 4-bit + bitmask ≈ 3 bits/weight) real on the serving
//! path: the `.spkt` store persists codes, and the kernels dequantize
//! *inside* the inner loop — no f32 weight matrix is ever materialized.
//!
//! Kernel contract (the testability invariant `tests/quant_parity.rs`
//! pins): each kernel visits stored entries in ascending column order per
//! output row and computes `scale * (code - zero)` per entry — exactly the
//! f32 operation [`QuantGrid::decode`] performs, which is bit-identical to
//! [`QuantGrid::quantize_at`] of the value the code came from. Therefore
//! quantized packed decode is *element-identical* to quantizing the pruned
//! dense matrix with the same grid and running the existing dense kernel.
//!
//! Structural zeros (pruned weights) are never grid-encoded: they live in
//! the index/bitmask streams, so they stay exact even on grids that do not
//! contain zero (all-positive groups).

use anyhow::{bail, Result};

use crate::solver::quant::QuantGrid;
use crate::sparse::buf::SectionBuf;
use crate::sparse::threads::{for_each_token_tile, TOKEN_TILE};
use crate::tensor::Tensor;

/// Validate a code width and return its level count (`2^bits - 1`).
pub fn levels_for_bits(bits: u8) -> Result<u32> {
    if !(2..=8).contains(&bits) {
        bail!("quantized pack formats need 2..=8 bits per code (got {bits})");
    }
    Ok((1u32 << bits) - 1)
}

/// Pack `bits`-wide codes into an LSB-first bitstream.
pub fn pack_codes(codes: &[u8], bits: u8) -> Vec<u8> {
    let bits = bits as usize;
    let mut out = vec![0u8; (codes.len() * bits).div_ceil(8)];
    for (i, &c) in codes.iter().enumerate() {
        let bit = i * bits;
        let (byte, sh) = (bit / 8, bit % 8);
        let v = (c as u16) << sh;
        out[byte] |= v as u8;
        if sh + bits > 8 {
            out[byte + 1] |= (v >> 8) as u8;
        }
    }
    out
}

/// Read code `idx` back out of a [`pack_codes`] stream.
#[inline]
pub fn code_at(stream: &[u8], idx: usize, bits: u8) -> u8 {
    let bits = bits as usize;
    let bit = idx * bits;
    let (byte, sh) = (bit / 8, bit % 8);
    let lo = stream[byte] as u16;
    let hi = if sh + bits > 8 { stream[byte + 1] as u16 } else { 0 };
    (((lo | (hi << 8)) >> sh) & ((1u16 << bits) - 1)) as u8
}

/// Expected stream length for `n` codes of `bits` bits.
pub fn code_stream_len(n: usize, bits: u8) -> usize {
    (n * bits as usize).div_ceil(8)
}

/// Build a pack-time grid: validates the code width and that a *grouped*
/// grid fits the `.spkt` v2 TOC's u16 group field (per-row grids store 0
/// there, so any column count is fine).
fn pack_grid(w: &Tensor, bits: u8, group_cols: usize) -> Result<QuantGrid> {
    let levels = levels_for_bits(bits)?;
    let grid = QuantGrid::from_weights_grouped(w, levels, group_cols);
    if grid.group_cols < grid.cols && grid.group_cols > u16::MAX as usize {
        bail!(
            "quantization group {} exceeds the .spkt TOC's u16 group field",
            grid.group_cols
        );
    }
    Ok(grid)
}

/// CSR with a bit-packed code stream instead of f32 values: the quantized
/// twin of [`crate::sparse::CsrMatrix`].
#[derive(Clone, Debug)]
pub struct QCsrMatrix {
    pub rows: usize,
    pub cols: usize,
    pub bits: u8,
    pub row_ptr: SectionBuf<u32>,
    pub col_idx: SectionBuf<u32>,
    /// bit-packed codes, one per stored entry (same order as `col_idx`)
    pub codes: SectionBuf<u8>,
    pub grid: QuantGrid,
}

impl QCsrMatrix {
    /// Quantize + pack a (pruned) dense matrix. The grid is computed from
    /// the matrix as given (zeros included in the min/max fold), exactly
    /// like the `quantize with QuantGrid -> dense` reference path.
    pub fn from_dense(w: &Tensor, bits: u8, group_cols: usize) -> Result<QCsrMatrix> {
        let grid = pack_grid(w, bits, group_cols)?;
        let (rows, cols) = (w.rows(), w.cols());
        let mut row_ptr = Vec::with_capacity(rows + 1);
        let mut col_idx = Vec::new();
        let mut raw = Vec::new();
        row_ptr.push(0u32);
        for r in 0..rows {
            for (c, &v) in w.row(r).iter().enumerate() {
                if v != 0.0 {
                    col_idx.push(c as u32);
                    raw.push(grid.encode(r, c, v));
                }
            }
            row_ptr.push(col_idx.len() as u32);
        }
        let codes = pack_codes(&raw, bits);
        Ok(QCsrMatrix {
            rows,
            cols,
            bits,
            row_ptr: row_ptr.into(),
            col_idx: col_idx.into(),
            codes: codes.into(),
            grid,
        })
    }

    /// Stored (structural-survivor) entries.
    pub fn nnz(&self) -> usize {
        self.col_idx.len()
    }

    pub fn to_dense(&self) -> Tensor {
        let mut out = vec![0.0f32; self.rows * self.cols];
        for r in 0..self.rows {
            for i in self.row_ptr[r] as usize..self.row_ptr[r + 1] as usize {
                let c = self.col_idx[i] as usize;
                out[r * self.cols + c] = self.grid.decode(r, c, code_at(&self.codes, i, self.bits));
            }
        }
        Tensor::new(vec![self.rows, self.cols], out)
    }

    /// y = x @ W^T with dequantization fused into the axpy (cf.
    /// [`crate::sparse::CsrMatrix::layer`] for the layout trick). The
    /// nonzero loop is unrolled 4 wide — four codes decoded up front, one
    /// fused `+=` per term in stream order, so every output element sees
    /// the exact accumulation sequence of the scalar loop (bit-exactness
    /// contract — see DESIGN.md); `decode()` ops are unchanged.
    pub fn layer(&self, x: &Tensor) -> Tensor {
        let (t_n, k_n) = (x.rows(), x.cols());
        assert_eq!(k_n, self.cols);
        let o_n = self.rows;
        let xt = x.transpose2();
        let xd = xt.data();
        let mut y = vec![0.0f32; t_n * o_n];
        for_each_token_tile(t_n, o_n, &mut y, |t0, yrows| {
            let tb = yrows.len() / o_n;
            let mut acc = [0.0f32; TOKEN_TILE];
            for o in 0..o_n {
                let lo = self.row_ptr[o] as usize;
                let hi = self.row_ptr[o + 1] as usize;
                let a = &mut acc[..tb];
                a.fill(0.0);
                let mut i = lo;
                while i + 4 <= hi {
                    let k0 = self.col_idx[i] as usize;
                    let k1 = self.col_idx[i + 1] as usize;
                    let k2 = self.col_idx[i + 2] as usize;
                    let k3 = self.col_idx[i + 3] as usize;
                    let v0 = self.grid.decode(o, k0, code_at(&self.codes, i, self.bits));
                    let v1 = self.grid.decode(o, k1, code_at(&self.codes, i + 1, self.bits));
                    let v2 = self.grid.decode(o, k2, code_at(&self.codes, i + 2, self.bits));
                    let v3 = self.grid.decode(o, k3, code_at(&self.codes, i + 3, self.bits));
                    let x0 = &xd[k0 * t_n + t0..][..tb];
                    let x1 = &xd[k1 * t_n + t0..][..tb];
                    let x2 = &xd[k2 * t_n + t0..][..tb];
                    let x3 = &xd[k3 * t_n + t0..][..tb];
                    for tt in 0..tb {
                        let mut s = a[tt];
                        s += v0 * x0[tt];
                        s += v1 * x1[tt];
                        s += v2 * x2[tt];
                        s += v3 * x3[tt];
                        a[tt] = s;
                    }
                    i += 4;
                }
                while i < hi {
                    let k = self.col_idx[i] as usize;
                    // dequant fused into the inner loop: exact decode() ops
                    let v = self.grid.decode(o, k, code_at(&self.codes, i, self.bits));
                    let xr = &xd[k * t_n + t0..k * t_n + t0 + tb];
                    for (av, xv) in a.iter_mut().zip(xr) {
                        *av += v * xv;
                    }
                    i += 1;
                }
                for (tt, &av) in a.iter().enumerate() {
                    yrows[tt * o_n + o] = av;
                }
            }
        });
        Tensor::new(vec![t_n, o_n], y)
    }
}

/// Bitmask-packed n:m with a bit-packed code stream: the quantized twin of
/// [`crate::sparse::NmMatrix`]. Stored entries are the group bitmask's set
/// bits, in ascending bit order per group.
#[derive(Clone, Debug)]
pub struct QNmMatrix {
    pub n: usize,
    pub m: usize,
    pub rows: usize,
    pub cols: usize,
    pub bits: u8,
    /// one mask byte per group (bit j = column g*m + j stored)
    pub masks: SectionBuf<u8>,
    /// bit-packed codes of stored entries, row-major, ascending bits
    pub codes: SectionBuf<u8>,
    /// stored-entry count (set bits across all masks)
    pub kept: usize,
    pub grid: QuantGrid,
}

impl QNmMatrix {
    pub fn from_dense(
        w: &Tensor,
        n: usize,
        m: usize,
        bits: u8,
        group_cols: usize,
    ) -> Result<QNmMatrix> {
        if n == 0 || m <= n || m > 8 {
            bail!("invalid n:m pattern {n}:{m} (need 0 < n < m <= 8)");
        }
        let (rows, cols) = (w.rows(), w.cols());
        if cols % m != 0 {
            bail!("cols {cols} not divisible by m {m}");
        }
        let grid = pack_grid(w, bits, group_cols)?;
        let groups = cols / m;
        let mut masks = vec![0u8; rows * groups];
        let mut raw = Vec::new();
        for r in 0..rows {
            let row = w.row(r);
            for g in 0..groups {
                let base = g * m;
                let mut stored = 0usize;
                for j in 0..m {
                    let v = row[base + j];
                    if v != 0.0 {
                        if stored == n {
                            bail!("row {r} group {g} violates {n}:{m} (too many nonzeros)");
                        }
                        masks[r * groups + g] |= 1u8 << j;
                        raw.push(grid.encode(r, base + j, v));
                        stored += 1;
                    }
                }
            }
        }
        let kept = raw.len();
        let codes = pack_codes(&raw, bits);
        Ok(QNmMatrix {
            n,
            m,
            rows,
            cols,
            bits,
            masks: masks.into(),
            codes: codes.into(),
            kept,
            grid,
        })
    }

    pub fn nnz(&self) -> usize {
        self.kept
    }

    pub fn to_dense(&self) -> Tensor {
        let mut out = vec![0.0f32; self.rows * self.cols];
        let groups = self.cols / self.m;
        let mut ci = 0usize;
        for r in 0..self.rows {
            for g in 0..groups {
                let mask = self.masks[r * groups + g];
                for j in 0..self.m {
                    if mask & (1u8 << j) != 0 {
                        let c = g * self.m + j;
                        out[r * self.cols + c] =
                            self.grid.decode(r, c, code_at(&self.codes, ci, self.bits));
                        ci += 1;
                    }
                }
            }
        }
        Tensor::new(vec![self.rows, self.cols], out)
    }

    /// y = x @ W^T, dequant fused (cf. [`crate::sparse::NmMatrix::layer`]).
    /// Each token tile walks the whole code stream with a running cursor —
    /// stored entries are row-major, so rows stay independent.
    pub fn layer(&self, x: &Tensor) -> Tensor {
        let (t_n, k_n) = (x.rows(), x.cols());
        assert_eq!(k_n, self.cols);
        let o_n = self.rows;
        let groups = self.cols / self.m;
        let xt = x.transpose2();
        let xd = xt.data();
        let mut y = vec![0.0f32; t_n * o_n];
        for_each_token_tile(t_n, o_n, &mut y, |t0, yrows| {
            let tb = yrows.len() / o_n;
            let mut acc = [0.0f32; TOKEN_TILE];
            let mut ci = 0usize;
            for o in 0..o_n {
                let a = &mut acc[..tb];
                a.fill(0.0);
                // pair up stored entries so two axpy rows run per pass;
                // one fused += per entry keeps the scalar f32 order
                let mut pk = 0usize;
                let mut pv = 0.0f32;
                let mut have = false;
                for g in 0..groups {
                    let mask = self.masks[o * groups + g];
                    if mask == 0 {
                        continue;
                    }
                    let gb = g * self.m;
                    for j in 0..self.m {
                        if mask & (1u8 << j) == 0 {
                            continue;
                        }
                        let k = gb + j;
                        let v = self.grid.decode(o, k, code_at(&self.codes, ci, self.bits));
                        ci += 1;
                        if !have {
                            (pk, pv, have) = (k, v, true);
                            continue;
                        }
                        let xp = &xd[pk * t_n + t0..][..tb];
                        let xc = &xd[k * t_n + t0..][..tb];
                        for tt in 0..tb {
                            let mut s = a[tt];
                            s += pv * xp[tt];
                            s += v * xc[tt];
                            a[tt] = s;
                        }
                        have = false;
                    }
                }
                if have {
                    let xp = &xd[pk * t_n + t0..][..tb];
                    for (av, xv) in a.iter_mut().zip(xp) {
                        *av += pv * xv;
                    }
                }
                for (tt, &av) in a.iter().enumerate() {
                    yrows[tt * o_n + o] = av;
                }
            }
        });
        Tensor::new(vec![t_n, o_n], y)
    }
}

/// Dense-shaped quantized storage: a survivor bitmask (1 bit per element —
/// the paper's Fig.-6 accounting unit) plus bit-packed codes for the
/// survivors. The quantized fallback for matrices too dense for CSR/n:m.
#[derive(Clone, Debug)]
pub struct QDenseMatrix {
    pub rows: usize,
    pub cols: usize,
    pub bits: u8,
    /// survivor bitmask over rows*cols elements, row-major, LSB-first
    pub mask: SectionBuf<u8>,
    /// bit-packed codes of survivors, row-major
    pub codes: SectionBuf<u8>,
    /// survivor count (set bits in `mask`)
    pub kept: usize,
    pub grid: QuantGrid,
}

impl QDenseMatrix {
    pub fn from_dense(w: &Tensor, bits: u8, group_cols: usize) -> Result<QDenseMatrix> {
        let grid = pack_grid(w, bits, group_cols)?;
        let (rows, cols) = (w.rows(), w.cols());
        let mut mask = vec![0u8; (rows * cols).div_ceil(8)];
        let mut raw = Vec::new();
        for r in 0..rows {
            for (c, &v) in w.row(r).iter().enumerate() {
                if v != 0.0 {
                    let idx = r * cols + c;
                    mask[idx / 8] |= 1u8 << (idx % 8);
                    raw.push(grid.encode(r, c, v));
                }
            }
        }
        let kept = raw.len();
        let codes = pack_codes(&raw, bits);
        Ok(QDenseMatrix { rows, cols, bits, mask: mask.into(), codes: codes.into(), kept, grid })
    }

    #[inline]
    fn stored(&self, idx: usize) -> bool {
        self.mask[idx / 8] & (1u8 << (idx % 8)) != 0
    }

    pub fn nnz(&self) -> usize {
        self.kept
    }

    pub fn to_dense(&self) -> Tensor {
        let mut out = vec![0.0f32; self.rows * self.cols];
        let mut ci = 0usize;
        for r in 0..self.rows {
            for c in 0..self.cols {
                if self.stored(r * self.cols + c) {
                    out[r * self.cols + c] =
                        self.grid.decode(r, c, code_at(&self.codes, ci, self.bits));
                    ci += 1;
                }
            }
        }
        Tensor::new(vec![self.rows, self.cols], out)
    }

    /// y = x @ W^T, dequant fused; scans the bitmask in ascending column
    /// order per row with a running code cursor.
    pub fn layer(&self, x: &Tensor) -> Tensor {
        let (t_n, k_n) = (x.rows(), x.cols());
        assert_eq!(k_n, self.cols);
        let o_n = self.rows;
        let xt = x.transpose2();
        let xd = xt.data();
        let mut y = vec![0.0f32; t_n * o_n];
        for_each_token_tile(t_n, o_n, &mut y, |t0, yrows| {
            let tb = yrows.len() / o_n;
            let mut acc = [0.0f32; TOKEN_TILE];
            let mut ci = 0usize;
            for o in 0..o_n {
                let a = &mut acc[..tb];
                a.fill(0.0);
                // pair up survivors (cf. QNmMatrix::layer): two axpy rows
                // per pass, one fused += per survivor in mask-scan order
                let mut pk = 0usize;
                let mut pv = 0.0f32;
                let mut have = false;
                for k in 0..self.cols {
                    if !self.stored(o * self.cols + k) {
                        continue;
                    }
                    let v = self.grid.decode(o, k, code_at(&self.codes, ci, self.bits));
                    ci += 1;
                    if !have {
                        (pk, pv, have) = (k, v, true);
                        continue;
                    }
                    let xp = &xd[pk * t_n + t0..][..tb];
                    let xc = &xd[k * t_n + t0..][..tb];
                    for tt in 0..tb {
                        let mut s = a[tt];
                        s += pv * xp[tt];
                        s += v * xc[tt];
                        a[tt] = s;
                    }
                    have = false;
                }
                if have {
                    let xp = &xd[pk * t_n + t0..][..tb];
                    for (av, xv) in a.iter_mut().zip(xp) {
                        *av += pv * xv;
                    }
                }
                for (tt, &av) in a.iter().enumerate() {
                    yrows[tt * o_n + o] = av;
                }
            }
        });
        Tensor::new(vec![t_n, o_n], y)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::solver::magnitude::{magnitude_prune, magnitude_prune_nm};
    use crate::sparse::dense_layer;
    use crate::util::prng::Rng;

    fn random(seed: u64, r: usize, c: usize) -> Tensor {
        let mut rng = Rng::new(seed);
        Tensor::new(vec![r, c], (0..r * c).map(|_| rng.normal_f32()).collect())
    }

    #[test]
    fn code_stream_round_trips_every_width() {
        let mut rng = Rng::new(0);
        for bits in 2u8..=8 {
            let maxc = (1u16 << bits) - 1;
            let codes: Vec<u8> = (0..97).map(|_| (rng.below(maxc as usize + 1)) as u8).collect();
            let stream = pack_codes(&codes, bits);
            assert_eq!(stream.len(), code_stream_len(codes.len(), bits));
            for (i, &c) in codes.iter().enumerate() {
                assert_eq!(code_at(&stream, i, bits), c, "bits {bits} idx {i}");
            }
        }
    }

    #[test]
    fn qcsr_matches_quantize_then_dense_kernel() {
        // the module contract: dequant-fused decode == quantize the pruned
        // matrix on the same grid, then run the dense kernel
        let (w, _) = magnitude_prune(&random(1, 16, 32), 0.5);
        let x = random(2, 5, 32);
        for (bits, group) in [(3u8, 0usize), (4, 8), (8, 16)] {
            let q = QCsrMatrix::from_dense(&w, bits, group).unwrap();
            let reference = q.grid.quantize_surviving(&w);
            assert_eq!(q.to_dense().data(), reference.data(), "bits {bits} g {group}");
            assert_eq!(
                q.layer(&x).data(),
                dense_layer(&x, &reference).data(),
                "bits {bits} g {group}"
            );
        }
    }

    #[test]
    fn qnm_matches_quantize_then_dense_kernel() {
        let (w, _) = magnitude_prune_nm(&random(3, 16, 32), 2, 4);
        let x = random(4, 5, 32);
        for (bits, group) in [(4u8, 0usize), (8, 8)] {
            let q = QNmMatrix::from_dense(&w, 2, 4, bits, group).unwrap();
            let reference = q.grid.quantize_surviving(&w);
            assert_eq!(q.to_dense().data(), reference.data(), "bits {bits} g {group}");
            assert_eq!(
                q.layer(&x).data(),
                dense_layer(&x, &reference).data(),
                "bits {bits} g {group}"
            );
        }
        // too many nonzeros per group is a clean error
        assert!(QNmMatrix::from_dense(&Tensor::ones(vec![2, 4]), 2, 4, 4, 0).is_err());
    }

    #[test]
    fn qdense_matches_quantize_then_dense_kernel() {
        // mixed case: some zeros (the bitmask path) on an otherwise dense
        // matrix, plus a fully dense one
        let mut w = random(5, 12, 24);
        for j in 0..12 {
            w.set2(j % 12, (j * 7) % 24, 0.0);
        }
        let x = random(6, 4, 24);
        for wcase in [w, random(7, 12, 24)] {
            for (bits, group) in [(4u8, 0usize), (8, 6)] {
                let q = QDenseMatrix::from_dense(&wcase, bits, group).unwrap();
                let reference = q.grid.quantize_surviving(&wcase);
                assert_eq!(q.to_dense().data(), reference.data(), "bits {bits} g {group}");
                assert_eq!(
                    q.layer(&x).data(),
                    dense_layer(&x, &reference).data(),
                    "bits {bits} g {group}"
                );
            }
        }
    }

    #[test]
    fn structural_zeros_never_pass_through_the_grid() {
        // pruned entries come back as exact zeros via the index/bitmask
        // streams — they are never grid-encoded, so no rounding can touch
        // them regardless of what the grid looks like
        let w = Tensor::new(vec![1, 8], vec![1.0, 0.0, 2.0, 0.0, 3.0, 0.0, 4.0, 0.0]);
        let q = QCsrMatrix::from_dense(&w, 4, 0).unwrap();
        let d = q.to_dense();
        for c in [1usize, 3, 5, 7] {
            assert_eq!(d.at2(0, c), 0.0, "col {c}");
        }
        assert_eq!(q.nnz(), 4);
        let qd = QDenseMatrix::from_dense(&w, 4, 0).unwrap();
        assert_eq!(qd.nnz(), 4);
        assert_eq!(qd.to_dense().data(), d.data());
    }

    #[test]
    fn bits_out_of_range_rejected() {
        let w = random(8, 4, 8);
        for bits in [0u8, 1, 9] {
            assert!(QCsrMatrix::from_dense(&w, bits, 0).is_err(), "bits {bits}");
            assert!(QDenseMatrix::from_dense(&w, bits, 0).is_err(), "bits {bits}");
        }
    }

    #[test]
    fn oversized_grid_group_rejected() {
        // the .spkt v2 TOC stores the group in a u16: a grouped grid that
        // cannot fit must fail at pack time instead of truncating silently
        let w = Tensor::new(vec![1, 70_000], vec![1.0; 70_000]);
        assert!(QCsrMatrix::from_dense(&w, 4, 66_000).is_err());
        // per-row grids (group 0 or >= cols) store 0 in the TOC: always ok
        assert!(QCsrMatrix::from_dense(&w, 4, 0).is_ok());
        assert!(QCsrMatrix::from_dense(&w, 4, 100_000).is_ok());
    }
}
