//! Dense baseline: y = x @ W^T with register-blocked inner loops — the
//! "cuBLAS / dense DeepSparse" stand-in that the sparse kernels are
//! measured against. Token tiles are stolen by the current worker pool
//! (see [`crate::sparse::threads`]); default pool size is 1.

use crate::sparse::threads::{for_each_token_tile, TOKEN_TILE};
use crate::tensor::Tensor;

/// y[t, o] = sum_k x[t, k] * w[o, k];  x: (T, K), w: (O, K) -> y: (T, O).
///
/// Same token-major axpy structure as the sparse kernels (one contiguous
/// vectorizable update per weight), so Table 7/8 compare identical kernel
/// shapes that differ only in how many weight terms they visit. The tile
/// body blocks 4 output rows together, reusing each transposed x row for
/// four weight rows; per output element the k-ascending one-`+=`-per-term
/// accumulation order of the scalar loop is unchanged (bit-exactness
/// contract — see DESIGN.md).
pub fn dense_layer(x: &Tensor, w: &Tensor) -> Tensor {
    dense_layer_slice(x, w.data(), w.rows(), w.cols())
}

/// Slice-weight twin of [`dense_layer`]: `wd` is the row-major (O, K) weight
/// payload, possibly a view straight into a mapped `.spkt` section. Same
/// tile body, same accumulation order — the two entry points are
/// element-identical by construction.
pub fn dense_layer_slice(x: &Tensor, wd: &[f32], o_n: usize, k_n: usize) -> Tensor {
    let (t_n, k2) = (x.rows(), x.cols());
    assert_eq!(k_n, k2);
    assert_eq!(wd.len(), o_n * k_n);
    let xt = x.transpose2();
    let xd = xt.data();
    let mut y = vec![0.0f32; t_n * o_n];
    for_each_token_tile(t_n, o_n, &mut y, |t0, yrows| {
        let tb = yrows.len() / o_n;
        let mut acc0 = [0.0f32; TOKEN_TILE];
        let mut acc1 = [0.0f32; TOKEN_TILE];
        let mut acc2 = [0.0f32; TOKEN_TILE];
        let mut acc3 = [0.0f32; TOKEN_TILE];
        let mut o = 0;
        while o + 4 <= o_n {
            let w0 = &wd[o * k_n..][..k_n];
            let w1 = &wd[(o + 1) * k_n..][..k_n];
            let w2 = &wd[(o + 2) * k_n..][..k_n];
            let w3 = &wd[(o + 3) * k_n..][..k_n];
            let a0 = &mut acc0[..tb];
            let a1 = &mut acc1[..tb];
            let a2 = &mut acc2[..tb];
            let a3 = &mut acc3[..tb];
            a0.fill(0.0);
            a1.fill(0.0);
            a2.fill(0.0);
            a3.fill(0.0);
            for k in 0..k_n {
                let xr = &xd[k * t_n + t0..][..tb];
                let (v0, v1, v2, v3) = (w0[k], w1[k], w2[k], w3[k]);
                for tt in 0..tb {
                    let xv = xr[tt];
                    a0[tt] += v0 * xv;
                    a1[tt] += v1 * xv;
                    a2[tt] += v2 * xv;
                    a3[tt] += v3 * xv;
                }
            }
            for tt in 0..tb {
                let yr = &mut yrows[tt * o_n + o..][..4];
                yr[0] = a0[tt];
                yr[1] = a1[tt];
                yr[2] = a2[tt];
                yr[3] = a3[tt];
            }
            o += 4;
        }
        while o < o_n {
            let wr = &wd[o * k_n..(o + 1) * k_n];
            let a = &mut acc0[..tb];
            a.fill(0.0);
            for (k, &v) in wr.iter().enumerate() {
                let xr = &xd[k * t_n + t0..k * t_n + t0 + tb];
                for (av, xv) in a.iter_mut().zip(xr) {
                    *av += v * xv;
                }
            }
            for (tt, &av) in a.iter().enumerate() {
                yrows[tt * o_n + o] = av;
            }
            o += 1;
        }
    });
    Tensor::new(vec![t_n, o_n], y)
}

/// Register-blocked row-major variant (kept for comparison).
pub fn dense_layer_rowmajor(x: &Tensor, w: &Tensor) -> Tensor {
    let (t_n, k_n) = (x.rows(), x.cols());
    let (o_n, k2) = (w.rows(), w.cols());
    assert_eq!(k_n, k2);
    let mut y = vec![0.0f32; t_n * o_n];
    let xd = x.data();
    let wd = w.data();
    // process 4 output rows at a time to reuse the x row in registers
    let mut o = 0;
    while o + 4 <= o_n {
        let w0 = &wd[o * k_n..(o + 1) * k_n];
        let w1 = &wd[(o + 1) * k_n..(o + 2) * k_n];
        let w2 = &wd[(o + 2) * k_n..(o + 3) * k_n];
        let w3 = &wd[(o + 3) * k_n..(o + 4) * k_n];
        for t in 0..t_n {
            let xr = &xd[t * k_n..(t + 1) * k_n];
            let (mut a0, mut a1, mut a2, mut a3) = (0f32, 0f32, 0f32, 0f32);
            for k in 0..k_n {
                let xv = xr[k];
                a0 += xv * w0[k];
                a1 += xv * w1[k];
                a2 += xv * w2[k];
                a3 += xv * w3[k];
            }
            let yr = &mut y[t * o_n + o..t * o_n + o + 4];
            yr[0] = a0;
            yr[1] = a1;
            yr[2] = a2;
            yr[3] = a3;
        }
        o += 4;
    }
    while o < o_n {
        let wr = &wd[o * k_n..(o + 1) * k_n];
        for t in 0..t_n {
            let xr = &xd[t * k_n..(t + 1) * k_n];
            let mut acc = 0f32;
            for k in 0..k_n {
                acc += xr[k] * wr[k];
            }
            y[t * o_n + o] = acc;
        }
        o += 1;
    }
    Tensor::new(vec![t_n, o_n], y)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Rng;

    #[test]
    fn matches_tensor_matmul() {
        let mut rng = Rng::new(0);
        let x = Tensor::new(vec![9, 33], (0..9 * 33).map(|_| rng.normal_f32()).collect());
        let w = Tensor::new(vec![14, 33], (0..14 * 33).map(|_| rng.normal_f32()).collect());
        let y = dense_layer(&x, &w);
        let yref = x.matmul(&w.transpose2());
        for (a, b) in y.data().iter().zip(yref.data()) {
            assert!((a - b).abs() < 1e-3, "{a} vs {b}");
        }
    }
}
