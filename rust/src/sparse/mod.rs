//! CPU sparse inference engine — the substrate for the paper's Appendix-E
//! acceleration study (Table 7: DeepSparse-style unstructured speedups;
//! Table 8: CUTLASS-style 2:4 structured speedups).
//!
//! Computes y = x @ W^T for a layer with weights W (d_out, d_in) over a
//! batch of token activations x (tokens, d_in), in three regimes: dense
//! reference GEMM, CSR (unstructured sparsity), and 2:4 structured — each
//! in f32 or with bit-packed quantized codes dequantized inside the inner
//! loop (`quant.rs`).

pub mod buf;
pub mod csr;
pub mod gemm;
pub mod nm;
pub mod pack;
pub mod pool;
pub mod quant;
pub mod threads;

pub use buf::SectionBuf;
pub use csr::CsrMatrix;
pub use gemm::dense_layer;
pub use nm::NmMatrix;
pub use pack::{DenseMatrix, PackFormat, PackPolicy, PackedMatrix};
pub use pool::WorkerPool;
pub use quant::{QCsrMatrix, QDenseMatrix, QNmMatrix};
