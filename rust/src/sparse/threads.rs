//! Token-tile driver for the sparse/dense layer kernels.
//!
//! All kernels (`dense_layer`, `CsrMatrix::layer`, `NmMatrix::layer`, the
//! quantized variants) share the same loop skeleton: the output y
//! (tokens, d_out) is produced one token *tile* at a time, and tiles are
//! independent. This module owns that skeleton and drains the tiles over a
//! persistent [`WorkerPool`](crate::sparse::pool::WorkerPool) with per-tile
//! work stealing: workers race on a shared atomic tile counter, so a slow
//! tile (cache misses, an uneven CSR row range) never leaves the other
//! workers idle the way the old contiguous-span split could.
//!
//! Every output element is computed by exactly one worker with the same
//! accumulation order as the serial loop, so results are bit-identical for
//! any worker count — the parity proptests hold regardless of the setting.
//!
//! Which pool runs the tiles is the caller's business, not the kernels':
//! [`for_each_token_tile`] uses the thread's installed pool (the serve
//! engine installs its own around the step loop) and falls back to the
//! process-global one. The old `num_threads()` — a process-global
//! `OnceLock` that froze the first `SPARSEGPT_THREADS` read forever — is
//! gone; the env var is read once at startup when the global pool is built.

use std::sync::atomic::{AtomicUsize, Ordering};

use crate::sparse::pool::WorkerPool;

/// Token tile kept L1/L2-resident by every kernel in this module's family.
pub const TOKEN_TILE: usize = 256;

/// Outputs smaller than this stay serial even with workers configured —
/// waking the pool would rival the kernel work itself.
const MIN_PARALLEL_OUT: usize = 8192;

/// Parse a `SPARSEGPT_THREADS` value: a worker count (0 is treated as 1,
/// matching the long-documented "0 means default" behavior). Anything
/// unparseable is an explicit error — a typo like `SPARSEGPT_THREADS=eight`
/// must not silently run single-threaded while the operator believes the
/// kernels are parallel.
pub fn parse_worker_count(raw: &str) -> Result<usize, String> {
    match raw.trim().parse::<usize>() {
        Ok(n) => Ok(n.max(1)),
        Err(_) => Err(format!(
            "SPARSEGPT_THREADS={raw:?} is not a worker count (expected a \
             non-negative integer; 0 selects the single-thread default)"
        )),
    }
}

/// Worker count from `SPARSEGPT_THREADS` with the error surfaced — the CLI
/// calls this at startup (before sizing the global pool) so a typo'd value
/// fails the run up front instead of panicking mid-decode.
pub fn worker_count() -> Result<usize, String> {
    match std::env::var("SPARSEGPT_THREADS") {
        Ok(raw) => parse_worker_count(&raw),
        Err(_) => Ok(1),
    }
}

/// Run `tile(t0, y_rows)` for every token tile `[t0, t0 + tb)` of an output
/// buffer `y` with `t_n` rows of `o_n` columns, where `y_rows` is exactly
/// that tile's contiguous row span of `y`. Tiles are stolen one at a time
/// by the current thread's [`WorkerPool`] (tiny outputs stay serial).
pub fn for_each_token_tile<F>(t_n: usize, o_n: usize, y: &mut [f32], tile: F)
where
    F: Fn(usize, &mut [f32]) + Sync,
{
    // tiny outputs stay serial: waking the pool would rival the kernel work
    if y.len() < MIN_PARALLEL_OUT {
        return serial_tiles(t_n, o_n, y, &tile);
    }
    for_each_token_tile_in(&WorkerPool::current(), t_n, o_n, y, tile)
}

/// [`for_each_token_tile`] on an explicit pool (no size cutoff — callers
/// who name a pool mean it).
pub fn for_each_token_tile_in<F>(
    pool: &WorkerPool,
    t_n: usize,
    o_n: usize,
    y: &mut [f32],
    tile: F,
) where
    F: Fn(usize, &mut [f32]) + Sync,
{
    debug_assert_eq!(y.len(), t_n * o_n);
    if t_n == 0 || o_n == 0 {
        return;
    }
    let n_tiles = t_n.div_ceil(TOKEN_TILE);
    if pool.workers() <= 1 || n_tiles <= 1 {
        return serial_tiles(t_n, o_n, y, &tile);
    }
    // Work stealing over a shared tile counter. Each claimed tile i owns
    // the disjoint row span y[i*TOKEN_TILE*o_n ..], so handing workers raw
    // sub-slices is sound: no element is reachable from two tiles.
    let next = AtomicUsize::new(0);
    let out = SpanOut { ptr: y.as_mut_ptr() };
    let next = &next;
    let out = &out;
    let tile = &tile;
    pool.run(&move || loop {
        let i = next.fetch_add(1, Ordering::Relaxed);
        if i >= n_tiles {
            break;
        }
        pool.note_tile();
        let t0 = i * TOKEN_TILE;
        let tb = TOKEN_TILE.min(t_n - t0);
        // SAFETY: tile i exclusively owns rows [t0, t0 + tb) of y, and the
        // pool's run() does not return until every worker is done.
        let rows = unsafe { std::slice::from_raw_parts_mut(out.ptr.add(t0 * o_n), tb * o_n) };
        tile(t0, rows);
    });
}

fn serial_tiles<F>(t_n: usize, o_n: usize, y: &mut [f32], tile: &F)
where
    F: Fn(usize, &mut [f32]),
{
    debug_assert_eq!(y.len(), t_n * o_n);
    if t_n == 0 || o_n == 0 {
        return;
    }
    for t0 in (0..t_n).step_by(TOKEN_TILE) {
        let tb = TOKEN_TILE.min(t_n - t0);
        tile(t0, &mut y[t0 * o_n..(t0 + tb) * o_n]);
    }
}

/// Raw base pointer of the shared output buffer, smuggled past the closure
/// capture rules; tile ownership (disjoint spans) makes the aliasing sound.
struct SpanOut {
    ptr: *mut f32,
}
unsafe impl Send for SpanOut {}
unsafe impl Sync for SpanOut {}

#[cfg(test)]
mod tests {
    use super::*;

    fn fill(workers: usize, t_n: usize, o_n: usize) -> Vec<f32> {
        let pool = WorkerPool::new(workers);
        let mut y = vec![0.0f32; t_n * o_n];
        for_each_token_tile_in(&pool, t_n, o_n, &mut y, |t0, rows| {
            for (i, v) in rows.iter_mut().enumerate() {
                *v = (t0 * o_n + i) as f32;
            }
        });
        y
    }

    #[test]
    fn covers_every_element_exactly_once() {
        for workers in [1, 2, 3, 8] {
            for (t_n, o_n) in [(1, 3), (255, 4), (256, 4), (257, 4), (1000, 7)] {
                let y = fill(workers, t_n, o_n);
                for (i, v) in y.iter().enumerate() {
                    assert_eq!(*v, i as f32, "workers={workers} t_n={t_n} o_n={o_n} idx {i}");
                }
            }
        }
    }

    #[test]
    fn oversubscribed_worker_count_is_harmless() {
        // more workers than tiles must not panic or drop tiles
        let y = fill(64, 300, 2);
        assert_eq!(y.last().copied(), Some((300 * 2 - 1) as f32));
    }

    #[test]
    fn installed_pool_drives_the_implicit_driver() {
        // large enough to clear MIN_PARALLEL_OUT so the pool path runs
        let (t_n, o_n) = (513, 32);
        let pool = WorkerPool::new(3);
        let mut y = vec![0.0f32; t_n * o_n];
        pool.install(|| {
            for_each_token_tile(t_n, o_n, &mut y, |t0, rows| {
                for (i, v) in rows.iter_mut().enumerate() {
                    *v = (t0 * o_n + i) as f32;
                }
            });
        });
        for (i, v) in y.iter().enumerate() {
            assert_eq!(*v, i as f32, "idx {i}");
        }
    }

    #[test]
    fn stolen_tiles_are_counted_once_each() {
        let pool = WorkerPool::new(2);
        let (t_n, o_n) = (1000, 4); // 4 tiles of TOKEN_TILE=256
        let mut y = vec![0.0f32; t_n * o_n];
        for_each_token_tile_in(&pool, t_n, o_n, &mut y, |_, rows| {
            for v in rows.iter_mut() {
                *v = 1.0;
            }
        });
        let total: u64 = pool.stats().iter().map(|&(_, tiles)| tiles).sum();
        assert_eq!(total, t_n.div_ceil(TOKEN_TILE) as u64);
        // the serial path (single worker) never books tiles
        let solo = WorkerPool::new(1);
        for_each_token_tile_in(&solo, t_n, o_n, &mut y, |_, _| {});
        assert_eq!(solo.stats(), vec![(0, 0)]);
    }

    #[test]
    fn worker_count_parses_strictly() {
        assert_eq!(parse_worker_count("4"), Ok(4));
        assert_eq!(parse_worker_count(" 2 "), Ok(2));
        // 0 keeps its documented "use the default" meaning
        assert_eq!(parse_worker_count("0"), Ok(1));
        // regression: these used to silently fall back to 1 thread
        for bad in ["eight", "", "4x", "-2", "1.5"] {
            let err = parse_worker_count(bad).unwrap_err();
            assert!(err.contains("SPARSEGPT_THREADS"), "{bad:?} -> {err}");
        }
    }
}
