//! Row-parallel driver for the sparse/dense layer kernels.
//!
//! All three kernels (`dense_layer`, `CsrMatrix::layer`, `NmMatrix::layer`)
//! share the same loop skeleton: the output y (tokens, d_out) is produced
//! one token *tile* at a time, and tiles are independent. This module owns
//! that skeleton and fans tiles out over `std::thread::scope` workers when
//! `SPARSEGPT_THREADS` asks for more than one (default 1, so single-core
//! bench numbers stay comparable with earlier PRs).
//!
//! Every output element is computed by exactly one worker with the same
//! accumulation order as the serial loop, so results are bit-identical for
//! any thread count — the parity proptests hold regardless of the setting.

/// Token tile kept L1/L2-resident by every kernel in this module's family.
pub const TOKEN_TILE: usize = 256;

/// Outputs smaller than this stay serial even with workers configured —
/// thread spawn/join would rival the kernel work itself.
const MIN_PARALLEL_OUT: usize = 8192;

/// Parse a `SPARSEGPT_THREADS` value: a worker count (0 is treated as 1,
/// matching the long-documented "0 means default" behavior). Anything
/// unparseable is an explicit error — a typo like `SPARSEGPT_THREADS=eight`
/// must not silently run single-threaded while the operator believes the
/// kernels are parallel.
pub fn parse_worker_count(raw: &str) -> Result<usize, String> {
    match raw.trim().parse::<usize>() {
        Ok(n) => Ok(n.max(1)),
        Err(_) => Err(format!(
            "SPARSEGPT_THREADS={raw:?} is not a worker count (expected a \
             non-negative integer; 0 selects the single-thread default)"
        )),
    }
}

/// Worker count from `SPARSEGPT_THREADS` with the error surfaced — the CLI
/// calls this at startup so a typo'd value fails the run up front instead
/// of panicking mid-decode.
pub fn worker_count() -> Result<usize, String> {
    match std::env::var("SPARSEGPT_THREADS") {
        Ok(raw) => parse_worker_count(&raw),
        Err(_) => Ok(1),
    }
}

/// Worker count from `SPARSEGPT_THREADS` (default 1; 0 is treated as 1).
/// Read once per process — the kernels sit in the decode hot loop and must
/// not take the env lock per call. Panics on an unparseable value (library
/// callers who want the error instead should check [`worker_count`] first,
/// as the CLI does at startup).
pub fn num_threads() -> usize {
    static THREADS: std::sync::OnceLock<usize> = std::sync::OnceLock::new();
    *THREADS.get_or_init(|| worker_count().unwrap_or_else(|e| panic!("{e}")))
}

/// Run `tile(t0, y_rows)` for every token tile `[t0, t0 + tb)` of an output
/// buffer `y` with `t_n` rows of `o_n` columns, where `y_rows` is exactly
/// that tile's contiguous row span of `y`. Tiles are distributed over
/// [`num_threads`] scoped threads (contiguous spans of whole tiles per
/// worker), or run serially when one thread is configured.
pub fn for_each_token_tile<F>(t_n: usize, o_n: usize, y: &mut [f32], tile: F)
where
    F: Fn(usize, &mut [f32]) + Sync,
{
    // tiny outputs stay serial: spawn/join would rival the kernel work
    let threads = if y.len() < MIN_PARALLEL_OUT { 1 } else { num_threads() };
    for_each_token_tile_with(threads, t_n, o_n, y, tile)
}

fn for_each_token_tile_with<F>(threads: usize, t_n: usize, o_n: usize, y: &mut [f32], tile: F)
where
    F: Fn(usize, &mut [f32]) + Sync,
{
    debug_assert_eq!(y.len(), t_n * o_n);
    if t_n == 0 || o_n == 0 {
        return;
    }
    let n_tiles = t_n.div_ceil(TOKEN_TILE);
    let threads = threads.min(n_tiles);
    if threads <= 1 {
        for t0 in (0..t_n).step_by(TOKEN_TILE) {
            let tb = TOKEN_TILE.min(t_n - t0);
            tile(t0, &mut y[t0 * o_n..(t0 + tb) * o_n]);
        }
        return;
    }
    // contiguous spans of whole tiles per worker, so each worker's output
    // rows form one contiguous &mut slice of y
    let rows_per = n_tiles.div_ceil(threads) * TOKEN_TILE;
    std::thread::scope(|scope| {
        let mut rest = &mut y[..];
        let mut t0 = 0usize;
        while t0 < t_n {
            let span = rows_per.min(t_n - t0);
            // move `rest` out so the split inherits its full lifetime
            let taken = std::mem::take(&mut rest);
            let (mine, tail) = taken.split_at_mut(span * o_n);
            rest = tail;
            let start = t0;
            let tile = &tile;
            scope.spawn(move || {
                let mut off = 0usize;
                while off < span {
                    let tb = TOKEN_TILE.min(span - off);
                    tile(start + off, &mut mine[off * o_n..(off + tb) * o_n]);
                    off += tb;
                }
            });
            t0 += span;
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fill(threads: usize, t_n: usize, o_n: usize) -> Vec<f32> {
        let mut y = vec![0.0f32; t_n * o_n];
        for_each_token_tile_with(threads, t_n, o_n, &mut y, |t0, rows| {
            for (i, v) in rows.iter_mut().enumerate() {
                *v = (t0 * o_n + i) as f32;
            }
        });
        y
    }

    #[test]
    fn covers_every_element_exactly_once() {
        for threads in [1, 2, 3, 8] {
            for (t_n, o_n) in [(1, 3), (255, 4), (256, 4), (257, 4), (1000, 7)] {
                let y = fill(threads, t_n, o_n);
                for (i, v) in y.iter().enumerate() {
                    assert_eq!(*v, i as f32, "threads={threads} t_n={t_n} o_n={o_n} idx {i}");
                }
            }
        }
    }

    #[test]
    fn oversubscribed_thread_count_is_clamped() {
        // more workers than tiles must not panic or drop tiles
        let y = fill(64, 300, 2);
        assert_eq!(y.last().copied(), Some((300 * 2 - 1) as f32));
    }

    #[test]
    fn env_default_is_single_thread() {
        if std::env::var_os("SPARSEGPT_THREADS").is_none() {
            assert_eq!(num_threads(), 1);
        }
    }

    #[test]
    fn worker_count_parses_strictly() {
        assert_eq!(parse_worker_count("4"), Ok(4));
        assert_eq!(parse_worker_count(" 2 "), Ok(2));
        // 0 keeps its documented "use the default" meaning
        assert_eq!(parse_worker_count("0"), Ok(1));
        // regression: these used to silently fall back to 1 thread
        for bad in ["eight", "", "4x", "-2", "1.5"] {
            let err = parse_worker_count(bad).unwrap_err();
            assert!(err.contains("SPARSEGPT_THREADS"), "{bad:?} -> {err}");
        }
    }
}
