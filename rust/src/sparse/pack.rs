//! Packed weight matrices for the serving path: one pruned linear layer in
//! the storage/compute format the sparse engine will execute it in —
//! CSR for unstructured sparsity, bitmask-packed n:m for the structured
//! regime, or plain dense for layers the pruner left (nearly) dense.
//!
//! Packing is *lossless over the value grid the kernels see*: `to_dense`
//! of a packed matrix equals the pruned dense matrix elementwise, and the
//! packed `layer` kernels visit surviving weights in the same order as
//! `dense_layer`, so packed decode is element-identical to dense decode
//! (pinned by the proptests).

use anyhow::{anyhow, bail, Result};

use crate::sparse::{dense_layer, CsrMatrix, NmMatrix};
use crate::tensor::Tensor;

/// Which storage format to pack a matrix into.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PackFormat {
    /// per-matrix choice: n:m when the pattern holds, CSR when sparse
    /// enough, dense otherwise
    Auto,
    Dense,
    Csr,
    Nm(usize, usize),
}

impl PackFormat {
    pub fn parse(s: &str) -> Result<PackFormat> {
        match s {
            "auto" => Ok(PackFormat::Auto),
            "dense" => Ok(PackFormat::Dense),
            "csr" => Ok(PackFormat::Csr),
            other => {
                let (n, m) = other.split_once(':').ok_or_else(|| {
                    anyhow!("unknown pack format {other:?} (expected auto|dense|csr|n:m)")
                })?;
                let (n, m): (usize, usize) = (n.parse()?, m.parse()?);
                if n == 0 || m <= n || m > 8 {
                    bail!("invalid n:m pack format {other:?} (need 0 < n < m <= 8)");
                }
                Ok(PackFormat::Nm(n, m))
            }
        }
    }

    pub fn label(&self) -> String {
        match self {
            PackFormat::Auto => "auto".to_string(),
            PackFormat::Dense => "dense".to_string(),
            PackFormat::Csr => "csr".to_string(),
            PackFormat::Nm(n, m) => format!("{n}:{m}"),
        }
    }
}

/// How the packer chooses formats under [`PackFormat::Auto`].
#[derive(Clone, Copy, Debug)]
pub struct PackPolicy {
    pub format: PackFormat,
    /// `Auto` only: matrices denser than this stay dense (the "fall back
    /// to `dense_layer` for unpruned layers" rule).
    pub dense_cutoff: f64,
}

impl Default for PackPolicy {
    fn default() -> PackPolicy {
        PackPolicy { format: PackFormat::Auto, dense_cutoff: 0.95 }
    }
}

impl PackPolicy {
    pub fn with_format(format: PackFormat) -> PackPolicy {
        PackPolicy { format, ..Default::default() }
    }
}

/// One weight matrix in its serving format.
#[derive(Clone, Debug)]
pub enum PackedMatrix {
    Dense(Tensor),
    Csr(CsrMatrix),
    Nm(NmMatrix),
}

/// Does `w` satisfy the n:m constraint (at most n nonzeros per group)?
fn satisfies_nm(w: &Tensor, n: usize, m: usize) -> bool {
    if w.cols() % m != 0 {
        return false;
    }
    for r in 0..w.rows() {
        let row = w.row(r);
        for g in (0..w.cols()).step_by(m) {
            if row[g..g + m].iter().filter(|&&v| v != 0.0).count() > n {
                return false;
            }
        }
    }
    true
}

impl PackedMatrix {
    /// Pack a (pruned) dense matrix per `policy`.
    pub fn pack(w: &Tensor, policy: &PackPolicy) -> Result<PackedMatrix> {
        match policy.format {
            PackFormat::Dense => Ok(PackedMatrix::Dense(w.clone())),
            PackFormat::Csr => Ok(PackedMatrix::Csr(CsrMatrix::from_dense(w))),
            PackFormat::Nm(n, m) => Ok(PackedMatrix::Nm(NmMatrix::from_dense(w, n, m)?)),
            PackFormat::Auto => {
                let density = 1.0 - w.sparsity();
                if density > policy.dense_cutoff {
                    return Ok(PackedMatrix::Dense(w.clone()));
                }
                for (n, m) in [(2usize, 4usize), (4, 8)] {
                    // prefer the structured format only when the pattern is
                    // genuinely n:m (not merely implied by deep sparsity)
                    if density > (n as f64 / m as f64) * 0.5 && satisfies_nm(w, n, m) {
                        return Ok(PackedMatrix::Nm(NmMatrix::from_dense(w, n, m)?));
                    }
                }
                Ok(PackedMatrix::Csr(CsrMatrix::from_dense(w)))
            }
        }
    }

    pub fn rows(&self) -> usize {
        match self {
            PackedMatrix::Dense(t) => t.rows(),
            PackedMatrix::Csr(c) => c.rows,
            PackedMatrix::Nm(n) => n.rows,
        }
    }

    pub fn cols(&self) -> usize {
        match self {
            PackedMatrix::Dense(t) => t.cols(),
            PackedMatrix::Csr(c) => c.cols,
            PackedMatrix::Nm(n) => n.cols,
        }
    }

    /// Surviving (nonzero-representable) weights.
    pub fn nnz(&self) -> usize {
        match self {
            PackedMatrix::Dense(t) => t.data().iter().filter(|&&v| v != 0.0).count(),
            PackedMatrix::Csr(c) => c.nnz(),
            PackedMatrix::Nm(n) => n.values.iter().filter(|&&v| v != 0.0).count(),
        }
    }

    pub fn density(&self) -> f64 {
        self.nnz() as f64 / (self.rows() * self.cols()).max(1) as f64
    }

    pub fn format_label(&self) -> &'static str {
        match self {
            PackedMatrix::Dense(_) => "dense",
            PackedMatrix::Csr(_) => "csr",
            PackedMatrix::Nm(_) => "nm",
        }
    }

    /// y = x @ W^T through the matching kernel. All three kernels share the
    /// token-major tile skeleton and visit surviving weights in the same
    /// order, so switching formats never perturbs f32 results.
    pub fn layer(&self, x: &Tensor) -> Tensor {
        match self {
            PackedMatrix::Dense(t) => dense_layer(x, t),
            PackedMatrix::Csr(c) => c.layer(x),
            PackedMatrix::Nm(n) => n.layer(x),
        }
    }

    pub fn to_dense(&self) -> Tensor {
        match self {
            PackedMatrix::Dense(t) => t.clone(),
            PackedMatrix::Csr(c) => c.to_dense(),
            PackedMatrix::Nm(n) => n.to_dense(),
        }
    }

    // ---- byte serialization (little-endian; the sparse_store sections) ----

    const TAG_DENSE: u8 = 0;
    const TAG_CSR: u8 = 1;
    const TAG_NM: u8 = 2;

    /// Append this matrix's byte encoding to `out`.
    ///
    /// ```text
    /// dense: tag=0 u8, pad[3], rows u32, cols u32, f32 * rows*cols
    /// csr:   tag=1 u8, pad[3], rows u32, cols u32, nnz u64,
    ///        row_ptr u32 * (rows+1), col_idx u32 * nnz, values f32 * nnz
    /// nm:    tag=2 u8, n u8, m u8, pad[1], rows u32, cols u32, kept u64,
    ///        group bitmasks u8 * (rows*cols/m)  (bit j = column g*m+j kept),
    ///        pad to 4, values f32 * kept        (set bits, ascending)
    /// ```
    pub fn write_bytes(&self, out: &mut Vec<u8>) {
        match self {
            PackedMatrix::Dense(t) => {
                out.push(Self::TAG_DENSE);
                out.extend_from_slice(&[0u8; 3]);
                out.extend_from_slice(&(t.rows() as u32).to_le_bytes());
                out.extend_from_slice(&(t.cols() as u32).to_le_bytes());
                for v in t.data() {
                    out.extend_from_slice(&v.to_le_bytes());
                }
            }
            PackedMatrix::Csr(c) => {
                out.push(Self::TAG_CSR);
                out.extend_from_slice(&[0u8; 3]);
                out.extend_from_slice(&(c.rows as u32).to_le_bytes());
                out.extend_from_slice(&(c.cols as u32).to_le_bytes());
                out.extend_from_slice(&(c.nnz() as u64).to_le_bytes());
                for v in &c.row_ptr {
                    out.extend_from_slice(&v.to_le_bytes());
                }
                for v in &c.col_idx {
                    out.extend_from_slice(&v.to_le_bytes());
                }
                for v in &c.values {
                    out.extend_from_slice(&v.to_le_bytes());
                }
            }
            PackedMatrix::Nm(nm) => {
                debug_assert!(nm.m <= 8, "n:m bitmask packing needs m <= 8");
                out.push(Self::TAG_NM);
                out.push(nm.n as u8);
                out.push(nm.m as u8);
                out.push(0u8);
                out.extend_from_slice(&(nm.rows as u32).to_le_bytes());
                out.extend_from_slice(&(nm.cols as u32).to_le_bytes());
                let groups = nm.rows * nm.cols / nm.m;
                // group bitmasks + surviving values in ascending-bit order
                let mut masks = vec![0u8; groups];
                let mut kept = Vec::new();
                for g in 0..groups {
                    // slots are stored in ascending within-group offset
                    // order by `NmMatrix::from_dense`, zero-padded at the end
                    for i in 0..nm.n {
                        let k = g * nm.n + i;
                        if nm.values[k] != 0.0 {
                            masks[g] |= 1u8 << nm.offsets[k];
                            kept.push(nm.values[k]);
                        }
                    }
                }
                out.extend_from_slice(&(kept.len() as u64).to_le_bytes());
                out.extend_from_slice(&masks);
                while out.len() % 4 != 0 {
                    out.push(0u8);
                }
                for v in &kept {
                    out.extend_from_slice(&v.to_le_bytes());
                }
            }
        }
    }

    /// Decode one matrix from `buf`; returns it plus the bytes consumed.
    pub fn read_bytes(buf: &[u8]) -> Result<(PackedMatrix, usize)> {
        let mut r = Reader { buf, i: 0 };
        let tag = r.u8()?;
        match tag {
            Self::TAG_DENSE => {
                r.skip(3)?;
                let rows = r.u32()? as usize;
                let cols = r.u32()? as usize;
                let data = r.f32s(rows * cols)?;
                Ok((PackedMatrix::Dense(Tensor::new(vec![rows, cols], data)), r.i))
            }
            Self::TAG_CSR => {
                r.skip(3)?;
                let rows = r.u32()? as usize;
                let cols = r.u32()? as usize;
                let nnz = r.u64()? as usize;
                if nnz > rows * cols {
                    bail!("csr nnz {nnz} exceeds {rows}x{cols}");
                }
                let row_ptr = r.u32s(rows + 1)?;
                if row_ptr.last().copied().unwrap_or(0) as usize != nnz {
                    bail!("csr row_ptr does not end at nnz");
                }
                if row_ptr.first().copied().unwrap_or(0) != 0
                    || row_ptr.windows(2).any(|w| w[0] > w[1])
                {
                    // non-monotonic pointers would make the kernels slice
                    // values[lo..hi] with lo > hi and panic mid-decode
                    bail!("csr row_ptr is not monotonically non-decreasing from 0");
                }
                let col_idx = r.u32s(nnz)?;
                if col_idx.iter().any(|&c| c as usize >= cols) {
                    bail!("csr column index out of range");
                }
                let values = r.f32s(nnz)?;
                Ok((PackedMatrix::Csr(CsrMatrix { rows, cols, row_ptr, col_idx, values }), r.i))
            }
            Self::TAG_NM => {
                let n = r.u8()? as usize;
                let m = r.u8()? as usize;
                r.skip(1)?;
                let rows = r.u32()? as usize;
                let cols = r.u32()? as usize;
                if n == 0 || m <= n || m > 8 || cols % m != 0 {
                    bail!("nm header invalid: {n}:{m} over {rows}x{cols}");
                }
                let kept_n = r.u64()? as usize;
                let groups = rows * cols / m;
                let masks = r.bytes(groups)?.to_vec();
                r.align4()?;
                let kept = r.f32s(kept_n)?;
                // rebuild the zero-padded (values, offsets) slot arrays
                let mut values = Vec::with_capacity(groups * n);
                let mut offsets = Vec::with_capacity(groups * n);
                let mut ki = 0usize;
                for &mask in &masks {
                    let mut cnt = 0usize;
                    for j in 0..m {
                        if mask & (1u8 << j) != 0 {
                            if cnt == n || ki >= kept.len() {
                                bail!("nm group overflows {n}:{m} on decode");
                            }
                            values.push(kept[ki]);
                            offsets.push(j as u8);
                            ki += 1;
                            cnt += 1;
                        }
                    }
                    while cnt < n {
                        values.push(0.0);
                        offsets.push(0);
                        cnt += 1;
                    }
                }
                if ki != kept.len() {
                    bail!("nm kept-value count mismatch");
                }
                Ok((PackedMatrix::Nm(NmMatrix { n, m, rows, cols, values, offsets }), r.i))
            }
            other => bail!("unknown packed-matrix tag {other}"),
        }
    }
}

struct Reader<'a> {
    buf: &'a [u8],
    i: usize,
}

impl<'a> Reader<'a> {
    fn bytes(&mut self, n: usize) -> Result<&'a [u8]> {
        if self.i + n > self.buf.len() {
            bail!("packed matrix truncated at byte {}", self.i);
        }
        let out = &self.buf[self.i..self.i + n];
        self.i += n;
        Ok(out)
    }

    fn skip(&mut self, n: usize) -> Result<()> {
        self.bytes(n).map(|_| ())
    }

    fn align4(&mut self) -> Result<()> {
        while self.i % 4 != 0 {
            self.skip(1)?;
        }
        Ok(())
    }

    fn u8(&mut self) -> Result<u8> {
        Ok(self.bytes(1)?[0])
    }

    fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.bytes(4)?.try_into().unwrap()))
    }

    fn u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.bytes(8)?.try_into().unwrap()))
    }

    fn u32s(&mut self, n: usize) -> Result<Vec<u32>> {
        let b = self.bytes(n * 4)?;
        Ok(b.chunks_exact(4).map(|c| u32::from_le_bytes(c.try_into().unwrap())).collect())
    }

    fn f32s(&mut self, n: usize) -> Result<Vec<f32>> {
        let b = self.bytes(n * 4)?;
        Ok(b.chunks_exact(4).map(|c| f32::from_le_bytes(c.try_into().unwrap())).collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::solver::magnitude::{magnitude_prune, magnitude_prune_nm};
    use crate::util::prng::Rng;

    fn random(seed: u64, r: usize, c: usize) -> Tensor {
        let mut rng = Rng::new(seed);
        Tensor::new(vec![r, c], (0..r * c).map(|_| rng.normal_f32()).collect())
    }

    /// Make row 0's first 8 columns dense so no n:m pattern (m <= 8) holds
    /// — keeps "unstructured but n:m-by-luck" out of deterministic tests.
    fn break_nm(mut w: Tensor) -> Tensor {
        for j in 0..8.min(w.cols()) {
            w.set2(0, j, 1.0 + j as f32);
        }
        w
    }

    #[test]
    fn auto_picks_by_structure() {
        let policy = PackPolicy::default();
        let dense = random(0, 8, 16);
        assert_eq!(PackedMatrix::pack(&dense, &policy).unwrap().format_label(), "dense");
        let w50 = break_nm(magnitude_prune(&random(1, 8, 16), 0.5).0);
        assert_eq!(PackedMatrix::pack(&w50, &policy).unwrap().format_label(), "csr");
        let (w24, _) = magnitude_prune_nm(&random(2, 8, 16), 2, 4);
        assert_eq!(PackedMatrix::pack(&w24, &policy).unwrap().format_label(), "nm");
    }

    #[test]
    fn forced_formats_respected() {
        let w = break_nm(magnitude_prune(&random(3, 6, 12), 0.5).0);
        for (fmt, label) in [
            (PackFormat::Dense, "dense"),
            (PackFormat::Csr, "csr"),
            (PackFormat::Auto, "csr"),
        ] {
            let p = PackedMatrix::pack(&w, &PackPolicy::with_format(fmt)).unwrap();
            assert_eq!(p.format_label(), label);
            assert_eq!(p.to_dense(), w);
        }
        // forcing n:m on a non-conforming matrix is a clean error
        let nm24 = PackPolicy::with_format(PackFormat::Nm(2, 4));
        assert!(PackedMatrix::pack(&random(3, 6, 12), &nm24).is_err());
    }

    #[test]
    fn bytes_roundtrip_all_formats() {
        let (w50, _) = magnitude_prune(&random(4, 9, 24), 0.6);
        let (w24, _) = magnitude_prune_nm(&random(5, 8, 24), 2, 4);
        let pol = PackPolicy::with_format;
        let cases = [
            PackedMatrix::pack(&random(6, 5, 7), &pol(PackFormat::Dense)).unwrap(),
            PackedMatrix::pack(&w50, &pol(PackFormat::Csr)).unwrap(),
            PackedMatrix::pack(&w24, &pol(PackFormat::Nm(2, 4))).unwrap(),
        ];
        for p in cases {
            let mut buf = Vec::new();
            p.write_bytes(&mut buf);
            let (q, used) = PackedMatrix::read_bytes(&buf).unwrap();
            assert_eq!(used, buf.len());
            assert_eq!(q.format_label(), p.format_label());
            assert_eq!(q.to_dense(), p.to_dense());
            assert_eq!(q.nnz(), p.nnz());
        }
    }

    #[test]
    fn layer_dispatch_matches_dense_kernel() {
        let (w, _) = magnitude_prune(&random(7, 16, 32), 0.5);
        let x = random(8, 5, 32);
        let want = dense_layer(&x, &w);
        for fmt in [PackFormat::Dense, PackFormat::Csr] {
            let p = PackedMatrix::pack(&w, &PackPolicy::with_format(fmt)).unwrap();
            assert_eq!(p.layer(&x).data(), want.data(), "{}", p.format_label());
        }
        let (w24, _) = magnitude_prune_nm(&random(9, 16, 32), 2, 4);
        let want = dense_layer(&x, &w24);
        let p = PackedMatrix::pack(&w24, &PackPolicy::with_format(PackFormat::Nm(2, 4))).unwrap();
        assert_eq!(p.layer(&x).data(), want.data());
    }

    #[test]
    fn truncated_bytes_rejected() {
        let (w, _) = magnitude_prune(&random(10, 4, 8), 0.5);
        let p = PackedMatrix::pack(&w, &PackPolicy::with_format(PackFormat::Csr)).unwrap();
        let mut buf = Vec::new();
        p.write_bytes(&mut buf);
        for cut in [0, 1, 5, buf.len() - 1] {
            assert!(PackedMatrix::read_bytes(&buf[..cut]).is_err(), "cut {cut}");
        }
        assert!(PackedMatrix::read_bytes(&[9, 0, 0, 0]).is_err()); // bad tag
    }

    #[test]
    fn csr_non_monotonic_row_ptr_rejected() {
        // passes the nnz/col-range checks but would slice values[3..2] in
        // the kernels — must be a clean decode error, not a later panic
        let bad = CsrMatrix {
            rows: 2,
            cols: 4,
            row_ptr: vec![0, 3, 2],
            col_idx: vec![0, 1],
            values: vec![1.0, 2.0],
        };
        let mut buf = Vec::new();
        PackedMatrix::Csr(bad).write_bytes(&mut buf);
        assert!(PackedMatrix::read_bytes(&buf).is_err());
    }

    #[test]
    fn format_parse_label_round_trip() {
        for s in ["auto", "dense", "csr", "2:4", "4:8"] {
            assert_eq!(PackFormat::parse(s).unwrap().label(), s);
        }
        for bad in ["", "nm", "4:2", "0:4", "2:16"] {
            assert!(PackFormat::parse(bad).is_err(), "{bad:?}");
        }
    }
}
